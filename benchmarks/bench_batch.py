"""Batch-engine benchmarks: one fleet compilation vs sequential solves.

Measures the serving-engine economics of ``core/batch.py`` (DESIGN.md §8):
``saif_batch`` at B=16 against 16 sequential warm ``saif`` calls on the
CI shape, across the fleet screen modes (default bitwise per-problem
scans vs the opt-in shared-X ``matmul`` fast path), plus the K-fold
``cv_path`` against solving every (fold, lambda) cell serially.

Acceptance (asserted):
  * the fleet runs in exactly ONE ``_saif_batch_jit`` compilation;
  * >= 2x over 16 sequential warm solves on the 2-core CPU CI.

Why the CPU gate is 2x and not more: with the bitwise-parity contract
every per-problem active-block stage must execute the literal serial
computation (lax.map) — batched reductions re-associate and lockstep
sweeps hit XLA:CPU gather overheads ~30x the serial dynamic-slice steps
(both measured; see DESIGN.md §8) — so the CPU fleet only amortizes the
per-solve fixed costs (driver, preprocessing, dispatch, syncs) and the
shared screening traffic. Measured headroom on the CI shape is ~2.5-2.7x;
the >= 4x regime belongs to the problem-gridded Pallas kernels on a real
TPU, where the fleet's bursts share the VMEM-resident design. The JSON
records both so the trajectory is tracked per PR.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import simulation_data
from repro.core import (SaifConfig, cv_path, get_loss, saif, saif_batch,
                        saif_batch_compile_count)
from repro.core.duality import lambda_max

B_FLEET = 16        # the acceptance fleet size
MIN_SPEEDUP = 2.0   # CPU-CI acceptance (see module docstring)


def _fleet_problem(n, p, b, frac, seed=1):
    loss = get_loss("least_squares")
    X, _, _ = simulation_data(n=n, p=p, seed=0)
    rng = np.random.default_rng(seed)
    Ys, lams = [], []
    for _ in range(b):
        w = np.zeros(p)
        w[rng.choice(p, 15, replace=False)] = rng.uniform(-1, 1, 15)
        y = X @ w + rng.normal(0, 1, n)
        Ys.append(y)
        lams.append(frac * float(lambda_max(loss, jnp.asarray(X),
                                            jnp.asarray(y))))
    return X, np.stack(Ys), lams


def _min_of(fn, reps):
    best = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(jax.tree.leaves(out)[0])
        best = min(best, time.perf_counter() - t0)
    return best


def run_fleet_rows(full: bool = False):
    n, p = (100, 2000) if full else (50, 500)
    frac, reps = 0.8, 4
    X, Y, lams = _fleet_problem(n, p, B_FLEET, frac)
    cfg = SaifConfig(eps=1e-6, inner_epochs=3, polish_factor=4,
                     inner_backend="gram")
    lam_arr = jnp.asarray(lams)

    def sequential():
        outs = [saif(X, Y[i], lams[i], cfg) for i in range(B_FLEET)]
        return outs[-1].beta

    # warm both paths (compiles excluded: the comparison is warm serving)
    sequential()
    c0 = saif_batch_compile_count()
    saif_batch(X, Y, lam_arr, cfg)
    n_comp = (saif_batch_compile_count() - c0
              if c0 >= 0 else None)
    if n_comp is not None:
        assert n_comp == 1, (
            f"fleet used {n_comp} _saif_batch_jit compilations (contract: 1)")

    t_seq = _min_of(sequential, reps)
    rows = []
    for screen in ("jnp", "matmul"):
        cfg_f = dataclasses.replace(cfg, screen_backend=screen)
        saif_batch(X, Y, lam_arr, cfg_f)    # warm this screen mode
        t_fleet = _min_of(lambda: saif_batch(X, Y, lam_arr, cfg_f).beta,
                          reps)
        speedup = t_seq / max(t_fleet, 1e-12)
        rows.append({
            "b": B_FLEET, "n": n, "p": p, "lam_frac": frac,
            "screen": screen, "seq_s": round(t_seq, 4),
            "fleet_s": round(t_fleet, 4), "speedup": round(speedup, 3),
            "fleet_compilations": n_comp, "min_speedup": MIN_SPEEDUP,
        })
        print(f"[batch] B={B_FLEET} n={n} p={p} screen={screen} "
              f"seq={t_seq*1e3:.0f}ms fleet={t_fleet*1e3:.0f}ms "
              f"speedup={speedup:.2f}x (gate {MIN_SPEEDUP}x, compiles="
              f"{n_comp})")
    best = max(r["speedup"] for r in rows)
    assert best >= MIN_SPEEDUP, (
        f"saif_batch(B={B_FLEET}) reached only {best:.2f}x over sequential "
        f"warm solves (CPU acceptance {MIN_SPEEDUP}x)")
    return rows


def run_cv_row(full: bool = False):
    n, p, K, L = (100, 1000, 5, 10) if full else (60, 300, 4, 6)
    loss = get_loss("least_squares")
    X, _, _ = simulation_data(n=n, p=p, seed=3)
    rng = np.random.default_rng(4)
    w = np.zeros(p)
    w[rng.choice(p, 12, replace=False)] = rng.uniform(-1, 1, 12)
    y = X @ w + rng.normal(0, 1, n)
    lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    lams = np.geomspace(0.8 * lmax, 0.1 * lmax, L)
    cfg = SaifConfig(eps=1e-6, inner_epochs=3, polish_factor=4,
                     inner_backend="gram")

    from repro.core import kfold_weights
    W = np.asarray(kfold_weights(n, K, seed=0))

    def sequential_cells():
        outs = []
        for lam in lams:
            for k in range(K):
                tr = W[k] > 0
                outs.append(saif(X[tr], y[tr], float(lam),
                                 dataclasses.replace(cfg,
                                                     use_seq_ball=False)))
        return outs[-1].beta

    sequential_cells()
    res = cv_path(X, y, lams, n_folds=K, config=cfg, refit=False)
    t_cells = _min_of(sequential_cells, 2)
    t_cv = _min_of(lambda: cv_path(X, y, lams, n_folds=K, config=cfg,
                                   refit=False).cv_mean, 2)
    row = {
        "k_folds": K, "n_lambda": L, "n": n, "p": p,
        "cells_seq_s": round(t_cells, 4), "cv_path_s": round(t_cv, 4),
        "speedup": round(t_cells / max(t_cv, 1e-12), 3),
        "cv_compilations": res.n_compilations,
        "best_lam_frac": round(float(res.best_lam) / lmax, 4),
    }
    print(f"[batch] cv_path {K}x{L} cells={t_cells*1e3:.0f}ms "
          f"cv={t_cv*1e3:.0f}ms speedup={row['speedup']:.2f}x "
          f"compiles={res.n_compilations}")
    return [row]


def run(full: bool = False):
    return run_fleet_rows(full=full) + run_cv_row(full=full)


if __name__ == "__main__":
    run()
