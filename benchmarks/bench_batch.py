"""Batch-engine benchmarks: one fleet compilation vs sequential solves.

Measures the serving-engine economics of ``core/batch.py`` (DESIGN.md
§8/§11): ``fleet_solve`` at B=16 against 16 sequential warm ``saif``
calls on the CI shape, across BOTH parity contracts:

  * ``parity="bitwise"`` (default) with the per-problem ``jnp`` scans
    and the shared-X ``matmul`` screen request (the resolve policy may
    downgrade matmul to jnp on CPU below the measured B*p crossover —
    the row records what actually ran);
  * ``parity="fast"`` (ISSUE 7) — lockstep relaxed-parity engine with
    certified mixed-precision screening at screen_dtype in
    {working, float32, bfloat16}.

Acceptance (asserted):
  * every fleet mode runs warm at ZERO extra compilations (one
    ``_saif_batch_jit``/``_saif_batch_fast_jit`` compile per mode);
  * bitwise >= 2x over 16 sequential warm solves on the 2-core CPU CI;
  * fast    >= 4x (the broken 2.6x ceiling, ISSUE 7 acceptance) — and
    every fast solution passes the working-precision KKT certificate.

Why the bitwise CPU gate stays 2x: the bitwise contract forces every
per-problem active-block stage through the literal serial computation
(lax.map) — batched reductions re-associate and lockstep sweeps hit
XLA:CPU gather overheads ~30x the serial dynamic-slice steps — so it
only amortizes fixed costs and shared screening traffic (measured
~2.5-2.7x). parity="fast" is allowed to re-associate (DESIGN.md §11):
batched Gram sweeps, one-gemm screens and an f32/bf16 decision pipeline
(f64 top_k alone is ~60x an f32 one on XLA:CPU) take the same fleet to
8x+, with safety carried by the widened-radius screening certificate
and the final working-precision KKT check. The JSON records every row
so the trajectory is tracked per PR.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import simulation_data
from repro.core import (SaifConfig, cv_path, fleet_solve, get_loss, saif,
                        saif_batch_compile_count)
from repro.core.duality import kkt_residual, lambda_max
from repro.core.screen_backend import resolve_batch_screen

B_FLEET = 16          # the acceptance fleet size
MIN_SPEEDUP = 2.0     # CPU-CI acceptance, parity="bitwise" (docstring)
MIN_SPEEDUP_FAST = 4.0  # CPU-CI acceptance, parity="fast" (ISSUE 7)

# (parity, screen_backend, screen_dtype) per benchmarked fleet mode
FLEET_MODES = [
    ("bitwise", "jnp", "working"),
    ("bitwise", "matmul", "working"),
    ("fast", "jnp", "working"),
    ("fast", "jnp", "float32"),
    ("fast", "jnp", "bfloat16"),
]


def _fleet_problem(n, p, b, frac, seed=1):
    loss = get_loss("least_squares")
    X, _, _ = simulation_data(n=n, p=p, seed=0)
    rng = np.random.default_rng(seed)
    Ys, lams = [], []
    for _ in range(b):
        w = np.zeros(p)
        w[rng.choice(p, 15, replace=False)] = rng.uniform(-1, 1, 15)
        y = X @ w + rng.normal(0, 1, n)
        Ys.append(y)
        lams.append(frac * float(lambda_max(loss, jnp.asarray(X),
                                            jnp.asarray(y))))
    return X, np.stack(Ys), lams


def _min_of(fn, reps):
    best = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(jax.tree.leaves(out)[0])
        best = min(best, time.perf_counter() - t0)
    return best


def _assert_kkt(X, Y, lams, res, tag):
    """Working-precision KKT certificate on every fleet solution."""
    loss = get_loss("least_squares")
    Xj = jnp.asarray(X)
    for i in range(Y.shape[0]):
        kkt = float(kkt_residual(loss, Xj, jnp.asarray(Y[i]), res.beta[i],
                                 float(lams[i])))
        assert kkt <= 1e-6 * lams[i], (
            f"{tag}: problem {i} fails KKT ({kkt:.3e} vs lam {lams[i]:.3e})")


def run_fleet_rows(full: bool = False):
    n, p = (100, 2000) if full else (50, 500)
    frac, reps = 0.8, 4
    X, Y, lams = _fleet_problem(n, p, B_FLEET, frac)
    cfg0 = SaifConfig(eps=1e-6, inner_epochs=3, polish_factor=4,
                      inner_backend="gram")
    lam_arr = jnp.asarray(lams)

    def sequential():
        outs = [saif(X, Y[i], lams[i], cfg0) for i in range(B_FLEET)]
        return outs[-1].beta

    sequential()                      # warm (compiles excluded: warm serving)
    t_seq = _min_of(sequential, reps)

    rows = []
    for parity, screen, screen_dtype in FLEET_MODES:
        cfg = dataclasses.replace(cfg0, parity=parity,
                                  screen_backend=screen,
                                  screen_dtype=screen_dtype)
        c0 = saif_batch_compile_count()
        res = fleet_solve(X, Y, lam_arr, cfg)          # warm this mode
        n_comp = saif_batch_compile_count() - c0 if c0 >= 0 else None
        if n_comp is not None:
            assert n_comp <= 1, (
                f"fleet mode {parity}/{screen}/{screen_dtype} used {n_comp} "
                f"compilations for one warmup (contract: 1)")
        _assert_kkt(X, Y, lams, res, f"{parity}/{screen_dtype}")
        c1 = saif_batch_compile_count()
        # a warm fleet solve is ~10ms — extra reps are cheap and the
        # min-of estimator needs them (the 4x gate must not flap on a
        # noisy 2-core CI box)
        t_fleet = _min_of(lambda: fleet_solve(X, Y, lam_arr, cfg).beta,
                          3 * reps)
        if c1 >= 0:
            assert saif_batch_compile_count() == c1, (
                f"fleet mode {parity}/{screen}/{screen_dtype} recompiled "
                f"during warm timing reps")
        speedup = t_seq / max(t_fleet, 1e-12)
        gate = MIN_SPEEDUP_FAST if parity == "fast" else MIN_SPEEDUP
        rows.append({
            "b": B_FLEET, "n": n, "p": p, "lam_frac": frac,
            "parity": parity, "screen": screen,
            "screen_resolved": resolve_batch_screen(screen, b=B_FLEET, p=p),
            "screen_dtype": screen_dtype,
            "seq_s": round(t_seq, 4), "fleet_s": round(t_fleet, 4),
            "speedup": round(speedup, 3), "fleet_compilations": n_comp,
            "min_speedup": gate,
        })
        print(f"[batch] B={B_FLEET} n={n} p={p} parity={parity} "
              f"screen={screen}->{rows[-1]['screen_resolved']} "
              f"dtype={screen_dtype} seq={t_seq*1e3:.0f}ms "
              f"fleet={t_fleet*1e3:.0f}ms speedup={speedup:.2f}x "
              f"(gate {gate}x, compiles={n_comp})")
    best_bitwise = max(r["speedup"] for r in rows if r["parity"] == "bitwise")
    assert best_bitwise >= MIN_SPEEDUP, (
        f"bitwise fleet (B={B_FLEET}) reached only {best_bitwise:.2f}x over "
        f"sequential warm solves (CPU acceptance {MIN_SPEEDUP}x)")
    best_fast = max(r["speedup"] for r in rows if r["parity"] == "fast")
    assert best_fast >= MIN_SPEEDUP_FAST, (
        f"fast fleet (B={B_FLEET}) reached only {best_fast:.2f}x over "
        f"sequential warm solves (CPU acceptance {MIN_SPEEDUP_FAST}x, "
        f"ISSUE 7)")
    return rows


def run_cv_row(full: bool = False):
    n, p, K, L = (100, 1000, 5, 10) if full else (60, 300, 4, 6)
    loss = get_loss("least_squares")
    X, _, _ = simulation_data(n=n, p=p, seed=3)
    rng = np.random.default_rng(4)
    w = np.zeros(p)
    w[rng.choice(p, 12, replace=False)] = rng.uniform(-1, 1, 12)
    y = X @ w + rng.normal(0, 1, n)
    lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    lams = np.geomspace(0.8 * lmax, 0.1 * lmax, L)
    cfg = SaifConfig(eps=1e-6, inner_epochs=3, polish_factor=4,
                     inner_backend="gram")

    from repro.core import kfold_weights
    W = np.asarray(kfold_weights(n, K, seed=0))

    def sequential_cells():
        outs = []
        for lam in lams:
            for k in range(K):
                tr = W[k] > 0
                outs.append(saif(X[tr], y[tr], float(lam),
                                 dataclasses.replace(cfg,
                                                     use_seq_ball=False)))
        return outs[-1].beta

    sequential_cells()
    res = cv_path(X, y, lams, n_folds=K, config=cfg, refit=False)
    t_cells = _min_of(sequential_cells, 2)
    t_cv = _min_of(lambda: cv_path(X, y, lams, n_folds=K, config=cfg,
                                   refit=False).cv_mean, 2)
    row = {
        "k_folds": K, "n_lambda": L, "n": n, "p": p,
        "cells_seq_s": round(t_cells, 4), "cv_path_s": round(t_cv, 4),
        "speedup": round(t_cells / max(t_cv, 1e-12), 3),
        "cv_compilations": res.n_compilations,
        "best_lam_frac": round(float(res.best_lam) / lmax, 4),
    }
    print(f"[batch] cv_path {K}x{L} cells={t_cells*1e3:.0f}ms "
          f"cv={t_cv*1e3:.0f}ms speedup={row['speedup']:.2f}x "
          f"compiles={res.n_compilations}")
    return [row]


def run(full: bool = False):
    return run_fleet_rows(full=full) + run_cv_row(full=full)


if __name__ == "__main__":
    run()
