"""Fig. 7 reproduction: tree fused LASSO — the SAIF fused *path* engine vs
the unscreened CM baseline (the paper's CVX stand-in), on the chain
(1-D fused lasso) workload.

Claim tracked by BENCH_fused.json (acceptance: >= 5x on the CI shape):
the compile-first fused path — transform once, ONE ``_saif_jit``
compilation for the whole descending lambda grid, slot-preserving warm
starts with the unpenalized b pinned resident — beats per-lambda
unscreened full-width CM solves by a large factor at equal objective.
``n_compilations`` is recorded per row; the path engine contract is 1.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import timed
from repro.core import (SaifConfig, fused_baseline_cm, fused_lambda_max,
                        fused_objective, fused_path)


def _chain_problem(n: int, p: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, p))
    beta = np.zeros(p)
    beta[: p // 8] = 2.0
    beta[p // 8: p // 4] = -1.0
    y = X @ beta + 0.1 * rng.normal(size=n)
    parent = np.arange(p) - 1          # chain tree (1-D fused lasso)
    return X, y, parent


def run(full: bool = False):
    n, p = (120, 800) if full else (60, 200)
    n_lams = 8
    eps = 1e-8
    X, y, parent = _chain_problem(n, p)
    lmax = fused_lambda_max(X, y, parent)
    lams = np.geomspace(0.7 * lmax, 0.02 * lmax, n_lams)
    cfg = SaifConfig(eps=eps)

    # Cold run: includes the fused grid's ONE _saif_jit compilation — the
    # engine contract (n_compilations) is read off this call. The timed
    # run then measures the warm engine, matching bench_path's protocol.
    t_cold = timed(lambda: fused_path(X, y, parent, lams, cfg),
                   warmup=False)
    n_comp = t_cold["out"].path.n_compilations
    t_path = timed(lambda: fused_path(X, y, parent, lams, cfg),
                   warmup=False)
    fp = t_path["out"]
    t_base = timed(
        lambda: [fused_baseline_cm(X, y, parent, float(lam), tol=eps)
                 for lam in lams],
        warmup=False)     # the baseline has no compile-first engine to warm
    bases = t_base["out"]

    obj_gap = max(
        fused_objective(X, y, parent, b_s, float(lam))
        - fused_objective(X, y, parent, b_b, float(lam))
        for lam, b_s, b_b in zip(fp.lams, fp.betas, bases))
    speedup = t_base["seconds"] / max(t_path["seconds"], 1e-12)
    row = {"n": n, "p": p, "n_lams": n_lams,
           "saif_path_s": t_path["seconds"],
           "saif_path_cold_s": t_cold["seconds"],
           "baseline_s": t_base["seconds"],
           "speedup": speedup,
           "n_compilations": n_comp,
           "max_obj_gap": float(obj_gap)}
    print(f"[fig7] n={n} p={p} lams={n_lams} "
          f"saif_path={t_path['seconds']:.2f}s "
          f"(cold {t_cold['seconds']:.2f}s, {n_comp} compiles) "
          f"baseline={t_base['seconds']:.2f}s speedup={speedup:.1f}x "
          f"obj_gap={obj_gap:.2e}")
    return [row]


if __name__ == "__main__":
    run(full=True)
