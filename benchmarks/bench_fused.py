"""Fig. 7 reproduction: tree fused LASSO — SAIF vs unscreened baseline
(the paper's CVX stand-in). Claim: large speedup at equal objective."""
from __future__ import annotations

import numpy as np

from benchmarks.common import timed
from repro.core import SaifConfig, fused_baseline_cm, fused_objective, saif_fused


def run(full: bool = False):
    rng = np.random.default_rng(0)
    n, p = (120, 800) if full else (60, 200)
    X = rng.normal(size=(n, p))
    beta = np.zeros(p)
    beta[: p // 8] = 2.0
    beta[p // 8: p // 4] = -1.0
    y = X @ beta + 0.1 * rng.normal(size=n)
    parent = np.arange(p) - 1          # chain tree (1-D fused lasso)
    rows = []
    for lam in (1.0, 5.0, 20.0):
        t_s = timed(lambda: saif_fused(X, y, parent, lam,
                                       SaifConfig(eps=1e-8)),
                    warmup=False)["seconds"]
        t_b = timed(lambda: fused_baseline_cm(X, y, parent, lam, tol=1e-8),
                    warmup=False)["seconds"]
        b_s, _ = saif_fused(X, y, parent, lam, SaifConfig(eps=1e-8))
        b_b = fused_baseline_cm(X, y, parent, lam, tol=1e-8)
        o_s = fused_objective(X, y, parent, b_s, lam)
        o_b = fused_objective(X, y, parent, b_b, lam)
        rows.append({"lam": lam, "saif_s": t_s, "baseline_s": t_b,
                     "obj_gap": o_s - o_b})
        print(f"[fig7] lam={lam} saif={t_s:.2f}s baseline={t_b:.2f}s "
              f"speedup={t_b/t_s:.1f}x obj_gap={o_s-o_b:.2e}")
    return rows


if __name__ == "__main__":
    run(full=True)
