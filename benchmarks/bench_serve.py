"""Serving benchmark: hot-session request latency vs cold per-request
solves (ISSUE 5 acceptance — BENCH_serve.json).

The comparison is the session API's reason to exist. A server WITHOUT a
session answers each request from scratch: fresh process-equivalent state
(``jax.clear_caches()``), fresh preparation, fresh ``_saif_jit``
compilation, then the solve. A server WITH a session pays preparation
once at ``open_session`` and compilation once per static key, after
which every request runs at solve cost with device-resident warm
buffers.

Protocol (CI shape): R scalar requests cycling over a few lambdas inside
one pow2 h bucket (one static key — the honest serving regime: clients
ask for nearby lambdas far more often than for new shapes).

  * cold: per request, ``jax.clear_caches()`` + ``saif(X, y, lam)`` —
    prep + compile + solve every time;
  * hot: one ``open_session``; after a warmup pass over the distinct
    lambdas, the measured pass must add ZERO compilations (asserted via
    ``session.compile_stats()``).

Acceptance (asserted): hot-session latency >= 3x better than cold
per-request solves. On CPU CI the gap is dominated by the per-request
XLA compile (seconds) vs the warm solve (milliseconds), so the measured
ratio is typically 2-3 orders of magnitude; the 3x gate is deliberately
conservative — it survives a hypothetical persistent-compilation-cache
world where cold requests only re-pay preparation + dispatch.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import simulation_data

MIN_SPEEDUP = 3.0   # ISSUE 5 acceptance gate
N_REQUESTS = 6      # cold requests are expensive (a compile each)
# ISSUE 8 acceptance gates (the async server): coalesced microbatches
# must beat serially servicing the same stream through one hot
# ServingSession by >= 3x at zero engine compiles in the measured
# steady state; the seeded Poisson pass gates p99 and steady-state
# compiles across a heterogeneous shape mix. The coalesced stream runs
# parity="fast" (the lockstep fleet engine with a working-precision KKT
# certificate per member) — the bitwise fleet replays the serial float
# path step-for-step, which bounds its ceiling below the 3x gate by
# construction; fast parity is the serving configuration (DESIGN.md
# §11/§12) and every member is still individually certified.
MIN_COALESCED_SPEEDUP = 3.0
POISSON_REQUESTS = 32
POISSON_MEAN_GAP_S = 0.003   # seeded exponential inter-arrival mean
P99_BOUND_S = 2.0            # smoke bound on the reduced CI shape
# ISSUE 6 acceptance gate: the fault-tolerant runtime's verdict plumbing
# (admission + KKT certification + ladder bookkeeping) may cost the
# happy-path hot request at most 10% (+ an absolute slack for the
# certificate jit dispatch and CI timer noise)
MAX_VERDICT_OVERHEAD = 0.10
VERDICT_SLACK_S = 1.5e-3


def _problem(n, p, seed=0):
    import jax.numpy as jnp

    from repro.core import get_loss
    from repro.core.duality import lambda_max

    X, _, _ = simulation_data(n=n, p=p, seed=seed)
    rng = np.random.default_rng(seed + 1)
    w = np.zeros(p)
    w[rng.choice(p, 15, replace=False)] = rng.uniform(-1, 1, 15)
    y = X @ w + rng.normal(0, 1, n)
    lmax = float(lambda_max(get_loss("least_squares"),
                            jnp.asarray(X), jnp.asarray(y)))
    return X, y, lmax


def _block(res):
    jax.block_until_ready(jax.tree.leaves(res)[0])


def run(full: bool = False):
    from repro import Problem, SaifConfig, Scalar, open_session
    from repro.core import saif

    n, p = (100, 2000) if full else (50, 500)
    cfg = SaifConfig(eps=1e-6, inner_epochs=3, polish_factor=4)
    X, y, lmax = _problem(n, p)
    # the request stream: lambdas inside one h bucket (checked below by
    # the zero-new-compilations assertion), revisited round-robin
    fracs = [0.30, 0.28, 0.26, 0.29, 0.27, 0.25][:N_REQUESTS]
    lams = [f * lmax for f in fracs]

    # --- cold: per-request prep + compile + solve ------------------------
    t_cold = 0.0
    for lam in lams:
        jax.clear_caches()
        t0 = time.perf_counter()
        _block(saif(X, y, lam, cfg))
        t_cold += time.perf_counter() - t0
    cold_per_req = t_cold / len(lams)

    # --- hot: one session, measured pass after warmup --------------------
    jax.clear_caches()
    t0 = time.perf_counter()
    session = open_session(Problem(X=X, y=y), cfg)
    t_open = time.perf_counter() - t0
    # warmup: TWO passes over the distinct lambdas. The first pass may
    # grow the warm capacity mid-stream (a smaller lambda can bump the h
    # bucket), so a lambda served early can still map to a fresh static
    # key on its next visit; the second pass compiles any such residue —
    # after it, the key set is closed and the measured pass is pure
    # serving.
    for _ in range(2):
        for lam in lams:
            _block(session.solve(Scalar(lam, warm=True)))
    stats0 = session.compile_stats()
    t_hot = 0.0
    for lam in lams:                      # measured: the hot request loop
        t0 = time.perf_counter()
        _block(session.solve(Scalar(lam, warm=True)))
        t_hot += time.perf_counter() - t0
    hot_per_req = t_hot / len(lams)
    stats1 = session.compile_stats()
    hot_compiles = stats1.since_open - stats0.since_open
    assert hot_compiles == 0, (
        f"hot session recompiled {hot_compiles} times during the "
        f"measured pass (contract: one compilation per static key)")

    # --- served: the same hot stream through the fault-tolerant runtime --
    # (ISSUE 6): admission + retry wrapper + KKT certificate + verdict.
    # Warmup compiles the certificate jit (outside the engine caches);
    # the measured pass must stay within MAX_VERDICT_OVERHEAD of the
    # bare hot session AND keep the zero-new-engine-compiles contract.
    from repro.core.serving import open_serving
    srv = open_serving(Problem(X=X, y=y), cfg)
    for _ in range(2):
        for lam in lams:
            _block(srv.solve(Scalar(lam, warm=True)).value)
    sstats0, engine0 = srv.stats(), srv.compile_stats().total
    t_served = 0.0
    for lam in lams:
        t0 = time.perf_counter()
        out = srv.solve(Scalar(lam, warm=True))
        _block(out.value)
        t_served += time.perf_counter() - t0
        assert out.verdict.ok and not out.verdict.degraded
    served_per_req = t_served / len(lams)
    sstats1 = srv.stats()
    assert srv.compile_stats().total == engine0, (
        "verdict plumbing compiled new engine keys on the happy path")
    degraded_rate = (sstats1.degraded - sstats0.degraded) / len(lams)
    retry_count = sstats1.retries - sstats0.retries
    kkt_check_ms = (sstats1.kkt_check_ms - sstats0.kkt_check_ms) / len(lams)

    speedup = cold_per_req / max(hot_per_req, 1e-12)
    served_speedup = cold_per_req / max(served_per_req, 1e-12)
    row = {
        "n": n, "p": p, "requests": len(lams),
        "cold_s_per_req": round(cold_per_req, 4),
        "hot_s_per_req": round(hot_per_req, 6),
        "served_s_per_req": round(served_per_req, 6),
        "open_session_s": round(t_open, 4),
        "speedup": round(speedup, 1),
        "served_speedup": round(served_speedup, 1),
        "degraded_rate": degraded_rate,
        "retry_count": retry_count,
        "kkt_check_ms": round(kkt_check_ms, 3),
        "hot_pass_compilations": hot_compiles,
        "warm_compilations": stats0.since_open,
        "min_speedup": MIN_SPEEDUP,
        "max_verdict_overhead": MAX_VERDICT_OVERHEAD,
    }
    print(f"[serve] n={n} p={p} R={len(lams)} "
          f"cold={cold_per_req * 1e3:.0f}ms/req "
          f"hot={hot_per_req * 1e3:.1f}ms/req "
          f"served={served_per_req * 1e3:.1f}ms/req "
          f"(kkt {kkt_check_ms:.2f}ms, degraded {degraded_rate:.0%}, "
          f"retries {retry_count}) "
          f"speedup={speedup:.0f}x (gate {MIN_SPEEDUP}x, "
          f"hot-pass compiles={hot_compiles})")
    assert speedup >= MIN_SPEEDUP, (
        f"hot session reached only {speedup:.2f}x over cold per-request "
        f"solves (acceptance {MIN_SPEEDUP}x)")
    assert degraded_rate == 0.0 and retry_count == 0, (
        "the happy-path stream triggered the degradation ladder")
    budget = hot_per_req * (1.0 + MAX_VERDICT_OVERHEAD) + VERDICT_SLACK_S
    assert served_per_req <= budget, (
        f"verdict plumbing costs {served_per_req * 1e3:.2f}ms/req vs a "
        f"budget of {budget * 1e3:.2f}ms/req "
        f"({MAX_VERDICT_OVERHEAD:.0%} of the bare hot request + "
        f"{VERDICT_SLACK_S * 1e3:.1f}ms slack)")
    assert served_speedup >= MIN_SPEEDUP, (
        f"served hot stream reached only {served_speedup:.2f}x over cold "
        f"(acceptance {MIN_SPEEDUP}x)")

    # --- served fast-parity fleet (ISSUE 7): the relaxed-parity lockstep
    # engine with certified bf16 screening, behind the same fault-tolerant
    # runtime. Asserted: the request is served un-degraded, the verdict's
    # working-precision KKT certificate passes, and the verdict records
    # the execution-mode provenance (parity + screening precision).
    import dataclasses

    import jax.numpy as jnp

    from repro import Fleet
    from repro.core import get_loss
    from repro.core.duality import lambda_max

    B = 8
    rng = np.random.default_rng(11)
    Ys, flams = [], []
    loss = get_loss("least_squares")
    for _ in range(B):
        w = np.zeros(p)
        w[rng.choice(p, 15, replace=False)] = rng.uniform(-1, 1, 15)
        yb = X @ w + rng.normal(0, 1, n)
        Ys.append(yb)
        flams.append(0.5 * float(lambda_max(loss, jnp.asarray(X),
                                            jnp.asarray(yb))))
    Yf = np.stack(Ys)
    cfg_fast = dataclasses.replace(cfg, parity="fast",
                                   screen_dtype="bfloat16")
    srv_f = open_serving(Problem(X=X), cfg_fast)
    req = Fleet(Y=Yf, lams=np.asarray(flams))
    _block(srv_f.solve(req).value)                 # warm: one compilation
    fstats0 = srv_f.stats()
    t0 = time.perf_counter()
    fout = srv_f.solve(req)
    _block(fout.value)
    t_fleet = time.perf_counter() - t0
    fstats1 = srv_f.stats()
    v = fout.verdict
    assert v.ok and not v.degraded, (
        f"served fast fleet degraded (ok={v.ok}, degraded={v.degraded}, "
        f"rungs={v.rungs})")
    assert fstats1.degraded - fstats0.degraded == 0
    assert v.parity == "fast" and v.screen_dtype == "bfloat16", (
        f"verdict must record execution-mode provenance, got "
        f"parity={v.parity!r} screen_dtype={v.screen_dtype!r}")
    fleet_row = {
        "fleet_b": B, "n": n, "p": p,
        "parity": v.parity, "screen_dtype": v.screen_dtype,
        "served_fleet_s": round(t_fleet, 4),
        "served_fleet_ms_per_problem": round(t_fleet / B * 1e3, 3),
        "gap": float(v.gap), "kkt_residual": float(v.kkt_residual),
        "kkt_tol": float(v.kkt_tol),
        "degraded_rate": 0.0, "verdict_ok": True,
    }
    print(f"[serve] fleet B={B} n={n} p={p} parity={v.parity} "
          f"dtype={v.screen_dtype} served={t_fleet * 1e3:.1f}ms "
          f"({t_fleet / B * 1e3:.1f}ms/problem, kkt={v.kkt_residual:.2e} "
          f"<= tol {v.kkt_tol:.2e}, degraded 0%)")

    # --- ISSUE 8: the async server ---------------------------------------
    coalesce_row = _bench_coalesced(cfg)
    poisson_row = _bench_poisson(X, y, lmax, cfg, n, p)
    restart_row = _bench_restart(X, y, lmax, cfg, n, p)
    return [row, fleet_row, coalesce_row, poisson_row, restart_row]


def _serve_cfg(cfg):
    """The serving solver configuration: the relaxed-parity lockstep
    fleet engine (every member still ends with a working-precision KKT
    certificate in its verdict)."""
    import dataclasses
    return dataclasses.replace(cfg, parity="fast")


def _bench_coalesced(cfg):
    """Coalesced microbatch throughput vs one hot ServingSession
    serially draining the identical request stream.

    The stream is the ROADMAP's "millions of users" serving regime: R
    users over ONE shared design, each submitting a small personal
    problem (own response, own lambda). The serial baseline is the
    strongest single-request use of the PR 6/7 surface for that
    stream — ONE hot ServingSession on the shared design, one
    fleet-of-1 request per user — so the gate isolates exactly what
    the server adds: coalescing riders into max_batch-wide lockstep
    fleet solves that amortize the per-request dispatch + verdict
    cost across the batch. Small per-user problems are the honest
    operating point for that comparison: per-request overhead is
    size-independent, so it (not raw solver compute) dominates a
    production stream of small personalization solves. A second,
    weaker baseline (a hot per-user session per request) is reported
    as a column but not gated."""
    import jax.numpy as jnp

    from repro import Fleet, Problem, Scalar
    from repro.core import get_loss
    from repro.core.duality import lambda_max
    from repro.core.saif import saif_jit_compile_count
    from repro.core.server import open_server
    from repro.core.serving import open_serving

    cfg_srv = _serve_cfg(cfg)
    loss = get_loss("least_squares")
    n_u, p_u = 60, 96                 # the per-user problem shape
    rng = np.random.default_rng(23)
    X = rng.uniform(-10, 10, (n_u, p_u))
    Xj = jnp.asarray(X)
    users = []
    for r in range(POISSON_REQUESTS):
        w = np.zeros(p_u)
        w[rng.choice(p_u, 10, replace=False)] = rng.uniform(-1, 1, 10)
        yu = X @ w + rng.normal(0, 1, n_u)
        lam_u = (0.45 + 0.01 * (r % 8)) * float(
            lambda_max(loss, Xj, jnp.asarray(yu)))
        users.append((yu, lam_u))
    problems = [Problem(X=X, y=yu) for yu, _ in users]

    # gated baseline: one hot session on the shared design, one
    # fleet-of-1 request per user
    serial = open_serving(Problem(X=X), cfg_srv)

    def serial_pass():
        for yu, lam_u in users:
            out = serial.solve(Fleet(Y=yu, lams=lam_u))
            _block(out.value)
            assert out.verdict.ok

    serial_pass()                          # warm every static key
    c0 = saif_jit_compile_count()
    t_serial = 1e9
    for _ in range(2):                     # best-of-2: 1-core CI noise
        t0 = time.perf_counter()
        serial_pass()
        t_serial = min(t_serial, time.perf_counter() - t0)
    assert saif_jit_compile_count() == c0, (
        "serial baseline compiled during its measured pass")

    # informational baseline: a hot per-user ServingSession each (the
    # engine jit caches are process-wide, so these pay prep, not
    # compiles)
    def session_pass():
        for pb, (_, lam_u) in zip(problems, users):
            out = open_serving(pb, cfg_srv).solve(Scalar(lam_u))
            assert out.verdict.ok

    session_pass()
    t0 = time.perf_counter()
    session_pass()
    t_sessions = time.perf_counter() - t0

    # coalesced: the identical user stream through the async server
    server = open_server(max_batch=8, max_wait_ms=50.0, solver=cfg_srv)

    def pump():
        futs = [server.submit(pb, Scalar(lam_u))
                for pb, (_, lam_u) in zip(problems, users)]
        res = [f.result(timeout=600) for f in futs]
        assert all(r.verdict.ok for r in res)
        return res

    pump()                                 # warm the fleet bucket keys
    c1 = saif_jit_compile_count()
    t_coal = 1e9
    for _ in range(2):
        t0 = time.perf_counter()
        pump()
        t_coal = min(t_coal, time.perf_counter() - t0)
    steady_compiles = saif_jit_compile_count() - c1
    stats = server.stats()
    server.close()
    assert steady_compiles == 0, (
        f"coalesced steady state compiled {steady_compiles} new engine "
        f"keys (contract: zero)")
    speedup = t_serial / max(t_coal, 1e-12)
    row = {
        "mode": "coalesced", "n": n_u, "p": p_u,
        "requests": POISSON_REQUESTS,
        "serial_hot_s": round(t_serial, 4),
        "per_user_sessions_s": round(t_sessions, 4),
        "coalesced_s": round(t_coal, 4),
        "coalesced_speedup": round(speedup, 2),
        "coalesced_batches": stats.coalesced_batches,
        "steady_state_compiles": steady_compiles,
        "min_coalesced_speedup": MIN_COALESCED_SPEEDUP,
    }
    print(f"[serve] coalesced R={POISSON_REQUESTS} n={n_u} p={p_u} "
          f"serial={t_serial:.2f}s sessions={t_sessions:.2f}s "
          f"coalesced={t_coal:.2f}s "
          f"speedup={speedup:.1f}x (gate {MIN_COALESCED_SPEEDUP}x, "
          f"steady compiles={steady_compiles})")
    assert speedup >= MIN_COALESCED_SPEEDUP, (
        f"coalesced microbatching reached only {speedup:.2f}x over the "
        f"serial hot stream (acceptance {MIN_COALESCED_SPEEDUP}x)")
    return row


def _bench_poisson(X, y, lmax, cfg, n, p):
    """Seeded Poisson-arrival load over a heterogeneous shape mix:
    p50/p99 latency and req/s columns, zero steady-state compiles."""
    from repro import Problem, Scalar
    from repro.core.saif import saif_jit_compile_count
    from repro.core.server import open_server

    cfg_srv = _serve_cfg(cfg)
    rng = np.random.default_rng(7)
    # two shapes -> two compile buckets -> the heterogeneous mix
    X2, y2, lmax2 = _problem(n - 10, p - 100, seed=3)
    problems = [(Problem(X=X, y=y), lmax), (Problem(X=X2, y=y2), lmax2)]
    fracs = [0.30, 0.28, 0.26, 0.24]
    picks = rng.integers(len(problems), size=POISSON_REQUESTS)
    fpicks = rng.integers(len(fracs), size=POISSON_REQUESTS)
    gaps = rng.exponential(POISSON_MEAN_GAP_S, size=POISSON_REQUESTS)

    # Deterministic key-space prewarm: a Poisson batch's compile key is
    # (bucket, pow2-padded B, h), and h of a mixed-lam batch is one of
    # the member values — so uniform-lam bursts of every pow2 size per
    # problem cover every key any arrival grouping can produce. Paused
    # servers pin exact batch sizes; the engine caches are process-wide.
    for prob, lm in problems:
        for frac in fracs:
            for B in (1, 2, 4, 8):
                with open_server(autostart=False, max_batch=8,
                                 max_wait_ms=0.0, solver=cfg_srv) as ps:
                    futs = [ps.submit(prob, Scalar(frac * lm))
                            for _ in range(B)]
                    ps.run(timeout=0.01)
                    for f in futs:
                        assert f.result(timeout=600).verdict.ok

    server = open_server(max_batch=8, max_wait_ms=5.0, solver=cfg_srv)

    def load_pass():
        t_done = [None] * POISSON_REQUESTS
        t_sub = [None] * POISSON_REQUESTS
        futs = []
        t_start = time.perf_counter()
        for i in range(POISSON_REQUESTS):
            time.sleep(gaps[i])
            prob, lm = problems[picks[i]]
            t_sub[i] = time.perf_counter()
            fut = server.submit(prob, Scalar(fracs[fpicks[i]] * lm))
            fut.add_done_callback(
                lambda _f, i=i: t_done.__setitem__(
                    i, time.perf_counter()))
            futs.append(fut)
        res = [f.result(timeout=600) for f in futs]
        assert all(r.verdict.ok for r in res)
        wall = time.perf_counter() - t_start
        lat = np.asarray([d - s for d, s in zip(t_done, t_sub)])
        return lat, wall

    load_pass()                              # warm every bucket/key
    c0 = saif_jit_compile_count()
    lat, wall = load_pass()                  # measured steady state
    steady_compiles = saif_jit_compile_count() - c0
    server.close()
    p50 = float(np.percentile(lat, 50))
    p99 = float(np.percentile(lat, 99))
    rps = POISSON_REQUESTS / wall
    row = {
        "mode": "poisson", "seed": 7,
        "requests": POISSON_REQUESTS,
        "mean_gap_ms": POISSON_MEAN_GAP_S * 1e3,
        "shapes": [[n, p], [n - 10, p - 100]],
        "p50_ms": round(p50 * 1e3, 2), "p99_ms": round(p99 * 1e3, 2),
        "req_per_s": round(rps, 1),
        "steady_state_compiles": steady_compiles,
        "p99_bound_s": P99_BOUND_S,
    }
    print(f"[serve] poisson R={POISSON_REQUESTS} seed=7 "
          f"p50={p50 * 1e3:.1f}ms p99={p99 * 1e3:.1f}ms "
          f"{rps:.1f} req/s (steady compiles={steady_compiles})")
    assert steady_compiles == 0, (
        f"Poisson steady state compiled {steady_compiles} new engine "
        f"keys across the heterogeneous mix (contract: zero)")
    assert p99 <= P99_BOUND_S, (
        f"p99 latency {p99:.3f}s exceeds the {P99_BOUND_S}s smoke bound")
    return row


def _bench_restart(X, y, lmax, cfg, n, p):
    """Restart-on-same-cache-dir: with the persistent compilation cache
    wired, a restarted server's warmup writes ZERO new cache entries —
    every compile replays from disk."""
    import glob
    import os
    import shutil
    import tempfile

    from repro import Problem, Scalar
    from repro.core.server import open_server

    cfg_srv = _serve_cfg(cfg)
    prob = Problem(X=X, y=y)
    lams = [f * lmax for f in (0.30, 0.28, 0.26, 0.24)]
    cache_dir = tempfile.mkdtemp(prefix="saif-serve-cache-")

    def cache_files():
        return len([f for f in glob.glob(
            os.path.join(cache_dir, "**"), recursive=True)
            if os.path.isfile(f)])

    def life():
        """One server lifetime: open on the cache dir, serve the warmup
        mix, report wall time."""
        server = open_server(cache_dir=cache_dir, max_batch=8,
                             max_wait_ms=20.0, solver=cfg_srv)
        t0 = time.perf_counter()
        futs = [server.submit(prob, Scalar(lam)) for lam in lams]
        res = [f.result(timeout=600) for f in futs]
        assert all(r.verdict.ok for r in res)
        dt = time.perf_counter() - t0
        server.close()
        return dt

    try:
        jax.clear_caches()                   # cold first life
        t_first = life()
        files_first = cache_files()
        assert files_first > 0, (
            "persistent compilation cache wrote nothing — the restart "
            "contract cannot hold")
        jax.clear_caches()                   # "restart": lose the
        t_second = life()                    # in-memory executables
        files_second = cache_files()
        row = {
            "mode": "restart", "n": n, "p": p,
            "cold_life_s": round(t_first, 3),
            "restart_life_s": round(t_second, 3),
            "cache_entries": files_first,
            "new_entries_after_restart": files_second - files_first,
        }
        print(f"[serve] restart cold={t_first:.2f}s "
              f"restarted={t_second:.2f}s cache_entries={files_first} "
              f"new_after_restart={files_second - files_first}")
        assert files_second == files_first, (
            f"restarted server wrote {files_second - files_first} new "
            f"cache entries — cold-start compiles leaked past the "
            f"persistent cache")
        assert t_second < t_first, (
            f"restart warmup ({t_second:.2f}s) not faster than the cold "
            f"first life ({t_first:.2f}s) — disk replay is not working")
        return row
    finally:
        jax.config.update("jax_compilation_cache_dir", None)
        shutil.rmtree(cache_dir, ignore_errors=True)


if __name__ == "__main__":
    run()
