"""Serving benchmark: hot-session request latency vs cold per-request
solves (ISSUE 5 acceptance — BENCH_serve.json).

The comparison is the session API's reason to exist. A server WITHOUT a
session answers each request from scratch: fresh process-equivalent state
(``jax.clear_caches()``), fresh preparation, fresh ``_saif_jit``
compilation, then the solve. A server WITH a session pays preparation
once at ``open_session`` and compilation once per static key, after
which every request runs at solve cost with device-resident warm
buffers.

Protocol (CI shape): R scalar requests cycling over a few lambdas inside
one pow2 h bucket (one static key — the honest serving regime: clients
ask for nearby lambdas far more often than for new shapes).

  * cold: per request, ``jax.clear_caches()`` + ``saif(X, y, lam)`` —
    prep + compile + solve every time;
  * hot: one ``open_session``; after a warmup pass over the distinct
    lambdas, the measured pass must add ZERO compilations (asserted via
    ``session.compile_stats()``).

Acceptance (asserted): hot-session latency >= 3x better than cold
per-request solves. On CPU CI the gap is dominated by the per-request
XLA compile (seconds) vs the warm solve (milliseconds), so the measured
ratio is typically 2-3 orders of magnitude; the 3x gate is deliberately
conservative — it survives a hypothetical persistent-compilation-cache
world where cold requests only re-pay preparation + dispatch.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import simulation_data

MIN_SPEEDUP = 3.0   # ISSUE 5 acceptance gate
N_REQUESTS = 6      # cold requests are expensive (a compile each)
# ISSUE 6 acceptance gate: the fault-tolerant runtime's verdict plumbing
# (admission + KKT certification + ladder bookkeeping) may cost the
# happy-path hot request at most 10% (+ an absolute slack for the
# certificate jit dispatch and CI timer noise)
MAX_VERDICT_OVERHEAD = 0.10
VERDICT_SLACK_S = 1.5e-3


def _problem(n, p, seed=0):
    import jax.numpy as jnp

    from repro.core import get_loss
    from repro.core.duality import lambda_max

    X, _, _ = simulation_data(n=n, p=p, seed=seed)
    rng = np.random.default_rng(seed + 1)
    w = np.zeros(p)
    w[rng.choice(p, 15, replace=False)] = rng.uniform(-1, 1, 15)
    y = X @ w + rng.normal(0, 1, n)
    lmax = float(lambda_max(get_loss("least_squares"),
                            jnp.asarray(X), jnp.asarray(y)))
    return X, y, lmax


def _block(res):
    jax.block_until_ready(jax.tree.leaves(res)[0])


def run(full: bool = False):
    from repro import Problem, SaifConfig, Scalar, open_session
    from repro.core import saif

    n, p = (100, 2000) if full else (50, 500)
    cfg = SaifConfig(eps=1e-6, inner_epochs=3, polish_factor=4)
    X, y, lmax = _problem(n, p)
    # the request stream: lambdas inside one h bucket (checked below by
    # the zero-new-compilations assertion), revisited round-robin
    fracs = [0.30, 0.28, 0.26, 0.29, 0.27, 0.25][:N_REQUESTS]
    lams = [f * lmax for f in fracs]

    # --- cold: per-request prep + compile + solve ------------------------
    t_cold = 0.0
    for lam in lams:
        jax.clear_caches()
        t0 = time.perf_counter()
        _block(saif(X, y, lam, cfg))
        t_cold += time.perf_counter() - t0
    cold_per_req = t_cold / len(lams)

    # --- hot: one session, measured pass after warmup --------------------
    jax.clear_caches()
    t0 = time.perf_counter()
    session = open_session(Problem(X=X, y=y), cfg)
    t_open = time.perf_counter() - t0
    # warmup: TWO passes over the distinct lambdas. The first pass may
    # grow the warm capacity mid-stream (a smaller lambda can bump the h
    # bucket), so a lambda served early can still map to a fresh static
    # key on its next visit; the second pass compiles any such residue —
    # after it, the key set is closed and the measured pass is pure
    # serving.
    for _ in range(2):
        for lam in lams:
            _block(session.solve(Scalar(lam, warm=True)))
    stats0 = session.compile_stats()
    t_hot = 0.0
    for lam in lams:                      # measured: the hot request loop
        t0 = time.perf_counter()
        _block(session.solve(Scalar(lam, warm=True)))
        t_hot += time.perf_counter() - t0
    hot_per_req = t_hot / len(lams)
    stats1 = session.compile_stats()
    hot_compiles = stats1.since_open - stats0.since_open
    assert hot_compiles == 0, (
        f"hot session recompiled {hot_compiles} times during the "
        f"measured pass (contract: one compilation per static key)")

    # --- served: the same hot stream through the fault-tolerant runtime --
    # (ISSUE 6): admission + retry wrapper + KKT certificate + verdict.
    # Warmup compiles the certificate jit (outside the engine caches);
    # the measured pass must stay within MAX_VERDICT_OVERHEAD of the
    # bare hot session AND keep the zero-new-engine-compiles contract.
    from repro.core.serving import open_serving
    srv = open_serving(Problem(X=X, y=y), cfg)
    for _ in range(2):
        for lam in lams:
            _block(srv.solve(Scalar(lam, warm=True)).value)
    sstats0, engine0 = srv.stats(), srv.compile_stats().total
    t_served = 0.0
    for lam in lams:
        t0 = time.perf_counter()
        out = srv.solve(Scalar(lam, warm=True))
        _block(out.value)
        t_served += time.perf_counter() - t0
        assert out.verdict.ok and not out.verdict.degraded
    served_per_req = t_served / len(lams)
    sstats1 = srv.stats()
    assert srv.compile_stats().total == engine0, (
        "verdict plumbing compiled new engine keys on the happy path")
    degraded_rate = (sstats1.degraded - sstats0.degraded) / len(lams)
    retry_count = sstats1.retries - sstats0.retries
    kkt_check_ms = (sstats1.kkt_check_ms - sstats0.kkt_check_ms) / len(lams)

    speedup = cold_per_req / max(hot_per_req, 1e-12)
    served_speedup = cold_per_req / max(served_per_req, 1e-12)
    row = {
        "n": n, "p": p, "requests": len(lams),
        "cold_s_per_req": round(cold_per_req, 4),
        "hot_s_per_req": round(hot_per_req, 6),
        "served_s_per_req": round(served_per_req, 6),
        "open_session_s": round(t_open, 4),
        "speedup": round(speedup, 1),
        "served_speedup": round(served_speedup, 1),
        "degraded_rate": degraded_rate,
        "retry_count": retry_count,
        "kkt_check_ms": round(kkt_check_ms, 3),
        "hot_pass_compilations": hot_compiles,
        "warm_compilations": stats0.since_open,
        "min_speedup": MIN_SPEEDUP,
        "max_verdict_overhead": MAX_VERDICT_OVERHEAD,
    }
    print(f"[serve] n={n} p={p} R={len(lams)} "
          f"cold={cold_per_req * 1e3:.0f}ms/req "
          f"hot={hot_per_req * 1e3:.1f}ms/req "
          f"served={served_per_req * 1e3:.1f}ms/req "
          f"(kkt {kkt_check_ms:.2f}ms, degraded {degraded_rate:.0%}, "
          f"retries {retry_count}) "
          f"speedup={speedup:.0f}x (gate {MIN_SPEEDUP}x, "
          f"hot-pass compiles={hot_compiles})")
    assert speedup >= MIN_SPEEDUP, (
        f"hot session reached only {speedup:.2f}x over cold per-request "
        f"solves (acceptance {MIN_SPEEDUP}x)")
    assert degraded_rate == 0.0 and retry_count == 0, (
        "the happy-path stream triggered the degradation ladder")
    budget = hot_per_req * (1.0 + MAX_VERDICT_OVERHEAD) + VERDICT_SLACK_S
    assert served_per_req <= budget, (
        f"verdict plumbing costs {served_per_req * 1e3:.2f}ms/req vs a "
        f"budget of {budget * 1e3:.2f}ms/req "
        f"({MAX_VERDICT_OVERHEAD:.0%} of the bare hot request + "
        f"{VERDICT_SLACK_S * 1e3:.1f}ms slack)")
    assert served_speedup >= MIN_SPEEDUP, (
        f"served hot stream reached only {served_speedup:.2f}x over cold "
        f"(acceptance {MIN_SPEEDUP}x)")

    # --- served fast-parity fleet (ISSUE 7): the relaxed-parity lockstep
    # engine with certified bf16 screening, behind the same fault-tolerant
    # runtime. Asserted: the request is served un-degraded, the verdict's
    # working-precision KKT certificate passes, and the verdict records
    # the execution-mode provenance (parity + screening precision).
    import dataclasses

    import jax.numpy as jnp

    from repro import Fleet
    from repro.core import get_loss
    from repro.core.duality import lambda_max

    B = 8
    rng = np.random.default_rng(11)
    Ys, flams = [], []
    loss = get_loss("least_squares")
    for _ in range(B):
        w = np.zeros(p)
        w[rng.choice(p, 15, replace=False)] = rng.uniform(-1, 1, 15)
        yb = X @ w + rng.normal(0, 1, n)
        Ys.append(yb)
        flams.append(0.5 * float(lambda_max(loss, jnp.asarray(X),
                                            jnp.asarray(yb))))
    Yf = np.stack(Ys)
    cfg_fast = dataclasses.replace(cfg, parity="fast",
                                   screen_dtype="bfloat16")
    srv_f = open_serving(Problem(X=X), cfg_fast)
    req = Fleet(Y=Yf, lams=np.asarray(flams))
    _block(srv_f.solve(req).value)                 # warm: one compilation
    fstats0 = srv_f.stats()
    t0 = time.perf_counter()
    fout = srv_f.solve(req)
    _block(fout.value)
    t_fleet = time.perf_counter() - t0
    fstats1 = srv_f.stats()
    v = fout.verdict
    assert v.ok and not v.degraded, (
        f"served fast fleet degraded (ok={v.ok}, degraded={v.degraded}, "
        f"rungs={v.rungs})")
    assert fstats1.degraded - fstats0.degraded == 0
    assert v.parity == "fast" and v.screen_dtype == "bfloat16", (
        f"verdict must record execution-mode provenance, got "
        f"parity={v.parity!r} screen_dtype={v.screen_dtype!r}")
    fleet_row = {
        "fleet_b": B, "n": n, "p": p,
        "parity": v.parity, "screen_dtype": v.screen_dtype,
        "served_fleet_s": round(t_fleet, 4),
        "served_fleet_ms_per_problem": round(t_fleet / B * 1e3, 3),
        "gap": float(v.gap), "kkt_residual": float(v.kkt_residual),
        "kkt_tol": float(v.kkt_tol),
        "degraded_rate": 0.0, "verdict_ok": True,
    }
    print(f"[serve] fleet B={B} n={n} p={p} parity={v.parity} "
          f"dtype={v.screen_dtype} served={t_fleet * 1e3:.1f}ms "
          f"({t_fleet / B * 1e3:.1f}ms/problem, kkt={v.kkt_residual:.2e} "
          f"<= tol {v.kkt_tol:.2e}, degraded 0%)")
    return [row, fleet_row]


if __name__ == "__main__":
    run()
