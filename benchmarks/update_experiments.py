"""Patch EXPERIMENTS.md with the rendered roofline table + hillclimb rows."""
import json
import sys

from benchmarks.roofline import fmt_table, load, pick_hillclimb


def main():
    recs = load("results/dryrun_single_pod.jsonl")
    try:
        screen = load("results/dryrun_saif_screen.jsonl")
    except FileNotFoundError:
        screen = []
    table = fmt_table(recs + screen)
    picks = pick_hillclimb(recs)
    pick_txt = "\n".join(
        f"* **{k}**: `{r['arch']} x {r['shape']}` (dominant {r['dominant']})"
        for k, r in picks.items())
    md = open("EXPERIMENTS.md").read()
    block = (table + "\n\nHillclimb picks (plus the paper-representative "
             "`saif_screen` row):\n" + pick_txt)
    md = md.replace("<!-- ROOFLINE_TABLE -->", block)
    open("EXPERIMENTS.md", "w").write(md)
    print("patched EXPERIMENTS.md")


if __name__ == "__main__":
    main()
