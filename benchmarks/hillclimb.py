"""§Perf system-side hillclimb driver: re-lowers the three chosen cells with
one candidate change at a time and prints before/after roofline terms.

Run AFTER the baseline sweep:
  PYTHONPATH=src python -m benchmarks.hillclimb --cell stablelm_mb4
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

# ruff: noqa: E402
import argparse
import json

from repro.launch.dryrun import (lower_cell, lower_saif_screen,
                                 make_production_mesh)
from repro.configs import get_config


def show(tag, rec):
    print(f"{tag}: dominant={rec['dominant']} "
          f"compute={rec['compute_s']:.3e}s memory={rec['memory_s']:.3e}s "
          f"coll={rec['collective_s']:.3e}s "
          f"coll_bytes={rec['collective_bytes']:.3e} "
          f"peak_mem={rec['peak_memory_per_device']/2**30:.2f}GiB "
          f"useful={rec.get('useful_flops_frac')}")
    return rec


CELLS = {}


def cell(name):
    def deco(f):
        CELLS[name] = f
        return f
    return deco


@cell("stablelm_base")
def stablelm_base(mesh):
    return lower_cell("stablelm_3b", "train_4k", mesh)


@cell("stablelm_mb2")
def stablelm_mb2(mesh):
    return lower_cell("stablelm_3b", "train_4k", mesh, microbatch=2)


@cell("stablelm_mb4")
def stablelm_mb4(mesh):
    return lower_cell("stablelm_3b", "train_4k", mesh, microbatch=4)


@cell("stablelm_mb4_fsdp")
def stablelm_mb4_fsdp(mesh):
    return lower_cell("stablelm_3b", "train_4k", mesh, microbatch=4,
                      fsdp=True)


@cell("dbrx_base")
def dbrx_base(mesh):
    return lower_cell("dbrx_132b", "train_4k", mesh)


@cell("dbrx_fsdp")
def dbrx_fsdp(mesh):
    return lower_cell("dbrx_132b", "train_4k", mesh, fsdp=True)


@cell("dbrx_fsdp_mb4")
def dbrx_fsdp_mb4(mesh):
    return lower_cell("dbrx_132b", "train_4k", mesh, fsdp=True, microbatch=4)


@cell("dbrx_fsdp_bf16grad")
def dbrx_fsdp_bf16grad(mesh):
    # bf16 params (compute dtype f32 master elsewhere): halves param/grad
    # traffic + collectives — posture experiment
    cfg = get_config("dbrx_132b").scaled(param_dtype="bfloat16")
    return lower_cell("dbrx_132b", "train_4k", mesh, cfg_override=cfg,
                      fsdp=True)


@cell("screen_f32")
def screen_f32(mesh):
    return lower_saif_screen(mesh, dtype="float32")


@cell("screen_bf16")
def screen_bf16(mesh):
    return lower_saif_screen(mesh, dtype="bfloat16")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True,
                    help=f"one of {sorted(CELLS)} or comma list")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    mesh = make_production_mesh()
    recs = []
    for name in args.cell.split(","):
        rec = show(name, CELLS[name](mesh))
        rec["cell"] = name
        recs.append(rec)
    if args.out:
        with open(args.out, "a") as f:
            for r in recs:
                f.write(json.dumps(r) + "\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
