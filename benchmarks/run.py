"""Benchmark runner (deliverable d): one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--full`` runs the paper-scale
shapes (slow on CPU); the default is a reduced sweep suitable for CI.
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: runtime,trajectory,heatmap,logistic,"
                         "path,fused,complexity")
    args = ap.parse_args(argv)

    from benchmarks import (bench_complexity, bench_fused, bench_heatmap,
                            bench_logistic, bench_path, bench_runtime,
                            bench_trajectory)

    suites = {
        "runtime": bench_runtime,        # Fig 2
        "trajectory": bench_trajectory,  # Fig 3
        "heatmap": bench_heatmap,        # Fig 4
        "logistic": bench_logistic,      # Fig 5
        "path": bench_path,              # Fig 6 + Table 1
        "fused": bench_fused,            # Fig 7
        "complexity": bench_complexity,  # Thm 4/5
    }
    if args.only:
        keep = set(args.only.split(","))
        suites = {k: v for k, v in suites.items() if k in keep}

    print("name,us_per_call,derived")
    for name, mod in suites.items():
        rows = mod.run(full=args.full)
        for i, row in enumerate(rows):
            t = row.get("saif_s") or row.get("saif_path_s") or 0.0
            derived = ";".join(f"{k}={v}" for k, v in row.items())
            print(f"{name}[{i}],{t*1e6:.1f},{derived}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
