"""Benchmark runner (deliverable d): one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and, per suite, writes a
machine-readable ``BENCH_<suite>.json`` next to the working directory so the
perf trajectory is tracked across PRs (``BENCH_path.json`` is the
acceptance artifact for the compile-first path engine). ``--full`` runs the
paper-scale shapes (slow on CPU); the default is a reduced sweep suitable
for CI.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time


def write_bench_json(name: str, rows, full: bool) -> str:
    import jax

    payload = {
        "suite": name,
        "rows": rows,
        "full": full,
        "meta": {
            "unix_time": int(time.time()),
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "python": platform.python_version(),
        },
    }
    path = f"BENCH_{name}.json"
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: runtime,trajectory,heatmap,logistic,"
                         "path,fused,complexity,inner,batch,baselines,"
                         "serve,stream")
    ap.add_argument("--no-json", action="store_true",
                    help="skip the BENCH_<suite>.json artifacts")
    args = ap.parse_args(argv)

    from benchmarks import (bench_baselines, bench_batch, bench_complexity,
                            bench_fused, bench_heatmap, bench_inner,
                            bench_logistic, bench_path, bench_runtime,
                            bench_serve, bench_stream, bench_trajectory)

    suites = {
        "runtime": bench_runtime,        # Fig 2
        "trajectory": bench_trajectory,  # Fig 3
        "heatmap": bench_heatmap,        # Fig 4
        "logistic": bench_logistic,      # Fig 5
        "path": bench_path,              # Fig 6 + Table 1 + engine speedup
        "fused": bench_fused,            # Fig 7
        "complexity": bench_complexity,  # Thm 4/5
        "inner": bench_inner,            # inner-backend epoch cost (PR 2)
        "batch": bench_batch,            # fleet engine vs sequential (PR 4)
        "baselines": bench_baselines,    # Sec 5 "50x vs dynamic" tracking
        "serve": bench_serve,            # hot session vs cold requests (PR 5)
        "stream": bench_stream,          # online rows / warm cache (PR 10)
    }
    if args.only:
        keep = set(args.only.split(","))
        suites = {k: v for k, v in suites.items() if k in keep}

    print("name,us_per_call,derived")
    for name, mod in suites.items():
        rows = mod.run(full=args.full)
        for i, row in enumerate(rows):
            t = (row.get("saif_s") or row.get("saif_path_s")
                 or row.get("engine_s") or row.get("epoch_s")
                 or row.get("fleet_s") or row.get("cv_path_s")
                 or row.get("hot_s_per_req") or row.get("stream_s")
                 or 0.0)
            derived = ";".join(f"{k}={v}" for k, v in row.items())
            print(f"{name}[{i}],{t*1e6:.1f},{derived}")
        if not args.no_json:
            path = write_bench_json(name, rows, args.full)
            print(f"# wrote {path}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
