"""Fig. 4 reproduction: p_t/p over (lambda, time) for dynamic screening vs
SAIF. Claim: dynamic screening sits at p_t ~ p until late; SAIF's p_t stays
within a small factor of the optimal support size from the start."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import simulation_data
from repro.core import DynConfig, SaifConfig, dynamic_screening, saif, get_loss
from repro.core.duality import lambda_max


def run(full: bool = False):
    X, y, _ = simulation_data(n=100, p=2000 if full else 600)
    loss = get_loss("least_squares")
    lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    p = X.shape[1]
    rows = []
    for frac in (0.3, 0.1, 0.03, 0.01):
        res = saif(X, y, frac * lmax, SaifConfig(eps=1e-7))
        tr = np.asarray(res.trace_n_active)
        tr = tr[tr >= 0]
        saif_mean_frac = float(np.mean(tr) / p)
        dres = dynamic_screening(X, y, frac * lmax, DynConfig(eps=1e-7))
        # time-weighted survivor fraction for dynamic screening
        hist = np.asarray(dres.survivor_history, float)
        dyn_mean_frac = float(np.mean(hist) / p)
        rows.append({"lam_frac": frac, "saif_mean_pt_frac": saif_mean_frac,
                     "dyn_mean_pt_frac": dyn_mean_frac})
        print(f"[fig4] lam={frac}*lmax mean p_t/p: saif={saif_mean_frac:.4f}"
              f" dyn={dyn_mean_frac:.4f}")
    return rows


if __name__ == "__main__":
    run(full=True)
