"""Shared benchmark utilities: data protocols matching the paper + timing."""
from __future__ import annotations

import time
from typing import Callable, Dict

import jax
import numpy as np

jax.config.update("jax_enable_x64", True)   # paper-grade duality gaps


def simulation_data(n=100, p=5000, seed=0):
    """Paper Sec 5.1.1: X ~ U[-10,10], 20% active betas in [-1,1], N(0,1)."""
    rng = np.random.default_rng(seed)
    X = rng.uniform(-10, 10, (n, p))
    beta = np.zeros(p)
    idx = rng.choice(p, int(0.2 * p), replace=False)
    beta[idx] = rng.uniform(-1, 1, len(idx))
    y = X @ beta + rng.normal(0, 1, n)
    return X, y, beta


def breast_cancer_shaped(seed=1):
    """Shape/conditioning-matched synthetic for the 295x8141 microarray set:
    standardized correlated gaussian features, +-1 labels (paper regresses
    the binary label with least squares)."""
    rng = np.random.default_rng(seed)
    n, p = 295, 8141
    # low-rank + noise covariance mimics gene co-expression structure
    k = 30
    F = rng.normal(size=(p, k)) / np.sqrt(k)
    Z = rng.normal(size=(n, k))
    X = Z @ F.T + 0.7 * rng.normal(size=(n, p))
    X = (X - X.mean(0)) / (X.std(0) + 1e-12)
    w = np.zeros(p)
    w[rng.choice(p, 60, replace=False)] = rng.normal(size=60)
    y = np.sign(X @ w + 0.5 * rng.normal(size=n))
    y[y == 0] = 1.0
    return X, y


def logistic_shaped(n, p, seed=2, k=40):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, p))
    w = np.zeros(p)
    w[rng.choice(p, k, replace=False)] = rng.uniform(-2, 2, k)
    y = np.sign(X @ w + 0.3 * rng.normal(size=n))
    y[y == 0] = 1.0
    return X, y


def timed(fn: Callable, *, warmup: bool = True) -> Dict[str, float]:
    """Wall-time a solver call (after one warmup for jit compilation)."""
    if warmup:
        fn()
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(jax.tree.leaves(out)[0] if jax.tree.leaves(out)
                          else out)
    return {"seconds": time.perf_counter() - t0, "out": out}


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
