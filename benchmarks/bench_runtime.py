"""Fig. 2 reproduction: running time of No-Screening / Dynamic / SAIF.

Paper claims to validate:
  * SAIF < Dynamic < NoScr at every (lambda, gap) cell
  * the advantage grows as lambda shrinks (more active features, but
    p_t << p throughout for SAIF)
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import breast_cancer_shaped, simulation_data, timed
from repro.core import DynConfig, SaifConfig, dynamic_screening, saif, \
    solve_lasso_cm, get_loss
from repro.core.duality import lambda_max
import jax.numpy as jnp


def run(full: bool = False):
    rows = []
    datasets = [("sim", *simulation_data(n=100, p=5000 if full else 1500)[:2])]
    if full:
        datasets.append(("bc_shaped", *breast_cancer_shaped()))
    loss = get_loss("least_squares")

    for dname, X, y in datasets:
        lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
        fracs = (0.2, 0.05, 0.01) if not full else (0.2, 0.05, 0.01, 0.002)
        gaps = (1e-6,) if not full else (1e-6, 1e-9)
        for frac in fracs:
            lam = frac * lmax
            for eps in gaps:
                t_saif = timed(lambda: saif(
                    X, y, lam, SaifConfig(eps=eps)))["seconds"]
                t_dyn = timed(lambda: dynamic_screening(
                    X, y, lam, DynConfig(eps=eps)))["seconds"]
                t_no = timed(lambda: solve_lasso_cm(
                    loss, jnp.asarray(X), jnp.asarray(y), lam,
                    tol=eps))["seconds"]
                rows.append({
                    "dataset": dname, "lam_frac": frac, "eps": eps,
                    "saif_s": t_saif, "dyn_s": t_dyn, "noscr_s": t_no,
                    "speedup_vs_dyn": t_dyn / t_saif,
                    "speedup_vs_noscr": t_no / t_saif,
                })
                print(f"[fig2:{dname}] lam={frac}*lmax eps={eps:g} "
                      f"saif={t_saif:.2f}s dyn={t_dyn:.2f}s "
                      f"noscr={t_no:.2f}s "
                      f"speedup dyn/saif={t_dyn/t_saif:.1f}x "
                      f"noscr/saif={t_no/t_saif:.1f}x")
    return rows


if __name__ == "__main__":
    run(full=True)
