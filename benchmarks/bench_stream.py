"""Streaming benchmark: online update-and-resolve vs cold re-solves,
warm-cache entry vs cold entry, and ``Session.select()`` wall time
(ISSUE 10 acceptance — BENCH_stream.json).

Three comparisons (DESIGN.md §14):

  * **append stream** (gated, ``MIN_STREAM_SPEEDUP``): rows arrive in
    batches; the online path absorbs each batch into the row-capacity-
    padded resident state and re-solves warm — zero new engine
    compilations at steady state (asserted). The cold baseline solves
    the concatenated problem from scratch per batch; each batch grows
    ``n``, so every cold solve is a NEW compile key — the cold path
    pays prep + ``_saif_jit`` compile + cold active-set growth every
    time, which is precisely what the padding + warm carry eliminate.
    On CPU CI the compile dominates, so the measured ratio is typically
    two orders of magnitude; the 5x gate is deliberately conservative.
  * **window stream** (reported, ungated): the sliding-window ring has
    a FIXED shape, so the cold baseline reuses one compiled executable
    and the comparison isolates prep + cold-growth vs the warm
    incremental re-solve — the compile-free share of the win.
  * **warm-cache entry** (gated, ``MIN_CACHE_SPEEDUP``): a repeat
    Scalar at 0.7x a cached lambda entering through the Theorem-2
    sequential-ball seed vs the same request on a cacheless session
    (both hot-compiled; medians over repeats).

``select()`` is timed at one compilation: the second call on a live
session must report ``n_compilations == 0`` (asserted).
"""
from __future__ import annotations

import time

import jax
import numpy as np

MIN_STREAM_SPEEDUP = 5.0    # ISSUE 10 acceptance gate (append stream)
MIN_CACHE_SPEEDUP = 1.05    # warm-cache entry vs cold entry (medians)
N_BATCHES = 6               # cold append solves compile each — keep few
N_WINDOW_BATCHES = 8
N_CACHE_REPS = 8


def _stream_problem(n0, p, k=10, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n0, p))
    beta = np.zeros(p)
    beta[rng.choice(p, k, replace=False)] = rng.uniform(0.8, 1.5, k)
    y = X @ beta + 0.1 * rng.normal(size=n0)
    return X, y, beta, rng


def _batch(rng, beta, m):
    Xn = rng.normal(size=(m, beta.shape[0]))
    return Xn, Xn @ beta + 0.1 * rng.normal(size=m)


def _block(res):
    jax.block_until_ready(jax.tree.leaves(res)[0])


def _bench_append(n0, p, m):
    """Online append stream vs per-batch cold concatenated solves."""
    from repro.core.api import (Problem, Scalar, open_session,
                                unified_compile_count)
    from repro.core.saif import SaifConfig

    X, y, bt, rng = _stream_problem(n0, p)
    lam = 0.15 * float(np.abs(X.T @ y).max())
    cfg = SaifConfig(eps=1e-8, inner_backend="gram")

    sess = open_session(Problem(X=X, y=y), cfg)
    _block(sess.solve(Scalar(lam)))
    # warm-up update: pays the one padded-shape compile
    Xn, yn = _batch(rng, bt, m)
    rows, ys = [X, Xn], [y, yn]
    _block(sess.update(rows=Xn, responses=yn, lam=lam))

    # pass 1: the online stream, timed with the engine caches intact
    c0 = unified_compile_count()
    online_t, prefixes = [], []
    for _ in range(N_BATCHES):
        Xn, yn = _batch(rng, bt, m)
        rows.append(Xn)
        ys.append(yn)
        t0 = time.perf_counter()
        _block(sess.update(rows=Xn, responses=yn, lam=lam))
        online_t.append(time.perf_counter() - t0)
        prefixes.append((np.vstack(rows), np.concatenate(ys)))
    engine_compiles = unified_compile_count() - c0

    # pass 2: cold re-solves of each concatenated prefix. Each batch
    # grows n => a fresh _saif_jit key; clearing the caches first makes
    # every cold solve pay the compile an unpadded stream actually pays
    cold_t = []
    for Xs, ysc in prefixes:
        jax.clear_caches()
        t0 = time.perf_counter()
        cold = open_session(Problem(X=Xs, y=ysc), cfg)
        _block(cold.solve(Scalar(lam)))
        cold_t.append(time.perf_counter() - t0)
    assert engine_compiles == 0, (
        f"steady-state append stream added {engine_compiles} engine "
        f"compilations (capacity headroom should absorb "
        f"{N_BATCHES} x {m} rows)")

    online_med = float(np.median(online_t))
    cold_med = float(np.median(cold_t))
    speedup = cold_med / online_med
    assert speedup >= MIN_STREAM_SPEEDUP, (
        f"online append stream {online_med*1e3:.2f} ms vs cold "
        f"re-solve {cold_med*1e3:.2f} ms = {speedup:.2f}x < "
        f"{MIN_STREAM_SPEEDUP}x gate")
    return {
        "mode": "append", "n0": n0, "p": p, "m": m,
        "batches": N_BATCHES,
        "stream_s": online_med, "cold_s": cold_med,
        "speedup": speedup, "engine_compiles": engine_compiles,
        "gate": MIN_STREAM_SPEEDUP,
    }


def _bench_window(n0, p, m):
    """Sliding-window ring (fixed shape) vs a hot-compiled cold solve of
    the window rows — the compile-free share of the streaming win."""
    from repro.core.api import (Problem, Scalar, open_session,
                                unified_compile_count)
    from repro.core.saif import SaifConfig

    X, y, bt, rng = _stream_problem(n0, p, seed=1)
    lam = 0.15 * float(np.abs(X.T @ y).max())
    cfg = SaifConfig(eps=1e-8, inner_backend="gram")

    sess = open_session(Problem(X=X, y=y), cfg)
    _block(sess.solve(Scalar(lam)))
    Xn, yn = _batch(rng, bt, m)
    rows, ys = [X, Xn], [y, yn]
    _block(sess.update(rows=Xn, responses=yn, lam=lam, window=n0))
    # pre-compile the cold path once at the (fixed) window shape
    warmup = open_session(
        Problem(X=np.vstack(rows)[-n0:], y=np.concatenate(ys)[-n0:]),
        cfg)
    _block(warmup.solve(Scalar(lam)))

    c0 = unified_compile_count()
    online_t, cold_t = [], []
    for _ in range(N_WINDOW_BATCHES):
        Xn, yn = _batch(rng, bt, m)
        rows.append(Xn)
        ys.append(yn)
        t0 = time.perf_counter()
        _block(sess.update(rows=Xn, responses=yn, lam=lam, window=n0))
        online_t.append(time.perf_counter() - t0)
        Xw = np.vstack(rows)[-n0:]
        yw = np.concatenate(ys)[-n0:]
        t0 = time.perf_counter()
        cold = open_session(Problem(X=Xw, y=yw), cfg)
        _block(cold.solve(Scalar(lam)))
        cold_t.append(time.perf_counter() - t0)
    engine_compiles = unified_compile_count() - c0
    assert engine_compiles == 0, (
        f"window stream added {engine_compiles} engine compilations")

    online_med = float(np.median(online_t))
    cold_med = float(np.median(cold_t))
    return {
        "mode": "window", "n0": n0, "p": p, "m": m,
        "batches": N_WINDOW_BATCHES,
        "stream_s": online_med, "cold_s": cold_med,
        "speedup": cold_med / online_med,
        "engine_compiles": engine_compiles,
    }


def _bench_cache(n, p):
    """Warm-cache hit (Theorem-2 seeded entry) vs cold entry at the same
    lambda, both hot-compiled; medians over fresh-session pairs."""
    from repro.core.api import Problem, Scalar, open_session
    from repro.core.saif import SaifConfig
    from repro.core.warm_cache import WarmCache, WarmCacheConfig

    X, y, _, _ = _stream_problem(n, p, seed=2)
    lam0 = 0.2 * float(np.abs(X.T @ y).max())
    lam = 0.7 * lam0
    cfg = SaifConfig(eps=1e-8, inner_backend="gram")
    prob = Problem(X=X, y=y)
    cache = WarmCache(WarmCacheConfig())
    seed_sess = open_session(prob, cfg, warm_cache=cache)
    _block(seed_sess.solve(Scalar(lam0)))       # populate + compile

    hit_t, cold_t = [], []
    for _ in range(N_CACHE_REPS):
        s_hit = open_session(prob, cfg, warm_cache=cache)
        t0 = time.perf_counter()
        _block(s_hit.solve(Scalar(lam)))
        hit_t.append(time.perf_counter() - t0)
        ev = s_hit.drain_events()
        assert any(e.startswith("warm_cache_hit") for e in ev), ev
        s_cold = open_session(prob, cfg)
        t0 = time.perf_counter()
        _block(s_cold.solve(Scalar(lam)))
        cold_t.append(time.perf_counter() - t0)

    hit_med = float(np.median(hit_t))
    cold_med = float(np.median(cold_t))
    speedup = cold_med / hit_med
    assert speedup >= MIN_CACHE_SPEEDUP, (
        f"warm-cache hit {hit_med*1e3:.2f} ms vs cold entry "
        f"{cold_med*1e3:.2f} ms = {speedup:.2f}x < "
        f"{MIN_CACHE_SPEEDUP}x gate")
    return {
        "mode": "cache", "n": n, "p": p, "reps": N_CACHE_REPS,
        "stream_s": hit_med, "cold_s": cold_med, "speedup": speedup,
        "hits": cache.stats().hits, "gate": MIN_CACHE_SPEEDUP,
    }


def _bench_select(n, p):
    """select() wall time; the repeat call must add zero compilations."""
    from repro.core.api import Problem, Select, open_session
    from repro.core.saif import SaifConfig

    X, y, _, _ = _stream_problem(n, p, k=6, seed=3)
    lam_max = float(np.abs(X.T @ y).max())
    lams = tuple(np.geomspace(0.5, 0.05, 6) * lam_max)
    cfg = SaifConfig(eps=1e-7, inner_backend="gram")
    sess = open_session(Problem(X=X, y=y), cfg)
    req = Select(lams=lams, n_folds=4, n_subsamples=8, seed=0)
    t0 = time.perf_counter()
    rep1 = sess.select(req)
    first_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    rep2 = sess.select(req)
    hot_s = time.perf_counter() - t0
    assert rep2.n_compilations == 0, (
        f"repeat select() recompiled ({rep2.n_compilations} keys)")
    return {
        "mode": "select", "n": n, "p": p, "lams": len(lams),
        "n_folds": 4, "n_subsamples": 8,
        "stream_s": hot_s, "first_s": first_s,
        "lam": float(rep1.lam),
        "stable_support": (0 if rep1.stable_support is None
                           else int(rep1.stable_support.size)),
        "hot_compilations": rep2.n_compilations,
    }


def run(full: bool = False):
    if full:
        n0, p, m = 192, 2048, 32
        nc, pc = 128, 1024
    else:
        n0, p, m = 96, 384, 16
        nc, pc = 96, 384
    rows = [
        _bench_append(n0, p, m),
        _bench_window(n0, p, m),
        _bench_cache(nc, pc),
        _bench_select(nc, pc),
    ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
