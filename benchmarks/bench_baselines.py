"""Baseline head-to-heads: SAIF rule grid vs dynamic / sequential /
homotopy.

Tracks the paper's headline claim — "up to 50x faster than dynamic
screening" (Sec 5) — per PR, now at the RULE layer (ISSUE 9): every row
solves the same problems with the full screen-rule grid (``saif`` |
``gap_safe`` | ``hybrid``, core/screen_rule.py) against all three
previously dormant baselines (``core/dynamic.py``, ``core/sequential.py``,
``core/homotopy.py``) at matched accuracy; wall-clock ratios, coordinate-
update ratios and the new screening observability counters land in
``BENCH_baselines.json`` alongside BENCH_path/inner/fused.

Protocol: the Sec 5.1.1 simulation design at CI scale (paper scale under
``--full``), a lambda sweep from moderate to aggressive screening
regimes. Dynamic screening is the gap-safe full-matrix method WITH
physical compaction (its strongest fair form, see core/dynamic.py);
sequential screening is the classical DPP warm path (safe, the paper's
Sec 5.3 comparison); homotopy is the unsafe strong-rule pathwise solver,
reported with its recall/precision so the safety gap is visible next to
the speed numbers. Every SAIF rule is asserted support-exact against the
unscreened CM oracle (SAIF: recall = precision = 1 by the safe
guarantee; the hybrid rule keeps it through the safe post-check).

Acceptance gate (ISSUE 9): the ``hybrid`` rule must beat the dynamic
baseline by :data:`MIN_HYBRID_SPEEDUP` at the CI shape.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import simulation_data
from repro.core import (DynConfig, HomotopyConfig, SaifConfig, SeqConfig,
                        dynamic_screening, get_loss, homotopy_path, saif,
                        sequential_path, solve_lasso_cm, support_metrics)
from repro.core.duality import lambda_max

# tracked-speedup gate (ISSUE 9 acceptance; was 1.3-1.4x for the single
# Theorem-2 rule through PR 8 — the hybrid safe-strong rule with the
# working-set Newton polish measures ~5-13x on the CI shape)
MIN_HYBRID_SPEEDUP = 4.0


def _timed(fn, reps=2):
    fn()                                     # warm (jit compiles excluded)
    best = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(jax.tree.leaves(out)[0])
        best = min(best, time.perf_counter() - t0)
    return best, out


def _rule_counters(res) -> dict:
    """Screening observability (ISSUE 9): why did this rule win?"""
    t = int(res.n_outer)
    scr = np.asarray(res.trace_screened)[:t]
    srv = np.asarray(res.trace_survivors)[:t]
    pv = np.asarray(res.trace_post_viol)[:t]
    ran = scr >= 0                      # steps whose ADD screen actually ran
    return {
        "n_outer": t,
        "screens_run": int(ran.sum()),
        "screened_mean": (float(scr[ran].mean()) if ran.any() else 0.0),
        "survivors_mean": (float(srv[ran].mean()) if ran.any() else 0.0),
        "post_checks": int((pv >= 0).sum()),
        "post_check_violations": int((pv == 1).sum()),
    }


def run(full: bool = False):
    n, p = (100, 5000) if full else (100, 1000)
    eps = 1e-6
    loss = get_loss("least_squares")
    X, y, _ = simulation_data(n=n, p=p, seed=0)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    lmax = float(lambda_max(loss, Xj, yj))
    rows = []
    for frac in ((0.1, 0.05, 0.02) if full else (0.1, 0.05)):
        lam = frac * lmax
        ref = solve_lasso_cm(loss, Xj, yj, lam, tol=1e-9)
        ref_sup = np.where(np.abs(np.asarray(ref)) > 1e-8)[0]

        # --- the screen-rule grid, all support-asserted vs the oracle ----
        rule_times, rule_counters = {}, {}
        for rule in ("saif", "gap_safe", "hybrid"):
            t_rule, res_r = _timed(
                lambda rule=rule: saif(
                    X, y, lam, SaifConfig(eps=eps, screen_rule=rule)))
            sup = np.where(np.abs(np.asarray(res_r.beta)) > 1e-8)[0]
            assert set(sup) == set(ref_sup.tolist()), (
                f"screen_rule={rule} lost the safe guarantee on the "
                f"benchmark problem (lam_frac={frac})")
            rule_times[rule] = t_rule
            rule_counters[rule] = _rule_counters(res_r)

        # --- baselines ---------------------------------------------------
        t_dyn, res_d = _timed(
            lambda: dynamic_screening(X, y, lam, DynConfig(eps=eps)))
        # sequential (DPP) screening: its natural mode is a warm lambda
        # path ending at lam — the safe counterpart of the homotopy run
        lams_h = np.geomspace(0.95 * lmax, lam, 5)
        t_seq, res_q = _timed(
            lambda: sequential_path(X, y, lams_h, SeqConfig(eps=eps)))
        seq_sup = np.where(
            np.abs(np.asarray(res_q.betas[-1])) > 1e-8)[0]
        seq_recall, seq_precision = support_metrics(seq_sup, ref_sup)
        # unsafe strong-rule homotopy over the same short path; quality
        # vs the safe oracle support
        t_hom, res_h = _timed(
            lambda: homotopy_path(X, y, lams_h, HomotopyConfig(eps=eps)))
        recall, precision = support_metrics(res_h.supports[-1], ref_sup)

        speedups = {r: round(t_dyn / max(t, 1e-12), 2)
                    for r, t in rule_times.items()}
        rows.append({
            "n": n, "p": p, "lam_frac": frac,
            "saif_s": round(rule_times["saif"], 4),
            "gap_safe_s": round(rule_times["gap_safe"], 4),
            "hybrid_s": round(rule_times["hybrid"], 4),
            "dynamic_s": round(t_dyn, 4),
            "sequential_path_s": round(t_seq, 4),
            "homotopy_path_s": round(t_hom, 4),
            "speedup_vs_dynamic": speedups["saif"],
            "gap_safe_speedup_vs_dynamic": speedups["gap_safe"],
            "hybrid_speedup_vs_dynamic": speedups["hybrid"],
            "dynamic_coord_updates": int(res_d.coord_updates),
            "sequential_coord_updates": int(res_q.coord_updates),
            "sequential_recall": round(seq_recall, 4),
            "sequential_precision": round(seq_precision, 4),
            "homotopy_recall": round(recall, 4),
            "homotopy_precision": round(precision, 4),
            "rule_counters": rule_counters,
        })
        print(f"[baselines] lam={frac}*lmax "
              f"saif={rule_times['saif']*1e3:.0f}ms "
              f"gap_safe={rule_times['gap_safe']*1e3:.0f}ms "
              f"hybrid={rule_times['hybrid']*1e3:.0f}ms "
              f"dynamic={t_dyn*1e3:.0f}ms "
              f"(saif {speedups['saif']:.1f}x / hybrid "
              f"{speedups['hybrid']:.1f}x) "
              f"seq(5-pt)={t_seq*1e3:.0f}ms homotopy(5-pt)="
              f"{t_hom*1e3:.0f}ms r={recall:.3f} p={precision:.3f}")
        hc = rule_counters["hybrid"]
        print(f"[baselines]   hybrid: outer={hc['n_outer']} "
              f"screens={hc['screens_run']} "
              f"screened~{hc['screened_mean']:.0f}/{p} "
              f"post_checks={hc['post_checks']} "
              f"violations={hc['post_check_violations']}")

    if not full:
        worst = min(r["hybrid_speedup_vs_dynamic"] for r in rows)
        assert worst >= MIN_HYBRID_SPEEDUP, (
            f"hybrid speedup vs dynamic regressed: {worst:.2f}x < "
            f"{MIN_HYBRID_SPEEDUP}x (ISSUE 9 acceptance gate)")
    return rows


if __name__ == "__main__":
    run()
