"""Baseline head-to-heads: SAIF vs dynamic screening vs unsafe homotopy.

Tracks the paper's headline claim — "up to 50x faster than dynamic
screening" (Sec 5) — per PR: the previously dormant baselines
(``core/dynamic.py``, ``core/homotopy.py``) solve the same problems as
SAIF at matched accuracy and the wall-clock ratio + coordinate-update
ratio land in ``BENCH_baselines.json`` alongside BENCH_path/inner/fused.

Protocol: the Sec 5.1.1 simulation design at CI scale (paper scale under
``--full``), a lambda sweep from moderate to aggressive screening
regimes. Dynamic screening is the gap-safe full-matrix method WITH
physical compaction (its strongest fair form, see core/dynamic.py);
homotopy is the unsafe strong-rule pathwise solver, reported with its
recall/precision so the safety gap is visible next to the speed numbers
(SAIF: recall = precision = 1 by the safe guarantee, tier-1-asserted).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import simulation_data
from repro.core import (DynConfig, HomotopyConfig, SaifConfig,
                        dynamic_screening, get_loss, homotopy_path, saif,
                        solve_lasso_cm, support_metrics)
from repro.core.duality import lambda_max


def _timed(fn, reps=2):
    fn()                                     # warm (jit compiles excluded)
    best = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(jax.tree.leaves(out)[0])
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(full: bool = False):
    n, p = (100, 5000) if full else (100, 1000)
    eps = 1e-6
    loss = get_loss("least_squares")
    X, y, _ = simulation_data(n=n, p=p, seed=0)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    lmax = float(lambda_max(loss, Xj, yj))
    rows = []
    for frac in ((0.1, 0.05, 0.02) if full else (0.1, 0.05)):
        lam = frac * lmax
        t_saif, res_s = _timed(lambda: saif(X, y, lam, SaifConfig(eps=eps)))
        t_dyn, res_d = _timed(
            lambda: dynamic_screening(X, y, lam, DynConfig(eps=eps)))
        # unsafe strong-rule homotopy: a short path ending at lam (its
        # natural mode); quality vs the safe oracle support
        lams_h = np.geomspace(0.95 * lmax, lam, 5)
        t_hom, res_h = _timed(
            lambda: homotopy_path(X, y, lams_h, HomotopyConfig(eps=eps)))
        ref = solve_lasso_cm(loss, Xj, yj, lam, tol=1e-9)
        ref_sup = np.where(np.abs(np.asarray(ref)) > 1e-8)[0]
        recall, precision = support_metrics(res_h.supports[-1], ref_sup)
        saif_sup = np.where(np.abs(np.asarray(res_s.beta)) > 1e-8)[0]
        assert set(saif_sup) == set(ref_sup.tolist()), \
            "SAIF lost the safe guarantee on the benchmark problem"
        rows.append({
            "n": n, "p": p, "lam_frac": frac,
            "saif_s": round(t_saif, 4),
            "dynamic_s": round(t_dyn, 4),
            "homotopy_path_s": round(t_hom, 4),
            "speedup_vs_dynamic": round(t_dyn / max(t_saif, 1e-12), 2),
            "dynamic_coord_updates": int(res_d.coord_updates),
            "homotopy_recall": round(recall, 4),
            "homotopy_precision": round(precision, 4),
        })
        print(f"[baselines] lam={frac}*lmax saif={t_saif*1e3:.0f}ms "
              f"dynamic={t_dyn*1e3:.0f}ms "
              f"({t_dyn/max(t_saif,1e-12):.1f}x) homotopy(5-pt path)="
              f"{t_hom*1e3:.0f}ms r={recall:.3f} p={precision:.3f}")
    return rows


if __name__ == "__main__":
    run()
