"""Fig. 3 reproduction: active-set size and dual objective D(theta_t)
trajectories for SAIF — |A_t| must grow from a small seed to ~|support|,
and D(theta_t) must decrease monotonically (Theorem 1/3)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import simulation_data
from repro.core import SaifConfig, saif, solve_lasso_cm, get_loss
from repro.core.duality import lambda_max


def run(full: bool = False):
    X, y, _ = simulation_data(n=100, p=3000 if full else 800)
    loss = get_loss("least_squares")
    lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    rows = []
    for frac in (0.1, 0.02):
        res = saif(X, y, frac * lmax, SaifConfig(eps=1e-8))
        tr_n = np.asarray(res.trace_n_active)
        tr_d = np.asarray(res.trace_dual)
        valid = tr_n >= 0
        tr_n, tr_d = tr_n[valid], tr_d[valid]
        beta_ref = solve_lasso_cm(loss, jnp.asarray(X), jnp.asarray(y),
                                  frac * lmax, tol=1e-10)
        sup = int(np.sum(np.abs(np.asarray(beta_ref)) > 1e-9))
        # D decreases after the initial ramp (allow tiny float noise)
        dual_drops = np.all(np.diff(tr_d) <= np.abs(tr_d[:-1]) * 1e-6 + 1e-9)
        rows.append({"lam_frac": frac, "start_size": int(tr_n[0]),
                     "peak_size": int(tr_n.max()), "opt_support": sup,
                     "n_outer": int(res.n_outer),
                     "dual_monotone": bool(dual_drops)})
        print(f"[fig3] lam={frac}*lmax start={tr_n[0]:.0f} "
              f"peak={tr_n.max():.0f} support={sup} "
              f"dual_monotone={dual_drops}")
    return rows


if __name__ == "__main__":
    run(full=True)
