"""Fig. 5 reproduction: sparse logistic regression (USPS/Gisette-shaped
synthetics). Claim: SAIF < Dynamic at every lambda."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import logistic_shaped, timed
from repro.core import DynConfig, SaifConfig, dynamic_screening, saif, get_loss
from repro.core.duality import lambda_max


def run(full: bool = False):
    # gisette-shaped (5000 feats x 6000 samples) is heavy on CPU; scale down
    shapes = [("usps_shaped", 600, 256)] if not full else \
        [("usps_shaped", 7291, 256), ("gisette_shaped", 1500, 5000)]
    rows = []
    loss = get_loss("logistic")
    for name, n, p in shapes:
        X, y = logistic_shaped(n=n, p=p)
        lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
        for frac in (0.3, 0.1):
            lam = frac * lmax
            t_s = timed(lambda: saif(X, y, lam, SaifConfig(
                eps=1e-6, loss="logistic")))["seconds"]
            t_d = timed(lambda: dynamic_screening(X, y, lam, DynConfig(
                eps=1e-6, loss="logistic")))["seconds"]
            rows.append({"dataset": name, "lam_frac": frac,
                         "saif_s": t_s, "dyn_s": t_d})
            print(f"[fig5:{name}] lam={frac}*lmax saif={t_s:.2f}s "
                  f"dyn={t_d:.2f}s speedup={t_d/t_s:.1f}x")
    return rows


if __name__ == "__main__":
    run(full=True)
