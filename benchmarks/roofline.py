"""Roofline report (deliverable g): renders the dry-run JSONL records into
the EXPERIMENTS.md tables and picks the hillclimb cells.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline \
      --records results/dryrun_single_pod.jsonl
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List


def load(path: str) -> List[Dict]:
    with open(path) as f:
        return [json.loads(l) for l in f if l.strip()]


def fmt_table(records: List[Dict]) -> str:
    hdr = ("| arch | shape | dominant | compute s | memory s | coll s | "
           "coll bytes | peak mem/dev | useful/HLO flops |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in records:
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                         f"| — | {r['status'][:60]} |")
            continue
        uf = r.get("useful_flops_frac")
        lines.append(
            f"| {r['arch']} | {r['shape']} | **{r['dominant']}** "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | {r['collective_bytes']:.2e} "
            f"| {r['peak_memory_per_device']/2**30:.1f} GiB "
            f"| {uf:.3f} |" if uf else
            f"| {r['arch']} | {r['shape']} | **{r['dominant']}** "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | {r['collective_bytes']:.2e} "
            f"| {r['peak_memory_per_device']/2**30:.1f} GiB | n/a |")
    return "\n".join(lines)


def pick_hillclimb(records: List[Dict]) -> Dict[str, Dict]:
    ok = [r for r in records if r.get("status") == "ok"]
    # worst roofline fraction: dominant term much larger than compute term
    # => furthest from the compute roofline
    def roofline_frac(r):
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        return r["compute_s"] / dom if dom else 1.0
    worst = min(ok, key=roofline_frac)
    coll = max(ok, key=lambda r: r["collective_s"] /
               max(r["compute_s"], r["memory_s"], 1e-30))
    return {"worst_roofline": worst, "most_collective_bound": coll}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", required=True)
    args = ap.parse_args(argv)
    recs = load(args.records)
    print(fmt_table(recs))
    picks = pick_hillclimb(recs)
    print("\nHillclimb candidates:")
    for k, r in picks.items():
        print(f"  {k}: {r['arch']} x {r['shape']} (dominant {r['dominant']})")


if __name__ == "__main__":
    main()
