"""Fig. 6 + Table 1 reproduction: lambda-path solving — SAIF(warm) vs
sequential DPP vs unsafe homotopy; homotopy recall/precision < 1, SAIF = 1."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import simulation_data, timed
from repro.core import (HomotopyConfig, SaifConfig, SeqConfig, get_loss,
                        homotopy_path, lambda_grid, saif_path,
                        sequential_path, solve_lasso_cm, support_metrics)
from repro.core.duality import lambda_max


def run(full: bool = False):
    X, y, _ = simulation_data(n=100, p=2000 if full else 600)
    loss = get_loss("least_squares")
    lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    rows = []
    for n_lam in ((5, 20) if not full else (20, 50, 100)):
        lams = lambda_grid(0.9 * lmax, n_lam, lo_frac=0.01)
        t_saif = timed(lambda: saif_path(X, y, lams, SaifConfig(eps=1e-6)),
                       warmup=False)["seconds"]
        t_seq = timed(lambda: sequential_path(X, y, lams, SeqConfig(
            eps=1e-6)), warmup=False)["seconds"]
        # Table 1: unsafe homotopy variants vs the safe ground truth.
        # greedy_cap emulates the truncated pathwise-CD active-set policy
        # (Zhao 2017) whose misses Table 1 quantifies.
        stats = {}
        for name, cfg_h in (
                ("strong", HomotopyConfig(eps=1e-6)),
                ("greedy", HomotopyConfig(eps=1e-6, greedy_cap=6))):
            hres = homotopy_path(X, y, lams, cfg_h)
            recalls, precisions = [], []
            for lam, sup in zip(hres.lams, hres.supports):
                ref = solve_lasso_cm(loss, jnp.asarray(X), jnp.asarray(y),
                                     float(lam), tol=1e-9)
                ref_sup = np.where(np.abs(np.asarray(ref)) > 1e-8)[0]
                r, pr = support_metrics(sup, ref_sup)
                recalls.append(r)
                precisions.append(pr)
            stats[name] = (float(np.mean(recalls)),
                           float(np.mean(precisions)))
        rows.append({"n_lambda": n_lam, "saif_path_s": t_saif,
                     "dpp_path_s": t_seq,
                     "homotopy_strong_recall": stats["strong"][0],
                     "homotopy_strong_precision": stats["strong"][1],
                     "homotopy_greedy_recall": stats["greedy"][0],
                     "homotopy_greedy_precision": stats["greedy"][1]})
        print(f"[fig6/tab1] n_lam={n_lam} saif={t_saif:.2f}s "
              f"dpp={t_seq:.2f}s | strong-rule r={stats['strong'][0]:.3f} "
              f"p={stats['strong'][1]:.3f} | greedy-truncated "
              f"r={stats['greedy'][0]:.3f} p={stats['greedy'][1]:.3f} "
              f"(SAIF: r=p=1 by construction, tests/test_saif.py)")
    return rows


if __name__ == "__main__":
    run(full=True)
