"""Lambda-path benchmarks.

Default (CI) mode measures the compile-first path engine against the
pre-engine Python-loop driver (``saif_path_naive``) on the default CI shapes
— ``simulation_data`` + a 20-point ``lambda_grid`` — across the screening
backend axis (jnp vs pallas). Each cell reports cold wall-clock (compiles
included: the engine's whole point is compile-count reduction), warm
wall-clock, the speedup, and the number of distinct ``_saif_jit``
compilations the engine used (asserted <= O(log p)).

``--full`` additionally reproduces Fig. 6 + Table 1: SAIF(warm) vs
sequential DPP vs unsafe homotopy; homotopy recall/precision < 1, SAIF = 1.
"""
from __future__ import annotations

import math
import time

import jax
import numpy as np
import jax.numpy as jnp

from benchmarks.common import simulation_data, timed
from repro.core import (HomotopyConfig, SaifConfig, SeqConfig, get_loss,
                        homotopy_path, lambda_grid, saif_path,
                        saif_path_naive, sequential_path, solve_lasso_cm,
                        support_metrics)
from repro.core.duality import lambda_max

N_LAMBDA = 20   # the acceptance-criteria grid size


def _timed_path(fn):
    """Wall-clock a path solve, blocking on every solution buffer."""
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out.betas)
    return time.perf_counter() - t0, out


def _timed_path_cleared(fn):
    """Cold-start wall clock: jit caches dropped first (compiles counted)."""
    jax.clear_caches()
    return _timed_path(fn)


def run_engine_rows(full: bool = False):
    n, p = (100, 2000) if full else (100, 600)
    X, y, _ = simulation_data(n=n, p=p)
    loss = get_loss("least_squares")
    lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    lams = lambda_grid(0.9 * lmax, N_LAMBDA, lo_frac=0.01)
    compile_bound = int(math.ceil(math.log2(p))) + 2   # O(log p) acceptance
    rows = []
    for backend in ("jnp", "pallas"):
        cfg = SaifConfig(eps=1e-6, screen_backend=backend)
        # fresh jit caches before every cold run: both drivers pay their
        # true compiles; min-of-k suppresses scheduler noise on the
        # acceptance (jnp) axis
        reps = 2 if backend == "jnp" else 1
        t_naive = min(_timed_path_cleared(
            lambda: saif_path_naive(X, y, lams, cfg))[0]
            for _ in range(reps))
        t_cold, res = _timed_path_cleared(
            lambda: saif_path(X, y, lams, cfg))
        if reps > 1:
            t_cold = min(t_cold, _timed_path_cleared(
                lambda: saif_path(X, y, lams, cfg))[0])
        t_warm, _ = _timed_path(lambda: saif_path(X, y, lams, cfg))
        n_comp = res.n_compilations
        if n_comp is not None:      # None => counter unavailable this jax
            assert n_comp <= compile_bound, (
                f"path used {n_comp} _saif_jit compilations "
                f"(O(log p) bound = {compile_bound})")
        rows.append({
            "n_lambda": N_LAMBDA, "n": n, "p": p, "backend": backend,
            "naive_s": round(t_naive, 4), "engine_s": round(t_cold, 4),
            "engine_warm_s": round(t_warm, 4),
            "speedup": round(t_naive / max(t_cold, 1e-12), 3),
            "engine_compilations": n_comp,
            "compile_bound": compile_bound,
        })
        print(f"[path-engine] backend={backend} naive={t_naive:.2f}s "
              f"engine={t_cold:.2f}s (warm {t_warm:.2f}s) "
              f"speedup={t_naive / max(t_cold, 1e-12):.2f}x "
              f"compiles={n_comp}<= {compile_bound}")
    return rows


def run_fig6_rows(full: bool = False):
    """Paper Fig. 6 + Table 1 reproduction (slow: unscreened oracles)."""
    X, y, _ = simulation_data(n=100, p=2000 if full else 600)
    loss = get_loss("least_squares")
    lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    rows = []
    for n_lam in ((20, 50, 100) if full else (5, 20)):
        lams = lambda_grid(0.9 * lmax, n_lam, lo_frac=0.01)
        t_saif = timed(lambda: saif_path(X, y, lams, SaifConfig(eps=1e-6)),
                       warmup=False)["seconds"]
        t_seq = timed(lambda: sequential_path(X, y, lams, SeqConfig(
            eps=1e-6)), warmup=False)["seconds"]
        # Table 1: unsafe homotopy variants vs the safe ground truth.
        # greedy_cap emulates the truncated pathwise-CD active-set policy
        # (Zhao 2017) whose misses Table 1 quantifies.
        stats = {}
        for name, cfg_h in (
                ("strong", HomotopyConfig(eps=1e-6)),
                ("greedy", HomotopyConfig(eps=1e-6, greedy_cap=6))):
            hres = homotopy_path(X, y, lams, cfg_h)
            recalls, precisions = [], []
            for lam, sup in zip(hres.lams, hres.supports):
                ref = solve_lasso_cm(loss, jnp.asarray(X), jnp.asarray(y),
                                     float(lam), tol=1e-9)
                ref_sup = np.where(np.abs(np.asarray(ref)) > 1e-8)[0]
                r, pr = support_metrics(sup, ref_sup)
                recalls.append(r)
                precisions.append(pr)
            stats[name] = (float(np.mean(recalls)),
                           float(np.mean(precisions)))
        rows.append({"n_lambda": n_lam, "saif_path_s": t_saif,
                     "dpp_path_s": t_seq,
                     "homotopy_strong_recall": stats["strong"][0],
                     "homotopy_strong_precision": stats["strong"][1],
                     "homotopy_greedy_recall": stats["greedy"][0],
                     "homotopy_greedy_precision": stats["greedy"][1]})
        print(f"[fig6/tab1] n_lam={n_lam} saif={t_saif:.2f}s "
              f"dpp={t_seq:.2f}s | strong-rule r={stats['strong'][0]:.3f} "
              f"p={stats['strong'][1]:.3f} | greedy-truncated "
              f"r={stats['greedy'][0]:.3f} p={stats['greedy'][1]:.3f} "
              f"(SAIF: r=p=1 by construction, tests/test_saif.py)")
    return rows


def run(full: bool = False):
    rows = run_engine_rows(full=full)
    if full:
        rows += run_fig6_rows(full=True)
    return rows


if __name__ == "__main__":
    run(full=True)
