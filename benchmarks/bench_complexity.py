"""Theorem 4/5 validation: coordinate-update counts vs p.

Dynamic screening pays O(p log(G0/epsD)) coordinate updates; SAIF pays
O(p_bar log + p_bar p_A) with p_bar ~ |support| << p. So as p grows with
the support held fixed, dynamic updates grow ~linearly while SAIF stays
nearly flat."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import DynConfig, SaifConfig, dynamic_screening, saif, get_loss
from repro.core.duality import lambda_max


def run(full: bool = False):
    rng = np.random.default_rng(0)
    n, k = 80, 20
    ps = (400, 800, 1600) if not full else (1000, 2000, 4000, 8000)
    loss = get_loss("least_squares")
    rows = []
    for p in ps:
        X = rng.uniform(-10, 10, (n, p))
        beta = np.zeros(p)
        beta[rng.choice(p, k, replace=False)] = rng.uniform(-1, 1, k)
        y = X @ beta + rng.normal(0, 1, n)
        lam = 0.05 * float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
        res = saif(X, y, lam, SaifConfig(eps=1e-7))
        # SAIF coordinate updates ~ outer * K * k_max_used
        saif_updates = int(res.n_outer) * 5 * int(res.n_active)
        d = dynamic_screening(X, y, lam, DynConfig(eps=1e-7))
        rows.append({"p": p, "saif_updates": saif_updates,
                     "dyn_updates": d.coord_updates})
        print(f"[thm4/5] p={p} saif_updates~{saif_updates} "
              f"dyn_updates={d.coord_updates} "
              f"ratio={d.coord_updates/max(saif_updates,1):.1f}x")
    return rows


if __name__ == "__main__":
    run(full=True)
