"""Inner-solver backend benchmark: CM epoch cost vs n at fixed capacity.

The acceptance axis of the Gram/covariance-update engine (DESIGN.md §6):
an inner epoch costs O(count * n) on the jnp residual-update path but
O(count * k_max) on the Gram path, so at fixed capacity the Gram epoch time
must stay flat while the jnp epoch grows linearly in n — >= 3x apart by
n = 2000 at k_max <= 256 (tracked in BENCH_inner.json).

Each row times ``n_epochs`` compact sweeps through one jitted call (the
same entry points ``_saif_jit``'s backends use), min-of-k to suppress
scheduler noise. The Gram rows also report the amortized one-off costs the
engine pays per outer step (q rebuild is inside the timed call; the column
refresh is benchmarked separately as ``refresh_s``, its per-ADD bound).

The pallas backend is measured compiled on TPU; off-TPU it executes in
interpreter mode, which is a correctness oracle rather than a performance
path (DESIGN.md §3/§6), so it is timed only at the smallest shape and
flagged ``interpret``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import get_loss
from repro.core.cm import cm_epochs_compact, gram_epochs
from repro.kernels.ops import cm_burst, on_tpu

K_MAX = 256          # the acceptance capacity
COUNT = 192          # live slots swept per epoch
N_EPOCHS = 20        # sweeps per timed call (amortizes dispatch)
# n=100 is the CI path shape's sample count — the data point the
# GRAM_CROSSOVER policy comment and DESIGN.md §6 cite
N_GRID = (100, 500, 2000, 4000)
N_GRID_FULL = (100, 500, 2000, 8000, 16000)


def _timeit(fn, *args, reps: int = 5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    best = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _problem(n: int, k_max: int, count: int, seed: int = 0):
    r = np.random.default_rng(seed)
    mask = jnp.zeros(k_max, bool).at[:count].set(True)
    Xa = jnp.where(mask[None, :],
                   jnp.asarray(r.normal(size=(n, k_max)), jnp.float32), 0.0)
    y = jnp.asarray(r.normal(size=n), jnp.float32)
    beta = jnp.where(mask,
                     jnp.asarray(r.normal(size=k_max) * 0.1, jnp.float32),
                     0.0)
    order = jnp.arange(k_max, dtype=jnp.int32)
    return Xa, y, beta, mask, order


def run(full: bool = False):
    loss = get_loss("least_squares")
    lam = jnp.float32(0.1)
    cnt = jnp.asarray(COUNT, jnp.int32)
    rows = []
    for n in (N_GRID_FULL if full else N_GRID):
        Xa, y, beta, mask, order = _problem(n, K_MAX, COUNT)
        G = Xa.T @ Xa
        rho = Xa.T @ y
        col_sq = jnp.sum(Xa * Xa, axis=0)

        jnp_fn = jax.jit(lambda Xa, y, beta: cm_epochs_compact(
            loss, Xa, y, beta, Xa @ beta, mask, lam, order, cnt, N_EPOCHS))
        gram_fn = jax.jit(lambda G, rho, beta: gram_epochs(
            G, rho, beta, mask, lam, order, cnt, N_EPOCHS))
        # the Gram engine's per-ADD amortized cost: one h-column refresh
        h = 32
        cols = Xa[:, :h]
        refresh_fn = jax.jit(
            lambda Xa, cols: (Xa.T @ cols, cols.T @ Xa, cols.T @ y))

        t_jnp = _timeit(jnp_fn, Xa, y, beta) / N_EPOCHS
        t_gram = _timeit(gram_fn, G, rho, beta) / N_EPOCHS
        t_refresh = _timeit(refresh_fn, Xa, cols)
        base = {"n": n, "k_max": K_MAX, "count": COUNT,
                "n_epochs": N_EPOCHS}
        rows.append(dict(base, backend="jnp",
                         epoch_s=round(t_jnp, 6), speedup_vs_jnp=1.0))
        rows.append(dict(base, backend="gram",
                         epoch_s=round(t_gram, 6),
                         speedup_vs_jnp=round(t_jnp / t_gram, 3),
                         refresh_s=round(t_refresh, 6), refresh_h=h))
        print(f"[inner] n={n:6d} k_max={K_MAX} count={COUNT}: "
              f"jnp {t_jnp*1e3:8.3f} ms/epoch  gram {t_gram*1e3:7.3f} "
              f"ms/epoch  ({t_jnp/t_gram:6.2f}x)  refresh {t_refresh*1e3:.3f} ms")

        if on_tpu() or n == min(N_GRID_FULL if full else N_GRID):
            burst_fn = jax.jit(lambda Xa, y, beta: cm_burst(
                Xa, y, beta, col_sq, mask, order, lam, N_EPOCHS, cnt))
            t_pal = _timeit(burst_fn, Xa, y, beta, reps=2) / N_EPOCHS
            rows.append(dict(base, backend="pallas",
                             epoch_s=round(t_pal, 6),
                             speedup_vs_jnp=round(t_jnp / t_pal, 3),
                             interpret=not on_tpu()))
            mode = "compiled" if on_tpu() else "interpret"
            print(f"[inner] n={n:6d} pallas[{mode}] {t_pal*1e3:.3f} ms/epoch"
                  f"  (incl. fused dual/gap tail)")
    return rows


if __name__ == "__main__":
    run(full=True)
