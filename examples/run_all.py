"""Run every SAIF example with repro's own deprecation warnings promoted
to errors — the CI serve-smoke gate (ISSUE 5).

    PYTHONPATH=src python examples/run_all.py

The examples are the first-party consumers of the public surface; they
must live entirely on the session API. Every legacy shim's
``DeprecationWarning`` message contains the literal ``use
repro.open_session`` (see ``repro/core/_compat.py``), so exactly that
pattern is an error here: if any example — or any first-party code path
an example exercises — falls back onto a deprecated frontend, this
runner fails. Third-party DeprecationWarnings (jax, numpy) are
untouched.
"""
import pathlib
import runpy
import sys
import warnings

EXAMPLES = ["quickstart", "lasso_path", "cv_readme", "serving",
            "online_stream"]


def main():
    warnings.filterwarnings(
        "error", category=DeprecationWarning,
        message=r".*use repro\.open_session.*")
    here = pathlib.Path(__file__).resolve().parent
    for name in EXAMPLES:
        print(f"\n=== examples/{name}.py ===", flush=True)
        runpy.run_path(str(here / f"{name}.py"), run_name="__main__")
    print(f"\nall {len(EXAMPLES)} examples ran with zero repro "
          f"deprecation warnings")
    return 0


if __name__ == "__main__":
    sys.exit(main())
