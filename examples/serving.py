"""Serving demo: ONE session, a stream of heterogeneous requests.

    PYTHONPATH=src python examples/serving.py

This is the workload the session API exists for (ISSUE 5 / DESIGN.md §9):
a server holds ``open_session(problem)`` for the lifetime of the problem
and answers a request stream — scalar solves at client-chosen lambdas
(warm-started from the previous answer), whole paths, fresh-response
fleets — without ever re-preparing or re-compiling. Watch the latency
column: the first request at a new static signature pays the one-time
compile, every later request runs at solve cost, and
``session.compile_stats()`` proves the caches stopped moving.
"""
import time

import jax
jax.config.update("jax_enable_x64", True)

import numpy as np
import jax.numpy as jnp

from repro import Fleet, Path, Problem, SaifConfig, Scalar, open_session
from repro.core import get_loss
from repro.core.duality import lambda_max


def timed(session, request):
    t0 = time.perf_counter()
    res = session.solve(request)
    jax.block_until_ready(jax.tree.leaves(res)[0])
    return res, (time.perf_counter() - t0) * 1e3


def main():
    rng = np.random.default_rng(0)
    n, p = 80, 1200
    X = rng.uniform(-10, 10, (n, p))
    w = np.zeros(p)
    w[rng.choice(p, 20, replace=False)] = rng.uniform(-1, 1, 20)
    y = X @ w + rng.normal(0, 1, n)
    lmax = float(lambda_max(get_loss("least_squares"),
                            jnp.asarray(X), jnp.asarray(y)))

    t0 = time.perf_counter()
    session = open_session(Problem(X=X, y=y), SaifConfig(eps=1e-6))
    print(f"session open (one-time preparation): "
          f"{(time.perf_counter() - t0) * 1e3:.0f} ms")

    # a client streaming scalar requests at nearby lambdas — the bread
    # and butter of a screening server. warm=True hands the previous
    # solve's device-resident active set + Gram carry to the next one.
    print("\nscalar request stream (warm-started):")
    for i, frac in enumerate([0.30, 0.28, 0.26, 0.24, 0.22, 0.20,
                              0.25, 0.27]):
        res, ms = timed(session, Scalar(frac * lmax, warm=i > 0))
        nnz = int(res.n_active)
        print(f"  req {i}: lam={frac:.2f}*lmax  |A|={nnz:3d}  "
              f"gap={float(res.gap):.1e}  {ms:8.1f} ms"
              + ("   <- pays the compile" if i == 0 else ""))

    # a full path request rides the same session
    grid = np.geomspace(0.6 * lmax, 0.1 * lmax, 8)
    pr, ms = timed(session, Path(tuple(grid)))
    print(f"\npath request ({len(grid)} lambdas): {ms:.1f} ms, "
          f"{pr.n_compilations} new compilations")

    # fresh responses arrive: a fleet request over the SAME design — the
    # batch engine solves them in lockstep in one compiled program
    Y = np.stack([X @ (w * s) + rng.normal(0, 1, n)
                  for s in (0.8, 1.1, 0.9, 1.3)])
    fleet, ms = timed(session, Fleet(Y=Y, lams=0.25 * lmax))
    print(f"fleet request (B={Y.shape[0]} new responses): {ms:.1f} ms, "
          f"gaps={[f'{g:.0e}' for g in np.asarray(fleet.gap)]}")

    # replay part of the stream. The first replay pass may add one last
    # static key (the path request above grew the warm capacity, and a
    # warm scalar at the grown capacity is a new shape); the second pass
    # is the steady state — it must add ZERO compilations.
    print("\nhot replay (steady state):")
    for frac in (0.30, 0.24, 0.20):
        timed(session, Scalar(frac * lmax, warm=True))
    stats0 = session.compile_stats()
    for frac in (0.30, 0.24, 0.20):
        _, ms = timed(session, Scalar(frac * lmax, warm=True))
        print(f"  lam={frac:.2f}*lmax: {ms:.1f} ms")
    stats1 = session.compile_stats()
    print(f"\ncompile_stats: serial={stats1.serial} fleet={stats1.fleet} "
          f"group={stats1.group} | {stats1.since_open} compilations for "
          f"{stats1.requests} requests "
          f"(steady-state replay added "
          f"{stats1.since_open - stats0.since_open})")
    assert stats1.since_open == stats0.since_open, "hot session recompiled!"

    fault_drill(X, y, lmax)
    async_clients()


def async_clients():
    """The async front-end (DESIGN.md §12): many clients, one Server.

    ``submit()`` returns a future immediately; the dispatcher pads each
    request into a static shape bucket and coalesces same-design riders
    into ONE fleet microbatch, so a burst of small per-user solves costs
    one engine dispatch instead of eight."""
    from repro import open_server
    from repro import Problem, SaifConfig, Scalar

    print("\nasync clients (queue -> bucket -> microbatch -> fleet):")
    rng = np.random.default_rng(7)
    n, p = 60, 96
    X = rng.uniform(-10, 10, (n, p))        # ONE design shared by all
    loss = get_loss("least_squares")

    def user(r):
        w = np.zeros(p)
        w[rng.choice(p, 10, replace=False)] = rng.uniform(-1, 1, 10)
        yu = X @ w + rng.normal(0, 1, n)    # ...but each their own y
        lmax_u = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(yu)))
        return Problem(X=X, y=yu), (0.45 + 0.01 * (r % 8)) * lmax_u

    users = [user(r) for r in range(8)]
    with open_server(max_batch=8, max_wait_ms=100.0,
                     solver=SaifConfig(eps=1e-6)) as srv:
        t0 = time.perf_counter()
        futs = [srv.submit(pb, Scalar(lam, deadline_s=300.0,
                                      priority=r % 2))
                for r, (pb, lam) in enumerate(users)]
        dt = (time.perf_counter() - t0) * 1e3
        print(f"  submitted {len(futs)} requests in {dt:.1f} ms "
              f"(non-blocking futures)")
        results = [f.result(timeout=600) for f in futs]
        stats = srv.stats()
    for r, res in enumerate(results[:3]):
        nnz = int(np.count_nonzero(np.asarray(res.value.beta)))
        print(f"  user {r}: |A|={nnz:2d} gap={float(res.value.gap):.1e} "
              f"ok={res.verdict.ok}")
    print(f"  served={stats.served} "
          f"coalesced={stats.coalesced_requests} requests in "
          f"{stats.coalesced_batches + max(0, stats.served - stats.coalesced_requests)} "
          f"dispatches, warm sessions opened={stats.sessions_opened}")
    assert all(r.verdict.ok for r in results)
    assert stats.coalesced_requests == len(users), \
        "same-design riders did not coalesce"


def fault_drill(X, y, lmax):
    """The fault-tolerant runtime under injected fire (DESIGN.md §10):
    a transient backend fault is retried away behind a typed verdict,
    and a simulated preemption checkpoint/restores the warm state."""
    import tempfile

    from repro import FaultInjector, Problem, SaifConfig, Scalar
    from repro.core.serving import ServingConfig, open_serving
    from repro.runtime.fault import PreemptionGuard

    print("\nfault drill (injected transient backend fault):")
    srv = open_serving(Problem(X=X, y=y), SaifConfig(eps=1e-6),
                       serving=ServingConfig(backoff_base_s=0.0))
    srv.solve(Scalar(0.25 * lmax))            # warm the caches
    with FaultInjector(fail_at={1}):          # first engine call faults
        out = srv.solve(Scalar(0.25 * lmax))
    v = out.verdict
    print(f"  verdict: ok={v.ok} retries={v.retries} "
          f"gap={v.gap:.1e} kkt={v.kkt_residual:.1e} "
          f"(tol {v.kkt_tol:.1e}) events={list(v.events)}")
    assert v.ok and v.retries == 1

    print("\npreemption drill (SIGTERM -> checkpoint -> warm restore):")
    with tempfile.TemporaryDirectory() as ckpt_dir:
        a = open_serving(Problem(X=X, y=y), SaifConfig(eps=1e-6),
                         serving=ServingConfig(ckpt_dir=ckpt_dir),
                         guard=PreemptionGuard(install=False))
        a.solve(Scalar(0.25 * lmax, warm=True))
        a.guard.trigger()                     # the preemption signal
        out_a = a.solve(Scalar(0.22 * lmax, warm=True))
        print(f"  preempted server: {list(out_a.verdict.events)}")

        # 'restart': a fresh serving session on the same checkpoint dir
        b = open_serving(Problem(X=X, y=y), SaifConfig(eps=1e-6),
                         serving=ServingConfig(ckpt_dir=ckpt_dir))
        n0 = b.compile_stats().total
        out_b = b.solve(Scalar(0.22 * lmax, warm=True))
        extra = b.compile_stats().total - n0
        print(f"  restarted server: restored={b.restored} "
              f"ok={out_b.verdict.ok} extra_compilations={extra}")
        assert b.restored and extra == 0
        assert np.array_equal(np.asarray(out_a.value.beta),
                              np.asarray(out_b.value.beta)), \
            "restore is not bitwise"
        print("  restored warm solve is bitwise the pre-preemption one")


if __name__ == "__main__":
    main()
