"""End-to-end LM training driver (deliverable b): trains a reduced-config
zoo model for a few hundred steps with checkpointing + fault tolerance.

    PYTHONPATH=src python examples/train_lm.py            # ~25M params, CPU
    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm_350m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    return train_main([
        "--arch", args.arch, "--smoke", "--steps", str(args.steps),
        "--batch", "8", "--seq", "128", "--lr", "3e-3",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
        "--log-every", "20",
    ])


if __name__ == "__main__":
    raise SystemExit(main())
