"""End-to-end integration: sparse linear probing of LM hidden states.

The production coupling of SAIF with the model zoo (DESIGN.md §4): extract
frozen hidden-state features from any assigned architecture, then run the
*distributed* SAIF screening (feature-sharded shard_map scan) to select a
sparse probe — p = d_model features per token position, n = probe examples.

    PYTHONPATH=src python examples/probe_features.py --arch glm4_9b
"""
import argparse

import jax
jax.config.update("jax_enable_x64", True)

import numpy as np
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.core import SaifConfig
from repro.core.duality import lambda_max
from repro.core.losses import get_loss
from repro.distributed.saif_sharded import saif_distributed
from repro.launch.mesh import make_host_mesh
from repro.models import init
from repro.models.lm import backbone


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_3b")
    ap.add_argument("--examples", type=int, default=96)
    args = ap.parse_args()

    cfg = smoke_config(args.arch).scaled(dtype="float32")
    params = init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    # 1) extract features: final hidden state at the last position
    B, S = args.examples, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    kw = {}
    if cfg.family == "vlm":
        kw["img_embed"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_image_tokens, cfg.d_model))
    if cfg.family == "encdec":
        kw["frames"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_frames, cfg.d_model))
    hidden, _ = backbone(params, toks, cfg, **kw)
    feats = np.asarray(hidden[:, -1, :], np.float64)          # (B, D)
    # expand with pairwise products => a p >> n probe design
    D = feats.shape[1]
    pairs = rng.choice(D, (4 * D, 2))
    design = np.concatenate(
        [feats, feats[:, pairs[:, 0]] * feats[:, pairs[:, 1]]], axis=1)
    design = (design - design.mean(0)) / (design.std(0) + 1e-9)
    w = np.zeros(design.shape[1])
    w[rng.choice(design.shape[1], 12, replace=False)] = rng.normal(size=12)
    target = design @ w + 0.1 * rng.normal(size=B)
    print(f"probe design: n={design.shape[0]} p={design.shape[1]} "
          f"(from {cfg.name} hidden states)")

    # 2) distributed SAIF probe selection
    loss = get_loss("least_squares")
    lam = 0.1 * float(lambda_max(loss, jnp.asarray(design),
                                 jnp.asarray(target)))
    mesh = make_host_mesh()
    with mesh:
        res = saif_distributed(design, target, lam, mesh,
                               SaifConfig(eps=1e-7))
    sel = np.where(np.abs(np.asarray(res.beta)) > 1e-9)[0]
    truth = set(np.where(w != 0)[0])
    print(f"selected {len(sel)} features, gap={float(res.gap):.1e}; "
          f"recovered {len(truth & set(sel))}/{len(truth)} planted features")


if __name__ == "__main__":
    main()
