"""Lambda-path + fused-LASSO example on the session API: one session
serves a warm-started regularization path (paper Sec 5.3), a second one
serves a tree fused LASSO (Sec 4) from a single Theorem-6 transform.

    PYTHONPATH=src python examples/lasso_path.py
"""
import jax
jax.config.update("jax_enable_x64", True)

import numpy as np
import jax.numpy as jnp

from repro import Path, Problem, SaifConfig, Scalar, fused, open_session
from repro.core import fused_objective, get_loss, lambda_grid
from repro.core.duality import lambda_max


def main():
    rng = np.random.default_rng(1)
    n, p = 80, 1000
    X = rng.uniform(-10, 10, (n, p))
    beta_true = np.zeros(p)
    beta_true[rng.choice(p, 30, replace=False)] = rng.uniform(-1, 1, 30)
    y = X @ beta_true + rng.normal(0, 1, n)

    loss = get_loss("least_squares")
    lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    lams = lambda_grid(0.9 * lmax, 10, lo_frac=0.01)

    session = open_session(Problem(X=X, y=y), SaifConfig(eps=1e-7))
    res = session.solve(Path(tuple(lams)))
    print("lambda path (one session, one compilation, warm-started):")
    for lam, beta, r in zip(res.lams, res.betas, res.results):
        nnz = int(np.sum(np.abs(np.asarray(beta)) > 1e-9))
        print(f"  lam={lam:9.2f}  nnz={nnz:4d}  outer={int(r.n_outer):4d}  "
              f"gap={float(r.gap):.1e}")
    print(f"  path compilations: {res.n_compilations}")

    # --- fused LASSO on a chain graph (1-D total variation) ---------------
    # the session performs the Theorem-6 transform ONCE at open_session;
    # every request after that reuses the transformed design
    p2 = 60
    X2 = rng.normal(size=(n, p2))
    beta2 = np.zeros(p2)
    beta2[:20] = 2.0
    beta2[20:35] = -1.0
    y2 = X2 @ beta2 + 0.1 * rng.normal(size=n)
    parent = np.arange(p2) - 1
    fsession = open_session(Problem(X=X2, y=y2, penalty=fused(parent)),
                            SaifConfig(eps=1e-9))
    beta_f, _ = fsession.solve(Scalar(4.0))
    jumps = int(np.sum(np.abs(np.diff(beta_f)) > 1e-6))
    print(f"\nfused LASSO: {jumps} breakpoints "
          f"(truth has 2), objective="
          f"{fused_objective(X2, y2, parent, beta_f, 4.0):.4f}")


if __name__ == "__main__":
    main()
