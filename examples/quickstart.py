"""Quickstart: open a SAIF session, solve a LASSO problem, verify the
safe guarantee against the unscreened oracle.

    PYTHONPATH=src python examples/quickstart.py

``open_session`` (DESIGN.md §9) prepares the problem once — null-gradient
scores, column norms, backend resolution — and then serves any number of
requests from the same compiled state; ``session.solve(Scalar(lam))`` is
one such request.
"""
import jax
jax.config.update("jax_enable_x64", True)

import numpy as np
import jax.numpy as jnp

from repro import Problem, SaifConfig, Scalar, open_session
from repro.core import get_loss, solve_lasso_cm
from repro.core.duality import lambda_max


def main():
    rng = np.random.default_rng(0)
    n, p = 100, 2000
    X = rng.uniform(-10, 10, (n, p))
    beta_true = np.zeros(p)
    beta_true[rng.choice(p, p // 5, replace=False)] = rng.uniform(-1, 1, p // 5)
    y = X @ beta_true + rng.normal(0, 1, n)

    loss = get_loss("least_squares")
    lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    lam = 0.05 * lmax
    print(f"LASSO: n={n} p={p} lambda={lam:.1f} (lambda_max={lmax:.1f})")

    session = open_session(Problem(X=X, y=y), SaifConfig(eps=1e-7))
    res = session.solve(Scalar(lam))
    print(f"SAIF: {int(res.n_outer)} outer iters, "
          f"|A|={int(res.n_active)}, gap={float(res.gap):.2e}")

    beta_ref = solve_lasso_cm(loss, jnp.asarray(X), jnp.asarray(y), lam,
                              tol=1e-9)
    sup_saif = set(np.where(np.abs(np.asarray(res.beta)) > 1e-9)[0])
    sup_ref = set(np.where(np.abs(np.asarray(beta_ref)) > 1e-9)[0])
    print(f"support: SAIF={len(sup_saif)} reference={len(sup_ref)} "
          f"symmetric-difference={len(sup_saif ^ sup_ref)}  <- safe == 0")
    P = lambda b: float(loss.primal_objective(jnp.asarray(X), jnp.asarray(y),
                                              b, lam))
    print(f"objective: SAIF={P(res.beta):.6f} reference={P(beta_ref):.6f}")

    # the session stays hot: a second request at a nearby lambda reuses
    # the preparation, the compilation AND (with warm=True) the previous
    # solve's device-resident active set
    res2 = session.solve(Scalar(0.8 * lam, warm=True))
    stats = session.compile_stats()
    print(f"second request (warm): |A|={int(res2.n_active)}, "
          f"gap={float(res2.gap):.2e}; session compiles="
          f"{stats.since_open} over {stats.requests} requests")


if __name__ == "__main__":
    main()
