"""Quickstart: solve a LASSO problem with SAIF and verify the safe guarantee.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
jax.config.update("jax_enable_x64", True)

import numpy as np
import jax.numpy as jnp

from repro.core import SaifConfig, get_loss, saif, solve_lasso_cm
from repro.core.duality import lambda_max


def main():
    rng = np.random.default_rng(0)
    n, p = 100, 2000
    X = rng.uniform(-10, 10, (n, p))
    beta_true = np.zeros(p)
    beta_true[rng.choice(p, p // 5, replace=False)] = rng.uniform(-1, 1, p // 5)
    y = X @ beta_true + rng.normal(0, 1, n)

    loss = get_loss("least_squares")
    lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    lam = 0.05 * lmax
    print(f"LASSO: n={n} p={p} lambda={lam:.1f} (lambda_max={lmax:.1f})")

    res = saif(X, y, lam, SaifConfig(eps=1e-7))
    print(f"SAIF: {int(res.n_outer)} outer iters, "
          f"|A|={int(res.n_active)}, gap={float(res.gap):.2e}")

    beta_ref = solve_lasso_cm(loss, jnp.asarray(X), jnp.asarray(y), lam,
                              tol=1e-9)
    sup_saif = set(np.where(np.abs(np.asarray(res.beta)) > 1e-9)[0])
    sup_ref = set(np.where(np.abs(np.asarray(beta_ref)) > 1e-9)[0])
    print(f"support: SAIF={len(sup_saif)} reference={len(sup_ref)} "
          f"symmetric-difference={len(sup_saif ^ sup_ref)}  <- safe == 0")
    P = lambda b: float(loss.primal_objective(jnp.asarray(X), jnp.asarray(y),
                                              b, lam))
    print(f"objective: SAIF={P(res.beta):.6f} reference={P(beta_ref):.6f}")


if __name__ == "__main__":
    main()
