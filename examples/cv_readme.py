"""Pick lambda by K-fold cross-validation through the session API.

    PYTHONPATH=src python examples/cv_readme.py

A ``CV`` request (DESIGN.md §8/§9) solves the whole K-folds x L-lambdas
grid as a fleet: the K fold problems share the design matrix (fold
masking is done with per-problem sample weights, so no row copies are
made), run in lockstep inside ONE compiled solver, and warm-start each
other down the lambda grid exactly like the serial path engine. The
winner is refit on the full data with the serial SAIF solver — all of it
behind one ``session.solve``.
"""
import jax
jax.config.update("jax_enable_x64", True)

import numpy as np
import jax.numpy as jnp

from repro import CV, Problem, SaifConfig, open_session
from repro.core import get_loss, lambda_grid
from repro.core.duality import lambda_max


def main():
    rng = np.random.default_rng(0)
    n, p, k_true = 120, 1500, 12
    X = rng.uniform(-10, 10, (n, p))
    beta_true = np.zeros(p)
    beta_true[rng.choice(p, k_true, replace=False)] = rng.uniform(-1, 1,
                                                                  k_true)
    y = X @ beta_true + rng.normal(0, 1, n)

    loss = get_loss("least_squares")
    lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    lams = lambda_grid(0.7 * lmax, 12, lo_frac=0.01)
    print(f"CV: n={n} p={p} | {len(lams)} lambdas x 5 folds "
          f"(lambda_max={lmax:.1f})")

    session = open_session(Problem(X=X, y=y), SaifConfig(eps=1e-7))
    res = session.solve(CV(n_folds=5, lams=tuple(lams)))
    print(f"fleet compilations: {res.n_compilations} "
          f"(one solver serves all {5 * len(lams)} fold-lambda solves)")
    for lam, m, se in zip(res.lams, res.cv_mean, res.cv_se):
        marker = "  <- best" if float(lam) == res.best_lam else ""
        print(f"  lambda={lam:9.2f}  cv-loss={m:9.4f} +- {se:.4f}{marker}")

    sup = np.where(np.abs(np.asarray(res.beta)) > 1e-8)[0]
    true_sup = np.where(beta_true != 0)[0]
    print(f"best lambda={res.best_lam:.2f}; refit support={len(sup)} "
          f"(true support {len(true_sup)}, recovered "
          f"{len(set(sup) & set(true_sup))})")


if __name__ == "__main__":
    main()
