"""Streaming demo: rows arrive in batches, the support evolves, repeat
traffic hits the homotopy cache, and ``select()`` picks the lambda.

    PYTHONPATH=src python examples/online_stream.py

The three production workloads of DESIGN.md §14 on one session:

  * ``session.update(rows, responses)`` absorbs each arriving batch
    into the device-resident state (row-capacity padding + incremental
    Gram/correlation updates) and re-solves warm — watch the latency
    column stay at solve cost and ``compile_stats()`` stay frozen;
  * a second session over the same problem asks for a nearby lambda
    and enters through the shared ``WarmCache`` (Theorem-2 sequential
    ball around the cached dual) instead of growing a cold active set;
  * ``session.select()`` runs the CV fleet + the 1-SE rule + a
    B-subsample stability-selection fleet and returns the support a
    client actually wants.
"""
import time

import jax
jax.config.update("jax_enable_x64", True)

import numpy as np

from repro import (Problem, SaifConfig, Scalar, Select, WarmCache,
                   WarmCacheConfig, open_session)


def main():
    rng = np.random.default_rng(0)
    n0, p = 96, 600
    beta = np.zeros(p)
    hot = rng.choice(p, 10, replace=False)
    beta[hot] = rng.uniform(0.8, 1.6, 10)
    X = rng.normal(size=(n0, p))
    y = X @ beta + 0.3 * rng.normal(size=n0)
    lam = 0.15 * float(np.abs(X.T @ y).max())

    cache = WarmCache(WarmCacheConfig())
    session = open_session(Problem(X=X, y=y), SaifConfig(eps=1e-7),
                           warm_cache=cache)
    res = session.solve(Scalar(lam))
    support = set(np.flatnonzero(np.abs(np.asarray(res.beta)) > 0))
    print(f"cold solve: {len(support)} active features")

    # --- rows arrive in batches; the support evolves ------------------
    print("\nstreaming 8 batches of 16 rows (zero engine recompiles "
          "after the first padded solve):")
    for t in range(8):
        Xn = rng.normal(size=(16, p))
        yn = Xn @ beta + 0.3 * rng.normal(size=16)
        t0 = time.perf_counter()
        res = session.update(rows=Xn, responses=yn, lam=lam)
        jax.block_until_ready(res.beta)
        ms = (time.perf_counter() - t0) * 1e3
        sup = set(np.flatnonzero(np.abs(np.asarray(res.beta)) > 0))
        joined = len(sup - support)
        left = len(support - sup)
        support = sup
        print(f"  batch {t}: {ms:7.1f} ms  active={len(sup):3d}  "
              f"(+{joined}/-{left})  gap={float(res.gap):.2e}")
    stats = session.compile_stats()
    print(f"engine compilations since open: {stats.since_open}")

    # --- repeat traffic at a nearby lambda hits the warm cache --------
    repeat = open_session(Problem(X=X, y=y), SaifConfig(eps=1e-7),
                          warm_cache=cache)
    t0 = time.perf_counter()
    r2 = repeat.solve(Scalar(0.7 * lam))
    jax.block_until_ready(r2.beta)
    ms = (time.perf_counter() - t0) * 1e3
    events = [e for e in repeat.drain_events()
              if e.startswith("warm_cache")]
    print(f"\nnearby-lambda repeat (0.7x) on a fresh session: "
          f"{ms:.1f} ms, events={events}")
    print(f"cache stats: {cache.stats()}")

    # --- auto-lambda: 1-SE CV + stability selection -------------------
    lam_max = float(np.abs(X.T @ y).max())
    report = session.select(Select(
        lams=tuple(np.geomspace(0.5, 0.03, 8) * lam_max),
        n_folds=4, n_subsamples=12, seed=1))
    stable = report.stable_support
    print(f"\nselect(): lam_min={report.lam_min:.3f}  "
          f"lam_1se={report.lam_1se:.3f}  (rule={report.rule})")
    print(f"stable support ({stable.size} features at "
          f"pi>={report.pi_threshold}): recovered "
          f"{len(set(stable.tolist()) & set(hot.tolist()))}/{len(hot)} "
          f"true signals")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
