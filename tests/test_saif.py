"""End-to-end SAIF correctness: the SAFE guarantee, convergence, traces."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SaifConfig, get_loss, saif, saif_path, lambda_grid,
                        solve_lasso_cm)
from repro.core.duality import lambda_max

from conftest import kkt_violation, make_classification, make_regression


def _support(beta, tol=1e-9):
    return set(np.where(np.abs(np.asarray(beta)) > tol)[0].tolist())


@pytest.mark.parametrize("frac", [0.5, 0.1, 0.02])
def test_saif_matches_full_solve_ls(rng, frac):
    loss = get_loss("least_squares")
    X, y, _ = make_regression(rng, n=50, p=300)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    lam = frac * float(lambda_max(loss, Xj, yj))
    res = saif(X, y, lam, SaifConfig(eps=1e-8))
    beta_ref = solve_lasso_cm(loss, Xj, yj, lam, tol=1e-10)
    p_saif = float(loss.primal_objective(Xj, yj, res.beta, lam))
    p_ref = float(loss.primal_objective(Xj, yj, beta_ref, lam))
    assert p_saif <= p_ref + 1e-6 * max(abs(p_ref), 1)
    assert _support(res.beta, 1e-8) == _support(beta_ref, 1e-8)
    assert kkt_violation(loss, Xj, yj, res.beta, lam) <= 1e-3 * lam


@pytest.mark.parametrize("frac", [0.3, 0.05])
def test_saif_matches_full_solve_logistic(rng, frac):
    loss = get_loss("logistic")
    X, y, _ = make_classification(rng, n=60, p=250)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    lam = frac * float(lambda_max(loss, Xj, yj))
    res = saif(X, y, lam, SaifConfig(eps=1e-8, loss="logistic"))
    beta_ref = solve_lasso_cm(loss, Xj, yj, lam, tol=1e-10)
    assert _support(res.beta, 1e-8) == _support(beta_ref, 1e-8)
    assert kkt_violation(loss, Xj, yj, res.beta, lam) <= 1e-3 * lam


def test_safety_recall_precision_one(rng):
    """The paper's headline: SAIF recall == precision == 1 vs ground truth."""
    loss = get_loss("least_squares")
    X, y, _ = make_regression(rng, n=40, p=200)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    lmax = float(lambda_max(loss, Xj, yj))
    for frac in (0.4, 0.1, 0.03):
        lam = frac * lmax
        res = saif(X, y, lam, SaifConfig(eps=1e-9))
        beta_ref = solve_lasso_cm(loss, Xj, yj, lam, tol=1e-11)
        s, r = _support(res.beta, 1e-8), _support(beta_ref, 1e-8)
        tp = len(s & r)
        assert tp == len(r) == len(s)   # recall = precision = 1


def test_gap_reaches_eps(rng):
    X, y, _ = make_regression(rng, n=40, p=150)
    loss = get_loss("least_squares")
    lam = 0.1 * float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    for eps in (1e-6, 1e-9):
        res = saif(X, y, lam, SaifConfig(eps=eps))
        assert float(res.gap) <= eps


def test_active_set_grows_from_small(rng):
    """Fig 3 behaviour: |A_t| starts << p and stays O(|support|)."""
    X, y, _ = make_regression(rng, n=50, p=500)
    loss = get_loss("least_squares")
    lam = 0.05 * float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    res = saif(X, y, lam, SaifConfig(eps=1e-7))
    tr = np.asarray(res.trace_n_active)
    tr = tr[tr >= 0]
    assert tr[0] < 0.2 * 500            # starts small
    assert tr.max() < 500               # never the full problem
    assert tr.max() <= 6 * max(int(res.n_active), 1)


def test_capacity_overflow_recovers(rng):
    """Tiny k_max forces the elastic-capacity recompile path; still exact."""
    X, y, _ = make_regression(rng, n=40, p=200)
    loss = get_loss("least_squares")
    lam = 0.05 * float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    res = saif(X, y, lam, SaifConfig(eps=1e-8, k_max=8))
    beta_ref = solve_lasso_cm(loss, jnp.asarray(X), jnp.asarray(y), lam,
                              tol=1e-10)
    assert _support(res.beta, 1e-8) == _support(beta_ref, 1e-8)


def test_lam_above_lambda_max_gives_zero(rng):
    X, y, _ = make_regression(rng, n=30, p=100)
    loss = get_loss("least_squares")
    lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    res = saif(X, y, 1.5 * lmax, SaifConfig(eps=1e-9))
    assert float(jnp.abs(res.beta).max()) == 0.0


def test_warm_started_path_consistent(rng):
    """Sec 5.3: warm-started path solutions match independent solves."""
    X, y, _ = make_regression(rng, n=40, p=150)
    loss = get_loss("least_squares")
    lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    lams = lambda_grid(lmax, 5, lo_frac=0.02)
    pres = saif_path(X, y, lams, SaifConfig(eps=1e-8))
    for lam, beta in zip(pres.lams, pres.betas):
        cold = saif(X, y, float(lam), SaifConfig(eps=1e-8))
        assert _support(beta, 1e-8) == _support(cold.beta, 1e-8)


@pytest.mark.parametrize("seed", [5, 0, 1, 16, 17])
def test_gaussian_design_near_lambda_max_support(seed):
    """Former ROADMAP open item (fixed): on gaussian (non-uniform) designs
    at lambda within ~10% of lambda_max, SAIF used to miss small true-
    support features vs the unscreened CM oracle. Root cause was neither
    the Thm-2 sequential ball nor the h formula: at a machine-converged
    sub-problem the duality gap underflows to exactly 0 (or negative), the
    gap-ball radius collapses to 0, and the strict <1 DEL rule deletes a
    boundary feature (|x^T theta*| = 1) on floating-point noise while the
    ADD-stop sees max_ub = 1 - O(eps) < 1. Fixed by flooring the gap at
    its own arithmetic precision (duality.gap_precision_floor) before the
    radius is derived. Seeds cover the PR-1 repro set (0-29 verified; the
    5 listed here were the reproducible misses worth keeping fast)."""
    loss = get_loss("least_squares")
    X, y, _ = make_regression(np.random.default_rng(seed), n=40, p=200,
                              uniform=False)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    lam = 0.9 * float(lambda_max(loss, Xj, yj))
    res = saif(X, y, lam, SaifConfig(eps=1e-8))
    ref = solve_lasso_cm(loss, Xj, yj, lam, tol=1e-10)
    assert _support(res.beta, 1e-8) == _support(ref, 1e-8)


from repro.testing import given, settings, st


@given(seed=st.integers(0, 10_000),
       lam_frac=st.sampled_from([0.5, 0.2, 0.08]),
       loss_name=st.sampled_from(["least_squares", "logistic"]))
@settings(max_examples=8, deadline=None)
def test_safety_property(seed, lam_frac, loss_name):
    """THE system invariant (hypothesis): for arbitrary problems, SAIF's
    support equals the unscreened oracle's — the safe guarantee."""
    r = np.random.default_rng(seed)
    n, p = 25, 60
    X = r.normal(size=(n, p)) * r.uniform(0.5, 3)
    w = np.zeros(p)
    w[r.choice(p, 8, replace=False)] = r.normal(size=8)
    if loss_name == "logistic":
        y = np.sign(X @ w + 0.2 * r.normal(size=n))
        y[y == 0] = 1.0
    else:
        y = X @ w + 0.5 * r.normal(size=n)
    loss = get_loss(loss_name)
    from repro.core.duality import lambda_max as lmax_fn
    lam = lam_frac * float(lmax_fn(loss, jnp.asarray(X), jnp.asarray(y)))
    res = saif(X, y, lam, SaifConfig(eps=1e-9, loss=loss_name))
    ref = solve_lasso_cm(loss, jnp.asarray(X), jnp.asarray(y), lam,
                         tol=1e-11)
    assert _support(res.beta, 1e-8) == _support(ref, 1e-8)
