"""Inner-solver backend parity: the jnp residual-update epochs, the Gram
covariance-update engine and the fused Pallas burst kernel must agree — to
float tolerance on the coefficients, and bitwise on the final SAIF active
sets — plus the Gram refresh invariants and the backend-selection policies.

On this CPU container the Pallas kernel runs in interpret mode (in the
problem dtype, so x64 parity is exact-grade); on a TPU backend the identical
entry point compiles to Mosaic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_classification, make_regression
from repro.core import (SaifConfig, get_loss, lambda_grid, resolve_backend,
                        resolve_inner_backend, saif, saif_path,
                        solve_lasso_cm)
from repro.core import active_set as asl
from repro.core.cm import cm_epochs_compact, gram_epochs
from repro.core.duality import lambda_max
from repro.core.inner_backend import (GRAM_CROSSOVER, cold_inner_carry,
                                      make_inner_gram, make_inner_jnp,
                                      make_inner_pallas)
from repro.kernels.ops import cm_burst, on_tpu

INNER_BACKENDS = ["jnp", "gram", "pallas"]


def _support(beta, tol=1e-8):
    return set(np.where(np.abs(np.asarray(beta)) > tol)[0].tolist())


def _random_block(rng, n, k_max, count, dtype=jnp.float64):
    mask = jnp.zeros(k_max, bool).at[:count].set(True)
    Xa = jnp.where(mask[None, :],
                   jnp.asarray(rng.normal(size=(n, k_max)), dtype), 0.0)
    y = jnp.asarray(rng.normal(size=n), dtype)
    beta = jnp.where(mask, jnp.asarray(rng.normal(size=k_max) * 0.1, dtype),
                     0.0)
    order = jnp.arange(k_max, dtype=jnp.int32)
    return Xa, y, beta, mask, order


# --------------------------------------------------------------------------
# epoch-level parity
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n,k_max,count", [(64, 16, 12), (200, 32, 32),
                                           (37, 24, 7)])
@pytest.mark.parametrize("n_ep", [1, 5])
def test_gram_epochs_match_jnp(rng, n, k_max, count, n_ep):
    """Covariance updates == residual updates, step for step (LS)."""
    loss = get_loss("least_squares")
    Xa, y, beta, mask, order = _random_block(rng, n, k_max, count)
    lam = 0.3
    b_ref, _ = cm_epochs_compact(loss, Xa, y, beta, Xa @ beta, mask, lam,
                                 order, jnp.asarray(count), n_ep)
    b_gram = gram_epochs(Xa.T @ Xa, Xa.T @ y, beta, mask, lam, order,
                         jnp.asarray(count), n_ep)
    np.testing.assert_allclose(np.asarray(b_gram), np.asarray(b_ref),
                               rtol=1e-8, atol=1e-10)


@pytest.mark.parametrize("loss_name", ["least_squares", "logistic"])
@pytest.mark.parametrize("n,k_max,count", [(64, 16, 12), (100, 32, 25)])
def test_pallas_burst_matches_jnp_backend(rng, loss_name, n, k_max, count):
    """The fused kernel's (beta, z, theta, gap) == the jnp backend's, to
    fp32-grade tolerance (exact-grade here: interpret mode runs in f64)."""
    loss = get_loss(loss_name)
    Xa, y, beta, mask, order = _random_block(rng, n, k_max, count)
    if loss_name == "logistic":
        y = jnp.sign(y) + (y == 0)
    lam = jnp.asarray(0.2, Xa.dtype)
    n_ep = 3
    col_sq = jnp.sum(Xa * Xa, axis=0)

    b_ref, z_ref = cm_epochs_compact(loss, Xa, y, beta, Xa @ beta, mask,
                                     lam, order, jnp.asarray(count), n_ep)
    from repro.core.duality import duality_gap, feasible_dual
    hat = -loss.grad(Xa @ b_ref, y) / lam
    th_ref = feasible_dual(loss, Xa, y, hat, lam, mask)
    gap_ref = duality_gap(loss, Xa, y, b_ref, th_ref, lam, mask)

    b, z, th, gap = cm_burst(Xa, y, beta, col_sq, mask, order, lam,
                             n_ep, count, loss_name=loss_name)
    np.testing.assert_allclose(np.asarray(b), np.asarray(b_ref),
                               rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(np.asarray(z), np.asarray(Xa @ b_ref),
                               rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(np.asarray(th), np.asarray(th_ref),
                               rtol=1e-6, atol=1e-8)
    assert float(gap) == pytest.approx(float(gap_ref), rel=1e-6, abs=1e-8)


def test_pallas_burst_masked_slots_stay_zero(rng):
    n, k_max, count = 50, 12, 5
    Xa, y, beta, mask, order = _random_block(rng, n, k_max, count)
    col_sq = jnp.sum(Xa * Xa, axis=0)
    b, _, _, _ = cm_burst(Xa, y, beta, col_sq, mask, order, 0.1, 4, count)
    assert (np.asarray(b)[count:] == 0).all()


# --------------------------------------------------------------------------
# Gram refresh invariants
# --------------------------------------------------------------------------

def _check_gram_invariant(carry, aset, X):
    """G == Xa^T Xa on every live x live pair; gidx matches idx on live."""
    Xa = np.asarray(asl.gather_columns(jnp.asarray(X), aset))
    mask = np.asarray(aset.mask)
    G_ref = Xa.T @ Xa
    G = np.asarray(carry.G)
    live = np.where(mask)[0]
    np.testing.assert_allclose(G[np.ix_(live, live)],
                               G_ref[np.ix_(live, live)],
                               rtol=1e-9, atol=1e-9)
    gidx = np.asarray(carry.gidx)
    assert (gidx[mask] == np.asarray(aset.idx)[mask]).all()


def test_gram_refresh_add_delete_sequence(rng):
    """Random ADD/DEL churn: the incrementally refreshed carry always
    equals a from-scratch Gram build on the live block (invariants 1-4)."""
    loss = get_loss("least_squares")
    n, p, k_max, h = 30, 60, 16, 4
    X = jnp.asarray(rng.normal(size=(n, p)))
    y = jnp.asarray(rng.normal(size=n))
    be = make_inner_gram(loss, X, y, h)

    init = rng.choice(p, 5, replace=False)
    aset = asl.init_active_set(p, k_max, jnp.asarray(init), X.dtype)
    carry = be.init(aset, cold_inner_carry(k_max, X.dtype),
                    asl.gather_columns(X, aset))
    _check_gram_invariant(carry, aset, X)

    for _ in range(12):
        if rng.random() < 0.5:
            member = np.asarray(aset.in_active)
            cands = np.where(~member)[0]
            m = min(h, len(cands))
            if m == 0:
                continue
            chosen = rng.choice(cands, m, replace=False).astype(np.int32)
            keep = rng.random(m) < 0.8
            aset = asl.add_features(aset, jnp.asarray(chosen),
                                    jnp.asarray(keep))
        else:
            drop = jnp.asarray(rng.random(k_max) < 0.3)
            aset = asl.delete_features(aset, drop)
        carry = be.refresh(carry, aset, asl.gather_columns(X, aset))
        _check_gram_invariant(carry, aset, X)
        # rho invariant on live slots
        live = np.where(np.asarray(aset.mask))[0]
        rho_ref = np.asarray(asl.gather_columns(X, aset)).T @ np.asarray(y)
        np.testing.assert_allclose(np.asarray(carry.rho)[live],
                                   rho_ref[live], rtol=1e-9, atol=1e-9)


def test_gram_init_reconciles_warm_carry(rng):
    """A clean warm carry is kept verbatim; a stale one triggers a full
    rebuild — both end in a valid invariant state."""
    loss = get_loss("least_squares")
    n, p, k_max, h = 25, 40, 8, 4
    X = jnp.asarray(rng.normal(size=(n, p)))
    y = jnp.asarray(rng.normal(size=n))
    be = make_inner_gram(loss, X, y, h)
    aset = asl.init_active_set(p, k_max, jnp.asarray([1, 5, 9]), X.dtype)
    Xa = asl.gather_columns(X, aset)
    carry = be.init(aset, cold_inner_carry(k_max, X.dtype), Xa)
    # clean handoff: same aset -> carry unchanged
    carry2 = be.init(aset, carry, Xa)
    np.testing.assert_array_equal(np.asarray(carry2.G), np.asarray(carry.G))
    # stale handoff: slot 0 now backs a different feature -> rebuilt
    aset3 = aset._replace(idx=aset.idx.at[0].set(17),
                          in_active=aset.in_active.at[1].set(False)
                          .at[17].set(True))
    carry3 = be.init(aset3, carry, asl.gather_columns(X, aset3))
    _check_gram_invariant(carry3, aset3, X)


# --------------------------------------------------------------------------
# solver-level parity: identical final active sets across inner backends
# --------------------------------------------------------------------------

@pytest.mark.parametrize("frac", [0.3, 0.08])
def test_saif_inner_backends_identical_active_sets(rng, frac):
    """Cold solves: all three inner backends land on the oracle support."""
    loss = get_loss("least_squares")
    X, y, _ = make_regression(rng, n=50, p=300)
    lam = frac * float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    ref = solve_lasso_cm(loss, jnp.asarray(X), jnp.asarray(y), lam,
                         tol=1e-10)
    sups = {}
    for be in INNER_BACKENDS:
        res = saif(X, y, lam, SaifConfig(eps=1e-8, inner_backend=be))
        assert float(res.gap) <= 1e-8
        sups[be] = _support(res.beta)
    assert sups["jnp"] == sups["gram"] == sups["pallas"] == _support(ref)


def test_saif_inner_backends_logistic(rng):
    """General-loss parity: the pallas prox-Newton burst == the jnp path."""
    loss = get_loss("logistic")
    X, y, _ = make_classification(rng, n=60, p=250)
    lam = 0.1 * float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    sups = {}
    for be in ("jnp", "pallas"):
        res = saif(X, y, lam,
                   SaifConfig(eps=1e-8, loss="logistic", inner_backend=be))
        sups[be] = _support(res.beta)
    assert sups["jnp"] == sups["pallas"]


def test_saif_path_inner_backends_warm_equals_cold(rng):
    """Warm-started paths (Gram buffers handed across lambdas) match cold
    solves and the unscreened oracle, for every inner backend."""
    loss = get_loss("least_squares")
    X, y, _ = make_regression(np.random.default_rng(91), n=40, p=200)
    lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    lams = lambda_grid(0.9 * lmax, 5, lo_frac=0.02)
    sups_by_backend = {}
    for be in INNER_BACKENDS:
        cfg = SaifConfig(eps=1e-8, inner_backend=be)
        eng = saif_path(X, y, lams, cfg)
        assert eng.n_compilations is None or eng.n_compilations <= 10
        sups = []
        for lam, beta in zip(eng.lams, eng.betas):
            cold = saif(X, y, float(lam), cfg)
            assert _support(beta) == _support(cold.beta)
            sups.append(_support(beta))
        sups_by_backend[be] = sups
    assert (sups_by_backend["jnp"] == sups_by_backend["gram"]
            == sups_by_backend["pallas"])


def test_gram_capacity_overflow_recovers(rng):
    """Elastic capacity growth pads the Gram carry; still exact."""
    loss = get_loss("least_squares")
    X, y, _ = make_regression(np.random.default_rng(92), n=40, p=200)
    lam = 0.05 * float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    res = saif(X, y, lam, SaifConfig(eps=1e-8, k_max=8,
                                     inner_backend="gram"))
    ref = solve_lasso_cm(loss, jnp.asarray(X), jnp.asarray(y), lam,
                         tol=1e-10)
    assert _support(res.beta) == _support(ref)


# --------------------------------------------------------------------------
# backend-selection policies (DESIGN.md §3 / §6)
# --------------------------------------------------------------------------

def test_screen_backend_auto_policy():
    """Satellite: "auto" must resolve to the jnp screen backend off-TPU
    (BENCH_path.json: pallas-interpret 1.32x vs jnp 2.12x on the CI shape)
    and to the fused kernels on TPU."""
    expected = "pallas" if on_tpu() else "jnp"
    assert resolve_backend("auto") == expected
    assert resolve_backend("jnp") == "jnp"          # explicit always wins
    assert resolve_backend("pallas") == "pallas"
    with pytest.raises(ValueError):
        resolve_backend("nope")


def test_inner_backend_auto_policy():
    # gram whenever the loss is LS and capacity is not >> n
    assert resolve_inner_backend("auto", "least_squares", 100, 256) == "gram"
    assert resolve_inner_backend("auto", "least_squares", 2000, 256) == "gram"
    # capacity way beyond the crossover: fall back (jnp on CPU)
    big_k = int(GRAM_CROSSOVER * 10) + 10
    fallback = resolve_inner_backend("auto", "least_squares", 10, big_k)
    assert fallback == ("pallas" if on_tpu() else "jnp")
    # non-linear gradient: no gram
    assert resolve_inner_backend("auto", "logistic", 100, 64) == \
        ("pallas" if on_tpu() else "jnp")
    # explicit names win / are validated
    assert resolve_inner_backend("jnp", "least_squares", 10**6, 8) == "jnp"
    with pytest.raises(ValueError):
        resolve_inner_backend("gram", "logistic", 100, 64)
    with pytest.raises(ValueError):
        resolve_inner_backend("turbo", "least_squares", 100, 64)
    # explicit pallas must fit the VMEM budget (DESIGN.md §6)
    assert resolve_inner_backend("pallas", "logistic", 100, 64) == "pallas"
    with pytest.raises(ValueError):
        resolve_inner_backend("pallas", "least_squares", 100_000, 1024)


def test_gram_epochs_touch_no_n_sized_arrays():
    """Acceptance: no O(n) work per coordinate step under the gram backend.
    Structural proof: the whole epoch jaxpr contains no array with a
    dimension larger than k_max (n never enters)."""
    k_max, n = 16, 10_000
    loss = get_loss("least_squares")
    closed = jax.make_jaxpr(
        lambda G, rho, beta, mask, order: gram_epochs(
            G, rho, beta, mask, 0.1, order, jnp.asarray(8), 3,
            smoothness=loss.smoothness))(
        jnp.zeros((k_max, k_max)), jnp.zeros(k_max), jnp.zeros(k_max),
        jnp.ones(k_max, bool), jnp.arange(k_max, dtype=jnp.int32))

    # walk nested jaxprs (fori_loop bodies live in eqn params)
    def walk(jaxpr, acc):
        for eqn in jaxpr.eqns:
            for v in list(eqn.invars) + list(eqn.outvars):
                aval = getattr(v, "aval", None)
                shape = getattr(aval, "shape", ()) if aval is not None else ()
                acc.extend(d for d in shape if isinstance(d, int))
            for p in eqn.params.values():
                if hasattr(p, "jaxpr"):
                    walk(p.jaxpr, acc)
                elif isinstance(p, (list, tuple)):
                    for q in p:
                        if hasattr(q, "jaxpr"):
                            walk(q.jaxpr, acc)
        return acc

    dims = walk(closed.jaxpr, [1])
    assert max(dims) <= k_max * k_max
    assert n not in dims          # nothing n-shaped anywhere in the burst
