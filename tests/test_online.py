"""Streaming & model-selection subsystem tests (DESIGN.md §14).

What must hold:

  * an online row stream (``Session.update``) matches the cold solve of
    the concatenated design — allclose coefficients, identical support,
    gap within the engine tolerance — across the jnp/gram inner grid;
  * the steady-state stream adds ZERO new engine compilations (the
    row-capacity padding keeps one ``_saif_jit`` key alive);
  * sliding-window (ring) streams match the cold solve of the last
    ``window`` rows, and the downdate conditioning guard catches
    catastrophic cancellation with an exact recompute (event + parity);
  * warm-cache entries stay KKT-certified through the serving layer
    (32-seed sweep, zero safety violations) and the cache LRU/band/
    invalidation semantics hold;
  * ``Session.select`` returns a coherent SelectionReport (1-SE >= min
    lambda, frequencies in [0, 1], one-compilation stability fleet)
    end-to-end through the serving layer;
  * the new request types validate with typed errors before any device
    work.
"""
import numpy as np
import pytest

from repro.core.api import (Problem, Scalar, Select, Update, open_session,
                            unified_compile_count)
from repro.core.online import online_compile_count
from repro.core.saif import SaifConfig
from repro.core.serving import (NumericalError, RequestError, open_serving)
from repro.core.warm_cache import (WarmCache, WarmCacheConfig,
                                   problem_digest)

from conftest import make_regression


def _stream_problem(seed=0, n0=40, p=120, k=5, noise=0.1):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n0, p))
    beta = np.zeros(p)
    beta[:k] = rng.uniform(0.8, 1.6, k)
    y = X @ beta + noise * rng.normal(size=n0)
    return X, y, beta, rng


def _batch(rng, beta, m, noise=0.1):
    p = beta.shape[0]
    Xn = rng.normal(size=(m, p))
    return Xn, Xn @ beta + noise * rng.normal(size=m)


# ---------------------------------------------------------------------------
# online-update parity vs the cold concatenated solve
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("inner", ["jnp", "gram"])
def test_update_parity_vs_cold(inner):
    X, y, bt, rng = _stream_problem(seed=0)
    lam = 0.2 * float(np.abs(X.T @ y).max())
    cfg = SaifConfig(eps=1e-8, inner_backend=inner)
    sess = open_session(Problem(X=X, y=y), cfg)
    sess.solve(Scalar(lam))

    Xs, ys = X, y
    res = None
    for _ in range(4):
        Xn, yn = _batch(rng, bt, m=8)
        res = sess.update(rows=Xn, responses=yn, lam=lam)
        Xs = np.vstack([Xs, Xn])
        ys = np.concatenate([ys, yn])

    cold = open_session(Problem(X=Xs, y=ys), cfg).solve(Scalar(lam))
    b1, b2 = np.asarray(res.beta), np.asarray(cold.beta)
    assert float(res.gap) <= cfg.eps
    assert np.allclose(b1, b2, atol=1e-6)
    assert np.array_equal(np.flatnonzero(np.abs(b1) > 0),
                          np.flatnonzero(np.abs(b2) > 0))


def test_update_request_convenience_and_lam_default():
    X, y, bt, rng = _stream_problem(seed=1)
    lam = 0.25 * float(np.abs(X.T @ y).max())
    sess = open_session(Problem(X=X, y=y),
                        SaifConfig(eps=1e-8, inner_backend="gram"))
    sess.solve(Scalar(lam))            # sets the session's last lambda
    Xn, yn = _batch(rng, bt, m=4)
    res = sess.update(rows=Xn, responses=yn)     # lam defaults to last
    assert float(res.gap) <= 1e-8
    # ingest-only, then the follow-up resolve sees the new rows
    Xn2, yn2 = _batch(rng, bt, m=4)
    assert sess.update(rows=Xn2, responses=yn2, resolve=False) is None
    res2 = sess.solve(Scalar(lam))
    cold = open_session(
        Problem(X=np.vstack([X, Xn, Xn2]),
                y=np.concatenate([y, yn, yn2])),
        SaifConfig(eps=1e-8, inner_backend="gram")).solve(Scalar(lam))
    assert np.allclose(np.asarray(res2.beta), np.asarray(cold.beta),
                       atol=1e-6)


def test_zero_engine_compiles_at_steady_state():
    """A 10-update stream (fixed batch size, windowed ring => fixed
    shapes) adds zero ``_saif_jit``-family keys and zero streaming-kernel
    keys after the warm-up update."""
    X, y, bt, rng = _stream_problem(seed=2, n0=64)
    lam = 0.2 * float(np.abs(X.T @ y).max())
    cfg = SaifConfig(eps=1e-8, inner_backend="gram")
    sess = open_session(Problem(X=X, y=y), cfg)
    sess.solve(Scalar(lam))
    Xn, yn = _batch(rng, bt, m=8)
    sess.update(rows=Xn, responses=yn, lam=lam, window=64)  # warm-up
    c_engine = unified_compile_count()
    c_online = online_compile_count()
    for _ in range(10):
        Xn, yn = _batch(rng, bt, m=8)
        res = sess.update(rows=Xn, responses=yn, lam=lam, window=64)
    assert unified_compile_count() == c_engine
    assert online_compile_count() == c_online
    assert float(res.gap) <= cfg.eps
    assert sess._online.updates == 11


def test_append_capacity_growth_is_logarithmic():
    X, y, bt, rng = _stream_problem(seed=3, n0=32)
    lam = 0.2 * float(np.abs(X.T @ y).max())
    sess = open_session(Problem(X=X, y=y),
                        SaifConfig(eps=1e-8, inner_backend="gram"))
    sess.solve(Scalar(lam))
    for _ in range(12):                      # 32 + 96 rows, cap 64 -> 128
        Xn, yn = _batch(rng, bt, m=8)
        sess.update(rows=Xn, responses=yn, lam=lam)
    st = sess._online
    assert st.grows == 1                     # one doubling for 4x rows
    ev = sess.drain_events()
    assert any(e.startswith("online_capacity_grown") for e in ev)


# ---------------------------------------------------------------------------
# sliding window: ring parity + downdate conditioning guard
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("inner", ["jnp", "gram"])
def test_window_parity_vs_cold_tail(inner):
    X, y, bt, rng = _stream_problem(seed=4, n0=64)
    W = 64
    lam = 0.2 * float(np.abs(X.T @ y).max())
    cfg = SaifConfig(eps=1e-8, inner_backend=inner)
    sess = open_session(Problem(X=X, y=y), cfg)
    sess.solve(Scalar(lam))
    rows_all, ys_all = [X], [y]
    res = None
    for _ in range(12):
        Xn, yn = _batch(rng, bt, m=8)
        rows_all.append(Xn)
        ys_all.append(yn)
        res = sess.update(rows=Xn, responses=yn, lam=lam, window=W)
    Xs = np.vstack(rows_all)[-W:]
    ys = np.concatenate(ys_all)[-W:]
    cold = open_session(Problem(X=Xs, y=ys), cfg).solve(Scalar(lam))
    b1, b2 = np.asarray(res.beta), np.asarray(cold.beta)
    assert np.allclose(b1, b2, atol=1e-6)
    assert np.array_equal(np.flatnonzero(np.abs(b1) > 0),
                          np.flatnonzero(np.abs(b2) > 0))


def test_downdate_conditioning_guard_rebuilds_exactly():
    """Huge-magnitude rows leaving the window cancel essentially all the
    incremental column mass; the guard must recompute the stats exactly
    (event + rebuild counter) and parity must still hold."""
    X, y, bt, rng = _stream_problem(seed=5, n0=32)
    W = 32
    lam = 0.2 * float(np.abs(X.T @ y).max())
    cfg = SaifConfig(eps=1e-8, inner_backend="gram")
    sess = open_session(Problem(X=X, y=y), cfg)
    sess.solve(Scalar(lam))
    # batch 1: pathological magnitude, fills half the ring (ingest-only
    # — solving the contaminated window at the clean-scale lambda would
    # activate everything and trip the window-vs-active guard)
    Xb = 1e8 * rng.normal(size=(16, X.shape[1]))
    yb = Xb @ bt
    sess.update(rows=Xb, responses=yb, window=W, resolve=False)
    sess.drain_events()
    # stream normal rows until every pathological row leaves the window,
    # then resolve on the clean tail
    rows_all = [X, Xb]
    ys_all = [y, yb]
    res = None
    for i in range(4):
        Xn, yn = _batch(rng, bt, m=8)
        rows_all.append(Xn)
        ys_all.append(yn)
        res = sess.update(rows=Xn, responses=yn, lam=lam, window=W,
                          resolve=(i == 3))
    assert sess._online.rebuilds >= 1
    assert any(e == "online_downdate_rebuild"
               for e in sess.drain_events())
    Xs = np.vstack(rows_all)[-W:]
    ys = np.concatenate(ys_all)[-W:]
    cold = open_session(Problem(X=Xs, y=ys), cfg).solve(Scalar(lam))
    b1, b2 = np.asarray(res.beta), np.asarray(cold.beta)
    assert np.allclose(b1, b2, atol=1e-6)
    assert np.array_equal(np.flatnonzero(np.abs(b1) > 0),
                          np.flatnonzero(np.abs(b2) > 0))


# ---------------------------------------------------------------------------
# admission: typed errors before any device work
# ---------------------------------------------------------------------------

def test_update_validation_errors():
    with pytest.raises(RequestError, match="non-empty"):
        Update(rows=np.zeros((0, 3)), responses=np.zeros(0))
    with pytest.raises(NumericalError, match="Update.rows"):
        Update(rows=[[np.nan, 1.0]], responses=[1.0])
    with pytest.raises(RequestError, match="responses"):
        Update(rows=np.ones((2, 3)), responses=np.ones(3))
    with pytest.raises(RequestError, match="window"):
        Update(rows=np.ones((4, 3)), responses=np.ones(4), window=2)
    with pytest.raises(RequestError, match="Update.lam"):
        Update(rows=np.ones((1, 3)), responses=np.ones(1), lam=-1.0)


def test_update_stream_admission_errors():
    X, y, bt, rng = _stream_problem(seed=6, n0=24)
    lam = 0.3 * float(np.abs(X.T @ y).max())
    sess = open_session(Problem(X=X, y=y),
                        SaifConfig(eps=1e-8, inner_backend="gram"))
    sess.solve(Scalar(lam))
    # window below the resident row count at entry
    Xn, yn = _batch(rng, bt, m=4)
    with pytest.raises(RequestError, match="resident row count"):
        sess.update(Update(rows=Xn, responses=yn, lam=lam, window=8))
    # enter, then change the window mid-stream
    sess.update(rows=Xn, responses=yn, lam=lam, window=24)
    with pytest.raises(RequestError, match="mid-stream"):
        sess.update(Update(rows=Xn, responses=yn, lam=lam, window=32))
    # wrong column count
    with pytest.raises(RequestError, match="columns"):
        sess.update(rows=np.ones((2, 7)), responses=np.ones(2), lam=lam)
    # a first resolving update with no lambda anywhere
    X2, y2, _, _ = _stream_problem(seed=7, n0=24)
    s2 = open_session(Problem(X=X2, y=y2),
                      SaifConfig(inner_backend="gram"))
    with pytest.raises(RequestError, match="first resolving update"):
        s2.update(rows=Xn, responses=yn)


def test_select_validation_errors():
    with pytest.raises(RequestError, match="non-empty"):
        Select(lams=())
    with pytest.raises(RequestError, match="n_folds"):
        Select(lams=(0.1,), n_folds=1)
    with pytest.raises(RequestError, match="rule"):
        Select(lams=(0.1,), rule="2se")
    with pytest.raises(RequestError, match="n_subsamples"):
        Select(lams=(0.1,), n_subsamples=1)
    with pytest.raises(RequestError, match="subsample_frac"):
        Select(lams=(0.1,), subsample_frac=1.5)
    with pytest.raises(RequestError, match="pi_threshold"):
        Select(lams=(0.1,), pi_threshold=0.0)


# ---------------------------------------------------------------------------
# cross-request homotopy cache
# ---------------------------------------------------------------------------

def test_warm_cache_lru_band_and_invalidate():
    cache = WarmCache(WarmCacheConfig(capacity=2, band=2.0))
    d = "digest-a"
    cache.store(d, 1.0, ("warm1",), 8)
    # band: lam <= lam0 <= 2 lam
    assert cache.lookup(d, 0.6).lam0 == 1.0
    assert cache.lookup(d, 1.0).lam0 == 1.0       # exact repeat hits
    assert cache.lookup(d, 0.4) is None           # 1.0 > 2 * 0.4
    assert cache.lookup(d, 2.0) is None           # upward: not certified
    assert cache.lookup("other", 0.6) is None
    # closest eligible entry wins
    cache.store(d, 0.8, ("warm2",), 8)
    assert cache.lookup(d, 0.6).lam0 == 0.8
    # LRU eviction at capacity
    cache.store(d, 0.5, ("warm3",), 8)
    assert len(cache) == 2
    st = cache.stats()
    assert st.evictions == 1 and st.puts == 3
    # invalidate one entry, then the whole problem
    assert cache.invalidate(d, 0.5) == 1
    assert cache.invalidate(d) == 1
    assert len(cache) == 0


def test_problem_digest_is_content_keyed():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(8, 5))
    y = rng.normal(size=8)
    assert problem_digest(X, y) == problem_digest(X.copy(), y.copy())
    assert problem_digest(X, y) != problem_digest(X + 1e-9, y)
    assert problem_digest(X, y) != problem_digest(
        X.astype(np.float32), y.astype(np.float32))


@pytest.mark.parametrize("screen_rule", ["saif", "hybrid"])
def test_warm_cache_hit_parity_and_certification(screen_rule):
    """A nearby-lambda repeat enters through the cached Theorem-2 ball
    and must return the cacheless session's support/coefficients with a
    passing serving certificate."""
    rng = np.random.default_rng(10)
    X, y, _ = make_regression(rng, n=60, p=200, uniform=False)
    lam_max = float(np.abs(X.T @ y).max())
    cfg = SaifConfig(eps=1e-8, inner_backend="gram",
                     screen_rule=screen_rule)
    cache = WarmCache(WarmCacheConfig())
    prob = Problem(X=X, y=y)

    s1 = open_serving(prob, cfg, warm_cache=cache)
    s1.solve(Scalar(0.3 * lam_max))
    val, verdict = s1.solve(Scalar(0.21 * lam_max))
    assert verdict.ok
    assert any(e.startswith("warm_cache_hit") for e in verdict.events)
    assert cache.stats().hits >= 1

    bare = open_session(prob, cfg).solve(Scalar(0.21 * lam_max))
    b1, b2 = np.asarray(val.beta), np.asarray(bare.beta)
    assert np.allclose(b1, b2, atol=1e-7)
    assert np.array_equal(np.flatnonzero(np.abs(b1) > 0),
                          np.flatnonzero(np.abs(b2) > 0))


def test_warm_cache_32_seed_safety_sweep():
    """Acceptance sweep: across 32 seeds the cached warm entry must
    produce a passing KKT certificate and the cacheless support — zero
    safety violations. One shape => the engine compiles are shared."""
    cfg = SaifConfig(eps=1e-8, inner_backend="gram")
    cache = WarmCache(WarmCacheConfig(capacity=64))
    violations = []
    for seed in range(32):
        rng = np.random.default_rng(1000 + seed)
        X, y, _ = make_regression(rng, n=40, p=96, uniform=False)
        lam_max = float(np.abs(X.T @ y).max())
        prob = Problem(X=X, y=y)
        ss = open_serving(prob, cfg, warm_cache=cache)
        ss.solve(Scalar(0.35 * lam_max))
        val, verdict = ss.solve(Scalar(0.25 * lam_max))
        hit = any(e.startswith("warm_cache_hit") for e in verdict.events)
        bare = open_session(prob, cfg).solve(Scalar(0.25 * lam_max))
        same = np.array_equal(
            np.flatnonzero(np.abs(np.asarray(val.beta)) > 0),
            np.flatnonzero(np.abs(np.asarray(bare.beta)) > 0))
        if not (verdict.ok and hit and same):
            violations.append((seed, verdict.ok, hit, same))
    assert not violations, violations
    assert cache.stats().hits >= 32


def test_warm_cache_skips_warm_and_screen_fn_sessions():
    rng = np.random.default_rng(11)
    X, y, _ = make_regression(rng, n=40, p=80, uniform=False)
    lam_max = float(np.abs(X.T @ y).max())
    cache = WarmCache()
    sess = open_session(Problem(X=X, y=y),
                        SaifConfig(inner_backend="gram"),
                        warm_cache=cache)
    sess.solve(Scalar(0.3 * lam_max))
    assert len(cache) == 1
    # warm=True continues the session's own state, not the cache
    sess.solve(Scalar(0.2 * lam_max, warm=True))
    assert cache.stats().hits == 0


# ---------------------------------------------------------------------------
# Session.select: 1-SE + stability selection
# ---------------------------------------------------------------------------

def test_select_end_to_end_through_serving():
    rng = np.random.default_rng(20)
    X, y, beta = make_regression(rng, n=80, p=120, frac_active=0.05,
                                 noise=0.5, uniform=False)
    lam_max = float(np.abs(X.T @ y).max())
    lams = tuple(np.geomspace(0.5, 0.02, 8) * lam_max)
    cfg = SaifConfig(eps=1e-7, inner_backend="gram")
    ss = open_serving(Problem(X=X, y=y), cfg)
    rep, verdict = ss.solve(Select(lams=lams, n_folds=4, n_subsamples=8,
                                   seed=3))
    assert verdict.ok
    assert rep.rule == "1se"
    assert rep.lam == rep.lam_1se >= rep.lam_min > 0
    assert rep.lams.shape == rep.cv_mean.shape == rep.cv_se.shape
    assert np.all(np.isfinite(rep.cv_mean)) and np.all(rep.cv_se >= 0)
    assert rep.frequencies.shape == (X.shape[1],)
    assert np.all((rep.frequencies >= 0) & (rep.frequencies <= 1))
    assert np.array_equal(rep.stable_support,
                          np.flatnonzero(rep.frequencies >= 0.6))
    assert rep.beta is not None and rep.best_result is not None
    assert float(rep.best_result.gap) <= 1e-7
    # the true signal should dominate the stable support
    truth = set(np.flatnonzero(np.abs(beta) > 0))
    assert truth & set(rep.stable_support.tolist())


def test_select_min_rule_and_no_stability_no_refit():
    rng = np.random.default_rng(21)
    X, y, _ = make_regression(rng, n=50, p=80, uniform=False)
    lam_max = float(np.abs(X.T @ y).max())
    lams = tuple(np.geomspace(0.5, 0.05, 5) * lam_max)
    sess = open_session(Problem(X=X, y=y),
                        SaifConfig(inner_backend="gram"))
    rep = sess.select(Select(lams=lams, n_folds=3, rule="min",
                             stability=False, refit=False))
    assert rep.lam == rep.lam_min
    assert rep.frequencies is None and rep.stable_support is None
    assert rep.beta is None and rep.best_result is None


def test_select_stability_fleet_compiles_once():
    """A repeat select on the same session must add zero engine
    compilations — the CV fold fleet and the B-subsample stability fleet
    each own exactly one persistent key."""
    rng = np.random.default_rng(22)
    X, y, _ = make_regression(rng, n=48, p=64, uniform=False)
    lam_max = float(np.abs(X.T @ y).max())
    lams = tuple(np.geomspace(0.4, 0.05, 4) * lam_max)
    sess = open_session(Problem(X=X, y=y),
                        SaifConfig(inner_backend="gram"))
    req = Select(lams=lams, n_folds=3, n_subsamples=6, seed=0)
    rep1 = sess.select(req)
    c0 = unified_compile_count()
    rep2 = sess.select(req)
    assert unified_compile_count() == c0
    assert rep2.n_compilations == 0
    assert rep1.lam == rep2.lam
    assert np.array_equal(rep1.stable_support, rep2.stable_support)


def test_select_on_streamed_session_uses_current_rows():
    """select() after updates must score the streamed problem (the
    logical rows), not the session's original design."""
    X, y, bt, rng = _stream_problem(seed=30, n0=40, p=64, k=4)
    lam = 0.25 * float(np.abs(X.T @ y).max())
    cfg = SaifConfig(eps=1e-7, inner_backend="gram")
    sess = open_session(Problem(X=X, y=y), cfg)
    sess.solve(Scalar(lam))
    Xs, ys = X, y
    for _ in range(3):
        Xn, yn = _batch(rng, bt, m=8)
        sess.update(rows=Xn, responses=yn, lam=lam)
        Xs = np.vstack([Xs, Xn])
        ys = np.concatenate([ys, yn])
    lams = tuple(np.geomspace(0.5, 0.05, 4)
                 * float(np.abs(Xs.T @ ys).max()))
    req = Select(lams=lams, n_folds=3, stability=False, seed=1)
    rep = sess.select(req)
    ref = open_session(Problem(X=Xs, y=ys), cfg).select(req)
    assert np.allclose(rep.cv_mean, ref.cv_mean, rtol=1e-10)
    assert rep.lam == ref.lam
    assert np.allclose(np.asarray(rep.beta), np.asarray(ref.beta),
                       atol=1e-7)
