"""Distribution substrate tests: shardings, optimizer, compression,
checkpoint/resume, fault tolerance. (Single-CPU-device mesh; the 512-device
production mesh is exercised by launch/dryrun.py in its own process.)
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.launch.mesh import make_host_mesh
from repro.launch.shardings import (batch_spec, param_shardings, zero1_spec,
                                    param_spec)
from repro.models import lm
from repro.optim import adamw, compress
from repro.runtime.fault import (PreemptionGuard, StepFailed,
                                 StragglerMonitor, retry_step)


class FakeMesh:
    """Shape-only stand-in so sharding rules can be tested against the
    production mesh geometry without 512 devices."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


PROD = FakeMesh({"data": 16, "model": 16})
PROD_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [PROD, PROD_MP], ids=["1pod", "2pod"])
def test_param_specs_divisible(arch, mesh):
    """Every sharded dim must divide by its mesh axis — for all 10 archs."""
    cfg = get_config(arch)
    shapes = lm.param_shapes(cfg)

    def walk(path, node):
        if isinstance(node, tuple):
            spec = param_spec(path, node, cfg, mesh)
            for dim, axis in enumerate(spec):
                if axis is None:
                    continue
                axes = axis if isinstance(axis, tuple) else (axis,)
                total = int(np.prod([mesh.shape[a] for a in axes]))
                assert node[dim] % total == 0, (path, node, spec)
        else:
            for k, v in node.items():
                walk(path + (k,), v)
    walk((), shapes)


def test_zero1_spec_adds_data_axis():
    spec = zero1_spec(P(None, None, "model"), (32, 2560, 6912), PROD)
    assert spec[0] == "data"     # L=32 divisible by 16
    # already fully sharded -> unchanged
    spec2 = zero1_spec(P("data", None, "model"), (32, 2560, 6912), PROD)
    assert spec2 == P("data", None, "model")


def test_batch_spec_small_batch_replicates():
    assert batch_spec(PROD, 256) == P(("data",),)
    assert batch_spec(PROD, 1) == P(None,)


def test_adamw_descends_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200,
                            weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw.init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}          # d/dw ||w||^2
        params, state = adamw.update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.15


def test_adamw_clipping():
    cfg = adamw.AdamWConfig(clip_norm=1.0, lr=1.0, warmup_steps=0,
                            total_steps=10, weight_decay=0.0)
    g = {"w": jnp.full((4,), 100.0)}
    p = {"w": jnp.zeros(4)}
    st = adamw.init(p)
    p2, _ = adamw.update(g, st, p, cfg)
    # clipped step magnitude bounded by lr * 1/sqrt(vhat) ~ lr
    assert float(jnp.abs(p2["w"]).max()) < 2.0


def test_error_feedback_invariant():
    """sum(applied) + residual == sum(true gradients), exactly."""
    rng = np.random.default_rng(0)
    params = {"a": jnp.zeros((64,)), "b": jnp.zeros((8, 8))}
    ef = compress.init(params)
    applied_sum = jax.tree.map(lambda p: np.zeros(p.shape), params)
    true_sum = jax.tree.map(lambda p: np.zeros(p.shape), params)
    for _ in range(20):
        g = {"a": jnp.asarray(rng.normal(size=64)),
             "b": jnp.asarray(rng.normal(size=(8, 8)))}
        q, ef = compress.compress_tree(g, ef)
        deq = compress.decompress_tree(q)
        applied_sum = jax.tree.map(lambda s, d: s + np.asarray(d),
                                   applied_sum, deq)
        true_sum = jax.tree.map(lambda s, d: s + np.asarray(d), true_sum, g)
    for k in params:
        np.testing.assert_allclose(
            applied_sum[k] + np.asarray(ef.residual[k]), true_sum[k],
            rtol=1e-5, atol=1e-5)


def test_quantize_roundtrip_bounds():
    x = jnp.asarray(np.random.default_rng(1).normal(size=1000) * 5)
    q, s = compress.quantize(x)
    err = jnp.abs(compress.dequantize(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-9


def test_retry_step_recovers_then_fails():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    assert retry_step(flaky, max_retries=2) == "ok"

    def broken():
        raise RuntimeError("persistent")

    with pytest.raises(StepFailed):
        retry_step(broken, max_retries=2)


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(factor=3.0, min_samples=3)
    for _ in range(5):
        mon.record(1.0)
    assert mon.record(10.0) is True
    assert mon.record(1.1) is False


def test_preemption_guard_flag():
    g = PreemptionGuard(install=False)
    assert not g.preempted
    g.trigger()
    assert g.preempted


def test_checkpoint_roundtrip(tmp_path):
    from repro.ckpt import checkpoint as ckpt
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.ones(3)},
            "step": jnp.asarray(7)}
    ckpt.save(str(tmp_path), 7, tree, extra={"cursor": 123})
    assert ckpt.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored, extra = ckpt.restore(str(tmp_path), 7, like)
    assert extra["cursor"] == 123
    np.testing.assert_array_equal(restored["params"]["w"],
                                  tree["params"]["w"])


def test_checkpoint_gc_keeps_latest(tmp_path):
    from repro.ckpt import checkpoint as ckpt
    tree = {"x": jnp.zeros(2)}
    for s in range(6):
        ckpt.save(str(tmp_path), s, tree)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 3 and ckpt.latest_step(str(tmp_path)) == 5


def test_train_resume_end_to_end(tmp_path):
    """Train 6 steps, kill, resume to 12: loss stream must equal an
    uninterrupted 12-step run (exact determinism incl. data cursor)."""
    env = dict(os.environ, PYTHONPATH="src")
    base = [sys.executable, "-m", "repro.launch.train", "--arch",
            "stablelm_3b", "--smoke", "--batch", "4", "--seq", "32",
            "--log-every", "1", "--lr", "1e-3"]
    r1 = subprocess.run(base + ["--steps", "6", "--ckpt-dir",
                                str(tmp_path / "a"), "--ckpt-every", "3"],
                        capture_output=True, text=True, env=env, cwd="/root/repo")
    assert r1.returncode == 0, r1.stderr[-2000:]
    r2 = subprocess.run(base + ["--steps", "12", "--ckpt-dir",
                                str(tmp_path / "a"), "--ckpt-every", "3"],
                        capture_output=True, text=True, env=env, cwd="/root/repo")
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "[resume] restored step 6" in r2.stdout
    r3 = subprocess.run(base + ["--steps", "12", "--ckpt-dir",
                                str(tmp_path / "b"), "--ckpt-every", "100"],
                        capture_output=True, text=True, env=env, cwd="/root/repo")
    losses_resumed = [l.split()[-1] for l in r2.stdout.splitlines()
                      if l.startswith("step ")]
    losses_straight = [l.split()[-1] for l in r3.stdout.splitlines()
                       if l.startswith("step ")]
    # compare the final overlapping steps
    assert losses_resumed[-3:] == losses_straight[-3:], (
        r2.stdout, r3.stdout)


def test_distributed_saif_subprocess_8dev():
    """SAIF with the shard_map screening scan on 8 host devices == serial."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.launch.mesh import make_host_mesh
from repro.distributed.saif_sharded import saif_distributed
from repro.core import saif, SaifConfig
rng = np.random.default_rng(3)
n, p = 40, 500
X = rng.uniform(-10, 10, (n, p))
b = np.zeros(p); b[rng.choice(p, 50, replace=False)] = rng.uniform(-1, 1, 50)
y = X @ b + rng.normal(0, 1, n)
lam = 0.05 * float(np.max(np.abs(X.T @ y)))
mesh = make_host_mesh()
assert jax.device_count() == 8
with mesh:
    r1 = saif_distributed(X, y, lam, mesh, SaifConfig(eps=1e-8))
r2 = saif(X, y, lam, SaifConfig(eps=1e-8))
assert np.allclose(np.asarray(r1.beta), np.asarray(r2.beta), atol=1e-6)
print("DIST_OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd="/root/repo", timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "DIST_OK" in r.stdout


def test_distributed_saif_batch_subprocess_8dev():
    """The fleet engine on the batched shard_map collective (DESIGN.md §8):
    all B problems screened per wire round == B serial solves."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.launch.mesh import make_host_mesh
from repro.distributed.saif_sharded import saif_batch_distributed
from repro.core import saif, SaifConfig
rng = np.random.default_rng(5)
n, p, B = 30, 240, 3
X = rng.uniform(-10, 10, (n, p))
Ys, lams = [], []
for i in range(B):
    w = np.zeros(p); w[rng.choice(p, 12, replace=False)] = rng.uniform(-1, 1, 12)
    y = X @ w + rng.normal(0, 1, n)
    Ys.append(y)
    lams.append((0.05 + 0.05 * i) * float(np.max(np.abs(X.T @ y))))
mesh = make_host_mesh()
assert jax.device_count() == 8
cfg = SaifConfig(eps=1e-8, inner_backend="gram")
with mesh:
    res = saif_batch_distributed(X, np.stack(Ys), jnp.asarray(lams), mesh, cfg)
for i in range(B):
    ref = saif(X, Ys[i], lams[i], cfg)
    assert np.array_equal(np.abs(np.asarray(res.beta[i])) > 1e-8,
                          np.abs(np.asarray(ref.beta)) > 1e-8)
    assert np.allclose(np.asarray(res.beta[i]), np.asarray(ref.beta),
                       atol=1e-6)
print("DIST_BATCH_OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd="/root/repo", timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "DIST_BATCH_OK" in r.stdout


def test_microbatch_equivalence():
    """Grad accumulation over microbatches == full-batch step (fp32)."""
    from repro.configs import smoke_config
    from repro.launch import steps as steps_lib
    from repro.models import init as model_init
    cfg = smoke_config("stablelm_3b").scaled(dtype="float32")
    params = model_init(jax.random.PRNGKey(0), cfg)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = adamw.init(params)
    state = steps_lib.TrainState(params=params, opt=opt)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    s1, l1 = steps_lib.make_train_step(cfg, opt_cfg, microbatch=1)(state, batch)
    s4, l4 = steps_lib.make_train_step(cfg, opt_cfg, microbatch=4)(state, batch)
    assert abs(float(l1) - float(l4)) < 1e-5
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     s1.params, s4.params)
    assert max(jax.tree.leaves(d)) < 5e-5   # fp32 accumulation-order noise


@pytest.mark.parametrize("arch", ["dbrx_132b", "nemotron_4_15b"])
def test_fsdp_specs_divisible(arch):
    """FSDP adds a data-axis shard on some dim; divisibility must hold."""
    from repro.launch.shardings import fsdp_spec
    cfg = get_config(arch)
    shapes = lm.param_shapes(cfg)

    def walk(path, node):
        if isinstance(node, tuple):
            spec = fsdp_spec(param_spec(path, node, cfg, PROD), node, PROD)
            used = []
            for dim, axis in enumerate(spec):
                if axis is None:
                    continue
                axes = axis if isinstance(axis, tuple) else (axis,)
                for a in axes:
                    assert a not in used
                    used.append(a)
                total = int(np.prod([PROD.shape[a] for a in axes]))
                assert node[dim] % total == 0, (path, node, spec)
        else:
            for k, v in node.items():
                walk(path + (k,), v)
    walk((), shapes)
