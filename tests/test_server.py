"""Async serving front-end tests (DESIGN.md §12).

What must hold:

  * bucket padding is *bitwise* neutral in p (the serving tier every
    request rides) across the jnp/gram screen x inner sample, and
    support-exact + KKT-certified in n (the opt-in row tier);
  * coalesced microbatches return each rider the bits of its own
    direct, unpadded, serial Session solve;
  * LRU eviction/readmission costs session re-prep but ZERO new engine
    compilations (the jit caches are process-wide);
  * one poisoned rider in a coalesced batch degrades only its own
    future (per-unit verdicts);
  * the deadline/priority request knobs validate, and the deprecated
    ``solve(deadline_s=)`` alias warns exactly once.
"""
import time
import warnings

import numpy as np
import pytest

from repro.core.api import (Problem, Scalar, open_session,
                            unified_compile_count)
from repro.core.saif import SaifConfig
from repro.core.server import ServerConfig, ServingFuture, open_server
from repro.core.serving import (DeadlineExceeded, RequestError,
                                ServingConfig, open_serving)
from repro.runtime.inject import FaultInjector

from conftest import make_regression


def _problem(rng, n=60, p=37):
    X, y, _ = make_regression(rng, n=n, p=p, uniform=False)
    return Problem(X=X, y=y)


# ---------------------------------------------------------------------------
# bucket-padding parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("inner", ["jnp", "gram"])
@pytest.mark.parametrize("screen", ["jnp"])
def test_p_bucket_padding_bitwise(rng, screen, inner):
    """A p-padded session returns bit-identical coefficients, gap and
    support to the direct unpadded solve — the serving bitwise tier."""
    prob = _problem(rng)
    cfg = SaifConfig(screen_backend=screen, inner_backend=inner)
    direct = open_session(prob, cfg)
    padded = open_session(prob, cfg, pad_to=(60, 64))
    for lam in (0.1, 0.05, 0.03):
        d = direct.solve(Scalar(lam))
        p_ = padded.solve(Scalar(lam))
        assert p_.beta.shape == d.beta.shape
        assert np.array_equal(np.asarray(p_.beta), np.asarray(d.beta))
        assert float(p_.gap) == float(d.gap)
        assert np.array_equal(np.asarray(p_.active_mask),
                              np.asarray(d.active_mask))


def test_n_bucket_padding_support_parity(rng):
    """Row padding (zero-weight rows) is exact in real arithmetic; in
    floats the contract is support equality + tight coefficients + a
    passing KKT certificate, not bitwise."""
    prob = _problem(rng)
    cfg = SaifConfig()
    direct = open_session(prob, cfg)
    padded = open_serving(prob, cfg, pad_to=(64, 64))
    for lam in (0.1, 0.04):
        d = direct.solve(Scalar(lam))
        res = padded.solve(Scalar(lam))
        assert res.verdict.ok
        dsup = np.abs(np.asarray(d.beta)) > 0
        psup = np.abs(np.asarray(res.value.beta)) > 0
        assert np.array_equal(dsup, psup)
        np.testing.assert_allclose(np.asarray(res.value.beta),
                                   np.asarray(d.beta),
                                   rtol=1e-10, atol=1e-12)


def test_pad_to_rejects_logistic_row_padding(rng):
    """Logistic pad rows shift the primal by log(2) each — row padding
    must be refused, column padding allowed."""
    X, y, _ = make_regression(rng, n=40, p=24, uniform=False)
    prob = Problem(X=X, y=np.sign(y) + (np.sign(y) == 0), loss="logistic")
    with pytest.raises(NotImplementedError, match="row padding"):
        open_session(prob, SaifConfig(loss="logistic"), pad_to=(48, 32))
    sess = open_session(prob, SaifConfig(loss="logistic"), pad_to=(40, 32))
    res = sess.solve(Scalar(0.05))
    direct = open_session(prob, SaifConfig(loss="logistic")).solve(
        Scalar(0.05))
    assert np.array_equal(np.asarray(res.beta), np.asarray(direct.beta))


# ---------------------------------------------------------------------------
# the server: coalescing, parity through the full async path
# ---------------------------------------------------------------------------

def test_server_coalesces_and_matches_direct_bitwise(rng):
    prob = _problem(rng)
    cfg = SaifConfig()
    lams = [0.09, 0.06, 0.045, 0.03]
    with open_server(max_batch=8, max_wait_ms=100.0, solver=cfg) as srv:
        futs = [srv.submit(prob, Scalar(lam)) for lam in lams]
        results = [f.result(timeout=300) for f in futs]
        stats = srv.stats()
    assert stats.served == len(lams)
    assert stats.coalesced_batches >= 1
    assert stats.coalesced_requests == len(lams)
    direct = open_session(prob, cfg)
    for lam, r in zip(lams, results):
        assert r.verdict.ok
        d = direct.solve(Scalar(lam))
        assert np.array_equal(np.asarray(r.value.beta),
                              np.asarray(d.beta))
        assert float(r.value.gap) == float(d.gap)


def test_server_coalesces_cross_user_same_design(rng):
    """Different users (distinct Problem objects, own y, own lam) over
    ONE shared design coalesce into a single fleet microbatch, and each
    rider gets the bits of its own direct solve."""
    X, y0, _ = make_regression(rng, n=60, p=37, uniform=False)
    cfg = SaifConfig()
    users = []
    for lam in (0.09, 0.06, 0.045, 0.03):
        yu = y0 + rng.normal(0, 0.3, size=y0.shape)
        users.append((Problem(X=X, y=yu), lam))
    with open_server(max_batch=8, max_wait_ms=100.0, solver=cfg) as srv:
        futs = [srv.submit(pb, Scalar(lam)) for pb, lam in users]
        results = [f.result(timeout=300) for f in futs]
        stats = srv.stats()
    # one design digest -> one queue -> all four coalesce
    assert stats.coalesced_requests == len(users)
    assert stats.sessions_opened == 1
    for (pb, lam), r in zip(users, results):
        assert r.verdict.ok
        d = open_session(pb, cfg).solve(Scalar(lam))
        assert np.array_equal(np.asarray(r.value.beta),
                              np.asarray(d.beta))
        assert float(r.value.gap) == float(d.gap)


def test_server_priority_orders_dispatch(rng):
    """With the dispatcher started late, the high-priority request must
    be served first even though it was submitted last."""
    prob = _problem(rng, n=40, p=24)
    order = []
    srv = open_server(autostart=False, max_wait_ms=0.0,
                      solver=SaifConfig())
    # distinct problems -> distinct queues -> dispatch order observable
    prob2 = _problem(rng, n=40, p=24)
    f1 = srv.submit(prob, Scalar(0.05, priority=0))
    f2 = srv.submit(prob2, Scalar(0.05, priority=5))
    srv.run(timeout=0.1)        # starts the dispatcher, returns
    for f in (f1, f2):
        f.result(timeout=300)
    # monotonic resolution order: the priority-5 future resolved first
    assert f2.done() and f1.done()
    srv.close()


def test_future_timeout_and_validation(rng):
    prob = _problem(rng, n=40, p=24)
    with pytest.raises(RequestError, match="deadline_s"):
        Scalar(0.1, deadline_s=-3.0)
    with pytest.raises(RequestError, match="priority"):
        Scalar(0.1, priority="high")
    fut = ServingFuture()
    with pytest.raises(DeadlineExceeded):
        fut.result(timeout=0.01)
    srv = open_server(autostart=False, solver=SaifConfig())
    f = srv.submit(prob, Scalar(0.05, deadline_s=0.02))
    time.sleep(0.05)            # expire in the queue, dispatcher off
    srv.run(timeout=0.2)
    exc = f.exception(timeout=60)
    assert isinstance(exc, DeadlineExceeded)
    assert srv.stats().deadline_misses == 1
    srv.close()


def test_deprecated_solve_deadline_kwarg_warns_once(rng):
    import repro.core.serving as serving_mod
    prob = _problem(rng, n=40, p=24)
    sess = open_serving(prob, SaifConfig())
    serving_mod._deadline_kwarg_warned = False
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        sess.solve(Scalar(0.05), deadline_s=60.0)
        sess.solve(Scalar(0.05), deadline_s=60.0)
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 1
    assert "request" in str(dep[0].message)


# ---------------------------------------------------------------------------
# LRU: eviction/readmission never recompiles an engine
# ---------------------------------------------------------------------------

def test_lru_eviction_readmission_compile_deltas(rng):
    cfg = SaifConfig()
    probs = [_problem(rng), _problem(rng)]     # same shape, two digests
    with open_server(max_sessions=1, max_wait_ms=0.0,
                     solver=cfg) as srv:
        # warm both buckets once (compiles happen here)
        for pb in probs:
            srv.submit(pb, Scalar(0.05)).result(timeout=300)
        warm = unified_compile_count()
        opened0 = srv.stats().sessions_opened
        # ping-pong: every hit is an LRU miss -> session reopen + evict
        for pb in (probs[0], probs[1], probs[0]):
            r = srv.submit(pb, Scalar(0.05)).result(timeout=300)
            assert r.verdict.ok
        stats = srv.stats()
    assert unified_compile_count() == warm, \
        "eviction/readmission must not recompile (process-wide caches)"
    assert stats.sessions_opened == opened0 + 3
    assert stats.evictions >= 3


# ---------------------------------------------------------------------------
# chaos: one poisoned rider degrades only its own future
# ---------------------------------------------------------------------------

def test_chaos_poisoned_rider_is_contained(rng):
    prob = _problem(rng)
    cfg = SaifConfig()
    lams = [0.09, 0.06, 0.045, 0.03]
    poisoned = 2
    # ladder disabled: the poisoned unit must FAIL its verdict (and only
    # it), proving per-unit attribution rather than ladder repair
    srv = open_server(max_batch=8, max_wait_ms=500.0, solver=cfg,
                      serving=ServingConfig(ladder=(), max_retries=0),
                      autostart=False)
    futs = [srv.submit(prob, Scalar(lam)) for lam in lams]
    with FaultInjector(nan_at={1}, nan_unit=poisoned, tags={"fleet"}):
        srv.run(timeout=0.05)
        results = [f.result(timeout=300) for f in futs]
    srv.close()
    direct = open_session(prob, cfg)
    for i, (lam, r) in enumerate(zip(lams, results)):
        if i == poisoned:
            assert not r.verdict.ok
            assert r.verdict.unit_ok == (False,)
            assert "nonfinite" in r.verdict.events
        else:
            assert r.verdict.ok, f"rider {i} was collaterally damaged"
            assert r.verdict.unit_ok == (True,)
            d = direct.solve(Scalar(lam))
            assert np.array_equal(np.asarray(r.value.beta),
                                  np.asarray(d.beta))


def test_chaos_poisoned_rider_ladder_recovers(rng):
    """With the ladder on, the poisoned rider's future still resolves
    ok — marked degraded — and the riders stay untouched."""
    prob = _problem(rng, n=40, p=24)
    cfg = SaifConfig()
    lams = [0.08, 0.05]
    srv = open_server(max_batch=4, max_wait_ms=500.0, solver=cfg,
                      serving=ServingConfig(max_retries=0),
                      autostart=False)
    futs = [srv.submit(prob, Scalar(lam)) for lam in lams]
    with FaultInjector(nan_at={1}, nan_unit=0, tags={"fleet"}):
        srv.run(timeout=0.05)
        results = [f.result(timeout=300) for f in futs]
    srv.close()
    assert results[0].verdict.ok and results[0].verdict.degraded
    assert results[1].verdict.ok and not results[1].verdict.degraded


# ---------------------------------------------------------------------------
# config surface
# ---------------------------------------------------------------------------

def test_server_config_grid_and_fallback(rng):
    prob = _problem(rng, n=40, p=24)
    with open_server(ServerConfig(p_buckets=(16,), max_wait_ms=0.0,
                                  solver=SaifConfig())) as srv:
        r = srv.submit(prob, Scalar(0.05)).result(timeout=300)
        assert r.verdict.ok
        assert srv.stats().bucket_fallbacks == 1   # p=24 > grid max 16


def test_open_server_rejects_pad_to():
    with pytest.raises(TypeError, match="pad_to"):
        open_server(pad_to=(64, 64))
