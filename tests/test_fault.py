"""Fault-tolerance runtime units (DESIGN.md §10).

Covers the PR's hardened ``repro.runtime.fault`` (jittered exponential
backoff with a deadline cap; straggler medians that exclude flagged
outliers), the deterministic ``repro.runtime.inject`` seams, the
PreemptionGuard drill, and checkpoint atomicity when a writer dies
mid-flush. Everything here is host-side and CPU-deterministic.
"""
import os
import random
import shutil
import time

import numpy as np
import pytest

from repro.runtime.fault import (PreemptionGuard, RetryDeadlineExceeded,
                                 StepFailed, StragglerMonitor, backoff_delay,
                                 retry_step)
from repro.runtime.inject import FaultInjector, armed, seam


# ---------------------------------------------------------------------------
# retry_step: backoff schedule + deadline cap
# ---------------------------------------------------------------------------

def test_backoff_is_exponential_and_jittered():
    rng = random.Random(0)
    d1 = backoff_delay(1, 0.1, 2.0, 0.0)
    d2 = backoff_delay(2, 0.1, 2.0, 0.0)
    d3 = backoff_delay(3, 0.1, 2.0, 0.0)
    assert (d1, d2, d3) == (0.1, 0.2, 0.4)
    js = [backoff_delay(1, 0.1, 2.0, 0.5, rng) for _ in range(64)]
    assert all(0.05 <= d <= 0.15 for d in js)
    assert len({round(d, 12) for d in js}) > 1      # actually jittered
    # deterministic under the same seed
    rng2 = random.Random(0)
    assert js == [backoff_delay(1, 0.1, 2.0, 0.5, rng2) for _ in range(64)]
    assert backoff_delay(1, 0.0, 2.0, 0.5) == 0.0   # base 0 = no sleep


def test_retry_sleeps_follow_the_schedule():
    calls = {"n": 0}
    sleeps = []

    def flaky():
        calls["n"] += 1
        if calls["n"] < 4:
            raise RuntimeError("transient")
        return "ok"

    out = retry_step(flaky, max_retries=3, backoff_base_s=0.1,
                     backoff_mult=2.0, jitter=0.0, sleep=sleeps.append)
    assert out == "ok"
    assert sleeps == [0.1, 0.2, 0.4]


def test_retry_deadline_caps_sleep_and_raises_typed():
    clock = {"t": 0.0}

    def fake_clock():
        return clock["t"]

    def fake_sleep(s):
        clock["t"] += s

    def always_fail():
        clock["t"] += 0.05
        raise RuntimeError("down")

    # generous retry budget, tight deadline: the deadline, not the retry
    # count, must terminate the loop — with the typed subclass
    with pytest.raises(RetryDeadlineExceeded):
        retry_step(always_fail, max_retries=100, backoff_base_s=0.1,
                   jitter=0.0, deadline_s=0.3, sleep=fake_sleep,
                   clock=fake_clock)
    assert clock["t"] <= 0.6        # sleeps were capped to the budget
    with pytest.raises(StepFailed):
        retry_step(always_fail, max_retries=1, sleep=fake_sleep,
                   clock=fake_clock)


def test_retry_does_not_catch_unlisted_exceptions():
    def boom():
        raise ValueError("not transient")

    with pytest.raises(ValueError):
        retry_step(boom, max_retries=5)


# ---------------------------------------------------------------------------
# StragglerMonitor: flagged outliers leave the median
# ---------------------------------------------------------------------------

def test_straggler_excluded_from_trailing_median():
    mon = StragglerMonitor(factor=3.0, min_samples=3)
    for _ in range(5):
        assert not mon.record(1.0)
    assert mon.record(10.0)         # 10x outlier flags
    # the outlier must NOT inflate the baseline: successors at ~4x the
    # true median still flag (the pre-fix behavior let them slip once
    # the 10.0 entered the window)
    assert mon.record(4.0)
    assert mon.record(4.0)
    assert not mon.record(1.1)
    assert mon.flagged == [6, 7, 8]


def test_straggler_callback_and_timed():
    seen = []
    mon = StragglerMonitor(factor=2.0, min_samples=2,
                           on_straggler=lambda step, s, med:
                           seen.append((step, round(med, 3))))
    for t in (0.1, 0.1, 0.1):
        mon.record(t)
    mon.record(0.5)
    assert seen == [(4, 0.1)]
    assert mon.timed(lambda: 42) == 42
    assert len(mon.times) == 5


# ---------------------------------------------------------------------------
# deterministic injection seams
# ---------------------------------------------------------------------------

def test_seam_is_identity_when_disarmed():
    assert armed() is None
    assert seam("serial", lambda: 123) == 123


def test_injector_schedules_are_deterministic_and_logged():
    a = FaultInjector.from_seed(7, 20, p_fail=0.3, p_nan=0.2)
    b = FaultInjector.from_seed(7, 20, p_fail=0.3, p_nan=0.2)
    assert a.fail_at == b.fail_at and a.nan_at == b.nan_at
    with FaultInjector(fail_at={2}, delay_at={3}, delay_s=0.01) as inj:
        assert seam("serial", lambda: "a") == "a"
        with pytest.raises(RuntimeError, match="injected"):
            seam("serial", lambda: "b")
        t0 = time.monotonic()
        assert seam("path", lambda: "c") == "c"
        assert time.monotonic() - t0 >= 0.01
    assert [(k, act) for k, _, act in inj.log] == [(2, "fail"), (3, "delay")]
    assert armed() is None          # disarmed on exit


def test_injector_tag_filter_still_advances_counter():
    with FaultInjector(fail_at={2}, tags={"fleet"}) as inj:
        assert seam("serial", lambda: 1) == 1   # call 1 (other tag)
        assert seam("serial", lambda: 2) == 2   # call 2: filtered out
        assert inj.calls == 2
    with pytest.raises(RuntimeError):
        with FaultInjector(fail_at={1}, tags={"fleet"}):
            seam("fleet", lambda: 3)


def test_injector_nan_poke_reaches_solver_results():
    import jax.numpy as jnp
    from repro.core.saif import SaifConfig, prepare_path, solve_scalar
    rng = np.random.default_rng(0)
    X = rng.normal(size=(20, 50))
    y = X[:, 0] + 0.1 * rng.normal(size=20)
    prep = prepare_path(X, y, SaifConfig())
    with FaultInjector(nan_at={1}):
        res = solve_scalar(prep, 5.0, SaifConfig())
    assert not bool(jnp.all(jnp.isfinite(res.beta)))
    assert not bool(jnp.isfinite(res.gap))
    # and the very next (uninjected) solve is clean — the poke happened
    # outside the compiled program, not inside its cache
    res2 = solve_scalar(prep, 5.0, SaifConfig())
    assert bool(jnp.all(jnp.isfinite(res2.beta)))


def test_double_arming_is_an_error():
    with FaultInjector():
        with pytest.raises(RuntimeError, match="already armed"):
            FaultInjector().__enter__()


# ---------------------------------------------------------------------------
# PreemptionGuard drill
# ---------------------------------------------------------------------------

def test_preemption_guard_trigger_and_uninstall():
    g = PreemptionGuard(install=False)
    assert not g.preempted
    g.trigger()
    assert g.preempted
    g.uninstall()                   # no-op without install; must not raise


# ---------------------------------------------------------------------------
# checkpoint atomicity under a killed mid-flush writer
# ---------------------------------------------------------------------------

def test_checkpoint_survives_killed_mid_flush_write(tmp_path):
    """A writer that dies mid-flush (torn .tmp dir, missing meta) must
    neither corrupt the previous checkpoint nor be offered for restore."""
    import jax.numpy as jnp
    from repro.ckpt import checkpoint as ckpt
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(4.0), "b": jnp.ones((2, 2))}
    ckpt.save(d, 1, tree, extra={"tag": "good"})

    # simulate a crash mid-flush of step 2: the temp dir exists with a
    # partial array and NO meta.json (meta is written last)
    torn = os.path.join(d, "step_00000002.tmp")
    os.makedirs(torn)
    np.save(os.path.join(torn, "arr_00000.npy"), np.zeros(4))

    assert ckpt.latest_step(d) == 1          # torn write invisible
    restored, extra = ckpt.restore(d, 1, tree)
    assert extra == {"tag": "good"}
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.arange(4.0))
    # a later writer reclaims the torn temp dir and completes atomically
    ckpt.save(d, 2, tree, extra={"tag": "retry"})
    assert ckpt.latest_step(d) == 2
    assert not os.path.exists(torn)
    meta = ckpt.load_meta(d, 2)
    assert meta["extra"]["tag"] == "retry"
    shutil.rmtree(d)


def test_serving_checkpoint_restore_resumes_warm(tmp_path):
    """SIGTERM drill: solve warm, checkpoint via the PreemptionGuard
    path, 'restart' (a fresh ServingSession on the same dir) and resume
    — the continued stream is bitwise the uninterrupted one, with zero
    extra solver compilations after restore."""
    from repro.core.api import Problem, Scalar
    from repro.core.serving import ServingConfig, open_serving

    rng = np.random.default_rng(3)
    X = rng.normal(size=(30, 80))
    y = X[:, 0] - X[:, 3] + 0.1 * rng.normal(size=30)
    prob = Problem(X=X, y=y)
    lams = [6.0, 4.0, 2.5]

    ref = open_serving(prob)
    want = [np.asarray(ref.solve(Scalar(l, warm=True)).value.beta)
            for l in lams]

    d = str(tmp_path / "warm")
    a = open_serving(prob, serving=ServingConfig(ckpt_dir=d),
                     guard=PreemptionGuard(install=False))
    a.solve(Scalar(lams[0], warm=True))
    a.guard.trigger()                       # the SIGTERM moment
    r = a.solve(Scalar(lams[1], warm=True))  # drain: checkpoints first
    assert "preempted_checkpointed" in r.verdict.events

    b = open_serving(prob, serving=ServingConfig(ckpt_dir=d))
    assert b.restored
    n0 = b.compile_stats().total
    got = [np.asarray(b.solve(Scalar(l, warm=True)).value.beta)
           for l in lams[1:]]
    assert b.compile_stats().total == n0    # warm restore: no recompiles
    np.testing.assert_array_equal(want[1], got[0])
    np.testing.assert_array_equal(want[2], got[1])


def test_checkpoint_digest_gates_restore(tmp_path):
    """A checkpoint of a different problem must be ignored (cold start),
    not restored into the wrong session."""
    from repro.core.api import Problem, Scalar
    from repro.core.serving import ServingConfig, open_serving
    rng = np.random.default_rng(4)
    X = rng.normal(size=(25, 60))
    y1 = X[:, 0] + 0.1 * rng.normal(size=25)
    y2 = X[:, 1] + 0.1 * rng.normal(size=25)
    d = str(tmp_path / "gate")
    a = open_serving(Problem(X=X, y=y1),
                     serving=ServingConfig(ckpt_dir=d))
    a.solve(Scalar(3.0, warm=True))
    assert a.checkpoint() is not None
    b = open_serving(Problem(X=X, y=y2),
                     serving=ServingConfig(ckpt_dir=d))
    assert not b.restored
    assert b.session.warm_state is None
