"""Certified mixed-precision screening properties (ISSUE 7 / DESIGN.md §11).

Three property families, each swept over >= 30 seeds / parameter pairs:

  (a) subset safety — the widened low-precision (bf16/f32) fleet screen
      never rules out a feature the exact f64 screen keeps: the widened
      low-precision ub dominates the exact ub elementwise, so both the
      ADD-stop decision (max_ub < 1) and the per-feature not-a-candidate
      decision are strictly conservative;
  (b) end-to-end parity — parity="fast" + bf16 screening reaches the
      bitwise engine's supports with gap <= eps and a passing
      working-precision KKT certificate;
  (c) bound monotonicity — gamma_n(u), the mixed-precision composition
      and the widened radius are monotone in n and in the unit roundoff
      u (a coarser precision / longer dot can only widen, never shrink,
      the certificate).

The module is quarantined into its own pytest process (the same
pre-existing XLA:CPU ``backend_compile`` segfault that quarantines
``test_screen_parity.py::test_path_engine_segmented_overflow_recovers``:
late in a long suite, compiling the screen's escalation ``lax.cond``
crashes the interpreter; fresh-process runs are deterministic-green).
``test_precision_cert_runs_quarantined`` re-invokes this file in a child
pytest with ``REPRO_PRECISION_CERT_INPROC=1`` so the assertions still
gate CI while the crash domain is the child.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

_INPROC = os.environ.get("REPRO_PRECISION_CERT_INPROC") == "1"
quarantined = pytest.mark.skipif(
    not _INPROC, reason="runs in the quarantined child process (see "
    "test_precision_cert_runs_quarantined)")


def test_precision_cert_runs_quarantined():
    """Parent-side driver: run this module's property tests in a child
    pytest process and gate on its exit status."""
    if _INPROC:
        pytest.skip("already inside the quarantined child")
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         "tests/test_precision_cert.py"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=dict(os.environ, REPRO_PRECISION_CERT_INPROC="1"),
    )
    assert proc.returncode == 0, (
        f"quarantined precision-cert suite failed (rc={proc.returncode})")

from conftest import make_regression
from repro.core import SaifConfig, get_loss
from repro.core.batch import fleet_solve
from repro.core.duality import (dot_error_gamma, kkt_residual, lambda_max,
                                mixed_precision_gamma, unit_roundoff,
                                widened_radius)
from repro.core.screen_backend import make_batch_screen_fast

N_SEEDS = 32


def _screen_state(rng, n, p, b):
    """Random fleet screen inputs: unit-ish columns, dual points, radii."""
    X = rng.uniform(-1, 1, (n, p))
    X /= np.linalg.norm(X, axis=0, keepdims=True)
    cn = np.linalg.norm(X, axis=0)
    Theta = rng.normal(0, 1.0 / np.sqrt(n), (b, n))
    # radii spanning decisive (tiny), borderline and sloppy (large) balls
    scales = np.array([1e-3, 0.3, 1.0])
    r = rng.uniform(0.0, 1.0, (b,)) * scales[rng.integers(0, 3, b)]
    in_active = rng.random((b, p)) < 0.05
    return X, cn, Theta, r, in_active


def _exact_ub(X, cn, Theta, r, in_active):
    """f64 numpy reference: unwidened masked scores and screening ub."""
    score = np.abs(Theta @ X)
    masked = np.where(in_active, -np.inf, score)
    return masked + cn[None, :] * r[:, None]


@pytest.mark.parametrize("screen_dtype", ["bfloat16", "float32"])
@quarantined
def test_widened_screen_is_subset_safe(screen_dtype):
    """(a) Elementwise: widened low-precision ub >= exact f64 ub, so the
    low-precision ruled-out set is a subset of the exact ruled-out set —
    zero unsafe evictions across the seed sweep (acceptance criterion)."""
    n, p, b, h = 48, 160, 3, 8
    u_acc = unit_roundoff(jnp.promote_types(jnp.float32,
                                            jnp.dtype(screen_dtype)))
    for seed in range(N_SEEDS):
        rng = np.random.default_rng(1000 + seed)
        X, cn, Theta, r, in_active = _screen_state(rng, n, p, b)
        screen = make_batch_screen_fast(jnp.asarray(X), jnp.asarray(cn),
                                        p, screen_dtype=screen_dtype)
        # do=False keeps the cheap (never-escalated) branch: that is the
        # branch whose bounds the certificate must carry on its own
        out = screen(jnp.asarray(Theta), jnp.asarray(r),
                     jnp.asarray(in_active), jnp.zeros((b,), bool))
        ub_exact = _exact_ub(X, cn, Theta, r, in_active)
        # reconstruct the per-feature low-precision ub from the h=p
        # candidate list + the library's own certified widening
        gamma = mixed_precision_gamma(n, jnp.dtype(screen_dtype),
                                      jnp.promote_types(jnp.float32,
                                                        jnp.dtype(screen_dtype)))
        r_wide = np.asarray(widened_radius(jnp.asarray(r), jnp.asarray(Theta),
                                           gamma))
        score_lo = np.full((b, p), -np.inf)
        np.put_along_axis(score_lo, np.asarray(out.cand_idx),
                          np.asarray(out.cand_score), axis=1)
        ub_lo = (score_lo + cn[None, :] * r_wide[:, None]) * (1 + 8 * u_acc)
        free = ~in_active
        assert np.all(ub_lo[free] >= ub_exact[free]), (
            f"seed {seed}: low-precision screen evicted a feature the "
            f"exact screen keeps (max deficit "
            f"{np.max(ub_exact[free] - ub_lo[free]):.3e})")
        # and the public ADD-stop observable dominates too
        assert np.all(np.asarray(out.max_ub) >= np.max(ub_exact, axis=1)
                      - 1e-12)


@quarantined
def test_widened_screen_add_stop_safe_under_escalation():
    """(a') With do=True the two-tier escalation may swap in working
    precision for undecidable problems; the ADD-stop bound must still
    dominate the exact one in every branch."""
    n, p, b = 48, 160, 4
    for seed in range(N_SEEDS):
        rng = np.random.default_rng(2000 + seed)
        X, cn, Theta, r, in_active = _screen_state(rng, n, p, b)
        # scale Theta so max_ub straddles 1 and the undecidable band is hit
        ub0 = _exact_ub(X, cn, Theta, r, in_active)
        Theta = Theta / np.max(ub0, axis=1, keepdims=True)
        screen = make_batch_screen_fast(jnp.asarray(X), jnp.asarray(cn),
                                        8, screen_dtype="bfloat16")
        out = screen(jnp.asarray(Theta), jnp.asarray(r),
                     jnp.asarray(in_active), jnp.ones((b,), bool))
        ub_exact = _exact_ub(X, cn, Theta, r, in_active)
        assert np.all(np.asarray(out.max_ub) >= np.max(ub_exact, axis=1)
                      - 1e-12)


@pytest.mark.parametrize("screen_dtype", ["bfloat16", "float32"])
@quarantined
def test_fast_parity_matches_bitwise_supports(screen_dtype):
    """(b) parity="fast" + low-precision screening: same supports as the
    bitwise engine, gap <= eps, passing working-precision KKT — across
    the full seed sweep at one compiled shape."""
    loss = get_loss("least_squares")
    B, n, p, eps = 4, 40, 100, 1e-6
    cfg_fast = SaifConfig(eps=eps, parity="fast", screen_dtype=screen_dtype)
    cfg_bit = SaifConfig(eps=eps)
    for seed in range(N_SEEDS):
        rng = np.random.default_rng(3000 + seed)
        X = rng.uniform(-10, 10, (n, p))
        Y = (X @ rng.normal(0, 0.2, (p, B))).T + rng.normal(0, 1.0, (B, n))
        lam = np.array([0.4 * float(lambda_max(loss, jnp.asarray(X),
                                               jnp.asarray(Y[i])))
                        for i in range(B)])
        fast = fleet_solve(X, Y, lam, cfg_fast)
        bit = fleet_solve(X, Y, lam, cfg_bit)
        for i in range(B):
            sf = set(np.flatnonzero(np.abs(np.asarray(fast.beta[i])) > 0))
            sb = set(np.flatnonzero(np.abs(np.asarray(bit.beta[i])) > 0))
            assert sf == sb, f"seed {seed} problem {i}: support mismatch"
            assert float(fast.gap[i]) <= eps
            kkt = float(kkt_residual(loss, jnp.asarray(X), jnp.asarray(Y[i]),
                                     fast.beta[i], float(lam[i])))
            assert kkt <= 1e-6 * lam[i], (
                f"seed {seed} problem {i}: kkt {kkt:.3e} vs lam {lam[i]:.3e}")


@quarantined
def test_gamma_monotone_in_n_and_u():
    """(c) gamma_n(u) = nu/(1-nu) strictly increases in n and in u."""
    us = [unit_roundoff(dt) for dt in ("float64", "float32", "bfloat16")]
    ns = [int(v) for v in np.unique(np.geomspace(2, 10_000, 32).astype(int))]
    assert len(ns) >= 30
    for u in us:
        gs = [dot_error_gamma(n, u) for n in ns]
        # strictly increasing until the bound saturates to +inf (the
        # vacuous n*u >= 1 region, reachable for bf16 at large n)
        assert all(b > a > 0 or (a == b == float("inf"))
                   for a, b in zip(gs, gs[1:]))
        assert gs == sorted(gs)
    for n in ns:
        gs = [dot_error_gamma(n, u) for u in sorted(us)]
        assert all(b > a or (a == b == float("inf"))
                   for a, b in zip(gs, gs[1:]))


@quarantined
def test_mixed_precision_gamma_monotone():
    """(c') the cast+accumulate composition is monotone in n and widens
    as either the input or accumulator precision coarsens."""
    ns = [int(v) for v in np.unique(np.geomspace(2, 10_000, 32).astype(int))]
    for in_dt, acc_dt in [("bfloat16", "float32"), ("float32", "float32"),
                          ("float64", "float64")]:
        gs = [mixed_precision_gamma(n, in_dt, acc_dt) for n in ns]
        # non-decreasing step to step (float evaluation of the composed
        # bound can plateau at the ulp for near-adjacent n), strictly
        # increasing across a decade
        assert all(b >= a > 0 for a, b in zip(gs, gs[1:]))
        assert all(mixed_precision_gamma(10 * n, in_dt, acc_dt) > g
                   for n, g in zip(ns, gs))
    for n in ns:
        g64 = mixed_precision_gamma(n, "float64", "float64")
        g32 = mixed_precision_gamma(n, "float32", "float32")
        g16 = mixed_precision_gamma(n, "bfloat16", "float32")
        assert g16 > g32 > g64


@quarantined
def test_widened_radius_monotone_and_conservative():
    """(c'') r' = widened_radius(r, theta, gamma) satisfies r' >= r, is
    monotone in gamma, and the widening grows with ||theta||."""
    rng = np.random.default_rng(7)
    theta = jnp.asarray(rng.normal(0, 1, (3, 50)))
    r = jnp.asarray([0.0, 0.1, 2.0])
    gammas = sorted(dot_error_gamma(n, unit_roundoff(dt))
                    for n in (10, 100, 1000, 10_000)
                    for dt in ("float64", "float32", "bfloat16"))
    assert len(gammas) >= 12
    prev = np.asarray(r)
    for g in gammas:
        rw = np.asarray(widened_radius(r, theta, g))
        assert np.all(rw >= prev)          # monotone in gamma, >= r at g0
        prev = rw
    # widening scales with ||theta||
    rw1 = np.asarray(widened_radius(r, theta, gammas[-1]))
    rw2 = np.asarray(widened_radius(r, 2.0 * theta, gammas[-1]))
    assert np.all(rw2 - np.asarray(r) >= 2.0 * (rw1 - np.asarray(r)) - 1e-15)
