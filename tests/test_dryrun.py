"""Integration test of the multi-pod dry-run pipeline (subprocess: needs the
512 placeholder devices, which must not leak into this test process)."""
import json
import os
import subprocess
import sys

import pytest


@pytest.mark.parametrize("multi", [False, True], ids=["1pod", "2pod"])
def test_dryrun_whisper_prefill(tmp_path, multi):
    out = tmp_path / "rec.jsonl"
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", "whisper_tiny", "--shape", "prefill_32k",
           "--out", str(out)]
    if multi:
        cmd.append("--multi-pod")
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       cwd="/root/repo", timeout=1200)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    recs = [json.loads(l) for l in out.read_text().splitlines()]
    assert len(recs) == 1 and recs[0]["status"] == "ok"
    rec = recs[0]
    assert rec["n_chips"] == (512 if multi else 256)
    # corrected costs present and physically sane
    assert rec["scan_corrected"]
    assert rec["flops"] > rec["raw_flops"] > 0
    assert rec["dominant"] in ("compute", "memory", "collective")
    assert rec["collective_bytes"] > 0      # TP really communicates
    assert 0 < rec["useful_flops_frac"] < 1.5


def test_saif_screen_row(tmp_path):
    out = tmp_path / "rec.jsonl"
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-m", "repro.launch.dryrun",
                        "--saif-screen", "--out", str(out)],
                       capture_output=True, text=True, env=env,
                       cwd="/root/repo", timeout=1200)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.loads(out.read_text().splitlines()[0])
    assert rec["status"] == "ok"
    # the screening collective is tiny by design (the paper's key property:
    # O(devs * h) wire bytes, not O(p))
    assert rec["collective_s"] < 0.01 * rec["memory_s"]
