"""Shared test fixtures. NOTE: do NOT set XLA_FLAGS device-count here —
smoke tests and benches must see the single real CPU device; only
launch/dryrun.py forces 512 placeholder devices (in its own process).
"""
import jax
import numpy as np
import pytest

# Convex-solver tests need f64 to reach paper-grade duality gaps (1e-6..1e-9).
jax.config.update("jax_enable_x64", True)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """Drop compiled executables at every module boundary.

    XLA:CPU's in-process JIT accumulates state with every compilation;
    past a few hundred compiles a single process starts segfaulting
    inside ``backend_compile`` (the crash roams to whichever test
    happens to compile next — see the quarantined tests in
    test_screen_parity.py / test_precision_cert.py for the two spots it
    struck first). Releasing the cached executables at module teardown
    keeps the live-executable population bounded so the full tier-1
    suite stays under the threshold. Within-module warm-cache
    assertions (zero-recompile steady state, compile-count bounds) are
    unaffected: every such test warms its own engine first and asserts
    deltas.
    """
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(12345)


def make_regression(rng, n=60, p=300, frac_active=0.2, noise=1.0,
                    uniform=True):
    """Simulation protocol of paper Sec 5.1.1 (scaled down)."""
    if uniform:
        X = rng.uniform(-10, 10, (n, p))
    else:
        X = rng.normal(0, 1, (n, p))
    beta = np.zeros(p)
    k = max(int(frac_active * p), 1)
    idx = rng.choice(p, k, replace=False)
    beta[idx] = rng.uniform(-1, 1, k)
    y = X @ beta + noise * rng.normal(0, 1, n)
    return X, y, beta


def make_classification(rng, n=80, p=300, k=10):
    X = rng.normal(0, 1, (n, p))
    beta = np.zeros(p)
    idx = rng.choice(p, k, replace=False)
    beta[idx] = rng.uniform(-2, 2, k)
    y = np.sign(X @ beta + 0.3 * rng.normal(0, 1, n))
    y[y == 0] = 1.0
    return X, y, beta


def kkt_violation(loss, X, y, beta, lam):
    """Max KKT violation of a LASSO solution (0 at the optimum).

    For all i: |x_i^T f'(X beta)| <= lam (+ equality with sign on support).
    """
    import jax.numpy as jnp
    g = jnp.asarray(X).T @ loss.grad(jnp.asarray(X) @ beta, jnp.asarray(y))
    inactive_viol = jnp.maximum(jnp.abs(g) - lam, 0.0)
    active = jnp.abs(beta) > 1e-12
    active_viol = jnp.where(active, jnp.abs(g + lam * jnp.sign(beta)), 0.0)
    return float(jnp.max(jnp.maximum(inactive_viol, active_viol)))
