"""Property tests of the fixed-capacity active-set buffer."""
import jax.numpy as jnp
import numpy as np
from repro.testing import given, settings, st

from repro.core import active_set as asl


def _consistent(aset, p):
    """Invariant: in_active == set(idx[mask]); no duplicate live ids; the
    incrementally maintained compact order lists exactly the count live
    slots first and is a permutation of all slots."""
    idx = np.asarray(aset.idx)
    mask = np.asarray(aset.mask)
    live = idx[mask]
    assert len(set(live.tolist())) == len(live), "duplicate live feature"
    member = np.zeros(p, bool)
    member[live] = True
    assert (member == np.asarray(aset.in_active)).all()
    assert (np.asarray(aset.beta)[~mask] == 0).all()
    order = np.asarray(aset.order)
    count = int(aset.count)
    k_max = mask.shape[0]
    assert count == mask.sum(), "count out of sync with mask"
    assert sorted(order.tolist()) == list(range(k_max)), "not a permutation"
    assert mask[order[:count]].all(), "dead slot in the live region"
    assert not mask[order[count:]].any(), "live slot in the dead region"


@given(seed=st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_random_add_delete_sequence(seed):
    r = np.random.default_rng(seed)
    p, k_max = 50, 16
    init = r.choice(p, r.integers(1, 8), replace=False)
    aset = asl.init_active_set(p, k_max, jnp.asarray(init))
    _consistent(aset, p)

    for _ in range(6):
        if r.random() < 0.5:
            # ADD a random batch of non-members
            member = np.asarray(aset.in_active)
            cands = np.where(~member)[0]
            h = min(4, len(cands))
            if h == 0:
                continue
            chosen = r.choice(cands, h, replace=False).astype(np.int32)
            keep = r.random(h) < 0.8
            before = np.asarray(aset.mask).sum()
            aset = asl.add_features(aset, jnp.asarray(chosen),
                                    jnp.asarray(keep))
            _consistent(aset, p)
            after = np.asarray(aset.mask).sum()
            free_before = k_max - before
            assert after == before + min(keep.sum(), free_before)
        else:
            # DEL a random subset of slots
            drop = jnp.asarray(r.random(k_max) < 0.3)
            aset = asl.delete_features(aset, drop)
            _consistent(aset, p)


def test_overflow_flag():
    p, k_max = 20, 4
    aset = asl.init_active_set(p, k_max, jnp.arange(3))
    aset = asl.add_features(aset, jnp.asarray([5, 6, 7], jnp.int32),
                            jnp.asarray([True, True, True]))
    assert bool(aset.overflowed)
    # exactly one was placed (1 free slot)
    assert int(np.asarray(aset.mask).sum()) == 4


def test_scatter_beta_roundtrip():
    p, k_max = 30, 8
    aset = asl.init_active_set(p, k_max, jnp.asarray([3, 7, 11]))
    aset = aset._replace(beta=aset.beta.at[:3].set(jnp.asarray([1., -2., 3.])))
    full = asl.scatter_beta(aset, p)
    assert full.shape == (p,)
    assert float(full[3]) == 1. and float(full[7]) == -2. and float(full[11]) == 3.
    assert float(jnp.abs(full).sum()) == 6.


def test_order_is_insertion_stable():
    """Surviving live slots never reshuffle: ADD appends to the live
    region, DEL compacts it while preserving relative order."""
    p, k_max = 20, 8
    aset = asl.init_active_set(p, k_max, jnp.asarray([3, 7, 11]))
    order0 = np.asarray(aset.order)[:3].tolist()
    aset = asl.add_features(aset, jnp.asarray([15, 18], jnp.int32),
                            jnp.asarray([True, True]))
    # prior live slots stay in front, in the same relative order
    assert np.asarray(aset.order)[:3].tolist() == order0
    assert int(aset.count) == 5
    # drop the middle original slot: the rest close ranks, order preserved
    drop = jnp.zeros(k_max, bool).at[1].set(True)
    aset = asl.delete_features(aset, drop)
    seq = np.asarray(aset.order)[:int(aset.count)].tolist()
    assert [s for s in seq if s in order0] == [s for s in order0 if s != 1]
    _consistent(aset, p)


def test_init_live_mask_mode_preserves_slots():
    """Slots mode: arbitrary live masks keep their slot assignment (the
    warm-handoff contract of the Gram carry, DESIGN.md §6)."""
    p, k_max = 30, 6
    idx = jnp.asarray([4, 9, 2, 9, 25, 0], jnp.int32)
    beta = jnp.asarray([1., 2., 3., 4., 5., 6.])
    live = jnp.asarray([True, False, True, False, True, False])
    aset = asl.init_active_set(p, k_max, idx, jnp.float32, beta,
                               live_mask=live)
    assert np.asarray(aset.idx)[np.asarray(live)].tolist() == [4, 2, 25]
    assert int(aset.count) == 3
    assert np.asarray(aset.beta)[np.asarray(live)].tolist() == [1., 3., 5.]
    assert (np.asarray(aset.beta)[~np.asarray(live)] == 0).all()
    _consistent(aset, p)


def test_delete_does_not_clobber_feature_zero():
    """Padding slots hold idx 0; deleting them must not evict feature 0."""
    p, k_max = 10, 6
    aset = asl.init_active_set(p, k_max, jnp.asarray([0, 4]))
    # delete a padding slot (slot 5 is padding, holds idx 0)
    drop = jnp.zeros(k_max, bool).at[5].set(True)
    aset2 = asl.delete_features(aset, drop)
    assert bool(aset2.in_active[0]), "feature 0 wrongly evicted by padding DEL"
    _consistent(aset2, p)
