"""Tree fused-LASSO (paper Sec 4, Thms 6-7) tests."""
import numpy as np
import jax.numpy as jnp

from repro.core import (SaifConfig, build_tree, fused_baseline_cm,
                        fused_objective, recover_beta, saif_fused,
                        transform_design)


def _chain_parent(p):
    """1-D fused lasso: path graph 0-1-2-...-p-1 rooted at 0."""
    parent = np.arange(p) - 1
    return parent


def _random_tree_parent(rng, p):
    parent = np.full(p, -1, np.int64)
    for v in range(1, p):
        parent[v] = rng.integers(0, v)
    return parent


def test_transform_inverts(rng):
    p = 12
    tree = build_tree(_random_tree_parent(rng, p))
    beta = rng.normal(size=p)
    # beta_tilde from beta: delta along each edge
    bt = beta[tree.edge_child] - beta[tree.parent[tree.edge_child]]
    b = beta[tree.root]
    rec = recover_beta(bt, b, tree)
    assert np.allclose(rec, beta)


def test_transform_design_preserves_predictions(rng):
    n, p = 9, 12
    X = rng.normal(size=(n, p))
    tree = build_tree(_random_tree_parent(rng, p))
    X_bar, xb = transform_design(X, tree)
    beta = rng.normal(size=p)
    bt = beta[tree.edge_child] - beta[tree.parent[tree.edge_child]]
    b = beta[tree.root]
    assert np.allclose(X @ beta, X_bar @ bt + xb * b)


def test_fused_chain_recovers_piecewise_constant(rng):
    """On step-function ground truth, fused solution is piecewise constant."""
    n, p = 80, 40
    X = rng.normal(size=(n, p))
    beta_true = np.zeros(p)
    beta_true[:15] = 2.0
    beta_true[15:30] = -1.0
    y = X @ beta_true + 0.05 * rng.normal(size=n)
    parent = _chain_parent(p)
    beta, res = saif_fused(X, y, parent, lam=5.0, config=SaifConfig(eps=1e-9))
    jumps = np.abs(np.diff(beta)) > 1e-6
    assert jumps.sum() <= 8      # few breakpoints
    # objective sanity vs the true generating vector
    assert (fused_objective(X, y, parent, beta, 5.0)
            <= fused_objective(X, y, parent, beta_true, 5.0) + 1e-6)


def test_saif_fused_matches_unscreened_baseline(rng):
    n, p = 40, 30
    X = rng.normal(size=(n, p))
    beta_true = np.zeros(p)
    beta_true[:10] = 1.5
    y = X @ beta_true + 0.1 * rng.normal(size=n)
    parent = _random_tree_parent(rng, p)
    for lam in (2.0, 10.0):
        beta_s, _ = saif_fused(X, y, parent, lam, SaifConfig(eps=1e-10))
        beta_b = fused_baseline_cm(X, y, parent, lam, tol=1e-12)
        o_s = fused_objective(X, y, parent, beta_s, lam)
        o_b = fused_objective(X, y, parent, beta_b, lam)
        assert abs(o_s - o_b) <= 1e-6 * max(abs(o_b), 1)
        assert np.allclose(beta_s, beta_b, atol=1e-4)


def test_large_lambda_fuses_everything(rng):
    n, p = 30, 20
    X = rng.normal(size=(n, p))
    y = rng.normal(size=n)
    parent = _chain_parent(p)
    beta, _ = saif_fused(X, y, parent, lam=1e5, config=SaifConfig(eps=1e-10))
    # all coefficients equal (single fused group; b is unpenalized)
    assert np.ptp(beta) <= 1e-6
