"""Session contract tests for the unified Problem/Session API (ISSUE 5).

The serving contract of ``repro.core.api`` (DESIGN.md §9):

  * (a) N heterogeneous requests on ONE session compile once per static
    key — re-serving the same request mix adds ZERO compilations,
    observed through ``session.compile_stats()``;
  * (b) session results are BITWISE those of the legacy frontends
    (``saif`` / ``saif_path`` / ``saif_batch`` / ``fused_path`` / ...)
    across a screen x inner backend sample;
  * the legacy frontends are deprecated shims: they delegate to a
    one-shot session and emit a one-shot ``DeprecationWarning``;
  * the public surface is lazy: ``from repro import Problem,
    open_session`` imports no jax-heavy engine module;
  * the group engine serves many lambdas from ONE ``_gsaif_jit``
    compilation (the satellite ``group_compile_count`` fix).
"""
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_regression
from repro.core import (CV, Fleet, GroupSaifConfig, Path, Problem,
                        SaifConfig, Scalar, get_loss, open_session, saif,
                        unified_compile_count)
from repro.core.api import fused, group
from repro.core.duality import lambda_max


def _problem(rng, n=40, p=160, seed_frac=0.25):
    X, y, _ = make_regression(rng, n=n, p=p)
    lmax = float(lambda_max(get_loss("least_squares"),
                            jnp.asarray(X), jnp.asarray(y)))
    return X, y, lmax


def _bitwise(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# (b) bitwise parity vs the legacy frontends, screen x inner sample
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("screen,inner", [
    ("jnp", "jnp"), ("jnp", "gram"), ("pallas", "jnp")])
def test_scalar_parity_backend_grid(rng, screen, inner):
    X, y, lmax = _problem(rng)
    cfg = SaifConfig(eps=1e-7, screen_backend=screen, inner_backend=inner)
    sess = open_session(Problem(X=X, y=y), cfg)
    res = sess.solve(Scalar(0.2 * lmax))
    ref = saif(X, y, 0.2 * lmax, cfg)
    _bitwise(res.beta, ref.beta)
    _bitwise(res.trace_gap, ref.trace_gap)
    _bitwise(res.active_idx, ref.active_idx)
    assert float(res.gap) == float(ref.gap)
    assert int(res.n_outer) == int(ref.n_outer)


def test_path_parity_and_compiles(rng):
    X, y, lmax = _problem(rng)
    cfg = SaifConfig(eps=1e-7)
    lams = np.geomspace(0.8 * lmax, 0.1 * lmax, 6)
    sess = open_session(Problem(X=X, y=y), cfg)
    pr = sess.solve(Path(tuple(lams)))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.core import saif_path
        pr0 = saif_path(X, y, lams, cfg)
    assert (pr.lams == pr0.lams).all()
    for b1, b0 in zip(pr.betas, pr0.betas):
        _bitwise(b1, b0)
    for r1, r0 in zip(pr.results, pr0.results):
        _bitwise(r1.trace_n_active, r0.trace_n_active)


@pytest.mark.parametrize("inner", ["jnp", "gram"])
def test_fleet_parity(rng, inner):
    X, y, lmax = _problem(rng)
    rng2 = np.random.default_rng(7)
    Y = np.stack([y, X @ rng2.normal(0, 0.1, X.shape[1])
                  + rng2.normal(0, 1, X.shape[0])])
    lams = np.array([0.3 * lmax, 0.2 * lmax])
    cfg = SaifConfig(eps=1e-6, inner_backend=inner)
    sess = open_session(Problem(X=X), cfg)      # fleet-only session: no y
    res = sess.solve(Fleet(Y=Y, lams=lams))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.core import saif_batch
        ref = saif_batch(X, Y, lams, cfg)
    _bitwise(res.beta, ref.beta)
    _bitwise(res.gap, ref.gap)


def test_cv_parity(rng):
    X, y, lmax = _problem(rng)
    cfg = SaifConfig(eps=1e-6)
    lams = np.geomspace(0.7 * lmax, 0.1 * lmax, 4)
    sess = open_session(Problem(X=X, y=y), cfg)
    res = sess.solve(CV(n_folds=3, lams=tuple(lams)))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.core import cv_path
        ref = cv_path(X, y, lams, n_folds=3, config=cfg)
    np.testing.assert_array_equal(res.cv_mean, ref.cv_mean)
    np.testing.assert_array_equal(res.cv_se, ref.cv_se)
    assert res.best_lam == ref.best_lam
    _bitwise(res.beta, ref.beta)


def test_fused_parity(rng):
    n, p = 40, 60
    X = rng.normal(size=(n, p))
    beta = np.zeros(p)
    beta[:20] = 2.0
    beta[20:35] = -1.0
    y = X @ beta + 0.1 * rng.normal(size=n)
    parent = np.arange(p) - 1
    cfg = SaifConfig(eps=1e-8)
    sess = open_session(Problem(X=X, y=y, penalty=fused(parent)), cfg)
    b1, r1 = sess.solve(Scalar(4.0))
    pr1 = sess.solve(Path((5.0, 3.0, 1.5)))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.core import fused_path, saif_fused
        b0, r0 = saif_fused(X, y, parent, 4.0, cfg)
        pr0 = fused_path(X, y, parent, (5.0, 3.0, 1.5), cfg)
    _bitwise(b1, b0)
    assert float(r1.gap) == float(r0.gap)
    for a, b in zip(pr1.betas, pr0.betas):
        _bitwise(a, b)


def test_weighted_problem_rides_fleet_engine(rng):
    X, y, lmax = _problem(rng)
    w = (np.random.default_rng(3).random(X.shape[0]) > 0.3).astype(float)
    cfg = SaifConfig(eps=1e-6)
    sess = open_session(Problem(X=X, y=y, weights=w), cfg)
    res = sess.solve(Scalar(0.3 * lmax))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.core import saif_batch
        ref = saif_batch(X, y[None], 0.3 * lmax, cfg, weights=w[None])
    assert res.beta.ndim == 1          # the B=1 axis is squeezed away
    _bitwise(res.beta, ref.beta[0])


# ---------------------------------------------------------------------------
# (a) one compilation per static key across a heterogeneous request stream
# ---------------------------------------------------------------------------

def test_one_compilation_per_static_key(rng):
    X, y, lmax = _problem(rng)
    cfg = SaifConfig(eps=1e-6)
    sess = open_session(Problem(X=X, y=y), cfg)
    Y = np.stack([y, y[::-1].copy()])
    grid = np.geomspace(0.6 * lmax, 0.15 * lmax, 4)
    mix = [
        Scalar(0.3 * lmax),
        Scalar(0.27 * lmax),               # same pow2 h bucket, same key
        Path(tuple(grid)),
        Fleet(Y=Y, lams=np.array([0.3 * lmax, 0.2 * lmax])),
        Scalar(0.3 * lmax, warm=True),     # device-resident warm handoff
        CV(n_folds=3, lams=tuple(grid), refit=False),
    ]
    for req in mix:
        sess.solve(req)
    first = sess.compile_stats()
    assert first.requests == len(mix)
    assert first.total >= 0, "jit cache introspection unavailable"

    # second pass over the SAME heterogeneous mix: every static key is
    # compiled — the hot session must add exactly ZERO compilations
    for req in mix:
        sess.solve(req)
    second = sess.compile_stats()
    assert second.requests == 2 * len(mix)
    assert second.since_open == first.since_open, (
        f"hot session recompiled: {first.since_open} -> "
        f"{second.since_open} ({second})")
    # ... and the stream above is >= 10 mixed requests total
    assert second.requests >= 10


def test_scalar_same_bucket_shares_compilation(rng):
    from repro.core.saif import add_batch_size_static, prepare_path
    X, y, lmax = _problem(rng)
    cfg = SaifConfig(eps=1e-6)
    sess = open_session(Problem(X=X, y=y), cfg)
    # find two lambdas that land in the same pow2 h bucket (the h formula
    # buckets exactly so a lambda path shares compilations — DESIGN.md §4)
    prep = prepare_path(X, y, cfg)
    p = X.shape[1]

    def h_of(lam):
        return add_batch_size_static(cfg.c, lam, prep.c0_max,
                                     prep.c0_median, p)

    lam1 = 0.30 * lmax
    lam2 = next(f * lmax for f in (0.29, 0.28, 0.31, 0.32, 0.27)
                if h_of(f * lmax) == h_of(lam1))
    sess.solve(Scalar(lam1))
    s0 = sess.compile_stats()
    sess.solve(Scalar(lam2))     # same static key: zero new compilations
    s1 = sess.compile_stats()
    assert s1.since_open == s0.since_open


def test_warm_stream_matches_cold_support(rng):
    X, y, lmax = _problem(rng)
    cfg = SaifConfig(eps=1e-7)
    sess = open_session(Problem(X=X, y=y), cfg)
    lam = 0.25 * lmax
    cold = sess.solve(Scalar(lam))
    warm = sess.solve(Scalar(lam, warm=True))
    assert float(warm.gap) <= cfg.eps
    sup_c = np.flatnonzero(np.abs(np.asarray(cold.beta)) > 1e-9)
    sup_w = np.flatnonzero(np.abs(np.asarray(warm.beta)) > 1e-9)
    np.testing.assert_array_equal(sup_c, sup_w)
    np.testing.assert_allclose(np.asarray(warm.beta),
                               np.asarray(cold.beta), atol=1e-6)


# ---------------------------------------------------------------------------
# group penalty through the session (+ the group_compile_count satellite)
# ---------------------------------------------------------------------------

def test_group_session_parity_and_single_compilation(rng):
    from repro.core import group_compile_count, group_lambda_max
    X, y, _ = make_regression(rng, n=40, p=120)
    loss = get_loss("least_squares")
    glmax = group_lambda_max(loss, X, y, 4)
    cfg = GroupSaifConfig(eps=1e-8)
    sess = open_session(Problem(X=X, y=y, penalty=group(4)), cfg)
    c0 = group_compile_count()
    r1 = sess.solve(Scalar(0.3 * glmax))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.core import group_saif
        r0 = group_saif(X, y, 0.3 * glmax, 4, cfg)
    _bitwise(r1.beta, r0.beta)
    # serve more lambdas (cold + warm + a path): the group static
    # signature is lambda-independent => ONE compilation for all of it
    sess.solve(Scalar(0.2 * glmax))
    sess.solve(Scalar(0.15 * glmax, warm=True))
    gp = sess.solve(Path((0.4 * glmax, 0.25 * glmax, 0.1 * glmax)))
    c1 = group_compile_count()
    if c0 >= 0 and c1 >= 0:
        assert c1 - c0 == 1, f"group engine compiled {c1 - c0} times"
        assert gp.n_compilations == 0   # the path rode the existing key
    assert len(gp.betas) == 3
    for res in gp.results:
        assert float(res.gap) <= 1e-8


def test_group_warm_path_matches_cold_solves(rng):
    from repro.core import group_lambda_max, group_solve, prepare_group
    X, y, _ = make_regression(rng, n=40, p=120)
    glmax = group_lambda_max(get_loss("least_squares"), X, y, 4)
    cfg = GroupSaifConfig(eps=1e-9)
    sess = open_session(Problem(X=X, y=y, penalty=group(4)), cfg)
    gp = sess.solve(Path((0.35 * glmax, 0.2 * glmax)))
    prep = prepare_group(X, y, 4, cfg)
    for lam, beta in zip(gp.lams, gp.betas):
        ref = group_solve(prep, float(lam), cfg)    # cold reference
        sup = np.linalg.norm(np.asarray(beta).reshape(-1, 4), axis=1)
        sup_ref = np.linalg.norm(np.asarray(ref.beta).reshape(-1, 4),
                                 axis=1)
        np.testing.assert_array_equal(sup > 1e-7, sup_ref > 1e-7)
        np.testing.assert_allclose(np.asarray(beta), np.asarray(ref.beta),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# sharded requests (1-device mesh: the collective path, minus the wire)
# ---------------------------------------------------------------------------

def test_sharded_scalar_and_path(rng):
    from jax.sharding import Mesh
    X, y, lmax = _problem(rng)
    cfg = SaifConfig(eps=1e-7)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("feature",))
    sess = open_session(Problem(X=X, y=y), cfg, mesh=mesh)
    res = sess.solve(Scalar(0.25 * lmax, sharded=True))
    ref = saif(X, y, 0.25 * lmax, cfg)
    assert res.beta.shape == ref.beta.shape
    np.testing.assert_allclose(np.asarray(res.beta), np.asarray(ref.beta),
                               atol=1e-8)
    s0 = sess.compile_stats()
    sess.solve(Scalar(0.25 * lmax, sharded=True))   # memoized ScreenFn ->
    s1 = sess.compile_stats()                       # same static key
    assert s1.since_open == s0.since_open
    pr = sess.solve(Path((0.3 * lmax, 0.2 * lmax), sharded=True))
    assert pr.betas[0].shape == (X.shape[1],)
    for r in pr.results:
        assert float(r.gap) <= cfg.eps


def test_sharded_fleet_replay_adds_no_compilations(rng):
    from jax.sharding import Mesh
    X, y, lmax = _problem(rng, n=30, p=120)
    Y = np.stack([y, y[::-1].copy()])
    lams = np.array([0.25 * lmax, 0.2 * lmax])
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("feature",))
    sess = open_session(Problem(X=X), SaifConfig(eps=1e-6), mesh=mesh)
    r1 = sess.solve(Fleet(Y=Y, lams=lams, sharded=True))
    s0 = sess.compile_stats()
    r2 = sess.solve(Fleet(Y=Y, lams=lams, sharded=True))
    s1 = sess.compile_stats()
    _bitwise(r1.beta, r2.beta)
    # cached placement + memoized batched ScreenFn => same static key
    assert s1.since_open == s0.since_open


def test_warm_sharded_scalar(rng):
    from jax.sharding import Mesh
    X, y, lmax = _problem(rng, n=30, p=120)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("feature",))
    sess = open_session(Problem(X=X, y=y), SaifConfig(eps=1e-7), mesh=mesh)
    lam = 0.25 * lmax
    cold = sess.solve(Scalar(lam, sharded=True))
    warm = sess.solve(Scalar(lam, sharded=True, warm=True))
    assert warm.beta.shape == (X.shape[1],)
    assert float(warm.gap) <= 1e-7
    np.testing.assert_allclose(np.asarray(warm.beta),
                               np.asarray(cold.beta), atol=1e-6)


def test_make_screen_hook_serves_cold_scalars(rng):
    from repro.core.screen_backend import make_screen_jnp
    X, y, lmax = _problem(rng)
    cfg = SaifConfig(eps=1e-6)
    calls = []
    Xd = jnp.asarray(X)
    col_norm = jnp.linalg.norm(Xd, axis=0)

    def hook(h):
        calls.append(h)
        return make_screen_jnp(Xd, col_norm, h)

    sess = open_session(Problem(X=X, y=y), cfg, make_screen=hook)
    res = sess.solve(Scalar(0.3 * lmax))
    assert calls, "make_screen hook ignored for a cold Scalar request"
    # the hook builds the same jnp screen the default path builds, so the
    # result stays bitwise the plain solve
    _bitwise(res.beta, saif(X, y, 0.3 * lmax, cfg).beta)


def test_sharded_requires_mesh(rng):
    X, y, lmax = _problem(rng)
    sess = open_session(Problem(X=X, y=y), SaifConfig())
    with pytest.raises(ValueError, match="mesh"):
        sess.solve(Scalar(0.3 * lmax, sharded=True))


# ---------------------------------------------------------------------------
# deprecation + lazy-surface satellites
# ---------------------------------------------------------------------------

def test_legacy_frontends_warn_once():
    from repro.core._compat import reset_deprecation_warnings
    rng = np.random.default_rng(0)
    X = rng.normal(size=(20, 40))
    y = X[:, 0] + 0.1 * rng.normal(size=20)
    from repro.core import saif_path
    reset_deprecation_warnings()
    with pytest.warns(DeprecationWarning,
                      match=r"use repro\.open_session"):
        saif_path(X, y, [1.0])
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        saif_path(X, y, [1.0])          # second call: one-shot, silent
    assert not [w for w in rec
                if issubclass(w.category, DeprecationWarning)
                and "open_session" in str(w.message)]
    reset_deprecation_warnings()


def test_lazy_public_surface_subprocess():
    code = (
        "import sys\n"
        "from repro import Problem, Scalar, Path, Fleet, CV, open_session\n"
        "from repro import open_server, ServerConfig, ServingFuture\n"
        "from repro import ScreenRule, resolve_screen_rule\n"
        "from repro import Update, Select, SelectionReport\n"
        "from repro import WarmCache, WarmCacheConfig\n"
        "light = {'repro.core.api', 'repro.core.server', "
        "'repro.core.serving', 'repro.core.screen_rule', "
        "'repro.core.online', 'repro.core.select', "
        "'repro.core.warm_cache'}\n"
        "heavy = [m for m in sys.modules if m.startswith('repro.core.') "
        "and m not in light]\n"
        "assert not heavy, f'heavy imports: {heavy}'\n"
        "assert 'jax' not in sys.modules, 'jax imported eagerly'\n"
        "p = Problem(X=None)\n"
        "cfg = ServerConfig(max_batch=4)\n"
        "fut = ServingFuture()\n"
        "assert not fut.done()\n"
        "rule = resolve_screen_rule('hybrid')\n"
        "assert isinstance(rule, ScreenRule) and rule.post_check\n"
        "assert resolve_screen_rule(rule) is rule\n"
        "upd = Update(rows=[[1.0, 2.0]], responses=[1.0])\n"
        "sel = Select(lams=(0.5, 0.1), n_subsamples=4)\n"
        "assert sel.rule == '1se' and SelectionReport._fields\n"
        "cache = WarmCache(WarmCacheConfig(capacity=2, band=2.0))\n"
        "assert len(cache) == 0 and cache.stats().hits == 0\n"
        "assert 'jax' not in sys.modules, 'jax imported eagerly'\n"
        "print('ok')\n"
    )
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env)
    assert out.returncode == 0, out.stderr
    assert "ok" in out.stdout


def test_core_reexports_keep_working():
    # the pre-session surface must stay importable, lazily
    from repro.core import (CVPathResult, FusedPathResult,  # noqa: F401
                            SaifConfig, SaifPathResult, fused_path,
                            kfold_weights, lambda_grid, saif, saif_batch,
                            saif_path, solve_lasso_cm)
    assert callable(saif) and callable(saif_path)
    import repro.core as core
    assert callable(core.saif)          # not shadowed by the submodule
    from repro.core.saif import saif as saif_fn
    assert saif_fn is saif


def test_unknown_request_and_penalty():
    with pytest.raises(TypeError, match="penalty"):
        open_session(Problem(X=np.eye(4), y=np.ones(4), penalty="ridge"))
    sess = open_session(Problem(X=np.eye(4), y=np.ones(4)))
    with pytest.raises(TypeError, match="request"):
        sess.solve(("not", "a", "request"))
