"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties.

Kernels run in interpret mode here (CPU container); on a TPU backend the
same entry points compile to Mosaic.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, st

from repro.kernels.ops import (cm_epochs, cm_epochs_ref, screen_scores,
                               screen_scores_ref)


@pytest.mark.parametrize("n,p", [(8, 16), (100, 100), (257, 513), (512, 256),
                                 (33, 1000)])
@pytest.mark.parametrize("bn,bp", [(128, 128), (256, 512)])
def test_screen_shape_sweep(rng, n, p, bn, bp):
    X = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    theta = jnp.asarray(rng.normal(size=n), jnp.float32)
    norm = jnp.linalg.norm(X, axis=0)
    r = 0.41
    s, u, l = screen_scores(X, theta, norm, r, bn=bn, bp=bp)
    sr, ur, lr = screen_scores_ref(X, theta, norm, r)
    scale = float(jnp.max(sr)) + 1.0
    np.testing.assert_allclose(s, sr, atol=2e-5 * scale)
    np.testing.assert_allclose(u, ur, atol=2e-5 * scale)
    np.testing.assert_allclose(l, lr, atol=2e-5 * scale)


@given(seed=st.integers(0, 10_000),
       n=st.integers(4, 200), k=st.integers(1, 40),
       n_epochs=st.integers(1, 4))
@settings(max_examples=25, deadline=None)
def test_cm_kernel_matches_oracle(seed, n, k, n_epochs):
    r = np.random.default_rng(seed)
    A = jnp.asarray(r.normal(size=(n, k)), jnp.float32)
    y = jnp.asarray(r.normal(size=n), jnp.float32)
    beta = jnp.asarray(r.normal(size=k) * 0.1, jnp.float32)
    csq = jnp.sum(A * A, axis=0)
    mask = jnp.asarray(r.random(k) < 0.85)
    lam = float(r.uniform(0.01, 2.0))
    b1, r1 = cm_epochs(A, y, beta, csq, mask, lam, n_epochs=n_epochs)
    b2, r2 = cm_epochs_ref(A, y, beta, csq, mask, jnp.float32(lam),
                           n_epochs=n_epochs)
    np.testing.assert_allclose(b1, b2, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(r1, r2, atol=1e-4, rtol=1e-4)


def test_cm_kernel_masked_coords_stay_zero(rng):
    n, k = 64, 12
    A = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    y = jnp.asarray(rng.normal(size=n), jnp.float32)
    beta = jnp.zeros(k, jnp.float32)
    csq = jnp.sum(A * A, axis=0)
    mask = jnp.zeros(k, bool).at[:5].set(True)
    b, _ = cm_epochs(A, y, beta, csq, mask, 0.1, n_epochs=5)
    assert (np.asarray(b)[5:] == 0).all()


def test_cm_kernel_decreases_objective(rng):
    n, k = 100, 20
    A = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    y = jnp.asarray(rng.normal(size=n), jnp.float32)
    beta = jnp.asarray(rng.normal(size=k), jnp.float32)
    csq = jnp.sum(A * A, axis=0)
    mask = jnp.ones(k, bool)
    lam = 0.3

    def obj(b):
        r = y - A @ b
        return float(0.5 * jnp.dot(r, r) + lam * jnp.sum(jnp.abs(b)))

    prev = obj(beta)
    for _ in range(4):
        beta, _ = cm_epochs(A, y, beta, csq, mask, lam, n_epochs=1)
        cur = obj(beta)
        assert cur <= prev + 1e-4
        prev = cur


def test_screen_zero_radius_is_plain_correlation(rng):
    n, p = 96, 200
    X = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    theta = jnp.asarray(rng.normal(size=n), jnp.float32)
    norm = jnp.linalg.norm(X, axis=0)
    s, u, l = screen_scores(X, theta, norm, 0.0, bn=128, bp=128)
    np.testing.assert_allclose(s, u, atol=1e-6)
    np.testing.assert_allclose(s, l, atol=1e-6)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_screen_dtype_sweep(rng, dtype):
    """bf16 inputs (the §Perf S4 variant) stay within bf16 tolerance."""
    import jax.numpy as jnp
    dt = jnp.dtype(dtype)
    n, p = 128, 384
    X = jnp.asarray(rng.normal(size=(n, p))).astype(dt)
    theta = jnp.asarray(rng.normal(size=n)).astype(dt)
    norm = jnp.linalg.norm(X.astype(jnp.float32), axis=0).astype(dt)
    s, u, l = screen_scores(X, theta, norm, 0.3, bn=128, bp=128)
    sr, ur, lr = screen_scores_ref(X.astype(jnp.float32),
                                   theta.astype(jnp.float32),
                                   norm.astype(jnp.float32), 0.3)
    scale = float(jnp.max(jnp.abs(sr))) + 1.0
    tol = 2e-5 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(np.asarray(s, np.float32), sr,
                               atol=tol * scale)
    np.testing.assert_allclose(np.asarray(u, np.float32), ur,
                               atol=tol * scale)
