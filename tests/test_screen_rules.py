"""Screen-rule grid safety + invariance (ISSUE 9, DESIGN.md §13).

The rule contract under test:

  * ``saif`` (default) is the Theorem-2 geometry, bitwise-unchanged from
    PR 8 — selecting it explicitly (by name or ScreenRule object) must
    not move a single bit across the screen x inner backend grid;
  * ``gap_safe`` is exactly the old engine with the sequential ball
    disabled — it must equal ``saif`` + ``use_seq_ball=False`` bitwise;
  * ``hybrid`` discards with the unsafe strong-rule point bound but
    gates every stop behind a full-radius safe post-check, so its FINAL
    answer carries the same safe guarantee: support and coefficients
    match the unscreened CM oracle on every problem — swept over 32
    seeds, near-lambda_max regimes, and the mixed-precision fast fleet.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SaifConfig, get_loss, saif, saif_batch,
                        solve_lasso_cm)
from repro.core.duality import lambda_max
from repro.core.screen_rule import (SCREEN_RULES, ScreenRule,
                                    resolve_screen_rule)

LOSS = get_loss("least_squares")


def _support(beta, tol=1e-8):
    return set(np.where(np.abs(np.asarray(beta)) > tol)[0].tolist())


def _problem(seed, n=40, p=150, frac=0.15):
    rng = np.random.default_rng(seed)
    X = rng.uniform(-10, 10, (n, p))
    w = np.zeros(p)
    k = max(p // 15, 3)
    w[rng.choice(p, k, replace=False)] = rng.normal(size=k)
    y = X @ w + 0.5 * rng.normal(size=n)
    lam = frac * float(lambda_max(LOSS, jnp.asarray(X), jnp.asarray(y)))
    return X, y, lam


# --------------------------------------------------------------- registry

def test_registry_and_defaults():
    assert set(SCREEN_RULES) == {"saif", "gap_safe", "hybrid"}
    assert SaifConfig().screen_rule == "saif"
    r = resolve_screen_rule("hybrid")
    assert isinstance(r, ScreenRule)
    assert r.add_bound == "point" and r.post_check and not r.delta_ramp
    assert resolve_screen_rule(r) is r           # objects pass through
    assert resolve_screen_rule("saif").use_seq_ball
    assert not resolve_screen_rule("gap_safe").use_seq_ball


def test_resolve_rejects_unknown():
    with pytest.raises(ValueError, match="unknown screen rule"):
        resolve_screen_rule("strong")
    with pytest.raises(ValueError):
        SaifConfig(screen_rule="strong")         # config fails fast too


def test_point_bound_requires_post_check():
    with pytest.raises(ValueError, match="post_check"):
        ScreenRule("bad", use_seq_ball=False, add_bound="point",
                   post_check=False, delta_ramp=False)


# --------------------------------------- saif-rule bitwise invariance

@pytest.mark.parametrize("screen,inner", [("jnp", "jnp"), ("jnp", "gram")])
def test_saif_rule_selection_is_bitwise_noop(screen, inner):
    """Naming the default rule (str or object) moves zero bits (PR-8
    parity across the backend grid)."""
    X, y, lam = _problem(3)
    base = SaifConfig(eps=1e-7, screen_backend=screen, inner_backend=inner)
    ref = saif(X, y, lam, base)
    for rule in ("saif", SCREEN_RULES["saif"]):
        res = saif(X, y, lam,
                   SaifConfig(eps=1e-7, screen_backend=screen,
                              inner_backend=inner, screen_rule=rule))
        assert bool(jnp.all(res.beta == ref.beta))
        assert bool(res.gap == ref.gap)
        assert int(res.n_outer) == int(ref.n_outer)
        assert bool(jnp.all(res.trace_gap == ref.trace_gap))
        assert bool(jnp.all(res.active_idx == ref.active_idx))


def test_gap_safe_equals_saif_without_seq_ball():
    """gap_safe IS the Theorem-2 engine minus the sequential ball."""
    X, y, lam = _problem(4)
    a = saif(X, y, lam, SaifConfig(eps=1e-7, use_seq_ball=False))
    g = saif(X, y, lam, SaifConfig(eps=1e-7, screen_rule="gap_safe"))
    assert bool(jnp.all(a.beta == g.beta))
    assert bool(a.gap == g.gap)
    assert int(a.n_outer) == int(g.n_outer)
    assert bool(jnp.all(a.trace_gap == g.trace_gap))


# ----------------------------------------------- safety: 32-seed sweep

@pytest.mark.parametrize("rule", ["hybrid", "gap_safe"])
def test_rule_safety_sweep_32_seeds(rule):
    """Across 32 random problems the rule's support AND coefficients
    match the unscreened CM oracle — the safe guarantee survives the
    unsafe discards (hybrid) because the post-check gates every stop."""
    b = 32
    rng = np.random.default_rng(7)
    n, p = 40, 150
    X = rng.uniform(-10, 10, (n, p))
    Ys, lams = [], []
    for i in range(b):
        w = np.zeros(p)
        w[rng.choice(p, 10, replace=False)] = rng.normal(size=10)
        Ys.append(X @ w + 0.5 * rng.normal(size=n))
        lams.append(0.05 + 0.3 * i / (b - 1))   # lam fractions 0.05..0.35
    Y = np.stack(Ys)
    Xj = jnp.asarray(X)
    lam_abs = [f * float(lambda_max(LOSS, Xj, jnp.asarray(Ys[i])))
               for i, f in enumerate(lams)]
    cfg = SaifConfig(eps=1e-8, screen_rule=rule)
    res = saif_batch(X, Y, jnp.asarray(lam_abs), cfg)
    for i in range(b):
        ref = solve_lasso_cm(LOSS, Xj, jnp.asarray(Ys[i]), lam_abs[i],
                             tol=1e-10)
        assert _support(res.beta[i]) == _support(ref), (
            f"rule={rule} seed-problem {i}: support mismatch")
        np.testing.assert_allclose(np.asarray(res.beta[i]),
                                   np.asarray(ref), atol=2e-5)
        assert float(res.gap[i]) <= 1e-8


@pytest.mark.parametrize("rule", ["hybrid", "gap_safe"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_rule_safety_near_lambda_max(rule, seed):
    """The hardest screening regime: lam -> lambda_max, tiny supports,
    gap at the precision floor. Serial engine, Gaussian design."""
    rng = np.random.default_rng(100 + seed)
    n, p = 50, 200
    X = rng.normal(0, 1, (n, p))
    w = np.zeros(p)
    w[rng.choice(p, 5, replace=False)] = rng.normal(size=5)
    y = X @ w + 0.1 * rng.normal(size=n)
    lmax = float(lambda_max(LOSS, jnp.asarray(X), jnp.asarray(y)))
    for frac in (0.95, 0.8):
        lam = frac * lmax
        res = saif(X, y, lam, SaifConfig(eps=1e-8, screen_rule=rule))
        ref = solve_lasso_cm(LOSS, jnp.asarray(X), jnp.asarray(y), lam,
                             tol=1e-10)
        assert _support(res.beta) == _support(ref)


def test_hybrid_composes_with_mixed_precision_fleet():
    """hybrid + parity='fast' + bfloat16 screening gemm: the unsafe
    point discards ride on the widened certified radii and the safe
    post-check still holds the final answer to the oracle support."""
    b = 6
    rng = np.random.default_rng(11)
    n, p = 40, 150
    X = rng.uniform(-10, 10, (n, p))
    Ys, lam_abs = [], []
    for i in range(b):
        w = np.zeros(p)
        w[rng.choice(p, 8, replace=False)] = rng.normal(size=8)
        y = X @ w + 0.5 * rng.normal(size=n)
        Ys.append(y)
        frac = 0.08 + 0.25 * i / (b - 1)
        lam_abs.append(frac * float(lambda_max(LOSS, jnp.asarray(X),
                                               jnp.asarray(y))))
    Y = np.stack(Ys)
    cfg = SaifConfig(eps=1e-7, screen_rule="hybrid", parity="fast",
                     screen_dtype="bfloat16")
    res = saif_batch(X, Y, jnp.asarray(lam_abs), cfg)
    for i in range(b):
        ref = solve_lasso_cm(LOSS, jnp.asarray(X), jnp.asarray(Ys[i]),
                             lam_abs[i], tol=1e-10)
        assert _support(res.beta[i]) == _support(ref)
        assert float(res.gap[i]) <= 1e-7


# ------------------------------------------------- observability traces

def test_hybrid_trace_counters_populated():
    """Per-step counters: screens every non-stop step, records the
    final post-check, and reports rule provenance via config."""
    X, y, lam = _problem(5)
    res = saif(X, y, lam, SaifConfig(eps=1e-7, screen_rule="hybrid"))
    t = int(res.n_outer)
    scr = np.asarray(res.trace_screened)[:t]
    srv = np.asarray(res.trace_survivors)[:t]
    pv = np.asarray(res.trace_post_viol)[:t]
    ran = scr >= 0
    assert ran.sum() >= t - 1          # hybrid screens every non-stop step
    assert (srv[ran] >= 0).all() and (srv[ran] <= X.shape[1]).all()
    # counters cover the non-active candidate pool only
    assert (scr[ran] + srv[ran] <= X.shape[1]).all()
    assert (scr[ran] > 0).all()        # point bound discards aggressively
    assert (pv == 0).sum() >= 1        # the accepted stop's clean check
    assert (pv == 1).sum() == 0        # no violations on this problem
    # untaken steps stay at the -1 sentinel
    assert (np.asarray(res.trace_screened)[t:] == -1).all()


def test_saif_trace_counters_populated():
    """The default rule also reports counters (ADD steps only, no
    post-checks — the ball geometry needs none)."""
    X, y, lam = _problem(6)
    res = saif(X, y, lam, SaifConfig(eps=1e-7))
    t = int(res.n_outer)
    scr = np.asarray(res.trace_screened)[:t]
    pv = np.asarray(res.trace_post_viol)[:t]
    assert (scr >= 0).sum() >= 1
    assert (pv == -1).all()            # saif never post-checks
