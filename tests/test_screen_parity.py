"""Screening-backend parity: the fused Pallas kernels, the jnp backend and
the legacy sort-based violation counts must agree — exactly for the integer
decisions, to float tolerance for the scores — across padded and unpadded
tile shapes. Plus the compile-first path-engine guarantees: warm vs cold
supports identical, O(log p) compilations per path.

On this CPU container the Pallas kernels run in interpret mode; on a TPU
backend the identical entry points compile to Mosaic and the ``compiled``
parametrization activates.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_regression
from repro.core import (SaifConfig, get_loss, lambda_grid, saif, saif_path,
                        saif_path_naive, saif_jit_compile_count,
                        solve_lasso_cm)
from repro.core.duality import lambda_max
from repro.core.screen_backend import (ge_counts_from_hist, make_screen_jnp,
                                       make_screen_pallas,
                                       violation_ge_counts)
from repro.kernels.ops import (autotune_screen_blocks, on_tpu, screen_fused,
                               screen_fused_ref, ub_histogram,
                               ub_histogram_ref)

# pallas-compiled only exists on a TPU backend; interpret everywhere
MODES = ["interpret"] + (["compiled"] if on_tpu() else [])


def _interpret(mode: str) -> bool:
    return mode == "interpret"


def _support(beta, tol=1e-8):
    return set(np.where(np.abs(np.asarray(beta)) > tol)[0].tolist())


# --------------------------------------------------------------------------
# kernel-level parity
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("n,p", [(64, 256), (57, 513), (100, 100),
                                 (33, 1000), (128, 384)])
@pytest.mark.parametrize("bn,bp", [(128, 128), (64, 256)])
def test_fused_screen_matches_ref(rng, mode, n, p, bn, bp):
    """(score, ub, lb, top-h, max-ub) parity incl. shapes where p % bp != 0
    and n % bn != 0 (padding paths)."""
    h = 16
    X = jnp.asarray(rng.normal(size=(n, p)))
    theta = jnp.asarray(rng.normal(size=n))
    norm = jnp.linalg.norm(X, axis=0)
    active = jnp.asarray(rng.random(p) < 0.1)
    r = 0.37
    s, u, l, tops, topi, tmax = screen_fused(
        X, theta, norm, active, r, h=h, bn=bn, bp=bp,
        interpret=_interpret(mode))
    sr, ur, lr, ts_ref, ti_ref, mu_ref = screen_fused_ref(
        X, theta, norm, active, r, h=h)
    scale = float(jnp.max(jnp.abs(sr[jnp.isfinite(sr)]))) + 1.0
    for a, b in ((s, sr), (u, ur), (l, lr)):
        fin = np.isfinite(np.asarray(b))
        np.testing.assert_allclose(np.asarray(a)[fin], np.asarray(b)[fin],
                                   atol=1e-10 * scale)
        assert (np.asarray(a)[~fin] == np.asarray(b)[~fin]).all()
    # merged tile winners == global stable top_k: ids exact on every finite
    # candidate (the -inf tail of a saturated tile is id-arbitrary but
    # never recruitable)
    cs, pos = jax.lax.top_k(tops.reshape(-1), h)
    ci = topi.reshape(-1)[pos]
    np.testing.assert_allclose(cs, ts_ref, atol=1e-10 * scale)
    fin = np.isfinite(np.asarray(ts_ref))
    assert (np.asarray(ci)[fin] == np.asarray(ti_ref)[fin]).all()
    assert float(jnp.max(tmax)) == pytest.approx(float(mu_ref), abs=1e-12)


@pytest.mark.parametrize("mode", MODES)
def test_fused_screen_saturated_tile(rng, mode):
    """A fully-active tile must emit distinct candidate ids (no duplicate
    -inf lanes) so downstream gathers stay well-defined."""
    n, p, bp, h = 32, 256, 128, 8
    X = jnp.asarray(rng.normal(size=(n, p)))
    theta = jnp.asarray(rng.normal(size=n))
    norm = jnp.linalg.norm(X, axis=0)
    active = np.ones(p, bool)
    active[252:] = False                   # tile 0 saturated, 4 finite in 1
    s, u, l, tops, topi, tmax = screen_fused(
        X, theta, norm, jnp.asarray(active), 0.3, h=h, bn=128, bp=bp,
        interpret=_interpret(mode))
    cs, pos = jax.lax.top_k(tops.reshape(-1), h)
    ci = np.asarray(topi.reshape(-1)[pos])
    assert len(set(ci.tolist())) == h      # all candidate ids distinct
    fin = np.isfinite(np.asarray(cs))
    assert sorted(ci[fin].tolist()) == [252, 253, 254, 255]
    sr, ur, lr, ts_ref, ti_ref, mu_ref = screen_fused_ref(
        X, theta, norm, jnp.asarray(active), 0.3, h=h)
    assert (ci[fin] == np.asarray(ti_ref)[fin]).all()


@pytest.mark.parametrize("mode", MODES)
def test_histogram_kernel_exact(rng, mode):
    """The streaming ub-histogram equals bincount(searchsorted) bit for bit,
    including -inf (masked) entries and tied thresholds."""
    p, h = 777, 12
    ub = rng.normal(size=p)
    ub[rng.choice(p, 60, replace=False)] = -np.inf
    lb = np.abs(rng.normal(size=h))
    lb[3] = lb[7]                       # force a tie
    lb_sorted = jnp.asarray(np.sort(lb))
    hist = np.asarray(ub_histogram(jnp.asarray(ub), lb_sorted,
                                   interpret=_interpret(mode)))
    ref = np.asarray(ub_histogram_ref(jnp.asarray(ub), lb_sorted))
    # tile padding (-inf) lands in bin 0, which the suffix counts never
    # read; every decision-relevant bin is exact
    assert (hist[1:] == ref[1:]).all()
    assert hist[0] >= ref[0]          # bin 0 grows by the pad count only
    assert int(hist.sum()) >= p


def test_violation_counts_match_legacy_sort(rng):
    """The O(p log h) count reproduces the legacy O(p log p) full-vector
    sort + searchsorted integer for integer."""
    p, h = 1201, 16
    ub = rng.normal(size=p) * 3
    ub[rng.choice(p, 100, replace=False)] = -np.inf
    lb = np.abs(rng.normal(size=h))
    lb[2] = ub[5]                       # force threshold==value tie
    new = violation_ge_counts(jnp.asarray(ub), jnp.asarray(lb))
    ub_sorted = jnp.sort(jnp.asarray(ub))
    legacy = p - jnp.searchsorted(ub_sorted, jnp.asarray(lb), side="left")
    assert (np.asarray(new) == np.asarray(legacy)).all()


def test_autotuner_blocks():
    from repro.kernels.screen.screen import VMEM_TILE_BUDGET_BYTES
    for n, p in [(1, 1), (100, 600), (100, 5000), (4096, 1_000_000),
                 (295, 8141)]:
        bn, bp = autotune_screen_blocks(n, p)
        assert bp % 128 == 0 and bn % 8 == 0
        assert 2 * bn * bp * 4 <= max(VMEM_TILE_BUDGET_BYTES,
                                      2 * 8 * 128 * 4)
        assert bn >= 8 and bp >= 128


# --------------------------------------------------------------------------
# solver-level parity: bitwise-identical active sets across backends
# --------------------------------------------------------------------------

@pytest.mark.parametrize("frac", [0.3, 0.08])
def test_saif_backends_identical_active_sets(rng, frac):
    loss = get_loss("least_squares")
    X, y, _ = make_regression(rng, n=50, p=300)
    lam = frac * float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    r_jnp = saif(X, y, lam, SaifConfig(eps=1e-8, screen_backend="jnp"))
    r_pal = saif(X, y, lam, SaifConfig(eps=1e-8, screen_backend="pallas"))
    assert _support(r_jnp.beta) == _support(r_pal.beta)
    assert int(r_jnp.n_active) == int(r_pal.n_active)
    assert int(r_jnp.n_outer) == int(r_pal.n_outer)
    # the whole recruiting trajectory matches step for step
    assert np.array_equal(np.asarray(r_jnp.trace_n_active),
                          np.asarray(r_pal.trace_n_active))


def test_screen_backend_outputs_identical(rng):
    """ScreenOut parity of the two in-process backends on one call."""
    n, p, h = 64, 500, 8
    X = jnp.asarray(rng.normal(size=(n, p)))
    norm = jnp.linalg.norm(X, axis=0)
    theta = jnp.asarray(rng.normal(size=n)) * 0.1
    active = jnp.zeros(p, bool).at[jnp.asarray([3, 99, 250])].set(True)
    o1 = make_screen_jnp(X, norm, h)(theta, 0.2, active)
    o2 = make_screen_pallas(X, norm, h)(theta, 0.2, active)
    assert (np.asarray(o1.cand_idx) == np.asarray(o2.cand_idx)).all()
    assert (np.asarray(o1.cand_ge) == np.asarray(o2.cand_ge)).all()
    np.testing.assert_allclose(o1.cand_score, o2.cand_score, rtol=1e-12)
    np.testing.assert_allclose(float(o1.max_ub), float(o2.max_ub),
                               rtol=1e-12)


# --------------------------------------------------------------------------
# path engine guarantees
# --------------------------------------------------------------------------

def test_path_engine_matches_naive_and_cold(rng):
    loss = get_loss("least_squares")
    # dedicated rng: path tests must not depend on fixture stream order
    X, y, _ = make_regression(np.random.default_rng(77), n=40, p=200)
    lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    lams = lambda_grid(0.9 * lmax, 6, lo_frac=0.02)
    cfg = SaifConfig(eps=1e-8)
    eng = saif_path(X, y, lams, cfg)
    naive = saif_path_naive(X, y, lams, cfg)
    for lam, b_eng, b_naive in zip(eng.lams, eng.betas, naive.betas):
        cold = saif(X, y, float(lam), cfg)
        assert _support(b_eng) == _support(cold.beta)       # warm == cold
        assert _support(b_eng) == _support(b_naive)         # engine == naive
        ref = solve_lasso_cm(loss, jnp.asarray(X), jnp.asarray(y),
                             float(lam), tol=1e-10)
        assert _support(b_eng) == _support(ref)             # and both safe


def test_path_make_screen_factory(rng):
    """The custom-backend hook receives the engine's grid-max h, so a
    factory-built backend threads through the whole path."""
    X, y, _ = make_regression(np.random.default_rng(79), n=40, p=200)
    loss = get_loss("least_squares")
    lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    lams = lambda_grid(0.9 * lmax, 5, lo_frac=0.05)
    Xj = jnp.asarray(X)
    norm = jnp.linalg.norm(Xj, axis=0)
    seen = []

    def factory(h):
        seen.append(h)
        return make_screen_jnp(Xj, norm, h)

    res = saif_path(X, y, lams, SaifConfig(eps=1e-8), make_screen=factory)
    base = saif_path(X, y, lams, SaifConfig(eps=1e-8))
    assert len(seen) == 1                  # called once, with grid-max h
    for a, b in zip(res.betas, base.betas):
        assert _support(a) == _support(b)


def test_path_engine_compile_count(rng):
    """Acceptance: at most O(log p) distinct _saif_jit compilations/path."""
    X, y, _ = make_regression(np.random.default_rng(80), n=40, p=256)
    loss = get_loss("least_squares")
    lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    lams = lambda_grid(0.9 * lmax, 20, lo_frac=0.02)
    res = saif_path(X, y, lams, SaifConfig(eps=1e-7))
    if res.n_compilations is None:
        pytest.skip("jit cache-size counter unavailable on this jax")
    bound = int(np.ceil(np.log2(256))) + 2   # capacity doublings + slack
    assert 0 <= res.n_compilations <= bound
    assert len(res.betas) == 20


def test_path_engine_segmented_overflow_recovers(rng):
    """Tiny forced capacity exercises the segment re-entry growth path.

    Compared against default-capacity cold solves: the property under test
    is that elastic growth doesn't corrupt results, so cold SAIF is the
    oracle. (The lambda ~ lambda_max boundary on gaussian designs is a
    pre-existing solver-vs-CM-oracle edge unrelated to capacity — the grid
    starts at 0.5 lambda_max to stay out of it.)

    Quarantined into its own pytest process: re-running this body in the
    same interpreter as the rest of the suite trips a pre-existing XLA
    ``backend_compile`` segfault (CPU backend state, unrelated to the
    solver). The parent test re-invokes just this node id in a child
    pytest with ``REPRO_SEGMENT_OVERFLOW_INPROC=1`` so the assertions
    still gate CI, while the crash domain is the child process.
    """
    if os.environ.get("REPRO_SEGMENT_OVERFLOW_INPROC") != "1":
        env = dict(os.environ, REPRO_SEGMENT_OVERFLOW_INPROC="1")
        nodeid = (
            "tests/test_screen_parity.py::"
            "test_path_engine_segmented_overflow_recovers"
        )
        proc = subprocess.run(
            [sys.executable, "-m", "pytest", "-x", "-q", nodeid],
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            env=env,
        )
        assert proc.returncode == 0, (
            f"quarantined segment-overflow test failed (rc={proc.returncode})"
        )
        return
    loss = get_loss("least_squares")
    X, y, _ = make_regression(np.random.default_rng(78), n=40, p=200)
    lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    lams = lambda_grid(0.5 * lmax, 4, lo_frac=0.03)
    eng = saif_path(X, y, lams, SaifConfig(eps=1e-8, k_max=8),
                    segment_len=2)
    for lam, beta in zip(eng.lams, eng.betas):
        cold = saif(X, y, float(lam), SaifConfig(eps=1e-8))
        assert _support(beta) == _support(cold.beta)
        ref = solve_lasso_cm(loss, jnp.asarray(X), jnp.asarray(y),
                             float(lam), tol=1e-10)
        assert _support(beta) == _support(ref)
