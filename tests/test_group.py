"""Group-LASSO SAIF extension tests (the paper's proposed extension)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.group import (GroupSaifConfig, group_lambda_max, group_saif,
                              solve_group_lasso_bcd)
from repro.core.losses import get_loss


def _make(rng, n=40, p=120, gsize=4, k_groups=5):
    X = rng.normal(size=(n, p))
    beta = np.zeros(p)
    ng = p // gsize
    act = rng.choice(ng, k_groups, replace=False)
    for g in act:
        beta[g * gsize:(g + 1) * gsize] = rng.normal(size=gsize)
    y = X @ beta + 0.3 * rng.normal(size=n)
    return X, y


@pytest.mark.parametrize("frac", [0.5, 0.1])
def test_group_saif_matches_bcd_oracle(rng, frac):
    loss = get_loss("least_squares")
    gsize = 4
    X, y = _make(rng)
    lam = frac * group_lambda_max(loss, X, y, gsize)
    res = group_saif(X, y, lam, gsize, GroupSaifConfig(eps=1e-9))
    ref = solve_group_lasso_bcd(loss, jnp.asarray(X), jnp.asarray(y),
                                lam, gsize, tol=1e-11)
    # group supports match
    def gsup(b):
        return set(np.where(np.linalg.norm(
            np.asarray(b).reshape(-1, gsize), axis=1) > 1e-7)[0].tolist())
    assert gsup(res.beta) == gsup(ref)
    np.testing.assert_allclose(np.asarray(res.beta), np.asarray(ref),
                               atol=1e-5)


def test_group_saif_zero_at_lambda_max(rng):
    loss = get_loss("least_squares")
    X, y = _make(rng)
    lmax = group_lambda_max(loss, X, y, 4)
    res = group_saif(X, y, 1.2 * lmax, 4, GroupSaifConfig(eps=1e-10))
    assert float(jnp.abs(res.beta).max()) == 0.0


def test_group_active_set_small(rng):
    loss = get_loss("least_squares")
    X, y = _make(rng, p=240, k_groups=4)
    lam = 0.2 * group_lambda_max(loss, X, y, 4)
    res = group_saif(X, y, lam, 4, GroupSaifConfig(eps=1e-8))
    assert int(res.n_active_groups) < 60   # << 60 groups total? p/4 = 60
    assert float(res.gap) <= 1e-8
