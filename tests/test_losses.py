"""Unit + property tests for repro.core.losses."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, st

from repro.core.losses import get_loss, least_squares, logistic

finite_f = st.floats(min_value=-20, max_value=20, allow_nan=False)


@given(z=finite_f, y=finite_f)
@settings(max_examples=50, deadline=None)
def test_ls_grad_matches_autodiff(z, y):
    g_auto = jax.grad(lambda zz: least_squares.value(zz, y))(jnp.asarray(z))
    assert np.allclose(least_squares.grad(jnp.asarray(z), y), g_auto)


@given(z=finite_f, y=st.sampled_from([-1.0, 1.0]))
@settings(max_examples=50, deadline=None)
def test_logistic_grad_matches_autodiff(z, y):
    g_auto = jax.grad(lambda zz: logistic.value(zz, jnp.asarray(y)))(
        jnp.asarray(z))
    assert np.allclose(logistic.grad(jnp.asarray(z), jnp.asarray(y)), g_auto,
                       atol=1e-10)


@given(z=finite_f, y=finite_f)
@settings(max_examples=50, deadline=None)
def test_ls_fenchel_young_equality(z, y):
    """f(z) + f*(u) = u z exactly when u = f'(z)."""
    z, y = jnp.asarray(z), jnp.asarray(y)
    u = least_squares.grad(z, y)
    lhs = least_squares.value(z, y) + least_squares.conj(u, y)
    assert np.allclose(lhs, u * z, atol=1e-8)


@given(z=st.floats(min_value=-10, max_value=10), y=st.sampled_from([-1.0, 1.0]))
@settings(max_examples=50, deadline=None)
def test_logistic_fenchel_young_equality(z, y):
    z, y = jnp.asarray(z), jnp.asarray(y)
    u = logistic.grad(z, y)
    lhs = logistic.value(z, y) + logistic.conj(u, y)
    assert np.allclose(lhs, u * z, atol=1e-7)


@given(z1=finite_f, z2=finite_f, y=st.sampled_from([-1.0, 1.0]))
@settings(max_examples=50, deadline=None)
def test_smoothness_constants(z1, z2, y):
    """|f'(z1) - f'(z2)| <= alpha |z1 - z2| for both losses."""
    for loss in (least_squares, logistic):
        d = abs(float(loss.grad(jnp.asarray(z1), y)
                      - loss.grad(jnp.asarray(z2), y)))
        assert d <= loss.smoothness * abs(z1 - z2) + 1e-9


def test_primal_dual_objectives_shapes():
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(7, 5)))
    y = jnp.asarray(rng.normal(size=7))
    beta = jnp.asarray(rng.normal(size=5))
    lam = jnp.asarray(0.3)
    for name in ("least_squares", "logistic"):
        loss = get_loss(name)
        yy = jnp.sign(y) if name == "logistic" else y
        p = loss.primal_objective(X, yy, beta, lam)
        d = loss.dual_objective(yy, jnp.zeros(7), lam)
        assert p.shape == () and d.shape == ()
        assert np.isfinite(float(p))


def test_get_loss_unknown():
    with pytest.raises(ValueError):
        get_loss("huber")
