"""Chaos suite for the fault-tolerant serving runtime (DESIGN.md §10).

The serving contract under fire: for EVERY request, exactly one of

  * a :class:`~repro.core.serving.ServingResult` whose verdict is ``ok``
    — and whose value then passes an *independent* KKT check here;
  * a ServingResult whose verdict is a typed degraded verdict
    (``ok=False`` with the ladder trail recorded);
  * a typed :class:`~repro.core.serving.ServingError` subclass.

Anything else — an untyped exception, a silently-NaN result with a green
verdict — is a failed test. The fault schedules are seeded
(``FaultInjector.from_seed``), so every sweep is reproducible, and the
happy path is additionally pinned to PR 5 semantics: bitwise-identical
values and ZERO new engine compilations at steady state.
"""
import numpy as np
import pytest

from conftest import kkt_violation, make_regression
from repro.core.api import CV, Fleet, Path, Problem, Scalar, open_session
from repro.core.losses import get_loss
from repro.core.saif import SaifConfig
from repro.core.serving import (BackendFault, DeadlineExceeded,
                                NumericalError, RequestError, ServingConfig,
                                ServingError, open_serving)
from repro.runtime.inject import FaultInjector

BACKEND_GRID = [("jnp", "jnp"), ("jnp", "gram"), ("pallas", "jnp")]


@pytest.fixture
def rng():
    return np.random.default_rng(11)


def _problem(rng, n=40, p=120):
    X, y, _ = make_regression(rng, n=n, p=p)
    from repro.core.duality import lambda_max
    import jax.numpy as jnp
    lmax = float(lambda_max(get_loss("least_squares"),
                            jnp.asarray(X), jnp.asarray(y)))
    return X, y, lmax


def _request_stream(lmax, y, rng):
    """A mixed, steady-state-shaped request stream."""
    return [
        Scalar(0.3 * lmax),
        Scalar(0.2 * lmax, warm=True),
        Path([0.5 * lmax, 0.3 * lmax, 0.2 * lmax]),
        Scalar(0.3 * lmax),
        Fleet(Y=np.stack([y, y + 0.05 * rng.normal(size=y.shape)]),
              lams=0.3 * lmax),
        Scalar(0.2 * lmax, warm=True),
    ]


# ---------------------------------------------------------------------------
# happy path: verdict plumbing must not perturb PR 5 semantics
# ---------------------------------------------------------------------------

def test_happy_path_bitwise_pr5_and_zero_steady_state_compiles(rng):
    X, y, lmax = _problem(rng)
    prob = Problem(X=X, y=y)
    cfg = SaifConfig(eps=1e-7)
    plain = open_session(prob, cfg)
    srv = open_serving(prob, cfg)
    stream = _request_stream(lmax, y, np.random.default_rng(0))
    plain_vals = [plain.solve(r) for r in stream]
    served = [srv.solve(r) for r in stream]
    def _unwrap(v):     # fused Scalar returns a plain (beta_rec, res) pair
        return v[1] if isinstance(v, tuple) and not hasattr(v, "_fields") \
            else v

    for want, got in zip(plain_vals, served):
        assert got.verdict.ok and not got.verdict.degraded
        want = _unwrap(want)
        val = _unwrap(got.value)
        if hasattr(want, "beta"):
            np.testing.assert_array_equal(np.asarray(want.beta),
                                          np.asarray(val.beta))
        else:   # path results
            for wb, gb in zip(want.betas, val.betas):
                np.testing.assert_array_equal(np.asarray(wb),
                                              np.asarray(gb))
    # steady state: replay the stream — zero new engine compilations
    # (the KKT certificate jit lives outside the engine caches)
    before = srv.compile_stats().total
    for r in stream:
        out = srv.solve(r)
        assert out.verdict.ok
    assert srv.compile_stats().total == before


# ---------------------------------------------------------------------------
# the chaos sweep: seeded faults over the screen x inner backend grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("screen,inner", BACKEND_GRID)
def test_chaos_sweep_no_silent_failures(rng, screen, inner):
    X, y, lmax = _problem(rng)
    loss = get_loss("least_squares")
    cfg = SaifConfig(eps=1e-7, screen_backend=screen, inner_backend=inner)
    srv = open_serving(Problem(X=X, y=y), cfg,
                       serving=ServingConfig(backoff_base_s=0.0))
    stream = _request_stream(lmax, y, np.random.default_rng(1))
    inj = FaultInjector.from_seed(2024, n_calls=40,
                                  p_fail=0.18, p_nan=0.12)
    outcomes = []
    with inj:
        for req in stream:
            try:
                out = srv.solve(req)
            except ServingError as e:
                outcomes.append(("typed", type(e).__name__))
                continue
            v = out.verdict
            outcomes.append(("ok" if v.ok else "degraded_verdict",
                             v.events))
            if not v.ok:
                # a failed verdict must carry its ladder trail — no
                # silent failures
                assert v.events and v.rungs
                continue
            # green verdict => independently certify the value here
            if isinstance(req, Scalar):
                val = out.value
                lam = float(req.lam)
                assert kkt_violation(loss, X, y, val.beta, lam) \
                    <= max(1e-3 * lam, 1e-8)
                assert bool(np.all(np.isfinite(np.asarray(val.beta))))
    assert inj.log, "the schedule never fired — sweep is vacuous"
    assert any(kind == "ok" for kind, _ in outcomes)


def test_nan_storm_every_result_still_certified(rng):
    """Aggressive NaN schedule: every primary engine call is poked. The
    ladder must still deliver KKT-certified solutions — the oracle rung
    is screening-free, so nothing the injector does upstream survives
    it."""
    X, y, lmax = _problem(rng, n=30, p=80)
    loss = get_loss("least_squares")
    srv = open_serving(Problem(X=X, y=y), SaifConfig(eps=1e-7))
    lam = 0.25 * lmax
    with FaultInjector(nan_at=set(range(1, 30))):
        out = srv.solve(Scalar(lam))
    v = out.verdict
    assert v.ok and v.degraded
    assert any(r.name == "oracle" and r.ok for r in v.rungs)
    assert "warm_state_reset" in v.events
    assert kkt_violation(loss, X, y, out.value.beta, lam) <= 1e-3 * lam
    # the scrub means the next warm request re-enters cold and is clean
    out2 = srv.solve(Scalar(lam, warm=True))
    assert out2.verdict.ok and not out2.verdict.degraded


def test_breaker_durably_degrades_backend(rng):
    """Persistent faults on a pallas-screened session: retries exhaust,
    the breaker pins the session to jnp for its remaining lifetime, and
    the stream keeps serving green verdicts on the degraded backend."""
    X, y, lmax = _problem(rng, n=30, p=80)
    cfg = SaifConfig(eps=1e-7, screen_backend="pallas")
    srv = open_serving(Problem(X=X, y=y), cfg,
                       serving=ServingConfig(backoff_base_s=0.0))
    with FaultInjector(fail_at={1, 2, 3}):
        out = srv.solve(Scalar(0.3 * lmax))
    assert out.verdict.ok
    assert srv.breaker_open
    assert any(e.startswith("breaker_open") for e in out.verdict.events)
    assert srv.session.config.screen_backend == "jnp"
    out2 = srv.solve(Scalar(0.2 * lmax))        # still degraded, still ok
    assert out2.verdict.ok and srv.breaker_open
    # nothing left to degrade: a second persistent fault is typed
    with FaultInjector(fail_at=set(range(1, 12))):
        with pytest.raises(BackendFault):
            srv.solve(Scalar(0.3 * lmax))


def test_deadline_is_typed(rng):
    X, y, lmax = _problem(rng, n=30, p=80)
    srv = open_serving(Problem(X=X, y=y), SaifConfig(eps=1e-7))
    srv.solve(Scalar(0.3 * lmax))               # compile outside the clock
    with FaultInjector(fail_at={1, 2, 3}, delay_at={1, 2, 3},
                       delay_s=0.2):
        with pytest.raises(DeadlineExceeded):
            srv.solve(Scalar(0.3 * lmax), deadline_s=0.05)


# ---------------------------------------------------------------------------
# verdicts across the penalty surface
# ---------------------------------------------------------------------------

def test_fused_and_group_requests_get_verdicts(rng):
    X, y, _ = make_regression(rng, n=30, p=64)
    parent = np.arange(-1, 63)                  # chain tree
    from repro.core.api import fused, group
    fsrv = open_serving(Problem(X=X, y=y, penalty=fused(parent)),
                        SaifConfig(eps=1e-7))
    out = fsrv.solve(Scalar(2.0))
    assert out.verdict.ok
    beta_rec, res = out.value
    assert np.all(np.isfinite(np.asarray(beta_rec)))
    outp = fsrv.solve(Path([4.0, 2.0]))
    assert outp.verdict.ok and len(outp.value.betas) == 2

    from repro.core.group import GroupSaifConfig
    gsrv = open_serving(Problem(X=X, y=y, penalty=group(8)),
                        GroupSaifConfig(eps=1e-6))
    outg = gsrv.solve(Scalar(2.0))
    assert outg.verdict.ok                       # gap-certified
    assert outg.verdict.kkt_residual == 0.0      # no scalar KKT ran
    # and a group solve that misses its own eps is a *failed* verdict
    tight = open_serving(Problem(X=X, y=y, penalty=group(8)),
                         GroupSaifConfig(eps=1e-14, max_outer=4))
    outt = tight.solve(Scalar(2.0))
    assert not outt.verdict.ok and outt.verdict.rungs   # typed, not silent


def test_weighted_and_cv_verdicts(rng):
    X, y, lmax = _problem(rng, n=36, p=90)
    w = np.asarray(np.random.default_rng(5).uniform(0.5, 2.0, size=36))
    srv = open_serving(Problem(X=X, y=y, weights=w), SaifConfig(eps=1e-7))
    out = srv.solve(Scalar(0.3 * lmax))
    assert out.verdict.ok and out.verdict.kkt_residual <= out.verdict.kkt_tol
    srv2 = open_serving(Problem(X=X, y=y), SaifConfig(eps=1e-7))
    outcv = srv2.solve(CV(n_folds=3, lams=[0.5 * lmax, 0.3 * lmax]))
    assert outcv.verdict.ok


# ---------------------------------------------------------------------------
# admission chaos: malformed requests die typed, at the door
# ---------------------------------------------------------------------------

def test_admission_rejects_are_typed_and_precompile(rng):
    X, y, _ = _problem(rng, n=20, p=40)
    with pytest.raises(NumericalError):
        Problem(X=X, y=np.r_[y[:-1], np.nan])
    with pytest.raises(RequestError):
        Problem(X=np.zeros((10, 3)), y=np.ones(10))    # zero-norm cols
    with pytest.raises(RequestError):
        Problem(X=X, y=y, loss="hinge")
    with pytest.raises(RequestError):
        Problem(X=X, y=y[:-1])                         # shape mismatch
    with pytest.raises(RequestError):
        Scalar(lam=0.0)
    with pytest.raises(RequestError):
        Path(lams=[])
    with pytest.raises(RequestError):
        Fleet(Y=np.stack([y, y]), lams=[1.0, 2.0, 3.0])
    with pytest.raises(RequestError):
        CV(n_folds=1, lams=[1.0])
    # the taxonomy keeps the builtin contracts
    assert issubclass(RequestError, ValueError)
    assert issubclass(NumericalError, ArithmeticError)
    assert issubclass(BackendFault, RuntimeError)
    assert issubclass(DeadlineExceeded, TimeoutError)
