"""Tests for ball regions and dual projection — the safety-critical math."""
import jax.numpy as jnp
import numpy as np
from repro.testing import given, settings, st

from repro.core.cm import solve_lasso_cm
from repro.core.duality import (Ball, duality_gap, feasible_dual, gap_ball,
                                intersect_balls, lambda_max, sequential_ball)
from repro.core.losses import get_loss

from conftest import make_regression


def _theta_star(loss, X, y, lam, tol=1e-12):
    beta = solve_lasso_cm(loss, X, y, lam, tol=tol)
    hat = -loss.grad(X @ beta, y) / lam
    return feasible_dual(loss, X, y, hat, lam), beta


def test_gap_ball_contains_theta_star(rng):
    """Eq (11): theta* within sqrt(2*alpha*gap)/lam of any feasible theta."""
    loss = get_loss("least_squares")
    X, y, _ = make_regression(rng, n=40, p=120)
    X, y = jnp.asarray(X), jnp.asarray(y)
    lam = 0.1 * float(lambda_max(loss, X, y))
    theta_star, _ = _theta_star(loss, X, y, lam)

    # a crude primal point -> feasible dual -> ball must contain theta*
    beta_crude = jnp.zeros(X.shape[1])
    hat = -loss.grad(X @ beta_crude, y) / lam
    theta = feasible_dual(loss, X, y, hat, lam)
    gap = duality_gap(loss, X, y, beta_crude, theta, lam)
    ball = gap_ball(loss, theta, gap, lam)
    dist = float(jnp.linalg.norm(theta_star - ball.center))
    assert dist <= float(ball.radius) * (1 + 1e-8)


def test_sequential_ball_contains_theta_star(rng):
    """Thm 2 with lam0 = lambda_max: ball around (lam0/lam) theta0."""
    loss = get_loss("least_squares")
    X, y, _ = make_regression(rng, n=40, p=120)
    X, y = jnp.asarray(X), jnp.asarray(y)
    lam0 = float(lambda_max(loss, X, y))
    theta0 = -loss.grad(jnp.zeros_like(y), y) / lam0   # exact optimum at lam0
    for frac in (0.9, 0.5, 0.1):
        lam = frac * lam0
        theta_star, _ = _theta_star(loss, X, y, lam)
        ball = sequential_ball(loss, y, theta0, jnp.asarray(lam0),
                               jnp.asarray(lam))
        dist = float(jnp.linalg.norm(theta_star - ball.center))
        assert dist <= float(ball.radius) * (1 + 1e-8), frac


@given(seed=st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_intersect_balls_is_valid_cover(seed):
    """Any point in B1 ∩ B2 lies in the covering ball (incl. sign edge cases)."""
    r = np.random.default_rng(seed)
    dim = 4
    c1 = r.normal(size=dim)
    c2 = c1 + r.normal(size=dim) * r.uniform(0, 2)
    r1, r2 = r.uniform(0.1, 3), r.uniform(0.1, 3)
    b1 = Ball(jnp.asarray(c1), jnp.asarray(r1))
    b2 = Ball(jnp.asarray(c2), jnp.asarray(r2))
    cover = intersect_balls(b1, b2)
    # rejection-sample points in the intersection
    pts = c1 + r.normal(size=(2000, dim)) * r1 / np.sqrt(dim)
    in1 = np.linalg.norm(pts - c1, axis=1) <= r1
    in2 = np.linalg.norm(pts - c2, axis=1) <= r2
    both = pts[in1 & in2]
    if len(both):
        d = np.linalg.norm(both - np.asarray(cover.center), axis=1)
        assert (d <= float(cover.radius) * (1 + 1e-9)).all()
    # the cover never exceeds the smaller ball
    assert float(cover.radius) <= min(r1, r2) * (1 + 1e-9)


def test_feasible_dual_is_feasible(rng):
    for name in ("least_squares", "logistic"):
        loss = get_loss(name)
        X, y, _ = make_regression(rng, n=30, p=80)
        if name == "logistic":
            y = np.sign(y)
            y[y == 0] = 1.0
        X, y = jnp.asarray(X), jnp.asarray(y)
        lam = 0.2 * float(lambda_max(loss, X, y))
        beta = jnp.asarray(rng.normal(size=X.shape[1]) * 0.01)
        hat = -loss.grad(X @ beta, y) / lam
        theta = feasible_dual(loss, X, y, hat, lam)
        assert float(jnp.max(jnp.abs(X.T @ theta))) <= 1.0 + 1e-9
        # dual objective is finite at the projected point
        assert np.isfinite(float(loss.dual_objective(y, theta, lam)))


def test_gap_nonnegative_at_feasible_pairs(rng):
    loss = get_loss("least_squares")
    X, y, _ = make_regression(rng, n=30, p=80)
    X, y = jnp.asarray(X), jnp.asarray(y)
    lam = 0.3 * float(lambda_max(loss, X, y))
    for scale in (0.0, 0.001, 0.01):
        beta = jnp.asarray(rng.normal(size=X.shape[1]) * scale)
        hat = -loss.grad(X @ beta, y) / lam
        theta = feasible_dual(loss, X, y, hat, lam)
        gap = duality_gap(loss, X, y, beta, theta, lam)
        assert float(gap) >= -1e-9
