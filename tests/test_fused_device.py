"""Device-native fused-LASSO subsystem tests (DESIGN.md §7).

Property tests: the chain-graph device transforms (the Pallas suffix-sum
kernel and the level-schedule ``lax.scan``) must match the dense numpy
``transform_design`` BITWISE on random designs — both are exact right
folds, so any deviation is a real indexing/carry bug, not float noise.
General trees (multiple children per level) agree to re-association only.
Plus the fused path-engine guarantees (one compilation per grid, warm ==
cold active sets) and the general-loss (logistic) end-to-end solve.

On this CPU container the Pallas kernel runs in interpret mode (f64, so
the bitwise claim is exact-grade); on TPU the same entry point compiles.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SaifConfig, build_schedule, build_tree,
                        fused_baseline_cm, fused_lambda_max,
                        fused_objective, fused_path, recover_beta,
                        recover_beta_device, saif_fused,
                        saif_fused_eliminated, transform_design,
                        transform_design_device, transform_design_scan)
from repro.kernels.ops import chain_suffix_sums, chain_suffix_sums_ref


def _support(beta, tol=1e-8):
    return set(np.where(np.abs(np.asarray(beta)) > tol)[0].tolist())


def _chain_parent(p):
    return np.arange(p) - 1


def _random_tree_parent(rng, p):
    parent = np.full(p, -1, np.int64)
    for v in range(1, p):
        parent[v] = rng.integers(0, v)
    return parent


# --------------------------------------------------------------------------
# device-transform parity (satellite: bitwise on chains, both device paths)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("n,p", [(9, 12), (33, 300), (16, 257), (8, 128)])
def test_chain_transform_bitwise_pallas_and_scan(seed, n, p):
    """Property: both device paths == dense numpy bit for bit on random
    chain designs, including shapes that exercise the kernel's row/column
    padding (p % bp != 0, n % 8 != 0)."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, p))
    tree = build_tree(_chain_parent(p))
    Xb_ref, xb_ref = transform_design(X, tree)

    Xb_s, xb_s = transform_design_scan(X, tree)
    assert np.array_equal(np.asarray(Xb_s), Xb_ref)
    assert np.array_equal(np.asarray(xb_s), xb_ref)

    S = chain_suffix_sums(jnp.asarray(X))      # interpret on CPU
    assert np.array_equal(np.asarray(S[:, 1:]), Xb_ref)
    assert np.array_equal(np.asarray(S[:, 0]), xb_ref)

    # and the jnp reference fold agrees with itself through the dispatcher
    Xb_d, xb_d = transform_design_device(X, tree, backend="pallas")
    assert np.array_equal(np.asarray(Xb_d), Xb_ref)
    assert np.array_equal(np.asarray(xb_d), xb_ref)
    Sr = chain_suffix_sums_ref(jnp.asarray(X))
    assert np.array_equal(np.asarray(Sr), np.asarray(S))


@pytest.mark.parametrize("seed", [0, 3])
@pytest.mark.parametrize("p", [2, 17, 60])
def test_tree_transform_scan_matches_numpy(seed, p):
    """General trees: level-schedule scan == numpy to fp re-association
    (several children can share a parent within one level)."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(14, p))
    tree = build_tree(_random_tree_parent(rng, p))
    Xb_ref, xb_ref = transform_design(X, tree)
    Xb_s, xb_s = transform_design_scan(X, tree)
    np.testing.assert_allclose(np.asarray(Xb_s), Xb_ref,
                               rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray(xb_s), xb_ref,
                               rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("seed", [0, 1])
def test_recover_beta_device_bitwise(seed):
    """recover_beta_device == numpy recover_beta bitwise on ANY tree:
    one add per node, identical order (no re-association anywhere)."""
    rng = np.random.default_rng(seed)
    for p in (2, 13, 41):
        for parent in (_chain_parent(p), _random_tree_parent(rng, p)):
            tree = build_tree(parent)
            bt = rng.normal(size=p - 1)
            b = float(rng.normal())
            dev = recover_beta_device(jnp.asarray(bt), b, tree)
            ref = recover_beta(bt, b, tree)
            assert np.array_equal(np.asarray(dev), ref)


def test_schedule_chain_detection():
    assert build_schedule(build_tree(_chain_parent(20))).is_chain
    rng = np.random.default_rng(0)
    assert not build_schedule(
        build_tree(_random_tree_parent(rng, 20))).is_chain
    with pytest.raises(ValueError):
        transform_design_device(np.zeros((3, 20)),
                                build_tree(_random_tree_parent(rng, 20)),
                                backend="pallas")


# --------------------------------------------------------------------------
# unpenalized-slot solver path (Thm 7 without elimination)
# --------------------------------------------------------------------------

def test_slot_matches_exact_elimination_ls():
    """The always-resident unpenalized slot == Theorem 7's exact LS
    elimination (the legacy route, kept as the parity oracle)."""
    rng = np.random.default_rng(7)   # dedicated: order-independent data
    n, p = 40, 30
    X = rng.normal(size=(n, p))
    beta_true = np.zeros(p)
    beta_true[:10] = 1.5
    y = X @ beta_true + 0.1 * rng.normal(size=n)
    parent = _random_tree_parent(rng, p)
    for lam in (2.0, 10.0):
        b_slot, res = saif_fused(X, y, parent, lam, SaifConfig(eps=1e-10))
        b_elim, _ = saif_fused_eliminated(X, y, parent, lam,
                                          SaifConfig(eps=1e-10))
        o_s = fused_objective(X, y, parent, b_slot, lam)
        o_e = fused_objective(X, y, parent, b_elim, lam)
        assert float(res.gap) <= 1e-10
        assert abs(o_s - o_e) <= 1e-6 * max(abs(o_e), 1)
        np.testing.assert_allclose(np.asarray(b_slot), b_elim, atol=1e-4)


def test_fused_logistic_end_to_end():
    """Acceptance: fused logistic regression solves with duality gap <=
    eps and matches the unscreened general-loss baseline's objective.
    (Dedicated rng: this must not depend on fixture stream order.)"""
    rng = np.random.default_rng(48)  # historically adversarial draw: the
    # pre-polish dual produced a NEGATIVE gap here (DESIGN.md §7)
    n, p = 50, 40
    X = rng.normal(size=(n, p))
    beta_true = np.zeros(p)
    beta_true[:8] = 2.0
    y = np.sign(X @ beta_true + 0.3 * rng.normal(size=n))
    y[y == 0] = 1.0
    parent = _chain_parent(p)
    lmax = fused_lambda_max(X, y, parent, loss="logistic")
    eps = 1e-8
    for frac in (0.3, 0.1):
        lam = frac * lmax
        beta, res = saif_fused(X, y, parent, lam,
                               SaifConfig(eps=eps, loss="logistic"))
        # a NEGATIVE gap means the dual point left Omega (the pre-polish
        # failure mode): the reported gap must be a genuine certificate
        assert -1e-12 <= float(res.gap) <= eps
        o_s = fused_objective(X, y, parent, beta, lam, loss="logistic")
        base = fused_baseline_cm(X, y, parent, lam, tol=1e-10,
                                 loss="logistic")
        o_b = fused_objective(X, y, parent, base, lam, loss="logistic")
        assert o_s <= o_b + 1e-6 * max(abs(o_b), 1)


def test_fused_lambda_max_fuses_everything():
    """Above the fused lambda_max every coefficient collapses to b* —
    confirms the unpenalized-null c0 (not |X^T f'(0)|) is the right
    grid anchor."""
    rng = np.random.default_rng(3)
    n, p = 30, 20
    X = rng.normal(size=(n, p))
    y = rng.normal(size=n)
    parent = _chain_parent(p)
    lmax = fused_lambda_max(X, y, parent)
    beta, _ = saif_fused(X, y, parent, 1.01 * lmax,
                         config=SaifConfig(eps=1e-10))
    assert np.ptp(np.asarray(beta)) <= 1e-6
    beta2, _ = saif_fused(X, y, parent, 0.5 * lmax,
                          config=SaifConfig(eps=1e-10))
    assert np.ptp(np.asarray(beta2)) > 1e-6       # below it, edges activate


def test_warm_start_never_truncates_unpen_slot():
    """A capacity-full warm support that lacks b must still pin b resident:
    the driver PREPENDS the unpenalized slot before truncating to k_max
    (appending let a full warm support silently slice it off)."""
    from repro.core import saif
    from repro.core.duality import null_gradient
    from repro.core.losses import get_loss

    rng = np.random.default_rng(2)
    n, p = 30, 300
    X = jnp.asarray(rng.normal(size=(n, p)))
    y = jnp.asarray(rng.normal(size=n))
    _, c0, _ = null_gradient(get_loss("least_squares"), X, y, p - 1)
    lam = 0.8 * float(jnp.max(c0))     # near lam_max => h small => k_max 64
    cfg = SaifConfig(eps=1e-9, unpen_idx=p - 1)
    res = saif(X, y, lam, cfg,
               warm_idx=jnp.arange(64),          # fills capacity, no b
               warm_beta=jnp.zeros(64))
    final = set(np.asarray(res.active_idx)[np.asarray(res.active_mask)]
                .tolist())
    assert p - 1 in final                        # b survived the handoff
    assert float(res.gap) <= 1e-9


# --------------------------------------------------------------------------
# fused path engine (compile-first guarantees on the transformed problem)
# --------------------------------------------------------------------------

def _fused_grid(X, y, parent, n_lams=6, hi=0.7, lo=0.02):
    lmax = fused_lambda_max(X, y, parent)
    return np.geomspace(hi * lmax, lo * lmax, n_lams)


def _path_problem():
    rng = np.random.default_rng(11)
    n, p = 50, 60
    X = rng.normal(size=(n, p))
    beta_true = np.zeros(p)
    beta_true[:10] = 2.0
    beta_true[10:20] = -1.0
    y = X @ beta_true + 0.1 * rng.normal(size=n)
    return X, y, _chain_parent(p)


def test_fused_path_warm_equals_cold():
    """Satellite: fused_path (slot-preserving warm starts, b pinned) lands
    on the same transformed-space active sets as cold per-lambda solves."""
    X, y, parent = _path_problem()
    lams = _fused_grid(X, y, parent)
    cfg = SaifConfig(eps=1e-8)
    fp = fused_path(X, y, parent, lams, cfg)
    for lam, beta_t, beta_node in zip(fp.lams, fp.path.betas, fp.betas):
        beta_c, res_c = saif_fused(X, y, parent, float(lam), cfg)
        assert _support(beta_t) == _support(res_c.beta)      # warm == cold
        # coefficients agree to solver accuracy (both gaps <= eps)
        np.testing.assert_allclose(np.asarray(beta_node),
                                   np.asarray(beta_c), atol=1e-4)


def test_fused_path_compiles_once():
    """Acceptance: one _saif_jit compilation serves the whole fused grid
    (same assertion style as test_screen_parity's path compile count).
    The problem shape is unique to this test so the count is exactly the
    fresh compile of this grid, not a cache hit from a neighbour test."""
    rng = np.random.default_rng(23)
    n, p = 44, 72
    X = rng.normal(size=(n, p))
    beta_true = np.zeros(p)
    beta_true[: p // 4] = 2.0
    y = X @ beta_true + 0.1 * rng.normal(size=n)
    parent = _chain_parent(p)
    lams = _fused_grid(X, y, parent, n_lams=8)
    fp = fused_path(X, y, parent, lams, SaifConfig(eps=1e-7))
    if fp.path.n_compilations is None:
        pytest.skip("jit cache-size counter unavailable on this jax")
    assert fp.path.n_compilations == 1
    assert len(fp.betas) == 8


def test_fused_path_matches_baseline_objective():
    """Every grid point's objective == the unscreened fused CM baseline."""
    X, y, parent = _path_problem()
    lams = _fused_grid(X, y, parent, n_lams=4)
    fp = fused_path(X, y, parent, lams, SaifConfig(eps=1e-10))
    for lam, beta in zip(fp.lams, fp.betas):
        base = fused_baseline_cm(X, y, parent, float(lam), tol=1e-12)
        o_s = fused_objective(X, y, parent, beta, float(lam))
        o_b = fused_objective(X, y, parent, base, float(lam))
        assert abs(o_s - o_b) <= 1e-6 * max(abs(o_b), 1.0)


def test_fused_transform_backends_identical_solutions():
    """pallas- and scan-transformed designs are bitwise equal, so the
    downstream SAIF solves are too."""
    rng = np.random.default_rng(15)
    n, p = 30, 50
    X = rng.normal(size=(n, p))
    y = rng.normal(size=n)
    parent = _chain_parent(p)
    lam = 0.3 * fused_lambda_max(X, y, parent)
    b1, r1 = saif_fused(X, y, parent, lam, SaifConfig(eps=1e-9),
                        transform_backend="pallas")
    b2, r2 = saif_fused(X, y, parent, lam, SaifConfig(eps=1e-9),
                        transform_backend="scan")
    assert np.array_equal(np.asarray(b1), np.asarray(b2))
    assert int(r1.n_outer) == int(r2.n_outer)
