"""Batch engine parity: a fleet solve IS B serial solves, bit for bit.

The acceptance contract of ``core/batch.py`` (DESIGN.md §8):

  * supports, coefficients, slot layouts, gaps, traces and outer-iteration
    counts of ``saif_batch(B)`` are bitwise those of B independent serial
    ``saif`` calls — across the screen x inner backend grid;
  * the whole fleet runs in exactly ONE ``_saif_batch_jit`` compilation;
  * per-problem early finish: a fast problem's trajectory is untouched by
    a straggler sharing its fleet;
  * capacity overflow in one problem grows the fleet but leaves every
    problem's answers bitwise-identical to its serial solve;
  * CV fleets (sample-weight masking) equal serial solves on the
    row-subsampled design.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SaifConfig, cv_path, get_loss, kfold_weights, saif,
                        saif_batch, saif_batch_compile_count,
                        saif_jit_compile_count)
from repro.core.batch import resolve_batch_inner
from repro.core.duality import lambda_max
from repro.core.screen_backend import make_batch_screen_distinct


def _fleet(rng, n, p, b, frac_lo=0.05, frac_hi=0.4, loss_name="least_squares"):
    loss = get_loss(loss_name)
    X = rng.uniform(-10, 10, (n, p))
    Ys, lams = [], []
    for i in range(b):
        w = np.zeros(p)
        w[rng.choice(p, max(p // 15, 3), replace=False)] = rng.normal(
            size=max(p // 15, 3))
        if loss_name == "logistic":
            y = np.sign(X @ w + 0.3 * rng.normal(size=n))
            y[y == 0] = 1.0
        else:
            y = X @ w + 0.5 * rng.normal(size=n)
        frac = frac_lo + (frac_hi - frac_lo) * i / max(b - 1, 1)
        lams.append(frac * float(lambda_max(loss, jnp.asarray(X),
                                            jnp.asarray(y))))
        Ys.append(y)
    return X, np.stack(Ys), lams


def _assert_bitwise(res, serial, b):
    """Fleet row b must equal the serial result byte for byte."""
    assert bool(jnp.all(res.beta[b] == serial.beta))
    assert bool(res.gap[b] == serial.gap)
    assert int(res.n_outer[b]) == int(serial.n_outer)
    assert int(res.n_active[b]) == int(serial.n_active)
    assert bool(res.overflowed[b]) == bool(serial.overflowed)
    assert bool(jnp.all(res.trace_gap[b] == serial.trace_gap))
    assert bool(jnp.all(res.trace_n_active[b] == serial.trace_n_active))
    if res.active_idx.shape[1] == serial.active_idx.shape[0]:
        # same capacity => the slot layout itself must agree exactly
        assert bool(jnp.all(res.active_idx[b] == serial.active_idx))
        assert bool(jnp.all(res.active_mask[b] == serial.active_mask))


@pytest.mark.parametrize("screen,inner", [
    ("jnp", "jnp"), ("jnp", "gram"), ("pallas", "jnp"),
    ("jnp", "pallas"), ("pallas", "gram"), ("pallas", "pallas"),
])
def test_fleet_bitwise_parity_backend_grid(screen, inner):
    """All screen x inner combos: fleet == B serial solves, bitwise."""
    heavy = "pallas" in (screen, inner)     # interpret mode is slow on CPU
    n, p, b = (30, 80, 2) if heavy else (40, 150, 4)
    X, Y, lams = _fleet(np.random.default_rng(0), n, p, b)
    cfg = SaifConfig(eps=1e-7, screen_backend=screen, inner_backend=inner)
    res = saif_batch(X, Y, jnp.asarray(lams), cfg)
    for i in range(b):
        _assert_bitwise(res, saif(X, Y[i], lams[i], cfg), i)


def test_fleet_single_compilation():
    """One fleet = exactly one ``_saif_batch_jit`` compilation, counted by
    both the batch counter and the unified solver-core counter."""
    X, Y, lams = _fleet(np.random.default_rng(1), 35, 100, 3)
    cfg = SaifConfig(eps=1e-7, inner_backend="gram")
    saif_batch(X, Y, jnp.asarray(lams), cfg)        # warm the cache
    c0b, c0u = saif_batch_compile_count(), saif_jit_compile_count()
    res = saif_batch(X, Y, jnp.asarray(lams), cfg)  # cached: 0 new
    assert bool(jnp.all(res.gap <= 1e-7))
    if c0b >= 0:
        assert saif_batch_compile_count() - c0b == 0
    # a fresh fleet signature (different B) adds exactly 1 compilation
    res2 = saif_batch(X, Y[:2], jnp.asarray(lams[:2]), cfg)
    assert not bool(jnp.any(res2.overflowed))
    if c0b >= 0:
        assert saif_batch_compile_count() - c0b == 1
        assert saif_jit_compile_count() - c0u == 1


def test_fleet_early_finish_is_isolated():
    """A straggler must not perturb an early-finishing problem: its
    per-problem n_outer, gap and full traces stay bitwise-serial even
    though the fleet keeps iterating long after it froze."""
    rng = np.random.default_rng(2)
    n, p = 40, 120
    X = rng.uniform(-10, 10, (n, p))
    loss = get_loss("least_squares")
    w = np.zeros(p)
    w[rng.choice(p, 10, replace=False)] = rng.normal(size=10)
    y = X @ w + 0.5 * rng.normal(size=n)
    lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    # problem 0: trivial (converges in a handful of steps); problem 1:
    # deep solve (tiny lambda + tight eps => many more outer steps)
    lams = [0.8 * lmax, 0.02 * lmax]
    Y = np.stack([y, y])
    cfg = SaifConfig(eps=1e-9, inner_backend="gram")
    res = saif_batch(X, Y, jnp.asarray(lams), cfg)
    s_fast = saif(X, y, lams[0], cfg)
    s_slow = saif(X, y, lams[1], cfg)
    assert int(res.n_outer[1]) > int(res.n_outer[0])     # genuine straggler
    _assert_bitwise(res, s_fast, 0)
    _assert_bitwise(res, s_slow, 1)


def test_fleet_mixed_convergence_logistic():
    """Mixed-loss-landscape fleet (logistic, heterogeneous lambdas):
    per-problem convergence masks keep every trajectory serial-exact."""
    X, Y, lams = _fleet(np.random.default_rng(3), 40, 100, 3,
                        frac_lo=0.1, frac_hi=0.5, loss_name="logistic")
    cfg = SaifConfig(eps=1e-7, loss="logistic", inner_backend="jnp")
    res = saif_batch(X, Y, jnp.asarray(lams), cfg)
    for i in range(3):
        _assert_bitwise(res, saif(X, Y[i], lams[i], cfg), i)


def test_fleet_overflow_isolated_to_one_problem():
    """A tiny capacity forces one problem (the smallest lambda) through
    the elastic-growth recompile; every problem — including the ones that
    never overflowed — still reproduces its serial solve bitwise."""
    rng = np.random.default_rng(4)
    n, p = 40, 150
    X = rng.uniform(-10, 10, (n, p))
    loss = get_loss("least_squares")
    w = np.zeros(p)
    w[rng.choice(p, 20, replace=False)] = rng.normal(size=20)
    y = X @ w + 0.5 * rng.normal(size=n)
    lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    lams = [0.6 * lmax, 0.03 * lmax]        # only the second overflows k=8
    Y = np.stack([y, y])
    cfg = SaifConfig(eps=1e-7, k_max=8, inner_backend="gram")
    res = saif_batch(X, Y, jnp.asarray(lams), cfg)
    assert not bool(res.overflowed[0]) or not bool(res.overflowed[1])
    for i in range(2):
        serial = saif(X, y, lams[i], cfg)
        assert bool(jnp.all(res.beta[i] == serial.beta))
        assert bool(res.gap[i] == serial.gap)


def test_fleet_distinct_x_screen_fallback():
    """The distinct-X screen (per-problem designs, batch-dim einsum) is a
    drop-in ScreenFn for the engine and stays bitwise with serial."""
    X, Y, lams = _fleet(np.random.default_rng(5), 30, 90, 3)
    b = Y.shape[0]
    cfg = SaifConfig(eps=1e-7, inner_backend="jnp")
    Xs = jnp.broadcast_to(jnp.asarray(X), (b,) + X.shape)
    cn = jnp.linalg.norm(jnp.asarray(X), axis=0)
    from repro.core.batch import fleet_batch_sizes, prepare_fleet
    prep = prepare_fleet(X, Y, cfg)
    _, h = fleet_batch_sizes(prep, lams, cfg)
    screen_fn = make_batch_screen_distinct(
        Xs, jnp.broadcast_to(cn, (b, X.shape[1])), h)
    res = saif_batch(X, Y, jnp.asarray(lams), cfg, screen_fn=screen_fn)
    for i in range(b):
        _assert_bitwise(res, saif(X, Y[i], lams[i], cfg), i)


@pytest.mark.parametrize("inner", ["jnp", "gram"])
def test_weighted_fleet_equals_subsampled_serial(inner):
    """The CV sample-weight trick: a binary-weighted fleet problem equals
    the serial solve on the weight-1 rows (support exactly; coefficients
    to reduction-order tolerance — summing explicit zero rows re-brackets
    the reductions, so this one is allclose, not bitwise)."""
    rng = np.random.default_rng(6)
    n, p, K = 48, 120, 3
    X = rng.uniform(-10, 10, (n, p))
    loss = get_loss("least_squares")
    w = np.zeros(p)
    w[rng.choice(p, 10, replace=False)] = rng.normal(size=10)
    y = X @ w + 0.5 * rng.normal(size=n)
    W = np.asarray(kfold_weights(n, K, seed=0))
    lam = 0.15 * float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    cfg = SaifConfig(eps=1e-8, inner_backend=inner, use_seq_ball=False)
    res = saif_batch(X, np.broadcast_to(y, (K, n)), lam, cfg,
                     weights=jnp.asarray(W))
    for k in range(K):
        tr = W[k] > 0
        ref = saif(X[tr], y[tr], lam, cfg)
        assert np.array_equal(np.abs(np.asarray(res.beta[k])) > 1e-8,
                              np.abs(np.asarray(ref.beta)) > 1e-8)
        assert np.allclose(np.asarray(res.beta[k]), np.asarray(ref.beta),
                           atol=1e-9)
        assert float(res.gap[k]) <= 1e-8


def test_cv_path_selects_and_refits():
    """cv_path: one compilation for the K x L grid, fold solutions match
    subsampled serial solves, and the winner is refit on the full data."""
    rng = np.random.default_rng(7)
    n, p = 60, 140
    X = rng.uniform(-10, 10, (n, p))
    loss = get_loss("least_squares")
    w = np.zeros(p)
    w[rng.choice(p, 8, replace=False)] = rng.normal(size=8)
    y = X @ w + 0.5 * rng.normal(size=n)
    lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    lams = np.geomspace(0.8 * lmax, 0.05 * lmax, 5)
    cfg = SaifConfig(eps=1e-8, inner_backend="gram")
    res = cv_path(X, y, lams, n_folds=4, config=cfg, keep_fold_betas=True)
    assert res.n_compilations is None or res.n_compilations == 1
    assert res.cv_mean.shape == (5,)
    assert float(res.best_lam) in [float(l) for l in res.lams]
    assert res.beta is not None and res.beta.shape == (p,)
    # decreasing lambda must not worsen in-range CV fit catastrophically;
    # spot-check one (fold, lambda) cell against the subsampled oracle
    W = np.asarray(kfold_weights(n, 4, seed=0))
    tr = W[1] > 0
    ref = saif(X[tr], y[tr], float(res.lams[2]),
               SaifConfig(eps=1e-8, inner_backend="gram",
                          use_seq_ball=False))
    fb = np.asarray(res.fold_betas[2][1])
    assert np.array_equal(np.abs(fb) > 1e-8,
                          np.abs(np.asarray(ref.beta)) > 1e-8)
    assert np.allclose(fb, np.asarray(ref.beta), atol=1e-9)


def test_resolve_batch_inner_policy():
    """Fleet inner policy: auto == serial policy with the fleet VMEM
    budget; invalid combinations are rejected at resolve time."""
    cfg = SaifConfig()
    assert resolve_batch_inner(cfg, n=100, k_max=256, b=16) == "gram"
    assert resolve_batch_inner(
        SaifConfig(loss="logistic"), n=100, k_max=256, b=16) == "jnp"
    with pytest.raises(ValueError, match="least_squares"):
        resolve_batch_inner(
            SaifConfig(loss="logistic", inner_backend="gram"),
            n=100, k_max=256, b=16)
    with pytest.raises(ValueError, match="VMEM"):
        resolve_batch_inner(
            SaifConfig(inner_backend="pallas"),
            n=4096, k_max=4096, b=16)
    with pytest.raises(ValueError, match="unknown"):
        resolve_batch_inner(SaifConfig(inner_backend="bogus"),
                            n=10, k_max=8, b=2)


def test_fleet_rejects_fused_problems():
    with pytest.raises(NotImplementedError):
        saif_batch(np.eye(4), np.ones((2, 4)), 0.1,
                   SaifConfig(unpen_idx=0))
