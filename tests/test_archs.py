"""Per-architecture smoke tests (reduced configs, CPU, deliverable f).

Each assigned arch: one forward/train step asserting output shapes + no NaNs,
plus decode-vs-train parity (the strongest single check of the KV-cache /
recurrent-state serving path vs the chunked/parallel training path).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, runnable_cells, smoke_config
from repro.models import (decode_step, fill_cross_cache, init,
                          init_decode_state, train_loss)
from repro.models.lm import backbone, logits_fn


def _batch(cfg, B, S, key=1):
    toks = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.family == "vlm":
        batch["img_embed"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_image_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = smoke_config(arch).scaled(dtype="float32")
    params = init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, B=2, S=32)
    loss, grads = jax.value_and_grad(train_loss)(params, batch, cfg)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat)
    # gradient step reduces loss (lr small)
    params2 = jax.tree.map(lambda p, g: p - 0.05 * g, params, grads)
    loss2 = train_loss(params2, batch, cfg)
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_train_forward(arch):
    cfg = smoke_config(arch).scaled(dtype="float32", remat=False,
                                    capacity_factor=64.0)  # no-drop MoE
    params = init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    hidden, _ = backbone(params, batch["tokens"], cfg,
                         img_embed=batch.get("img_embed"),
                         frames=batch.get("frames"))
    full = logits_fn(params, hidden, cfg)
    st = init_decode_state(params, cfg, B, S)
    st = fill_cross_cache(params, cfg, st,
                          img_embed=batch.get("img_embed"),
                          frames=batch.get("frames"))
    worst = 0.0
    for t in range(S):
        lg, st = decode_step(params, batch["tokens"][:, t], st, cfg)
        assert lg.shape == (B, cfg.vocab)
        worst = max(worst, float(jnp.max(jnp.abs(lg - full[:, t]))))
    scale = float(jnp.max(jnp.abs(full))) + 1.0
    assert worst <= 2e-4 * scale, f"decode/train divergence {worst}"


def test_sliding_window_ring_buffer_long_decode():
    """Hybrid arch decodes past the window with a ring KV cache and stays
    consistent with a full-context forward truncated to the window."""
    cfg = smoke_config("hymba_1_5b").scaled(dtype="float32", remat=False,
                                            window=8, ssm_chunk=8)
    params = init(jax.random.PRNGKey(0), cfg)
    B, S = 1, 24   # 3x window
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    hidden, _ = backbone(params, toks, cfg)
    full = logits_fn(params, hidden, cfg)
    st = init_decode_state(params, cfg, B, S)
    worst = 0.0
    for t in range(S):
        lg, st = decode_step(params, toks[:, t], st, cfg)
        worst = max(worst, float(jnp.max(jnp.abs(lg - full[:, t]))))
    scale = float(jnp.max(jnp.abs(full))) + 1.0
    assert worst <= 2e-4 * scale
    # cache really is window-sized (sub-quadratic memory)
    assert st.caches["kv"].k.shape[2] == cfg.window


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_shapes(arch):
    """Full (unreduced) configs must build their shape tree & param count."""
    from repro.models.lm import param_shapes
    cfg = get_config(arch)
    tree = param_shapes(cfg)
    n = sum(int(np.prod(s)) for s in jax.tree.leaves(
        tree, is_leaf=lambda x: isinstance(x, tuple)))
    expected = {
        "stablelm_3b": 3e9, "deepseek_7b": 7e9, "nemotron_4_15b": 15e9,
        "glm4_9b": 9e9, "hymba_1_5b": 1.5e9, "xlstm_350m": 350e6,
        "qwen3_moe_30b_a3b": 30e9, "dbrx_132b": 132e9,
        "whisper_tiny": 39e6, "llama_3_2_vision_11b": 11e9,
    }[arch]
    assert 0.2 * expected < n < 5 * expected, f"{arch}: {n/1e9:.2f}B params"


def test_runnable_cells_inventory():
    cells = runnable_cells()
    assert len(cells) == 40
    skips = [c for c in cells if c[2] != "run"]
    # long_500k skipped for the 8 non-sub-quadratic archs
    assert len(skips) == 8
    assert all(c[1] == "long_500k" for c in skips)
