"""Dynamic screening, sequential (DPP) path, and unsafe-homotopy baselines."""
import jax.numpy as jnp
import numpy as np

from repro.core import (DynConfig, HomotopyConfig, SaifConfig, dynamic_screening,
                        get_loss, homotopy_path, lambda_grid, saif,
                        sequential_path, solve_lasso_cm, support_metrics)
from repro.core.duality import lambda_max

from conftest import kkt_violation, make_regression


def _support(beta, tol=1e-8):
    return np.where(np.abs(np.asarray(beta)) > tol)[0]


def test_dynamic_screening_exact(rng):
    loss = get_loss("least_squares")
    X, y, _ = make_regression(rng, n=40, p=200)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    lam = 0.1 * float(lambda_max(loss, Xj, yj))
    res = dynamic_screening(X, y, lam, DynConfig(eps=1e-9))
    assert kkt_violation(loss, Xj, yj, res.beta, lam) <= 1e-4 * lam
    # screening monotonically shrinks the survivors
    assert res.survivor_history == sorted(res.survivor_history, reverse=True)
    assert res.survivor_history[-1] < res.survivor_history[0]


def test_dynamic_screening_never_kills_true_support(rng):
    loss = get_loss("least_squares")
    X, y, _ = make_regression(rng, n=40, p=200)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    lam = 0.05 * float(lambda_max(loss, Xj, yj))
    res = dynamic_screening(X, y, lam, DynConfig(eps=1e-9))
    beta_ref = solve_lasso_cm(loss, Xj, yj, lam, tol=1e-11)
    assert set(_support(res.beta)) == set(_support(beta_ref))


def test_sequential_path_exact_and_screens(rng):
    loss = get_loss("least_squares")
    X, y, _ = make_regression(rng, n=40, p=180)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    lmax = float(lambda_max(loss, Xj, yj))
    lams = lambda_grid(lmax, 6, lo_frac=0.05)
    res = sequential_path(X, y, lams, )
    for lam, beta in zip(res.lams, res.betas):
        assert kkt_violation(loss, Xj, yj, beta, lam) <= 1e-4 * lam
    # with a fine path, screening should actually remove features sometimes
    assert max(res.screened_frac) > 0.2


def test_homotopy_unsafe_vs_safe(rng):
    """Table 1: the unsafe homotopy can miss/keep-wrong features; the
    KKT-checked variant recovers the exact support."""
    loss = get_loss("least_squares")
    X, y, _ = make_regression(rng, n=40, p=200)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    lmax = float(lambda_max(loss, Xj, yj))
    # start below lambda_max: at the boundary the support is threshold-fuzzy
    lams = lambda_grid(0.8 * lmax, 8, lo_frac=0.02)

    safe = homotopy_path(X, y, lams, HomotopyConfig(eps=1e-9, kkt_check=True))
    unsafe = homotopy_path(X, y, lams, HomotopyConfig(eps=1e-9,
                                                      kkt_check=False))
    recalls, precisions = [], []
    for lam, sup_s, sup_u in zip(lams, safe.supports, unsafe.supports):
        ref = solve_lasso_cm(loss, Xj, yj, float(lam), tol=1e-11)
        ref_sup = _support(ref)
        r_safe, p_safe = support_metrics(sup_s, ref_sup)
        assert r_safe == 1.0 and p_safe == 1.0
        r_u, p_u = support_metrics(sup_u, ref_sup)
        recalls.append(r_u)
        precisions.append(p_u)
    # the unsafe variant must be *capable* of being wrong in this regime —
    # but even when it gets lucky it never beats safe, and metrics are <= 1
    assert all(r <= 1.0 for r in recalls) and all(p <= 1.0 for p in precisions)


def test_saif_vs_dynamic_same_answer(rng):
    loss = get_loss("least_squares")
    X, y, _ = make_regression(rng, n=40, p=250)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    lam = 0.08 * float(lambda_max(loss, Xj, yj))
    b1 = saif(X, y, lam, SaifConfig(eps=1e-9)).beta
    b2 = dynamic_screening(X, y, lam, DynConfig(eps=1e-9)).beta
    assert np.allclose(np.asarray(b1), np.asarray(b2), atol=1e-5)


def test_greedy_homotopy_actually_fails(rng):
    """Table 1's phenomenon: the truncated pathwise active-set policy
    misses true actives / keeps spurious ones; the safe variant does not."""
    import numpy as np
    r = np.random.default_rng(7)
    n, p, k = 60, 300, 25
    F = r.normal(size=(p, 8))
    X = r.normal(size=(n, 8)) @ F.T + 0.3 * r.normal(size=(n, p))
    X = (X - X.mean(0)) / X.std(0)
    w = np.zeros(p)
    w[r.choice(p, k, replace=False)] = r.normal(size=k)
    y = X @ w + 0.5 * r.normal(size=n)
    loss = get_loss("least_squares")
    lmax = float(lambda_max(loss, jnp.asarray(X), jnp.asarray(y)))
    lams = np.geomspace(0.5 * lmax, 0.005 * lmax, 4)
    greedy = homotopy_path(X, y, lams,
                           HomotopyConfig(eps=1e-8, greedy_cap=6))
    safe = homotopy_path(X, y, lams,
                         HomotopyConfig(eps=1e-8, kkt_check=True))
    rec_g, rec_s = [], []
    for lam, sg, ss in zip(lams, greedy.supports, safe.supports):
        ref = solve_lasso_cm(loss, jnp.asarray(X), jnp.asarray(y),
                             float(lam), tol=1e-10)
        rsup = _support(ref)
        rec_g.append(support_metrics(sg, rsup)[0])
        rec_s.append(support_metrics(ss, rsup)[0])
    assert min(rec_s) == 1.0          # safe variant exact
    assert np.mean(rec_g) < 0.9       # unsafe truncation misses features
