"""Property-testing compatibility layer.

Re-exports ``hypothesis`` (``given``/``settings``/``st``) when the package is
installed. When it is not (minimal CI images, hermetic containers), a small
deterministic fallback implements the subset of the strategy API this repo's
tests use — ``st.integers``, ``st.floats``, ``st.sampled_from``,
``st.booleans`` — by drawing a fixed number of seeded examples per test.

This keeps the tier-1 suite runnable everywhere: with hypothesis the tests
get real shrinking/coverage, without it they degrade to a deterministic
multi-example sweep instead of aborting collection with ImportError.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised implicitly when hypothesis is present
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import zlib

    import numpy as _np

    # Cap fallback examples: without shrinking, very large sweeps only cost
    # time; a dozen seeded draws keeps the property signal at CI speed.
    _FALLBACK_MAX_EXAMPLES = 12

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _StrategiesModule:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value=-1e6, max_value=1e6, allow_nan=False,
                   allow_infinity=False, **_ignored):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

    st = _StrategiesModule()

    def settings(max_examples=10, deadline=None, **_ignored):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = min(getattr(fn, "_compat_max_examples", 10),
                        _FALLBACK_MAX_EXAMPLES)
                seed = zlib.crc32(fn.__qualname__.encode("utf-8"))
                rng = _np.random.default_rng(seed)
                for _ in range(n):
                    draw = {k: s.example(rng) for k, s in strategies.items()}
                    fn(*args, **draw, **kwargs)
            # pytest must not unwrap to fn and see the strategy params as
            # missing fixtures
            del wrapper.__wrapped__
            return wrapper
        return deco


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
