"""Deterministic fault injection for the serving runtime (DESIGN.md §10).

Chaos testing a *compiled* solver needs a seam the injector can reach
without perturbing the compiled artifact itself: the host-side boundary
where a driver dispatches one compiled engine step. Every engine call
site (``core/saif.py::solve_scalar``, ``core/path.py``'s per-lambda
dispatch, ``core/batch.py::fleet_solve``) routes through :func:`seam`,
which is a single module-global ``is None`` check when disarmed — zero
overhead, zero new compilations, and byte-identical behavior on the
happy path.

Armed (``with FaultInjector(...):``), the injector keys on a global call
counter and deterministically

  * raises a transient ``RuntimeError`` *before* dispatch on chosen call
    indices — exactly how an XLA backend fault surfaces to the host
    (``fail_at``);
  * sleeps an artificial per-call delay — a straggling device step
    (``delay_at`` / ``delay_s``);
  * pokes NaN into the returned result's ``beta``/``gap`` — how a NaN
    born in the gradient pipeline of a faulty kernel surfaces at the
    host boundary (``nan_at``). The poke happens outside the compiled
    program, so the compiled artifact and its cache keys are untouched.

All schedules are either explicit index sets or derived from a seed via
:meth:`FaultInjector.from_seed` — runs are reproducible by construction.
This module imports no jax at module scope (the NaN poke imports it
lazily) so arming the seam costs nothing at import time.
"""
from __future__ import annotations

import time
from typing import Iterable, List, Optional, Tuple

import numpy as np

_ACTIVE: Optional["FaultInjector"] = None


def armed() -> Optional["FaultInjector"]:
    """The currently armed injector, or None (the steady state)."""
    return _ACTIVE


def seam(tag: str, fn):
    """Run one engine dispatch through the active injector.

    ``tag`` names the engine boundary (``"serial"`` / ``"path"`` /
    ``"fleet"``). Identity — one global None-check — when disarmed.
    """
    inj = _ACTIVE
    if inj is None:
        return fn()
    return inj.run(tag, fn)


def _poke_nan(out, unit: Optional[int] = None):
    """Corrupt a solver result the way an in-kernel NaN surfaces: NaN in
    the coefficients and the gap. Works on any result NamedTuple with
    ``beta``/``gap`` fields (serial SaifResult and fleet results alike);
    anything else is returned untouched. With ``unit`` set and a batched
    result (leading problem axis), only that one fleet member is
    poisoned — the blast radius a per-unit verdict must contain."""
    if not (hasattr(out, "_replace") and hasattr(out, "beta")
            and hasattr(out, "gap")):
        return out
    import jax.numpy as jnp
    beta = jnp.asarray(out.beta)
    gap = jnp.asarray(out.gap)
    nan = jnp.asarray(jnp.nan, beta.dtype)
    if unit is not None and beta.ndim >= 2 and gap.ndim >= 1:
        return out._replace(
            beta=beta.at[unit, ..., 0].set(nan),
            gap=gap.at[unit].set(jnp.asarray(jnp.nan, gap.dtype)))
    return out._replace(beta=beta.at[..., 0].set(nan),
                        gap=jnp.full_like(gap, jnp.nan))


class FaultInjector:
    """Seeded, deterministic fault schedule over the engine-call counter.

    ``fail_at`` / ``nan_at`` / ``delay_at`` are 1-based engine-call
    indices (the counter spans every seam, in dispatch order). ``tags``
    optionally restricts injection to specific seams (calls at other
    seams still advance the counter, keeping schedules stable when a
    request mixes engines). Use as a context manager::

        with FaultInjector(fail_at={1}):
            serving.solve(Scalar(lam))   # first engine call faults,
                                         # the retry path recovers
    """

    def __init__(self, *, fail_at: Iterable[int] = (),
                 nan_at: Iterable[int] = (),
                 delay_at: Iterable[int] = (), delay_s: float = 0.0,
                 nan_unit: Optional[int] = None,
                 tags: Optional[Iterable[str]] = None,
                 exc: type = RuntimeError,
                 message: str = "injected transient backend fault"):
        self.fail_at = {int(i) for i in fail_at}
        self.nan_at = {int(i) for i in nan_at}
        self.delay_at = {int(i) for i in delay_at}
        self.delay_s = float(delay_s)
        self.nan_unit = None if nan_unit is None else int(nan_unit)
        self.tags = None if tags is None else set(tags)
        self.exc = exc
        self.message = message
        self.calls = 0
        self.log: List[Tuple[int, str, str]] = []   # (call#, tag, action)

    @classmethod
    def from_seed(cls, seed: int, n_calls: int, *, p_fail: float = 0.0,
                  p_nan: float = 0.0, p_delay: float = 0.0,
                  delay_s: float = 0.0, **kw) -> "FaultInjector":
        """Derive a schedule over ``n_calls`` engine calls from a seed —
        the chaos suite's reproducible random sweep."""
        rng = np.random.default_rng(seed)
        draws = rng.random((3, n_calls))
        idx = np.arange(1, n_calls + 1)
        return cls(fail_at=idx[draws[0] < p_fail],
                   nan_at=idx[draws[1] < p_nan],
                   delay_at=idx[draws[2] < p_delay], delay_s=delay_s, **kw)

    def run(self, tag: str, fn):
        if self.tags is not None and tag not in self.tags:
            self.calls += 1
            return fn()
        self.calls += 1
        k = self.calls
        if k in self.delay_at and self.delay_s > 0.0:
            self.log.append((k, tag, "delay"))
            time.sleep(self.delay_s)
        if k in self.fail_at:
            self.log.append((k, tag, "fail"))
            raise self.exc(f"{self.message} (engine call {k}, {tag})")
        out = fn()
        if k in self.nan_at:
            self.log.append((k, tag, "nan"))
            out = _poke_nan(out, unit=self.nan_unit)
        return out

    # -- arming ---------------------------------------------------------
    def __enter__(self) -> "FaultInjector":
        global _ACTIVE
        if _ACTIVE is not None:
            raise RuntimeError("a FaultInjector is already armed")
        _ACTIVE = self
        return self

    def __exit__(self, *exc_info):
        global _ACTIVE
        _ACTIVE = None
        return False
