"""Fault-tolerance runtime: step retries, straggler detection, preemption.

The policies below are host-side and hardware-agnostic, so they are fully
unit-testable in this CPU container with injected fakes:

* ``retry_step`` — re-executes a step closure on transient failure
  (``jaxlib`` RuntimeError / timeout) with jittered exponential backoff,
  up to ``max_retries`` and an optional wall-clock ``deadline_s`` cap; on
  persistent failure raises ``StepFailed`` (``RetryDeadlineExceeded`` when
  the deadline, not the retry budget, ran out) so the caller restores the
  last checkpoint / escalates its degradation ladder.
* ``StragglerMonitor`` — tracks per-step wall times; flags a step as
  straggling when it exceeds ``factor`` x the trailing-median of the
  *non-straggling* recent steps (a flagged outlier is excluded from the
  median, so one straggler cannot inflate the threshold its successors
  are judged against). At scale the flag triggers the collective-timeout
  path (abort + restore + exclude the slow host from the next mesh —
  i.e. elastic downsize); here we surface it via a callback.
* ``PreemptionGuard`` — cooperative SIGTERM handling: sets a flag the
  serve/train loop polls to checkpoint-and-exit cleanly (how TPU pods
  signal preemption).
"""
from __future__ import annotations

import random
import signal
import statistics
import time
from typing import Callable, List, Optional, Tuple


class StepFailed(RuntimeError):
    pass


class RetryDeadlineExceeded(StepFailed):
    """The retry loop's wall-clock budget ran out before the step
    succeeded (distinct from exhausting ``max_retries``, so callers can
    map it onto a deadline-typed serving error)."""


def backoff_delay(attempt: int, base_s: float, mult: float, jitter: float,
                  rng: Optional[random.Random] = None) -> float:
    """Jittered exponential backoff: ``base * mult**(attempt-1)`` scaled
    by a uniform factor in ``[1-jitter, 1+jitter]`` (attempt counts from
    1). Deterministic under a seeded ``rng``."""
    if base_s <= 0.0:
        return 0.0
    delay = base_s * mult ** max(attempt - 1, 0)
    if jitter > 0.0:
        u = (rng.random() if rng is not None else random.random())
        delay *= 1.0 + jitter * (2.0 * u - 1.0)
    return max(delay, 0.0)


def retry_step(fn: Callable[[], object], *, max_retries: int = 2,
               retriable: tuple = (RuntimeError,),
               on_retry: Optional[Callable[[int, Exception], None]] = None,
               backoff_base_s: float = 0.0, backoff_mult: float = 2.0,
               jitter: float = 0.5, deadline_s: Optional[float] = None,
               rng: Optional[random.Random] = None,
               sleep: Callable[[float], None] = time.sleep,
               clock: Callable[[], float] = time.monotonic):
    """Run ``fn``; retry on transient device errors with jittered
    exponential backoff and a wall-clock deadline cap.

    ``backoff_base_s`` is the first retry's nominal delay (0.0 = the
    legacy immediate-retry behavior); each further retry multiplies it by
    ``backoff_mult`` and jitters it by ±``jitter`` (fraction). A seeded
    ``rng`` (``random.Random``) makes the schedule deterministic.
    ``deadline_s`` caps the whole attempt loop: a retry is only issued if
    wall time remains, and the pre-retry sleep never overshoots the
    budget; exhaustion raises :class:`RetryDeadlineExceeded`.
    ``sleep``/``clock`` are injectable for tests.
    """
    t0 = clock()
    attempt = 0
    while True:
        try:
            return fn()
        except retriable as e:  # noqa: PERF203
            attempt += 1
            if attempt > max_retries:
                raise StepFailed(
                    f"step failed after {max_retries} retries: {e}") from e
            delay = backoff_delay(attempt, backoff_base_s, backoff_mult,
                                  jitter, rng)
            if deadline_s is not None:
                remaining = deadline_s - (clock() - t0)
                if remaining <= 0.0:
                    raise RetryDeadlineExceeded(
                        f"retry deadline ({deadline_s:g}s) exhausted "
                        f"after {attempt - 1} retries: {e}") from e
                delay = min(delay, remaining)
            if on_retry:
                on_retry(attempt, e)
            if delay > 0.0:
                sleep(delay)


class StragglerMonitor:
    def __init__(self, factor: float = 3.0, window: int = 20,
                 min_samples: int = 5,
                 on_straggler: Optional[Callable[[int, float, float], None]]
                 = None):
        self.factor = factor
        self.window = window
        self.min_samples = min_samples
        self.on_straggler = on_straggler
        self.times: List[float] = []            # every recorded duration
        self.flagged: List[int] = []            # 1-based straggling steps
        self._samples: List[Tuple[float, bool]] = []  # (seconds, flagged)
        self._step = 0

    def record(self, seconds: float) -> bool:
        """Record a step duration; returns True if it straggled.

        The threshold is ``factor`` x the median of the trailing
        ``window`` *non-flagged* samples: an already-flagged straggler is
        excluded, so a single slow step cannot inflate the baseline its
        successors are compared against (a 10x outlier followed by 4x
        outliers must flag all of them, not just the first).
        """
        self._step += 1
        hist = [t for t, fl in self._samples[-self.window:] if not fl]
        is_straggler = False
        if len(hist) >= self.min_samples:
            med = statistics.median(hist)
            if seconds > self.factor * med:
                is_straggler = True
                self.flagged.append(self._step)
                if self.on_straggler:
                    self.on_straggler(self._step, seconds, med)
        self.times.append(seconds)
        self._samples.append((seconds, is_straggler))
        return is_straggler

    def timed(self, fn: Callable[[], object]):
        t0 = time.monotonic()
        out = fn()
        self.record(time.monotonic() - t0)
        return out


class PreemptionGuard:
    """Cooperative SIGTERM -> checkpoint-and-exit flag."""

    def __init__(self, install: bool = True):
        self.preempted = False
        self._prev = None
        if install:
            self._prev = signal.signal(signal.SIGTERM, self._handler)

    def _handler(self, signum, frame):
        self.preempted = True

    def trigger(self):          # for tests / manual drills
        self.preempted = True

    def uninstall(self):
        if self._prev is not None:
            signal.signal(signal.SIGTERM, self._prev)
