"""Fault-tolerance runtime: step retries, straggler detection, preemption.

The policies below are host-side and hardware-agnostic, so they are fully
unit-testable in this CPU container with injected fakes:

* ``retry_step`` — re-executes a step closure on transient failure
  (``jaxlib`` RuntimeError / timeout), up to ``max_retries``; on persistent
  failure raises ``StepFailed`` so the trainer restores the last checkpoint.
* ``StragglerMonitor`` — tracks per-step wall times; flags a step as
  straggling when it exceeds ``factor`` x the trailing-median. At scale the
  flag triggers the collective-timeout path (abort + restore + exclude the
  slow host from the next mesh — i.e. elastic downsize); here we surface it
  via a callback.
* ``PreemptionGuard`` — cooperative SIGTERM handling: sets a flag the train
  loop polls to checkpoint-and-exit cleanly (how TPU pods signal preemption).
"""
from __future__ import annotations

import signal
import statistics
import time
from typing import Callable, List, Optional


class StepFailed(RuntimeError):
    pass


def retry_step(fn: Callable[[], object], *, max_retries: int = 2,
               retriable: tuple = (RuntimeError,),
               on_retry: Optional[Callable[[int, Exception], None]] = None):
    """Run ``fn``; retry on transient device errors."""
    attempt = 0
    while True:
        try:
            return fn()
        except retriable as e:  # noqa: PERF203
            attempt += 1
            if attempt > max_retries:
                raise StepFailed(
                    f"step failed after {max_retries} retries: {e}") from e
            if on_retry:
                on_retry(attempt, e)


class StragglerMonitor:
    def __init__(self, factor: float = 3.0, window: int = 20,
                 min_samples: int = 5,
                 on_straggler: Optional[Callable[[int, float, float], None]]
                 = None):
        self.factor = factor
        self.window = window
        self.min_samples = min_samples
        self.on_straggler = on_straggler
        self.times: List[float] = []
        self.flagged: List[int] = []
        self._step = 0

    def record(self, seconds: float) -> bool:
        """Record a step duration; returns True if it straggled."""
        self._step += 1
        hist = self.times[-self.window:]
        is_straggler = False
        if len(hist) >= self.min_samples:
            med = statistics.median(hist)
            if seconds > self.factor * med:
                is_straggler = True
                self.flagged.append(self._step)
                if self.on_straggler:
                    self.on_straggler(self._step, seconds, med)
        self.times.append(seconds)
        return is_straggler

    def timed(self, fn: Callable[[], object]):
        t0 = time.monotonic()
        out = fn()
        self.record(time.monotonic() - t0)
        return out


class PreemptionGuard:
    """Cooperative SIGTERM -> checkpoint-and-exit flag."""

    def __init__(self, install: bool = True):
        self.preempted = False
        self._prev = None
        if install:
            self._prev = signal.signal(signal.SIGTERM, self._handler)

    def _handler(self, signum, frame):
        self.preempted = True

    def trigger(self):          # for tests / manual drills
        self.preempted = True

    def uninstall(self):
        if self._prev is not None:
            signal.signal(signal.SIGTERM, self._prev)
