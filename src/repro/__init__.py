"""repro: SAIF sparse-learning framework (JAX, multi-pod)."""
__version__ = "0.1.0"
