"""repro: SAIF sparse-learning framework (JAX, multi-pod).

The public serving surface lives here (DESIGN.md §9)::

    from repro import Problem, Scalar, Path, Fleet, CV, open_session

    session = open_session(Problem(X=X, y=y), SaifConfig(eps=1e-7))
    res = session.solve(Scalar(lam))          # ... and keep serving

Attributes load lazily (PEP 562): ``from repro import open_session,
Problem`` imports no jax-heavy solver module — the engines are pulled in
on first use (``open_session(...)`` / ``session.solve(...)``).
"""
from __future__ import annotations

import importlib

__version__ = "0.2.0"

# name -> defining module; resolved on first attribute access
_EXPORTS = {
    # the unified Problem/Session API (repro.core.api is import-light)
    "Problem": "repro.core.api", "Session": "repro.core.api",
    "open_session": "repro.core.api",
    "Scalar": "repro.core.api", "Path": "repro.core.api",
    "Fleet": "repro.core.api", "CV": "repro.core.api",
    "lasso": "repro.core.api", "fused": "repro.core.api",
    "group": "repro.core.api",
    "LassoPenalty": "repro.core.api", "FusedPenalty": "repro.core.api",
    "GroupPenalty": "repro.core.api",
    "GroupPathResult": "repro.core.api",
    "CompileStats": "repro.core.api",
    "unified_compile_count": "repro.core.api",
    # configs + the one-shot convenience solver
    "SaifConfig": "repro.core.saif", "SaifResult": "repro.core.saif",
    "saif": "repro.core.saif",
    "GroupSaifConfig": "repro.core.group",
    # screening-rule geometry (DESIGN.md §13; repro.core.screen_rule is
    # import-light — no jax at import)
    "ScreenRule": "repro.core.screen_rule",
    "resolve_screen_rule": "repro.core.screen_rule",
    # fault-tolerant serving runtime (DESIGN.md §10; import-light too)
    "open_serving": "repro.core.serving",
    "ServingSession": "repro.core.serving",
    "ServingConfig": "repro.core.serving",
    "ServingResult": "repro.core.serving",
    "ServingStats": "repro.core.serving",
    "Verdict": "repro.core.serving", "Rung": "repro.core.serving",
    "ServingError": "repro.core.serving",
    "RequestError": "repro.core.serving",
    "NumericalError": "repro.core.serving",
    "BackendFault": "repro.core.serving",
    "DeadlineExceeded": "repro.core.serving",
    # streaming & model selection (DESIGN.md §14; import-light)
    "Update": "repro.core.online",
    "Select": "repro.core.select",
    "SelectionReport": "repro.core.select",
    "WarmCache": "repro.core.warm_cache",
    "WarmCacheConfig": "repro.core.warm_cache",
    # async serving front-end (DESIGN.md §12; import-light as well)
    "open_server": "repro.core.server",
    "Server": "repro.core.server",
    "ServerConfig": "repro.core.server",
    "ServerStats": "repro.core.server",
    "ServingFuture": "repro.core.server",
    "FaultInjector": "repro.runtime.inject",
}

__all__ = sorted(_EXPORTS) + ["__version__"]


def __getattr__(name: str):
    if name in _EXPORTS:
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(set(__all__) | set(globals()))
