"""Production mesh construction (deliverable e).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init; tests see
the single real CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(data=16, model=16) single pod; (pod=2, data=16, model=16) two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the real local devices (CPU tests / examples)."""
    n = jax.device_count()
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")
