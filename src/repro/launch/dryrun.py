"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape x mesh) cell on the production mesh and emit
memory/cost/collective analysis for the roofline table.

MUST set the placeholder-device flag before ANY other import — jax locks the
device count on first init.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

# ruff: noqa: E402
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict

import jax
import numpy as np

from repro.configs import ARCH_IDS, SHAPES, get_config, runnable_cells
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import (batch_shardings, cache_shardings,
                                    opt_shardings, param_shardings)
from repro.models import lm
from repro.optim import adamw

# v5e hardware model (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^=]*?=\s*(\w+)\[([0-9,{}\sx]*)\]", re.I)

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "f8e4m3": 1,
                "f8e5m2": 1, "s16": 2, "u16": 2}


def cost_dict(cost) -> Dict[str, float]:
    """Normalize Compiled.cost_analysis() — dict on newer jaxlibs, a
    one-element list of dicts on older ones (None if unavailable)."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output operand bytes of every collective op in the compiled HLO."""
    totals: Dict[str, float] = {}
    for m in re.finditer(
            r"=\s*(?:\(([^)]*)\)|(\w+)\[([0-9,\s]*)\][^ ]*)\s*"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)", hlo_text, re.I):
        tuple_part, dtype, dims, op = m.groups()
        nbytes = 0.0
        if tuple_part:
            for shp in re.finditer(r"(\w+)\[([0-9,\s]*)\]", tuple_part):
                d, ds = shp.groups()
                n = np.prod([int(x) for x in ds.split(",") if x.strip()]
                            or [1])
                nbytes += n * _DTYPE_BYTES.get(d, 4)
        else:
            n = np.prod([int(x) for x in dims.split(",") if x.strip()] or [1])
            nbytes = n * _DTYPE_BYTES.get(dtype, 4)
        key = op.lower()
        totals[key] = totals.get(key, 0.0) + float(nbytes)
    return totals


def roofline_terms(flops: float, bytes_hbm: float, coll: Dict[str, float],
                   n_chips: int) -> Dict[str, float]:
    """All inputs are PER-DEVICE quantities: the compiled artifact under
    SPMD partitioning is the per-device program, so cost_analysis()
    (and the HLO the collectives are parsed from) describe one chip.
    Dividing by per-chip peaks gives the per-step time lower bound each
    subsystem imposes. Caveat: XLA 'bytes accessed' counts op-level operand
    traffic, an upper bound on true HBM traffic after fusion.
    """
    compute_t = flops / PEAK_FLOPS
    memory_t = bytes_hbm / HBM_BW
    coll_bytes = sum(coll.values())
    collective_t = coll_bytes / ICI_BW
    dom = max(("compute", compute_t), ("memory", memory_t),
              ("collective", collective_t), key=lambda kv: kv[1])[0]
    return {"compute_s": compute_t, "memory_s": memory_t,
            "collective_s": collective_t, "collective_bytes": coll_bytes,
            "dominant": dom}


def _small_depths(cfg):
    """Two reduced depths for the scan-body cost extrapolation, chosen so
    every per-depth stack (cross_every groups, L//4 sLSTM layers) scales
    linearly between them."""
    if cfg.family == "vlm":
        ce = cfg.cross_every
        return ce, 2 * ce
    if cfg.family == "ssm":
        return 4, 8
    return 2, 4


def corrected_costs(arch: str, shape_name: str, mesh, cfg, *,
                    microbatch: int = 1, fsdp: bool = False):
    """XLA cost_analysis counts a while-loop (lax.scan) body ONCE, so the
    scanned-layer contribution is undercounted by ~n_layers. Lower two
    fully-unrolled reduced-depth variants and extrapolate linearly:
        total(L) = fixed + L * per_layer.
    """
    L1, L2 = _small_depths(cfg)
    variants = []
    for L in (L1, L2):
        kw = {"n_layers": L, "scan_unroll": True}
        # chunk scans stay rolled: their interior is counted once per layer
        # (documented undercount on the recurrence arithmetic — the
        # projections dominate ssm/hybrid FLOPs; fully-unrolled chunk scans
        # blow up XLA compile time at 32k+ sequence lengths)
        if cfg.family == "encdec":
            kw["n_enc_layers"] = L
        vcfg = cfg.scaled(**kw)
        variants.append(_lower_one(arch, shape_name, mesh, vcfg,
                                   microbatch=microbatch, fsdp=fsdp))
    v1, v2 = variants

    def extrap(key):
        body = (v2[key] - v1[key]) / (L2 - L1)
        fixed = v1[key] - L1 * body
        return max(fixed + cfg.n_layers * body, 0.0)

    coll_keys = set(v1["collectives"]) | set(v2["collectives"])
    coll = {}
    for k in coll_keys:
        a = v1["collectives"].get(k, 0.0)
        b = v2["collectives"].get(k, 0.0)
        body = (b - a) / (L2 - L1)
        coll[k] = max(a - L1 * body + cfg.n_layers * body, 0.0)
    return {"flops": extrap("flops"), "bytes": extrap("bytes"),
            "collectives": coll,
            "extrap_depths": (L1, L2)}


def _lower_one(arch: str, shape_name: str, mesh, cfg, *,
               microbatch: int = 1, fsdp: bool = False):
    """Lower+compile one configuration; returns raw cost dict."""
    shape = SHAPES[shape_name]
    n_chips = int(np.prod(list(mesh.shape.values())))
    shapes_tree = lm.param_shapes(cfg)
    p_sh = param_shardings(shapes_tree, cfg, mesh, fsdp=fsdp)

    with mesh:
        if shape.kind == "train":
            opt_cfg = adamw.AdamWConfig()
            step_fn = steps_lib.make_train_step(cfg, opt_cfg,
                                                microbatch=microbatch)
            specs = steps_lib.input_specs(cfg, shape)
            o_sh = opt_shardings(p_sh, shapes_tree, mesh, zero1=True)
            state_sh = steps_lib.TrainState(
                params=p_sh,
                opt=adamw.AdamWState(
                    step=jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec()),
                    m=o_sh, v=o_sh))
            b_sh = batch_shardings(mesh, specs["batch"])
            jitted = jax.jit(step_fn,
                             in_shardings=(state_sh, b_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(specs["state"], specs["batch"])
        elif shape.kind == "prefill":
            step_fn = steps_lib.make_prefill(cfg)
            specs = steps_lib.input_specs(cfg, shape)
            b_sh = batch_shardings(mesh, specs["batch"])
            jitted = jax.jit(step_fn, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(specs["params"], specs["batch"])
        else:  # decode
            step_fn = steps_lib.make_serve_step(cfg)
            specs = steps_lib.input_specs(cfg, shape)
            tok_sh = batch_shardings(mesh, {"t": specs["tok"]})["t"]
            c_sh = cache_shardings(mesh, specs["state"].caches)
            st_sh = lm.DecodeState(
                caches=c_sh, pos=jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()))
            jitted = jax.jit(step_fn,
                             in_shardings=(p_sh, tok_sh, st_sh),
                             out_shardings=(None, st_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(specs["params"], specs["tok"],
                                   specs["state"])

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = cost_dict(compiled.cost_analysis())
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll,
        "compile_s": round(compile_s, 1),
        "peak_memory_per_device": getattr(
            mem, "temp_size_in_bytes", 0) + getattr(
            mem, "argument_size_in_bytes", 0) + getattr(
            mem, "output_size_in_bytes", 0),
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
    }


def lower_cell(arch: str, shape_name: str, mesh, cfg_override=None,
               corrected: bool = True, microbatch: int = 1,
               fsdp: bool = False):
    """Full analysis of one cell: production lowering (memory + raw costs)
    plus the scan-corrected flops/bytes/collectives extrapolation."""
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    n_chips = int(np.prod(list(mesh.shape.values())))

    raw = _lower_one(arch, shape_name, mesh, cfg,
                     microbatch=microbatch, fsdp=fsdp)
    flops, bytes_hbm, coll = raw["flops"], raw["bytes"], raw["collectives"]
    corr = None
    if corrected:
        corr = corrected_costs(arch, shape_name, mesh, cfg,
                               microbatch=microbatch, fsdp=fsdp)
        flops, bytes_hbm, coll = (corr["flops"], corr["bytes"],
                                  corr["collectives"])
    terms = roofline_terms(flops, bytes_hbm, coll, n_chips)

    n_active = cfg.active_param_count()
    tokens = (shape.global_batch
              * (shape.seq_len if shape.kind != "decode" else 1))
    if shape.kind == "train":
        model_flops = 6.0 * n_active * tokens   # fwd(2) + bwd(4) per param
    else:
        model_flops = 2.0 * n_active * tokens

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(f"{k}={v}" for k, v in mesh.shape.items()),
        "n_chips": n_chips, "compile_s": raw["compile_s"],
        "flops": flops, "bytes": bytes_hbm,
        "raw_flops": raw["flops"], "raw_bytes": raw["bytes"],
        "scan_corrected": bool(corrected),
        "microbatch": microbatch, "fsdp": fsdp,
        "peak_memory_per_device": raw["peak_memory_per_device"],
        "argument_bytes": raw["argument_bytes"],
        "temp_bytes": raw["temp_bytes"],
        "collectives": coll,
        "model_flops": model_flops,
        "useful_flops_frac": (model_flops / (flops * n_chips)
                              if flops else None),
        **terms,
    }
    return rec


def lower_saif_screen(mesh, *, n: int = 4096, log2_p: int = 26,
                      h: int = 64, dtype="float32"):
    """The paper-technique roofline row: the distributed SAIF screening scan
    (fused local top-h + max-ub, one small gather) on the production mesh,
    at framework scale: p = 2^26 features sharded over every mesh axis.
    """
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.saif_sharded import ShardedDesign, make_fused_screen

    n_chips = int(np.prod(list(mesh.shape.values())))
    p = 2 ** log2_p
    axes = tuple(mesh.axis_names)
    dt = jnp.dtype(dtype)
    X = jax.ShapeDtypeStruct((n, p), dt)
    norm = jax.ShapeDtypeStruct((p,), dt)

    x_sh = NamedSharding(mesh, P(None, axes))
    v_sh = NamedSharding(mesh, P(axes))
    r_sh = NamedSharding(mesh, P())

    def step(X, norm, theta, r):
        d = ShardedDesign(X=X, col_norm=norm, c0=None, p=p, mesh=mesh)
        return make_fused_screen(d, h=h)(theta, r)

    with mesh:
        jitted = jax.jit(step, in_shardings=(x_sh, v_sh, r_sh, r_sh))
        lowered = jitted.lower(X, norm,
                               jax.ShapeDtypeStruct((n,), dt),
                               jax.ShapeDtypeStruct((), dt))
        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0
    cost = cost_dict(compiled.cost_analysis())
    mem = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    terms = roofline_terms(flops, bytes_hbm, coll, n_chips)
    rec = {
        "arch": f"saif_screen_p2^{log2_p}_{dtype}", "shape": f"n{n}_h{h}",
        "mesh": "x".join(f"{k}={v}" for k, v in mesh.shape.items()),
        "n_chips": n_chips, "compile_s": round(compile_s, 1),
        "flops": flops, "bytes": bytes_hbm, "collectives": coll,
        "scan_corrected": False,
        "peak_memory_per_device": getattr(
            mem, "temp_size_in_bytes", 0) + getattr(
            mem, "argument_size_in_bytes", 0) + getattr(
            mem, "output_size_in_bytes", 0),
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        # useful flops per device: the scan is 2*n*p/devices matvec MACs
        "model_flops": 2.0 * n * p,
        "useful_flops_frac": (2.0 * n * p / (flops * n_chips)
                              if flops else None),
        **terms,
    }
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true",
                    help="run single-pod AND multi-pod for each cell")
    ap.add_argument("--out", default=None, help="write JSONL records here")
    ap.add_argument("--saif-screen", action="store_true",
                    help="only lower the SAIF screening-collective row")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--no-corrected", action="store_true",
                    help="skip the scan-cost extrapolation (1 compile/cell)")
    args = ap.parse_args(argv)

    if args.saif_screen:
        records = []
        for multi in ([False, True] if args.both_meshes
                      else [args.multi_pod]):
            mesh = make_production_mesh(multi_pod=multi)
            rec = lower_saif_screen(mesh)
            rec["status"] = "ok"
            records.append(rec)
            print(f"OK    saif_screen x {rec['mesh']}: "
                  f"dominant={rec['dominant']} "
                  f"compute={rec['compute_s']:.2e}s "
                  f"memory={rec['memory_s']:.2e}s "
                  f"coll={rec['collective_s']:.2e}s")
        if args.out:
            with open(args.out, "w") as f:
                for r in records:
                    f.write(json.dumps(r) + "\n")
        return 0

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shape_filter = (list(SHAPES) if args.shape == "all" else [args.shape])
    meshes = ([False, True] if args.both_meshes
              else [args.multi_pod])

    cells = [(a, s, st) for a, s, st in runnable_cells()
             if a in archs and s in shape_filter]
    records = []

    def flush(rec):
        records.append(rec)
        if args.out:                      # incremental append (crash-safe)
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    n_fail = 0
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        for arch, shape_name, status in cells:
            tag = f"{arch} x {shape_name} x {'2x16x16' if multi else '16x16'}"
            if status != "run":
                print(f"SKIP  {tag}: {status}")
                flush({"arch": arch, "shape": shape_name,
                       "mesh": "2x16x16" if multi else "16x16",
                       "status": status})
                continue
            try:
                rec = lower_cell(arch, shape_name, mesh,
                                 corrected=not args.no_corrected,
                                 microbatch=args.microbatch,
                                 fsdp=args.fsdp)
                rec["status"] = "ok"
                flush(rec)
                print(f"OK    {tag}: dominant={rec['dominant']} "
                      f"compute={rec['compute_s']:.2e}s "
                      f"memory={rec['memory_s']:.2e}s "
                      f"coll={rec['collective_s']:.2e}s "
                      f"peak_mem/dev={rec['peak_memory_per_device']/2**30:.2f}GiB "
                      f"(compile {rec['compile_s']}s)")
            except Exception as e:  # noqa: BLE001
                n_fail += 1
                print(f"FAIL  {tag}: {type(e).__name__}: {e}")
                traceback.print_exc()
                flush({"arch": arch, "shape": shape_name,
                       "mesh": "2x16x16" if multi else "16x16",
                       "status": f"fail: {e}"})
    print(f"\n{len(records)} cells, {n_fail} failures")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
