"""Sharding rules: params (TP/EP), optimizer state (ZeRO-1), batches (DP),
decode caches. All rules are name+shape driven and divisibility-checked, so
one rule set covers all 10 architectures on any mesh.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import dp_axes
from repro.models.config import ModelConfig


def _axis_size(mesh, name) -> int:
    return mesh.shape[name]


def _fits(dim: int, mesh, axis: str) -> bool:
    return dim % _axis_size(mesh, axis) == 0


# parameter name -> which logical dim prefers the model axis.
# Index is into the *unstacked* (per-layer) shape; the stacked L dim is
# prepended for block params, handled by offset detection below.
_MODEL_DIM_RULES: Dict[str, int] = {
    # attention
    "wq": 1, "wk": 1, "wv": 1, "wo": 0,
    # dense mlp
    "w1": 1, "w3": 1, "w2": 0,
    # moe (E is dim 0 of the per-layer shape) — expert parallelism
    "router": 1,
    # mamba
    "in_proj": 1, "conv": 1, "x_proj": 0, "dt_proj": 1, "A_log": 0,
    "Dskip": 0, "out_proj": 0,
    # mlstm / slstm
    "wi": 1, "wf": 1, "wo_gate": 1, "out": 0, "wz": 1,
    # vlm
    "img_proj": 1,
}

_MOE_PARAMS = {"w1", "w3", "w2"}   # (E, D, F)/(E, F, D): shard E


def param_spec(path, shape, cfg: ModelConfig, mesh) -> P:
    name = path[-1]
    ndim = len(shape)
    none = (None,) * ndim

    def with_model(dim):
        if dim < ndim and _fits(shape[dim], mesh, "model"):
            spec = list(none)
            spec[dim] = "model"
            return P(*spec)
        return P(*none)

    stacked = path[-2] in ("blocks", "blocks_m", "blocks_s", "cross_blocks",
                           "enc_blocks") if len(path) >= 2 else False
    off = 1 if stacked else 0

    if name == "embed":
        if _fits(shape[0], mesh, "model"):
            return P("model", None)
        if _fits(shape[1], mesh, "model"):
            return P(None, "model")
        return P(None, None)
    if name == "lm_head":
        return with_model(1)
    if name in ("final_ln", "enc_ln") or name.startswith("ln"):
        return P(*none)
    if cfg.family == "moe" and name in _MOE_PARAMS and ndim == 3 + off:
        return with_model(off + 0)      # shard experts (EP)
    if name in _MODEL_DIM_RULES:
        return with_model(off + _MODEL_DIM_RULES[name])
    return P(*none)


def fsdp_spec(spec: P, shape, mesh) -> P:
    """FSDP: additionally shard parameters over the data axis on the first
    free, divisible dim. XLA all-gathers the shard per use (inside the layer
    scan), trading an all-gather per layer for an n_data-fold cut in
    parameter + gradient + optimizer residency — mandatory for the 100B+
    archs whose TP-only residency exceeds HBM (§Perf hillclimb B)."""
    return zero1_spec(spec, shape, mesh)


def param_shardings(shapes_tree, cfg: ModelConfig, mesh, *,
                    fsdp: bool = False):
    """shapes_tree: pytree of shape tuples (from models.lm.param_shapes)."""
    def walk(path, node):
        if isinstance(node, tuple):
            spec = param_spec(path, node, cfg, mesh)
            if fsdp:
                spec = fsdp_spec(spec, node, mesh)
            return NamedSharding(mesh, spec)
        return {k: walk(path + (k,), v) for k, v in node.items()}
    return walk((), shapes_tree)


def zero1_spec(spec: P, shape, mesh) -> P:
    """ZeRO-1: additionally shard optimizer moments over the data axis on
    the first dim that is free and divisible (usually the stacked L dim)."""
    dp = [a for a in dp_axes(mesh)]
    if not dp:
        return spec
    axis = dp[-1]   # the largest dp axis ('data')
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = {a for e in entries if e is not None
            for a in (e if isinstance(e, tuple) else (e,))}
    if axis in used:
        return spec
    for d, (e, s) in enumerate(zip(entries, shape)):
        if e is None and s % _axis_size(mesh, axis) == 0:
            entries[d] = axis
            return P(*entries)
    return spec


def opt_shardings(param_sh, shapes_tree, mesh, *, zero1: bool = True):
    """Sharding for AdamW m/v (params-shaped). step is replicated."""
    def walk(sh_node, shape_node):
        if isinstance(shape_node, tuple):
            spec = sh_node.spec
            if zero1:
                spec = zero1_spec(spec, shape_node, mesh)
            return NamedSharding(mesh, spec)
        return {k: walk(sh_node[k], shape_node[k]) for k in shape_node}
    return walk(param_sh, shapes_tree)


def batch_spec(mesh, batch_size: int) -> P:
    dp = dp_axes(mesh)
    total = int(np.prod([_axis_size(mesh, a) for a in dp]))
    if batch_size % total == 0:
        return P(dp,)
    # small batches (long_500k B=1): replicate batch, shard elsewhere
    return P(None,)


def batch_shardings(mesh, batch: Dict[str, Any]):
    out = {}
    for k, v in batch.items():
        spec = batch_spec(mesh, v.shape[0])
        pad = (None,) * (v.ndim - 1)
        out[k] = NamedSharding(mesh, P(*(tuple(spec) + pad)))
    return out


def cache_sharding(mesh, shape, batch_dim: int = 1,
                   seq_shard: bool = True):
    """Decode-cache rule: batch dim -> dp axes if divisible.

    KV caches (rank-5: L, B, S, Hkv, hd): the SEQUENCE dim takes the model
    axis ("context parallelism"). Sharding hd instead forces XLA to
    all-gather the whole cache for the attention einsums (observed: 90 GB of
    collectives per decode step on llama-vision; SPMD 'involuntary full
    rematerialization' warnings) — contracting over a sequence-sharded cache
    only psums the tiny (B, H) partials. §Perf hillclimb cell 1.

    Lower-rank recurrent states (mLSTM/mamba) shard their feature dim on
    model when divisible.
    """
    dp = dp_axes(mesh)
    total = int(np.prod([_axis_size(mesh, a) for a in dp]))
    spec = [None] * len(shape)
    batch_ok = len(shape) > batch_dim and shape[batch_dim] % total == 0
    if batch_ok:
        spec[batch_dim] = dp
    if len(shape) >= 5 and seq_shard:
        # KV cache: shard sequence over model (+ data when batch can't)
        seq_dim = batch_dim + 1
        axes = ("model",) if batch_ok else (dp[-1], "model")
        size = int(np.prod([_axis_size(mesh, a) for a in axes]))
        if shape[seq_dim] % size == 0:
            spec[seq_dim] = axes if len(axes) > 1 else axes[0]
            return NamedSharding(mesh, P(*spec))
    # fallback / recurrent states: last dim on model
    if not batch_ok and len(shape) > batch_dim + 1 \
            and shape[batch_dim + 1] % _axis_size(mesh, dp[-1]) == 0:
        spec[batch_dim + 1] = dp[-1]
    last = len(shape) - 1
    if last > batch_dim and shape[last] % _axis_size(mesh, "model") == 0:
        spec[last] = "model"
    return NamedSharding(mesh, P(*spec))


def cache_shardings(mesh, cache_tree):
    """Apply cache_sharding leaf-wise to a DecodeState-shaped spec tree
    (leaves are ShapeDtypeStruct or arrays)."""
    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        return cache_sharding(mesh, leaf.shape, batch_dim=1)
    return jax.tree.map(one, cache_tree)
