"""CLI shim over the async serving front-end (DESIGN.md §12).

A thin argparse layer that builds a :class:`repro.core.server.
ServerConfig`, opens the server and drives it with a small seeded
synthetic request mix — the smoke-test entry point for the queue →
shape-bucket → microbatch → fleet pipeline. The real load generator
with Poisson arrivals and latency percentiles lives in
``benchmarks/bench_serve.py``.

Example:
  PYTHONPATH=src python -m repro.launch.serve --requests 32 \
      --max-batch 8 --max-wait-ms 5 --cache-dir /tmp/saif-cache
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="SAIF async serving smoke driver")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--problems", type=int, default=3,
                    help="distinct problem shapes in the mix")
    ap.add_argument("--n", type=int, default=128)
    ap.add_argument("--p", type=int, default=96)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=5.0)
    ap.add_argument("--max-sessions", type=int, default=8)
    ap.add_argument("--cache-dir", default=None,
                    help="persistent compilation cache directory")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro import Problem, Scalar, open_server
    from repro.core.saif import SaifConfig

    server = open_server(
        max_batch=args.max_batch, max_wait_ms=args.max_wait_ms,
        max_sessions=args.max_sessions, cache_dir=args.cache_dir,
        solver=SaifConfig())

    rng = np.random.default_rng(args.seed)
    problems = []
    for k in range(args.problems):
        n = args.n - 8 * k
        p = args.p - 8 * k
        X = rng.normal(size=(n, p))
        y = rng.normal(size=n)
        problems.append(Problem(X=X, y=y))

    t0 = time.monotonic()
    futs = []
    for _ in range(args.requests):
        prob = problems[int(rng.integers(len(problems)))]
        lam = float(rng.uniform(0.03, 0.12))
        futs.append(server.submit(prob, Scalar(lam)))
    results = [f.result(timeout=600) for f in futs]
    dt = time.monotonic() - t0
    server.drain()
    stats = server.stats()
    server.close()

    ok = sum(1 for r in results if r.verdict.ok)
    print(f"served {stats.served}/{stats.submitted} requests in "
          f"{dt:.2f}s ({stats.served / dt:.1f} req/s); "
          f"{ok} certified ok")
    print(f"coalesced {stats.coalesced_requests} requests into "
          f"{stats.coalesced_batches} microbatches; "
          f"{stats.sessions_opened} sessions opened "
          f"({stats.evictions} evicted)")
    return 0 if ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
