"""Batched serving driver (deliverable b): prefill + decode loop.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch whisper_tiny --smoke \
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.launch.mesh import make_host_mesh
from repro.launch.shardings import param_shardings
from repro.models import (decode_step, fill_cross_cache, init,
                          init_decode_state)
from repro.models import lm


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if jax.default_backend() == "cpu":
        cfg = cfg.scaled(dtype="float32")
    mesh = make_host_mesh(model=args.model_parallel)

    with mesh:
        params = init(jax.random.PRNGKey(0), cfg)
        shapes_tree = lm.param_shapes(cfg)
        params = jax.tree.map(jax.device_put, params,
                              param_shardings(shapes_tree, cfg, mesh))
        B = args.batch
        total = args.prompt_len + args.gen
        key = jax.random.PRNGKey(1)
        prompt = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab)

        state = init_decode_state(params, cfg, B, total)
        extras = {}
        if cfg.family == "vlm":
            extras["img_embed"] = 0.02 * jax.random.normal(
                key, (B, cfg.n_image_tokens, cfg.d_model))
        if cfg.family == "encdec":
            extras["frames"] = 0.02 * jax.random.normal(
                key, (B, cfg.n_frames, cfg.d_model))
        state = fill_cross_cache(params, cfg, state, **extras)

        step = jax.jit(lambda p, t, s: decode_step(p, t, s, cfg),
                       donate_argnums=(2,))

        # prefill by teacher-forcing the prompt through the decode path
        # (a production server would use the chunked prefill kernel; the
        # decode path is the correctness reference)
        t0 = time.time()
        logits = None
        for t in range(args.prompt_len):
            logits, state = step(params, prompt[:, t], state)
        out_tokens = []
        for t in range(args.gen):
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits / args.temperature,
                                         axis=-1).astype(jnp.int32)
            out_tokens.append(nxt)
            logits, state = step(params, nxt, state)
        jax.block_until_ready(logits)
        dt = time.time() - t0
        toks = B * (args.prompt_len + args.gen)
        print(f"{cfg.name}: {toks} tokens in {dt:.2f}s "
              f"({toks/dt:.1f} tok/s batched decode)")
        sample = jnp.stack(out_tokens, axis=1)[0, :16]
        print("sample token ids:", sample.tolist())
        return 0


if __name__ == "__main__":
    raise SystemExit(main())
