"""train_step / serve_step factories + dry-run input specs (deliverable e).

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, zero allocation — used by both the dry-run
(.lower on the production mesh) and the roofline harness.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ShapeCfg
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import adamw


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    microbatch: int = 1):
    """Training step; ``microbatch`` > 1 accumulates gradients over
    sequential micro-batches (lax.scan), dividing activation live-memory by
    the microbatch count at the cost of per-microbatch collective latency —
    the standard fit-the-HBM lever (§Perf hillclimb A)."""

    def train_step(state: TrainState, batch) -> Tuple[TrainState, jax.Array]:
        if microbatch == 1:
            loss, grads = jax.value_and_grad(lm.train_loss)(
                state.params, batch, cfg)
        else:
            def split(x):
                B = x.shape[0]
                return x.reshape(microbatch, B // microbatch, *x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_step(carry, mbatch):
                loss_sum, gacc = carry
                l, g = jax.value_and_grad(lm.train_loss)(
                    state.params, mbatch, cfg)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (loss_sum + l, gacc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
            (loss_sum, gsum), _ = jax.lax.scan(
                acc_step, (jnp.zeros((), jnp.float32), g0), mb)
            loss = loss_sum / microbatch
            grads = jax.tree.map(lambda g: g / microbatch, gsum)
        params, opt = adamw.update(grads, state.opt, state.params, opt_cfg)
        return TrainState(params, opt), loss
    return train_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, tok, state: lm.DecodeState):
        return lm.decode_step(params, tok, state, cfg)
    return serve_step


def make_prefill(cfg: ModelConfig):
    def prefill(params, batch):
        hidden, _ = lm.backbone(params, batch["tokens"], cfg,
                                img_embed=batch.get("img_embed"),
                                frames=batch.get("frames"))
        return lm.logits_fn(params, hidden, cfg)[:, -1]
    return prefill


# ---------------------------------------------------------------------------
# shape-struct builders (no allocation)
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: ShapeCfg) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    batch = {"tokens": sds((B, S), jnp.int32)}
    if shape.kind == "train":
        batch["labels"] = sds((B, S), jnp.int32)
    if cfg.family == "vlm":
        batch["img_embed"] = sds((B, cfg.n_image_tokens, cfg.d_model),
                                 cfg.adtype)
    if cfg.family == "encdec":
        batch["frames"] = sds((B, cfg.n_frames, cfg.d_model), cfg.adtype)
    return batch


def param_specs(cfg: ModelConfig) -> Any:
    shapes = lm.param_shapes(cfg)

    def walk(path, node):
        if isinstance(node, tuple):
            name = path[-1] if path else ""
            return jax.ShapeDtypeStruct(node, cfg.pdtype)
        return {k: walk(path + (k,), v) for k, v in node.items()}
    return walk((), shapes)


def opt_specs(cfg: ModelConfig) -> adamw.AdamWState:
    p = param_specs(cfg)
    z = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), p)
    z2 = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), p)
    return adamw.AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                            m=z, v=z2)


def decode_state_specs(cfg: ModelConfig, shape: ShapeCfg) -> lm.DecodeState:
    """eval_shape the cache allocator — zero real allocation."""
    B, S = shape.global_batch, shape.seq_len
    p_specs = param_specs(cfg)
    return jax.eval_shape(
        lambda p: lm.init_decode_state(p, cfg, B, S), p_specs)


def serve_input_specs(cfg: ModelConfig, shape: ShapeCfg):
    B = shape.global_batch
    tok = jax.ShapeDtypeStruct((B,), jnp.int32)
    return tok, decode_state_specs(cfg, shape)


def input_specs(cfg: ModelConfig, shape: ShapeCfg):
    """The full argument-spec bundle for the cell's entry point."""
    if shape.kind == "train":
        return {"state": TrainState(params=param_specs(cfg),
                                    opt=opt_specs(cfg)),
                "batch": batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"params": param_specs(cfg),
                "batch": batch_specs(cfg, shape)}
    # decode
    tok, dstate = serve_input_specs(cfg, shape)
    return {"params": param_specs(cfg), "tok": tok, "state": dstate}
