"""End-to-end training driver (deliverable b): fault-tolerant train loop.

Runs any zoo arch (reduced or full config) on the local mesh, with:
  * checkpoint/resume (atomic, async flush, data-cursor replay)
  * preemption handling (SIGTERM -> checkpoint -> clean exit)
  * straggler monitoring + step retry
  * optional int8 error-feedback gradient compression on the DP axis

Example (CPU container):
  PYTHONPATH=src python -m repro.launch.train --arch stablelm_3b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.configs import get_config, smoke_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch import steps as steps_lib
from repro.launch.mesh import dp_axes, make_host_mesh
from repro.launch.shardings import (batch_shardings, opt_shardings,
                                    param_shardings)
from repro.models import init as model_init
from repro.models import lm
from repro.optim import adamw
from repro.runtime.fault import (PreemptionGuard, StragglerMonitor,
                                 retry_step)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (smoke_config(args.arch) if args.smoke else get_config(args.arch))
    cfg = cfg.scaled(dtype="float32") if jax.default_backend() == "cpu" \
        else cfg
    mesh = make_host_mesh(model=args.model_parallel)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=10,
                                total_steps=args.steps)

    shapes_tree = lm.param_shapes(cfg)
    p_sh = param_shardings(shapes_tree, cfg, mesh)
    o_sh = opt_shardings(p_sh, shapes_tree, mesh, zero1=True)

    data = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                    global_batch=args.batch, seed=args.seed))

    with mesh:
        params = model_init(jax.random.PRNGKey(args.seed), cfg)
        params = jax.tree.map(jax.device_put, params, p_sh)
        opt = adamw.init(params)
        state = steps_lib.TrainState(params=params, opt=opt)

        start_step = 0
        if args.ckpt_dir:
            last = ckpt.latest_step(args.ckpt_dir)
            if last is not None:
                state, extra = ckpt.restore(
                    args.ckpt_dir, last, state,
                    steps_lib.TrainState(params=p_sh, opt=adamw.AdamWState(
                        step=jax.sharding.NamedSharding(
                            mesh, jax.sharding.PartitionSpec()),
                        m=o_sh, v=o_sh)))
                data.restore(extra["data"])
                start_step = extra["train_step"]
                print(f"[resume] restored step {start_step} "
                      f"from {args.ckpt_dir}")

        train_step = jax.jit(steps_lib.make_train_step(cfg, opt_cfg),
                             donate_argnums=(0,))

        guard = PreemptionGuard()
        monitor = StragglerMonitor(
            on_straggler=lambda s, t, m: print(
                f"[straggler] step {s}: {t:.2f}s vs median {m:.2f}s"))

        losses = []
        for step in range(start_step, args.steps):
            batch = jax.tree.map(jnp.asarray, data.next_batch())

            def run():
                return train_step(state, batch)

            t0 = time.monotonic()
            state, loss = retry_step(run, max_retries=2)
            monitor.record(time.monotonic() - t0)
            losses.append(float(loss))

            if args.log_every and step % args.log_every == 0:
                print(f"step {step:5d} loss {float(loss):.4f}")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                ckpt.save_async(args.ckpt_dir, step + 1, state,
                                extra={"train_step": step + 1,
                                       "data": data.state()})
            if guard.preempted:
                print("[preempt] SIGTERM received: checkpoint + exit")
                if args.ckpt_dir:
                    ckpt.save(args.ckpt_dir, step + 1, state,
                              extra={"train_step": step + 1,
                                     "data": data.state()})
                return 0

        ckpt.wait_pending()
        if args.ckpt_dir:
            ckpt.save(args.ckpt_dir, args.steps, state,
                      extra={"train_step": args.steps, "data": data.state()})
        print(f"final loss {losses[-1]:.4f} "
              f"(first {losses[0]:.4f}) over {len(losses)} steps")
        return 0


if __name__ == "__main__":
    raise SystemExit(main())
