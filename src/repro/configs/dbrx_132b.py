"""dbrx-132b [moe] — 16 experts top-4, fine-grained
[hf:databricks/dbrx-base; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe", n_layers=40, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=10752, vocab=100352, mlp_act="swiglu",
    n_experts=16, top_k=4)

SMOKE = CONFIG.scaled(n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
                      d_ff=64, vocab=128, n_experts=4, top_k=2)
