"""llama-3.2-vision-11b [vlm] — cross-attn image layers every 5, vision
tower STUB (input_specs provides patch embeddings)
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=128256, mlp_act="swiglu",
    cross_every=5, n_image_tokens=1024)

SMOKE = CONFIG.scaled(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=160, vocab=128, cross_every=2, n_image_tokens=16)
