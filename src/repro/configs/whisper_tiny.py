"""whisper-tiny [audio] — enc-dec, conv frontend STUB (input_specs provides
precomputed frame embeddings) [arXiv:2212.04356; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec", n_layers=4, d_model=384,
    n_heads=6, n_kv_heads=6, d_ff=1536, vocab=51865, mlp_act="gelu",
    n_enc_layers=4, n_frames=1500)

SMOKE = CONFIG.scaled(n_layers=2, d_model=48, n_heads=3, n_kv_heads=3,
                      d_ff=96, vocab=128, n_enc_layers=2, n_frames=32)
