"""qwen3-moe-30b-a3b [moe] — 128 experts top-8, d_ff(expert)=768
[hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=4, d_ff=768, vocab=151936, mlp_act="swiglu",
    n_experts=128, top_k=8)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=32, vocab=128, n_experts=8, top_k=2)
