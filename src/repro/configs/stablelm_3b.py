"""stablelm-3b [dense] — [hf:stabilityai/stablelm-2-1_6b; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense", n_layers=32, d_model=2560,
    n_heads=32, n_kv_heads=32, d_ff=6912, vocab=50304, mlp_act="swiglu")

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=128, vocab=128)
