"""Assigned-architecture registry: ``get_config(arch_id)`` + shape registry.

Every entry reproduces the published config verbatim (see per-file source
tags). ``SHAPES`` defines the four assigned input-shape cells; applicability
filtering (long_500k needs sub-quadratic attention) lives in
``runnable_cells``.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Tuple

from repro.models.config import ModelConfig

ARCH_IDS = [
    "stablelm_3b", "deepseek_7b", "nemotron_4_15b", "glm4_9b", "hymba_1_5b",
    "xlstm_350m", "qwen3_moe_30b_a3b", "dbrx_132b", "whisper_tiny",
    "llama_3_2_vision_11b",
]

ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    arch = ALIASES.get(arch, arch)
    if arch not in ARCH_IDS:
        raise ValueError(f"unknown arch {arch!r}; options: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def smoke_config(arch: str) -> ModelConfig:
    arch = ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.SMOKE


def runnable_cells() -> List[Tuple[str, str, str]]:
    """All (arch, shape, status) cells; status 'run' or a skip reason."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, s in SHAPES.items():
            if sname == "long_500k" and not cfg.sub_quadratic:
                out.append((arch, sname,
                            "skip: full-attention arch, 512k dense KV is "
                            "quadratic (DESIGN.md §4)"))
            else:
                out.append((arch, sname, "run"))
    return out
