"""glm4-9b [dense] — RoPE, GQA kv=2 [hf:THUDM/glm-4-9b; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", family="dense", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=2, d_ff=13696, vocab=151552, mlp_act="swiglu")

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=160, vocab=128)
