"""nemotron-4-15b [dense] — GQA kv=8, squared-ReLU [arXiv:2402.16819]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense", n_layers=32, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=24576, vocab=256000, mlp_act="sq_relu")

SMOKE = CONFIG.scaled(n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
                      d_ff=256, vocab=160)
