"""hymba-1.5b [hybrid] — parallel attn+mamba heads, GQA kv=5, ssm_state=16,
sliding-window attention (sub-quadratic) [arXiv:2411.13676; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", n_layers=32, d_model=1600,
    n_heads=25, n_kv_heads=5, d_ff=5504, vocab=32001, mlp_act="swiglu",
    ssm_state=16, ssm_expand=2, window=1024, ssm_chunk=128)

SMOKE = CONFIG.scaled(n_layers=2, d_model=80, n_heads=5, n_kv_heads=5,
                      d_ff=128, vocab=128, window=32, ssm_chunk=16)
