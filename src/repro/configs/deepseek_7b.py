"""deepseek-7b [dense] — llama-arch [arXiv:2401.02954; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense", n_layers=30, d_model=4096,
    n_heads=32, n_kv_heads=32, d_ff=11008, vocab=102400, mlp_act="swiglu")

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=160, vocab=128)
