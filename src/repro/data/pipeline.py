"""Deterministic synthetic token pipeline with a checkpointable cursor.

Production posture: the stream is a pure function of (seed, step), so resume
== replay from the cursor; no shuffle-buffer state needs snapshotting. Batches
are produced host-side as numpy and placed onto the mesh with the batch
sharding (data-parallel axes over the batch dim).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


class TokenPipeline:
    """Markov-ish synthetic LM stream (has learnable structure, so loss
    decreases under training — used by the end-to-end example)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.step = 0
        rng = np.random.default_rng(cfg.seed)
        # fixed random bigram table => learnable next-token structure
        k = min(cfg.vocab, 64)
        self._trans = rng.integers(0, cfg.vocab, size=(cfg.vocab, k))

    def state(self) -> Dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def restore(self, state: Dict):
        assert state["seed"] == self.cfg.seed, "data seed changed mid-run"
        self.step = int(state["step"])

    def next_batch(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, self.step))
        B, S = cfg.global_batch, cfg.seq_len
        toks = np.empty((B, S), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab, B)
        choice = rng.integers(0, self._trans.shape[1], (B, S))
        for t in range(1, S):
            toks[:, t] = self._trans[toks[:, t - 1], choice[:, t]]
        self.step += 1
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = toks[:, 0]
        return {"tokens": toks, "labels": labels}

    def iter(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()


def device_put_batch(batch: Dict[str, np.ndarray], shardings=None):
    if shardings is None:
        return jax.tree.map(jax.device_put, batch)
    return jax.tree.map(jax.device_put, batch, shardings)
