"""Fault-tolerant serving runtime over the Session API (DESIGN.md §10).

A :class:`~repro.core.api.Session` makes SAIF *fast* to serve; this
module makes it *safe* to serve. The SAFE line of work (El Ghaoui et
al. 2013) sells screening on a machine-checkable certificate — the
duality gap — and a production runtime must extend that certificate
discipline to every failure mode between the request and the result:

* **Admission control** — :func:`validate_problem` /
  :func:`validate_request` reject non-finite data, degenerate zero-norm
  columns, lam <= 0 and shape mismatches with a *typed* error taxonomy
  (:class:`RequestError`, :class:`NumericalError`, :class:`BackendFault`,
  :class:`DeadlineExceeded`) before anything reaches a compiled program.
  The types multiply-inherit the builtin they historically surfaced as
  (``ValueError``/``ArithmeticError``/``RuntimeError``/``TimeoutError``)
  so existing callers keep working.
* **Certified results** — every ``ServingSession.solve`` returns a
  :class:`ServingResult` ``(value, verdict)``. The :class:`Verdict`
  carries the final duality gap, a converged flag, h-overflow /
  precision-floor / retry events, and a *post-hoc KKT residual* of the
  returned support (:func:`repro.core.duality.kkt_residual`) checked
  against ``max(kkt_rtol * lam, kkt_atol)``. The KKT check is its own
  tiny jit, deliberately outside the engine caches, so the serving
  contract — zero new solver compilations at steady state — still holds.
* **Certified degradation** — a failed verdict walks a ladder:
  ``grow`` (re-solve with grown capacity / outer budget), ``oracle``
  (the unscreened CM solve — screening-free, so a screening bug cannot
  survive it), ``x64`` (retry in float64). Each rung is re-verified and
  recorded in ``verdict.rungs``; no silent failures, ever.
* **Fault containment** — transient backend ``RuntimeError``s are
  retried with jittered exponential backoff under a per-request deadline
  (:func:`repro.runtime.fault.retry_step`); per-compile-bucket
  :class:`~repro.runtime.fault.StragglerMonitor`s flag slow steps; a
  circuit breaker durably degrades a faulting backend (pallas -> jnp)
  for the rest of the session's lifetime.
* **Warm checkpoint/restore** — the session's device-resident warm
  boundary state (slot idx / beta / mask + InnerCarry) snapshots through
  ``repro.ckpt.checkpoint``'s atomic writes, keyed by a problem digest;
  a SIGTERM'd (``PreemptionGuard``) or restarted server resumes warm
  with zero extra compilations.

Module scope imports only stdlib + numpy: constructing a
:class:`~repro.core.api.Problem` (which validates here) keeps the lazy
surface contract of ``repro/__init__.py``.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import math
import random
import time
import warnings
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import numpy as np

__all__ = [
    "ServingError", "RequestError", "NumericalError", "BackendFault",
    "DeadlineExceeded",
    "validate_problem", "validate_request",
    "Rung", "Verdict", "ServingResult", "ServingConfig", "ServingStats",
    "ServingSession", "open_serving",
]


# ---------------------------------------------------------------------------
# typed error taxonomy (DESIGN.md §10)
# ---------------------------------------------------------------------------

class ServingError(Exception):
    """Root of the serving error taxonomy. Every admission / runtime
    failure the serving layer raises is a ServingError, and each subtype
    also IS the builtin it historically surfaced as, so pre-taxonomy
    ``except ValueError`` call sites keep working."""


class RequestError(ServingError, ValueError):
    """The request itself is malformed: bad shapes, lam <= 0, unknown
    loss, degenerate (zero-norm) columns. Client-side; never retried."""


class NumericalError(ServingError, ArithmeticError):
    """Non-finite data in, or a result that failed numerical
    certification (NaN coefficients, KKT violation) after the full
    degradation ladder."""


class BackendFault(ServingError, RuntimeError):
    """A compiled backend faulted persistently — retries exhausted and,
    where possible, the circuit breaker's degraded backend also failed."""


class DeadlineExceeded(ServingError, TimeoutError):
    """The per-request wall-clock budget ran out (during retries or
    between degradation rungs)."""


class _NonRetriable(Exception):
    """Internal carrier: an exception the retry loop must not eat
    (NotImplementedError and typed serving errors pass straight up)."""

    def __init__(self, cause: BaseException):
        super().__init__(cause)
        self.cause = cause


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

_KNOWN_LOSSES = ("least_squares", "logistic")


def _np(x) -> np.ndarray:
    return np.asarray(x)


def _require_finite(name: str, arr: np.ndarray) -> None:
    if not np.all(np.isfinite(arr)):
        bad = int(np.sum(~np.isfinite(arr)))
        raise NumericalError(
            f"{name} has {bad} non-finite entr{'y' if bad == 1 else 'ies'} "
            f"(NaN/Inf): admission control rejects it before it can reach "
            f"a compiled program")


def _require_lam(lam, what: str = "lam") -> None:
    arr = np.asarray(lam, dtype=np.float64)
    if arr.ndim > 1:
        raise RequestError(f"{what} must be a scalar or 1-D grid, got "
                           f"shape {arr.shape}")
    if not np.all(np.isfinite(arr)):
        raise RequestError(f"{what} must be finite, got {lam!r}")
    if not np.all(arr > 0.0):
        raise RequestError(
            f"{what} must be > 0 (lam = 0 is an unregularized fit the "
            f"screening certificate does not cover), got {lam!r}")


def validate_problem(problem) -> None:
    """Admission control for :class:`~repro.core.api.Problem` — runs at
    construction, so a malformed spec fails with a typed error before a
    session (let alone a compiled engine) ever sees it."""
    if problem.X is None:
        # a spec without a design is legal to *construct* (the legacy
        # surface allows it); open_session rejects it at serve time
        return
    X = _np(problem.X)
    if X.ndim != 2:
        raise RequestError(
            f"Problem.X must be 2-D (n, p), got shape {X.shape}")
    if X.shape[0] < 1 or X.shape[1] < 1:
        raise RequestError(f"Problem.X must be non-empty, got {X.shape}")
    _require_finite("Problem.X", X)
    norms = np.linalg.norm(X.astype(np.float64, copy=False), axis=0)
    dead = np.flatnonzero(norms == 0.0)
    if dead.size:
        raise RequestError(
            f"Problem.X has {dead.size} zero-norm (degenerate) column"
            f"{'s' if dead.size > 1 else ''} (e.g. {dead[:5].tolist()}): "
            f"a dead column has no screening statistic and can never "
            f"enter the support — drop it before building the Problem")
    if problem.loss not in _KNOWN_LOSSES:
        raise RequestError(
            f"unknown loss {problem.loss!r}; options: "
            f"{sorted(_KNOWN_LOSSES)}")
    n = X.shape[0]
    if problem.y is not None:
        y = _np(problem.y)
        if y.shape != (n,):
            raise RequestError(
                f"Problem.y must have shape ({n},) to match X "
                f"{X.shape}, got {y.shape}")
        _require_finite("Problem.y", y)
    if problem.weights is not None:
        w = _np(problem.weights)
        if w.shape != (n,):
            raise RequestError(
                f"Problem.weights must have shape ({n},), got {w.shape}")
        _require_finite("Problem.weights", w)
        if np.any(w < 0.0):
            raise RequestError("Problem.weights must be non-negative")
        if not np.any(w > 0.0):
            raise RequestError("Problem.weights must not be all zero")


def validate_request(req) -> None:
    """Admission control for Scalar/Path/Fleet/CV — duck-typed on the
    request's fields so this module never imports the (lazily loaded)
    api module at validation time."""
    kind = type(req).__name__
    if kind == "Scalar":
        _require_lam(req.lam, "Scalar.lam")
        if np.asarray(req.lam, dtype=np.float64).ndim != 0:
            raise RequestError(
                f"Scalar.lam must be a scalar, got shape "
                f"{np.asarray(req.lam).shape}; submit a Path for a grid")
    elif kind == "Path":
        lams = np.asarray(req.lams, dtype=np.float64)
        if lams.size == 0:
            raise RequestError("Path.lams must be a non-empty grid")
        _require_lam(lams, "Path.lams")
    elif kind == "Fleet":
        Y = _np(req.Y)
        if Y.ndim not in (1, 2):
            raise RequestError(
                f"Fleet.Y must be (n,) or (B, n), got shape {Y.shape}")
        _require_finite("Fleet.Y", Y)
        B = 1 if Y.ndim == 1 else Y.shape[0]
        lams = np.asarray(req.lams, dtype=np.float64)
        if lams.ndim == 1 and lams.shape[0] != B:
            raise RequestError(
                f"Fleet.lams must be a scalar or shape ({B},) to match "
                f"Y, got {lams.shape}")
        _require_lam(lams, "Fleet.lams")
        if req.weights is not None:
            w = _np(req.weights)
            if w.shape != Y.shape:
                raise RequestError(
                    f"Fleet.weights must match Y's shape {Y.shape}, "
                    f"got {w.shape}")
            _require_finite("Fleet.weights", w)
            if np.any(w < 0.0):
                raise RequestError("Fleet.weights must be non-negative")
            w2 = w if w.ndim == 2 else w[None, :]
            if not np.all(np.any(w2 > 0.0, axis=1)):
                raise RequestError(
                    "every Fleet.weights row needs a positive entry")
    elif kind == "CV":
        if int(req.n_folds) < 2:
            raise RequestError(
                f"CV.n_folds must be >= 2, got {req.n_folds}")
        lams = np.asarray(req.lams, dtype=np.float64)
        if lams.size == 0:
            raise RequestError("CV.lams must be a non-empty grid")
        _require_lam(lams, "CV.lams")
    elif kind == "Update":
        rows = _np(req.rows)
        if rows.ndim != 2 or rows.shape[0] < 1 or rows.shape[1] < 1:
            raise RequestError(
                f"Update.rows must be a non-empty (m, p) row block, got "
                f"shape {rows.shape}")
        _require_finite("Update.rows", rows)
        resp = _np(req.responses)
        if resp.shape != (rows.shape[0],):
            raise RequestError(
                f"Update.responses must have shape ({rows.shape[0]},) to "
                f"match rows {rows.shape}, got {resp.shape}")
        _require_finite("Update.responses", resp)
        if req.lam is not None:
            if np.asarray(req.lam, dtype=np.float64).ndim != 0:
                raise RequestError(
                    f"Update.lam must be a scalar (or None to re-solve at "
                    f"the session's last lambda), got shape "
                    f"{np.asarray(req.lam).shape}")
            _require_lam(req.lam, "Update.lam")
        if req.window is not None:
            w = int(req.window)
            if w < 1:
                raise RequestError(
                    f"Update.window must be a positive row count (or None "
                    f"for an append-only stream), got {req.window!r}")
            if w < rows.shape[0]:
                raise RequestError(
                    f"Update.window ({w}) must be >= the update batch "
                    f"({rows.shape[0]} rows); a single batch may not "
                    f"overflow the sliding window")
            # window >= resident-active-count is enforced at serve time
            # (core/online.py) where the active set is known
    elif kind == "Select":
        lams = np.asarray(req.lams, dtype=np.float64)
        if lams.size == 0:
            raise RequestError("Select.lams must be a non-empty grid")
        _require_lam(lams, "Select.lams")
        if int(req.n_folds) < 2:
            raise RequestError(
                f"Select.n_folds must be >= 2, got {req.n_folds}")
        if req.rule not in ("1se", "min"):
            raise RequestError(
                f"Select.rule must be '1se' or 'min', got {req.rule!r}")
        if req.stability:
            if int(req.n_subsamples) < 2:
                raise RequestError(
                    f"Select.n_subsamples must be >= 2 (selection "
                    f"frequencies need >= 2 subsamples), got "
                    f"{req.n_subsamples}")
            frac = float(req.subsample_frac)
            if not (0.0 < frac < 1.0):
                raise RequestError(
                    f"Select.subsample_frac must lie in (0, 1), got "
                    f"{req.subsample_frac!r}")
        pi = float(req.pi_threshold)
        if not (0.0 < pi <= 1.0):
            raise RequestError(
                f"Select.pi_threshold must lie in (0, 1], got "
                f"{req.pi_threshold!r}")
    # Serving knobs shared by every request kind (PR 8): the sync
    # ServingSession.solve() and the async Server.submit() accept the
    # same request values, so both are validated here.
    deadline = getattr(req, "deadline_s", None)
    if deadline is not None:
        d = float(deadline)
        if not math.isfinite(d) or d <= 0.0:
            raise RequestError(
                f"{kind}.deadline_s must be a finite positive number of "
                f"seconds (or None), got {deadline!r}")
    priority = getattr(req, "priority", 0)
    if not isinstance(priority, (int, np.integer)) or isinstance(
            priority, bool):
        raise RequestError(
            f"{kind}.priority must be an int (higher dequeues first), "
            f"got {priority!r}")


# ---------------------------------------------------------------------------
# verdicts
# ---------------------------------------------------------------------------

class Rung(NamedTuple):
    """One attempted degradation-ladder rung (DESIGN.md §10)."""
    name: str                   # "grow" | "oracle" | "x64"
    ok: bool                    # did the rung's result pass verification
    gap: float                  # worst duality gap of the rung's result
    kkt_residual: float         # worst KKT residual of the rung's result
    note: str = ""              # "skipped" / "error:..." / ""


class Verdict(NamedTuple):
    """The certificate attached to every served result.

    ``ok`` is the serving guarantee: the returned value passed numerical
    certification (finite + post-hoc KKT residual within tolerance; for
    penalties without a scalar KKT check, duality gap <= eps).
    ``converged`` is the stricter engine criterion ``gap <= eps`` — a
    result can be ``ok`` but not ``converged`` when the gap bottomed out
    at its arithmetic precision floor (DESIGN.md §3) yet the KKT
    residual certifies the support. ``events`` is the de-duplicated
    trail (retries, h-overflow, warm-state resets, breaker trips);
    ``rungs`` records every degradation attempt, in order."""
    ok: bool
    converged: bool
    gap: float
    kkt_residual: float
    kkt_tol: float
    events: Tuple[str, ...] = ()
    rungs: Tuple[Rung, ...] = ()
    degraded: bool = False
    retries: int = 0
    kkt_check_ms: float = 0.0
    # execution-mode provenance (DESIGN.md §11): which parity contract and
    # screening precision produced the certified value. The KKT check that
    # backs ``ok`` always runs in working precision, whatever these say.
    parity: str = "bitwise"
    screen_dtype: str = "working"
    # screening-rule provenance (DESIGN.md §13): which certificate
    # geometry produced the value — "saif" | "gap_safe" | "hybrid" | a
    # custom ScreenRule's name. The KKT certification behind ``ok`` is
    # rule-independent (it checks the returned value, not the rule).
    screen_rule: str = "saif"
    # Per-unit breakdown (one entry per lambda / fleet member), so a
    # coalescing front-end can attribute a failed certificate to the one
    # poisoned member of a microbatch instead of degrading every rider
    # (DESIGN.md §12). ``unit_ok[i]`` is unit i's final certification;
    # ``unit_degraded[i]`` marks units that failed the FIRST
    # certification pass and owe their final state to the degradation
    # ladder. None when no certification units were produced.
    unit_ok: Optional[Tuple[bool, ...]] = None
    unit_degraded: Optional[Tuple[bool, ...]] = None


class ServingResult(NamedTuple):
    value: Any                  # the engine result (type per request kind)
    verdict: Verdict


class ServingStats(NamedTuple):
    """Session-lifetime counters (benchmarks/bench_serve.py columns)."""
    requests: int
    degraded: int               # requests that needed >= 1 ladder rung
    retries: int                # transient-fault retries issued
    stragglers: int             # steps flagged by the monitors
    breaker_open: bool          # backend durably degraded to jnp
    restored: bool              # warm state came from a checkpoint
    kkt_check_ms: float         # cumulative certification time


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Policy knobs of the fault-tolerant runtime (DESIGN.md §10)."""
    max_retries: int = 2          # transient-fault retries per request
    backoff_base_s: float = 0.01  # first retry's nominal backoff
    backoff_mult: float = 2.0
    jitter: float = 0.5           # +- fraction on each backoff delay
    deadline_s: Optional[float] = None    # per-request wall-clock budget
    check_kkt: bool = True
    kkt_rtol: float = 1e-3        # tol = max(kkt_rtol * lam, kkt_atol)
    kkt_atol: float = 1e-8
    ladder: Tuple[str, ...] = ("grow", "oracle", "x64")
    oracle_tol: Optional[float] = None    # None => the engine's eps
    breaker_threshold: int = 1    # consecutive exhausted-retry failures
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0           # checkpoint every N ok requests (0=off)
    seed: int = 0                 # backoff-jitter rng seed
    straggler_factor: float = 3.0
    strict: bool = False          # raise NumericalError on a failed verdict


# ---------------------------------------------------------------------------
# the KKT certificate jit — deliberately OUTSIDE the engine caches, so
# certification never perturbs the zero-new-compilations serving contract
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _kkt_fn(loss_name: str):
    import jax
    from repro.core.duality import kkt_residual
    from repro.core.losses import get_loss
    loss = get_loss(loss_name)

    def residual(X, y, beta, lam, pen, sample_w):
        return kkt_residual(loss, X, y, beta, lam, pen=pen,
                            sample_w=sample_w)

    return jax.jit(residual)


@functools.lru_cache(maxsize=None)
def _kkt_fleet_fn(loss_name: str):
    """Vmapped fleet certificate: one dispatch for all B members
    (shared X, per-member y/beta/lam) instead of B scalar dispatches —
    the per-unit jit round-trips would dominate wide coalesced
    batches."""
    import jax
    from repro.core.duality import kkt_residual
    from repro.core.losses import get_loss
    loss = get_loss(loss_name)

    def residual(X, y, beta, lam, pen):
        return kkt_residual(loss, X, y, beta, lam, pen=pen,
                            sample_w=None)

    return jax.jit(jax.vmap(residual,
                            in_axes=(None, 0, 0, 0, None)))


def _wmax(a: float, b: float) -> float:
    """NaN-propagating max: a non-finite entry must dominate the
    verdict's worst-case fields, never be masked by a healthy one."""
    if math.isnan(a) or math.isnan(b):
        return float("nan")
    return max(a, b)


_deadline_kwarg_warned = False


def _warn_deadline_kwarg_once() -> None:
    """One-shot DeprecationWarning for ``solve(deadline_s=...)`` — the
    knob moved onto the request objects (``Scalar(..., deadline_s=)``)
    so sync and async submission accept identical request values."""
    global _deadline_kwarg_warned
    if not _deadline_kwarg_warned:
        _deadline_kwarg_warned = True
        warnings.warn(
            "ServingSession.solve(deadline_s=...) is deprecated; set "
            "deadline_s on the request object (e.g. Scalar(lam, "
            "deadline_s=...)) so the same request works with "
            "Server.submit()", DeprecationWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# the serving session
# ---------------------------------------------------------------------------

class ServingSession:
    """A :class:`~repro.core.api.Session` wrapped in the fault-tolerant
    runtime: every ``solve`` admits, retries, certifies, degrades and
    (optionally) checkpoints. Construct via :func:`open_serving`."""

    def __init__(self, problem, config=None, *, serving=None, guard=None,
                 **kwargs):
        from repro.core.api import open_session, session_kwargs
        self.serving = serving if serving is not None else ServingConfig()
        self.problem = problem
        # one shared passthrough spec (api.SESSION_KWARG_DEFAULTS) so
        # open_session / open_serving / open_server never drift
        self._opts = session_kwargs(**kwargs)
        self.session = open_session(problem, config, **self._opts)
        self.guard = guard
        self._rng = random.Random(self.serving.seed)
        self._monitors: Dict[tuple, Any] = {}
        self._breaker_failures = 0
        self.breaker_open = False
        self.restored = False
        self._preempt_ckpt = False
        self._requests = 0
        self._degraded = 0
        self._retries_total = 0
        self._stragglers = 0
        self._kkt_ms = 0.0
        self._step = 0
        self._last_unit_ok: List[bool] = []
        if self.serving.ckpt_dir:
            self.restored = self._maybe_restore()

    # -- passthrough surface -------------------------------------------

    def compile_stats(self):
        return self.session.compile_stats()

    @property
    def config(self):
        return self.session.config

    def stats(self) -> ServingStats:
        return ServingStats(
            requests=self._requests, degraded=self._degraded,
            retries=self._retries_total, stragglers=self._stragglers,
            breaker_open=self.breaker_open, restored=self.restored,
            kkt_check_ms=self._kkt_ms)

    # ------------------------------------------------------------------
    # the one entry point
    # ------------------------------------------------------------------

    def solve(self, request, *, deadline_s: Optional[float] = None
              ) -> ServingResult:
        """Serve one request under the full runtime: admission already
        ran at request construction; here the request is dispatched with
        retry/backoff and a deadline, the result is certified, and a
        failed certificate walks the degradation ladder. Returns
        ``(value, verdict)`` — a typed error (the taxonomy above) is the
        only other way out."""
        ser = self.serving
        t0 = time.monotonic()
        if deadline_s is not None:
            _warn_deadline_kwarg_once()
        deadline = getattr(request, "deadline_s", None)
        if deadline is None:
            deadline = ser.deadline_s if deadline_s is None else deadline_s
        self._requests += 1
        events: List[str] = []
        self._drain_preemption(events)

        retries = 0

        def on_retry(attempt: int, e: Exception) -> None:
            nonlocal retries
            retries += 1
            events.append(f"retry:{attempt}:{type(e).__name__}")

        value = self._primary(request, t0, deadline, on_retry, events)
        drain = getattr(self.session, "drain_events", None)
        if drain is not None:
            events += list(drain())
        self._retries_total += retries
        self._breaker_failures = 0      # a served request closes the streak

        kkt_ms0 = self._kkt_ms
        ok, converged, gap, kkt, tol, ev = self._verify(request, value)
        events += ev
        first_unit = tuple(self._last_unit_ok)
        final_unit = first_unit
        rungs: List[Rung] = []
        degraded = False
        if not ok:
            self._scrub_warm(request, events)
            best_value, best_score = value, _score(kkt, gap)
            best_unit = first_unit
            for name in ser.ladder:
                self._check_deadline(t0, deadline, f"ladder rung {name!r}")
                try:
                    cand = self._run_rung(name, request, value)
                except ServingError:
                    raise
                except Exception as e:   # noqa: BLE001 - a rung crashing
                    # must surface in the verdict, not mask it
                    rungs.append(Rung(name, False, float("nan"),
                                      float("nan"),
                                      f"error:{type(e).__name__}: {e}"))
                    continue
                if cand is None:
                    rungs.append(Rung(name, False, float("nan"),
                                      float("nan"), "skipped"))
                    continue
                value2, sess2 = cand
                degraded = True
                ok2, conv2, gap2, kkt2, _, ev2 = self._verify(
                    request, value2, sess=sess2)
                rungs.append(Rung(name, ok2, gap2, kkt2))
                if _score(kkt2, gap2) < best_score:
                    best_value, best_score = value2, _score(kkt2, gap2)
                    best_unit = tuple(self._last_unit_ok)
                if ok2:
                    ok, converged, gap, kkt = True, conv2, gap2, kkt2
                    value = value2
                    final_unit = tuple(self._last_unit_ok)
                    events += [f"degraded:{name}"] + ev2
                    break
            else:
                value = best_value
                final_unit = best_unit
                events.append("ladder_exhausted")
        if degraded:
            self._degraded += 1

        cfg = self.session.config
        rule = getattr(cfg, "screen_rule", "saif")   # str or ScreenRule
        verdict = Verdict(
            ok=ok, converged=converged, gap=gap, kkt_residual=kkt,
            kkt_tol=tol, events=tuple(dict.fromkeys(events)),
            rungs=tuple(rungs), degraded=degraded, retries=retries,
            kkt_check_ms=self._kkt_ms - kkt_ms0,
            parity=getattr(cfg, "parity", "bitwise"),
            screen_dtype=getattr(cfg, "screen_dtype", "working"),
            screen_rule=getattr(rule, "name", rule),
            unit_ok=final_unit or None,
            unit_degraded=(tuple(not u for u in first_unit)
                           if first_unit else None))
        if ok and ser.ckpt_every and self._requests % ser.ckpt_every == 0:
            self.checkpoint()
        if ser.strict and not ok:
            raise NumericalError(
                f"result failed certification after the full degradation "
                f"ladder: gap={gap:g}, kkt_residual={kkt:g} (tol {tol:g}), "
                f"events={verdict.events}")
        return ServingResult(value=value, verdict=verdict)

    # ------------------------------------------------------------------
    # primary dispatch: retry / backoff / deadline / breaker / straggler
    # ------------------------------------------------------------------

    def _primary(self, request, t0, deadline, on_retry, events):
        from repro.runtime.fault import (RetryDeadlineExceeded, StepFailed,
                                         StragglerMonitor, retry_step)
        ser = self.serving
        bucket = self._bucket(request)
        mon = self._monitors.get(bucket)
        if mon is None:
            mon = self._monitors[bucket] = StragglerMonitor(
                factor=ser.straggler_factor)

        def attempt():
            tA = time.monotonic()
            try:
                out = self.session.solve(request)
            except (NotImplementedError, ServingError) as e:
                raise _NonRetriable(e) from e
            if mon.record(time.monotonic() - tA):
                self._stragglers += 1
                events.append("straggler")
            return out

        remaining = None
        if deadline is not None:
            remaining = max(deadline - (time.monotonic() - t0), 0.0)
        try:
            return retry_step(
                attempt, max_retries=ser.max_retries,
                retriable=(RuntimeError,), on_retry=on_retry,
                backoff_base_s=ser.backoff_base_s,
                backoff_mult=ser.backoff_mult, jitter=ser.jitter,
                deadline_s=remaining, rng=self._rng)
        except _NonRetriable as e:
            raise e.cause
        except RetryDeadlineExceeded as e:
            raise DeadlineExceeded(
                f"request deadline ({deadline:g}s) exceeded while "
                f"retrying a transient backend fault: {e}") from e
        except StepFailed as e:
            return self._trip_breaker(request, e, events)

    def _trip_breaker(self, request, err, events):
        """Retries exhausted: durably degrade the faulting backend
        (pallas -> jnp) and give the degraded session one clean shot;
        anything else is a typed BackendFault."""
        self._breaker_failures += 1
        events.append("backend_fault")
        if self._breaker_failures >= self.serving.breaker_threshold \
                and self._open_degraded(events):
            try:
                return self.session.solve(request)
            except Exception as e2:
                raise BackendFault(
                    f"backend fault persisted on the degraded (jnp) "
                    f"backend: {e2}") from e2
        raise BackendFault(
            f"persistent backend fault (retries exhausted"
            f"{', breaker already open' if self.breaker_open else ''}): "
            f"{err}") from err

    def _open_degraded(self, events) -> bool:
        """Pin screen/inner backends to jnp for the session's remaining
        lifetime. Returns False when there is nothing left to degrade."""
        if self.breaker_open:
            return False
        cfg = self.session.config
        repl = {}
        if getattr(cfg, "screen_backend", "jnp") != "jnp":
            repl["screen_backend"] = "jnp"
        if getattr(cfg, "inner_backend", "jnp") != "jnp":
            repl["inner_backend"] = "jnp"
        if not repl:
            return False
        from repro.core.api import open_session
        cfg2 = dataclasses.replace(cfg, **repl)
        self.session = open_session(self.problem, cfg2, **self._opts)
        self.breaker_open = True
        events.append("breaker_open:" + ",".join(
            f"{k}=jnp" for k in sorted(repl)))
        return True

    def _bucket(self, request) -> tuple:
        """Compile-bucket key for the straggler monitors: requests that
        share a static signature share a latency distribution."""
        name = type(request).__name__.lower()
        cfg = self.session.config
        prep = getattr(self.session, "_prep", None)
        if name == "scalar" and prep is not None and hasattr(cfg, "c"):
            try:
                from repro.core.saif import add_batch_size_static
                h = add_batch_size_static(
                    cfg.c, float(request.lam), float(prep.c0_max),
                    float(prep.c0_median), int(prep.X.shape[1]))
                return (name, h)
            except Exception:       # pragma: no cover - stats unreadable
                pass
        return (name, 0)

    def _check_deadline(self, t0, deadline, where: str) -> None:
        if deadline is not None and time.monotonic() - t0 > deadline:
            raise DeadlineExceeded(
                f"request deadline ({deadline:g}s) exceeded before "
                f"{where}")

    def _drain_preemption(self, events) -> None:
        g = self.guard
        if g is not None and g.preempted and not self._preempt_ckpt:
            self._preempt_ckpt = True
            if self.checkpoint() is not None:
                events.append("preempted_checkpointed")

    # ------------------------------------------------------------------
    # certification
    # ------------------------------------------------------------------

    def _verify(self, request, value, sess=None):
        """Certify ``value``: finiteness, gap convergence and — where the
        scalar KKT conditions apply — the post-hoc KKT residual. Returns
        ``(ok, converged, gap, kkt, tol, events)`` worst-cased over the
        request's units (one per lambda / fleet member)."""
        sess = self.session if sess is None else sess
        ser = self.serving
        import jax.numpy as jnp
        events: List[str] = []
        eps = float(getattr(sess.config, "eps", 1e-6))
        max_outer = int(getattr(sess.config, "max_outer", 0))
        units = self._units(request, value, sess)
        ok, converged = True, True
        gap_w, kkt_w, tol_w = 0.0, 0.0, 0.0
        unit_ok: List[bool] = []
        t_k0 = time.perf_counter()
        for u in units:
            finite = bool(np.all(np.isfinite(np.asarray(u["beta"]))))
            g = float(u["gap"])
            finite = finite and math.isfinite(g)
            u_ok = finite
            if not finite:
                events.append("nonfinite")
            if u.get("overflowed"):
                events.append("h_overflow")
            if max_outer and u.get("n_outer", -1) >= max_outer:
                events.append("max_outer_exhausted")
            gap_w = _wmax(gap_w, g if math.isfinite(g) else float("nan"))
            if not (g <= eps):
                converged = False
                if finite:
                    # the engine stops at max(eps, precision floor): a
                    # finite gap above eps means the floor (or the outer
                    # budget) cut it short — the KKT check arbitrates
                    events.append("precision_floor"
                                  if u.get("n_outer", -1) < max_outer
                                  or not max_outer
                                  else "gap_above_eps")
            if u["kkt"] and ser.check_kkt:
                lam = float(u["lam"])
                tol = max(ser.kkt_rtol * lam, ser.kkt_atol)
                tol_w = max(tol_w, tol)
                X = u["X"]
                if u.get("kkt_r") is not None:   # batched fleet cert
                    r = u["kkt_r"]
                else:
                    r = float(_kkt_fn(sess.config.loss)(
                        X, u["y"], u["beta"],
                        jnp.asarray(lam, X.dtype), u["pen"],
                        u["sample_w"]))
                kkt_w = _wmax(kkt_w, r)
                if not (r <= tol):           # NaN residual fails too
                    u_ok = False
                    events.append("kkt_violation")
            else:
                # no scalar KKT conditions (group penalty, CV scores) or
                # checking disabled: the duality gap is the certificate
                u_ok = u_ok and (g <= eps)
            ok = ok and u_ok
            unit_ok.append(u_ok)
        self._kkt_ms += (time.perf_counter() - t_k0) * 1e3
        self._last_unit_ok = unit_ok
        return ok, converged, gap_w, kkt_w, tol_w, events

    def _units(self, request, value, sess) -> List[dict]:
        """Decompose a result into per-solution certification units.
        Each unit: beta/gap to check, the (X, y, lam, pen, sample_w)
        the KKT residual needs, and whether scalar KKT applies."""
        import jax.numpy as jnp
        from repro.core import api
        grouped = isinstance(sess.penalty, api.GroupPenalty)
        fusedp = isinstance(sess.penalty, api.FusedPenalty)

        def design():
            if fusedp:
                pen = jnp.ones(sess._design.Xt.shape[1],
                               sess._design.Xt.dtype
                               ).at[sess._design.unpen_idx].set(0.0)
                return sess._design.Xt, sess._y, pen
            X = jnp.asarray(sess.problem.X)
            y = None if sess.problem.y is None \
                else jnp.asarray(sess.problem.y, X.dtype)
            return X, y, None

        if isinstance(request, api.Scalar):
            if grouped:
                # group KKT is blockwise; certify by gap only
                return [dict(beta=value.beta, gap=value.gap,
                             lam=request.lam, kkt=False,
                             n_outer=int(value.n_outer))]
            X, y, pen = design()
            res = value[1] if fusedp else value
            sw = None if sess.problem.weights is None \
                else jnp.asarray(sess.problem.weights, X.dtype)
            return [dict(beta=res.beta, gap=res.gap, lam=request.lam,
                         kkt=True, X=X, y=y, pen=pen, sample_w=sw,
                         overflowed=bool(res.overflowed),
                         n_outer=int(res.n_outer))]

        if isinstance(request, api.Path):
            if grouped:
                return [dict(beta=r.beta, gap=r.gap, lam=float(lam),
                             kkt=False, n_outer=int(r.n_outer))
                        for lam, r in zip(value.lams, value.results)]
            X, y, pen = design()
            pr = value.path if fusedp else value
            return [dict(beta=b, gap=r.gap, lam=float(lam), kkt=True,
                         X=X, y=y, pen=pen, sample_w=None,
                         overflowed=bool(r.overflowed),
                         n_outer=int(r.n_outer))
                    for lam, b, r in zip(pr.lams, pr.betas, pr.results)]

        if isinstance(request, api.Fleet):
            X, _, pen = design()
            Y = jnp.asarray(request.Y, X.dtype)
            Y = Y[None, :] if Y.ndim == 1 else Y
            B = Y.shape[0]
            lams = np.broadcast_to(
                np.asarray(request.lams, np.float64).reshape(-1), (B,)) \
                if np.asarray(request.lams).ndim else \
                np.full((B,), float(request.lams))
            W = None
            if request.weights is not None:
                W = jnp.asarray(request.weights, X.dtype)
                W = W[None, :] if W.ndim == 1 else W
            # one host transfer per batched field, then free numpy
            # slicing — per-unit device reads would cost a dispatch +
            # sync each and dominate wide coalesced batches
            beta = np.asarray(value.beta)
            gap = np.asarray(value.gap)
            ovf = np.asarray(value.overflowed)
            nout = np.asarray(value.n_outer)
            kkt_r = None
            if self.serving.check_kkt and W is None:
                kkt_r = np.asarray(_kkt_fleet_fn(sess.config.loss)(
                    X, Y, value.beta,
                    jnp.asarray(lams, X.dtype), pen))
            Y_np = np.asarray(Y)    # host y slices for the fallback path
            return [dict(beta=beta[b], gap=gap[b],
                         lam=float(lams[b]), kkt=True, X=X, y=Y_np[b],
                         pen=pen,
                         sample_w=None if W is None else W[b],
                         kkt_r=None if kkt_r is None
                         else float(kkt_r[b]),
                         overflowed=bool(ovf[b]),
                         n_outer=int(nout[b]))
                    for b in range(B)]

        if isinstance(request, api.CV):
            X, y, pen = design()
            if value.beta is None:
                # scores-only CV: certify the score table's finiteness
                return [dict(beta=jnp.asarray(np.asarray(value.cv_mean)),
                             gap=0.0, lam=float(value.best_lam),
                             kkt=False)]
            res = value.best_result
            return [dict(beta=value.beta,
                         gap=(0.0 if res is None else res.gap),
                         lam=float(value.best_lam), kkt=True, X=X, y=y,
                         pen=pen, sample_w=None,
                         overflowed=False if res is None
                         else bool(res.overflowed),
                         n_outer=0 if res is None else int(res.n_outer))]

        if isinstance(request, api.Update):
            if value is None:        # resolve=False: ingest-only, nothing
                return []            # to certify until the next solve
            prep = sess._prep
            lam = getattr(sess, "_last_lam", None)
            # streaming design: the capacity-padding rows are exactly
            # zero, so the full padded (X, y) gives the same LS KKT
            # residual as the logical row set (DESIGN.md §14)
            return [dict(beta=value.beta, gap=value.gap,
                         lam=float(lam), kkt=True, X=prep.X, y=prep.y,
                         pen=None, sample_w=None,
                         overflowed=bool(value.overflowed),
                         n_outer=int(value.n_outer))]

        if isinstance(request, api.Select):
            if value.beta is None:
                # no refit requested: certify the CV score table's
                # finiteness at the chosen lambda (the CV idiom above)
                return [dict(beta=jnp.asarray(np.asarray(value.cv_mean)),
                             gap=0.0, lam=float(value.lam), kkt=False)]
            if getattr(sess, "_online", None) is not None:
                prep = sess._prep
                X, y, pen = prep.X, prep.y, None   # zero pad rows exact
            else:
                X, y, pen = design()
            res = value.best_result
            return [dict(beta=value.beta,
                         gap=(0.0 if res is None else res.gap),
                         lam=float(value.lam), kkt=True, X=X, y=y,
                         pen=pen, sample_w=None,
                         overflowed=False if res is None
                         else bool(res.overflowed),
                         n_outer=0 if res is None else int(res.n_outer))]

        raise RequestError(f"unknown request {request!r}")

    def _scrub_warm(self, request, events) -> None:
        """A failed solve may have harvested corrupt warm state (NaN
        coefficients in the slot buffers); reset the affected warm
        surface so later warm=True requests re-enter cold."""
        from repro.core import api
        if not isinstance(request, (api.Scalar, api.Path, api.Update)):
            return
        s = self.session
        if getattr(request, "sharded", False):
            s._sharded_warm, s._sharded_warm_k = None, None
        elif isinstance(s.penalty, api.GroupPenalty):
            s._gwarm = None
        else:
            s.set_warm_state(None, None)
            # a result seeded from the cross-request cache failed its
            # certificate: drop the seeding entry so repeat traffic
            # re-enters cold (DESIGN.md §14)
            drop = getattr(s, "drop_cache_entry", None)
            if drop is not None and drop():
                events.append("warm_cache_invalidated")
        events.append("warm_state_reset")

    # ------------------------------------------------------------------
    # the degradation ladder
    # ------------------------------------------------------------------

    def _run_rung(self, name, request, value):
        if name == "grow":
            return self._rung_grow(request)
        if name == "oracle":
            return self._rung_oracle(request, value)
        if name == "x64":
            return self._rung_x64(request)
        return None

    def _rung_grow(self, request):
        """Re-solve with grown active-set capacity and a 4x outer budget
        — the *safe-guarantee-preserving* rung: it still screens, so the
        gap certificate semantics are unchanged (DESIGN.md §10)."""
        from repro.core import api
        sess = self.session
        if isinstance(sess.penalty, api.GroupPenalty):
            return None
        if isinstance(request, api.Update):
            # replaying an Update on a fresh session of the ORIGINAL
            # problem would double-apply the rows; the oracle rung
            # re-solves the streamed problem instead
            return None
        if getattr(request, "sharded", False):
            return None
        if isinstance(request, api.Fleet) and request.screen_fn is not None:
            return None
        cfg = sess.config
        p = int(np.asarray(self.problem.X).shape[1])
        k2 = min(p, max(2 * (cfg.k_max or 0), 256))
        cfg2 = dataclasses.replace(cfg, k_max=k2,
                                   max_outer=cfg.max_outer * 4)
        tmp = api.open_session(self.problem, cfg2,
                               mesh=self._opts["mesh"],
                               segment_len=self._opts["segment_len"])
        req2 = dataclasses.replace(request, warm=False) \
            if isinstance(request, (api.Scalar, api.Path)) else request
        return tmp.solve(req2), tmp

    def _rung_oracle(self, request, value):
        """Re-solve the failed units with the unscreened CM oracle
        (``solve_lasso_cm``) — screening-free, so even a screening bug
        cannot survive it; the cost is the full O(np)-per-epoch sweep
        the paper's method exists to avoid. The safe guarantee is
        *vacuously* preserved (nothing is screened)."""
        from repro.core import api
        sess = self.session
        if isinstance(sess.penalty, api.GroupPenalty):
            return None
        fusedp = isinstance(sess.penalty, api.FusedPenalty)
        import jax.numpy as jnp
        failed = self._last_unit_ok

        if isinstance(request, api.Scalar):
            if fusedp:
                rec, res = value
                out = self._oracle_solve(sess._design.Xt, sess._y,
                                         float(request.lam), None)
                if out is None:
                    return None
                beta, gap = out
                res2 = _result_like(res, beta, gap)
                from repro.core.fused import recover_from_transformed
                return (recover_from_transformed(beta, sess._design),
                        res2), sess
            X = jnp.asarray(self.problem.X)
            y = jnp.asarray(self.problem.y, X.dtype)
            out = self._oracle_solve(X, y, float(request.lam),
                                     self.problem.weights)
            if out is None:
                return None
            beta, gap = out
            return _result_like(value, beta, gap), sess

        if isinstance(request, api.Path):
            pr = value.path if fusedp else value
            if fusedp:
                Xd, yd = sess._design.Xt, sess._y
            else:
                Xd = jnp.asarray(self.problem.X)
                yd = jnp.asarray(self.problem.y, Xd.dtype)
            betas, results = list(pr.betas), list(pr.results)
            for i, lam in enumerate(pr.lams):
                if i < len(failed) and failed[i]:
                    continue
                out = self._oracle_solve(Xd, yd, float(lam), None)
                if out is None:
                    return None
                b, g = out
                betas[i] = b
                results[i] = _result_like(results[i], b, g)
            from repro.core.path import SaifPathResult
            pr2 = SaifPathResult(lams=pr.lams, betas=betas,
                                 results=results,
                                 n_compilations=pr.n_compilations)
            if fusedp:
                from repro.core.fused import (FusedPathResult,
                                              recover_from_transformed)
                rec = [recover_from_transformed(b, sess._design)
                       for b in betas]
                return FusedPathResult(lams=pr.lams, betas=rec,
                                       path=pr2), sess
            return pr2, sess

        if isinstance(request, api.Fleet):
            X = jnp.asarray(self.problem.X)
            Y = jnp.asarray(request.Y, X.dtype)
            Y = Y[None, :] if Y.ndim == 1 else Y
            B = Y.shape[0]
            lams = np.broadcast_to(
                np.asarray(request.lams, np.float64).reshape(-1), (B,)) \
                if np.asarray(request.lams).ndim else \
                np.full((B,), float(request.lams))
            W = request.weights
            beta, gap = value.beta, value.gap
            n_act, ovf = value.n_active, value.overflowed
            for b in range(B):
                if b < len(failed) and failed[b]:
                    continue
                w_b = None
                if W is not None:
                    w_arr = np.asarray(W)
                    w_b = w_arr if w_arr.ndim == 1 else w_arr[b]
                out = self._oracle_solve(X, Y[b], float(lams[b]), w_b)
                if out is None:
                    return None
                ob, og = out
                beta = beta.at[b].set(jnp.asarray(ob, beta.dtype))
                gap = gap.at[b].set(jnp.asarray(og, gap.dtype))
                n_act = n_act.at[b].set(
                    jnp.asarray((jnp.abs(ob) > 0).sum(), n_act.dtype))
                ovf = ovf.at[b].set(False)
            return value._replace(beta=beta, gap=gap, n_active=n_act,
                                  overflowed=ovf), sess

        if isinstance(request, api.CV):
            if value.beta is None:
                return None
            X = jnp.asarray(self.problem.X)
            y = jnp.asarray(self.problem.y, X.dtype)
            out = self._oracle_solve(X, y, float(value.best_lam), None)
            if out is None:
                return None
            beta, gap = out
            res = value.best_result
            if res is not None:
                res = _result_like(res, beta, gap)
            return value._replace(beta=beta, best_result=res), sess

        if isinstance(request, api.Select):
            if value.beta is None:
                return None
            if getattr(sess, "_online", None) is not None:
                Xd, yd = sess._prep.X, sess._prep.y   # zero pad rows exact
            else:
                Xd = jnp.asarray(self.problem.X)
                yd = jnp.asarray(self.problem.y, Xd.dtype)
            out = self._oracle_solve(Xd, yd, float(value.lam), None)
            if out is None:
                return None
            beta, gap = out
            res = value.best_result
            if res is not None:
                res = _result_like(res, beta, gap)
            return value._replace(beta=beta, best_result=res), sess

        if isinstance(request, api.Update):
            prep = getattr(sess, "_prep", None)
            lam = getattr(sess, "_last_lam", None)
            if value is None or prep is None or lam is None:
                return None
            # the streamed problem lives in the session's padded prep;
            # zero pad rows make the unscreened LS oracle exact
            out = self._oracle_solve(prep.X, prep.y, float(lam), None)
            if out is None:
                return None
            beta, gap = out
            return _result_like(value, beta, gap), sess

        return None

    def _oracle_solve(self, X, y, lam: float, sample_w):
        """One unscreened CM solve to the serving tolerance, plus its
        own duality-gap certificate. Weighted least squares rides the
        sqrt-weight row rescaling; weighted non-quadratic losses have no
        oracle here (rung reports 'skipped')."""
        import jax.numpy as jnp
        from repro.core.cm import solve_lasso_cm
        from repro.core.duality import duality_gap, feasible_dual
        from repro.core.losses import get_loss
        cfg = self.session.config
        loss = get_loss(cfg.loss)
        if sample_w is not None:
            if cfg.loss != "least_squares":
                return None
            sw = jnp.sqrt(jnp.asarray(sample_w, X.dtype))
            X, y = X * sw[:, None], y * sw
        tol = self.serving.oracle_tol
        tol = float(getattr(cfg, "eps", 1e-6)) if tol is None else tol
        unpen = getattr(cfg, "unpen_idx", None)
        beta = solve_lasso_cm(loss, X, y, float(lam), tol=tol,
                              unpen_idx=unpen)
        lam_a = jnp.asarray(lam, X.dtype)
        pen = x_unpen = None
        if unpen is not None:
            pen = jnp.ones(X.shape[1], X.dtype).at[unpen].set(0.0)
            x_unpen = X[:, unpen]
        hat = -loss.grad(X @ beta, y) / lam_a
        theta = feasible_dual(loss, X, y, hat, lam_a, pen=pen,
                              x_unpen=x_unpen)
        gap = duality_gap(loss, X, y, beta, theta, lam_a, pen=pen)
        return beta, gap

    def _rung_x64(self, request):
        """Last rung: the whole problem re-cast to float64 — for
        precision-floor failures where the gap certificate bottomed out
        above the verdict tolerance in float32."""
        import jax
        from repro.core import api
        if not jax.config.jax_enable_x64:
            return None
        if isinstance(self.session.penalty, api.GroupPenalty):
            return None
        if isinstance(request, api.Update):
            return None     # same double-apply hazard as _rung_grow
        X = np.asarray(self.problem.X)
        y = self.problem.y
        y64 = None if y is None else np.asarray(y, np.float64)
        w = self.problem.weights
        already = X.dtype == np.float64 and (
            y is None or np.asarray(y).dtype == np.float64)
        if already:
            return None
        p64 = api.Problem(X.astype(np.float64), y64,
                          loss=self.problem.loss,
                          penalty=self.problem.penalty,
                          weights=None if w is None
                          else np.asarray(w, np.float64))
        tmp = api.open_session(p64, self.session.config,
                               mesh=self._opts["mesh"],
                               segment_len=self._opts["segment_len"])
        req2 = dataclasses.replace(request, warm=False) \
            if isinstance(request, (api.Scalar, api.Path)) else request
        return tmp.solve(req2), tmp

    # ------------------------------------------------------------------
    # warm checkpoint / restore (DESIGN.md §10 checkpoint layout)
    # ------------------------------------------------------------------

    def checkpoint(self) -> Optional[str]:
        """Atomically snapshot the session's device-resident warm
        boundary state. Layout: the ckpt module's one-.npy-per-leaf
        directory with leaf shapes/dtypes + the problem digest recorded
        in meta ``extra`` — restore needs no caller-supplied structure.
        No-op (None) without a ckpt_dir or before the first warm
        harvest."""
        ser = self.serving
        warm = self.session.warm_state
        if ser.ckpt_dir is None or warm is None:
            return None
        idx, beta, mask, inner = warm
        tree = {"idx": idx, "beta": beta, "mask": mask,
                "G": inner.G, "rho": inner.rho, "gidx": inner.gidx}
        leaves = {k: {"shape": list(np.shape(v)),
                      "dtype": str(np.asarray(v).dtype)}
                  for k, v in tree.items()}
        extra = {"kind": "saif-warm-state",
                 "k_max": self.session.warm_capacity,
                 "digest": self._digest(), "leaves": leaves,
                 "requests": self._requests}
        from repro.ckpt import checkpoint as ck
        self._step += 1
        return ck.save(ser.ckpt_dir, self._step, tree, extra=extra)

    def _maybe_restore(self) -> bool:
        """Resume warm from the latest matching checkpoint: digest-gated
        (a checkpoint of a *different* problem is ignored, not an
        error), structure rebuilt from the recorded shapes/dtypes."""
        from repro.ckpt import checkpoint as ck
        ser = self.serving
        step = ck.latest_step(ser.ckpt_dir)
        if step is None:
            return False
        try:
            meta = ck.load_meta(ser.ckpt_dir, step)
        except (OSError, ValueError):    # torn/garbage dir: stay cold
            return False
        extra = meta.get("extra", {})
        if extra.get("kind") != "saif-warm-state" \
                or extra.get("digest") != self._digest():
            return False
        import jax.numpy as jnp
        from repro.core.inner_backend import InnerCarry
        like = {k: jnp.zeros(tuple(v["shape"]), np.dtype(v["dtype"]))
                for k, v in extra["leaves"].items()}
        tree, _ = ck.restore(ser.ckpt_dir, step, like)
        warm = (tree["idx"], tree["beta"], tree["mask"],
                InnerCarry(G=tree["G"], rho=tree["rho"],
                           gidx=tree["gidx"]))
        self.session.set_warm_state(warm, extra["k_max"])
        self._step = step
        return True

    def _digest(self) -> str:
        """Problem identity for checkpoint gating: design + response +
        weights bytes, loss, penalty spec and the unpenalized slot.
        Backend knobs are deliberately excluded — warm state survives a
        circuit-breaker backend swap."""
        h = hashlib.sha256()
        pb = self.problem
        for arr in (pb.X, pb.y, pb.weights):
            if arr is None:
                h.update(b"<none>")
                continue
            a = np.ascontiguousarray(np.asarray(arr))
            h.update(str(a.shape).encode())
            h.update(str(a.dtype).encode())
            h.update(a.tobytes())
        h.update(pb.loss.encode())
        h.update(repr(self.session.penalty).encode())
        h.update(str(getattr(self.session.config,
                             "unpen_idx", None)).encode())
        return h.hexdigest()

    def close(self) -> None:
        """Flush pending async checkpoint writes, take a final warm
        snapshot and release the SIGTERM hook."""
        from repro.ckpt import checkpoint as ck
        ck.wait_pending()
        self.checkpoint()
        if self.guard is not None:
            self.guard.uninstall()


def _score(kkt: float, gap: float) -> float:
    """Ladder candidate ranking: lower is better, NaN is worst."""
    s = kkt if math.isfinite(kkt) else float("inf")
    g = gap if math.isfinite(gap) else float("inf")
    return s if s < float("inf") else g + 1e30


def _result_like(like, beta, gap):
    """Wrap an oracle solution in the engine's result type: beta/gap
    replaced, support fields recomputed, traces left as the failed
    solve's (the verdict's rung record is the authority on provenance)."""
    import jax.numpy as jnp
    k = like.active_idx.shape[-1]
    beta = jnp.asarray(beta, like.beta.dtype)
    nz = jnp.nonzero(jnp.abs(beta) > 0, size=k, fill_value=-1)[0]
    nz = nz.astype(like.active_idx.dtype)
    return like._replace(
        beta=beta, gap=jnp.asarray(gap, like.gap.dtype),
        n_active=jnp.asarray((jnp.abs(beta) > 0).sum(),
                             like.n_active.dtype),
        overflowed=jnp.zeros_like(like.overflowed),
        active_idx=nz, active_mask=nz >= 0)


def open_serving(problem, config=None, *, serving=None, guard=None,
                 install_sigterm: bool = False,
                 **session_kwargs) -> ServingSession:
    """Open a fault-tolerant serving session (DESIGN.md §10).

    Same signature as :func:`repro.core.api.open_session` — the
    passthrough ``session_kwargs`` are the one shared spec
    ``repro.core.api.SESSION_KWARG_DEFAULTS`` (``mesh``,
    ``segment_len``, ``make_screen``, ``pad_to``) — plus ``serving``
    (a :class:`ServingConfig`) and preemption wiring:
    ``install_sigterm=True`` installs a
    :class:`~repro.runtime.fault.PreemptionGuard` whose SIGTERM flag
    makes the next ``solve`` checkpoint the warm state; passing an
    existing ``guard`` reuses one. With ``serving.ckpt_dir`` set, a
    matching checkpoint is restored at open — a restarted server's
    first warm request re-enters exactly where the SIGTERM'd one left
    off."""
    if guard is None and install_sigterm:
        from repro.runtime.fault import PreemptionGuard
        guard = PreemptionGuard(install=True)
    return ServingSession(problem, config, serving=serving, guard=guard,
                          **session_kwargs)
