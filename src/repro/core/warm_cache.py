"""Cross-request homotopy cache (DESIGN.md §14).

Repeat traffic to a feature-selection service clusters: the same design
is queried at nearby lambdas (a user sweeping regularization, a client
retrying, CV followed by a refit). A :class:`WarmCache` is a host-side
LRU of device-resident exit warm states — ``(problem digest, lambda) ->
(WarmState, k_max)`` — shared across Sessions (the async Server hands
one instance to every session it opens). On a hit, the session enters
the solve through :func:`repro.core.path.seq_warm_entry`: the paper's
Theorem-2 sequential ball, seeded from the cached dual and widened by
the propagated gap radius, certifies which features can be active at
the requested lambda and pre-recruits them — skipping the cold
active-set growth that dominates cold-entry latency.

Hit/miss semantics: a cached entry at ``lam0`` serves a request at
``lam`` when ``lam <= lam0 <= band * lam`` — entering *downward* along
the regularization path, the direction Theorem 2 certifies; among
eligible entries the closest (smallest ``lam0/lam``) wins. Safety does
NOT rest on the band: the entry only *seeds* the active set, SAIF's own
ADD loop and stop test still run (under every ScreenRule the final stop
is gated by a full-safe-radius screen — the delta-ramped ADD-stop of
the ``saif`` rule, the explicit PR-9 safe post-check of ``hybrid``),
and the serving layer's KKT residual check certifies the result
end-to-end. A failed certification invalidates the entry
(:meth:`WarmCache.invalidate`, wired into the serving scrub path).

Module scope stays numpy+stdlib only (import-light contract); the
device work happens in ``path.seq_warm_entry`` at solve time.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
from collections import OrderedDict
from typing import Any, NamedTuple, Optional, Tuple

import numpy as np

__all__ = ["WarmCacheConfig", "WarmCache", "WarmCacheStats",
           "problem_digest"]


@dataclasses.dataclass(frozen=True)
class WarmCacheConfig:
    """Policy knobs for a :class:`WarmCache`.

    ``capacity`` — max resident entries (device memory per entry is a
    few (k_max,) buffers plus the (k_max, k_max) gram block).
    ``band`` — continuation band: an entry at lam0 serves lam when
    ``lam <= lam0 <= band * lam``. Wider bands trade entry-ball
    tightness for hit rate; safety is independent of the band (see the
    module docstring).
    """
    capacity: int = 32
    band: float = 4.0

    def __post_init__(self):
        if int(self.capacity) < 1:
            raise ValueError(
                f"WarmCacheConfig.capacity must be >= 1, got "
                f"{self.capacity!r}")
        if not float(self.band) >= 1.0:
            raise ValueError(
                f"WarmCacheConfig.band must be >= 1, got {self.band!r}")


class WarmCacheStats(NamedTuple):
    hits: int = 0
    misses: int = 0
    puts: int = 0
    evictions: int = 0
    invalidations: int = 0


class _Entry(NamedTuple):
    lam0: float
    warm: Any          # path.WarmState (device arrays)
    k_max: int


def problem_digest(X, y) -> str:
    """Content digest of a (design, response) pair — the cache key's
    problem half. Hashes the exact bytes the session solves (for a
    bucket-padded session, the padded arrays), so hits can only occur
    between sessions whose compiled problems are identical."""
    h = hashlib.sha256()
    for arr in (X, y):
        a = np.asarray(arr)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


class WarmCache:
    """Thread-safe LRU of exit warm states keyed by (digest, lambda).

    One instance may be shared across Sessions/threads (the Server hands
    its configured cache to every session in its LRU); all state
    transitions hold an internal lock. The stored values are immutable
    device-array tuples, so readers never observe a torn entry.
    """

    def __init__(self, config: Optional[WarmCacheConfig] = None):
        self.config = config or WarmCacheConfig()
        self._lock = threading.Lock()
        self._entries: "OrderedDict[Tuple[str, str], _Entry]" = \
            OrderedDict()
        self._hits = self._misses = self._puts = 0
        self._evictions = self._invalidations = 0

    @staticmethod
    def _key(digest: str, lam: float) -> Tuple[str, str]:
        return (digest, f"{float(lam):.12g}")

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def stats(self) -> WarmCacheStats:
        with self._lock:
            return WarmCacheStats(self._hits, self._misses, self._puts,
                                  self._evictions, self._invalidations)

    def lookup(self, digest: str, lam: float) -> Optional[_Entry]:
        """Closest cached entry whose continuation band covers ``lam``
        (None on miss). Counts a hit/miss and refreshes LRU order."""
        lam = float(lam)
        band = float(self.config.band)
        best_key = None
        best = None
        with self._lock:
            for key, entry in self._entries.items():
                if key[0] != digest:
                    continue
                # downward continuation only: lam <= lam0 <= band * lam
                # (1e-12 slack keeps exact repeats on the hit path)
                if not (entry.lam0 >= lam * (1.0 - 1e-12)
                        and entry.lam0 <= band * lam):
                    continue
                if best is None or entry.lam0 < best.lam0:
                    best_key, best = key, entry
            if best is None:
                self._misses += 1
                return None
            self._hits += 1
            self._entries.move_to_end(best_key)
            return best

    def store(self, digest: str, lam: float, warm: Any,
              k_max: int) -> None:
        """Insert/refresh the exit warm state of a solve at ``lam``."""
        key = self._key(digest, lam)
        with self._lock:
            self._entries[key] = _Entry(float(lam), warm, int(k_max))
            self._entries.move_to_end(key)
            self._puts += 1
            while len(self._entries) > int(self.config.capacity):
                self._entries.popitem(last=False)
                self._evictions += 1

    def invalidate(self, digest: str,
                   lam: Optional[float] = None) -> int:
        """Drop one entry (``lam`` given) or every entry of a problem —
        the serving layer's scrub path calls this when a result fails
        KKT certification. Returns the number of entries removed."""
        with self._lock:
            if lam is not None:
                removed = self._entries.pop(self._key(digest, lam),
                                            None)
                n = 0 if removed is None else 1
            else:
                keys = [k for k in self._entries if k[0] == digest]
                for k in keys:
                    del self._entries[k]
                n = len(keys)
            self._invalidations += n
            return n

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
