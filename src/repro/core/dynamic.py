"""Gap-safe dynamic screening baseline (Ndiaye et al. 2015; Fercoq et al. 2015).

Starts from the *full* feature set, interleaves K CM epochs with gap-safe
screening, and physically compacts the design matrix when enough features have
been screened (the real implementations shrink their working matrices too —
without compaction the wall-clock comparison against SAIF would be unfair in
dynamic screening's favor on vectorized hardware, since masked coordinates
still burn ALU).

The stage loop lives at host level (shape changes => recompile per
compaction); each stage is a single jitted while_loop.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cm import cm_epoch
from repro.core.duality import duality_gap, feasible_dual, gap_ball
from repro.core.losses import get_loss


@dataclasses.dataclass(frozen=True)
class DynConfig:
    eps: float = 1e-6
    inner_epochs: int = 5
    max_outer: int = 20000
    compact_ratio: float = 0.7   # compact when surviving fraction < this
    loss: str = "least_squares"


class DynResult(NamedTuple):
    beta: jax.Array
    gap: jax.Array
    n_outer: int
    coord_updates: int      # total coordinate-update count (complexity proxy)
    survivor_history: list  # feature count after each stage


class _Stage(NamedTuple):
    beta: jax.Array
    z: jax.Array
    mask: jax.Array
    gap: jax.Array
    t: jax.Array


@partial(jax.jit, static_argnames=("loss_name", "inner_epochs", "max_outer"))
def _stage_jit(X, y, col_norm, beta, mask, lam, eps, frac_target,
               *, loss_name, inner_epochs, max_outer):
    """Run outer iterations until gap<=eps OR survivors < frac_target."""
    loss = get_loss(loss_name)

    def cond(s: _Stage):
        frac = jnp.sum(s.mask) / s.mask.shape[0]
        return (s.gap > eps) & (s.t < max_outer) & (frac >= frac_target)

    def body(s: _Stage) -> _Stage:
        def cm_body(_, carry):
            beta, z = carry
            return cm_epoch(loss, X, y, beta, z, s.mask, lam)
        beta, z = jax.lax.fori_loop(0, inner_epochs, cm_body,
                                    (s.beta, X @ s.beta))
        hat = -loss.grad(z, y) / lam
        theta = feasible_dual(loss, X, y, hat, lam, s.mask)
        gap = duality_gap(loss, X, y, beta, theta, lam, s.mask)
        ball = gap_ball(loss, theta, gap, lam)
        corr = jnp.abs(X.T @ ball.center)
        keep = s.mask & ~(corr + col_norm * ball.radius < 1.0)
        beta = jnp.where(keep, beta, 0.0)
        return _Stage(beta=beta, z=z, mask=keep, gap=gap, t=s.t + 1)

    s0 = _Stage(beta=beta, z=X @ beta, mask=mask,
                gap=jnp.asarray(jnp.inf, X.dtype), t=jnp.asarray(0))
    s = jax.lax.while_loop(cond, body, s0)
    return s.beta, s.mask, s.gap, s.t


def dynamic_screening(X, y, lam: float,
                      config: DynConfig = DynConfig()) -> DynResult:
    loss = get_loss(config.loss)
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    p = X.shape[1]
    lam = jnp.asarray(lam, X.dtype)

    live_idx = np.arange(p)              # global ids of current columns
    Xc = X
    beta_c = jnp.zeros((p,), X.dtype)
    mask = jnp.ones((p,), bool)
    total_outer = 0
    coord_updates = 0
    history = [p]
    gap = jnp.inf

    while True:
        col_norm = jnp.linalg.norm(Xc, axis=0)
        beta_c, mask, gap, t = _stage_jit(
            Xc, y, col_norm, beta_c, mask, lam,
            jnp.asarray(config.eps, X.dtype), config.compact_ratio,
            loss_name=config.loss, inner_epochs=config.inner_epochs,
            max_outer=config.max_outer - total_outer)
        total_outer += int(t)
        coord_updates += int(t) * config.inner_epochs * Xc.shape[1]
        if float(gap) <= config.eps or total_outer >= config.max_outer:
            break
        # compact: keep surviving columns only (recompile at new width)
        keep_np = np.asarray(mask)
        if keep_np.sum() == 0 or keep_np.sum() == len(keep_np):
            # nothing screened this stage but gap not reached: continue as-is
            # (loop again; while_loop exited only on frac, so this is rare)
            if keep_np.sum() == len(keep_np):
                continue
            break
        live_idx = live_idx[keep_np]
        Xc = Xc[:, keep_np]
        beta_c = beta_c[keep_np]
        mask = jnp.ones((len(live_idx),), bool)
        history.append(len(live_idx))

    beta_full = jnp.zeros((p,), X.dtype).at[live_idx].set(
        jnp.where(mask, beta_c, 0.0))
    return DynResult(beta=beta_full, gap=gap, n_outer=total_outer,
                     coord_updates=coord_updates, survivor_history=history)
