"""Unified Problem/Session serving API — one declarative spec, one
persistent compiled session for every SAIF workload (DESIGN.md §9).

The SAFE line of work (El Ghaoui et al. 2013; Liu et al. 2014) frames safe
screening as a reusable *pre-solve service*, not a one-shot call — and the
repo's engines already price their economics that way: preparation
(c0 / column norms / the Theorem-6 transform / fleet prep) is one-time,
compilations are keyed on static shapes and meant to be reused, and warm
slot buffers hand device-resident state from one solve to the next. What
was missing is the object that *owns* that state across calls. This module
is that object:

  * :class:`Problem` — the declarative spec: design ``X``, response(s)
    ``y``, ``loss``, penalty ∈ {:func:`lasso` (default), :func:`fused`
    (tree ``parent``), :func:`group` (``gsize``)}, optional sample
    ``weights``.
  * :func:`open_session` — performs preparation exactly once, resolves the
    screen/inner backends through the existing ``resolve_*`` policies, and
    returns a long-lived :class:`Session`.
  * ``session.solve(request)`` — ONE entry point for every workload. A
    request is :class:`Scalar`, :class:`Path`, :class:`Fleet` or
    :class:`CV` — any of them with ``sharded=True`` to ride the §5
    feature-sharded screening collective (the session needs a ``mesh``).
  * ``session.compile_stats()`` — the per-module compile counters
    (``saif_jit_compile_count`` / ``saif_batch_compile_count`` /
    ``group_compile_count``) unified into one report; the serving
    contract is *one compilation per static key across the whole request
    stream*, asserted in tests/test_api.py.

Dispatch lands on the existing engines — ``_saif_jit`` via
:func:`repro.core.saif.solve_scalar`, the compile-first path engine
:func:`repro.core.path.run_path`, the fleet engine
:func:`repro.core.batch.fleet_solve`, :func:`repro.core.cv.cv_solve`,
:func:`repro.core.group.group_solve` and the sharded drivers — so session
results are BITWISE those of the legacy frontends (which are now thin
deprecated shims over one-shot sessions; migration table in DESIGN.md §9).

Default requests are *cold* (bitwise-reproducible, parity-testable);
``Scalar(lam, warm=True)`` / ``Path(lams, warm=True)`` opt into the
device-resident warm handoff — the previous solve's slot layout and inner
(Gram) carry seed the next solve exactly like the intra-path warm starts,
now *across* requests. That plus the persistent jit caches is what makes a
hot session serve a request stream at solve cost instead of
compile+prep+solve cost (benchmarks/bench_serve.py).

This module imports nothing jax-heavy at module scope: ``from repro
import Problem, Scalar, open_session`` stays cheap, and the engines load
on first use (the lazy surface contract of ``repro/__init__.py``).
"""
from __future__ import annotations

import dataclasses
import sys
from typing import Any, List, NamedTuple, Optional, Tuple

import numpy as np

# streaming / model-selection request types (DESIGN.md §14) — both
# modules are import-light (numpy+stdlib at module scope), so re-export
# here keeps `from repro import Update, Select` on the cheap path while
# serving.py can isinstance-dispatch on api.Update / api.Select
from repro.core.online import Update
from repro.core.select import Select, SelectionReport

__all__ = [
    "Problem", "Session", "open_session",
    "Scalar", "Path", "Fleet", "CV", "Update", "Select",
    "SelectionReport",
    "lasso", "fused", "group",
    "LassoPenalty", "FusedPenalty", "GroupPenalty",
    "GroupPathResult", "CompileStats", "unified_compile_count",
]


# ---------------------------------------------------------------------------
# penalty specs (plain data — no engine imports)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LassoPenalty:
    """Plain l1 penalty (the paper's Sections 2-3 problem)."""


@dataclasses.dataclass(frozen=True)
class FusedPenalty:
    """Tree fused-LASSO penalty ``lam * ||D beta||_1`` over the tree
    encoded by ``parent`` (Sec 4 / DESIGN.md §7). The session performs the
    Theorem-6 transform exactly once at ``open_session``."""
    parent: Any                       # (p,) parent ids, -1 at the root
    transform_backend: str = "auto"   # "auto" | "scan" | "pallas"


@dataclasses.dataclass(frozen=True)
class GroupPenalty:
    """Disjoint equal-size group-LASSO penalty (the paper's proposed
    extension; DESIGN.md §9)."""
    gsize: int


def lasso() -> LassoPenalty:
    """Penalty spec: plain LASSO (also the default, spelled ``"lasso"``)."""
    return LassoPenalty()


def fused(parent, transform_backend: str = "auto") -> FusedPenalty:
    """Penalty spec: tree fused LASSO over ``parent`` (−1 marks the root)."""
    return FusedPenalty(parent=np.asarray(parent),
                        transform_backend=transform_backend)


def group(gsize: int) -> GroupPenalty:
    """Penalty spec: group LASSO with consecutive groups of size ``gsize``."""
    return GroupPenalty(gsize=int(gsize))


def _coerce_penalty(pen) -> Any:
    if pen is None or pen == "lasso":
        return LassoPenalty()
    if isinstance(pen, (LassoPenalty, FusedPenalty, GroupPenalty)):
        return pen
    raise TypeError(
        f"unknown penalty spec {pen!r}: use 'lasso', lasso(), "
        f"fused(parent) or group(gsize)")


# ---------------------------------------------------------------------------
# the declarative problem spec + requests
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class Problem:
    """What to solve — independent of how and how often it will be served.

    ``y`` may be omitted for a fleet-only session (every :class:`Fleet`
    request carries its own responses). ``weights`` are optional sample
    weights for the default response; weighted problems ride the fleet
    engine (DESIGN.md §8), which is the one place the weighted gradient
    algebra lives.
    """
    X: Any
    y: Any = None
    loss: str = "least_squares"
    penalty: Any = "lasso"
    weights: Any = None

    def __post_init__(self):
        # admission control (DESIGN.md §10): non-finite data, degenerate
        # zero-norm columns, shape mismatches fail HERE with a typed
        # error — they never reach the compiled path
        from repro.core.serving import validate_problem
        validate_problem(self)


@dataclasses.dataclass(frozen=True)
class Scalar:
    """One solve at ``lam``. ``warm=True`` seeds from the session's
    device-resident warm state (slot layout + inner carry of the previous
    serial solve); the default is a cold, bitwise-reproducible solve.

    ``deadline_s``/``priority`` are the serving knobs shared by the sync
    ``ServingSession.solve()`` and the async ``Server.submit()``: a
    request past its deadline fails with ``DeadlineExceeded`` instead of
    occupying a solver, and higher-priority requests dequeue first.
    """
    lam: float
    warm: bool = False
    sharded: bool = False
    deadline_s: Optional[float] = None
    priority: int = 0

    def __post_init__(self):
        from repro.core.serving import validate_request
        validate_request(self)


@dataclasses.dataclass(frozen=True, eq=False)
class Path:
    """A descending lambda grid on the compile-first path engine.
    ``warm=True`` enters the grid from the session's warm state instead of
    the cold top-h start."""
    lams: Any
    warm: bool = False
    sharded: bool = False
    deadline_s: Optional[float] = None
    priority: int = 0

    def __post_init__(self):
        from repro.core.serving import validate_request
        validate_request(self)


@dataclasses.dataclass(frozen=True, eq=False)
class Fleet:
    """B lockstep solves over the shared design: per-request responses
    ``Y`` ((B, n) — a (n,) vector is a fleet of 1), scalar-or-(B,)
    ``lams``, optional (B, n) sample ``weights``. ``screen_fn`` is the
    advanced hook for a custom batched screening backend."""
    Y: Any
    lams: Any
    weights: Any = None
    sharded: bool = False
    screen_fn: Any = None
    deadline_s: Optional[float] = None
    priority: int = 0

    def __post_init__(self):
        from repro.core.serving import validate_request
        validate_request(self)


@dataclasses.dataclass(frozen=True, eq=False)
class CV:
    """K-fold cross-validation over a lambda grid (one fold-fleet
    compilation; DESIGN.md §8), scored by mean held-out loss, optionally
    refit at the winner."""
    n_folds: int
    lams: Any
    seed: int = 0
    keep_fold_betas: bool = False
    refit: bool = True
    sharded: bool = False
    deadline_s: Optional[float] = None
    priority: int = 0

    def __post_init__(self):
        from repro.core.serving import validate_request
        validate_request(self)


class GroupPathResult(NamedTuple):
    """Lambda path over a group-LASSO problem (a session-only workload —
    the legacy surface had no group path)."""
    lams: np.ndarray
    betas: List[Any]
    results: List[Any]                    # GroupSaifResult per lambda
    n_compilations: Optional[int] = None  # _gsaif_jit compiles added


# ---------------------------------------------------------------------------
# the shared session-kwargs spec (ONE signature for the whole entry-point
# family: open_session / open_serving / open_server all accept exactly
# these passthrough knobs — no drifting copies)
# ---------------------------------------------------------------------------

SESSION_KWARG_DEFAULTS = {
    "mesh": None,          # device mesh enabling sharded=True requests
    "segment_len": 16,     # path-engine overflow-sync segment length
    "make_screen": None,   # custom ScreenFn factory (h -> ScreenFn)
    "pad_to": None,        # (n_bucket, p_bucket) compile-bucket padding
    "warm_cache": None,    # shared cross-request homotopy WarmCache (§14)
}


def session_kwargs(**kw) -> dict:
    """Validate and normalize the shared session passthrough kwargs."""
    unknown = sorted(set(kw) - set(SESSION_KWARG_DEFAULTS))
    if unknown:
        raise TypeError(
            f"unknown session kwargs {unknown}; the shared spec accepts "
            f"{sorted(SESSION_KWARG_DEFAULTS)}")
    out = dict(SESSION_KWARG_DEFAULTS)
    out.update(kw)
    return out


# ---------------------------------------------------------------------------
# unified compile accounting
# ---------------------------------------------------------------------------

class CompileStats(NamedTuple):
    """Unified view of every solver-core jit cache (DESIGN.md §9).

    ``serial``/``fleet``/``group`` are the process-wide cache sizes of
    ``_saif_jit`` / ``_saif_batch_jit`` / ``_gsaif_jit`` (-1 if the jit
    internals moved); ``since_open`` is the total's delta since the
    session opened — the number every serving assertion watches: across
    any request stream it must equal the number of *distinct static
    keys*, never the number of requests.
    """
    serial: int
    fleet: int
    group: int
    total: int
    since_open: int
    requests: int


def _cache_size(mod_name: str, fn_name: str) -> int:
    """Cache size of one engine's jit, 0 if the module was never imported
    (an un-imported engine has compiled nothing), -1 if unreadable."""
    mod = sys.modules.get(mod_name)
    if mod is None:
        return 0
    try:
        return int(getattr(mod, fn_name)._cache_size())
    except Exception:       # pragma: no cover - jit internals moved
        return -1


def _engine_cache_sizes() -> Tuple[int, int, int]:
    return (_cache_size("repro.core.saif", "_saif_jit"),
            _cache_size("repro.core.batch", "_saif_batch_jit"),
            _cache_size("repro.core.group", "_gsaif_jit"))


def unified_compile_count() -> int:
    """Total solver-core compilations alive in this process: the serial,
    fleet and group engine caches in one number (supersedes reading the
    three per-module counters separately)."""
    sizes = _engine_cache_sizes()
    if min(sizes) < 0:
        return -1
    return sum(sizes)


# ---------------------------------------------------------------------------
# the session
# ---------------------------------------------------------------------------

class Session:
    """A long-lived solver for one :class:`Problem`.

    Owns, for its whole lifetime:

      * the one-time preparation (``PathState`` c0/col-norm stats, the
        Theorem-6 ``FusedDesign``, the ``GroupPrep``, the sharded design
        placement) — requests never re-prepare;
      * the resolved screen-backend policy and the per-h screen-function
        memo (ScreenFn objects are jit-static arguments, so they must be
        *the same object* across requests to share a compilation);
      * the device-resident warm state — slot layout, coefficients and
        inner (Gram) carry of the last serial solve, used by
        ``warm=True`` requests;
      * the request/compile accounting behind :meth:`compile_stats`.

    Construct via :func:`open_session`. Results are exactly the legacy
    frontends' result types (``SaifResult``, ``SaifPathResult``,
    ``FusedPathResult``, ``CVPathResult``, ``GroupSaifResult``, ...), and
    for default (cold) requests they are bitwise the legacy results.
    """

    def __init__(self, problem: Problem, config=None, **kwargs):
        kw = session_kwargs(**kwargs)
        self.problem = problem
        self.penalty = _coerce_penalty(problem.penalty)
        self.mesh = kw["mesh"]
        self._segment_len = kw["segment_len"]
        self._make_screen = kw["make_screen"]
        self._pad_to = kw["pad_to"]
        self._p_real = None             # real width when pad_to is set
        self._screen_memo = {}          # h -> ScreenFn (make_screen hook)
        self._sharded = None            # ShardedDesign, built lazily
        self._sharded_screen_memo = {}  # h -> sharded ScreenFn
        self._sharded_prep = None       # PathState over the padded design
        self._sharded_fleet = None      # fleet placement (c0 slot unused)
        self._sharded_fleet_screens = {}  # h -> batched sharded ScreenFn
        self._warm = None               # serial WarmState handoff
        self._warm_k = None
        self._sharded_warm = None
        self._sharded_warm_k = None
        self._gwarm = None              # group (gidx, gmask, beta_slots)
        self._requests = 0
        # streaming + homotopy-cache state (DESIGN.md §14)
        self._warm_cache = kw["warm_cache"]  # shared WarmCache or None
        self._online = None             # OnlineState once streaming
        self._last_lam = None           # last solved lambda (Update default)
        self._pending_events = []       # provenance drained by serving
        self._cache_last = None         # (digest, lam) of last cache store
        self._digest_memo = None        # problem digest, computed once

        if problem.X is None:
            raise ValueError("Problem.X is required")

        if self._pad_to is not None:
            # compile-bucket padding (DESIGN.md §12): the session holds a
            # bucket-shaped preparation whose stats were computed on the
            # real problem; results are sliced back to the real width.
            nb, pb = (int(self._pad_to[0]), int(self._pad_to[1]))
            n0, p0 = np.shape(problem.X)
            if nb < n0 or pb < p0:
                raise ValueError(
                    f"pad_to={self._pad_to} must dominate the problem "
                    f"shape ({n0}, {p0}) — buckets only pad, never crop")
            if problem.loss == "logistic" and nb > n0:
                raise NotImplementedError(
                    "row padding a logistic problem shifts the primal by "
                    "log(2) per pad row (the zero-row trick is exact for "
                    "least squares only); bucket logistic requests on "
                    "exact n (p-only padding), DESIGN.md §12")
            if problem.weights is not None:
                raise NotImplementedError(
                    "pad_to with sample weights: weighted problems ride "
                    "the fleet engine with per-problem column norms; "
                    "serve them from an unpadded session")
            if self._make_screen is not None:
                raise NotImplementedError(
                    "pad_to with a custom make_screen: the built-in "
                    "screens mask pad columns via the traced pad mask; a "
                    "custom backend would need its own masking")
            if not isinstance(_coerce_penalty(problem.penalty),
                              LassoPenalty):
                raise NotImplementedError(
                    "pad_to serves plain-LASSO problems (the fused "
                    "transform and group layout are shape-coupled)")
            self._pad_to = (nb, pb)
            self._p_real = p0

        if isinstance(self.penalty, GroupPenalty):
            from repro.core.group import GroupSaifConfig, prepare_group
            cfg = config if config is not None else GroupSaifConfig()
            if not isinstance(cfg, GroupSaifConfig):
                # accept a SaifConfig spec-side: map the shared fields
                cfg = GroupSaifConfig(
                    eps=cfg.eps, inner_epochs=cfg.inner_epochs,
                    polish_factor=cfg.polish_factor, k_max=cfg.k_max,
                    max_outer=cfg.max_outer, loss=cfg.loss)
            if cfg.loss != problem.loss:
                cfg = dataclasses.replace(cfg, loss=problem.loss)
            self.config = cfg
            if problem.y is None:
                raise ValueError("group sessions need Problem.y")
            if problem.weights is not None:
                raise NotImplementedError(
                    "weighted group problems are not supported")
            self._gprep = prepare_group(problem.X, problem.y,
                                        self.penalty.gsize, cfg)
            self.screen_backend = None   # the group engine has no pluggable
            self.screen_rule = None      # screen backend (nor rule)
            self._compiles0 = unified_compile_count()
            return

        from repro.core.saif import SaifConfig, prepare_path
        from repro.core.screen_backend import (resolve_backend,
                                               resolve_batch_screen,
                                               resolve_screen_rule)
        cfg = config if config is not None else SaifConfig()
        if cfg.loss != problem.loss:
            cfg = dataclasses.replace(cfg, loss=problem.loss)

        if isinstance(self.penalty, FusedPenalty):
            from repro.core.fused import prepare_fused
            import jax.numpy as jnp
            if problem.weights is not None:
                raise NotImplementedError(
                    "weighted fused problems are not supported")
            # the one-time Theorem-6 transform (chain Pallas kernel or
            # level-schedule scan) — THE preparation the fused session
            # amortizes over every subsequent request
            self._design = prepare_fused(problem.X, self.penalty.parent,
                                         self.penalty.transform_backend)
            cfg = dataclasses.replace(cfg, unpen_idx=self._design.unpen_idx)
            self.config = cfg
            if problem.y is not None:
                self._y = jnp.asarray(problem.y, self._design.Xt.dtype)
                self._prep = prepare_path(self._design.Xt, self._y, cfg)
            else:
                self._y = None
                self._prep = None
        else:
            self._design = None
            self.config = cfg
            self._y = problem.y
            if problem.weights is not None and self._make_screen is not None:
                raise NotImplementedError(
                    "make_screen with a weighted problem: the fleet "
                    "engine serving weighted problems takes per-request "
                    "Fleet(..., screen_fn=...) hooks instead")
            if problem.y is not None and problem.weights is None:
                self._prep = prepare_path(problem.X, problem.y, cfg)
                if self._pad_to is not None:
                    from repro.core.saif import pad_path_state
                    self._prep = pad_path_state(self._prep, *self._pad_to)
            else:
                self._prep = None
        try:
            self.screen_backend = resolve_backend(cfg.screen_backend)
        except ValueError:
            # fleet-only screen modes (the opt-in "matmul" shared-X fast
            # path, §8) resolve through the batch policy; serial requests
            # on such a session fail at the engine boundary exactly like
            # the legacy frontends did. An unknown name raises here.
            self.screen_backend = resolve_batch_screen(cfg.screen_backend)
        # the resolved certificate geometry (DESIGN.md §13) — validated at
        # open_session so a bad rule name fails before any engine dispatch,
        # and inspectable for Verdict provenance
        self.screen_rule = resolve_screen_rule(cfg.screen_rule)
        self._compiles0 = unified_compile_count()

    # ------------------------------------------------------------------
    # the one entry point
    # ------------------------------------------------------------------

    def solve(self, request):
        """Serve one request; see :class:`Scalar` / :class:`Path` /
        :class:`Fleet` / :class:`CV` for the workload shapes and the
        module docstring for the result types."""
        self._requests += 1
        if isinstance(request, Scalar):
            return self._solve_scalar(request)
        if isinstance(request, Path):
            return self._solve_path(request)
        if isinstance(request, Fleet):
            return self._solve_fleet(request)
        if isinstance(request, CV):
            return self._solve_cv(request)
        if isinstance(request, Update):
            return self._solve_update(request)
        if isinstance(request, Select):
            return self._solve_select(request)
        raise TypeError(f"unknown request {request!r}: expected Scalar, "
                        f"Path, Fleet, CV, Update or Select")

    def update(self, rows=None, responses=None, request=None, **kw):
        """Streaming verb (DESIGN.md §14): absorb an (m, p) row block into
        the device-resident problem state and re-solve warm — sugar for
        ``solve(Update(rows, responses, ...))``."""
        if isinstance(rows, Update):     # update(Update(...)) sugar
            request = rows
        if request is None:
            request = Update(rows=rows, responses=responses, **kw)
        return self.solve(request)

    def select(self, request=None, **kw):
        """Auto-lambda verb (DESIGN.md §14): CV + 1-SE rule + stability
        selection + refit — sugar for ``solve(Select(...))``; returns a
        :class:`~repro.core.select.SelectionReport`."""
        if request is None:
            request = Select(**kw)
        return self.solve(request)

    # ------------------------------------------------------------------
    # warm boundary state (the serving runtime's checkpoint surface)
    # ------------------------------------------------------------------

    @property
    def warm_state(self):
        """The device-resident serial warm boundary state — the
        ``(idx, beta, mask, InnerCarry)`` tuple ``run_path`` hands across
        requests — or None before the first serial solve. This plus
        :attr:`warm_capacity` is exactly what a warm checkpoint must
        persist (``repro.core.serving``, DESIGN.md §10)."""
        return self._warm

    @property
    def warm_capacity(self):
        """Capacity (k_max) the warm state was built at, or None."""
        return self._warm_k

    def set_warm_state(self, warm, k_max) -> None:
        """Install a warm boundary state (e.g. restored from a
        checkpoint); the next ``Scalar/Path(warm=True)`` request enters
        from it exactly as if the previous solve had produced it."""
        self._warm = warm
        self._warm_k = None if k_max is None else int(k_max)

    def compile_stats(self) -> CompileStats:
        """Unified compile accounting; see :class:`CompileStats`."""
        serial, fleet, grp = _engine_cache_sizes()
        total = -1 if min(serial, fleet, grp) < 0 else serial + fleet + grp
        base = getattr(self, "_compiles0", 0)
        since = (total - base) if (total >= 0 and base >= 0) else -1
        return CompileStats(serial=serial, fleet=fleet, group=grp,
                            total=total, since_open=since,
                            requests=self._requests)

    # ------------------------------------------------------------------
    # provenance events + cross-request homotopy cache (DESIGN.md §14)
    # ------------------------------------------------------------------

    def _push_event(self, name: str) -> None:
        self._pending_events.append(name)

    def drain_events(self) -> Tuple[str, ...]:
        """Hand back (and clear) provenance events accumulated by the
        streaming / warm-cache paths — the serving layer folds these
        into the request's Verdict."""
        events, self._pending_events = tuple(self._pending_events), []
        return events

    def drop_cache_entry(self) -> int:
        """Invalidate the warm-cache entry stored by the most recent
        cache-routed solve (the serving scrub path calls this when a
        result fails certification)."""
        if self._warm_cache is None or self._cache_last is None:
            return 0
        digest, lam = self._cache_last
        self._cache_last = None
        return self._warm_cache.invalidate(digest, lam)

    def _cache_eligible(self, req) -> bool:
        """The homotopy cache serves cold, unsharded, plain-LASSO
        requests on a static (non-streaming) design with the built-in
        screens — everything else keeps its existing path untouched."""
        return (self._warm_cache is not None and not req.warm
                and not req.sharded and self._make_screen is None
                and self._design is None and self._online is None
                and self.problem.weights is None
                and isinstance(self.penalty, LassoPenalty))

    def _cached_entry_solve(self, lams: List[float]):
        """Solve through the cross-request homotopy cache: on a band hit,
        enter via the compiled Theorem-2 sequential-ball seed
        (``path.seq_warm_entry``); on a miss, run the bitwise cold path.
        Either way the exit warm state is stored for the next request."""
        from repro.core.path import run_path, seq_warm_entry
        from repro.core.warm_cache import problem_digest
        cache = self._warm_cache
        if self._digest_memo is None:
            self._digest_memo = problem_digest(self._prep.X, self._prep.y)
        digest = self._digest_memo
        lam_hi = max(lams)
        entry = cache.lookup(digest, lam_hi)
        if entry is not None:
            warm0, k0 = seq_warm_entry(self._prep, entry.warm,
                                       entry.k_max, entry.lam0, lam_hi,
                                       self.config)
            self._push_event(f"warm_cache_hit:lam0={entry.lam0:.6g}")
        else:
            warm0, k0 = None, None
            self._push_event("warm_cache_miss")
        pr, warm, k_max = run_path(self._prep, lams, self.config,
                                   segment_len=self._segment_len,
                                   warm0=warm0, k_max0=k0)
        self._warm, self._warm_k = warm, k_max
        lam_lo = min(lams)
        cache.store(digest, lam_lo, warm, k_max)
        self._cache_last = (digest, lam_lo)
        return pr

    # ------------------------------------------------------------------
    # dispatch arms
    # ------------------------------------------------------------------

    def _require_y(self):
        if self.problem.y is None:
            raise ValueError(
                "this request needs a response: the session was opened "
                "without Problem.y (fleet-only)")

    def _memo_make_screen(self, h: int):
        if h not in self._screen_memo:
            self._screen_memo[h] = self._make_screen(h)
        return self._screen_memo[h]

    def _harvest_warm(self, res):
        from repro.core.path import _warm_state
        unpen = self.config.unpen_idx
        self._warm = _warm_state(res.active_idx, res.active_mask, res.beta,
                                 res.inner,
                                 unpen_idx=-1 if unpen is None else unpen)
        self._warm_k = int(res.active_idx.shape[0])

    def _solve_scalar(self, req: Scalar):
        if isinstance(self.penalty, GroupPenalty):
            if req.sharded:
                raise NotImplementedError(
                    "sharded group screening is not implemented")
            from repro.core.group import group_solve
            res = group_solve(self._gprep, float(req.lam), self.config,
                              warm=self._gwarm if req.warm else None)
            self._gwarm = (res.gidx, res.gmask, res.beta_slots)
            return res

        self._require_y()
        if self.problem.weights is not None:
            if req.sharded:
                raise NotImplementedError(
                    "weighted sharded solves: per-problem column norms "
                    "live on the replicated path for now (DESIGN.md §8)")
            if req.warm:
                raise NotImplementedError(
                    "warm weighted solves: the fleet engine serving "
                    "weighted problems has no cross-request warm handoff "
                    "yet (DESIGN.md §9)")
            return self._weighted_scalar(float(req.lam))
        if req.sharded:
            res = self._scalar_sharded(float(req.lam), warm=req.warm)
        elif self._cache_eligible(req):
            # cross-request homotopy cache (DESIGN.md §14): band hits
            # enter via the Theorem-2 sequential-ball seed, misses run
            # the bitwise cold path; the exit warm state is cached
            pr = self._cached_entry_solve([float(req.lam)])
            res = pr.results[0]
        elif req.warm or self._make_screen is not None:
            # a single-lambda run of the path engine: bitwise the cold
            # solve_scalar when entered cold, and the only driver that
            # threads the warm handoff and the custom make_screen hook
            from repro.core.path import run_path
            pr, warm, k = run_path(self._prep, [float(req.lam)],
                                   self.config,
                                   make_screen=(None if self._make_screen
                                                is None
                                                else self._memo_make_screen),
                                   segment_len=self._segment_len,
                                   warm0=self._warm if req.warm else None,
                                   k_max0=(self._warm_k if req.warm
                                           else None))
            self._warm, self._warm_k = warm, k
            res = pr.results[0]
        else:
            from repro.core.saif import solve_scalar
            res = solve_scalar(self._prep, float(req.lam), self.config)
            self._harvest_warm(res)
        self._last_lam = float(req.lam)
        if isinstance(self.penalty, FusedPenalty):
            from repro.core.fused import recover_from_transformed
            return recover_from_transformed(res.beta, self._design), res
        if self._p_real is not None and not req.sharded:
            res = res._replace(beta=res.beta[:self._p_real])
        return res

    def _weighted_scalar(self, lam: float):
        import jax
        import jax.numpy as jnp
        from repro.core.batch import fleet_solve
        y = jnp.asarray(self.problem.y)
        w = jnp.asarray(self.problem.weights)
        res = fleet_solve(self.problem.X, y[None, :], lam, self.config,
                          weights=w[None, :])
        return jax.tree.map(lambda a: a[0], res)   # drop the B=1 axis

    def _solve_path(self, req: Path):
        lams = tuple(float(l) for l in req.lams)
        if isinstance(self.penalty, GroupPenalty):
            if req.sharded:
                raise NotImplementedError(
                    "sharded group screening is not implemented")
            return self._group_path(lams, warm=req.warm)

        self._require_y()
        if self.problem.weights is not None:
            raise NotImplementedError(
                "weighted lambda paths: submit a Fleet (one lambda per "
                "weighted problem) or a CV request instead")
        from repro.core.path import run_path
        if req.sharded:
            design = self._sharded_design()
            prep = self._sharded_path_prep(design)
            pr, warm, k = run_path(
                prep, lams, self.config,
                make_screen=lambda h: self._memo_sharded_screen(design, h),
                segment_len=self._segment_len,
                warm0=self._sharded_warm if req.warm else None,
                k_max0=self._sharded_warm_k if req.warm else None)
            self._sharded_warm, self._sharded_warm_k = warm, k
            # slice the padding columns back off (design.p is the true
            # transformed/plain width)
            from repro.core.path import SaifPathResult
            betas = [b[:design.p] for b in pr.betas]
            pr = SaifPathResult(lams=pr.lams, betas=betas,
                                results=pr.results,
                                n_compilations=pr.n_compilations)
        else:
            if self._cache_eligible(req):
                pr = self._cached_entry_solve(list(lams))
            else:
                pr, warm, k = run_path(
                    self._prep, lams, self.config,
                    make_screen=(None if self._make_screen is None
                                 else self._memo_make_screen),
                    segment_len=self._segment_len,
                    warm0=self._warm if req.warm else None,
                    k_max0=self._warm_k if req.warm else None)
                self._warm, self._warm_k = warm, k
            self._last_lam = float(min(lams))
            if self._p_real is not None:
                from repro.core.path import SaifPathResult
                pr = SaifPathResult(
                    lams=pr.lams,
                    betas=[b[:self._p_real] for b in pr.betas],
                    results=pr.results, n_compilations=pr.n_compilations)
        if isinstance(self.penalty, FusedPenalty):
            from repro.core.fused import (FusedPathResult,
                                          recover_from_transformed)
            betas = [recover_from_transformed(b, self._design)
                     for b in pr.betas]
            return FusedPathResult(lams=pr.lams, betas=betas, path=pr)
        return pr

    def _group_path(self, lams, warm: bool) -> GroupPathResult:
        from repro.core.group import group_compile_count, group_solve
        lams_np = np.asarray(sorted(lams, reverse=True))
        n0 = group_compile_count()
        cur = self._gwarm if warm else None
        results = []
        for lam in lams_np:
            res = group_solve(self._gprep, float(lam), self.config,
                              warm=cur)
            cur = (res.gidx, res.gmask, res.beta_slots)
            results.append(res)
        self._gwarm = cur
        n1 = group_compile_count()
        n_comp = max(n1 - n0, 0) if (n0 >= 0 and n1 >= 0) else None
        return GroupPathResult(lams=lams_np,
                               betas=[r.beta for r in results],
                               results=results, n_compilations=n_comp)

    def _solve_fleet(self, req: Fleet):
        if isinstance(self.penalty, GroupPenalty):
            raise NotImplementedError(
                "group fleets are not implemented (DESIGN.md §9)")
        if isinstance(self.penalty, FusedPenalty):
            raise NotImplementedError(
                "fused fleets are serial-only for now (DESIGN.md §8)")
        if self.problem.weights is not None:
            raise NotImplementedError(
                "Problem-level weights serve Scalar requests; fleets take "
                "per-request Fleet(..., weights=...) instead")
        if req.sharded:
            self._require_mesh()
            if req.weights is not None:
                raise NotImplementedError(
                    "weighted sharded fleets: per-fold column norms live "
                    "on the replicated path for now (DESIGN.md §8)")
            from repro.distributed.saif_sharded import fleet_solve_sharded
            return fleet_solve_sharded(
                self.problem.X, req.Y, req.lams, self.mesh, self.config,
                design=self._sharded_fleet_design(req.Y),
                screen_cache=self._sharded_fleet_screens)
        from repro.core.batch import fleet_solve
        if self._pad_to is not None:
            import jax
            from repro.core.batch import pad_fleet_prep, prepare_fleet
            fprep = prepare_fleet(self.problem.X, req.Y, self.config,
                                  weights=req.weights)
            fprep = pad_fleet_prep(fprep, *self._pad_to)
            res = fleet_solve(None, None, req.lams, self.config,
                              screen_fn=req.screen_fn, prep=fprep)
            return res._replace(beta=res.beta[:, :self._p_real])
        return fleet_solve(self.problem.X, req.Y, req.lams, self.config,
                           weights=req.weights, screen_fn=req.screen_fn)

    def _solve_cv(self, req: CV):
        if not isinstance(self.penalty, LassoPenalty):
            raise NotImplementedError(
                "cross-validation serves plain-LASSO problems "
                "(DESIGN.md §8)")
        if req.sharded:
            raise NotImplementedError(
                "sharded CV fleets: per-fold column norms live on the "
                "replicated path for now (DESIGN.md §8)")
        if self.problem.weights is not None:
            raise NotImplementedError(
                "weighted cross-validation is not supported: CV builds "
                "its own binary fold weights (DESIGN.md §8)")
        self._require_y()
        from repro.core.cv import cv_solve
        return cv_solve(self.problem.X, self.problem.y,
                        tuple(float(l) for l in req.lams), req.n_folds,
                        self.config, seed=req.seed,
                        keep_fold_betas=req.keep_fold_betas,
                        refit=req.refit)

    def _solve_update(self, req: Update):
        if not isinstance(self.penalty, LassoPenalty):
            raise NotImplementedError(
                "online row updates serve plain-LASSO sessions "
                "(DESIGN.md §14)")
        from repro.core.online import apply_update
        return apply_update(self, req)

    def _solve_select(self, req: Select) -> SelectionReport:
        if not isinstance(self.penalty, LassoPenalty):
            raise NotImplementedError(
                "Session.select serves plain-LASSO problems "
                "(DESIGN.md §8/§14)")
        if self.problem.weights is not None:
            raise NotImplementedError(
                "weighted selection is not supported: CV and stability "
                "selection build their own binary row weights")
        self._require_y()
        from repro.core.select import select_solve
        if self._online is not None:
            # streaming session: select on the CURRENT resident rows
            # (the first `filled` buffer rows hold exactly the live data)
            n = self._prep.n_true or self._prep.X.shape[0]
            X, y = self._prep.X[:n], self._prep.y[:n]
        else:
            X, y = self.problem.X, self.problem.y
        report = select_solve(X, y, req, self.config)
        self._last_lam = float(report.lam)
        return report

    # ------------------------------------------------------------------
    # sharded plumbing (lazy: built at the first sharded request)
    # ------------------------------------------------------------------

    def _require_mesh(self):
        if self.mesh is None:
            raise ValueError(
                "sharded=True needs a device mesh: open_session(problem, "
                "config, mesh=mesh)")

    def _sharded_design(self):
        self._require_mesh()
        if self._sharded is None:
            from repro.distributed.saif_sharded import design_for
            if isinstance(self.penalty, FusedPenalty):
                X, y = self._design.Xt, self._y
            else:
                X, y = self.problem.X, self.problem.y
            self._sharded = design_for(X, y, self.mesh, self.config)
        return self._sharded

    def _sharded_path_prep(self, design):
        if self._sharded_prep is None:
            from repro.core.saif import prepare_path
            y = self._y if isinstance(self.penalty, FusedPenalty) \
                else self.problem.y
            self._sharded_prep = prepare_path(design.X, y, self.config)
        return self._sharded_prep

    def _memo_sharded_screen(self, design, h: int):
        if h not in self._sharded_screen_memo:
            from repro.distributed.saif_sharded import make_sharded_screen
            self._sharded_screen_memo[h] = make_sharded_screen(design, h)
        return self._sharded_screen_memo[h]

    def _sharded_fleet_design(self, Y):
        """Fleet placement, built at the first sharded fleet request and
        reused by every later one (see ``fleet_design_for``)."""
        if self._sharded_fleet is None:
            from repro.distributed.saif_sharded import fleet_design_for
            self._sharded_fleet = fleet_design_for(self.problem.X, Y,
                                                   self.mesh, self.config)
        return self._sharded_fleet

    def _scalar_sharded(self, lam: float, warm: bool = False):
        self._require_mesh()
        design = self._sharded_design()
        if warm:
            # the sharded edition of the warm handoff: a single-lambda
            # run of the path engine over the padded prep, entered from
            # (and refreshing) the sharded warm state
            from repro.core.path import run_path
            pr, wstate, k = run_path(
                self._sharded_path_prep(design), [lam], self.config,
                make_screen=lambda h: self._memo_sharded_screen(design, h),
                segment_len=self._segment_len,
                warm0=self._sharded_warm, k_max0=self._sharded_warm_k)
            self._sharded_warm, self._sharded_warm_k = wstate, k
            res = pr.results[0]
            return res._replace(beta=res.beta[:design.p])
        from repro.distributed.saif_sharded import solve_scalar_sharded
        y = self._y if isinstance(self.penalty, FusedPenalty) \
            else self.problem.y
        return solve_scalar_sharded(None, y, lam, self.mesh, self.config,
                                    design=design,
                                    screen_cache=self._sharded_screen_memo,
                                    prep=self._sharded_path_prep(design))


def open_session(problem: Problem, config=None, **kwargs) -> Session:
    """Open a persistent solving session for ``problem``.

    Preparation (c0 / column norms / Theorem-6 transform / group norms)
    runs HERE, exactly once; every subsequent ``session.solve(request)``
    reuses it along with the process-wide solver compilations and the
    session's device-resident warm buffers. ``config`` is a
    :class:`~repro.core.saif.SaifConfig` (or
    :class:`~repro.core.group.GroupSaifConfig` for group penalties;
    defaults per penalty).

    Keyword arguments are the shared session spec
    (:data:`SESSION_KWARG_DEFAULTS` — identical for ``open_session``,
    ``open_serving`` and ``open_server``): ``mesh`` enables
    ``sharded=True`` requests; ``make_screen``/``segment_len`` are the
    path-engine hooks; ``pad_to=(n_bucket, p_bucket)`` serves every
    request from a compile-bucket-padded preparation (DESIGN.md §12).
    """
    return Session(problem, config, **kwargs)
