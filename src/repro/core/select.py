"""Auto-lambda model selection: 1-SE CV + stability selection
(DESIGN.md §14).

``Session.select(Select(lams))`` answers the question clients actually
have — "which features?" — without asking them to pick a lambda:

  1. the existing K-fold CV fleet scores the grid (ONE fleet
     compilation, ``core/cv.py``);
  2. the **1-SE rule** picks the largest lambda within one standard
     error of the CV minimum (``rule="min"`` keeps the raw argmin);
  3. optional **stability selection** (Meinshausen–Bühlmann): B
     random half-subsamples solved as ONE weighted ``fleet_solve``
     (binary row masks are exact row subsampling — the CV sample-weight
     trick, DESIGN.md §8 — so the B solves share one compilation and
     compose with ``parity="fast"``), yielding per-feature selection
     frequencies and the stable support ``freq >= pi_threshold``;
  4. a full-data refit at the chosen lambda (the serial engine).

Everything returns in one :class:`SelectionReport`; the serving layer
KKT-certifies the refit and carries the report through Verdict
provenance. Module scope stays numpy+stdlib only (import-light
contract); jax loads inside the solve functions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import numpy as np

__all__ = ["Select", "SelectionReport", "subsample_weights",
           "select_solve"]


@dataclasses.dataclass(frozen=True)
class Select:
    """Model-selection request: CV over ``lams``, 1-SE choice, optional
    stability selection, full-data refit."""
    lams: Any
    n_folds: int = 5
    rule: str = "1se"                 # "1se" | "min"
    stability: bool = True
    n_subsamples: int = 16
    subsample_frac: float = 0.5
    pi_threshold: float = 0.6
    seed: int = 0
    refit: bool = True
    keep_fold_betas: bool = False
    deadline_s: Optional[float] = None
    priority: int = 0

    def __post_init__(self):
        from repro.core.serving import validate_request
        validate_request(self)


class SelectionReport(NamedTuple):
    """What :func:`select_solve` hands back (and serving certifies)."""
    lams: np.ndarray                   # (L,) descending CV grid
    cv_mean: np.ndarray                # (L,) mean held-out loss
    cv_se: np.ndarray                  # (L,) standard error across folds
    lam_min: float                     # argmin of cv_mean
    lam_1se: float                     # 1-SE rule choice
    lam: float                         # the chosen lambda (per rule)
    rule: str                          # "1se" | "min"
    frequencies: Optional[np.ndarray]  # (p,) selection frequencies
    stable_support: Optional[np.ndarray]   # indices with freq >= pi
    pi_threshold: float
    beta: Optional[Any]                # (p,) full-data refit at lam
    best_result: Optional[Any]         # the refit's SaifResult
    fold_betas: Optional[Any]          # per-lambda (K, p), if kept
    n_compilations: Optional[int]      # engine compiles this call added


def subsample_weights(n: int, n_subsamples: int, frac: float,
                      seed: int = 0, dtype=None):
    """(B, n) binary row masks, each keeping ``floor(frac * n)`` rows
    drawn without replacement (host RNG, reproducible) — the stability-
    selection analogue of :func:`repro.core.cv.kfold_weights`."""
    import jax.numpy as jnp

    m = int(frac * n)
    if not 1 <= m < n:
        raise ValueError(
            f"subsample_frac={frac} keeps {m} of {n} rows; need 1 <= "
            f"rows < n")
    rng = np.random.default_rng(seed)
    W = np.zeros((n_subsamples, n))
    for b in range(n_subsamples):
        W[b, rng.choice(n, size=m, replace=False)] = 1.0
    return jnp.asarray(W, dtype if dtype is not None else None)


def stability_frequencies(X, y, lam: float, config, n_subsamples: int,
                          frac: float, seed: int = 0
                          ) -> Tuple[np.ndarray, Any]:
    """Selection frequency per feature over B subsample solves, run as
    ONE weighted fleet (one compilation). Returns ``(freq (p,), fleet
    SaifResult)``."""
    import jax.numpy as jnp

    from repro.core.batch import fleet_solve

    X = jnp.asarray(X)
    y = jnp.asarray(y, X.dtype)
    n = X.shape[0]
    W = subsample_weights(n, n_subsamples, frac, seed=seed,
                          dtype=X.dtype)
    Y = jnp.broadcast_to(y, (int(n_subsamples), n))
    fr = fleet_solve(X, Y, float(lam), config, weights=W)
    freq = np.asarray(
        jnp.mean((jnp.abs(fr.beta) > 0).astype(X.dtype), axis=0))
    return freq, fr


def select_solve(X, y, req: Select,
                 config=None) -> SelectionReport:
    """Run the full selection protocol (module docstring) on (X, y)."""
    from repro.core.batch import saif_batch_compile_count
    from repro.core.cv import cv_solve, one_se_lambda
    from repro.core.saif import SaifConfig, saif, saif_jit_compile_count

    config = config or SaifConfig()
    lams = tuple(float(l) for l in np.asarray(req.lams).ravel())
    c0 = saif_batch_compile_count() + saif_jit_compile_count()
    cv = cv_solve(X, y, lams, n_folds=int(req.n_folds), config=config,
                  seed=int(req.seed),
                  keep_fold_betas=bool(req.keep_fold_betas), refit=False)
    lam_min = float(cv.best_lam)
    lam_1se = one_se_lambda(cv.lams, cv.cv_mean, cv.cv_se)
    lam = lam_1se if req.rule == "1se" else lam_min

    freq = stable = None
    if req.stability:
        freq, _ = stability_frequencies(
            X, y, lam, config, int(req.n_subsamples),
            float(req.subsample_frac), seed=int(req.seed) + 1)
        stable = np.flatnonzero(freq >= float(req.pi_threshold))

    beta = best = None
    if req.refit:
        best = saif(X, y, lam, config)
        beta = best.beta

    c1 = saif_batch_compile_count() + saif_jit_compile_count()
    n_comp = max(c1 - c0, 0) if c0 >= 0 and c1 >= 0 else None
    return SelectionReport(
        lams=cv.lams, cv_mean=cv.cv_mean, cv_se=cv.cv_se,
        lam_min=lam_min, lam_1se=lam_1se, lam=lam, rule=str(req.rule),
        frequencies=freq, stable_support=stable,
        pi_threshold=float(req.pi_threshold), beta=beta,
        best_result=best, fold_betas=cv.fold_betas,
        n_compilations=n_comp)
