"""Pluggable screening backends for the SAIF ADD phase.

The ADD decision of Algorithm 2 needs, per outer iteration, exactly four
things from the full feature set R_t:

  * ``max_ub``                — the ADD-stop reduction  max_{R_t} ub_i,
  * the top-h candidates      — (score, feature id) pairs,
  * their lower bounds        — lb_l = |score_l - ||x_l|| r|,
  * their violation counts    — |V_l| = #{i in R_t : ub_i >= lb_l}.

A :data:`ScreenFn` produces all four as one :class:`ScreenOut`; the jitted
solver in :mod:`repro.core.saif` is backend-agnostic and touches nothing
(p,)-shaped in the ADD phase. Three implementations ship:

  * ``jnp``     — XLA matvec + ``top_k`` + searchsorted/bincount counts.
  * ``pallas``  — the fused TPU kernel pair from ``repro.kernels.screen``:
                  one pass emits masked (score, ub, lb) + tile-local top-h +
                  tile max-ub; a second streaming pass histograms ub against
                  the merged candidates' lower bounds.
  * sharded     — ``repro.distributed.saif_sharded.make_sharded_screen``,
                  same math under ``shard_map``.

All three produce *identical integers* for the violation counts and the same
candidate sets (ties break to the lowest feature id everywhere), which is
what makes the backends interchangeable mid-path.

Fused problems (DESIGN.md §7) screen through this same interface: the
Theorem-6 transform materializes the edge columns + the b column once, and
every backend — the sharded one included (``saif_fused_distributed``) —
scans the transformed design like any other; the always-resident
unpenalized slot is excluded the same way any active feature is (it is in
``in_active`` from step 0 and never DELed), so no backend needs a fused
special case.

Violation counts without the O(p log p) sort
--------------------------------------------
The legacy implementation sorted the (p,) ub vector and binary-searched each
candidate bound in it. Equivalent, cheaper (O(p log h + h log h)):

  1. sort only the h candidate bounds: ``lb_sorted``;
  2. for every feature, c_i = #{l : lb_sorted[l] <= ub_i}   (searchsorted);
  3. histogram the c_i values into bins 0..h;
  4. suffix sums:  #{i : ub_i >= lb_sorted[j]} = sum_{m > j} hist[m].

Step 2+3 stream over ub once; the (p,)-sized sort is gone. For a candidate
with bound lb_l sitting at position j = searchsorted(lb_sorted, lb_l, 'left')
the suffix sum at j+1 is exactly #{i : ub_i >= lb_l} — including ties, since
both sides count with the same <= comparison.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

# the rule/backend seam (DESIGN.md §13): the ScreenRule picks the
# certificate geometry, the backends here only compute its bounds fast —
# re-exported so rule consumers import one module
from repro.core.screen_rule import (SCREEN_RULES, ScreenRule,  # noqa: F401
                                    resolve_screen_rule)


class ScreenOut(NamedTuple):
    max_ub: jax.Array      # scalar: max over R_t of ub (−inf if R_t empty)
    cand_score: jax.Array  # (h,) top-h scores over R_t (−inf padded)
    cand_idx: jax.Array    # (h,) int32 global feature ids
    cand_lb: jax.Array     # (h,) |score − ||x|| r| per candidate
    cand_ge: jax.Array     # (h,) int32 #{i in R_t : ub_i >= cand_lb}
    # observability (ISSUE 9): #{i in R_t : ub_i >= 1} — the features this
    # screen could NOT rule out ("survivors"; |R_t| - n_surv were screened).
    # Mixed-precision screens count against the widened bounds, so the
    # count is conservative exactly like the decisions themselves. None is
    # tolerated from legacy/custom ScreenFns; engines treat it as 0.
    n_surv: Optional[jax.Array] = None


# signature: (theta (n,), r scalar, in_active (p,) bool) -> ScreenOut
ScreenFn = Callable[[jax.Array, jax.Array, jax.Array], ScreenOut]


def ge_counts_from_hist(hist: jax.Array, lb_sorted: jax.Array,
                        lb_cand: jax.Array) -> jax.Array:
    """Per-candidate #{i : ub_i >= lb} from the c-histogram (exact)."""
    suffix = jnp.cumsum(hist[::-1])[::-1]            # suffix[m] = Σ_{t>=m}
    pos = jnp.searchsorted(lb_sorted, lb_cand, side="left")
    return suffix[pos + 1].astype(jnp.int32)


def violation_ge_counts(ub: jax.Array, lb_cand: jax.Array) -> jax.Array:
    """Pure-jnp counts #{i : ub_i >= lb_l} per candidate, sort-free in p."""
    h = lb_cand.shape[0]
    lb_sorted = jnp.sort(lb_cand)
    c = jnp.searchsorted(lb_sorted, ub, side="right")
    hist = jnp.zeros((h + 1,), jnp.int32).at[c].add(1)
    return ge_counts_from_hist(hist, lb_sorted, lb_cand)


def survivor_count(ub: jax.Array, axis=None) -> jax.Array:
    """#{i : ub_i >= 1} over the trailing feature axis — the screen's
    survivor count. -inf entries (active/skipped) never count."""
    return jnp.sum((ub >= 1.0), axis=axis, dtype=jnp.int32)


def _candidate_out(scores_masked, ub, col_norm, r, h) -> ScreenOut:
    """Shared tail: top-h + bounds + counts from masked scores and ub."""
    cand_score, cand_idx = jax.lax.top_k(scores_masked, h)
    cand_idx = cand_idx.astype(jnp.int32)
    cand_lb = jnp.abs(cand_score - jnp.take(col_norm, cand_idx) * r)
    cand_ge = violation_ge_counts(ub, cand_lb)
    return ScreenOut(max_ub=jnp.max(ub), cand_score=cand_score,
                     cand_idx=cand_idx, cand_lb=cand_lb, cand_ge=cand_ge,
                     n_surv=survivor_count(ub))


def make_screen_jnp(X: jax.Array, col_norm: jax.Array, h: int) -> ScreenFn:
    """Reference backend: one XLA matvec + cheap reductions.

    The scan is written ``theta @ X`` (not ``X.T @ theta``): with the
    row-vector orientation XLA:CPU computes each column's dot product with
    a bracketing that does not depend on how many columns sit to its
    right, so appending zero columns (the serving layer's p-bucket
    padding, DESIGN.md §12) leaves every real column's score bitwise
    unchanged. The transposed orientation re-tiles with the output width
    and is measurably not padding-stable.
    """
    def screen(theta, r, in_active):
        score = jnp.abs(theta @ X)
        masked = jnp.where(in_active, -jnp.inf, score)
        ub = masked + col_norm * r
        return _candidate_out(masked, ub, col_norm, r, h)
    return screen


def make_screen_from_scan(scan_fn, col_norm: jax.Array, h: int) -> ScreenFn:
    """Adapt a bare ``theta -> |X^T theta|`` scan (e.g. the shard_map one)
    to the full backend interface; everything past the scan is O(p) jnp."""
    def screen(theta, r, in_active):
        score = scan_fn(theta)
        masked = jnp.where(in_active, -jnp.inf, score)
        ub = masked + col_norm * r
        return _candidate_out(masked, ub, col_norm, r, h)
    return screen


def make_screen_pallas(X: jax.Array, col_norm: jax.Array, h: int,
                       bn: Optional[int] = None, bp: Optional[int] = None,
                       interpret: Optional[bool] = None) -> ScreenFn:
    """Fused-kernel backend; see repro/kernels/screen/screen.py."""
    from repro.kernels.screen.screen import (screen_fused_pallas,
                                             ub_histogram_pallas)

    def screen(theta, r, in_active):
        _, ub, _, tops, topi, tmax = screen_fused_pallas(
            X, theta, col_norm, in_active, r, h=h, bn=bn, bp=bp,
            interpret=interpret)
        # merge tile winners: O((p/bp) h) candidates, not O(p)
        cand_score, pos = jax.lax.top_k(tops.reshape(-1), h)
        cand_idx = topi.reshape(-1)[pos]
        cand_lb = jnp.abs(cand_score -
                          jnp.take(col_norm, cand_idx).astype(cand_score.dtype)
                          * jnp.asarray(r, cand_score.dtype))
        lb_sorted = jnp.sort(cand_lb)
        hist = ub_histogram_pallas(ub, lb_sorted, interpret=interpret)
        cand_ge = ge_counts_from_hist(hist, lb_sorted, cand_lb)
        return ScreenOut(max_ub=jnp.max(tmax), cand_score=cand_score,
                         cand_idx=cand_idx, cand_lb=cand_lb, cand_ge=cand_ge,
                         n_surv=survivor_count(ub))
    return screen


# --------------------------------------------------------------------------
# batched (problem-axis) screens — the fleet engine (core/batch.py, §8)
# --------------------------------------------------------------------------
# A batched ScreenFn maps (Theta (B, n), r (B,), in_active (B, p),
# do (B,)) to a ScreenOut whose every field carries a leading problem
# axis; ``do`` flags the problems whose ADD phase is actually running this
# outer step (the serial solver's per-solve screen gate, per problem).
#
# The default ``jnp`` fleet screen is a liveness-gated lax.map of the
# SERIAL screen: each problem's scan is the literal serial matvec — the
# bitwise-parity contract — and polish-phase/frozen problems skip their
# scan entirely, exactly like the serial solver's lax.cond. The shared-X
# ``matmul`` fast path turns the fleet's scans into ONE (B, n) x (n, p)
# matmul (the design is read once per outer step for the whole fleet);
# its re-tiled reduction can differ from a serial matvec by an ulp, which
# near an ADD-stop boundary (max_ub == 1 exactly) can flip one decision —
# opt in for scan-bound fleets where that trade is right (DESIGN.md §8).
# The distinct-X fallback (per-problem designs, (B, n, p)) keeps the
# problem axis a batch dim of the contraction and stays bitwise.

# signature: (Theta (B,n), r (B,), in_active (B,p), do (B,)) -> ScreenOut
BatchScreenFn = Callable[[jax.Array, jax.Array, jax.Array, jax.Array],
                         ScreenOut]


def _candidate_out_batch(masked, ub, col_norm, r, h,
                         sel_dtype=None) -> ScreenOut:
    """Batched :func:`_candidate_out`: per-problem top-h + bounds + counts.
    ``col_norm`` is the fleet (B, p) matrix.

    For small h the violation counts are ONE (B, p, h) comparison-reduce
    instead of a vmapped sort+searchsorted — integer-identical (a count
    of exact float comparisons has no accumulation order), and materially
    fewer ops inside the fleet while_loop. Large h keeps the sort form
    (the dense compare would be B*p*h).

    ``sel_dtype`` runs the top-h *selection* sort on down-cast scores
    (the f64 top_k is ~60x the f32 one on XLA:CPU) while the returned
    scores/bounds are gathered from the full-precision ``masked`` — used
    by the mixed-precision escalation tier, where selection order is
    heuristic-grade but the bounds must stay working precision.
    """
    if sel_dtype is None:
        cand_score, cand_idx = jax.lax.top_k(masked, h)      # (B, h)
    else:
        _, cand_idx = jax.lax.top_k(masked.astype(sel_dtype), h)
        cand_score = jnp.take_along_axis(masked, cand_idx, axis=1)
    cand_idx = cand_idx.astype(jnp.int32)
    cand_lb = jnp.abs(cand_score -
                      jnp.take_along_axis(col_norm, cand_idx, axis=1)
                      * r[:, None])
    if h <= 32:
        cand_ge = jnp.sum(
            (ub[:, :, None] >= cand_lb[:, None, :]).astype(jnp.int32),
            axis=1)
    else:
        cand_ge = jax.vmap(violation_ge_counts)(ub, cand_lb)
    return ScreenOut(max_ub=jnp.max(ub, axis=1), cand_score=cand_score,
                     cand_idx=cand_idx, cand_lb=cand_lb, cand_ge=cand_ge,
                     n_surv=survivor_count(ub, axis=1))


def fleet_col_norms(col_norm: jax.Array, b: int) -> jax.Array:
    """(B, p) fleet column norms from a shared (p,) vector or pass-through."""
    cn = jnp.asarray(col_norm)
    return jnp.broadcast_to(cn, (b,) + cn.shape) if cn.ndim == 1 else cn


def _skip_screen_out(h: int, dtype) -> ScreenOut:
    """Neutral per-problem ScreenOut for a skipped scan: max_ub = -inf
    (reads as add_done, but the engine's do-mask already gates every
    consumer), no finite candidates."""
    return ScreenOut(max_ub=jnp.asarray(-jnp.inf, dtype),
                     cand_score=jnp.full((h,), -jnp.inf, dtype),
                     cand_idx=jnp.zeros((h,), jnp.int32),
                     cand_lb=jnp.full((h,), jnp.inf, dtype),
                     cand_ge=jnp.zeros((h,), jnp.int32),
                     n_surv=jnp.zeros((), jnp.int32))


def make_batch_screen_jnp(X: jax.Array, col_norm: jax.Array,
                          h: int) -> BatchScreenFn:
    """Default fleet screen: per-problem serial scans, lax.mapped, with a
    per-problem skip for problems whose ADD phase is off this step."""
    def screen(Theta, r, in_active, do):
        cn = fleet_col_norms(col_norm, Theta.shape[0])

        def one(args):
            do_b, theta_b, r_b, act_b, cn_b = args
            return jax.lax.cond(
                do_b,
                lambda _: make_screen_jnp(X, cn_b, h)(theta_b, r_b, act_b),
                lambda _: _skip_screen_out(h, Theta.dtype), None)

        return jax.lax.map(one, (do, Theta, r, in_active, cn))
    return screen


def make_batch_screen_matmul(X: jax.Array, col_norm: jax.Array,
                             h: int) -> BatchScreenFn:
    """Shared-X fast path: one (B, n) x (n, p) matmul scans the fleet
    (ulp-grade vs serial scans — see the section comment)."""
    def screen(Theta, r, in_active, do):
        cn = fleet_col_norms(col_norm, Theta.shape[0])
        score = jnp.abs(Theta @ X)                           # (B, p)
        masked = jnp.where(in_active, -jnp.inf, score)
        ub = masked + cn * r[:, None]
        return _candidate_out_batch(masked, ub, cn, r, h)
    return screen


def make_batch_screen_distinct(Xs: jax.Array, col_norm: jax.Array,
                               h: int) -> BatchScreenFn:
    """Distinct-X fallback: per-problem designs Xs (B, n, p). The problem
    axis stays a batch dim of the contraction, so every problem's scan is
    bitwise its serial matvec (no shared-operand re-tiling)."""
    def screen(Theta, r, in_active, do):
        cn = fleet_col_norms(col_norm, Theta.shape[0])
        score = jnp.abs(jnp.einsum("bnp,bn->bp", Xs, Theta))
        masked = jnp.where(in_active, -jnp.inf, score)
        ub = masked + cn * r[:, None]
        return _candidate_out_batch(masked, ub, cn, r, h)
    return screen


def make_batch_screen_pallas(X: jax.Array, col_norm: jax.Array, h: int,
                             bn: Optional[int] = None,
                             bp: Optional[int] = None,
                             interpret: Optional[bool] = None
                             ) -> BatchScreenFn:
    """Problem-gridded fused kernels: grid axis over the fleet, shared X
    tiles revisited across problems (kernels/screen/screen.py). Each grid
    step runs the serial kernel body on one problem's blocks, so the
    per-problem scores match the serial pallas screen bitwise."""
    from repro.kernels.screen.screen import (screen_fused_batch_pallas,
                                             ub_histogram_batch_pallas)

    def screen(Theta, r, in_active, do):
        b = Theta.shape[0]
        cn = fleet_col_norms(col_norm, b)
        _, ub, _, tops, topi, tmax = screen_fused_batch_pallas(
            X, Theta, cn, in_active, r, h=h, bn=bn, bp=bp,
            interpret=interpret)
        cand_score, pos = jax.lax.top_k(tops.reshape(b, -1), h)
        cand_idx = jnp.take_along_axis(topi.reshape(b, -1), pos, axis=1)
        cand_lb = jnp.abs(
            cand_score - jnp.take_along_axis(cn, cand_idx, axis=1)
            .astype(cand_score.dtype) * r[:, None].astype(cand_score.dtype))
        lb_sorted = jnp.sort(cand_lb, axis=1)
        hist = ub_histogram_batch_pallas(ub, lb_sorted, interpret=interpret)
        cand_ge = jax.vmap(ge_counts_from_hist)(hist, lb_sorted, cand_lb)
        return ScreenOut(max_ub=jnp.max(tmax, axis=1),
                         cand_score=cand_score, cand_idx=cand_idx,
                         cand_lb=cand_lb, cand_ge=cand_ge,
                         n_surv=survivor_count(ub, axis=1))
    return screen


def make_batch_screen_fast(X: jax.Array, col_norm: jax.Array, h: int,
                           screen_dtype: str = "working") -> BatchScreenFn:
    """Certified mixed-precision fleet screen (parity="fast", DESIGN.md §11).

    One (B, n) x (n, p) gemm scans the fleet with inputs cast to
    ``screen_dtype`` ("working" | "float32" | "bfloat16") and an
    accumulator no narrower than f32. Safety: the safe-ball radius is
    widened by the rigorous per-dot rounding bound
    gamma_total * ||theta||_2 (:func:`repro.core.duality.widened_radius`)
    BEFORE any bound is formed, so the low-precision ub upper-bounds the
    exact ub and the ADD-stop / not-a-candidate decisions are strictly
    conservative — a feature this screen rules out is also ruled out by
    the exact working-precision screen at the same state. The top-h
    *selection* (scores/lb/violation counts) runs on the low-precision
    scores unwidened-equivalent: selection order is heuristic-grade (any
    selected feature is safe to add; Thm 1a), only the bounds are
    certificate-grade.
    """
    from repro.core.duality import (mixed_precision_gamma, unit_roundoff,
                                    widened_radius)

    n = X.shape[0]
    X = jnp.asarray(X)
    work_dt = X.dtype
    in_dt = work_dt if screen_dtype == "working" else jnp.dtype(screen_dtype)
    acc_dt = work_dt if screen_dtype == "working" else jnp.promote_types(
        jnp.float32, in_dt)
    low_precision = in_dt != work_dt
    gamma = mixed_precision_gamma(n, in_dt, acc_dt)
    gamma_work = mixed_precision_gamma(n, work_dt, work_dt)
    # post-dot scalar guard (DESIGN.md §11): the bound pipeline itself
    # (|.|, the cn * r product, the final add — and the acc_dt casts of
    # cn and r) runs in acc_dt, ~5 roundings of nonnegative terms; an
    # explicit (1 +- 8u_acc) factor on the finished bounds absorbs them,
    # so EVERY float op between the exact score and the decision is
    # accounted, not just the dot
    u_acc = unit_roundoff(acc_dt)
    one_plus = 1.0 + 8.0 * u_acc
    one_minus = 1.0 - 8.0 * u_acc
    Xc = X.astype(in_dt)

    def screen(Theta, r, in_active, do):
        b = Theta.shape[0]
        cn_w = fleet_col_norms(col_norm, b)
        r_wide = widened_radius(r, Theta, gamma)
        # the whole decision pipeline stays in acc_dt: under x64 working
        # precision the f64 top_k/sort alone is ~60x an f32 one on
        # XLA:CPU, and selection order is heuristic-grade anyway — only
        # the *bounds* carry certificates, and those are widened in
        # acc_dt with the scalar guard above
        score = jnp.abs(jnp.einsum(
            "bn,np->bp", Theta.astype(in_dt), Xc,
            preferred_element_type=acc_dt))
        cn = cn_w.astype(acc_dt)
        masked = jnp.where(in_active, jnp.asarray(-jnp.inf, acc_dt), score)
        ub = ((masked + cn * r_wide.astype(acc_dt)[:, None]) *
              jnp.asarray(one_plus, acc_dt))
        if not low_precision:
            return _candidate_out_batch(masked, ub, cn, r_wide, h)

        # Two-tier escalation (DESIGN.md §11): a genuinely low-precision
        # pass can leave the ADD-stop decision *undecidable* — the widened
        # ub refuses to certify max_ub < 1 while the anti-conservative
        # bound says the exact screen would have stopped. Refusing forever
        # stalls the delta ramp (the stop certificate can sit permanently
        # inside the bf16 noise band), so undecidable problems re-screen
        # in working precision this step — certified degradation instead
        # of non-termination; decidable problems keep the cheap pass.
        widen = (r_wide - r).astype(acc_dt)               # (B,)
        r_lo = r_wide.astype(acc_dt) - 2.0 * widen
        ub_lo = ((masked + cn * r_lo[:, None]) *
                 jnp.asarray(one_minus, acc_dt))
        undecidable = (do & (jnp.max(ub, axis=1) >= 1.0)
                       & (jnp.max(ub_lo, axis=1) < 1.0))

        def cheap(_):
            out = _candidate_out_batch(masked, ub, cn, r_wide, h)
            return ScreenOut(max_ub=out.max_ub.astype(work_dt),
                             cand_score=out.cand_score.astype(work_dt),
                             cand_idx=out.cand_idx,
                             cand_lb=out.cand_lb.astype(work_dt),
                             cand_ge=out.cand_ge, n_surv=out.n_surv)

        def escalate(_):
            score_w = jnp.where(undecidable[:, None],
                                jnp.abs(Theta @ X),
                                score.astype(work_dt))
            r_eff = jnp.where(undecidable,
                              widened_radius(r, Theta, gamma_work), r_wide)
            masked_w = jnp.where(in_active, -jnp.inf, score_w)
            ub_w = jnp.where(undecidable[:, None],
                             masked_w + cn_w * r_eff[:, None],
                             ub.astype(work_dt))
            return _candidate_out_batch(masked_w, ub_w, cn_w, r_eff, h,
                                        sel_dtype=jnp.float32)

        return jax.lax.cond(jnp.any(undecidable), escalate, cheap, None)
    return screen


def make_batch_screen(name: str, X: jax.Array, col_norm: jax.Array,
                      h: int) -> BatchScreenFn:
    """Factory used inside ``_saif_batch_jit`` (name is jit-static)."""
    if name == "pallas":
        return make_batch_screen_pallas(X, col_norm, h)
    if name == "matmul":
        return make_batch_screen_matmul(X, col_norm, h)
    return make_batch_screen_jnp(X, col_norm, h)


# Measured on the CI CPU (2 cores, x64, warm jits; numbers in DESIGN.md
# §8). The deciding mechanism is NOT gemm tiling: the raw one-gemm screen
# beats the lax.map of serial scans at EVERY fleet size when all problems
# screen (1.3-1.7x at B*p = 2k..128k). What the gemm lacks is the jnp
# path's per-problem ``do`` skip — once ADD phases desynchronize, skipped
# problems cost the jnp screen ~nothing (0.04ms vs the gemm's full 1.7ms
# at B=16 with do=0) while the matmul always pays the whole fleet. End to
# end the skip dominates small fleets (B*p=8k: matmul 2.17x vs jnp 2.61x,
# BENCH_batch.json PR 4) and the gemm amortization dominates larger ones
# (B*p=32k: matmul 1.18x faster; 64k: parity within noise across shapes).
# Crossover measured between B*p = 8k and 32k; below it an informed
# resolve call downgrades matmul to jnp on CPU.
MATMUL_MIN_BP = 32_768


def resolve_batch_screen(name: str, *, b: Optional[int] = None,
                         p: Optional[int] = None) -> str:
    """Fleet screen policy (DESIGN.md §8).

    ``matmul`` (the shared-X one-gemm screen, ulp-grade vs serial scans)
    is honored on accelerators unconditionally (lax.map serializes
    there), but on CPU only when the fleet's B*p crosses
    :data:`MATMUL_MIN_BP` — below that the jnp path's per-problem ``do``
    skip beats the gemm end to end (2.17x vs 2.61x fleet speedup at the
    B*p=8k CI shape; mechanism measured in the section comment above),
    so an informed call (``b``/``p`` known) downgrades it to ``jnp``.
    Name-only calls (legacy/tests that construct a screen directly) keep
    honoring the explicit opt-in.
    """
    if name == "matmul":
        if jax.default_backend() != "cpu":
            return name
        if b is None or p is None:          # uninformed call: honor opt-in
            return name
        return name if b * p >= MATMUL_MIN_BP else "jnp"
    return resolve_backend(name)


def resolve_backend(name: str) -> str:
    """Backend-selection policy (DESIGN.md §3): explicit name wins; ``auto``
    compiles the fused kernels on TPU and keeps the XLA path elsewhere
    (the interpreter would be strictly slower than the jnp matvec)."""
    if name == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if name not in ("jnp", "pallas"):
        raise ValueError(f"unknown screen backend {name!r}")
    return name
