"""Async serving front-end: queue → shape-bucket → microbatch → fleet
(DESIGN.md §12).

PR 5/6 made one request cheap (:class:`~repro.core.api.Session`) and
safe (:class:`~repro.core.serving.ServingSession`); this module makes a
*traffic stream* cheap. The problems it solves are compilation-shape
economics, not numerics:

* **Shape buckets** — every novel ``(n, p)`` would pay a fresh engine
  compile. Incoming problems are padded up to a small static grid of
  ``(n_bucket, p_bucket)`` buckets, so a heterogeneous request mix runs
  on a handful of compiled programs. Column (p) padding is *bitwise*
  neutral — pad columns carry ``c0 = -inf`` / ``col_norm = 1`` guards
  and are born "already active" through a traced pad mask, and the one
  full-width reduction in the engine (``theta @ X``) is column-append
  invariant — so a padded solve returns bit-identical coefficients to
  the direct unpadded solve. Row (n) padding is the opt-in second tier
  (exact in real arithmetic; support-parity + KKT-certified in floats).
* **Microbatch coalescing** — :class:`~repro.core.api.Scalar` requests
  over the *same design* (per-user responses ``y``, per-user lambdas —
  the paper's "millions of users" regime) waiting in one bucket's queue
  are coalesced (under a ``max_wait_ms``/``max_batch`` policy) into one
  :class:`~repro.core.api.Fleet` solved by the lockstep fleet engine in
  a single dispatch, whose per-member results are bitwise the serial
  solves. Each rider's future resolves to its own
  :class:`~repro.core.serving.ServingResult` with a *per-unit* verdict
  — one poisoned member degrades only its own future.
* **Warm-session LRU** — dispatch goes through a per-``(problem digest,
  bucket)`` LRU of :class:`~repro.core.serving.ServingSession`s. The
  engine jit caches are process-wide, so eviction and readmission cost
  session re-prep but *zero* new engine compilations.
* **Restart warmth** — with ``ServerConfig.cache_dir`` set, JAX's
  persistent compilation cache is enabled (min-compile-time/entry-size
  thresholds zeroed) so a restarted server replays its compiles from
  disk: zero cold-start compilations on the second life.

Module scope imports only stdlib + numpy — ``from repro import
open_server`` keeps the lazy-surface contract; jax and the engines load
on first dispatch.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import threading
import time
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import numpy as np

__all__ = ["ServerConfig", "ServerStats", "ServingFuture", "Server",
           "open_server"]


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Policy knobs of the async front-end (DESIGN.md §12).

    ``p_buckets``/``n_buckets`` define the static compile-bucket grid: a
    request lands in the smallest bucket that dominates its shape. With
    ``p_buckets=None`` the column bucket is the next power of two of
    ``p`` (floored at ``min_p_bucket``); with ``n_buckets=None`` rows
    are never padded (the bitwise tier — row padding is opt-in because
    it is exact in real arithmetic but only support-parity in floats,
    and is structurally wrong for the logistic loss, whose pad rows
    would shift the primal by log 2 each). A shape beyond the grid falls
    back to its power-of-two bucket (counted in ``stats().bucket_
    fallbacks``) instead of rejecting the request.
    """
    p_buckets: Optional[Tuple[int, ...]] = None
    n_buckets: Optional[Tuple[int, ...]] = None
    min_p_bucket: int = 8
    max_batch: int = 8            # coalesced microbatch size cap
    max_wait_ms: float = 5.0      # coalescing window per microbatch
    max_sessions: int = 8         # warm-session LRU capacity
    cache_dir: Optional[str] = None   # persistent compilation cache
    solver: Any = None            # solver config shared by every session
    serving: Any = None           # ServingConfig shared by every session
    warm_cache: Any = None        # shared WarmCache — cross-request
    #                               homotopy entries (DESIGN.md §14)
    autostart: bool = True        # start the dispatch thread at open


class ServerStats(NamedTuple):
    """Server-lifetime counters (benchmarks/bench_serve.py columns)."""
    submitted: int
    served: int                  # futures resolved with a result
    failed: int                  # futures rejected with a typed error
    deadline_misses: int         # expired in the queue, never dispatched
    coalesced_batches: int       # microbatches with >= 2 riders
    coalesced_requests: int      # requests served inside those batches
    sessions_opened: int         # LRU misses (includes readmissions)
    evictions: int
    bucket_fallbacks: int        # shapes beyond the configured grid
    stragglers: int              # dispatches flagged by the monitors
    pending: int                 # queued + in-flight right now


# ---------------------------------------------------------------------------
# futures
# ---------------------------------------------------------------------------

class ServingFuture:
    """Resolves to the request's :class:`~repro.core.serving.
    ServingResult`; a typed serving error propagates out of
    :meth:`result` exactly as it would from the sync
    ``ServingSession.solve``."""

    __slots__ = ("_event", "_result", "_exc", "_callbacks", "_cb_lock")

    def __init__(self):
        self._event = threading.Event()
        self._result = None
        self._exc: Optional[BaseException] = None
        self._callbacks: List[Any] = []
        self._cb_lock = threading.Lock()

    def done(self) -> bool:
        return self._event.is_set()

    def add_done_callback(self, fn) -> None:
        """Call ``fn(self)`` when the future resolves (immediately if it
        already has) — the load generator's latency timestamp hook."""
        with self._cb_lock:
            if not self._event.is_set():
                self._callbacks.append(fn)
                return
        fn(self)

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            from repro.core.serving import DeadlineExceeded
            raise DeadlineExceeded(
                f"future not resolved within {timeout!r}s")
        if self._exc is not None:
            raise self._exc
        return self._result

    def exception(self, timeout: Optional[float] = None
                  ) -> Optional[BaseException]:
        if not self._event.wait(timeout):
            from repro.core.serving import DeadlineExceeded
            raise DeadlineExceeded(
                f"future not resolved within {timeout!r}s")
        return self._exc

    # -- producer side (Server only) -----------------------------------
    def _resolve(self, result) -> None:
        self._result = result
        self._fire()

    def _reject(self, exc: BaseException) -> None:
        self._exc = exc
        self._fire()

    def _fire(self) -> None:
        with self._cb_lock:
            self._event.set()
            cbs, self._callbacks = self._callbacks, []
        for fn in cbs:
            fn(self)


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------

def _next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


def _pick_bucket(v: int, grid: Optional[Tuple[int, ...]],
                 floor: int = 1) -> Tuple[int, bool]:
    """Smallest grid entry >= v, else the pow2 fallback (flagged)."""
    if grid:
        fits = [g for g in grid if g >= v]
        if fits:
            return min(fits), False
        return max(_next_pow2(v), floor), True
    return max(_next_pow2(v), floor), False


def _problem_digest(problem, *, design_only: bool = False) -> str:
    """Problem identity for session keying — mirrors the checkpoint
    digest in ``serving.py``: data bytes + loss + penalty spec. With
    ``design_only`` the response ``y`` is excluded: requests from
    different users over the SAME design coalesce into one fleet (the
    paper's serving regime — one shared design, per-user responses),
    so the queue keys on the design while per-problem sessions key on
    the full identity."""
    h = hashlib.sha256()
    arrs = (problem.X, problem.weights) if design_only else (
        problem.X, problem.y, problem.weights)
    for arr in arrs:
        if arr is None:
            h.update(b"<none>")
            continue
        a = np.ascontiguousarray(np.asarray(arr))
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes())
    h.update(problem.loss.encode())
    h.update(repr(problem.penalty).encode())
    return h.hexdigest()


def _is_lasso(problem) -> bool:
    pen = problem.penalty
    return pen == "lasso" or type(pen).__name__ == "LassoPenalty"


# ---------------------------------------------------------------------------
# queue entries
# ---------------------------------------------------------------------------

class _Entry:
    __slots__ = ("seq", "priority", "t_submit", "problem", "request",
                 "future", "coalesce")

    def __init__(self, seq, priority, problem, request, future, coalesce):
        self.seq = seq
        self.priority = priority
        self.t_submit = time.monotonic()
        self.problem = problem
        self.request = request
        self.future = future
        self.coalesce = coalesce


def _rank(e: _Entry):
    # higher priority first; FIFO within a priority class
    return (-e.priority, e.seq)


# ---------------------------------------------------------------------------
# the server
# ---------------------------------------------------------------------------

class Server:
    """Queue → shape-bucket → microbatch → fleet. Construct via
    :func:`open_server`; submit with :meth:`submit`; every future
    resolves to a :class:`~repro.core.serving.ServingResult`."""

    def __init__(self, config: Optional[ServerConfig] = None, *,
                 guard=None, **kwargs):
        from repro.core.api import session_kwargs
        self.config = config if config is not None else ServerConfig()
        opts = session_kwargs(**kwargs)
        if opts.get("pad_to") is not None:
            raise TypeError(
                "open_server() owns bucket padding; configure "
                "ServerConfig.p_buckets/n_buckets instead of pad_to")
        opts.pop("pad_to", None)
        self._opts = opts
        self._guard = guard
        if self.config.cache_dir:
            _enable_persistent_cache(self.config.cache_dir)
        self._cond = threading.Condition()
        self._queues: Dict[tuple, List[_Entry]] = {}
        self._inflight = 0
        self._stop = False
        self._thread: Optional[threading.Thread] = None
        self._seq = itertools.count()
        # LRU of warm sessions, most-recently-used last
        self._lru: "Dict[tuple, Any]" = {}
        self._digests: Dict[int, Tuple[Any, str]] = {}
        self._monitors: Dict[tuple, Any] = {}
        # counters (read under _cond)
        self._submitted = 0
        self._served = 0
        self._failed = 0
        self._deadline_misses = 0
        self._coalesced_batches = 0
        self._coalesced_requests = 0
        self._sessions_opened = 0
        self._evictions = 0
        self._bucket_fallbacks = 0
        self._stragglers = 0
        if self.config.autostart:
            self._start()

    # -- lifecycle ------------------------------------------------------

    def _start(self) -> None:
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._worker_loop, name="repro-server", daemon=True)
            self._thread.start()

    def run(self, timeout: Optional[float] = None) -> None:
        """Block the calling thread serving requests until
        :meth:`close` (from another thread) or ``timeout``."""
        self._start()
        self._thread.join(timeout)

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted request has resolved."""
        end = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while self._pending_locked():
                rem = None if end is None else end - time.monotonic()
                if rem is not None and rem <= 0:
                    from repro.core.serving import DeadlineExceeded
                    raise DeadlineExceeded(
                        f"drain() timed out with "
                        f"{self._pending_locked()} requests pending")
                self._cond.wait(0.2 if rem is None else min(rem, 0.2))

    def close(self) -> None:
        """Stop the dispatcher; queued-but-unserved futures reject with
        a ``RequestError``. Warm sessions are closed."""
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        for sess in self._lru.values():
            sess.close()
        self._lru.clear()

    def __enter__(self) -> "Server":
        return self

    def __exit__(self, *exc_info):
        self.close()
        return False

    # -- submission -----------------------------------------------------

    def submit(self, problem, request) -> ServingFuture:
        """Validate, bucket and enqueue one request. Returns immediately
        with a :class:`ServingFuture`; admission errors raise *here*,
        synchronously, with the same typed taxonomy as the sync path."""
        from repro.core.serving import RequestError, validate_request
        validate_request(request)
        with self._cond:
            if self._stop:
                raise RequestError("server is closed")
        key = self._bucket_key(problem, request)
        fut = ServingFuture()
        entry = _Entry(next(self._seq),
                       int(getattr(request, "priority", 0)),
                       problem, request, fut,
                       self._coalescible(problem, request))
        with self._cond:
            self._submitted += 1
            self._queues.setdefault(key, []).append(entry)
            self._cond.notify_all()
        return fut

    def stats(self) -> ServerStats:
        with self._cond:
            return ServerStats(
                submitted=self._submitted, served=self._served,
                failed=self._failed,
                deadline_misses=self._deadline_misses,
                coalesced_batches=self._coalesced_batches,
                coalesced_requests=self._coalesced_requests,
                sessions_opened=self._sessions_opened,
                evictions=self._evictions,
                bucket_fallbacks=self._bucket_fallbacks,
                stragglers=self._stragglers,
                pending=self._pending_locked())

    # -- bucketing ------------------------------------------------------

    def _digest(self, problem, *, design_only: bool = False) -> str:
        cache_key = (id(problem), design_only)
        hit = self._digests.get(cache_key)
        if hit is not None and hit[0] is problem:
            return hit[1]
        d = _problem_digest(problem, design_only=design_only)
        self._digests[cache_key] = (problem, d)
        return d

    def _bucket_key(self, problem, request) -> tuple:
        cfg = self.config
        n, p = np.asarray(problem.X).shape
        # padding is the lasso fleet substrate's contract; other
        # penalties / weighted problems serve at their exact shape
        pad_ok = _is_lasso(problem) and problem.weights is None
        if pad_ok:
            p_b, fb_p = _pick_bucket(p, cfg.p_buckets, cfg.min_p_bucket)
            fb_n = False
            if cfg.n_buckets and problem.loss == "least_squares":
                n_b, fb_n = _pick_bucket(n, cfg.n_buckets)
            else:
                n_b = n
            if fb_p or fb_n:
                with self._cond:
                    self._bucket_fallbacks += 1
        else:
            n_b, p_b = n, p
        # queues key on the DESIGN digest so same-design requests from
        # different users land in one coalescing pool
        return (self._digest(problem, design_only=True), n_b, p_b)

    def _coalescible(self, problem, request) -> bool:
        """Same-design Scalars (each with its own response and lam) ride
        one fleet solve. Warm/sharded scalars and non-lasso problems
        stay serial."""
        return (type(request).__name__ == "Scalar"
                and not getattr(request, "warm", False)
                and not getattr(request, "sharded", False)
                and _is_lasso(problem)
                and problem.weights is None
                and problem.y is not None)

    # -- the dispatch loop ----------------------------------------------

    def _pending_locked(self) -> int:
        return sum(len(q) for q in self._queues.values()) + self._inflight

    def _worker_loop(self) -> None:
        from repro.core.serving import RequestError
        while True:
            with self._cond:
                while not self._stop and not any(self._queues.values()):
                    self._cond.wait(0.2)
                if self._stop:
                    err = RequestError(
                        "server closed before the request was served")
                    for q in self._queues.values():
                        for e in q:
                            e.future._reject(err)
                            self._failed += 1
                    self._queues.clear()
                    self._cond.notify_all()
                    return
                key, batch = self._claim_batch_locked()
                if not batch:
                    continue
                self._inflight += len(batch)
            try:
                self._dispatch(key, batch)
            finally:
                with self._cond:
                    self._inflight -= len(batch)
                    self._cond.notify_all()

    def _claim_batch_locked(self) -> Tuple[tuple, List[_Entry]]:
        """Pick the queue whose head outranks all others; coalescible
        heads hold the microbatch window open for riders."""
        best_key, best_rank = None, None
        for k, q in self._queues.items():
            if not q:
                continue
            r = min(_rank(e) for e in q)
            if best_rank is None or r < best_rank:
                best_key, best_rank = k, r
        if best_key is None:
            return (), []
        q = self._queues[best_key]
        head = min(q, key=_rank)
        if head.coalesce:
            window = self.config.max_wait_ms / 1e3
            deadline = head.t_submit + window
            while (not self._stop
                   and len([e for e in q if e.coalesce])
                   < self.config.max_batch
                   and time.monotonic() < deadline):
                self._cond.wait(max(deadline - time.monotonic(), 1e-4))
            q = self._queues.get(best_key, [])
            batch = sorted((e for e in q if e.coalesce),
                           key=_rank)[: self.config.max_batch]
        else:
            batch = [head]
        for e in batch:
            q.remove(e)
        if not q:
            self._queues.pop(best_key, None)
        return best_key, batch

    # -- sessions -------------------------------------------------------

    def _session(self, problem, key: tuple):
        from repro.core.serving import open_serving
        sess = self._lru.get(key)
        if sess is not None:
            # refresh recency
            self._lru.pop(key)
            self._lru[key] = sess
            return sess
        n_b, p_b = key[-2], key[-1]
        n, p = np.asarray(problem.X).shape
        pad_to = (n_b, p_b) if (n_b, p_b) != (n, p) else None
        opts = self._opts
        if self.config.warm_cache is not None \
                and opts.get("warm_cache") is None:
            # every session the server opens shares the configured
            # cross-request homotopy cache; an eviction/readmission
            # cycle then re-enters warm instead of cold
            opts = dict(opts, warm_cache=self.config.warm_cache)
        sess = open_serving(problem, self.config.solver,
                            serving=self.config.serving,
                            guard=self._guard, pad_to=pad_to,
                            **opts)
        with self._cond:
            self._sessions_opened += 1
        self._lru[key] = sess
        while len(self._lru) > max(self.config.max_sessions, 1):
            old_key = next(iter(self._lru))
            self._lru.pop(old_key).close()
            with self._cond:
                self._evictions += 1
        return sess

    def _monitor(self, key: tuple):
        mon = self._monitors.get(key)
        if mon is None:
            from repro.runtime.fault import StragglerMonitor
            factor = getattr(self.config.serving, "straggler_factor", 3.0)
            mon = self._monitors[key] = StragglerMonitor(factor=factor)
        return mon

    # -- dispatch -------------------------------------------------------

    def _expire_locked(self, batch: List[_Entry]) -> List[_Entry]:
        from repro.core.serving import DeadlineExceeded
        now = time.monotonic()
        live = []
        for e in batch:
            dl = getattr(e.request, "deadline_s", None)
            if dl is not None and now - e.t_submit >= dl:
                e.future._reject(DeadlineExceeded(
                    f"request deadline ({dl:g}s) expired in the queue "
                    f"after {now - e.t_submit:.3g}s"))
                with self._cond:
                    self._deadline_misses += 1
                    self._failed += 1
            else:
                live.append(e)
        return live

    def _dispatch(self, key: tuple, batch: List[_Entry]) -> None:
        batch = self._expire_locked(batch)
        if not batch:
            return
        _, n_b, p_b = key
        # fleet sessions serve every same-design user (requests carry
        # their own Y), so they key on the design digest; single-request
        # sessions are bound to the problem's y and key on the full one
        if batch[0].coalesce:
            skey = ("fleet",) + key
        else:
            skey = ("single", self._digest(batch[0].problem), n_b, p_b)
        try:
            sess = self._session(batch[0].problem, skey)
        except BaseException as exc:  # noqa: BLE001 - session build
            # failure must reach every rider's future, not kill the loop
            self._reject_batch(batch, exc)
            return
        mon = self._monitor(key)
        t0 = time.monotonic()
        try:
            if len(batch) == 1 and not batch[0].coalesce:
                res = sess.solve(batch[0].request)
                batch[0].future._resolve(res)
                with self._cond:
                    self._served += 1
            else:
                self._dispatch_coalesced(sess, batch)
        except BaseException as exc:  # noqa: BLE001 - typed serving
            # errors (and anything else) resolve the futures
            self._reject_batch(batch, exc)
        if mon.record(time.monotonic() - t0):
            with self._cond:
                self._stragglers += 1

    def _reject_batch(self, batch: List[_Entry], exc: BaseException):
        for e in batch:
            if not e.future.done():
                e.future._reject(exc)
        with self._cond:
            self._failed += sum(1 for e in batch)

    def _dispatch_coalesced(self, sess, batch: List[_Entry]) -> None:
        """B same-design Scalars (per-user y, per-user lam) → one fleet
        microbatch. The batch axis is padded to a power of two with
        duplicates of rider 0 so batch size joins the bucket grid
        instead of the compile-key churn; the fleet engine solves each
        member independently and bitwise-equal to its serial solve, so
        riders can't perturb each other and per-unit verdicts attribute
        any failure precisely."""
        from repro.core.api import Fleet
        from repro.core.serving import ServingResult
        b_real = len(batch)
        b_pad = _next_pow2(b_real)
        # every rider contributes its OWN response row — the shared
        # design is what the bucket key guarantees
        Y = np.stack([np.asarray(e.problem.y) for e in batch])
        lams = [float(e.request.lam) for e in batch]
        lams += [lams[0]] * (b_pad - b_real)
        deadlines = [e.request.deadline_s for e in batch
                     if e.request.deadline_s is not None]
        if b_pad > b_real:
            Y = np.concatenate(
                [Y, np.tile(Y[:1], (b_pad - b_real, 1))], axis=0)
        fleet = Fleet(Y=Y,
                      lams=np.asarray(lams),
                      deadline_s=min(deadlines) if deadlines else None,
                      priority=max(e.priority for e in batch))
        res = sess.solve(fleet)
        verdict = res.verdict
        unit_ok = verdict.unit_ok or (verdict.ok,) * b_pad
        unit_deg = verdict.unit_degraded or (False,) * b_pad
        value_np = _to_host(res.value)   # one transfer per field, then
        for i, e in enumerate(batch):    # free numpy views per rider
            v_i = verdict._replace(
                ok=bool(unit_ok[i]), degraded=bool(unit_deg[i]),
                unit_ok=(bool(unit_ok[i]),),
                unit_degraded=(bool(unit_deg[i]),))
            e.future._resolve(
                ServingResult(value=_unit_view(value_np, i),
                              verdict=v_i))
        with self._cond:
            self._served += b_real
            if b_real > 1:
                self._coalesced_batches += 1
                self._coalesced_requests += b_real


def _to_host(value):
    """Materialize every leaf of a batched result on the host — done
    once per microbatch so the per-rider slices below are numpy views,
    not per-field device reads."""
    import jax
    return jax.tree_util.tree_map(np.asarray, value)


def _unit_view(value, i: int):
    """Slice fleet member ``i`` out of a batched result — every field of
    the fleet result carries a leading problem axis."""
    import jax
    return jax.tree_util.tree_map(lambda a: a[i], value)


def _enable_persistent_cache(cache_dir: str) -> None:
    """Wire JAX's persistent compilation cache with the thresholds
    zeroed, so even the small SAIF engines persist — a restarted server
    on the same directory replays every compile from disk."""
    import jax
    jax.config.update("jax_compilation_cache_dir", str(cache_dir))
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    # jax latches the cache off at the first compile it sees with no
    # cache dir configured (_cache_initialized=True, _cache=None) — a
    # server opened mid-process would silently never persist. Reset so
    # the next compile re-initializes against the directory above.
    try:
        from jax._src import compilation_cache as _cc
        _cc.reset_cache()
    except Exception:
        pass


def open_server(config: Optional[ServerConfig] = None, *, guard=None,
                **kwargs) -> Server:
    """Open the async serving front-end (DESIGN.md §12).

    ``config`` is a :class:`ServerConfig` (or None for defaults); its
    fields may also be passed as keyword overrides (``open_server(
    max_batch=16, cache_dir=...)``). Remaining keywords are the shared
    session passthrough spec ``repro.core.api.SESSION_KWARG_DEFAULTS``
    (``mesh``, ``segment_len``, ``make_screen``) handed to every warm
    :class:`~repro.core.serving.ServingSession` the server opens —
    ``pad_to`` is owned by the server's bucket grid.

    ::

        server = open_server(max_batch=8, max_wait_ms=5.0)
        fut = server.submit(Problem(X=X, y=y), Scalar(lam, priority=1))
        value, verdict = fut.result(timeout=30)
    """
    field_names = {f.name for f in dataclasses.fields(ServerConfig)}
    overrides = {k: kwargs.pop(k) for k in list(kwargs)
                 if k in field_names}
    if config is None:
        config = ServerConfig(**overrides)
    elif overrides:
        config = dataclasses.replace(config, **overrides)
    return Server(config, guard=guard, **kwargs)
