"""Online row updates for a live Session (DESIGN.md §14).

A production feature-selection service sees new samples (users) arrive
while a session is hot. ``Session.update(rows, responses)`` absorbs an
(m, p) row block into the device-resident problem state and re-solves
warm through the existing ``_saif_jit`` boundary:

  * the design/response buffers are **row-capacity padded** once, at
    stream entry, to a fixed ``n_cap`` (pow2 headroom in append mode, the
    ring size in sliding-window mode). Zero pad rows are *exact* for
    least squares (``grad(0, 0) = 0`` contributes nothing to any X^T
    correlation, the primal value, or the dual), which is the same
    identity ``pad_path_state`` already relies on — so the engine's
    compile key (X's shape) never changes at steady state: **zero new
    engine compilations per update**;
  * the screening statistics stay exact incrementally: the signed
    correlation ``xty = X^T y`` and squared column norms are rank-m
    updated on device (``c0 = |xty|``, ``col_norm = sqrt(col_sq)``), so
    the in-loop Theorem-2 sequential ball keeps its exact geometry under
    streaming;
  * the resident gram ``InnerCarry`` is block-updated in place
    (``G += X_new^T X_new - X_old^T X_old`` on the active block,
    ``rho += X_new^T y_new - X_old^T y_old``) via
    :func:`repro.core.inner_backend.gram_block_update`; ``gidx`` is left
    untouched on live slots, so the engine's ``init`` reconciliation
    finds zero dirty slots and the warm re-solve skips the O(n k^2)
    rebuild entirely;
  * sliding-window mode replaces the oldest resident rows (a ring
    buffer), i.e. a rank-m **downdate**. Catastrophic cancellation in
    the downdated column stats is caught by a conditioning guard
    (``col_sq`` shrinking below ~64 eps of the removed mass), which
    triggers a one-shot exact recompute of the stats and invalidates the
    carry (``gidx = -1`` forces the engine's out-of-loop rebuild).

Host-side policy statistics frozen at stream entry — and why that is
sound: ``lam_max`` / ``c0_max`` / ``c0_median`` feed only the *policy*
quantities (the pow2 ADD-batch bucket ``h`` and the ``delta0`` radius
ramp), never a safety certificate. Freezing them keeps the compile key
and host/device sync count constant across the stream; the safe
screening geometry itself runs on the exactly-updated device ``c0`` /
``col_norm`` / ``y``.

Module scope stays numpy+stdlib only (the PEP-562 import-light
contract); jax loads on first use inside :func:`_fns`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import numpy as np

__all__ = ["Update", "OnlineState", "apply_update", "online_compile_count"]


@dataclasses.dataclass(frozen=True)
class Update:
    """Streaming request: absorb an (m, p) row block, then re-solve warm.

    ``lam`` defaults to the session's last solved lambda; ``window``
    (fixed at stream entry) turns the stream into a sliding window of
    the most recent ``window`` rows; ``resolve=False`` applies the
    update without re-solving (the next Update/Scalar sees the new
    rows).
    """
    rows: Any
    responses: Any
    lam: Optional[float] = None
    window: Optional[int] = None
    resolve: bool = True
    deadline_s: Optional[float] = None
    priority: int = 0

    def __post_init__(self):
        from repro.core.serving import validate_request
        validate_request(self)


class OnlineState:
    """Host bookkeeping for a streaming session.

    The authoritative problem state (padded X/y, exact c0/col_norm)
    lives in the session's ``PathState``; this object tracks the ring
    geometry plus the two signed device stats the incremental updates
    need (``xty`` keeps the *sign* that ``c0 = |xty|`` drops).
    """
    __slots__ = ("n_cap", "filled", "head", "window", "xty", "col_sq",
                 "updates", "rebuilds", "grows")

    def __init__(self, n_cap, filled, head, window, xty, col_sq):
        self.n_cap = n_cap          # padded row capacity (== window in ring mode)
        self.filled = filled        # true resident row count (n_true)
        self.head = head            # next write position
        self.window = window        # None => append-only stream
        self.xty = xty              # (p,) device: X^T y, signed
        self.col_sq = col_sq        # (p,) device: ||x_j||^2
        self.updates = 0
        self.rebuilds = 0           # downdate-guard exact recomputes
        self.grows = 0              # append-mode capacity doublings


def _next_pow2(x: int) -> int:
    return 1 << (int(x) - 1).bit_length()


@functools.lru_cache(maxsize=None)
def _fns():
    """Jitted streaming kernels, built on first use (keeps this module
    import-light). None of these touch the engine caches — the
    compile-count contract ``unified_compile_count()`` tracks is about
    ``_saif_jit``/fleet keys, which a steady-state stream never adds to."""
    import jax
    import jax.numpy as jnp

    from repro.core.inner_backend import gram_block_update

    @jax.jit
    def init_stats(X, y):
        xty = X.T @ y
        col_sq = jnp.sum(X * X, axis=0)
        return xty, col_sq, jnp.abs(xty), jnp.sqrt(jnp.maximum(col_sq, 0.0))

    def _core(X, y, xty, col_sq, pos, rows, resp):
        old = X[pos]
        old_y = y[pos]
        X2 = X.at[pos].set(rows)
        y2 = y.at[pos].set(resp)
        removed = jnp.sum(old * old, axis=0)
        col_sq2 = col_sq + jnp.sum(rows * rows, axis=0) - removed
        xty2 = xty + rows.T @ resp - old.T @ old_y
        # downdate conditioning guard: if removing the old rows cancelled
        # essentially all of a column's mass, the incremental stat has no
        # trustworthy bits left — flag for an exact recompute. Append-mode
        # streams replace zero rows (removed == 0) and never trigger.
        eps = jnp.finfo(X.dtype).eps
        bad = jnp.any((removed > 0.0) & (col_sq2 <= 64.0 * eps * removed))
        return (X2, y2, xty2, jnp.maximum(col_sq2, 0.0), old, old_y, bad)

    @jax.jit
    def apply_plain(X, y, xty, col_sq, pos, rows, resp):
        X2, y2, xty2, col_sq2, _, _, bad = _core(
            X, y, xty, col_sq, pos, rows, resp)
        return (X2, y2, xty2, col_sq2, jnp.abs(xty2),
                jnp.sqrt(col_sq2), bad)

    @jax.jit
    def apply_carry(X, y, xty, col_sq, pos, rows, resp, mask, G, rho, gidx):
        X2, y2, xty2, col_sq2, old, old_y, bad = _core(
            X, y, xty, col_sq, pos, rows, resp)
        G2, rho2 = gram_block_update(G, rho, gidx, rows, resp, old, old_y)
        n_live = jnp.sum(mask).astype(jnp.int32)
        return (X2, y2, xty2, col_sq2, jnp.abs(xty2),
                jnp.sqrt(col_sq2), G2, rho2, bad, n_live)

    return {"init": init_stats, "plain": apply_plain, "carry": apply_carry}


def online_compile_count() -> int:
    """Total compilations of the streaming kernels (observability; these
    are deliberately *outside* ``unified_compile_count`` — the zero-new-
    engine-compilations contract is about ``_saif_jit`` keys)."""
    if _fns.cache_info().currsize == 0:
        return 0
    return sum(int(f._cache_size()) for f in _fns().values())


def _request_error(msg: str):
    from repro.core.serving import RequestError
    return RequestError(msg)


def _enter_stream(session, req: Update, m: int) -> OnlineState:
    """First Update on a session: check eligibility, pad the resident
    design to its row capacity, seed the device stats."""
    import jax.numpy as jnp

    from repro.core.api import LassoPenalty

    if not isinstance(session.penalty, LassoPenalty):
        raise NotImplementedError(
            "online row updates serve plain-LASSO sessions only "
            f"(penalty: {type(session.penalty).__name__})")
    prep = getattr(session, "_prep", None)
    if prep is None:
        raise _request_error(
            "Update needs a session with responses (Problem.y)")
    if session.config.loss != "least_squares":
        raise NotImplementedError(
            "online row updates need the least-squares zero-pad-row "
            f"identity (DESIGN.md §14); loss is {session.config.loss!r}")
    if session.problem.weights is not None:
        raise NotImplementedError(
            "online row updates do not compose with per-sample weights")
    if getattr(session, "_pad_to", None) is not None:
        raise NotImplementedError(
            "online updates own their row-capacity padding; open the "
            "session without pad_to")
    if getattr(session, "_sharded", None) is not None:
        raise NotImplementedError(
            "online updates would stale the sharded design placement; "
            "open an unsharded session for streaming")

    n0, _p = prep.X.shape
    if req.window is not None:
        window: Optional[int] = int(req.window)
        if window < n0:
            raise _request_error(
                f"Update.window ({window}) must be >= the resident row "
                f"count ({n0}) at stream entry")
        n_cap = window
    else:
        window = None
        # pow2 headroom: absorbs many updates before the one recompile a
        # capacity doubling costs (amortized O(log total_rows) compiles)
        n_cap = _next_pow2(max(2 * n0, n0 + 4 * m))
    Xp = jnp.pad(jnp.asarray(prep.X), ((0, n_cap - n0), (0, 0)))
    yp = jnp.pad(jnp.asarray(prep.y), (0, n_cap - n0))
    xty, col_sq, c0, col_norm = _fns()["init"](Xp, yp)
    # zero pad rows leave every column dot product bit-identical, so the
    # pre-stream warm state (idx/beta/mask and the (k, k) gram carry —
    # all n-independent shapes) survives the padding exactly.
    session._prep = prep._replace(X=Xp, y=yp, c0=c0, col_norm=col_norm,
                                  n_true=n0)
    st = OnlineState(n_cap=n_cap, filled=n0, head=n0 % n_cap,
                     window=window, xty=xty, col_sq=col_sq)
    session._online = st
    session._push_event(f"online_stream_entered:n_cap={n_cap}")
    return st


def apply_update(session, req: Update):
    """Absorb ``req`` into ``session`` and (optionally) re-solve warm.

    Returns the warm re-solve's :class:`~repro.core.saif.SaifResult`, or
    ``None`` when ``req.resolve`` is False. The update is functional on
    device buffers — nothing is committed to the session until every
    admission check has passed.
    """
    import jax
    import jax.numpy as jnp

    rows_np = np.asarray(req.rows)
    m = rows_np.shape[0]
    st = session._online
    if st is None:
        st = _enter_stream(session, req, m)
    elif req.window is not None and int(req.window) != st.window:
        raise _request_error(
            f"Update.window changed mid-stream ({st.window} -> "
            f"{req.window}); the ring capacity is fixed at stream entry")
    prep = session._prep
    n_cap, p = prep.X.shape
    if rows_np.shape[1] != p:
        raise _request_error(
            f"Update.rows must have {p} columns to match the design, "
            f"got {rows_np.shape[1]}")

    # append-mode capacity growth: double the row buffer (one engine
    # recompile at the next solve; O(log) such events over any stream)
    if st.window is None and st.filled + m > n_cap:
        new_cap = _next_pow2(st.filled + m)
        pad = new_cap - n_cap
        prep = prep._replace(X=jnp.pad(prep.X, ((0, pad), (0, 0))),
                             y=jnp.pad(prep.y, (0, pad)))
        session._prep = prep
        st.n_cap = n_cap = new_cap
        st.grows += 1
        session._push_event(f"online_capacity_grown:n_cap={new_cap}")

    if st.window is None:
        pos_np = st.head + np.arange(m)
    else:
        pos_np = (st.head + np.arange(m)) % st.n_cap
    dtype = prep.X.dtype
    rows = jnp.asarray(rows_np, dtype)
    resp = jnp.asarray(np.asarray(req.responses), dtype)
    pos = jnp.asarray(pos_np, jnp.int32)

    fns = _fns()
    warm = session._warm
    carry = None if warm is None else warm[3]
    use_carry = (carry is not None and carry.G.ndim == 2
                 and carry.G.shape[0] == warm[0].shape[0]
                 and warm[0].shape[0] > 1)
    if use_carry:
        idx, vals, mask, carry = warm
        (X2, y2, xty2, col_sq2, c02, cn2, G2, rho2, bad, n_live) = (
            fns["carry"](prep.X, prep.y, st.xty, st.col_sq, pos, rows,
                         resp, mask, carry.G, carry.rho, carry.gidx))
    else:
        (X2, y2, xty2, col_sq2, c02, cn2, bad) = fns["plain"](
            prep.X, prep.y, st.xty, st.col_sq, pos, rows, resp)
        n_live = None

    # the one host sync per update: the conditioning guard, batched with
    # the window-vs-active admission count when a carry is resident
    if n_live is not None:
        bad_h, live_h = (int(v) for v in jax.device_get((bad, n_live)))
    else:
        bad_h, live_h = int(jax.device_get(bad)), 0
    if st.window is not None and live_h > st.window:
        # nothing committed yet — the session state is untouched
        raise _request_error(
            f"Update.window ({st.window}) is smaller than the resident "
            f"active count ({live_h}); the windowed system would be "
            f"underdetermined — raise the window")

    # commit
    st.updates += 1
    if st.window is None:
        st.filled += m
        st.head += m
    else:
        st.filled = min(st.filled + m, st.window)
        st.head = (st.head + m) % st.n_cap
    if bad_h:
        from repro.core.inner_backend import InnerCarry
        xty2, col_sq2, c02, cn2 = fns["init"](X2, y2)
        if use_carry:
            # the freshly-updated G/rho shared the cancellation — mark
            # every slot dirty so the engine's init rebuilds them exactly
            session._warm = (idx, vals, mask, InnerCarry(
                G=G2, rho=rho2, gidx=jnp.full_like(carry.gidx, -1)))
        st.rebuilds += 1
        session._push_event("online_downdate_rebuild")
    elif use_carry:
        from repro.core.inner_backend import InnerCarry
        session._warm = (idx, vals, mask,
                         InnerCarry(G=G2, rho=rho2, gidx=carry.gidx))
    st.xty, st.col_sq = xty2, col_sq2
    session._prep = prep._replace(X=X2, y=y2, c0=c02, col_norm=cn2,
                                  n_true=st.filled)

    if not req.resolve:
        return None
    lam = req.lam if req.lam is not None else session._last_lam
    if lam is None:
        raise _request_error(
            "Update.lam is required on the first resolving update (the "
            "session has no previous lambda to re-solve at)")
    return _resolve(session, float(lam))


def _resolve(session, lam: float):
    """Warm re-solve at the updated state through the shared engine —
    identical statics to the session's Scalar path, so the steady-state
    stream reuses one ``_saif_jit`` entry."""
    from repro.core.path import run_path

    pr, warm, k_max = run_path(
        session._prep, [lam], session.config,
        make_screen=(None if session._make_screen is None
                     else session._memo_make_screen),
        segment_len=session._segment_len,
        warm0=session._warm, k_max0=session._warm_k)
    session._warm, session._warm_k = warm, k_max
    session._last_lam = lam
    return pr.results[0]
