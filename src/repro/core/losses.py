"""Loss functions for the general LASSO problem (paper Eq. 1-3).

The paper works with an ``alpha``-smooth, ``gamma``-convex loss ``f`` whose
conjugate ``f*`` is (1/alpha)-strongly-convex (Kakade et al. 2009, Thm 6).
We implement the two losses the paper evaluates:

* least-squares  f(z, y) = 0.5 (z - y)^2          (alpha = 1)
* logistic       f(z, y) = log(1 + exp(-y z))     (alpha = 1/4, labels y in {-1, +1})

Each loss exposes the pieces the SAIF machinery needs:
  value(z, y)        elementwise loss
  grad(z, y)         f'(z, y) w.r.t. z  (the "residual" vector up to sign)
  conj(u, y)         f*(u, y) elementwise conjugate
  smoothness         alpha such that f'' <= alpha (dual strong convexity 1/alpha)
  dual_domain(u, y)  clamp u into dom f* (identity for LS)

Everything is pure jnp so it vmaps/jits/shards transparently.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Loss:
    """Bundle of the loss-specific callables used throughout core/."""

    name: str
    value: Callable[[jax.Array, jax.Array], jax.Array]
    grad: Callable[[jax.Array, jax.Array], jax.Array]
    conj: Callable[[jax.Array, jax.Array], jax.Array]
    smoothness: float  # alpha: f is alpha-smooth  =>  f* is (1/alpha)-strongly convex
    dual_clip: Callable[[jax.Array, jax.Array], jax.Array]
    hess: Callable[[jax.Array, jax.Array], jax.Array]  # elementwise f''(z, y)
    #   (exact curvature — the unpenalized-slot Newton polish needs it;
    #    `smoothness` is only its upper bound)

    def primal_objective(self, X: jax.Array, y: jax.Array, beta: jax.Array,
                         lam: jax.Array,
                         weights: jax.Array | None = None) -> jax.Array:
        """P(beta) = sum_j f(x_j. beta, y_j) + lam sum_i w_i |beta_i|.

        ``weights`` (optional) is the per-coordinate l1 weight — 0 on an
        unpenalized coordinate (fused LASSO's ``b``), 1 elsewhere/default.
        """
        z = X @ beta
        l1 = jnp.abs(beta) if weights is None else weights * jnp.abs(beta)
        return jnp.sum(self.value(z, y)) + lam * jnp.sum(l1)

    def dual_objective(self, y: jax.Array, theta: jax.Array,
                       lam: jax.Array) -> jax.Array:
        """D(theta) = -sum_j f*(-lam theta_j, y_j)   (paper Eq. 2)."""
        return -jnp.sum(self.conj(-lam * theta, y))


# --------------------------------------------------------------------------
# Least squares: f(z, y) = 0.5 (z - y)^2
#   f'(z, y)  = z - y
#   f*(u, y)  = 0.5 u^2 + u y     (since f*(u) = sup_z uz - 0.5(z-y)^2)
# --------------------------------------------------------------------------

def _ls_value(z, y):
    d = z - y
    return 0.5 * d * d


def _ls_grad(z, y):
    return z - y


def _ls_conj(u, y):
    return 0.5 * u * u + u * y


def _ls_dual_clip(u, y):
    return u


def _ls_hess(z, y):
    return jnp.ones_like(z)


least_squares = Loss(
    name="least_squares",
    value=_ls_value,
    grad=_ls_grad,
    conj=_ls_conj,
    smoothness=1.0,
    dual_clip=_ls_dual_clip,
    hess=_ls_hess,
)


# --------------------------------------------------------------------------
# Logistic: f(z, y) = log(1 + exp(-y z)), y in {-1, +1}
#   f'(z, y)  = -y sigma(-y z)
#   f*(u, y): with s = -u y in [0, 1],
#       f*(u, y) = s log s + (1 - s) log(1 - s)   (negative entropy), else +inf
# --------------------------------------------------------------------------

def _xlogx(s):
    return jnp.where(s > 0, s * jnp.log(jnp.where(s > 0, s, 1.0)), 0.0)


def _logit_value(z, y):
    # log(1 + exp(-yz)) computed stably.
    m = -y * z
    return jnp.logaddexp(0.0, m)


def _logit_grad(z, y):
    return -y * jax.nn.sigmoid(-y * z)


def _logit_conj(u, y):
    s = -u * y
    return _xlogx(s) + _xlogx(1.0 - s)


def _logit_dual_clip(u, y):
    # dom f* is { u : -u y in [0, 1] } ; clip to the interior for finiteness.
    eps = 1e-12
    s = jnp.clip(-u * y, eps, 1.0 - eps)
    return -s * y


def _logit_hess(z, y):
    s = jax.nn.sigmoid(-y * z)
    return s * (1.0 - s)          # y^2 = 1 for labels in {-1, +1}


logistic = Loss(
    name="logistic",
    value=_logit_value,
    grad=_logit_grad,
    conj=_logit_conj,
    smoothness=0.25,
    dual_clip=_logit_dual_clip,
    hess=_logit_hess,
)


LOSSES = {"least_squares": least_squares, "logistic": logistic}


def get_loss(name: str) -> Loss:
    try:
        return LOSSES[name]
    except KeyError:
        raise ValueError(f"unknown loss {name!r}; options: {sorted(LOSSES)}")
