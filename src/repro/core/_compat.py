"""One-shot deprecation plumbing for the legacy frontends (DESIGN.md §9).

Every pre-session frontend (``saif_path``, ``saif_batch``, ``cv_path``,
``fused_path``, ``group_saif``, the ``*_distributed`` trio, ...) now
delegates to the unified :mod:`repro.core.api` session and announces the
migration exactly once per process. The message deliberately contains the
literal string ``use repro.open_session`` — the CI serving smoke job turns
exactly that pattern into an error when running the examples, so no
first-party entry point can silently regress onto a deprecated surface.
"""
from __future__ import annotations

import warnings

_WARNED: set = set()


def warn_deprecated(old: str, new: str) -> None:
    """Emit the one-shot ``DeprecationWarning`` for a legacy frontend.

    ``old`` is the legacy callable, ``new`` the session-side call shape
    (the full table lives in DESIGN.md §9). Idempotent per process so
    request loops built on a legacy shim do not spam.
    """
    if old in _WARNED:
        return
    _WARNED.add(old)
    warnings.warn(
        f"{old} is deprecated: use repro.open_session(...) and "
        f"{new} instead (migration table: DESIGN.md §9)",
        DeprecationWarning, stacklevel=3)


def reset_deprecation_warnings() -> None:
    """Forget which one-shot warnings already fired (test hook)."""
    _WARNED.clear()
