"""Fixed-capacity active-set state (TPU adaptation of the paper's A_t / R_t).

Matlab grows/shrinks arrays freely; XLA requires static shapes. The active set
is therefore a capacity-``k_max`` buffer of feature indices plus a validity
mask. ADD/DEL are masked scatters — the whole SAIF outer loop compiles to a
single XLA program with no retraces.

The buffer also maintains, incrementally, the *compact sweep order* the inner
solver consumes: ``order`` is a permutation of the slot ids with the ``count``
live slots listed first. The old solver re-derived this with a per-outer-step
``jnp.argsort(~mask)``; ADD/DEL now keep it up to date with an O(k_max)
stable partition (cumsum + scatter, no sort). Live slots keep their relative
order across mutations, so the CM sweep order is deterministic and
insertion-stable.

Overflow policy (documented in DESIGN.md §2): if an ADD wants more slots than
are free, we add as many as fit and set ``overflowed``; the non-jitted driver
in ``saif.py`` doubles capacity and re-enters (warm-started) — an explicit,
rare recompile event, analogous to elastic resharding.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ActiveSet(NamedTuple):
    idx: jax.Array        # int32 (k_max,) feature ids; padding slots hold 0
    mask: jax.Array       # bool  (k_max,) slot validity
    beta: jax.Array       # f32   (k_max,) coefficients (0 on padding)
    in_active: jax.Array  # bool  (p,)     global membership mask
    overflowed: jax.Array  # bool scalar — an ADD ran out of slots
    order: jax.Array      # int32 (k_max,) slot permutation, live slots first
    count: jax.Array      # int32 scalar — number of live slots (= sum(mask))


def compact_order(order: jax.Array, mask: jax.Array) -> jax.Array:
    """Stable partition of ``order`` by slot liveness — live slots first.

    O(k_max) cumsum + scatter (no argsort): rank live and dead slots
    separately along the current sequence and scatter each slot to its new
    position. Relative order within both groups is preserved, so repeated
    calls are idempotent and mutations never reshuffle surviving slots.
    """
    live = jnp.take(mask, order)
    live_i = live.astype(jnp.int32)
    dead_i = 1 - live_i
    n_live = jnp.sum(live_i)
    rank_live = jnp.cumsum(live_i) - live_i
    rank_dead = jnp.cumsum(dead_i) - dead_i
    pos = jnp.where(live, rank_live, n_live + rank_dead)
    return jnp.zeros_like(order).at[pos].set(order)


def init_active_set(p: int, k_max: int, init_idx: jax.Array,
                    dtype=jnp.float32,
                    init_beta: jax.Array | None = None,
                    live_mask: jax.Array | None = None) -> ActiveSet:
    """Seed the buffer with ``init_idx``.

    Two modes:
      * static (live_mask=None): init_idx has shape (m,), m <= k_max.
      * slots  (live_mask given): init_idx/init_beta have shape (k_max,)
        and ``live_mask`` flags the live slots *in place*. The shape stays
        jit-static across warm-started lambda paths (no per-lambda
        recompiles, §Perf it. 1) and slot assignment is preserved exactly,
        which is what lets a warm-started path hand the Gram buffers of
        the previous lambda to the next solve without re-indexing
        (DESIGN.md §6).
    """
    if live_mask is None:
        m = init_idx.shape[0]
        idx = jnp.zeros((k_max,), jnp.int32).at[:m].set(
            init_idx.astype(jnp.int32))
        mask = jnp.zeros((k_max,), bool).at[:m].set(True)
        beta = jnp.zeros((k_max,), dtype)
        if init_beta is not None:
            beta = beta.at[:m].set(init_beta.astype(dtype))
        in_active = jnp.zeros((p,), bool).at[init_idx].set(True)
        order = jnp.arange(k_max, dtype=jnp.int32)
        n_live = jnp.asarray(m, jnp.int32)
    else:
        mask = jnp.asarray(live_mask, bool)
        idx = jnp.where(mask, init_idx.astype(jnp.int32), 0)
        beta = (jnp.where(mask, init_beta.astype(dtype), 0)
                if init_beta is not None else jnp.zeros((k_max,), dtype))
        in_active = jnp.zeros((p,), bool).at[
            jnp.where(mask, idx, p)].set(True, mode="drop")
        order = compact_order(jnp.arange(k_max, dtype=jnp.int32), mask)
        n_live = jnp.sum(mask).astype(jnp.int32)
    return ActiveSet(idx, mask, beta, in_active,
                     overflowed=jnp.asarray(False),
                     order=order, count=n_live)


def gather_columns(X: jax.Array, aset: ActiveSet) -> jax.Array:
    """(n, k_max) active design block; padded columns zeroed."""
    Xa = jnp.take(X, aset.idx, axis=1)
    return jnp.where(aset.mask[None, :], Xa, 0.0)


def pen_weights(aset: ActiveSet, unpen_idx: int, dtype=jnp.float32
                ) -> jax.Array:
    """(k_max,) per-slot l1 weight: 0 on the always-resident unpenalized
    slot (fused LASSO's ``b``, DESIGN.md §7), 1 everywhere else.

    ``unpen_idx`` is the *feature id* of the unpenalized coordinate (-1 =
    none); the weight follows the slot it currently occupies, so it is
    stable under ADD/DEL churn and capacity growth. Dead slots keep weight
    1 — their betas are pinned to 0 by the mask anyway.
    """
    if unpen_idx < 0:
        return jnp.ones_like(aset.beta, dtype)
    unpen_slot = aset.mask & (aset.idx == unpen_idx)
    return jnp.where(unpen_slot, 0.0, 1.0).astype(dtype)


def delete_features(aset: ActiveSet, drop_slot_mask: jax.Array) -> ActiveSet:
    """DEL: clear slots flagged in ``drop_slot_mask`` (bool (k_max,))."""
    p = aset.in_active.shape[0]
    drop = drop_slot_mask & aset.mask
    new_mask = aset.mask & ~drop
    new_beta = jnp.where(drop, 0.0, aset.beta)
    # Only dropped slots write (False) to the membership mask; padding and
    # surviving slots scatter out-of-bounds (mode="drop" discards them).
    write_idx = jnp.where(drop, aset.idx, p)
    new_in_active = aset.in_active.at[write_idx].set(False, mode="drop")
    return aset._replace(mask=new_mask, beta=new_beta,
                         in_active=new_in_active,
                         order=compact_order(aset.order, new_mask),
                         count=aset.count -
                         jnp.sum(drop).astype(jnp.int32))


def add_features(aset: ActiveSet, cand_idx: jax.Array,
                 cand_keep: jax.Array) -> ActiveSet:
    """ADD: scatter kept candidates into free slots.

    Args:
      cand_idx:  int32 (h,) candidate feature ids (descending score order).
      cand_keep: bool  (h,) which candidates to actually add.
    """
    k_max = aset.mask.shape[0]
    h = cand_idx.shape[0]
    free = ~aset.mask                                   # (k_max,)
    # Rank free slots: free_rank[s] = number of free slots strictly before s.
    free_rank = jnp.cumsum(free.astype(jnp.int32)) - free.astype(jnp.int32)
    n_free = jnp.sum(free.astype(jnp.int32))
    # Rank candidates among kept ones.
    keep = cand_keep
    cand_rank = jnp.cumsum(keep.astype(jnp.int32)) - keep.astype(jnp.int32)
    n_want = jnp.sum(keep.astype(jnp.int32))
    placed = keep & (cand_rank < n_free)

    # slot for candidate c: the (cand_rank[c])-th free slot. Build a map
    # free_order -> slot id via argsort of (free ? rank : big).
    big = jnp.asarray(k_max + 1, jnp.int32)
    order_key = jnp.where(free, free_rank, big)
    slot_of_rank = jnp.argsort(order_key)               # (k_max,)
    target_slot = slot_of_rank[jnp.clip(cand_rank, 0, k_max - 1)]
    target_slot = jnp.where(placed, target_slot, k_max)  # k_max => dropped

    new_idx = aset.idx.at[target_slot].set(cand_idx, mode="drop")
    new_mask = aset.mask.at[target_slot].set(True, mode="drop")
    new_beta = aset.beta.at[target_slot].set(0.0, mode="drop")
    p = aset.in_active.shape[0]
    new_in_active = aset.in_active.at[jnp.where(placed, cand_idx, p)].set(
        True, mode="drop")
    n_placed = jnp.sum(placed).astype(jnp.int32)
    return ActiveSet(new_idx, new_mask, new_beta, new_in_active,
                     overflowed=aset.overflowed | (n_want > n_free),
                     order=compact_order(aset.order, new_mask),
                     count=aset.count + n_placed)


def scatter_beta(aset: ActiveSet, p: int) -> jax.Array:
    """Inflate the compact beta back to (p,) (Algorithm 1 last line)."""
    out = jnp.zeros((p,), aset.beta.dtype)
    vals = jnp.where(aset.mask, aset.beta, 0.0)
    return out.at[jnp.where(aset.mask, aset.idx, p)].add(vals, mode="drop")


# --------------------------------------------------------------------------
# batched (problem-axis) views — the fleet engine (core/batch.py, DESIGN §8)
# --------------------------------------------------------------------------
# A *fleet* active set is the same ActiveSet NamedTuple with a leading
# problem axis B on every field: idx/mask/beta/order (B, k_max),
# in_active (B, p), overflowed/count (B,). All mutations are per-problem
# independent (cumsum/scatter over the slot axis only), so the batched
# forms are vmaps of the serial ones — each problem's slot arithmetic is
# bit-for-bit the serial computation, which is what the batch-parity
# acceptance (bitwise-identical active sets vs B serial solves) rests on.

def init_active_set_batch(p: int, k_max: int, init_idx: jax.Array,
                          dtype=jnp.float32,
                          init_beta: jax.Array | None = None,
                          live_mask: jax.Array | None = None) -> ActiveSet:
    """Batched slots-mode :func:`init_active_set` (leading problem axis)."""
    if init_beta is None:
        init_beta = jnp.zeros(init_idx.shape, dtype)
    if live_mask is None:
        raise ValueError("the batched init is slots-mode only: pass "
                         "(k_max,)-shaped per-problem buffers + live_mask")
    return jax.vmap(
        lambda i, b, m: init_active_set(p, k_max, i, dtype, b, m)
    )(init_idx, init_beta, live_mask)


def gather_columns_batch(X: jax.Array, aset: ActiveSet) -> jax.Array:
    """(B, n, k_max) active blocks from a shared (n, p) design."""
    return jax.vmap(gather_columns, in_axes=(None, 0))(X, aset)


delete_features_batch = jax.vmap(delete_features)
add_features_batch = jax.vmap(add_features)


def scatter_beta_batch(aset: ActiveSet, p: int) -> jax.Array:
    """(B, p) full solutions from a fleet active set."""
    return jax.vmap(scatter_beta, in_axes=(0, None))(aset, p)
