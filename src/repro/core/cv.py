"""K-fold cross-validated lambda paths on the batch-polymorphic engine.

The standard glmnet-style protocol — K folds x L lambdas — is a fleet
workload: all K fold problems share the design X and differ only in which
rows count. :func:`cv_path` runs the fold fleet through
``core/batch.py::_saif_batch_jit`` one lambda at a time (descending,
warm-started), so the whole K x L grid costs ONE compilation, the O(p)
screen scan is amortized across folds at every outer step, and the
Gram/screen state of the fleet survives every lambda handoff verbatim
(the slot-preserving warm extraction, exactly like the serial path
engine).

Fold masking is the *sample-weight trick* (DESIGN.md §8): fold k's
training problem is the LASSO on diag(w_k) rows with binary w_k, which
equals the row-subsampled problem exactly — gradients, primal values and
conjugate sums are weighted elementwise while X (and therefore the
screening matmul, the gathered active blocks and the Pallas tiles) stays
shared across the fleet. Per-fold column norms/c0/lambda_max ride along
as fleet (K, p) matrices. The Thm-2 sequential ball assumes the
unweighted null dual, so weighted fleets run on the (precision-floored)
gap ball alone — same deviation discipline as the fused subsystem (§7).
"""
from __future__ import annotations

import math
from typing import List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batch import (_saif_batch_jit, initial_support_batch,
                              prepare_fleet, resolve_batch_inner,
                              saif_batch_compile_count)
from repro.core.inner_backend import cold_inner_carry_batch
from repro.core.losses import get_loss
from repro.core.saif import (SaifConfig, SaifResult, add_batch_size_static,
                             default_capacity, saif)
from repro.core.screen_backend import resolve_batch_screen


class CVPathResult(NamedTuple):
    lams: np.ndarray            # (L,) descending grid
    cv_mean: np.ndarray         # (L,) mean held-out loss per lambda
    cv_se: np.ndarray           # (L,) standard error across folds
    best_lam: float             # argmin of cv_mean
    beta: Optional[jnp.ndarray]      # (p,) full-data refit at best_lam
    best_result: Optional[SaifResult]
    fold_betas: Optional[List[jnp.ndarray]]  # per-lambda (K, p) if kept
    n_compilations: Optional[int]   # batch-engine compiles this path added


def kfold_weights(n: int, n_folds: int, seed: int = 0,
                  dtype=jnp.float64) -> jnp.ndarray:
    """(K, n) binary TRAIN-row masks: row k is 1 off fold k, 0 on it.
    Folds are a balanced random partition (host RNG, reproducible)."""
    if not 2 <= n_folds <= n:
        raise ValueError(f"need 2 <= n_folds <= n, got {n_folds} for n={n}")
    rng = np.random.default_rng(seed)
    assign = rng.permutation(np.arange(n) % n_folds)
    W = np.ones((n_folds, n))
    W[assign, np.arange(n)] = 0.0
    return jnp.asarray(W, dtype)


def cv_solve(X, y, lams: Sequence[float], n_folds: int = 5,
             config: SaifConfig = SaifConfig(), seed: int = 0,
             keep_fold_betas: bool = False,
             refit: bool = True) -> CVPathResult:
    """K-fold cross-validation over a lambda grid, one fleet compilation.

    Solves the K fold problems in lockstep at every lambda (descending,
    fleet-warm-started), scores each lambda by the mean held-out loss
    (``loss.value`` averaged over each fold's validation rows), and
    refits the winner on the full data with the serial solver.
    """
    if config.unpen_idx is not None:
        raise NotImplementedError("cv_path cross-validates plain-LASSO "
                                  "problems (DESIGN.md §8)")
    if len(lams) == 0:
        raise ValueError("cv_path needs a non-empty lambda grid")
    loss = get_loss(config.loss)
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    n, p = X.shape
    K = n_folds
    W = kfold_weights(n, K, seed=seed, dtype=X.dtype)
    Y = jnp.broadcast_to(y, (K, n))
    lams_np = np.asarray(sorted([float(l) for l in lams], reverse=True))
    n_compile0 = saif_batch_compile_count()

    prep = prepare_fleet(X, Y, config, weights=W)
    backend = resolve_batch_screen(config.screen_backend)
    # grid-max static h over the whole K x L fleet family; per-(fold,
    # lambda) batch sizes and tolerances stay traced — the path-engine
    # trick (§4), fleet edition
    hs_grid = [[add_batch_size_static(config.c, lam, mx, md, p)
                for mx, md in zip(prep.c0_max, prep.c0_median)]
               for lam in lams_np]
    h = max(max(hs_l) for hs_l in hs_grid)
    k_max = config.k_max or default_capacity(h, p)
    eps_vec = jnp.full((K,), config.eps, X.dtype)

    def delta0_vec(lam: float) -> jnp.ndarray:
        if config.delta0 is not None:
            return jnp.full((K,), config.delta0, X.dtype)
        return jnp.asarray([min(max(lam / mx, 1e-3), 1.0)
                            for mx in prep.c0_max], X.dtype)

    # cold start at the grid's first lambda, computed once (elastic growth
    # pads it, mirroring the serial driver's overflow recovery)
    cold_idx, cold_beta, cold_mask = initial_support_batch(
        prep.c0, hs_grid[0], k_max, p, X.dtype)
    while True:
        pad = k_max - cold_idx.shape[1]
        if pad > 0:
            cold_idx = jnp.pad(cold_idx, ((0, 0), (0, pad)))
            cold_beta = jnp.pad(cold_beta, ((0, 0), (0, pad)))
            cold_mask = jnp.pad(cold_mask, ((0, 0), (0, pad)))
        inner = resolve_batch_inner(config, n, k_max, K)
        warm = None
        results: List[SaifResult] = []
        for li, lam in enumerate(lams_np):
            hs_l = hs_grid[li]
            if warm is None:
                init_idx, init_beta, init_mask = cold_idx, cold_beta, \
                    cold_mask
                carry = cold_inner_carry_batch(K, k_max, X.dtype,
                                               backend=inner)
            else:
                init_idx, init_beta, init_mask, carry = warm
            res = _saif_batch_jit(
                X, Y, W, prep.col_norm, prep.c0,
                jnp.full((K,), lam, X.dtype), eps_vec, delta0_vec(lam),
                init_idx, init_beta, init_mask,
                carry.G, carry.rho, carry.gidx,
                jnp.asarray([max(int(math.ceil(config.zeta * h_b)), 1)
                             for h_b in hs_l], jnp.int32),
                jnp.asarray(hs_l, jnp.int32),
                loss_name=config.loss, h=h, k_max=k_max,
                inner_epochs=config.inner_epochs,
                polish_factor=config.polish_factor,
                max_outer=config.max_outer, use_seq_ball=False,
                screen_backend=backend, inner_backend=inner,
                has_weights=True)
            results.append(res)
            # slot-preserving fleet warm handoff (path.py::_warm_state,
            # batched): Gram buffers stay valid verbatim across lambdas
            vals = jnp.where(res.active_mask,
                             jnp.take_along_axis(res.beta, res.active_idx,
                                                 axis=1), 0.0)
            live = res.active_mask & (vals != 0)
            warm = (res.active_idx, jnp.where(live, vals, 0.0), live,
                    res.inner)
        # ONE host sync for the whole grid's overflow flags
        flags = jnp.stack([r.overflowed for r in results])
        if not bool(jnp.any(flags)) or k_max >= p:
            break
        k_max = min(2 * k_max, p)   # elastic growth, full-path re-entry

    # --- held-out scoring: mean validation loss per (fold, lambda) --------
    W_test = 1.0 - W                                        # (K, n)
    n_test = jnp.sum(W_test, axis=1)                        # (K,)
    errs = []
    for res in results:
        Z = res.beta @ X.T                                  # (K, n)
        errs.append(jnp.sum(W_test * loss.value(Z, Y), axis=1) / n_test)
    err_kl = np.asarray(jax.device_get(jnp.stack(errs)))    # (L, K)
    cv_mean = err_kl.mean(axis=1)
    cv_se = err_kl.std(axis=1, ddof=1) / np.sqrt(K)
    best_i = int(np.argmin(cv_mean))
    best_lam = float(lams_np[best_i])

    beta_best = best_result = None
    if refit:
        best_result = saif(X, y, best_lam, config)
        beta_best = best_result.beta

    n_compile1 = saif_batch_compile_count()
    n_comp = (max(n_compile1 - n_compile0, 0)
              if n_compile0 >= 0 and n_compile1 >= 0 else None)
    return CVPathResult(
        lams=lams_np, cv_mean=cv_mean, cv_se=cv_se, best_lam=best_lam,
        beta=beta_best, best_result=best_result,
        fold_betas=[r.beta for r in results] if keep_fold_betas else None,
        n_compilations=n_comp)


def one_se_lambda(lams: np.ndarray, cv_mean: np.ndarray,
                  cv_se: np.ndarray) -> float:
    """The glmnet 1-SE rule (DESIGN.md §14): the *largest* lambda whose
    CV error is within one standard error of the minimum — the sparsest
    model statistically indistinguishable from the best scorer. Expects
    the descending grid / per-lambda scores of a :class:`CVPathResult`.
    """
    lams = np.asarray(lams, np.float64)
    cv_mean = np.asarray(cv_mean, np.float64)
    cv_se = np.asarray(cv_se, np.float64)
    i_min = int(np.argmin(cv_mean))
    thresh = cv_mean[i_min] + cv_se[i_min]
    # descending grid: the first index within the threshold is the
    # largest eligible lambda (i_min itself qualifies, so one exists)
    return float(lams[int(np.argmax(cv_mean <= thresh))])


def cv_path(X, y, lams: Sequence[float], n_folds: int = 5,
            config: SaifConfig = SaifConfig(), seed: int = 0,
            keep_fold_betas: bool = False,
            refit: bool = True) -> CVPathResult:
    """DEPRECATED legacy frontend — one-shot session over
    :func:`cv_solve`.

    Use ``repro.open_session(Problem(X, y), config).solve(CV(n_folds,
    lams))``; a held-open session keeps the fold-fleet compilation alive
    for the next grid (DESIGN.md §9).
    """
    from repro.core._compat import warn_deprecated
    warn_deprecated("repro.core.cv_path",
                    "session.solve(CV(n_folds, lams))")
    from repro.core.api import CV, Problem, open_session

    sess = open_session(Problem(X=X, y=y, loss=config.loss), config)
    return sess.solve(CV(n_folds=n_folds,
                         lams=tuple(float(l) for l in lams), seed=seed,
                         keep_fold_betas=keep_fold_betas, refit=refit))
