"""Group-LASSO SAIF — the extension the paper's conclusion proposes.

Problem:  min_beta  sum_j f(x_j. beta, y_j) + lam * sum_g ||beta_g||_2
with disjoint equal-size groups (p = n_groups * gsize, static).

Dual feasible set:  Omega = { theta : ||X_g^T theta||_2 <= 1  for all g }.
Everything from the LASSO machinery carries over group-wise:

* gap-safe ball: identical (Eq. 11 depends only on f*, not the penalty);
* screening rule:  ||X_g^T theta|| + ||X_g||_F * r < 1  =>  group inactive
  (|| . ||_F upper-bounds the operator norm, so the rule stays SAFE);
* ADD: recruit the argmax_g ||X_g^T theta|| groups from the remaining set;
* inner solver: cyclic block-proximal minimization with the group
  soft-threshold  S_t(v) = v * max(0, 1 - t/||v||)  and block Lipschitz
  L_g = ||X_g||_F^2 * alpha (majorization — exact for orthonormal groups).

Implementation mirrors core/saif.py at group granularity with a
fixed-capacity *group* active set.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.losses import Loss, get_loss


@dataclasses.dataclass(frozen=True)
class GroupSaifConfig:
    eps: float = 1e-8
    inner_epochs: int = 5
    polish_factor: int = 8
    k_max: Optional[int] = None    # active-set capacity in GROUPS
    max_outer: int = 2000
    h: Optional[int] = None        # groups recruited per ADD
    loss: str = "least_squares"


class GroupSaifResult(NamedTuple):
    beta: jax.Array
    gap: jax.Array
    n_outer: jax.Array
    n_active_groups: jax.Array
    # final slot state — the warm handoff a session threads between group
    # requests (mirrors SaifResult.active_idx/active_mask, DESIGN.md §9)
    gidx: jax.Array = None          # (k_max,) slot -> group id
    gmask: jax.Array = None         # (k_max,) slot validity
    beta_slots: jax.Array = None    # (k_max, gsize) slot coefficients


def _group_norms(v: jax.Array, gsize: int) -> jax.Array:
    """(p,) -> (n_groups,) euclidean norms of consecutive blocks."""
    return jnp.linalg.norm(v.reshape(-1, gsize), axis=1)


def group_soft_threshold(v: jax.Array, t: jax.Array) -> jax.Array:
    nrm = jnp.linalg.norm(v)
    scale = jnp.maximum(1.0 - t / jnp.maximum(nrm, 1e-30), 0.0)
    return v * scale


def solve_group_lasso_bcd(loss: Loss, X, y, lam, gsize: int,
                          tol=1e-10, max_epochs=50_000):
    """Unscreened block-CD oracle (ground truth for tests/benches)."""
    n, p = X.shape
    ng = p // gsize
    Xg = X.reshape(n, ng, gsize)
    Lg = jnp.maximum(loss.smoothness
                     * jnp.sum(Xg * Xg, axis=(0, 2)), 1e-30)   # (ng,)

    def epoch(carry):
        beta, z, _, t = carry

        def block(g, bz):
            beta, z = bz
            bg = jax.lax.dynamic_slice(beta, (g * gsize,), (gsize,))
            grad = jnp.einsum("nk,n->k", jax.lax.dynamic_slice(
                Xg, (0, g, 0), (n, 1, gsize))[:, 0], loss.grad(z, y))
            v = bg - grad / Lg[g]
            bg_new = group_soft_threshold(v, lam / Lg[g])
            z = z + jax.lax.dynamic_slice(Xg, (0, g, 0),
                                          (n, 1, gsize))[:, 0] @ (bg_new - bg)
            beta = jax.lax.dynamic_update_slice(beta, bg_new, (g * gsize,))
            return beta, z

        beta, z = jax.lax.fori_loop(0, ng, block, (beta, z))
        # duality gap with the group-feasible scaled dual point
        hat = -loss.grad(z, y) / lam
        gmax = jnp.max(_group_norms(X.T @ hat, gsize))
        theta = hat / jnp.maximum(gmax, 1.0)
        p_val = (jnp.sum(loss.value(z, y))
                 + lam * jnp.sum(_group_norms(beta, gsize)))
        gap = p_val - loss.dual_objective(y, theta, lam)
        return beta, z, gap, t + 1

    def cond(c):
        return (c[2] > tol) & (c[3] < max_epochs)

    beta0 = jnp.zeros((p,), X.dtype)
    out = jax.lax.while_loop(cond, epoch,
                             (beta0, jnp.zeros_like(y),
                              jnp.asarray(jnp.inf, X.dtype), jnp.asarray(0)))
    return out[0]


@partial(jax.jit, static_argnames=("loss_name", "gsize", "h", "k_max",
                                   "inner_epochs", "polish_factor",
                                   "max_outer"))
def _gsaif_jit(X, y, gfro, lam, eps, init_gidx, init_beta, init_gmask, *,
               loss_name, gsize, h, k_max, inner_epochs, polish_factor,
               max_outer):
    # (init_gidx, init_beta, init_gmask) are traced (k_max,)-shaped slot
    # buffers — zeros/top-h for a cold start, the previous solve's final
    # slot state for a warm one — so every lambda served at a given
    # (gsize, h, k_max) signature shares ONE compilation (the group
    # engine's edition of the path-engine trick, DESIGN.md §9).
    loss = get_loss(loss_name)
    n, p = X.shape
    ng = p // gsize
    Xg = X.reshape(n, ng, gsize)
    Lg_all = jnp.maximum(loss.smoothness * gfro ** 2, 1e-30)

    class S(NamedTuple):
        gidx: jax.Array     # (k_max,) group ids
        gmask: jax.Array    # (k_max,)
        beta: jax.Array     # (k_max, gsize)
        in_active: jax.Array  # (ng,)
        gap: jax.Array
        is_add: jax.Array
        stop: jax.Array
        t: jax.Array

    s0 = S(gidx=init_gidx.astype(jnp.int32),
           gmask=init_gmask,
           beta=init_beta.astype(X.dtype),
           in_active=jnp.zeros((ng,), bool).at[
               jnp.where(init_gmask, init_gidx, ng)].set(True, mode="drop"),
           gap=jnp.asarray(jnp.inf, X.dtype),
           is_add=jnp.asarray(True), stop=jnp.asarray(False),
           t=jnp.asarray(0))

    def cond(s):
        return (~s.stop) & (s.t < max_outer)

    def body(s: S) -> S:
        Xa = jnp.where(s.gmask[None, :, None],
                       jnp.take(Xg, s.gidx, axis=1), 0.0)  # (n, k_max, gs)
        Lg = jnp.where(s.gmask, jnp.take(Lg_all, s.gidx), 1.0)

        def bcd_epoch(_, bz):
            def block(j, bz):
                beta, z = bz
                xj = Xa[:, j]                          # (n, gsize)
                grad = xj.T @ loss.grad(z, y)
                v = beta[j] - grad / Lg[j]
                bnew = group_soft_threshold(v, lam / Lg[j])
                bnew = jnp.where(s.gmask[j], bnew, 0.0)
                z = z + xj @ (bnew - beta[j])
                return beta.at[j].set(bnew), z
            return jax.lax.fori_loop(0, k_max, block, bz)

        n_ep = jnp.where(s.is_add, inner_epochs,
                         inner_epochs * polish_factor)
        beta, z = jax.lax.fori_loop(
            0, n_ep, bcd_epoch,
            (s.beta, jnp.einsum("nkg,kg->n", Xa, s.beta)))

        # dual point, gap, ball
        hat = -loss.grad(z, y) / lam
        gnorm_hat = jnp.linalg.norm(
            jnp.einsum("nkg,n->kg", Xa, hat), axis=1)
        tau = 1.0 / jnp.maximum(jnp.max(jnp.where(s.gmask, gnorm_hat, 0.0)),
                                1.0)
        theta = tau * hat
        p_val = (jnp.sum(loss.value(z, y))
                 + lam * jnp.sum(jnp.where(s.gmask,
                                           jnp.linalg.norm(beta, axis=1),
                                           0.0)))
        gap = p_val - loss.dual_objective(y, theta, lam)
        r = jnp.sqrt(2.0 * loss.smoothness * jnp.maximum(gap, 0.0)) / lam

        stop_now = (~s.is_add) & (gap <= eps)

        # DEL groups
        corr_act = jnp.linalg.norm(jnp.einsum("nkg,n->kg", Xa, theta),
                                   axis=1)
        fro_act = jnp.where(s.gmask, jnp.take(gfro, s.gidx), 0.0)
        drop = s.gmask & (corr_act + fro_act * r < 1.0) & ~stop_now
        gmask = s.gmask & ~drop
        beta = jnp.where(drop[:, None], 0.0, beta)
        in_active = s.in_active.at[jnp.where(drop, s.gidx, ng)].set(
            False, mode="drop")

        # ADD groups
        scores = jnp.linalg.norm(jnp.einsum("njg,n->jg", Xg, theta), axis=1)
        scores = jnp.where(in_active, -jnp.inf, scores)
        ub = scores + gfro * r
        add_done = jnp.max(ub) < 1.0

        def on_add(args):
            gidx, gmask, in_active, is_add = args
            top_s, top_i = jax.lax.top_k(scores, h)
            keep = jnp.isfinite(top_s)
            free = ~gmask
            free_rank = jnp.cumsum(free.astype(jnp.int32)) - free
            order_key = jnp.where(free, free_rank, k_max + 1)
            slot_of_rank = jnp.argsort(order_key)
            cand_rank = jnp.cumsum(keep.astype(jnp.int32)) - keep
            placed = keep & (cand_rank < jnp.sum(free))
            tgt = jnp.where(placed,
                            slot_of_rank[jnp.clip(cand_rank, 0, k_max - 1)],
                            k_max)
            gidx = gidx.at[tgt].set(top_i.astype(jnp.int32), mode="drop")
            gmask = gmask.at[tgt].set(True, mode="drop")
            in_active = in_active.at[jnp.where(placed, top_i, ng)].set(
                True, mode="drop")
            return gidx, gmask, in_active, is_add

        def on_done(args):
            gidx, gmask, in_active, _ = args
            return gidx, gmask, in_active, jnp.asarray(False)

        gidx, gmask, in_active, is_add = jax.lax.cond(
            s.is_add & ~stop_now,
            lambda a: jax.lax.cond(add_done, on_done, on_add, a),
            lambda a: a, (s.gidx, gmask, in_active, s.is_add))

        return S(gidx=gidx, gmask=gmask, beta=beta, in_active=in_active,
                 gap=gap, is_add=is_add, stop=stop_now, t=s.t + 1)

    f = jax.lax.while_loop(cond, body, s0)
    beta_full = jnp.zeros((ng, gsize), X.dtype).at[
        jnp.where(f.gmask, f.gidx, ng)].add(
        jnp.where(f.gmask[:, None], f.beta, 0.0), mode="drop")
    return GroupSaifResult(beta=beta_full.reshape(-1), gap=f.gap,
                           n_outer=f.t,
                           n_active_groups=jnp.sum(f.gmask),
                           gidx=f.gidx, gmask=f.gmask, beta_slots=f.beta)


def group_compile_count() -> int:
    """Distinct ``_gsaif_jit`` compilations alive in this process (the
    group-engine leg of :func:`repro.core.api.unified_compile_count`;
    mirrors ``saif_jit_compile_count``). The group static signature
    (gsize, h, k_max) is lambda-independent, so a session serving many
    group requests must move this counter exactly once — asserted in
    tests/test_api.py."""
    try:
        return int(_gsaif_jit._cache_size())
    except Exception:       # pragma: no cover - jit internals moved
        return -1


class GroupPrep(NamedTuple):
    """One-time group-problem preparation: null-gradient group norms, the
    per-group Frobenius norms, and the (lambda-independent) static sizes.
    Computed once per session (``repro.core.api``)."""
    X: jax.Array
    y: jax.Array
    c0: jax.Array      # (ng,) group norms of X^T f'(0)
    gfro: jax.Array    # (ng,) per-group Frobenius norms
    gsize: int
    h: int
    k_max: int


def prepare_group(X, y, gsize: int,
                  config: GroupSaifConfig = GroupSaifConfig()) -> GroupPrep:
    loss = get_loss(config.loss)
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    n, p = X.shape
    assert p % gsize == 0, "p must be a multiple of the group size"
    ng = p // gsize
    g0 = loss.grad(jnp.zeros_like(y), y)
    c0 = _group_norms(X.T @ g0, gsize)
    gfro = jnp.sqrt(jnp.sum((X * X).reshape(n, ng, gsize), axis=(0, 2)))
    h = config.h or max(1, 1 << (math.ceil(math.log2(max(ng, 2))) // 2))
    k_max = config.k_max or min(ng, max(8 * h, 32))
    return GroupPrep(X=X, y=y, c0=c0, gfro=gfro, gsize=gsize, h=h,
                     k_max=k_max)


def group_solve(prep: GroupPrep, lam: float,
                config: GroupSaifConfig = GroupSaifConfig(),
                warm=None) -> GroupSaifResult:
    """One group solve from an existing preparation. ``warm`` is the
    previous solve's ``(gidx, gmask, beta_slots)`` (e.g. the fields of a
    :class:`GroupSaifResult` at the neighbouring lambda); ``None`` is the
    cold top-h start — bitwise the legacy ``group_saif`` behavior."""
    X, gsize, h, k_max = prep.X, prep.gsize, prep.h, prep.k_max
    if warm is None:
        m = min(h, k_max)
        top = jax.lax.top_k(prep.c0, m)[1]
        gidx = jnp.zeros((k_max,), jnp.int32).at[:m].set(
            top.astype(jnp.int32))
        gmask = jnp.zeros((k_max,), bool).at[:m].set(True)
        beta = jnp.zeros((k_max, gsize), X.dtype)
    else:
        gidx, gmask, beta = warm
    return _gsaif_jit(X, prep.y, prep.gfro, jnp.asarray(lam, X.dtype),
                      jnp.asarray(config.eps, X.dtype), gidx, beta, gmask,
                      loss_name=config.loss, gsize=gsize, h=h, k_max=k_max,
                      inner_epochs=config.inner_epochs,
                      polish_factor=config.polish_factor,
                      max_outer=config.max_outer)


def group_saif(X, y, lam: float, gsize: int,
               config: GroupSaifConfig = GroupSaifConfig()
               ) -> GroupSaifResult:
    """DEPRECATED legacy frontend — one-shot session over
    :func:`group_solve`. Use ``repro.open_session(Problem(X, y,
    penalty=group(gsize)), config).solve(Scalar(lam))``; the session
    reuses the preparation, the single group compilation and the warm
    slot buffers across requests (DESIGN.md §9)."""
    from repro.core._compat import warn_deprecated
    warn_deprecated("repro.core.group_saif",
                    "session.solve(Scalar(lam)) with penalty=group(gsize)")
    from repro.core.api import Problem, Scalar, group, open_session

    sess = open_session(Problem(X=X, y=y, loss=config.loss,
                                penalty=group(gsize)), config)
    return sess.solve(Scalar(lam=float(lam)))


def group_lambda_max(loss: Loss, X, y, gsize: int) -> float:
    g0 = loss.grad(jnp.zeros_like(jnp.asarray(y)), jnp.asarray(y))
    return float(jnp.max(_group_norms(jnp.asarray(X).T @ g0, gsize)))
