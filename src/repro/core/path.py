"""Warm-started SAIF lambda-path driver (paper Sec 5.3)."""
from __future__ import annotations

from typing import List, NamedTuple, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.saif import SaifConfig, SaifResult, saif


class SaifPathResult(NamedTuple):
    lams: np.ndarray
    betas: List[jnp.ndarray]
    results: List[SaifResult]


def saif_path(X, y, lams: Sequence[float],
              config: SaifConfig = SaifConfig()) -> SaifPathResult:
    """Solve a descending lambda path; each solve warm-starts from the last."""
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    lams = np.asarray(sorted([float(l) for l in lams], reverse=True))
    betas, results = [], []
    warm_idx = warm_beta = None
    for lam in lams:
        res = saif(X, y, float(lam), config,
                   warm_idx=warm_idx, warm_beta=warm_beta)
        betas.append(res.beta)
        results.append(res)
        support = jnp.nonzero(jnp.abs(res.beta) > 0,
                              size=res.beta.shape[0], fill_value=0)[0]
        n_sup = int(jnp.sum(jnp.abs(res.beta) > 0))
        if n_sup > 0:
            warm_idx = support[:n_sup]
            warm_beta = res.beta[warm_idx]
        else:
            warm_idx = warm_beta = None
    return SaifPathResult(lams=lams, betas=betas, results=results)


def lambda_grid(lam_max: float, n: int, lo_frac: float = 1e-3) -> np.ndarray:
    """Log-evenly spaced descending grid in [lo_frac*lam_max, lam_max)."""
    return np.geomspace(lam_max * (1 - 1e-9), lam_max * lo_frac, n)
