"""Compile-first warm-started SAIF lambda-path engine (paper Sec 5.3).

The naive path driver (kept as :func:`saif_path_naive`, the benchmark
baseline) calls the single-lambda host driver per grid point, which costs
per lambda: an O(np) re-preprocessing of (c0, col_norm, lam_max), a host
sync for the overflow flag, a host round-trip to extract the warm-start
support, and — whenever the static (h, k_max) signature moves — a fresh
``_saif_jit`` compilation.

The engine here (:func:`run_path`) hoists all of that out of the lambda
loop:

  * **prepare once** — the driver consumes a prebuilt
    :class:`~repro.core.saif.PathState` (c0 / col_norm / lam_max and the
    c0 statistics feeding the h formula, computed exactly once — at
    ``open_session`` when serving through :mod:`repro.core.api`);
  * **one static signature** — the candidate-buffer size h is bucketed to
    the *grid maximum* (already a power of two) so every lambda shares a
    single ``_saif_jit`` compilation, while the per-lambda batch size
    (h_cap) and violation tolerance (h~) ride along as *traced* scalars —
    they only feed comparisons. The ADD decisions are therefore bitwise
    those of a per-lambda compile; only the compile count changes. Worst
    case over capacity growth this is O(log p) distinct compilations per
    path (assert via :func:`repro.core.saif.saif_jit_compile_count`);
  * **fixed-capacity warm buffers** — the (k_max,) warm-start index/value
    buffers are produced *on device* from the previous solution and
    *preserve the slot layout* of the previous solve, so the inter-lambda
    handoff never syncs to the host AND the inner-solver carry (the Gram
    buffers of the covariance-update backend, DESIGN.md §6) rides along
    verbatim — the next solve's init finds zero dirty slots and skips the
    O(n k^2) Gram rebuild. The same warm tuple is the engine's *boundary*
    state: ``run_path`` accepts an entry warm state and returns its exit
    warm state, which is how a session keeps the buffers device-resident
    across requests (``Scalar(lam, warm=True)`` streams);
  * **segment-batched overflow checks** — solutions are collected per path
    segment and the ``overflowed`` flags are reduced in one host sync per
    segment instead of one per lambda. On overflow the capacity doubles and
    the segment re-runs from its entry state (rare: capacity starts at the
    grid-max 8h).

The legacy frontend :func:`saif_path` is a deprecated shim over a one-shot
session (DESIGN.md §9).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core._compat import warn_deprecated
from repro.core.duality import (duality_gap, feasible_dual, gap_ball,
                                sequential_ball)
from repro.core.inner_backend import (InnerCarry, cold_inner_carry,
                                      resolve_inner_backend)
from repro.core.losses import get_loss
from repro.core.saif import (PathState, SaifConfig, SaifResult, _saif_jit,
                             add_batch_size_static, default_capacity,
                             initial_support, prepare_path, saif,
                             saif_jit_compile_count)
from repro.core.screen_backend import (ScreenFn, resolve_backend,
                                       resolve_screen_rule)
from repro.runtime.inject import seam as _fault_seam

# Device-resident inter-solve handoff: (idx (k,), beta (k,), live-mask (k,),
# InnerCarry). Produced by _warm_state / cold_start, consumed by run_path.
WarmState = Tuple[jax.Array, jax.Array, jax.Array, InnerCarry]


class SaifPathResult(NamedTuple):
    lams: np.ndarray
    betas: List[jnp.ndarray]
    results: List[SaifResult]
    n_compilations: Optional[int] = None   # _saif_jit compiles this path added


@partial(jax.jit, static_argnames=("unpen_idx",))
def _warm_state(active_idx: jax.Array, active_mask: jax.Array,
                beta_full: jax.Array, inner: InnerCarry,
                unpen_idx: int = -1) -> WarmState:
    """Device-side warm-start extraction, *slot-preserving*.

    The next lambda is seeded with the previous solve's final slot layout
    (masked down to the nonzero support), so the Gram buffers in ``inner``
    — which are indexed by slot — remain valid verbatim: the next
    ``_saif_jit``'s init finds zero dirty slots and skips the O(n k^2)
    rebuild entirely (DESIGN.md §6). No host round-trip anywhere. The
    unpenalized slot (fused paths) stays resident even at b = 0 exactly.
    """
    vals = jnp.where(active_mask, jnp.take(beta_full, active_idx), 0.0)
    live = active_mask & (vals != 0)
    if unpen_idx >= 0:
        live = live | (active_mask & (active_idx == unpen_idx))
    return active_idx, jnp.where(live, vals, 0.0), live, inner


def cold_start(prep: PathState, h0: int, k: int,
               config: SaifConfig) -> WarmState:
    """Cold entry state at capacity ``k``: the shared ``initial_support``
    constructor seeded with the FIRST lambda's own batch size ``h0`` (not
    the grid-max h) — a cold path entry must match a standalone solve at
    its first lambda exactly."""
    n, p = prep.X.shape
    idx, beta, n_init = initial_support(prep.c0, h0, k, prep.p_true or p,
                                        config.unpen_idx, prep.b0,
                                        prep.X.dtype)
    inner = resolve_inner_backend(config.inner_backend, config.loss,
                                  prep.n_true or n, k)
    return (idx, beta, jnp.arange(k) < n_init,
            cold_inner_carry(k, prep.X.dtype, backend=inner))


def grow_warm(warm: WarmState, k: int, inner_name: str) -> WarmState:
    """Pad a warm state to capacity ``k`` (elastic growth / session handoff
    across requests of different static signatures)."""
    idx, vals, mask, carry = warm
    pad = k - idx.shape[0]
    if pad <= 0:
        return warm
    if inner_name == "gram" and carry.G.shape[0] == idx.shape[0]:
        # pad the Gram buffers in place: padded slots are dead/-1, the
        # carried warmth survives the capacity doubling
        carry = InnerCarry(
            G=jnp.pad(carry.G, ((0, pad), (0, pad))),
            rho=jnp.pad(carry.rho, (0, pad)),
            gidx=jnp.pad(carry.gidx, (0, pad), constant_values=-1))
    else:   # crossover flipped the backend: rebuild a cold carry
        carry = cold_inner_carry(k, vals.dtype, backend=inner_name)
    return (jnp.pad(idx, (0, pad)), jnp.pad(vals, (0, pad)),
            jnp.pad(mask, (0, pad)), carry)


@partial(jax.jit, static_argnames=("loss_name",))
def _seq_entry_jit(X, y, col_norm, idx, vals, mask, gidx, lam0, lam, p_true,
                   loss_name: str = "least_squares"):
    """Theorem-2 sequential-ball warm entry (DESIGN.md §14), compiled.

    Given a cached solution at ``lam0 >= lam`` (slot layout idx/vals/mask
    plus its gram carry's gidx), certify a dual ball that contains the
    *target* dual optimum theta*(lam) and pre-recruit its screening
    survivors into the free slots:

      * theta0 = feasible dual of the cached primal at lam0, with
        gap0 its duality gap — so theta*(lam0) lies in the gap sphere
        B(theta0, r_gap0) (Ndiaye et al., "Mind the duality gap");
      * the paper's Theorem-2 sequential ball maps theta*(lam0) to a
        ball around (lam0/lam) theta*(lam0); seeding it from theta0
        instead is made rigorous by widening with the *propagated* gap
        radius: theta*(lam) in B((lam0/lam) theta0,
        r_seq + (lam0/lam) r_gap0), since the center moved by at most
        (lam0/lam) ||theta0 - theta*(lam0)||.

    Features with ub_j = |x_j^T center| + ||x_j|| r < 1 are certified
    inactive at lam; the survivors (minus those already resident) fill
    the free slots with vals 0 and gidx -1, so the engine's ``init``
    reconciles the new columns in-trace (one bounded rebuild, no
    recompile). The cached live slots keep gidx untouched — an exact-
    lambda repeat enters with zero dirty slots. This only *seeds* the
    active set: the solve itself still runs SAIF's ADD loop and stop
    test, so the end result stays KKT-certified regardless of the seed.
    """
    loss = get_loss(loss_name)
    p = X.shape[1]
    k = idx.shape[0]
    vals = jnp.where(mask, vals, 0.0)
    cols = jnp.take(X, idx, axis=1)
    z = cols @ vals
    hat = -loss.grad(z, y) / lam0
    theta0 = feasible_dual(loss, X, y, hat, lam0)
    gap0 = jnp.maximum(duality_gap(loss, cols, y, vals, theta0, lam0,
                                   mask=mask), 0.0)
    r_gap0 = gap_ball(loss, theta0, gap0, lam0).radius
    ball = sequential_ball(loss, y, theta0, lam0, lam)
    r = ball.radius + (lam0 / lam) * r_gap0
    ub = jnp.abs(X.T @ ball.center) + col_norm * r
    real = jnp.arange(p) < p_true          # bucket-padded columns never seed
    survive = (ub >= 1.0) & real
    # pre-recruit survivors not already resident into the free slots
    in_slots = jnp.zeros((p,), bool).at[idx].max(mask)
    score = jnp.where(survive & ~in_slots, ub, -jnp.inf)
    cand_score, cand_idx = jax.lax.top_k(score, k)
    ok = jnp.isfinite(cand_score)
    free_pos = jnp.nonzero(~mask, size=k, fill_value=k)[0]
    pos = jnp.where(ok, free_pos, k)       # k = out of range -> dropped
    idx2 = idx.at[pos].set(cand_idx, mode="drop")
    mask2 = mask.at[pos].set(True, mode="drop")
    gidx2 = gidx.at[pos].set(-1, mode="drop")
    n_seeded = jnp.sum(ok & (free_pos < k)).astype(jnp.int32)
    return idx2, vals, mask2, gidx2, jnp.sum(survive).astype(jnp.int32), \
        n_seeded


def seq_warm_entry(prep: PathState, warm: WarmState, k_max: int,
                   lam0: float, lam: float,
                   config: SaifConfig) -> Tuple[WarmState, int]:
    """Build a certified warm-entry state at ``lam`` from a cached
    solution at ``lam0`` (the cross-request homotopy cache's hit path,
    DESIGN.md §14). Host-sync-free: one jitted call, lam/lam0 traced, so
    every (shape, capacity) pair compiles exactly once."""
    n, _ = prep.X.shape
    k_out = max(int(k_max), int(warm[0].shape[0]))
    name = resolve_inner_backend(config.inner_backend, config.loss,
                                 prep.n_true or n, k_out)
    idx, vals, mask, carry = grow_warm(warm, k_out, name)
    X = prep.X
    p_true = prep.p_true or X.shape[1]
    idx2, vals2, mask2, gidx2, _, _ = _seq_entry_jit(
        X, prep.y, prep.col_norm, idx, vals, mask, carry.gidx,
        jnp.asarray(lam0, X.dtype), jnp.asarray(lam, X.dtype),
        jnp.asarray(p_true, jnp.int32), loss_name=config.loss)
    return ((idx2, vals2, mask2,
             InnerCarry(G=carry.G, rho=carry.rho, gidx=gidx2)), k_out)


def _segments(n_lams: int, segment_len: int) -> List[slice]:
    return [slice(i, min(i + segment_len, n_lams))
            for i in range(0, n_lams, segment_len)]


def run_path(prep: PathState, lams: Sequence[float],
             config: SaifConfig = SaifConfig(),
             make_screen: Optional[Callable[[int], ScreenFn]] = None,
             segment_len: int = 16,
             warm0: Optional[WarmState] = None,
             k_max0: Optional[int] = None
             ) -> Tuple[SaifPathResult, WarmState, int]:
    """The path engine: solve a descending lambda grid from ``prep``, each
    solve warm-starting from the last.

    ``make_screen`` threads a custom screening backend through every solve:
    it is called once with the engine's grid-max candidate count h (which
    sizes the ScreenOut arrays and is only known here) and must return the
    ScreenFn, e.g. ``lambda h: make_sharded_screen(design, h)``. Otherwise
    ``config.screen_backend`` picks a built-in backend.

    ``warm0``/``k_max0`` are the session handoff: an entry warm state from
    a previous request (padded here if this grid needs more capacity) and
    the capacity it was built at. ``None`` means a cold entry — bitwise
    the legacy ``saif_path`` behavior. Returns ``(result, exit_warm,
    k_max)`` so the caller can keep the buffers device-resident.
    """
    X = prep.X
    n, p = X.shape
    # bucket-padded preparations: policy quantities on real dims, and the
    # traced pad mask rides every engine dispatch (DESIGN.md §12)
    n_true = prep.n_true or n
    p_true = prep.p_true or p
    pad_mask = (jnp.arange(p) >= p_true) if p_true < p else None
    unpen = config.unpen_idx
    unpen_static = -1 if unpen is None else unpen
    rule = resolve_screen_rule(config.screen_rule)
    # DESIGN.md §7 (fused) + §13 (rule geometry): the rule gates the
    # Theorem-2 ball exactly like the serial driver — warm lambda-path
    # steps are where the gap-safe/hybrid radii screen hardest (the entry
    # gap from the previous grid point is already tiny)
    use_seq = config.use_seq_ball and unpen is None and rule.use_seq_ball
    lams_np = np.asarray(sorted([float(l) for l in lams], reverse=True))
    backend = resolve_backend(config.screen_backend)
    n_compile0 = saif_jit_compile_count()

    # One static signature for the whole path: grid-max h (pow2-bucketed).
    # h sizes the candidate shapes, so it must be static; the violation
    # tolerance h~ only feeds comparisons, so it stays a per-lambda traced
    # scalar — the active set remains exactly as lean as per-lambda
    # compilation would keep it, at one compile for the whole grid.
    hs = [add_batch_size_static(config.c, lam, prep.c0_max, prep.c0_median,
                                p_true)
          for lam in lams_np]
    h = max(hs) if hs else 1
    k_max = config.k_max or default_capacity(h, p_true)
    if k_max0 is not None:
        k_max = max(k_max, k_max0)
    if warm0 is not None:
        k_max = max(k_max, int(warm0[0].shape[0]))
    # the backend's candidate arrays must be sized for the grid-max h
    screen_fn = make_screen(h) if make_screen is not None else None

    def inner_name(k: int) -> str:
        return resolve_inner_backend(config.inner_backend, config.loss,
                                     n_true, k)

    def run_lam(lam: float, h_lam: int, warm: WarmState) -> SaifResult:
        delta0 = config.delta0 if config.delta0 is not None else \
            min(max(lam / prep.lam_max, 1e-3), 1.0)
        warm_idx, warm_beta, warm_mask, carry = warm
        # per-lambda engine dispatch through the fault-injection seam
        # (repro.runtime.inject) — identity when disarmed
        return _fault_seam("path", lambda: _saif_jit(
            X, prep.y, prep.col_norm, prep.c0, jnp.asarray(lam, X.dtype),
            jnp.asarray(config.eps, X.dtype), delta0,
            warm_idx, warm_beta, warm_mask,
            carry.G, carry.rho, carry.gidx,
            jnp.asarray(max(int(np.ceil(config.zeta * h_lam)), 1),
                        jnp.int32),
            jnp.asarray(h_lam, jnp.int32),
            pad_mask,
            loss_name=config.loss, h=h, k_max=k_max,
            inner_epochs=config.inner_epochs,
            polish_factor=config.polish_factor,
            max_outer=config.max_outer, use_seq_ball=use_seq,
            screen_backend=backend, inner_backend=inner_name(k_max),
            unpen_idx=unpen_static, screen_fn=screen_fn,
            screen_rule=rule))

    results: List[SaifResult] = [None] * len(lams_np)
    if warm0 is not None:
        warm = grow_warm(warm0, k_max, inner_name(k_max))
    else:
        warm = cold_start(prep, hs[0] if hs else 1, k_max, config)
    for seg in _segments(len(lams_np), segment_len):
        entry = warm
        while True:
            cur = entry
            seg_results = []
            for j, lam in zip(range(seg.start, seg.stop), lams_np[seg]):
                res = run_lam(float(lam), hs[j], cur)
                seg_results.append(res)
                cur = _warm_state(res.active_idx, res.active_mask,
                                  res.beta, res.inner,
                                  unpen_idx=unpen_static)
            # ONE host sync per segment: the batched overflow check
            flags = jnp.stack([r.overflowed for r in seg_results])
            if not bool(jnp.any(flags)) or k_max >= p_true:
                break
            k_max = min(2 * k_max, p_true)  # elastic growth, segment re-entry
            entry = grow_warm(entry, k_max, inner_name(k_max))
        results[seg] = seg_results
        warm = cur

    betas = [r.beta for r in results]
    n_compile1 = saif_jit_compile_count()
    n_comp = (max(n_compile1 - n_compile0, 0)
              if n_compile0 >= 0 and n_compile1 >= 0 else None)
    return (SaifPathResult(lams=lams_np, betas=betas, results=results,
                           n_compilations=n_comp),
            warm, k_max)


def saif_path(X, y, lams: Sequence[float],
              config: SaifConfig = SaifConfig(),
              make_screen: Optional[Callable[[int], ScreenFn]] = None,
              segment_len: int = 16) -> SaifPathResult:
    """DEPRECATED legacy frontend — one-shot session over :func:`run_path`.

    Use ``repro.open_session(Problem(X, y), config).solve(Path(lams))``;
    a held-open session keeps the preparation, the compilation and the
    warm buffers alive for the next request (DESIGN.md §9).
    """
    warn_deprecated("repro.core.saif_path",
                    "session.solve(Path(lams))")
    from repro.core.api import Path as PathRequest
    from repro.core.api import Problem, open_session

    sess = open_session(Problem(X=X, y=y, loss=config.loss), config,
                        make_screen=make_screen, segment_len=segment_len)
    return sess.solve(PathRequest(lams=tuple(float(l) for l in lams)))


def saif_path_naive(X, y, lams: Sequence[float],
                    config: SaifConfig = SaifConfig()) -> SaifPathResult:
    """Pre-engine Python-loop driver: one full host round-trip per lambda.

    Kept verbatim as the benchmark baseline (BENCH_path.json tracks the
    engine's speedup over this) and as a brute-force parity oracle.
    """
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    lams_np = np.asarray(sorted([float(l) for l in lams], reverse=True))
    betas, results = [], []
    warm_idx = warm_beta = None
    for lam in lams_np:
        res = saif(X, y, float(lam), config,
                   warm_idx=warm_idx, warm_beta=warm_beta)
        betas.append(res.beta)
        results.append(res)
        support = jnp.nonzero(jnp.abs(res.beta) > 0,
                              size=res.beta.shape[0], fill_value=0)[0]
        n_sup = int(jnp.sum(jnp.abs(res.beta) > 0))
        if n_sup > 0:
            warm_idx = support[:n_sup]
            warm_beta = res.beta[warm_idx]
        else:
            warm_idx = warm_beta = None
    return SaifPathResult(lams=lams_np, betas=betas, results=results)


def lambda_grid(lam_max: float, n: int, lo_frac: float = 1e-3) -> np.ndarray:
    """Log-evenly spaced descending grid in [lo_frac*lam_max, lam_max)."""
    return np.geomspace(lam_max * (1 - 1e-9), lam_max * lo_frac, n)
