"""Compile-first warm-started SAIF lambda-path engine (paper Sec 5.3).

The naive path driver (kept as :func:`saif_path_naive`, the benchmark
baseline) calls the single-lambda host driver per grid point, which costs
per lambda: an O(np) re-preprocessing of (c0, col_norm, lam_max), a host
sync for the overflow flag, a host round-trip to extract the warm-start
support, and — whenever the static (h, k_max) signature moves — a fresh
``_saif_jit`` compilation.

The engine here hoists all of that out of the lambda loop:

  * **prepare once** — ``PathState`` computes c0 / col_norm / lam_max and
    the c0 statistics feeding the h formula exactly once per path;
  * **one static signature** — the candidate-buffer size h is bucketed to
    the *grid maximum* (already a power of two) so every lambda shares a
    single ``_saif_jit`` compilation, while the per-lambda batch size
    (h_cap) and violation tolerance (h~) ride along as *traced* scalars —
    they only feed comparisons. The ADD decisions are therefore bitwise
    those of a per-lambda compile; only the compile count changes. Worst
    case over capacity growth this is O(log p) distinct compilations per
    path (assert via :func:`repro.core.saif.saif_jit_compile_count`);
  * **fixed-capacity warm buffers** — the (k_max,) warm-start index/value
    buffers are produced *on device* from the previous solution and
    *preserve the slot layout* of the previous solve, so the inter-lambda
    handoff never syncs to the host AND the inner-solver carry (the Gram
    buffers of the covariance-update backend, DESIGN.md §6) rides along
    verbatim — the next solve's init finds zero dirty slots and skips the
    O(n k^2) Gram rebuild;
  * **segment-batched overflow checks** — solutions are collected per path
    segment and the ``overflowed`` flags are reduced in one host sync per
    segment instead of one per lambda. On overflow the capacity doubles and
    the segment re-runs from its entry state (rare: capacity starts at the
    grid-max 8h).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.inner_backend import (InnerCarry, cold_inner_carry,
                                      resolve_inner_backend)
from repro.core.losses import get_loss
from repro.core.saif import (SaifConfig, SaifResult, _saif_jit,
                             add_batch_size_static, default_capacity, saif,
                             saif_jit_compile_count)
from repro.core.screen_backend import ScreenFn, resolve_backend


class PathState(NamedTuple):
    """One-time O(np) preprocessing shared by every lambda on the path."""
    X: jax.Array          # (n, p)
    y: jax.Array          # (n,)
    c0: jax.Array         # (p,) |X^T f'(null model)|
    col_norm: jax.Array   # (p,)
    lam_max: float
    c0_max: float         # host copies of the c0 statistics the h formula
    c0_median: float      # needs — synced exactly once per path
    b0: float = 0.0       # unpenalized-slot null fit (fused paths; §7)


class SaifPathResult(NamedTuple):
    lams: np.ndarray
    betas: List[jnp.ndarray]
    results: List[SaifResult]
    n_compilations: Optional[int] = None   # _saif_jit compiles this path added


def prepare_path(X, y, config: SaifConfig) -> PathState:
    from repro.core.duality import null_gradient

    loss = get_loss(config.loss)
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    _, c0, b0 = null_gradient(loss, X, y, config.unpen_idx)
    col_norm = jnp.linalg.norm(X, axis=0)
    c0_max, c0_median, b0 = jax.device_get(
        (jnp.max(c0), jnp.median(c0), b0))
    return PathState(X=X, y=y, c0=c0, col_norm=col_norm,
                     lam_max=float(c0_max), c0_max=float(c0_max),
                     c0_median=float(c0_median), b0=float(b0))


@partial(jax.jit, static_argnames=("unpen_idx",))
def _warm_state(active_idx: jax.Array, active_mask: jax.Array,
                beta_full: jax.Array, inner: InnerCarry,
                unpen_idx: int = -1):
    """Device-side warm-start extraction, *slot-preserving*.

    The next lambda is seeded with the previous solve's final slot layout
    (masked down to the nonzero support), so the Gram buffers in ``inner``
    — which are indexed by slot — remain valid verbatim: the next
    ``_saif_jit``'s init finds zero dirty slots and skips the O(n k^2)
    rebuild entirely (DESIGN.md §6). No host round-trip anywhere. The
    unpenalized slot (fused paths) stays resident even at b = 0 exactly.
    """
    vals = jnp.where(active_mask, jnp.take(beta_full, active_idx), 0.0)
    live = active_mask & (vals != 0)
    if unpen_idx >= 0:
        live = live | (active_mask & (active_idx == unpen_idx))
    return active_idx, jnp.where(live, vals, 0.0), live, inner


def _segments(n_lams: int, segment_len: int) -> List[slice]:
    return [slice(i, min(i + segment_len, n_lams))
            for i in range(0, n_lams, segment_len)]


def saif_path(X, y, lams: Sequence[float],
              config: SaifConfig = SaifConfig(),
              make_screen: Optional[Callable[[int], ScreenFn]] = None,
              segment_len: int = 16) -> SaifPathResult:
    """Solve a descending lambda path; each solve warm-starts from the last.

    ``make_screen`` threads a custom screening backend through every solve:
    it is called once with the engine's grid-max candidate count h (which
    sizes the ScreenOut arrays and is only known here) and must return the
    ScreenFn, e.g. ``lambda h: make_sharded_screen(design, h)``. Otherwise
    ``config.screen_backend`` picks a built-in backend.
    """
    prep = prepare_path(X, y, config)
    X, y, c0, col_norm = prep.X, prep.y, prep.c0, prep.col_norm
    n, p = X.shape
    unpen = config.unpen_idx
    unpen_static = -1 if unpen is None else unpen
    use_seq = config.use_seq_ball and unpen is None   # DESIGN.md §7
    lams_np = np.asarray(sorted([float(l) for l in lams], reverse=True))
    backend = resolve_backend(config.screen_backend)
    n_compile0 = saif_jit_compile_count()

    # One static signature for the whole path: grid-max h (pow2-bucketed).
    # h sizes the candidate shapes, so it must be static; the violation
    # tolerance h~ only feeds comparisons, so it stays a per-lambda traced
    # scalar — the active set remains exactly as lean as per-lambda
    # compilation would keep it, at one compile for the whole grid.
    hs = [add_batch_size_static(config.c, lam, prep.c0_max, prep.c0_median, p)
          for lam in lams_np]
    h = max(hs) if hs else 1
    k_max = config.k_max or default_capacity(h, p)
    # the backend's candidate arrays must be sized for the grid-max h
    screen_fn = make_screen(h) if make_screen is not None else None

    def inner_name(k: int) -> str:
        return resolve_inner_backend(config.inner_backend, config.loss, n, k)

    def run_lam(lam: float, h_lam: int, warm) -> SaifResult:
        delta0 = config.delta0 if config.delta0 is not None else \
            min(max(lam / prep.lam_max, 1e-3), 1.0)
        warm_idx, warm_beta, warm_mask, carry = warm
        return _saif_jit(
            X, y, col_norm, c0, jnp.asarray(lam, X.dtype),
            jnp.asarray(config.eps, X.dtype), delta0,
            warm_idx, warm_beta, warm_mask,
            carry.G, carry.rho, carry.gidx,
            jnp.asarray(max(int(np.ceil(config.zeta * h_lam)), 1),
                        jnp.int32),
            jnp.asarray(h_lam, jnp.int32),
            loss_name=config.loss, h=h, k_max=k_max,
            inner_epochs=config.inner_epochs,
            polish_factor=config.polish_factor,
            max_outer=config.max_outer, use_seq_ball=use_seq,
            screen_backend=backend, inner_backend=inner_name(k_max),
            unpen_idx=unpen_static, screen_fn=screen_fn)

    def cold_start(k: int):
        # seed with the FIRST lambda's own batch size (hs[0]), not the
        # grid-max h: the cold solve must match a standalone solve at
        # lams[0] exactly (initial_support is the shared constructor)
        from repro.core.saif import initial_support
        idx, beta, n_init = initial_support(c0, hs[0] if hs else 1, k, p,
                                            unpen, prep.b0, X.dtype)
        return (idx, beta, jnp.arange(k) < n_init,
                cold_inner_carry(k, X.dtype, backend=inner_name(k)))

    def grow(warm, k: int):
        idx, vals, mask, carry = warm
        pad = k - idx.shape[0]
        if inner_name(k) == "gram" and carry.G.shape[0] == idx.shape[0]:
            # pad the Gram buffers in place: padded slots are dead/-1, the
            # carried warmth survives the capacity doubling
            carry = InnerCarry(
                G=jnp.pad(carry.G, ((0, pad), (0, pad))),
                rho=jnp.pad(carry.rho, (0, pad)),
                gidx=jnp.pad(carry.gidx, (0, pad), constant_values=-1))
        else:   # crossover flipped the backend: rebuild a cold carry
            carry = cold_inner_carry(k, X.dtype, backend=inner_name(k))
        return (jnp.pad(idx, (0, pad)), jnp.pad(vals, (0, pad)),
                jnp.pad(mask, (0, pad)), carry)

    results: List[SaifResult] = [None] * len(lams_np)
    warm = cold_start(k_max)
    for seg in _segments(len(lams_np), segment_len):
        entry = warm
        while True:
            cur = entry
            seg_results = []
            for j, lam in zip(range(seg.start, seg.stop), lams_np[seg]):
                res = run_lam(float(lam), hs[j], cur)
                seg_results.append(res)
                cur = _warm_state(res.active_idx, res.active_mask,
                                  res.beta, res.inner,
                                  unpen_idx=unpen_static)
            # ONE host sync per segment: the batched overflow check
            flags = jnp.stack([r.overflowed for r in seg_results])
            if not bool(jnp.any(flags)) or k_max >= p:
                break
            k_max = min(2 * k_max, p)   # elastic growth, segment re-entry
            entry = grow(entry, k_max)
        results[seg] = seg_results
        warm = cur

    betas = [r.beta for r in results]
    n_compile1 = saif_jit_compile_count()
    n_comp = (max(n_compile1 - n_compile0, 0)
              if n_compile0 >= 0 and n_compile1 >= 0 else None)
    return SaifPathResult(lams=lams_np, betas=betas, results=results,
                          n_compilations=n_comp)


def saif_path_naive(X, y, lams: Sequence[float],
                    config: SaifConfig = SaifConfig()) -> SaifPathResult:
    """Pre-engine Python-loop driver: one full host round-trip per lambda.

    Kept verbatim as the benchmark baseline (BENCH_path.json tracks the
    engine's speedup over this) and as a brute-force parity oracle.
    """
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    lams_np = np.asarray(sorted([float(l) for l in lams], reverse=True))
    betas, results = [], []
    warm_idx = warm_beta = None
    for lam in lams_np:
        res = saif(X, y, float(lam), config,
                   warm_idx=warm_idx, warm_beta=warm_beta)
        betas.append(res.beta)
        results.append(res)
        support = jnp.nonzero(jnp.abs(res.beta) > 0,
                              size=res.beta.shape[0], fill_value=0)[0]
        n_sup = int(jnp.sum(jnp.abs(res.beta) > 0))
        if n_sup > 0:
            warm_idx = support[:n_sup]
            warm_beta = res.beta[warm_idx]
        else:
            warm_idx = warm_beta = None
    return SaifPathResult(lams=lams_np, betas=betas, results=results)


def lambda_grid(lam_max: float, n: int, lo_frac: float = 1e-3) -> np.ndarray:
    """Log-evenly spaced descending grid in [lo_frac*lam_max, lam_max)."""
    return np.geomspace(lam_max * (1 - 1e-9), lam_max * lo_frac, n)
