"""Cyclic coordinate minimization ("shooting", Fu 1998) — the inner solver.

This is the pure-JAX reference path; the Pallas VMEM-resident kernel in
``repro.kernels.cm`` implements the same epoch and is tested against
:func:`cm_epoch` as its oracle.

For least squares the coordinate step is the exact minimizer
    beta_j <- S(beta_j + x_j^T r / ||x_j||^2,  lam / ||x_j||^2),   r = y - z
For a general alpha-smooth loss we take the standard prox-Newton-majorized
coordinate step with per-coordinate Lipschitz L_j = alpha ||x_j||^2:
    beta_j <- S(beta_j - x_j^T f'(z) / L_j,  lam / L_j)
which for LS coincides with the exact step. The model vector z = Xa beta is
maintained incrementally (rank-1 updates), exactly as the paper's C shooting
implementation does.

:func:`gram_epochs` is the covariance-update variant of the same sweep
(least squares only): it maintains q = G beta on the active-block Gram
matrix instead of z, making every coordinate step O(k_max) instead of O(n)
— the engine behind the ``gram`` inner backend (DESIGN.md §6).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.losses import Loss


def soft_threshold(x: jax.Array, t: jax.Array) -> jax.Array:
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)


def _coordinate_step(loss: Loss, Xa: jax.Array, y: jax.Array,
                     mask: jax.Array, lam: jax.Array, col_sq: jax.Array,
                     pen: jax.Array | None,
                     j: jax.Array, beta: jax.Array, z: jax.Array,
                     sample_w: jax.Array | None = None
                     ) -> Tuple[jax.Array, jax.Array]:
    """One prox coordinate update of slot ``j`` (shared epoch body).

    ``pen`` (optional, (k,)) is the per-slot l1 weight: 0 on an unpenalized
    slot (the threshold vanishes and the step is the exact/prox-Newton
    unconstrained minimizer), 1 elsewhere.

    ``sample_w`` (optional, (n,)) is the per-SAMPLE weight of the weighted
    loss sum_i w_i f(z_i, y_i) — the K-fold CV row-mask trick (DESIGN.md
    §8): the gradient picks up the elementwise weight while z and the
    design column stay unweighted, so X is shared across a CV fleet. The
    caller must pass a matching weighted ``col_sq`` (sum_i w_i x_ij^2).
    """
    xj = Xa[:, j]
    lj = jnp.maximum(loss.smoothness * col_sq[j], 1e-30)
    g_vec = loss.grad(z, y)
    if sample_w is not None:
        g_vec = sample_w * g_vec
    g = jnp.dot(xj, g_vec)
    lam_j = lam if pen is None else lam * pen[j]
    bj_new = soft_threshold(beta[j] - g / lj, lam_j / lj)
    bj_new = jnp.where(mask[j], bj_new, 0.0)
    z = z + (bj_new - beta[j]) * xj
    return beta.at[j].set(bj_new), z


def cm_epoch(loss: Loss, Xa: jax.Array, y: jax.Array, beta: jax.Array,
             z: jax.Array, mask: jax.Array, lam: jax.Array,
             pen: jax.Array | None = None) -> Tuple[jax.Array, jax.Array]:
    """One full cyclic sweep over the (masked) coordinates.

    Args:
      Xa:   (n, k) active design block (padded columns are arbitrary).
      beta: (k,) current coefficients (padded entries must be 0).
      z:    (n,) current model vector Xa @ beta.
      mask: (k,) bool validity of each column.
      pen:  (k,) optional per-column l1 weight (0 = unpenalized).
    Returns updated (beta, z).
    """
    col_sq = jnp.sum(Xa * Xa, axis=0)  # (k,)
    k = beta.shape[0]

    def body(j, carry):
        return _coordinate_step(loss, Xa, y, mask, lam, col_sq, pen, j,
                                *carry)

    return jax.lax.fori_loop(0, k, body, (beta, z))


def cm_epoch_compact(loss: Loss, Xa: jax.Array, y: jax.Array,
                     beta: jax.Array, z: jax.Array, mask: jax.Array,
                     lam: jax.Array, order: jax.Array, count: jax.Array
                     ) -> Tuple[jax.Array, jax.Array]:
    """One compact sweep: sweeps only the ``count`` live slots listed first
    in ``order`` (an argsort putting mask=True slots first). With a
    capacity buffer k_max ~ 8x the live size this is ~8x fewer coordinate
    steps per epoch (§Perf iteration 3)."""
    return cm_epochs_compact(loss, Xa, y, beta, z, mask, lam, order, count,
                             1)


def cm_epochs_compact(loss: Loss, Xa: jax.Array, y: jax.Array,
                      beta: jax.Array, z: jax.Array, mask: jax.Array,
                      lam: jax.Array, order: jax.Array, count: jax.Array,
                      n_epochs: jax.Array,
                      pen: jax.Array | None = None,
                      sample_w: jax.Array | None = None
                      ) -> Tuple[jax.Array, jax.Array]:
    """``n_epochs`` compact sweeps (n_epochs may be traced — the solver
    batches a longer polish burst through the same compiled epoch).
    ``sample_w`` weights the loss per sample (CV fleets, DESIGN.md §8)."""
    if sample_w is None:
        col_sq = jnp.sum(Xa * Xa, axis=0)   # hoisted out of the epoch loop
    else:
        col_sq = jnp.sum(sample_w[:, None] * Xa * Xa, axis=0)

    def step(jj, carry):
        return _coordinate_step(loss, Xa, y, mask, lam, col_sq, pen,
                                order[jj], *carry, sample_w=sample_w)

    def epoch(_, carry):
        return jax.lax.fori_loop(0, count, step, carry)

    return jax.lax.fori_loop(0, n_epochs, epoch, (beta, z))


def gram_epochs(G: jax.Array, rho: jax.Array, beta: jax.Array,
                mask: jax.Array, lam: jax.Array, order: jax.Array,
                count: jax.Array, n_epochs: jax.Array,
                smoothness: float = 1.0,
                pen: jax.Array | None = None) -> jax.Array:
    """Covariance-update CM sweeps: every coordinate step is O(k_max), not O(n).

    Least-squares only (the gradient must be linear in z for the Gram trick):
        x_j^T f'(z) = x_j^T (Xa beta - y) = (G beta)_j - rho_j
    so maintaining ``qr = G beta - rho`` turns the O(n) correlation dot of
    :func:`_coordinate_step` into a scalar read, and the O(n) rank-1 model
    update into an O(k_max) Gram-column axpy — glmnet's "covariance updates",
    on the fixed-capacity active block. ``G`` must satisfy
    G[s, t] = x_s^T x_t for every pair of *live* slots (stale entries on dead
    rows/columns are never read: the sweep is compact and dead betas are 0).

    Args:
      G:     (k_max, k_max) active-block Gram matrix (see invariant above).
      rho:   (k_max,) x_j^T y per slot.
      beta:  (k_max,) coefficients (0 on dead slots).
      order: (k_max,) slot permutation, the ``count`` live slots first.
      n_epochs: traced sweep count.
      pen:   (k_max,) optional per-slot l1 weight (0 = unpenalized slot).
    Returns the updated beta. (The model vector z = Xa beta is intentionally
    NOT maintained here — the caller reconstitutes it once per burst.)
    """
    diag = jnp.diagonal(G)
    inv_l = 1.0 / jnp.maximum(smoothness * diag, 1e-30)
    thr = lam * inv_l if pen is None else lam * pen * inv_l
    qr = G @ beta - rho                     # q - rho; garbage on dead slots

    def step(jj, carry):
        beta, qr = carry
        j = order[jj]
        bj = beta[j]
        b_new = soft_threshold(bj - qr[j] * inv_l[j], thr[j])
        b_new = jnp.where(mask[j], b_new, 0.0)
        qr = qr + (b_new - bj) * G[:, j]
        return beta.at[j].set(b_new), qr

    def epoch(_, carry):
        return jax.lax.fori_loop(0, count, step, carry)

    beta, _ = jax.lax.fori_loop(0, n_epochs, epoch, (beta, qr))
    return beta


def cm_epochs(loss: Loss, Xa: jax.Array, y: jax.Array, beta: jax.Array,
              mask: jax.Array, lam: jax.Array, n_epochs: int
              ) -> Tuple[jax.Array, jax.Array]:
    """Run ``n_epochs`` cyclic sweeps; returns (beta, z)."""
    z = Xa @ jnp.where(mask, beta, 0.0)

    def body(_, carry):
        beta, z = carry
        return cm_epoch(loss, Xa, y, beta, z, mask, lam)

    beta, z = jax.lax.fori_loop(0, n_epochs, body, (beta, z))
    return beta, z


def solve_lasso_cm(loss: Loss, X: jax.Array, y: jax.Array, lam: float,
                   tol: float = 1e-9, max_epochs: int = 100_000,
                   unpen_idx: int | None = None) -> jax.Array:
    """Unscreened full LASSO solve to duality gap <= tol (the "No Scr." baseline).

    Used both as the paper's no-screening baseline and as the ground-truth
    oracle in tests (safety checks compare active sets against this solve).
    ``unpen_idx`` exempts one coordinate from the l1 penalty (fused LASSO's
    ``b`` slot, Thm 7): its coordinate step is unthresholded and the dual
    point is projected onto its equality constraint before scaling.
    """
    from repro.core.duality import duality_gap, feasible_dual

    p = X.shape[1]
    mask = jnp.ones((p,), dtype=bool)
    lam = jnp.asarray(lam, X.dtype)
    pen = x_unpen = None
    if unpen_idx is not None:
        pen = jnp.ones((p,), X.dtype).at[unpen_idx].set(0.0)
        x_unpen = X[:, unpen_idx]

    def cond(state):
        beta, z, gap, epoch = state
        return (gap > tol) & (epoch < max_epochs)

    def body(state):
        beta, z, _, epoch = state
        beta, z = cm_epoch(loss, X, y, beta, z, mask, lam, pen=pen)
        if unpen_idx is not None and loss.name != "least_squares":
            # keep the dual point's equality constraint satisfied through
            # the gradient itself (duality.polish_unpen, DESIGN.md §7)
            from repro.core.duality import polish_unpen
            b_new, z = polish_unpen(loss, x_unpen, y, z, beta[unpen_idx])
            beta = beta.at[unpen_idx].set(b_new)
        hat = -loss.grad(z, y) / lam
        theta = feasible_dual(loss, X, y, hat, lam, pen=pen,
                              x_unpen=x_unpen)
        gap = duality_gap(loss, X, y, beta, theta, lam, pen=pen)
        return beta, z, gap, epoch + 1

    beta0 = jnp.zeros((p,), X.dtype)
    z0 = jnp.zeros_like(y)
    state = (beta0, z0, jnp.asarray(jnp.inf, X.dtype), jnp.asarray(0))
    beta, *_ = jax.lax.while_loop(cond, body, state)
    return beta
