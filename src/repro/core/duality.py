"""Dual-variable machinery: feasibility projection, duality gap, ball regions.

Implements, in order of appearance in the paper:
  * the primal->dual map and scaled feasibility projection (Lemma 2's theta_k)
  * the gap-safe ball   B(theta, r),  r^2 = 2*alpha*gap/lam^2        (Eq. 6/11)
  * the sequential-style ball from lambda_max(t)                     (Thm 2)
  * the covering ball of the intersection of two balls               (Eq. 12)

All functions operate on a *sub-problem* defined by an explicit design matrix
``Xa`` (n x k, the gathered active columns) so the same code serves SAIF
sub-problems, dynamic screening (Xa = X), and fused LASSO (transformed X).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.losses import Loss


class Ball(NamedTuple):
    center: jax.Array  # (n,)
    radius: jax.Array  # scalar


def dual_point(loss: Loss, Xa: jax.Array, y: jax.Array, beta: jax.Array,
               lam: jax.Array) -> jax.Array:
    """hat_theta = -f'(Xa beta) / lam  (the unscaled dual candidate)."""
    z = Xa @ beta
    return -loss.grad(z, y) / lam


def feasible_dual(loss: Loss, X_for_constraints: jax.Array, y: jax.Array,
                  hat_theta: jax.Array, lam: jax.Array,
                  mask: jax.Array | None = None,
                  pen: jax.Array | None = None,
                  x_unpen: jax.Array | None = None) -> jax.Array:
    """Scale hat_theta into Omega = {theta : |x_i^T theta| <= 1 for i in set}.

    Lemma 2: theta = tau * hat_theta with tau = 1 / max_i |x_i^T hat_theta|
    (only when that max exceeds 1 — otherwise already feasible). For least
    squares we additionally use the DPP-style optimal scaling
    tau* = y^T hat_theta / (lam ||hat_theta||^2) clipped into the feasible
    range, which is the projection of theta* direction (paper Thm 7 logic).

    ``mask`` marks valid columns of ``X_for_constraints`` (padded actives).

    Unpenalized coordinate (fused LASSO's ``b``, Thm 7): its dual constraint
    is the *equality* ``x_b^T theta = 0``. Pass its column as ``x_unpen`` and
    a per-column weight vector ``pen`` (0 on the unpenalized column, 1
    elsewhere): ``hat_theta`` is first projected onto the hyperplane, the
    |corr|-scaling then only sees penalized columns, and (scaling through 0)
    the equality survives the rescale. For general losses the final
    dom-f* clamp can leave an O(clip) residual on the equality — same
    approximation grade as the existing general-loss rescale (DESIGN.md §7).
    """
    if x_unpen is not None:
        sq_b = jnp.sum(x_unpen * x_unpen)
        hat_theta = hat_theta - x_unpen * (
            jnp.dot(x_unpen, hat_theta) / jnp.maximum(sq_b, 1e-30))
    corr = X_for_constraints.T @ hat_theta  # (k,)
    if mask is not None:
        corr = jnp.where(mask, corr, 0.0)
    if pen is not None:
        corr = corr * pen
    max_corr = jnp.max(jnp.abs(corr))
    denom = jnp.maximum(max_corr, 1.0)
    bound = 1.0 / jnp.maximum(max_corr, 1e-30)

    if loss.name == "least_squares":
        sq = jnp.sum(hat_theta * hat_theta)
        tau_star = jnp.dot(y, hat_theta) / (lam * jnp.maximum(sq, 1e-30))
        tau = jnp.clip(tau_star, -bound, bound)
        # Fall back to simple scaling if tau* degenerate (e.g. hat_theta ~ 0).
        tau = jnp.where(jnp.isfinite(tau), tau, 1.0 / denom)
        return tau * hat_theta
    # General smooth loss: plain rescale, then clamp into dom f*.
    theta = hat_theta / denom
    return -loss.dual_clip(-lam * theta, y) / lam


def duality_gap(loss: Loss, Xa: jax.Array, y: jax.Array, beta: jax.Array,
                theta: jax.Array, lam: jax.Array,
                mask: jax.Array | None = None,
                pen: jax.Array | None = None) -> jax.Array:
    """P_t(beta) - D_t(theta) for the sub-problem restricted to ``Xa``.

    ``pen`` (optional, (k,)) weights the l1 term per column — 0 on an
    unpenalized coordinate (fused LASSO's ``b``), 1 elsewhere.
    """
    if mask is not None:
        beta = jnp.where(mask, beta, 0.0)
    p_val = loss.primal_objective(Xa, y, beta, lam, weights=pen)
    d_val = loss.dual_objective(y, theta, lam)
    return p_val - d_val


def gap_ball(loss: Loss, theta: jax.Array, gap: jax.Array,
             lam: jax.Array, floor: jax.Array | float = 0.0) -> Ball:
    """Gap-safe ball (Eq. 6 generalized): r^2 = 2*alpha*gap / lam^2.

    f is alpha-smooth => f* is (1/alpha)-strongly convex => the dual objective
    is (lam^2/alpha)-strongly concave, giving the radius below. For least
    squares alpha=1 recovers Eq. (6) exactly.

    ``floor`` (optional) lower-bounds the gap before the radius is derived.
    The computed gap is a *difference* P - D of two near-equal objective
    values, so it is only accurate to ~eps_machine * |D|; once the
    sub-problem is solved to machine precision the raw gap underflows to 0
    (or goes negative) and the radius collapses to exactly 0 — at which
    point the strict <1 DEL rule and the <1 ADD-stop operate with zero
    margin and evict/ignore boundary features (|x^T theta*| = 1) on
    floating-point noise. Passing the gap's own arithmetic-precision scale
    (see :func:`gap_precision_floor`) restores the honest uncertainty
    radius. Default 0.0 preserves the textbook formula.
    """
    gap = jnp.maximum(gap, floor)
    r = jnp.sqrt(2.0 * loss.smoothness * gap) / lam
    return Ball(center=theta, radius=r)


def gap_precision_floor(theta: jax.Array, lam: jax.Array) -> jax.Array:
    """Arithmetic-precision scale of a duality-gap estimate at ``theta``.

    P - D cancels against objective values of magnitude ~|D(theta)|; the
    0.5 lam^2 ||theta||^2 term bounds that magnitude for least squares (and
    its order for the bounded-conjugate losses), so the gap cannot be
    trusted below ~eps_dtype times it. The factor 8 covers the O(n)-term
    accumulation of the two objective sums. Discovered root cause of the
    near-lambda_max support misses on gaussian designs (ROADMAP open item;
    the Thm-2 ball and the h formula were innocent): with the raw gap
    flooring at exactly 0, a truly-active boundary feature sits at
    |x^T theta| = 1 - O(eps) and the full-radius DEL rule deletes it.
    """
    eps_m = jnp.finfo(theta.dtype).eps
    scale = jnp.maximum(
        0.5 * lam * lam * jnp.sum(theta * theta, axis=-1), 1.0)
    return 8.0 * eps_m * scale


def sequential_ball(loss: Loss, y: jax.Array, theta0: jax.Array,
                    lam0: jax.Array, lam: jax.Array) -> Ball:
    """Theorem 2 ball around (lam0/lam) * theta0, for lam < lam0.

    r^2 = (2 alpha / lam^2) [ f*(-(lam^2/lam0) theta0) - f*(-lam0 theta0)
                              + (lam - lam0) <f*'(-lam0 theta0), theta0> ].

    For least squares with theta0 = theta*(lam_max) = -f'(0)/lam_max = y/lam_max
    this reproduces the DPP-style initial ball.
    """
    alpha = loss.smoothness
    u0 = -lam0 * theta0
    # f*'(u) for least squares is u + y; for logistic we use autodiff-free form.
    if loss.name == "least_squares":
        fstar_grad = u0 + y
    else:
        fstar_grad = jax.grad(lambda u: jnp.sum(loss.conj(u, y)))(u0)
    term = (jnp.sum(loss.conj(-(lam * lam / lam0) * theta0, y))
            - jnp.sum(loss.conj(u0, y))
            + (lam - lam0) * jnp.dot(fstar_grad, theta0))
    r2 = jnp.maximum(2.0 * alpha / (lam * lam) * term, 0.0)
    return Ball(center=(lam0 / lam) * theta0, radius=jnp.sqrt(r2))


def intersect_balls(b1: Ball, b2: Ball) -> Ball:
    """Smallest ball covering B1 ∩ B2 (paper Eq. 12), robustly.

    Degenerate cases (disjoint, containment, identical centers) fall back to
    the smaller input ball, which is always a valid (if looser) cover given
    both balls are valid containers of theta*.
    """
    d = jnp.linalg.norm(b1.center - b2.center)
    r1, r2 = b1.radius, b2.radius
    safe_d = jnp.maximum(d, 1e-30)
    # Signed distance from b1.center to the radical plane. The paper's Eq. 12
    # writes d1 = sqrt(r1^2 - rt^2), which drops the sign — when one center
    # lies beyond the chord plane that formula places the cover on the wrong
    # side and the "cover" no longer contains the lens (observed as unsafe
    # DELs). We use the signed radical-plane form instead.
    d1 = (d * d + r1 * r1 - r2 * r2) / (2.0 * safe_d)
    rt = jnp.sqrt(jnp.maximum(r1 * r1 - d1 * d1, 0.0))  # half-chord radius
    center_t = (1.0 - d1 / safe_d) * b1.center + (d1 / safe_d) * b2.center

    # Ball(center_t, rt) covers B1 ∩ B2 iff the spheres genuinely intersect
    # AND the radical center lies between the two centers (0 <= d1 <= d);
    # otherwise one lens cap bulges past the chord disk. Require improvement
    # too, else fall back to the smaller input ball (always a valid cover).
    intersects = (d <= r1 + r2) & (d >= jnp.abs(r1 - r2))
    between = (d1 >= 0.0) & (d1 <= d)
    use_lens = intersects & between & (rt < jnp.minimum(r1, r2))

    small_is_1 = r1 <= r2
    fallback_c = jnp.where(small_is_1, b1.center, b2.center)
    fallback_r = jnp.minimum(r1, r2)
    center = jnp.where(use_lens, center_t, fallback_c)
    radius = jnp.where(use_lens, rt, fallback_r)
    return Ball(center=center, radius=radius)


def kkt_residual(loss: Loss, X: jax.Array, y: jax.Array, beta: jax.Array,
                 lam: jax.Array, pen: jax.Array | None = None,
                 sample_w: jax.Array | None = None,
                 active_tol: float = 0.0) -> jax.Array:
    """Post-hoc KKT residual of a candidate LASSO solution (0 at the
    exact optimum) — the serving runtime's machine-checkable certificate
    (DESIGN.md §10).

    With ``g = X^T f'(X beta)``, the stationarity conditions of Eq. 1 are

      * ``|g_i| <= lam``                for ``beta_i = 0``,
      * ``g_i = -lam * sign(beta_i)``   for ``beta_i != 0``,
      * ``g_i = 0``                     for an unpenalized coordinate
        (``pen_i = 0``, the fused slot).

    Returns the max violation over all p coordinates — El Ghaoui's SAFE
    framework's observation that the post-solve check is one O(np)
    matvec, independent of how the support was produced (screened solve,
    degraded rung, oracle), is exactly why the degradation ladder can be
    *certificate-driven* rather than trust-based. ``pen`` weights the l1
    term per column (0 = unpenalized); ``sample_w`` carries per-sample
    weights (the weighted-fleet gradient); ``active_tol`` is the
    magnitude below which a coefficient is treated as zero.
    """
    g = loss.grad(X @ beta, y)
    if sample_w is not None:
        g = g * sample_w
    c = X.T @ g
    lam_i = lam * (pen if pen is not None else 1.0)
    active = jnp.abs(beta) > active_tol
    inactive_viol = jnp.maximum(jnp.abs(c) - lam_i, 0.0)
    active_viol = jnp.abs(c + lam_i * jnp.sign(beta))
    return jnp.max(jnp.where(active, active_viol, inactive_viol))


# ---------------------------------------------------------------------------
# certified mixed-precision screening: rigorous rounding-error bounds
# (ISSUE 7 / DESIGN.md §11). A gap-safe ball whose radius is widened by a
# bound on the float error of the screening correlations is still safe —
# low precision can then only screen *conservatively*, never unsafely.
# ---------------------------------------------------------------------------

def unit_roundoff(dtype) -> float:
    """u = eps/2 for the dtype: |fl(x op y) - (x op y)| <= u |x op y|."""
    return float(jnp.finfo(jnp.dtype(dtype)).eps) / 2.0


def dot_error_gamma(n: int, u: float) -> float:
    """Classical gamma_n = n*u / (1 - n*u)  (Higham, ASNA Lemma 3.1).

    A length-``n`` inner product evaluated in precision with unit
    roundoff ``u`` — in ANY summation order, including pairwise/blocked
    re-association — satisfies |fl(x.y) - x.y| <= gamma_n * |x|.|y|
    <= gamma_n * ||x||_2 ||y||_2. (Sequential summation needs only
    gamma_n; tree orders need gamma_{ceil(log2 n)+1} <= gamma_n, so the
    bound is order-oblivious — exactly what a re-associating batched
    contraction requires.) Returns +inf when n*u >= 1 (bound vacuous).
    """
    nu = float(n) * u
    if nu >= 1.0:
        return float("inf")
    return nu / (1.0 - nu)


def mixed_precision_gamma(n: int, in_dtype, acc_dtype) -> float:
    """Forward-error factor of a dot with inputs *cast* to ``in_dtype``
    and accumulated in ``acc_dtype``.

    Casting x_i -> fl_in(x_i) = x_i(1+d_i), |d_i| <= u_in, on both
    operands multiplies each product by at most (1+u_in)^2; the
    accumulation then contributes (1 + gamma_n(u_acc)). Composed:

        |fl(x.y) - x.y| <= gamma_total * ||x||_2 ||y||_2,
        gamma_total = (1+u_in)^2 (1 + gamma_n(u_acc)) - 1.

    This is the bound for an MXU/gemm-style bf16-input f32-accumulator
    screen pass (and, with in_dtype == acc_dtype, for a plain
    re-associated working-precision contraction). Monotone increasing
    in ``n`` and in both unit roundoffs.
    """
    u_in = unit_roundoff(in_dtype)
    u_acc = unit_roundoff(acc_dtype)
    return (1.0 + u_in) ** 2 * (1.0 + dot_error_gamma(n, u_acc)) - 1.0


def widened_radius(r: jax.Array, theta: jax.Array,
                   gamma: float) -> jax.Array:
    """Safe-ball radius widened to absorb screening-dot rounding error.

    With unit columns (||x_i|| <= 1) the error of each low-precision
    correlation fl(x_i . theta) is <= gamma * ||theta||_2 by
    Cauchy-Schwarz, so the exact screening rule evaluated on the
    low-precision score is implied by the same rule with radius

        r' = r + gamma * ||theta||_2.

    Column norms > 1 are covered because every screening rule already
    multiplies the radius by the column norm (ub = score + cn_i * r).
    The *computed* ||theta||_2 is itself inexact; it is inflated by
    1 + 2*gamma_{n+2}(u_work) so r' upper-bounds the true widening.
    ``theta`` is the ball center, shape (..., n); r broadcasts.
    """
    n = theta.shape[-1]
    u_w = unit_roundoff(theta.dtype)
    slack = 1.0 + 2.0 * dot_error_gamma(n + 2, u_w)
    norm = jnp.sqrt(jnp.sum(theta * theta, axis=-1))
    return r + gamma * slack * norm


def lambda_max(loss: Loss, X: jax.Array, y: jax.Array) -> jax.Array:
    """Smallest lam with beta* = 0:  max_i |x_i^T f'(0)|   (paper Sec 2.2)."""
    g0 = loss.grad(jnp.zeros_like(y), y)
    return jnp.max(jnp.abs(X.T @ g0))


def polish_unpen(loss: Loss, x: jax.Array, y: jax.Array, z: jax.Array,
                 b: jax.Array, iters: int = 4):
    """Newton-polish the unpenalized coordinate to stationarity.

    ``iters`` exact 1-D Newton steps on ``b`` along column ``x`` from the
    point ``z`` (the full model vector, which already includes ``x b``).
    Returns the updated ``(b, z)`` with ``x^T f'(z) ~ 0``.

    Why this exists (DESIGN.md §7): the CM burst's prox step on ``b`` uses
    the *majorized* curvature ``alpha ||x||^2``, so ``x^T f'(z)`` is small
    but not ~0 after a burst. For general losses the dual point must
    satisfy the equality constraint ``x^T theta = 0`` WITHOUT a geometric
    projection — projecting ``-f'(z)/lam`` can flip the sign structure
    (for logistic: theta_j y_j > 0) and the subsequent dom-f* clamp then
    moves theta far enough that D(theta) is no longer a lower bound
    (observed as *negative* duality gaps => bogus instant convergence).
    Driving ``b`` to stationarity makes the gradient itself satisfy the
    equality, so the projection inside :func:`feasible_dual` is a benign
    ~0 correction and the clamp stays epsilon-grade. The Hessian is
    floored and the step clipped so separable logistic data cannot send
    the iterate to infinity.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30)
    lim = 1e3 / scale

    def step(_, carry):
        b, z = carry
        g = jnp.dot(x, loss.grad(z, y))
        H = jnp.dot(x * x, loss.hess(z, y))
        d = jnp.clip(g / jnp.maximum(H, 1e-30), -lim, lim)
        return b - d, z - d * x

    return jax.lax.fori_loop(0, iters, step, (b, z))


def fit_unpenalized(loss: Loss, x: jax.Array, y: jax.Array,
                    iters: int = 30) -> jax.Array:
    """1-D Newton for ``min_b sum_j f(x_j b, y_j)`` (the unpenalized slot).

    The penalized-null model of a problem with one unpenalized coordinate
    ``b`` (fused LASSO, Thm 7) is beta_tilde = 0 with b at its partial
    optimum — NOT beta = 0.
    """
    b0 = jnp.asarray(0.0, x.dtype)
    b, _ = polish_unpen(loss, x, y, jnp.zeros_like(y), b0, iters=iters)
    return b


def null_gradient(loss: Loss, X: jax.Array, y: jax.Array,
                  unpen_idx: int | None = None):
    """(g0, c0, b0) of the penalized-null model.

    Plain LASSO (unpen_idx None): g0 = f'(0), c0 = |X^T g0|, b0 = 0 — the
    quantities every SAIF driver derives lambda_max / h / the initial
    active set from. With an unpenalized coordinate the null model is the
    partial optimum over that coordinate alone: g0 = f'(x_b b0), and
    c0[unpen] is forced to 0 (the slot is always resident, never a
    screening candidate, and must not distort lambda_max).
    """
    if unpen_idx is None:
        g0 = loss.grad(jnp.zeros_like(y), y)
        return g0, jnp.abs(X.T @ g0), jnp.asarray(0.0, X.dtype)
    xb = X[:, unpen_idx]
    b0 = fit_unpenalized(loss, xb, y)
    g0 = loss.grad(xb * b0, y)
    c0 = jnp.abs(X.T @ g0).at[unpen_idx].set(0.0)
    return g0, c0, b0
