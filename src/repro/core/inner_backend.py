"""Pluggable inner-solver backends for the SAIF CM burst (DESIGN.md §6).

A SAIF outer step needs exactly four things from the inner solver, computed
on the fixed-capacity active block:

  * ``beta``  — the coefficients after the K-sweep CM burst,
  * ``z``     — the model vector Xa beta,
  * ``theta`` — the feasible dual point (Lemma 2 scaling),
  * ``gap``   — the sub-problem duality gap (drives the ball radius, the
                DEL rule and the stop test).

An :class:`InnerBackend` produces all four as one :class:`InnerOut`; the
jitted solver in :mod:`repro.core.saif` is backend-agnostic, mirroring the
PR-1 :mod:`repro.core.screen_backend` design. Three implementations ship:

  * ``jnp``    — the reference path: residual-update coordinate steps
                 (``core/cm.py::cm_epochs_compact``), each step an O(n) dot
                 plus an O(n) rank-1 model update.
  * ``gram``   — the covariance-update engine (least squares only): the
                 active-block Gram matrix ``G = Xa^T Xa`` and ``rho = Xa^T y``
                 live in an :class:`InnerCarry` threaded through the outer
                 while_loop, so each coordinate step is an O(k_max) Gram
                 axpy (``core/cm.py::gram_epochs``) — *no O(n) work per
                 coordinate step*. ADD/DEL trigger an incremental column
                 refresh (at most ``h`` new columns per outer step, O(n k h)
                 amortized; never a full O(n k^2) rebuild inside the loop).
  * ``pallas`` — the VMEM-resident fused kernel
                 (``kernels/cm/cm.py::cm_burst_pallas``): prox-Newton steps
                 for any alpha-smooth loss with the dual-point/duality-gap
                 reduction fused into the same kernel call.

Gram refresh invariants (the correctness contract of the ``gram`` carry):

  1. ``gidx[s]`` names the feature whose data currently backs row/column
     ``s`` of ``G`` and entry ``s`` of ``rho`` (-1 = nothing valid).
  2. For every pair of slots (s, t) with ``gidx == idx`` and ``mask`` live,
     ``G[s, t] = x_s^T x_t`` holds exactly. Dead rows/columns may be stale —
     the compact sweep never reads them and dead betas are 0.
  3. ``refresh`` (called at the top of every outer step) first invalidates
     ``gidx`` on dead slots, then recomputes rows+columns of every live slot
     whose ``gidx`` disagrees with ``idx``. Invalidation-on-death is what
     makes (2) inductive: a slot revived after >= 1 outer step always
     refreshes, so entries that went stale while it was dead (ADDs refresh
     against the mask-zeroed block) are never trusted.
  4. At most ``h`` slots can become live per outer step (the candidate
     buffer is (h,)-shaped), so the in-loop refresh is bounded by ``h``
     columns; unbounded reconciliation (cold starts, warm handoffs whose
     carry disagrees) happens once, outside the while_loop, in ``init``.

Backend-selection policy lives in :func:`resolve_inner_backend`; the
n-vs-k_max crossover and the VMEM gate are documented in DESIGN.md §6.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import active_set as aset_lib
from repro.core.active_set import ActiveSet
from repro.core.cm import cm_epochs_compact, gram_epochs
from repro.core.duality import duality_gap, feasible_dual, polish_unpen
from repro.core.losses import Loss


class InnerCarry(NamedTuple):
    """Inner-solver state threaded through the outer while_loop (and, for
    warm-started lambda paths, across solves). Placeholder-shaped ((1, 1) /
    (1,)) for backends that keep no state."""
    G: jax.Array      # (k_max, k_max) active-block Gram matrix
    rho: jax.Array    # (k_max,) x_j^T y per slot
    gidx: jax.Array   # (k_max,) int32 feature id backing each slot (-1=none)


class InnerOut(NamedTuple):
    beta: jax.Array   # (k_max,) post-burst coefficients
    z: jax.Array      # (n,) model vector Xa beta
    theta: jax.Array  # (n,) feasible dual point
    gap: jax.Array    # scalar sub-problem duality gap


class InnerBackend(NamedTuple):
    """The inner-solver interface ``_saif_jit`` consumes.

    ``init(aset, carry, Xa)``    — outside the while_loop: reconcile an
                                   inbound (possibly cold / stale) carry
                                   with the initial active set.
    ``refresh(carry, aset, Xa)`` — inside the loop, bounded work: absorb
                                   the previous step's ADD/DEL.
    ``run(carry, aset, Xa, lam, n_ep)`` — the CM burst + dual/gap.
    """
    name: str
    init: Callable[[ActiveSet, InnerCarry, jax.Array], InnerCarry]
    refresh: Callable[[InnerCarry, ActiveSet, jax.Array], InnerCarry]
    run: Callable[[InnerCarry, ActiveSet, jax.Array, jax.Array, jax.Array],
                  InnerOut]


def empty_inner_carry(dtype=jnp.float32) -> InnerCarry:
    """Placeholder carry for stateless backends (jnp / pallas)."""
    return InnerCarry(G=jnp.zeros((1, 1), dtype), rho=jnp.zeros((1,), dtype),
                      gidx=jnp.full((1,), -1, jnp.int32))


def cold_inner_carry(k_max: int, dtype=jnp.float32,
                     backend: str = "gram") -> InnerCarry:
    """All-invalid carry: forces a full (out-of-loop) rebuild in ``init``."""
    if backend != "gram":
        return empty_inner_carry(dtype)
    return InnerCarry(G=jnp.zeros((k_max, k_max), dtype),
                      rho=jnp.zeros((k_max,), dtype),
                      gidx=jnp.full((k_max,), -1, jnp.int32))


def _dual_and_gap(loss: Loss, Xa, y, beta, z, mask, lam,
                  pen=None, x_unpen=None, sample_w=None):
    """Shared post-burst tail of the jnp and gram backends — byte-for-byte
    the dual/gap computation the pre-backend solver did inline. ``pen`` /
    ``x_unpen`` carry the unpenalized-slot machinery (DESIGN.md §7): the
    dual point is projected onto x_unpen's equality constraint and the l1
    term of the gap skips the unpenalized coordinate.

    ``sample_w`` (optional, (n,)) is a per-sample loss weight (the K-fold
    CV row-mask trick, DESIGN.md §8): the gradient, primal value and
    conjugate sums pick up the elementwise weight. With binary weights the
    unscaled dual candidate is supported on the weight-1 rows by
    construction, so the LS tau* scaling and the constraint correlations
    against the *shared* Xa equal their row-subsampled counterparts
    exactly; the general-loss dom-f* clamp can move an exact 0 off 0, so
    theta is re-zeroed on the weight-0 rows after it."""
    if sample_w is None:
        hat = -loss.grad(z, y) / lam
        theta = feasible_dual(loss, Xa, y, hat, lam, mask, pen=pen,
                              x_unpen=x_unpen)
        gap = duality_gap(loss, Xa, y, beta, theta, lam, mask, pen=pen)
        return theta, gap
    hat = -(sample_w * loss.grad(z, y)) / lam
    theta = feasible_dual(loss, Xa, y, hat, lam, mask, pen=pen,
                          x_unpen=x_unpen)
    if loss.name != "least_squares":
        theta = jnp.where(sample_w > 0, theta, 0.0)
    beta_m = jnp.where(mask, beta, 0.0) if mask is not None else beta
    l1 = jnp.abs(beta_m) if pen is None else pen * jnp.abs(beta_m)
    p_val = (jnp.sum(sample_w * loss.value(Xa @ beta_m, y)) +
             lam * jnp.sum(l1))
    d_val = -jnp.sum(sample_w * loss.conj(-lam * theta, y))
    return theta, p_val - d_val


def make_inner_jnp(loss: Loss, X: jax.Array, y: jax.Array,
                   unpen_idx: int = -1,
                   sample_w: jax.Array | None = None) -> InnerBackend:
    """Reference backend: residual-update epochs, O(n) per coordinate step.
    ``sample_w`` weights the loss per sample (CV fleets, DESIGN.md §8);
    it composes with everything except the fused unpenalized slot."""
    if unpen_idx >= 0 and sample_w is not None:
        raise ValueError("sample weights do not compose with the fused "
                         "unpenalized slot (DESIGN.md §8)")
    x_unpen = X[:, unpen_idx] if unpen_idx >= 0 else None

    def run(carry, aset, Xa, lam, n_ep):
        pen = (aset_lib.pen_weights(aset, unpen_idx, X.dtype)
               if unpen_idx >= 0 else None)
        beta, z = cm_epochs_compact(loss, Xa, y, aset.beta, Xa @ aset.beta,
                                    aset.mask, lam, aset.order, aset.count,
                                    n_ep, pen=pen, sample_w=sample_w)
        if unpen_idx >= 0 and loss.name != "least_squares":
            # general loss: Newton-polish b to stationarity so the dual
            # point satisfies its equality constraint through the gradient
            # itself — see duality.polish_unpen (DESIGN.md §7)
            unpen_slot = aset.mask & (aset.idx == unpen_idx)
            slot = jnp.argmax(unpen_slot)
            present = jnp.any(unpen_slot)
            b_new, z_new = polish_unpen(loss, x_unpen, y, z, beta[slot])
            beta = beta.at[slot].set(jnp.where(present, b_new, beta[slot]))
            z = jnp.where(present, z_new, z)
        theta, gap = _dual_and_gap(loss, Xa, y, beta, z, aset.mask, lam,
                                   pen=pen, x_unpen=x_unpen,
                                   sample_w=sample_w)
        return InnerOut(beta=beta, z=z, theta=theta, gap=gap)

    return InnerBackend(name="jnp",
                        init=lambda aset, carry, Xa: carry,
                        refresh=lambda carry, aset, Xa: carry,
                        run=run)


def make_inner_gram(loss: Loss, X: jax.Array, y: jax.Array,
                    h: int, unpen_idx: int = -1,
                    sample_w: jax.Array | None = None) -> InnerBackend:
    """Covariance-update backend: O(k_max) coordinate steps (LS only).

    The unpenalized slot (``unpen_idx`` >= 0, fused LASSO) needs no special
    Gram handling: it is always resident, so its row/column of G stays hot
    across the whole solve — only its threshold (0) and the dual tail's
    equality projection differ.

    ``sample_w`` (CV fleets, §8) folds into the carry itself — G becomes
    Xa^T diag(w) Xa and rho becomes Xa^T diag(w) y — so the O(k_max)
    sweep needs no weight hook at all; only the carry builds and the
    dual/gap tail see the weights.
    """
    if loss.name != "least_squares":
        raise ValueError("the gram inner backend needs a linear gradient "
                         f"(least squares); got loss {loss.name!r}")
    if unpen_idx >= 0 and sample_w is not None:
        raise ValueError("sample weights do not compose with the fused "
                         "unpenalized slot (DESIGN.md §8)")
    x_unpen = X[:, unpen_idx] if unpen_idx >= 0 else None

    def _wgt(cols):
        return cols if sample_w is None else sample_w[:, None] * cols

    def _rebuild(aset, Xa):
        G = Xa.T @ _wgt(Xa)
        rho = _wgt(Xa).T @ y
        gidx = jnp.where(aset.mask, aset.idx, -1)
        return InnerCarry(G=G, rho=rho, gidx=gidx.astype(jnp.int32))

    def init(aset, carry, Xa):
        # Reconcile a warm-handoff carry: keep it when every live slot's
        # backing feature matches (the warm-started path case — slot
        # assignment is preserved across lambdas); otherwise rebuild in
        # full. This is the ONLY place an O(n k^2) Gram build can happen,
        # and it is outside the while_loop.
        gidx = jnp.where(aset.mask, carry.gidx, -1).astype(jnp.int32)
        dirty = aset.mask & (gidx != aset.idx)
        return jax.lax.cond(jnp.any(dirty),
                            lambda c: _rebuild(aset, Xa),
                            lambda c: c._replace(gidx=gidx), carry)

    def refresh(carry, aset, Xa):
        # Invalidate dead slots, then recompute the (<= h) dirty live
        # columns — invariants 1-4 in the module docstring.
        kc = carry.gidx.shape[0]
        gidx = jnp.where(aset.mask, carry.gidx, -1).astype(jnp.int32)
        dirty = aset.mask & (gidx != aset.idx)
        carry = carry._replace(gidx=gidx)

        def do_refresh(c):
            slots = jnp.nonzero(dirty, size=h, fill_value=kc)[0]
            slots = slots.astype(jnp.int32)
            valid = slots < kc
            sl = jnp.minimum(slots, kc - 1)
            ids = jnp.where(valid, jnp.take(aset.idx, sl), 0)
            cols = jnp.take(X, ids, axis=1) * valid.astype(X.dtype)[None, :]
            cols_w = _wgt(cols)
            # two dots rather than one dot + transpose: each orientation is
            # consumed in its natural layout (XLA:CPU's dot thunk rejects
            # transposed-output fusions), and the column refresh stays
            # O(n k h) either way
            Gblk = Xa.T @ cols_w                      # (k_max, h)
            GblkT = cols_w.T @ Xa                     # (h, k_max)
            G = c.G.at[:, slots].set(Gblk, mode="drop")
            G = G.at[slots, :].set(GblkT, mode="drop")
            rho = c.rho.at[slots].set(cols_w.T @ y, mode="drop")
            new_gidx = c.gidx.at[slots].set(
                jnp.where(valid, ids, -1), mode="drop")
            return InnerCarry(G=G, rho=rho, gidx=new_gidx)

        return jax.lax.cond(jnp.any(dirty), do_refresh, lambda c: c, carry)

    def run(carry, aset, Xa, lam, n_ep):
        pen = (aset_lib.pen_weights(aset, unpen_idx, X.dtype)
               if unpen_idx >= 0 else None)
        beta = gram_epochs(carry.G, carry.rho, aset.beta, aset.mask, lam,
                           aset.order, aset.count, n_ep,
                           smoothness=loss.smoothness, pen=pen)
        z = Xa @ beta                # the only O(n k) term: once per burst
        theta, gap = _dual_and_gap(loss, Xa, y, beta, z, aset.mask, lam,
                                   pen=pen, x_unpen=x_unpen,
                                   sample_w=sample_w)
        return InnerOut(beta=beta, z=z, theta=theta, gap=gap)

    return InnerBackend(name="gram", init=init, refresh=refresh, run=run)


def make_inner_pallas(loss: Loss, X: jax.Array, y: jax.Array,
                      col_norm: jax.Array,
                      interpret: bool | None = None,
                      unpen_idx: int = -1) -> InnerBackend:
    """VMEM-resident fused-kernel backend (kernels/cm/cm.py)."""
    from repro.kernels.cm.cm import cm_burst_pallas

    def run(carry, aset, Xa, lam, n_ep):
        # O(k_max) gather from the solver's precomputed column norms — not
        # an O(n k_max) reduction over the gathered block
        norms = jnp.where(aset.mask, jnp.take(col_norm, aset.idx), 0.0)
        col_sq = norms * norms
        pen = (aset_lib.pen_weights(aset, unpen_idx, X.dtype)
               if unpen_idx >= 0 else None)
        beta, z, theta, gap = cm_burst_pallas(
            Xa, y, aset.beta, col_sq, aset.mask, aset.order, lam, n_ep,
            aset.count, pen=pen, loss_name=loss.name, interpret=interpret)
        return InnerOut(beta=beta, z=z, theta=theta, gap=gap)

    return InnerBackend(name="pallas",
                        init=lambda aset, carry, Xa: carry,
                        refresh=lambda carry, aset, Xa: carry,
                        run=run)


def make_inner(name: str, loss: Loss, X: jax.Array, y: jax.Array,
               col_norm: jax.Array, h: int,
               unpen_idx: int = -1) -> InnerBackend:
    """Factory used inside ``_saif_jit`` (name is a jit-static string)."""
    if name == "gram":
        return make_inner_gram(loss, X, y, h, unpen_idx)
    if name == "pallas":
        return make_inner_pallas(loss, X, y, col_norm, unpen_idx=unpen_idx)
    return make_inner_jnp(loss, X, y, unpen_idx)


# --------------------------------------------------------------------------
# batched (problem-axis) backends — the fleet engine (core/batch.py, §8)
# --------------------------------------------------------------------------
# The same three backends lifted to a fleet of B problems. The jnp and
# gram fleet backends are ``lax.map``s of the *serial* per-problem bodies
# (the very factories above, instantiated inside the traced map body with
# that problem's response/weights as operands): each problem's burst, dual
# point and gap are the literal serial computation — same HLO shapes, same
# reduction association — which is what makes fleet coefficients bitwise
# against B serial solves (batch-dim contractions provably re-associate on
# XLA:CPU; see DESIGN.md §8). The map's per-problem *traced* trip counts
# (n_epochs, count) also mean a finished problem's burst is a genuine
# zero-trip loop — zero marginal flops, not a masked no-op. A plain
# ``vmap`` could deliver neither property. The pallas fleet backend is the
# problem-gridded kernel instead: one launch, one grid step per problem,
# each step executing the serial kernel body on that problem's VMEM block.
# Optional ``weights`` (B, n) are the K-fold CV sample-weight trick (§8).


class BatchInnerBackend(NamedTuple):
    """The batched inner-solver interface ``_saif_batch_jit`` consumes.

    Two structural paths (engine picks by which field is set):

      * ``make_one(y_b, w_b) -> InnerBackend`` — the *map-fused* path
        (jnp / gram): the engine lax.maps one per-problem body that
        gathers the active block, refreshes and runs the SERIAL backend
        built here, all under a per-problem liveness ``lax.cond`` — a
        frozen problem costs literally nothing per outer step.
      * ``fleet_step(carry, aset, lam, n_ep) -> (InnerOut, carry)`` — the
        *gridded-kernel* path (pallas): gathers its own fleet blocks and
        runs one problem-gridded launch for every burst; frozen problems
        ride along with zero-trip epoch loops (cheap, not free — the
        kernel still runs their z/dual tail).

    ``init`` is fleet-level either way (outside the while_loop).
    """
    name: str
    init: Callable[[ActiveSet, InnerCarry, jax.Array], InnerCarry]
    make_one: Optional[Callable] = None
    fleet_step: Optional[Callable] = None


def cold_inner_carry_batch(b: int, k_max: int, dtype=jnp.float32,
                           backend: str = "gram") -> InnerCarry:
    """Fleet-shaped all-invalid carry (leading problem axis)."""
    if backend != "gram":
        return InnerCarry(G=jnp.zeros((b, 1, 1), dtype),
                          rho=jnp.zeros((b, 1), dtype),
                          gidx=jnp.full((b, 1), -1, jnp.int32))
    return InnerCarry(G=jnp.zeros((b, k_max, k_max), dtype),
                      rho=jnp.zeros((b, k_max), dtype),
                      gidx=jnp.full((b, k_max), -1, jnp.int32))


def _fleet_init(make_backend, Y, weights):
    """Fleet-level init: lax.map of the serial backend's init (one
    O(n k^2) reconcile per problem, outside the while_loop)."""
    def init(aset, carry, Xa):
        def one(args):
            if weights is None:
                y_b, carry_b, aset_b, Xa_b = args
                w_b = None
            else:
                y_b, w_b, carry_b, aset_b, Xa_b = args
            return make_backend(y_b, w_b).init(aset_b, carry_b, Xa_b)
        xs = ((Y, carry, aset, Xa) if weights is None
              else (Y, weights, carry, aset, Xa))
        return jax.lax.map(one, xs)
    return init


def make_batch_inner_jnp(loss: Loss, X: jax.Array, Y: jax.Array,
                         weights=None) -> BatchInnerBackend:
    """Fleet reference backend: the serial jnp backend, map-fused."""
    def make_one(y_b, w_b):
        return make_inner_jnp(loss, X, y_b, sample_w=w_b)
    return BatchInnerBackend(name="jnp",
                             init=_fleet_init(make_one, Y, weights),
                             make_one=make_one)


def make_batch_inner_gram(loss: Loss, X: jax.Array, Y: jax.Array,
                          h: int, weights=None) -> BatchInnerBackend:
    """Fleet covariance-update backend: the serial gram backend,
    map-fused — per-problem (k_max, k_max) Gram buffers with the refresh
    invariants 1-4 applied per problem (including the per-problem
    ``lax.cond`` skip when no slots are dirty). Sample weights fold into
    each problem's G/rho (G_b = Xa^T diag(w_b) Xa). A lockstep batched
    sweep was tried and rejected: per-problem dynamic indexing across a
    batch lowers to XLA gather/scatter ops whose per-op overhead on CPU
    exceeds the serial sweep's dynamic-slice steps ~30-fold, and batched
    float updates pick up FMA contractions that break bitwise parity —
    the map keeps the sweep serial-exact and lets the fleet win where it
    structurally should, on the shared O(p) scan."""
    def make_one(y_b, w_b):
        return make_inner_gram(loss, X, y_b, h, sample_w=w_b)
    return BatchInnerBackend(name="gram",
                             init=_fleet_init(make_one, Y, weights),
                             make_one=make_one)


def make_batch_inner_pallas(loss: Loss, X: jax.Array, Y: jax.Array,
                            col_norm: jax.Array,
                            interpret: bool | None = None,
                            weights=None) -> BatchInnerBackend:
    """Fleet VMEM-resident kernel backend: ONE problem-gridded launch
    drives the whole fleet's bursts (kernels/cm/cm.py)."""
    from repro.kernels.cm.cm import cm_burst_batch_pallas

    if weights is not None:
        raise ValueError("the batched pallas inner backend does not take "
                         "sample weights; use 'jnp' or 'gram' for CV "
                         "fleets (DESIGN.md §8)")

    def fleet_step(carry, aset, lam, n_ep):
        Xa = aset_lib.gather_columns_batch(X, aset)
        # col_norm is the fleet (B, p) matrix (shared designs broadcast it)
        norms = jnp.where(aset.mask,
                          jnp.take_along_axis(col_norm, aset.idx, axis=1),
                          0.0)
        col_sq = norms * norms
        beta, z, theta, gap = cm_burst_batch_pallas(
            Xa, Y, aset.beta, col_sq, aset.mask, aset.order, lam, n_ep,
            aset.count, loss_name=loss.name, interpret=interpret)
        return InnerOut(beta=beta, z=z, theta=theta, gap=gap), carry

    return BatchInnerBackend(name="pallas",
                             init=lambda aset, carry, Xa: carry,
                             fleet_step=fleet_step)


def make_batch_inner(name: str, loss: Loss, X: jax.Array, Y: jax.Array,
                     col_norm: jax.Array, h: int,
                     weights=None) -> BatchInnerBackend:
    """Factory used inside ``_saif_batch_jit`` (name is jit-static)."""
    if name == "gram":
        return make_batch_inner_gram(loss, X, Y, h, weights=weights)
    if name == "pallas":
        return make_batch_inner_pallas(loss, X, Y, col_norm,
                                       weights=weights)
    return make_batch_inner_jnp(loss, X, Y, weights=weights)


# n/k_max crossover of the auto policy: the gram step is an O(k_max) axpy
# against the jnp step's ~3 O(n) passes (gradient, dot, rank-1 update), so
# gram wins whenever k_max is not vastly larger than n. Measured on the CI
# shape (n=100, k_max=256, BENCH_inner.json) gram is still ahead at
# k_max ~ 2.5n; the factor 4 keeps a safety margin before handing back to
# the jnp path. Policy table in DESIGN.md §6.
GRAM_CROSSOVER = 4.0


def resolve_inner_backend(name: str, loss_name: str, n: int,
                          k_max: int) -> str:
    """Inner-backend selection policy (DESIGN.md §6): explicit name wins;
    ``auto`` picks the covariance-update engine whenever the loss gradient
    is linear (least squares) and the active capacity is not >> n, the
    fused Pallas kernel on TPU when the block fits VMEM, and the jnp
    reference path elsewhere (off-TPU the kernel would run interpreted —
    a correctness oracle, strictly slower than XLA)."""
    from repro.kernels.cm.cm import cm_vmem_ok

    if name == "auto":
        if loss_name == "least_squares" and GRAM_CROSSOVER * n >= k_max:
            return "gram"
        if jax.default_backend() == "tpu" and cm_vmem_ok(n, k_max):
            return "pallas"
        return "jnp"
    if name not in ("jnp", "gram", "pallas"):
        raise ValueError(f"unknown inner backend {name!r}")
    if name == "gram" and loss_name != "least_squares":
        raise ValueError("inner_backend='gram' requires loss='least_squares'"
                         " (covariance updates need a linear gradient); use"
                         " 'jnp' or 'pallas'")
    if name == "pallas" and not cm_vmem_ok(n, k_max):
        raise ValueError(
            f"inner_backend='pallas': a {n}x{k_max} active block exceeds "
            f"the VMEM budget (DESIGN.md §6); shrink k_max, shard the "
            f"sample dimension, or use 'gram'/'jnp'")
    return name


def gram_block_update(G: jax.Array, rho: jax.Array, gidx: jax.Array,
                      rows_new: jax.Array, y_new: jax.Array,
                      rows_old: jax.Array, y_old: jax.Array):
    """Rank-m streaming update/downdate of a resident gram carry
    (DESIGN.md §14): replace the (m, p) rows ``rows_old`` (responses
    ``y_old``) with ``rows_new`` (``y_new``) in the active-block state,

        G   += C_new^T C_new - C_old^T C_old
        rho += C_new^T y_new - C_old^T y_old

    where ``C = rows[:, gidx]`` gathers the per-slot feature columns of
    the row block. Traceable (no shape depends on data). Slots with
    ``gidx < 0`` are masked out of the gather; their G/rho entries may go
    stale, which invariant (2) above explicitly allows — ``init`` /
    ``refresh`` never read a slot before reconciling it. An append-only
    stream passes zero rows as ``rows_old``/``y_old`` (an exact no-op on
    the subtracted terms), so one traced expression serves both the
    update and the downdate.

    ``gidx`` is returned unchanged by construction: live slots keep
    ``gidx == idx``, so the warm re-solve's ``init`` finds zero dirty
    slots and keeps the updated carry without the O(n k^2) rebuild.
    """
    valid = gidx >= 0
    ids = jnp.where(valid, gidx, 0)
    vf = valid.astype(G.dtype)
    c_new = jnp.take(rows_new, ids, axis=1) * vf[None, :]
    c_old = jnp.take(rows_old, ids, axis=1) * vf[None, :]
    G2 = G + c_new.T @ c_new - c_old.T @ c_old
    rho2 = rho + c_new.T @ y_new - c_old.T @ y_old
    return G2, rho2
