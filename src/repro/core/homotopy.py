"""Unsafe strong-rule homotopy baseline (Tibshirani et al. 2012; Zhao 2017).

Reproduces the paper's Table-1 antagonist: a pathwise coordinate-descent
solver whose active set is initialized per-lambda by the *strong rule*
    |x_i^T f'(X beta(lam_prev))| >= 2 lam - lam_prev
plus warm start, WITHOUT a safe convergence check on the discarded set.
It can therefore miss true active features (recall < 1) and retain spurious
ones (precision < 1) — exactly the failure mode Table 1 quantifies.

A ``kkt_check`` switch turns the method into its safe variant (violations
re-enter the active set until none remain) so tests can demonstrate both
behaviours.
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cm import cm_epoch
from repro.core.duality import duality_gap, feasible_dual
from repro.core.losses import get_loss
from repro.core.sequential import _solve_reduced


@dataclasses.dataclass(frozen=True)
class HomotopyConfig:
    eps: float = 1e-6
    inner_epochs: int = 10
    max_outer: int = 5000
    kkt_check: bool = False   # False = paper's unsafe baseline
    # Greedy active-set truncation (Zhao 2017-style pathwise CD keeps only
    # the top-scoring candidates, "no safe convergence stopping criteria for
    # the active set" — the failure source Table 1 quantifies). 0 = off
    # (pure strong rule); k>0 caps the set at warm-support + k candidates.
    greedy_cap: int = 0
    loss: str = "least_squares"


class HomotopyResult(NamedTuple):
    lams: np.ndarray
    betas: List[jax.Array]
    supports: List[np.ndarray]
    coord_updates: int


def homotopy_path(X, y, lams: Sequence[float],
                  config: HomotopyConfig = HomotopyConfig()) -> HomotopyResult:
    loss = get_loss(config.loss)
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    n, p = X.shape
    g0 = loss.grad(jnp.zeros_like(y), y)
    lam_max = float(jnp.max(jnp.abs(X.T @ g0)))

    lams = np.asarray(sorted([float(l) for l in lams], reverse=True))
    betas, supports = [], []
    coord_updates = 0

    lam_prev = lam_max
    beta_full = jnp.zeros((p,), X.dtype)

    for lam_f in lams:
        lam = jnp.asarray(min(lam_f, lam_max * (1 - 1e-12)), X.dtype)
        # strong rule on the residual correlations at the previous solution
        corr = jnp.abs(X.T @ loss.grad(X @ beta_full, y))
        strong = np.array(corr >= 2.0 * float(lam) - lam_prev)
        if config.greedy_cap > 0:
            # truncated pathwise variant: keep only the top-`cap` strong
            # candidates by correlation (plus the warm support)
            cand = np.where(strong)[0]
            if len(cand) > config.greedy_cap:
                order = np.argsort(-np.asarray(corr)[cand])
                keep = cand[order[:config.greedy_cap]]
                strong[:] = False
                strong[keep] = True
        strong |= np.array(jnp.abs(beta_full) > 0)   # warm-start support
        if not strong.any():
            strong[int(jnp.argmax(corr))] = True

        while True:
            idx = np.where(strong)[0]
            Xr = X[:, idx]
            beta_r, z, gap, t = _solve_reduced(
                loss, Xr, y, lam, beta_full[idx],
                jnp.asarray(config.eps, X.dtype),
                config.inner_epochs, config.max_outer)
            coord_updates += int(t) * config.inner_epochs * len(idx)
            beta_full = jnp.zeros((p,), X.dtype).at[idx].set(beta_r)
            if not config.kkt_check:
                break
            # safe variant: re-admit KKT violators among discarded features
            corr_all = jnp.abs(X.T @ loss.grad(X @ beta_full, y))
            viol = np.asarray(corr_all > float(lam) * (1 + 1e-9)) & ~strong
            if not viol.any():
                break
            strong |= viol

        betas.append(beta_full)
        supports.append(np.where(np.asarray(jnp.abs(beta_full) > 1e-8))[0])
        lam_prev = float(lam)

    return HomotopyResult(lams=lams, betas=betas, supports=supports,
                          coord_updates=coord_updates)


def support_metrics(est_support: np.ndarray, true_support: np.ndarray):
    """Recall / precision of a recovered support vs the safe ground truth."""
    est, true = set(est_support.tolist()), set(true_support.tolist())
    tp = len(est & true)
    recall = tp / len(true) if true else 1.0     # vacuous: nothing to recall
    precision = tp / len(est) if est else 1.0    # vacuous: nothing spurious
    return recall, precision
