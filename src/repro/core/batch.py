"""Batch-polymorphic SAIF: one compilation solving a fleet of B problems.

Real traffic arrives as *fleets* of related solves — many responses over a
shared design, K-fold cross-validation over a lambda grid (the glmnet-style
workload; see Fercoq et al.'s CV protocol). The serial engine
(``core/saif.py``) prices Theorem 5's economics — a tiny active block plus
one O(p) scan — per problem; this module re-prices them per *fleet*:

  * **one compilation** — ``_saif_batch_jit`` is a single hand-batched
    ``lax.while_loop`` whose every state leaf carries a leading problem
    axis B. One XLA program drives B lockstep solves; the compile counter
    (``saif_jit_compile_count``) must move by exactly 1 per fleet.
  * **amortized fixed costs + shared scans** — the fleet pays ONE host
    driver, ONE preprocessing pass, ONE dispatch and ONE set of device
    syncs where B serial calls pay B of each (the dominant term for
    serving-sized solves), and the screening stage is pluggable per fleet:
    the default keeps per-problem serial scans (bitwise, and skipped per
    problem outside its ADD phase), while the opt-in ``matmul`` shared-X
    path and the problem-gridded Pallas kernels read the O(n p) design
    once per outer step for the entire fleet.
  * **per-problem masks, not a barrier** — ``lam``/``eps``/``h_cap``/
    ``h~``/``delta`` are traced (B,) vectors; convergence, the ADD ramp
    and capacity overflow are all per-problem. A finished problem is
    *frozen*: its state is select-masked, its inner burst runs zero
    epochs, and it never forces extra work on stragglers. This is why the
    loop is hand-batched — ``vmap`` over the serial while_loop would
    re-run every problem's full body until the whole fleet converges and
    could not give per-problem burst budgets.

The batching discipline (DESIGN.md §8): every float path of the default
configuration — bursts, dual points, gaps, balls, DEL certificates, the
screening scans, even the c0 preprocessing — runs as a ``lax.map`` of the
*literal serial code* over the fleet, under per-problem liveness conds.
Batch-dim float contractions provably re-associate on XLA:CPU (a batched
dot is not bitwise the serial dot, and near an ADD-stop boundary an ulp
flips a decision), so mapping the serial bodies is what makes fleet
supports, coefficients, gaps and traces byte-for-byte those of B serial
solves — asserted across every screen x inner backend combination in
``tests/test_batch_parity.py``. The explicitly opt-in deviations are the
``matmul`` screen and the sharded collective, which trade ulp-grade score
equality for fleet-shared memory traffic.

Frontends: :func:`saif_batch` (B responses, one X, per-problem lambdas)
here; :func:`repro.core.cv.cv_path` (K-fold CV fleets via the
sample-weight trick); ``repro.distributed.saif_sharded.
saif_batch_distributed`` (the §5 collective serving all B problems per
wire round). DESIGN.md §8 documents the layer.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import active_set as aset_lib
from repro.core.duality import (gap_ball, gap_precision_floor,
                                intersect_balls, sequential_ball)
from repro.core.inner_backend import (InnerCarry, cold_inner_carry_batch,
                                      make_batch_inner)
from repro.core.losses import get_loss
from repro.core.saif import (SaifConfig, SaifResult, add_batch_size_static,
                             default_capacity)
from repro.core.screen_backend import (BatchScreenFn, ScreenOut,
                                       make_batch_screen,
                                       resolve_batch_screen)
from repro.runtime.inject import seam as _fault_seam


class _BatchState(NamedTuple):
    aset: aset_lib.ActiveSet   # every field with leading problem axis B
    z: jax.Array        # (B, n)
    gap: jax.Array      # (B,)
    delta: jax.Array    # (B,)
    is_add: jax.Array   # (B,) bool
    stop: jax.Array     # (B,) bool
    t: jax.Array        # (B,) int32 per-problem outer counters
    inner: InnerCarry   # batched inner carry
    trace_n_active: jax.Array   # (B, max_outer)
    trace_gap: jax.Array
    trace_dual: jax.Array


def _freeze_select(live: jax.Array, old, new):
    """Per-problem state freeze: keep ``old`` wherever ``live`` is False."""
    def sel(o, n):
        m = live.reshape(live.shape + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)
    return jax.tree.map(sel, old, new)


@partial(jax.jit, static_argnames=("loss_name", "h", "k_max",
                                   "inner_epochs", "polish_factor",
                                   "max_outer", "use_seq_ball",
                                   "screen_backend", "inner_backend",
                                   "has_weights", "screen_fn"))
def _saif_batch_jit(X, Y, W, col_norm, c0, lam, eps, delta0, init_idx,
                    init_beta, init_mask, init_G, init_rho, init_gidx,
                    h_tilde, h_cap, *, loss_name: str, h: int, k_max: int,
                    inner_epochs: int, polish_factor: int, max_outer: int,
                    use_seq_ball: bool, screen_backend: str = "jnp",
                    inner_backend: str = "jnp", has_weights: bool = False,
                    screen_fn: Optional[BatchScreenFn] = None
                    ) -> SaifResult:
    """The fleet while_loop. Mirrors ``_saif_jit`` body-for-body with a
    leading problem axis; see the module docstring for the batching rules.
    ``lam``/``eps``/``delta0``/``h_tilde``/``h_cap`` are (B,) traced
    vectors, ``col_norm``/``c0`` fleet (B, p) matrices, ``W`` the sample
    weights ((B, n); a (1, 1) placeholder when ``has_weights`` is False).
    Returns a :class:`SaifResult` whose every field has a leading B.
    """
    loss = get_loss(loss_name)
    n, p = X.shape
    b = Y.shape[0]
    barange = jnp.arange(b)
    lam = jnp.asarray(lam, X.dtype)
    weights = W if has_weights else None
    if screen_fn is not None:
        screen = screen_fn
    else:
        screen = make_batch_screen(screen_backend, X, col_norm, h)
    inner = make_batch_inner(inner_backend, loss, X, Y, col_norm, h,
                             weights=weights)

    aset0 = aset_lib.init_active_set_batch(p, k_max, init_idx, X.dtype,
                                           init_beta, live_mask=init_mask)
    carry_in = InnerCarry(G=init_G, rho=init_rho, gidx=init_gidx)
    inner0 = inner.init(aset0, carry_in,
                        aset_lib.gather_columns_batch(X, aset0))
    trace0 = jnp.full((b, max_outer), -1.0, X.dtype)
    state0 = _BatchState(
        aset=aset0, z=jnp.zeros_like(Y),
        gap=jnp.full((b,), jnp.inf, X.dtype),
        delta=jnp.asarray(delta0, X.dtype),
        is_add=jnp.ones((b,), bool), stop=jnp.zeros((b,), bool),
        t=jnp.zeros((b,), jnp.int32), inner=inner0,
        trace_n_active=trace0, trace_gap=trace0, trace_dual=trace0)

    def cond(s: _BatchState):
        return jnp.any(~s.stop & (s.t < max_outer))

    def _certify(y_b, w_b, theta_b, gap_b, lam_b, eps_b, delta_b,
                 is_add_b, Xa_b, idx_b, mask_b, cn_b, c0_b):
        """Serial ball / stop / DEL certificates for one problem — the
        exact serial body arithmetic (module docstring: batch-dim
        reductions re-associate, serial maps don't)."""
        ball = gap_ball(loss, theta_b, gap_b, lam_b,
                        floor=gap_precision_floor(theta_b, lam_b))
        if use_seq_ball:
            c0_active = jnp.where(mask_b, jnp.take(c0_b, idx_b), -jnp.inf)
            lam0t = jnp.maximum(jnp.max(c0_active), lam_b * (1 + 1e-12))
            g0_b = loss.grad(jnp.zeros_like(y_b), y_b)
            theta0t = -g0_b / lam0t
            b_seq = sequential_ball(loss, y_b, theta0t, lam0t, lam_b)
            ball = intersect_balls(b_seq, ball)
        stop_now_b = (~is_add_b) & (gap_b <= eps_b)
        corr_act = jnp.abs(Xa_b.T @ ball.center)
        norm_act = jnp.where(mask_b, jnp.take(cn_b, idx_b), 0.0)
        del_row = mask_b & (corr_act + norm_act * ball.radius < 1.0)
        conj = loss.conj(-lam_b * theta_b, y_b)
        if w_b is not None:
            conj = w_b * conj
        dual_val = -jnp.sum(conj)
        return (ball.center, delta_b * ball.radius, stop_now_b, del_row,
                dual_val)

    def body(s: _BatchState) -> _BatchState:
        live = ~s.stop & (s.t < max_outer)       # (B,) frozen problems coast
        aset = s.aset
        n_ep = jnp.where(s.is_add, inner_epochs,
                         inner_epochs * polish_factor)
        n_ep = jnp.where(live, n_ep, 0).astype(jnp.int32)

        if inner.make_one is not None:
            # --- map-fused path: ONE lax.map owns gather + refresh +
            # burst + certificates per problem, and a per-problem liveness
            # cond skips the whole body — a frozen problem costs nothing.
            def solve_one(args):
                if has_weights:
                    (live_b, y_b, w_b, lam_b, eps_b, nep_b, delta_b,
                     is_add_b, z_b, gap_b, carry_b, aset_b, cn_b,
                     c0_b) = args
                else:
                    (live_b, y_b, lam_b, eps_b, nep_b, delta_b,
                     is_add_b, z_b, gap_b, carry_b, aset_b, cn_b,
                     c0_b) = args
                    w_b = None

                def live_branch(_):
                    Xa_b = aset_lib.gather_columns(X, aset_b)
                    be = inner.make_one(y_b, w_b)
                    carry2 = be.refresh(carry_b, aset_b, Xa_b)
                    out = be.run(carry2, aset_b, Xa_b, lam_b, nep_b)
                    cert = _certify(y_b, w_b, out.theta,
                                    jnp.asarray(out.gap, X.dtype), lam_b,
                                    eps_b, delta_b, is_add_b, Xa_b,
                                    aset_b.idx, aset_b.mask, cn_b, c0_b)
                    return (out.beta, out.z,
                            jnp.asarray(out.gap, X.dtype), carry2) + cert

                def frozen_branch(_):
                    k = aset_b.beta.shape[0]
                    return (aset_b.beta, z_b, gap_b, carry_b,
                            jnp.zeros_like(z_b),
                            jnp.zeros((), X.dtype),
                            jnp.asarray(True),
                            jnp.zeros((k,), bool),
                            jnp.zeros((), X.dtype))

                return jax.lax.cond(live_b, live_branch, frozen_branch,
                                    None)

            xs = (live, Y, lam, eps, n_ep, s.delta, s.is_add, s.z, s.gap,
                  s.inner, aset, col_norm, c0)
            if has_weights:
                xs = (live, Y, weights) + xs[2:]
            (beta, z, gap, inner_carry, theta_c, r_eff, stop_now, del_row,
             dual_val) = jax.lax.map(solve_one, xs)
        else:
            # --- fleet-step path (the pallas problem-gridded kernel): the
            # backend owns the whole fleet's bursts in one launch, then
            # the per-problem certificate map runs (liveness-gated,
            # gathering each live problem's block like the serial body).
            out, inner_carry = inner.fleet_step(s.inner, aset, lam, n_ep)
            beta = jnp.where(live[:, None], out.beta, aset.beta)
            z = jnp.where(live[:, None], out.z, s.z)
            gap = jnp.where(live, jnp.asarray(out.gap, X.dtype), s.gap)
            theta = out.theta

            def certify_one(args):
                if has_weights:
                    (live_b, y_b, w_b, theta_b, gap_b, lam_b, eps_b,
                     delta_b, is_add_b, aset_b, cn_b, c0_b) = args
                else:
                    (live_b, y_b, theta_b, gap_b, lam_b, eps_b, delta_b,
                     is_add_b, aset_b, cn_b, c0_b) = args
                    w_b = None

                def live_branch(_):
                    Xa_b = aset_lib.gather_columns(X, aset_b)
                    return _certify(y_b, w_b, theta_b, gap_b, lam_b,
                                    eps_b, delta_b, is_add_b, Xa_b,
                                    aset_b.idx, aset_b.mask, cn_b, c0_b)

                def frozen_branch(_):
                    k = aset_b.mask.shape[0]
                    return (jnp.zeros_like(theta_b),
                            jnp.zeros((), X.dtype), jnp.asarray(True),
                            jnp.zeros((k,), bool), jnp.zeros((), X.dtype))

                return jax.lax.cond(live_b, live_branch, frozen_branch,
                                    None)

            xs = (live, Y, theta, gap, lam, eps, s.delta, s.is_add,
                  aset, col_norm, c0)
            if has_weights:
                xs = (live, Y, weights) + xs[2:]
            theta_c, r_eff, stop_now, del_row, dual_val = jax.lax.map(
                certify_one, xs)

        aset = aset._replace(beta=beta)

        # --- DEL (per-problem gap-safe rule) ------------------------------
        deleting = live & ~stop_now
        del_mask = del_row & deleting[:, None]
        aset = aset_lib.delete_features_batch(aset, del_mask)

        # --- ADD phase (skipped fleet-wide once every problem is done) ----
        do_add = live & s.is_add & ~stop_now

        def do_add_phase(args):
            aset, delta, is_add = args
            out: ScreenOut = screen(theta_c, r_eff, aset.in_active, do_add)
            add_done = out.max_ub < 1.0                       # (B,)
            ranks = jnp.arange(h)
            v_count = jnp.maximum(out.cand_ge - 1 - ranks[None, :], 0)
            keep = ((v_count < h_tilde[:, None]) &
                    (ranks[None, :] < h_cap[:, None]) &
                    jnp.isfinite(out.cand_score))
            keep = jnp.cumprod(keep.astype(jnp.int32), axis=1).astype(bool)
            # progress guarantee, per problem (DESIGN.md §2)
            stuck = gap <= 100.0 * eps
            keep = keep.at[:, 0].set(
                keep[:, 0] | (stuck & jnp.isfinite(out.cand_score[:, 0])))
            adding = do_add & ~add_done
            aset = aset_lib.add_features_batch(aset, out.cand_idx,
                                               keep & adding[:, None])
            done = do_add & add_done
            grown = jnp.minimum(10.0 * delta, 1.0)
            new_delta = jnp.where(done & (delta < 1.0), grown, delta)
            new_is_add = jnp.where(done & (delta >= 1.0), False, is_add)
            return aset, new_delta, new_is_add

        aset, delta, is_add = jax.lax.cond(
            jnp.any(do_add), do_add_phase, lambda a: a,
            (aset, s.delta, s.is_add))

        n_act = aset.count.astype(X.dtype)
        new = _BatchState(
            aset=aset, z=z, gap=gap, delta=delta, is_add=is_add,
            stop=stop_now, t=s.t + 1, inner=inner_carry,
            trace_n_active=s.trace_n_active.at[barange, s.t].set(
                n_act, mode="drop"),
            trace_gap=s.trace_gap.at[barange, s.t].set(gap, mode="drop"),
            trace_dual=s.trace_dual.at[barange, s.t].set(
                dual_val, mode="drop"))
        return _freeze_select(live, s, new)

    final = jax.lax.while_loop(cond, body, state0)
    beta_full = aset_lib.scatter_beta_batch(final.aset, p)
    return SaifResult(beta=beta_full, gap=final.gap, n_outer=final.t,
                      n_active=final.aset.count,
                      overflowed=final.aset.overflowed,
                      trace_n_active=final.trace_n_active,
                      trace_gap=final.trace_gap,
                      trace_dual=final.trace_dual,
                      active_idx=final.aset.idx,
                      active_mask=final.aset.mask,
                      inner=final.inner)


def saif_batch_compile_count() -> int:
    """Distinct ``_saif_batch_jit`` compilations alive in this process."""
    try:
        return int(_saif_batch_jit._cache_size())
    except Exception:       # pragma: no cover - jit internals moved
        return -1


class FleetPrep(NamedTuple):
    """One-time per-fleet preprocessing (one host sync for the h formula).
    ``c0_max`` doubles as the per-problem lambda_max: for the penalized-
    null model, lambda_max = max_i |x_i^T f'(null)| = max(c0) exactly."""
    X: jax.Array            # (n, p) shared design
    Y: jax.Array            # (B, n)
    W: Optional[jax.Array]  # (B, n) sample weights or None
    c0: jax.Array           # (B, p) per-problem |X^T f'(null)|
    col_norm: jax.Array     # (B, p) per-problem column norms
    c0_max: list            # B host floats (= per-problem lambda_max)
    c0_median: list


def prepare_fleet(X, Y, config: SaifConfig, weights=None) -> FleetPrep:
    """Per-problem null gradients, c0, column norms + ONE host sync of the
    c0 statistics the (host-side) h formula needs."""
    loss = get_loss(config.loss)
    X = jnp.asarray(X)
    Y = jnp.asarray(Y)
    if Y.ndim == 1:
        Y = Y[None, :]
    W = None if weights is None else jnp.asarray(weights, X.dtype)
    G0 = loss.grad(jnp.zeros_like(Y), Y)
    if W is not None:
        G0 = W * G0
    # per-problem c0 scans as B EAGER serial matvecs — the literal op the
    # serial driver's null_gradient dispatches, so lambda_max, delta0, the
    # cold-start top-h and the seq-ball lam0t are bitwise per problem (a
    # (B, n) x (n, p) matmul — or even a lax.map'd matvec, which compiles
    # under scan instead of dispatching the eager dot executable —
    # re-associates the reduction at the ulp level; same rule as the §8
    # screen paths). One-time prep cost, off the hot path.
    c0 = jnp.stack([jnp.abs(X.T @ G0[i]) for i in range(Y.shape[0])])
    if W is None:
        col_norm = jnp.broadcast_to(jnp.linalg.norm(X, axis=0),
                                    c0.shape)
    else:
        col_norm = jnp.sqrt(W @ (X * X))                   # (B, p)
    c0_max, c0_med = jax.device_get(
        (jnp.max(c0, axis=1), jnp.median(c0, axis=1)))
    return FleetPrep(X=X, Y=Y, W=W, c0=c0, col_norm=col_norm,
                     c0_max=[float(v) for v in c0_max],
                     c0_median=[float(v) for v in c0_med])


def fleet_batch_sizes(prep: FleetPrep, lams, config: SaifConfig):
    """Per-problem h values + the fleet-static maximum (pow2-bucketed by
    ``add_batch_size_static`` already)."""
    p = prep.X.shape[1]
    hs = [add_batch_size_static(config.c, float(lam), mx, md, p)
          for lam, mx, md in zip(lams, prep.c0_max, prep.c0_median)]
    return hs, (max(hs) if hs else 1)


def initial_support_batch(c0: jax.Array, hs, k_max: int, p: int,
                          dtype=jnp.float32):
    """Batched cold start: per-problem top-h_b features by c0.

    Per-problem counts ride on the static fleet maximum via top_k's prefix
    property (top_k(x, m)[: j] == top_k(x, j) for j <= m, ties to the
    lowest id), so every problem's initial slots are bitwise the serial
    :func:`repro.core.saif.initial_support` layout.
    """
    b = c0.shape[0]
    n_cap = min(max(hs), k_max, p)
    top = jax.lax.top_k(c0, n_cap)[1].astype(jnp.int32)    # (B, n_cap)
    n_init = jnp.asarray([min(h_b, k_max, p) for h_b in hs], jnp.int32)
    ranks = jnp.arange(k_max)
    init_idx = jnp.zeros((b, k_max), jnp.int32).at[:, :n_cap].set(top)
    mask = ranks[None, :] < n_init[:, None]
    init_idx = jnp.where(mask, init_idx, 0)
    return init_idx, jnp.zeros((b, k_max), dtype), mask


def _delta0s(prep: FleetPrep, lams, config: SaifConfig):
    if config.delta0 is not None:
        return [float(config.delta0)] * len(lams)
    return [min(max(float(lam) / mx, 1e-3), 1.0)
            for lam, mx in zip(lams, prep.c0_max)]


def resolve_batch_inner(config: SaifConfig, n: int, k_max: int,
                        b: int) -> str:
    """Fleet inner-backend policy: the serial policy with the
    double-buffered fleet VMEM budget gating the pallas kernel."""
    from repro.kernels.cm.cm import cm_vmem_ok

    name, loss_name = config.inner_backend, config.loss
    from repro.core.inner_backend import GRAM_CROSSOVER
    if name == "auto":
        if loss_name == "least_squares" and GRAM_CROSSOVER * n >= k_max:
            return "gram"
        if jax.default_backend() == "tpu" and cm_vmem_ok(n, k_max, batch=b):
            return "pallas"
        return "jnp"
    if name not in ("jnp", "gram", "pallas"):
        raise ValueError(f"unknown inner backend {name!r}")
    if name == "gram" and loss_name != "least_squares":
        raise ValueError("inner_backend='gram' requires "
                         "loss='least_squares'")
    if name == "pallas" and not cm_vmem_ok(n, k_max, batch=b):
        raise ValueError(
            f"inner_backend='pallas': a fleet of {b} {n}x{k_max} active "
            f"blocks exceeds the double-buffered VMEM budget (DESIGN.md "
            f"§8); shrink k_max or use 'gram'/'jnp'")
    return name


def fleet_solve(X, Y, lam, config: SaifConfig = SaifConfig(),
                weights=None,
                screen_fn: Optional[BatchScreenFn] = None) -> SaifResult:
    """Solve a fleet of B LASSO problems over a shared design in lockstep.

    Args:
      X:       (n, p) shared design.
      Y:       (B, n) per-problem responses (a (n,) vector is a fleet of 1).
      lam:     scalar or (B,) per-problem regularization.
      weights: optional (B, n) per-problem sample weights (binary row
               masks = the K-fold CV trick, DESIGN.md §8; disables the
               Thm-2 sequential ball exactly like the fused subsystem).
      screen_fn: custom batched screening backend (e.g. the sharded
               collective from ``repro.distributed.saif_sharded``).

    Returns a :class:`~repro.core.saif.SaifResult` whose every field has a
    leading problem axis. The whole fleet runs in ONE ``_saif_batch_jit``
    compilation (plus the rare elastic-capacity recompile, exactly like
    the serial driver); supports and coefficients are bitwise those of B
    serial :func:`~repro.core.saif.saif` calls.
    """
    if config.unpen_idx is not None:
        raise NotImplementedError(
            "saif_batch solves plain-LASSO fleets; the fused unpenalized "
            "slot is serial-only for now (DESIGN.md §8)")
    prep = prepare_fleet(X, Y, config, weights=weights)
    X, Y, W = prep.X, prep.Y, prep.W
    n, p = X.shape
    b = Y.shape[0]
    lam_arr = jnp.broadcast_to(
        jnp.asarray(lam, X.dtype).reshape(-1), (b,))
    lams = [float(v) for v in jax.device_get(lam_arr)]
    use_seq = config.use_seq_ball and W is None
    backend = resolve_batch_screen(config.screen_backend)

    hs, h = fleet_batch_sizes(prep, lams, config)
    h_tilde = jnp.asarray(
        [max(int(math.ceil(config.zeta * h_b)), 1) for h_b in hs],
        jnp.int32)
    h_cap = jnp.asarray(hs, jnp.int32)
    k_max = config.k_max or default_capacity(h, p)
    delta0 = jnp.asarray(_delta0s(prep, lams, config), X.dtype)
    W_arg = W if W is not None else jnp.zeros((1, 1), X.dtype)

    # cold start computed ONCE at the original capacity: like the serial
    # driver, elastic growth pads the buffers but keeps the original
    # (possibly capacity-truncated) initial support, so a re-entered fleet
    # reproduces the serial overflow-recovery trajectories bitwise
    init_idx, init_beta, init_mask = initial_support_batch(
        prep.c0, hs, k_max, p, X.dtype)
    while True:
        pad = k_max - init_idx.shape[1]
        if pad > 0:
            init_idx = jnp.pad(init_idx, ((0, 0), (0, pad)))
            init_beta = jnp.pad(init_beta, ((0, 0), (0, pad)))
            init_mask = jnp.pad(init_mask, ((0, 0), (0, pad)))
        inner = resolve_batch_inner(config, n, k_max, b)
        carry = cold_inner_carry_batch(b, k_max, X.dtype, backend=inner)
        # the fleet dispatch routes through the fault-injection seam
        # (repro.runtime.inject) — a single None-check when disarmed
        res = _fault_seam("fleet", lambda: _saif_batch_jit(
            X, Y, W_arg, prep.col_norm, prep.c0, lam_arr,
            jnp.full((b,), config.eps, X.dtype), delta0,
            init_idx, init_beta, init_mask,
            carry.G, carry.rho, carry.gidx, h_tilde, h_cap,
            loss_name=config.loss, h=h, k_max=k_max,
            inner_epochs=config.inner_epochs,
            polish_factor=config.polish_factor,
            max_outer=config.max_outer, use_seq_ball=use_seq,
            screen_backend=backend, inner_backend=inner,
            has_weights=W is not None, screen_fn=screen_fn))
        # ONE host sync for the whole fleet's overflow flags; elastic
        # growth re-enters cold at doubled capacity (per-problem results
        # are capacity-invariant, so non-overflowing problems reproduce
        # their previous answers bitwise)
        if not bool(jnp.any(res.overflowed)) or k_max >= p:
            return res
        k_max = min(2 * k_max, p)


def saif_batch(X, Y, lam, config: SaifConfig = SaifConfig(),
               weights=None,
               screen_fn: Optional[BatchScreenFn] = None) -> SaifResult:
    """DEPRECATED legacy frontend — one-shot session over
    :func:`fleet_solve`.

    Use ``repro.open_session(Problem(X), config).solve(Fleet(Y, lams))``;
    a held-open session keeps the fleet compilation alive across request
    streams (DESIGN.md §9).
    """
    from repro.core._compat import warn_deprecated
    warn_deprecated("repro.core.saif_batch",
                    "session.solve(Fleet(Y, lams))")
    from repro.core.api import Fleet, Problem, open_session

    sess = open_session(Problem(X=X, loss=config.loss), config)
    return sess.solve(Fleet(Y=Y, lams=lam, weights=weights,
                            screen_fn=screen_fn))
