"""Batch-polymorphic SAIF: one compilation solving a fleet of B problems.

Real traffic arrives as *fleets* of related solves — many responses over a
shared design, K-fold cross-validation over a lambda grid (the glmnet-style
workload; see Fercoq et al.'s CV protocol). The serial engine
(``core/saif.py``) prices Theorem 5's economics — a tiny active block plus
one O(p) scan — per problem; this module re-prices them per *fleet*:

  * **one compilation** — ``_saif_batch_jit`` is a single hand-batched
    ``lax.while_loop`` whose every state leaf carries a leading problem
    axis B. One XLA program drives B lockstep solves; the compile counter
    (``saif_jit_compile_count``) must move by exactly 1 per fleet.
  * **amortized fixed costs + shared scans** — the fleet pays ONE host
    driver, ONE preprocessing pass, ONE dispatch and ONE set of device
    syncs where B serial calls pay B of each (the dominant term for
    serving-sized solves), and the screening stage is pluggable per fleet:
    the default keeps per-problem serial scans (bitwise, and skipped per
    problem outside its ADD phase), while the opt-in ``matmul`` shared-X
    path and the problem-gridded Pallas kernels read the O(n p) design
    once per outer step for the entire fleet.
  * **per-problem masks, not a barrier** — ``lam``/``eps``/``h_cap``/
    ``h~``/``delta`` are traced (B,) vectors; convergence, the ADD ramp
    and capacity overflow are all per-problem. A finished problem is
    *frozen*: its state is select-masked, its inner burst runs zero
    epochs, and it never forces extra work on stragglers. This is why the
    loop is hand-batched — ``vmap`` over the serial while_loop would
    re-run every problem's full body until the whole fleet converges and
    could not give per-problem burst budgets.

The batching discipline (DESIGN.md §8): every float path of the default
configuration — bursts, dual points, gaps, balls, DEL certificates, the
screening scans, even the c0 preprocessing — runs as a ``lax.map`` of the
*literal serial code* over the fleet, under per-problem liveness conds.
Batch-dim float contractions provably re-associate on XLA:CPU (a batched
dot is not bitwise the serial dot, and near an ADD-stop boundary an ulp
flips a decision), so mapping the serial bodies is what makes fleet
supports, coefficients, gaps and traces byte-for-byte those of B serial
solves — asserted across every screen x inner backend combination in
``tests/test_batch_parity.py``. The explicitly opt-in deviations are the
``matmul`` screen and the sharded collective, which trade ulp-grade score
equality for fleet-shared memory traffic.

Frontends: :func:`saif_batch` (B responses, one X, per-problem lambdas)
here; :func:`repro.core.cv.cv_path` (K-fold CV fleets via the
sample-weight trick); ``repro.distributed.saif_sharded.
saif_batch_distributed`` (the §5 collective serving all B problems per
wire round). DESIGN.md §8 documents the layer.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import active_set as aset_lib
from repro.core.cm import soft_threshold
from repro.core.duality import (gap_ball, gap_precision_floor,
                                intersect_balls, mixed_precision_gamma,
                                sequential_ball, widened_radius)
from repro.core.inner_backend import (InnerCarry, _dual_and_gap,
                                      cold_inner_carry_batch,
                                      make_batch_inner)
from repro.core.losses import get_loss
from repro.core.saif import (SaifConfig, SaifResult, add_batch_size_static,
                             default_capacity)
from repro.core.screen_backend import (SCREEN_RULES, BatchScreenFn,
                                       ScreenOut, ScreenRule,
                                       make_batch_screen,
                                       make_batch_screen_fast,
                                       resolve_batch_screen,
                                       resolve_screen_rule)
from repro.runtime.inject import seam as _fault_seam


class _BatchState(NamedTuple):
    aset: aset_lib.ActiveSet   # every field with leading problem axis B
    z: jax.Array        # (B, n)
    gap: jax.Array      # (B,)
    delta: jax.Array    # (B,)
    is_add: jax.Array   # (B,) bool
    stop: jax.Array     # (B,) bool
    t: jax.Array        # (B,) int32 per-problem outer counters
    inner: InnerCarry   # batched inner carry
    trace_n_active: jax.Array   # (B, max_outer)
    trace_gap: jax.Array
    trace_dual: jax.Array
    trace_screened: jax.Array   # (B, max_outer) int32 observability (ISSUE 9)
    trace_survivors: jax.Array
    trace_post_viol: jax.Array


def _freeze_select(live: jax.Array, old, new):
    """Per-problem state freeze: keep ``old`` wherever ``live`` is False."""
    def sel(o, n):
        m = live.reshape(live.shape + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)
    return jax.tree.map(sel, old, new)


def _n_surv32_batch(out: ScreenOut, b: int) -> jax.Array:
    """(B,) int32 survivor counts; ``None`` (legacy custom BatchScreenFns)
    reads as 0, matching the serial engine's normalization."""
    ns = out.n_surv
    if ns is None:
        return jnp.zeros((b,), jnp.int32)
    return jnp.broadcast_to(ns.astype(jnp.int32), (b,))


@partial(jax.jit, static_argnames=("loss_name", "h", "k_max",
                                   "inner_epochs", "polish_factor",
                                   "max_outer", "use_seq_ball",
                                   "screen_backend", "inner_backend",
                                   "has_weights", "screen_fn",
                                   "screen_rule"))
def _saif_batch_jit(X, Y, W, col_norm, c0, lam, eps, delta0, init_idx,
                    init_beta, init_mask, init_G, init_rho, init_gidx,
                    h_tilde, h_cap, pad_mask=None,
                    *, loss_name: str, h: int, k_max: int,
                    inner_epochs: int, polish_factor: int, max_outer: int,
                    use_seq_ball: bool, screen_backend: str = "jnp",
                    inner_backend: str = "jnp", has_weights: bool = False,
                    screen_fn: Optional[BatchScreenFn] = None,
                    screen_rule: ScreenRule = SCREEN_RULES["saif"]
                    ) -> SaifResult:
    """The fleet while_loop. Mirrors ``_saif_jit`` body-for-body with a
    leading problem axis; see the module docstring for the batching rules.
    ``lam``/``eps``/``delta0``/``h_tilde``/``h_cap`` are (B,) traced
    vectors, ``col_norm``/``c0`` fleet (B, p) matrices, ``W`` the sample
    weights ((B, n); a (1, 1) placeholder when ``has_weights`` is False).
    Returns a :class:`SaifResult` whose every field has a leading B.
    """
    loss = get_loss(loss_name)
    n, p = X.shape
    b = Y.shape[0]
    barange = jnp.arange(b)
    lam = jnp.asarray(lam, X.dtype)
    weights = W if has_weights else None
    if screen_fn is not None:
        screen = screen_fn
    else:
        screen = make_batch_screen(screen_backend, X, col_norm, h)
    inner = make_batch_inner(inner_backend, loss, X, Y, col_norm, h,
                             weights=weights)

    aset0 = aset_lib.init_active_set_batch(p, k_max, init_idx, X.dtype,
                                           init_beta, live_mask=init_mask)
    if pad_mask is not None:
        # bucket-pad columns are born "already active" in every problem
        # (traced, shared across the compile bucket) — never recruited,
        # never scored; see the serial engine's identical guard
        aset0 = aset0._replace(in_active=aset0.in_active | pad_mask[None, :])
    carry_in = InnerCarry(G=init_G, rho=init_rho, gidx=init_gidx)
    inner0 = inner.init(aset0, carry_in,
                        aset_lib.gather_columns_batch(X, aset0))
    trace0 = jnp.full((b, max_outer), -1.0, X.dtype)
    itrace0 = jnp.full((b, max_outer), -1, jnp.int32)
    state0 = _BatchState(
        aset=aset0, z=jnp.zeros_like(Y),
        gap=jnp.full((b,), jnp.inf, X.dtype),
        delta=jnp.asarray(delta0, X.dtype),
        is_add=jnp.ones((b,), bool), stop=jnp.zeros((b,), bool),
        t=jnp.zeros((b,), jnp.int32), inner=inner0,
        trace_n_active=trace0, trace_gap=trace0, trace_dual=trace0,
        trace_screened=itrace0, trace_survivors=itrace0,
        trace_post_viol=itrace0)
    # per-problem serial Newton polish (hybrid rule): rides inside the
    # map-fused live branch so each problem's arithmetic is the literal
    # serial newton_step — the parity contract extends to the hybrid rule
    newton = (screen_rule.newton_polish and inner_backend == "gram"
              and loss_name == "least_squares")

    def cond(s: _BatchState):
        return jnp.any(~s.stop & (s.t < max_outer))

    def _newton_one(carry_b, mask_b, Xa_b, y_b, w_b, lam_b, args):
        """The serial engine's working-set Newton step for one problem
        (core/saif.py body, DESIGN.md §13): solve on the CM iterate's
        support, accept only if the official gap certifies improvement."""
        beta_c, z_c, theta_c_, gap_c = args
        G, rho = carry_b.G, carry_b.rho
        m = mask_b & (beta_c != 0.0)
        sgn = jnp.sign(beta_c)
        mf = m.astype(X.dtype)
        Gm = G * (mf[:, None] * mf[None, :]) + jnp.diag(1.0 - mf)
        rhs = (rho - lam_b * sgn) * mf
        b_n = jnp.where(m, jnp.linalg.solve(Gm, rhs), 0.0)
        z_n = Xa_b @ b_n
        if w_b is None:
            th_n, gap_n = _dual_and_gap(loss, Xa_b, y_b, b_n, z_n, m,
                                        lam_b)
        else:
            th_n, gap_n = _dual_and_gap(loss, Xa_b, y_b, b_n, z_n, m,
                                        lam_b, sample_w=w_b)
        gap_n = jnp.asarray(gap_n, X.dtype)
        better = gap_n < gap_c          # NaN/garbage reads False
        return (jnp.where(better, b_n, beta_c),
                jnp.where(better, z_n, z_c),
                jnp.where(better, th_n, theta_c_),
                jnp.where(better, gap_n, gap_c))

    def _certify(y_b, w_b, theta_b, gap_b, lam_b, eps_b, delta_b,
                 is_add_b, Xa_b, idx_b, mask_b, cn_b, c0_b):
        """Serial ball / stop / DEL certificates for one problem — the
        exact serial body arithmetic (module docstring: batch-dim
        reductions re-associate, serial maps don't)."""
        ball = gap_ball(loss, theta_b, gap_b, lam_b,
                        floor=gap_precision_floor(theta_b, lam_b))
        if use_seq_ball:
            c0_active = jnp.where(mask_b, jnp.take(c0_b, idx_b), -jnp.inf)
            lam0t = jnp.maximum(jnp.max(c0_active), lam_b * (1 + 1e-12))
            g0_b = loss.grad(jnp.zeros_like(y_b), y_b)
            theta0t = -g0_b / lam0t
            b_seq = sequential_ball(loss, y_b, theta0t, lam0t, lam_b)
            ball = intersect_balls(b_seq, ball)
        stop_now_b = (~is_add_b) & (gap_b <= eps_b)
        corr_act = jnp.abs(Xa_b.T @ ball.center)
        norm_act = jnp.where(mask_b, jnp.take(cn_b, idx_b), 0.0)
        del_row = mask_b & (corr_act + norm_act * ball.radius < 1.0)
        conj = loss.conj(-lam_b * theta_b, y_b)
        if w_b is not None:
            conj = w_b * conj
        dual_val = -jnp.sum(conj)
        if screen_rule.add_bound == "point":
            # strong-rule ADD geometry (DESIGN.md §13): radius 0
            r_eff_b = jnp.zeros_like(ball.radius)
        else:
            r_eff_b = delta_b * ball.radius
        return (ball.center, r_eff_b, stop_now_b, del_row,
                dual_val, ball.radius)

    def body(s: _BatchState) -> _BatchState:
        live = ~s.stop & (s.t < max_outer)       # (B,) frozen problems coast
        aset = s.aset
        n_ep = jnp.where(s.is_add, inner_epochs,
                         inner_epochs * polish_factor)
        n_ep = jnp.where(live, n_ep, 0).astype(jnp.int32)

        if inner.make_one is not None:
            # --- map-fused path: ONE lax.map owns gather + refresh +
            # burst + certificates per problem, and a per-problem liveness
            # cond skips the whole body — a frozen problem costs nothing.
            def solve_one(args):
                if has_weights:
                    (live_b, y_b, w_b, lam_b, eps_b, nep_b, delta_b,
                     is_add_b, z_b, gap_b, carry_b, aset_b, cn_b,
                     c0_b) = args
                else:
                    (live_b, y_b, lam_b, eps_b, nep_b, delta_b,
                     is_add_b, z_b, gap_b, carry_b, aset_b, cn_b,
                     c0_b) = args
                    w_b = None

                def live_branch(_):
                    Xa_b = aset_lib.gather_columns(X, aset_b)
                    be = inner.make_one(y_b, w_b)
                    carry2 = be.refresh(carry_b, aset_b, Xa_b)
                    out = be.run(carry2, aset_b, Xa_b, lam_b, nep_b)
                    beta_b = out.beta
                    zo_b = out.z
                    theta_b = out.theta
                    gapo_b = jnp.asarray(out.gap, X.dtype)
                    if newton:
                        beta_b, zo_b, theta_b, gapo_b = jax.lax.cond(
                            ~is_add_b,
                            lambda a: _newton_one(carry2, aset_b.mask,
                                                  Xa_b, y_b, w_b, lam_b,
                                                  a),
                            lambda a: a, (beta_b, zo_b, theta_b, gapo_b))
                    cert = _certify(y_b, w_b, theta_b, gapo_b, lam_b,
                                    eps_b, delta_b, is_add_b, Xa_b,
                                    aset_b.idx, aset_b.mask, cn_b, c0_b)
                    return (beta_b, zo_b, gapo_b, carry2) + cert

                def frozen_branch(_):
                    k = aset_b.beta.shape[0]
                    return (aset_b.beta, z_b, gap_b, carry_b,
                            jnp.zeros_like(z_b),
                            jnp.zeros((), X.dtype),
                            jnp.asarray(True),
                            jnp.zeros((k,), bool),
                            jnp.zeros((), X.dtype),
                            jnp.zeros((), X.dtype))

                return jax.lax.cond(live_b, live_branch, frozen_branch,
                                    None)

            xs = (live, Y, lam, eps, n_ep, s.delta, s.is_add, s.z, s.gap,
                  s.inner, aset, col_norm, c0)
            if has_weights:
                xs = (live, Y, weights) + xs[2:]
            (beta, z, gap, inner_carry, theta_c, r_eff, stop_now, del_row,
             dual_val, r_del) = jax.lax.map(solve_one, xs)
        else:
            # --- fleet-step path (the pallas problem-gridded kernel): the
            # backend owns the whole fleet's bursts in one launch, then
            # the per-problem certificate map runs (liveness-gated,
            # gathering each live problem's block like the serial body).
            out, inner_carry = inner.fleet_step(s.inner, aset, lam, n_ep)
            beta = jnp.where(live[:, None], out.beta, aset.beta)
            z = jnp.where(live[:, None], out.z, s.z)
            gap = jnp.where(live, jnp.asarray(out.gap, X.dtype), s.gap)
            theta = out.theta

            def certify_one(args):
                if has_weights:
                    (live_b, y_b, w_b, theta_b, gap_b, lam_b, eps_b,
                     delta_b, is_add_b, aset_b, cn_b, c0_b) = args
                else:
                    (live_b, y_b, theta_b, gap_b, lam_b, eps_b, delta_b,
                     is_add_b, aset_b, cn_b, c0_b) = args
                    w_b = None

                def live_branch(_):
                    Xa_b = aset_lib.gather_columns(X, aset_b)
                    return _certify(y_b, w_b, theta_b, gap_b, lam_b,
                                    eps_b, delta_b, is_add_b, Xa_b,
                                    aset_b.idx, aset_b.mask, cn_b, c0_b)

                def frozen_branch(_):
                    k = aset_b.mask.shape[0]
                    return (jnp.zeros_like(theta_b),
                            jnp.zeros((), X.dtype), jnp.asarray(True),
                            jnp.zeros((k,), bool), jnp.zeros((), X.dtype),
                            jnp.zeros((), X.dtype))

                return jax.lax.cond(live_b, live_branch, frozen_branch,
                                    None)

            xs = (live, Y, theta, gap, lam, eps, s.delta, s.is_add,
                  aset, col_norm, c0)
            if has_weights:
                xs = (live, Y, weights) + xs[2:]
            (theta_c, r_eff, stop_now, del_row, dual_val,
             r_del) = jax.lax.map(certify_one, xs)

        aset = aset._replace(beta=beta)

        # --- DEL (per-problem gap-safe rule) ------------------------------
        deleting = live & ~stop_now
        del_mask = del_row & deleting[:, None]
        aset = aset_lib.delete_features_batch(aset, del_mask)

        # --- ADD phase (skipped fleet-wide once every problem is done) ----
        if screen_rule.add_bound == "point":
            # point screens run on EVERY non-stopping step (see the serial
            # engine: a straggler recruited mid-convergence saves a full
            # re-convergence after the post-check)
            do_add = live & ~stop_now
        else:
            do_add = live & s.is_add & ~stop_now

        def do_add_phase(args):
            aset, delta, is_add = args
            out: ScreenOut = screen(theta_c, r_eff, aset.in_active, do_add)
            add_done = out.max_ub < 1.0                       # (B,)
            n_sur_scr = _n_surv32_batch(out, b)
            n_scr_scr = (jnp.sum(~aset.in_active, axis=1).astype(jnp.int32)
                         - n_sur_scr)
            ranks = jnp.arange(h)
            v_count = jnp.maximum(out.cand_ge - 1 - ranks[None, :], 0)
            keep = ((v_count < h_tilde[:, None]) &
                    (ranks[None, :] < h_cap[:, None]) &
                    jnp.isfinite(out.cand_score))
            if screen_rule.add_bound == "point":
                # strong-rule recruiting: only actual KKT violators
                keep = keep & (out.cand_score >= 1.0)
            keep = jnp.cumprod(keep.astype(jnp.int32), axis=1).astype(bool)
            # progress guarantee, per problem (DESIGN.md §2)
            stuck = gap <= 100.0 * eps
            keep = keep.at[:, 0].set(
                keep[:, 0] | (stuck & jnp.isfinite(out.cand_score[:, 0])))
            adding = do_add & ~add_done
            aset = aset_lib.add_features_batch(aset, out.cand_idx,
                                               keep & adding[:, None])
            done = do_add & add_done
            if screen_rule.delta_ramp:
                grown = jnp.minimum(10.0 * delta, 1.0)
                new_delta = jnp.where(done & (delta < 1.0), grown, delta)
                new_is_add = jnp.where(done & (delta >= 1.0), False,
                                       is_add)
            else:
                new_delta = delta
                new_is_add = jnp.where(done, False, is_add)
            return (aset, new_delta, new_is_add,
                    jnp.where(do_add, n_scr_scr, -1),
                    jnp.where(do_add, n_sur_scr, -1))

        neg1 = jnp.full((b,), -1, jnp.int32)
        aset, delta, is_add, n_scr, n_sur = jax.lax.cond(
            jnp.any(do_add), do_add_phase,
            lambda a: a + (neg1, neg1),
            (aset, s.delta, s.is_add))

        # --- safe post-check (hybrid rule, DESIGN.md §13) -----------------
        # one full screen at the unshrunk safe radius gates every stop;
        # violators deny the stop and are recruited (the safe fallback) —
        # the serial engine's check, batched per problem
        if screen_rule.post_check:
            do_check = live & stop_now

            def check(a):
                chk: ScreenOut = screen(theta_c, r_del, a.in_active,
                                        do_check)
                viol = do_check & (chk.max_ub >= 1.0)         # (B,)
                ub_c = (chk.cand_score +
                        jnp.take_along_axis(col_norm, chk.cand_idx, axis=1)
                        * r_del[:, None])
                keep = (viol[:, None] & jnp.isfinite(chk.cand_score) &
                        (ub_c >= 1.0))
                keep = keep.at[:, 0].set(
                    viol & jnp.isfinite(chk.cand_score[:, 0]))
                return (aset_lib.add_features_batch(a, chk.cand_idx, keep),
                        jnp.where(do_check, viol.astype(jnp.int32), -1))

            def no_check(a):
                return a, neg1

            aset, post_viol = jax.lax.cond(jnp.any(do_check), check,
                                           no_check, aset)
            stop_final = stop_now & (post_viol != 1)
        else:
            post_viol = neg1
            stop_final = stop_now

        n_act = aset.count.astype(X.dtype)
        new = _BatchState(
            aset=aset, z=z, gap=gap, delta=delta, is_add=is_add,
            stop=stop_final, t=s.t + 1, inner=inner_carry,
            trace_n_active=s.trace_n_active.at[barange, s.t].set(
                n_act, mode="drop"),
            trace_gap=s.trace_gap.at[barange, s.t].set(gap, mode="drop"),
            trace_dual=s.trace_dual.at[barange, s.t].set(
                dual_val, mode="drop"),
            trace_screened=s.trace_screened.at[barange, s.t].set(
                n_scr, mode="drop"),
            trace_survivors=s.trace_survivors.at[barange, s.t].set(
                n_sur, mode="drop"),
            trace_post_viol=s.trace_post_viol.at[barange, s.t].set(
                post_viol, mode="drop"))
        return _freeze_select(live, s, new)

    final = jax.lax.while_loop(cond, body, state0)
    beta_full = aset_lib.scatter_beta_batch(final.aset, p)
    return SaifResult(beta=beta_full, gap=final.gap, n_outer=final.t,
                      n_active=final.aset.count,
                      overflowed=final.aset.overflowed,
                      trace_n_active=final.trace_n_active,
                      trace_gap=final.trace_gap,
                      trace_dual=final.trace_dual,
                      active_idx=final.aset.idx,
                      active_mask=final.aset.mask,
                      inner=final.inner,
                      trace_screened=final.trace_screened,
                      trace_survivors=final.trace_survivors,
                      trace_post_viol=final.trace_post_viol)


# ---------------------------------------------------------------------------
# fast-parity fleet engine (parity="fast", DESIGN.md §11)
# ---------------------------------------------------------------------------
# The bitwise engine above buys byte-for-byte serial equality by running
# every per-problem float path as a lax.map of the literal serial code —
# which is a scan, so the fleet's per-problem work is SEQUENTIAL and the
# speedup ceiling is the amortized fixed costs (~2.6x measured). The fast
# engine is the opt-in other half of the trade: batch-axis einsums for
# bursts/certificates, a lockstep CM sweep over a STATIC slot order
# (dynamic_slice on batch-leading arrays — no per-problem gathers in the
# inner loop, the measured ~30x XLA:CPU gather trap that killed the PR 4
# lockstep attempt), and the one-gemm-per-step screen, optionally in
# reduced precision with a certified rounding-error widening of the safe
# radius (screen_backend.make_batch_screen_fast). What it may re-associate
# and what it may never skip is the §11 parity contract; acceptance is
# supports + gap <= eps + a passing working-precision KKT residual, not
# bitwise trajectories. Least-squares fleets only — other losses fall
# back to the bitwise engine (fleet_solve dispatch).


def _delete_features_fast(aset, drop):
    """Batched DEL without ``order`` maintenance.

    The fast engine's sweep visits a static slot range (``hi`` in
    :func:`_gram_sweep_fast`) instead of the serial engine's compacted
    ``order[:count]``, so the order permutation is dead weight here —
    skipping its cumsum/scatter upkeep trims the while_loop body, which
    on XLA:CPU is billed per op. Slot placement is unaffected:
    :func:`repro.core.active_set.add_features` ranks free slots by slot
    id, never through ``order``."""
    p = aset.in_active.shape[1]
    drop = drop & aset.mask
    new_mask = aset.mask & ~drop
    new_beta = jnp.where(drop, 0.0, aset.beta)
    write_idx = jnp.where(drop, aset.idx, p)
    bar = jnp.arange(aset.idx.shape[0])[:, None]
    new_in_active = aset.in_active.at[bar, write_idx].set(
        False, mode="drop")
    return aset._replace(mask=new_mask, beta=new_beta,
                         in_active=new_in_active,
                         count=aset.count -
                         jnp.sum(drop, axis=1).astype(jnp.int32))


def _add_features_fast(aset, cand_idx, cand_keep):
    """Batched ADD without ``order`` maintenance (see
    :func:`_delete_features_fast`). Same slot arithmetic as the serial
    :func:`repro.core.active_set.add_features` — kept candidates fill
    the lowest free slots — minus the compact_order call."""
    b, k_max = aset.mask.shape
    p = aset.in_active.shape[1]
    free = ~aset.mask
    free_i = free.astype(jnp.int32)
    free_rank = jnp.cumsum(free_i, axis=1) - free_i
    n_free = jnp.sum(free_i, axis=1)
    keep_i = cand_keep.astype(jnp.int32)
    cand_rank = jnp.cumsum(keep_i, axis=1) - keep_i
    n_want = jnp.sum(keep_i, axis=1)
    placed = cand_keep & (cand_rank < n_free[:, None])
    big = jnp.asarray(k_max + 1, jnp.int32)
    order_key = jnp.where(free, free_rank, big)
    slot_of_rank = jnp.argsort(order_key, axis=1)
    target_slot = jnp.take_along_axis(
        slot_of_rank, jnp.clip(cand_rank, 0, k_max - 1), axis=1)
    target_slot = jnp.where(placed, target_slot, k_max)
    bar = jnp.arange(b)[:, None]
    new_idx = aset.idx.at[bar, target_slot].set(cand_idx, mode="drop")
    new_mask = aset.mask.at[bar, target_slot].set(True, mode="drop")
    new_beta = aset.beta.at[bar, target_slot].set(0.0, mode="drop")
    new_in_active = aset.in_active.at[
        bar, jnp.where(placed, cand_idx, p)].set(True, mode="drop")
    return aset._replace(idx=new_idx, mask=new_mask, beta=new_beta,
                         in_active=new_in_active,
                         overflowed=aset.overflowed | (n_want > n_free),
                         count=aset.count +
                         jnp.sum(placed, axis=1).astype(jnp.int32))


def _gram_rebuild_fast(X, Y, weights, aset):
    """Full batched Gram build at fleet start: G = Xa^T diag(w) Xa,
    rho = Xa^T diag(w) y, per problem via batch-axis einsums."""
    Xa = aset_lib.gather_columns_batch(X, aset)          # (B, n, k)
    Xw = Xa if weights is None else Xa * weights[:, :, None]
    G = jnp.einsum("bnk,bnl->bkl", Xw, Xa)
    rho = jnp.einsum("bnk,bn->bk", Xw, Y)
    gidx = jnp.where(aset.mask, aset.idx, -1)
    return InnerCarry(G=G, rho=rho, gidx=gidx), Xa


def _gram_refresh_fast(X, Y, weights, carry, aset, Xa, h):
    """Per-step batched Gram reconcile: at most ``h`` slots per problem
    changed feature since the last step (the ADD batch); their rows /
    columns / rho entries are recomputed from ``h`` gathered columns.
    Branchless (a problem with nothing dirty scatters into the dropped
    fill slot); dead slots keep stale entries — their beta is masked to
    zero so the sweep never reads them through a live term."""
    kc = aset.idx.shape[1]
    hs = min(h, kc)

    # Xa is already gathered this step — each problem's block rides along
    def one_with_xa(G, rho, gidx, idx_b, mask_b, y_b, Xa_b, w_b):
        gidx = jnp.where(mask_b, gidx, -1)
        dirty = mask_b & (gidx != idx_b)
        slots = jnp.nonzero(dirty, size=hs, fill_value=kc)[0]
        ids = jnp.take(idx_b, jnp.minimum(slots, kc - 1))
        cols = jnp.take(X, ids, axis=1)                  # (n, hs)
        cols_w = cols if w_b is None else cols * w_b[:, None]
        Gblk = Xa_b.T @ cols_w                           # (k, hs)
        G = G.at[:, slots].set(Gblk, mode="drop")
        G = G.at[slots, :].set(Gblk.T, mode="drop")
        rho = rho.at[slots].set(cols_w.T @ y_b, mode="drop")
        return G, rho, jnp.where(mask_b, idx_b, -1)

    if weights is None:
        G, rho, gidx = jax.vmap(
            lambda G, rho, gidx, idx_b, mask_b, y_b, Xa_b:
            one_with_xa(G, rho, gidx, idx_b, mask_b, y_b, Xa_b, None))(
            carry.G, carry.rho, carry.gidx, aset.idx, aset.mask, Y, Xa)
    else:
        G, rho, gidx = jax.vmap(one_with_xa)(
            carry.G, carry.rho, carry.gidx, aset.idx, aset.mask, Y, Xa,
            weights)
    return InnerCarry(G=G, rho=rho, gidx=gidx)


def _gram_sweep_fast(G, rho, beta, mask, lam, n_ep, smoothness=1.0):
    """Lockstep batched CM sweep (least squares, Gram form).

    Every problem steps the SAME static slot j each inner iteration, so
    the per-iteration work is dynamic_slice / dynamic_update_slice on
    batch-leading (B, k) arrays — no batched-index gathers. Dead slots
    are masked to a zero coefficient; problems whose per-problem epoch
    budget ``n_ep[b]`` is exhausted (or that are frozen, budget 0) are
    gated to a no-op so their (beta, qr) carry is exactly preserved.
    Sweeping all k slots instead of the serial engine's compacted
    ``order[:count]`` visits dead slots too — a fast-parity re-ordering
    the §11 contract explicitly allows (a dead slot's step is the
    identity; extra passes only tighten the sub-problem solve).
    """
    k = beta.shape[1]
    diag = jnp.diagonal(G, axis1=1, axis2=2)
    inv_l = 1.0 / jnp.maximum(smoothness * diag, 1e-30)
    thr = lam[:, None] * inv_l
    qr = jnp.einsum("bkl,bl->bk", G, beta) - rho
    max_ep = jnp.max(n_ep)
    # the sweep visits slots [0, hi): everything above the fleet's highest
    # live slot is dead everywhere (adds fill the lowest free slots), so
    # the loop trip count tracks the actual active-set size, not k_max
    hi = jnp.max(jnp.where(mask, jnp.arange(k)[None, :] + 1, 0))

    def slot_step(j, carry, gate):
        beta, qr = carry
        col = lambda a: jax.lax.dynamic_slice_in_dim(a, j, 1, axis=1)[:, 0]
        bj, qrj, ilj, tj, mj = col(beta), col(qr), col(inv_l), col(thr), \
            col(mask)
        val = jnp.where(mj, soft_threshold(bj - qrj * ilj, tj), 0.0)
        b_new = jnp.where(gate, val, bj)
        Gj = jax.lax.dynamic_slice_in_dim(G, j, 1, axis=2)[:, :, 0]
        qr = qr + (b_new - bj)[:, None] * Gj
        beta = jax.lax.dynamic_update_slice_in_dim(
            beta, b_new[:, None], j, axis=1)
        return beta, qr

    # one flat loop (i -> epoch i//hi, slot i%hi) instead of nested
    # fori_loops: the scalar divmod is cheaper than per-epoch loop setup
    def flat_step(i, carry):
        return slot_step(i % hi, carry, (i // hi) < n_ep)

    beta, _ = jax.lax.fori_loop(0, max_ep * hi, flat_step, (beta, qr))
    return beta


@partial(jax.jit, static_argnames=("loss_name", "h", "k_max",
                                   "inner_epochs", "polish_factor",
                                   "max_outer", "use_seq_ball",
                                   "screen_dtype", "has_weights",
                                   "screen_rule"))
def _saif_batch_fast_jit(X, Y, W, col_norm, c0, lam, eps, delta0, init_idx,
                         init_beta, init_mask, h_tilde, h_cap,
                         pad_mask=None, *,
                         loss_name: str, h: int, k_max: int,
                         inner_epochs: int, polish_factor: int,
                         max_outer: int, use_seq_ball: bool,
                         screen_dtype: str = "working",
                         has_weights: bool = False,
                         screen_rule: ScreenRule = SCREEN_RULES["saif"]
                         ) -> SaifResult:
    """The fast-parity fleet while_loop (see the section comment above).

    Same decision structure as ``_saif_batch_jit`` — the same per-problem
    liveness masks, DEL / ADD-stop / delta-ramp / stuck-recruit rules,
    traces and overflow flags — but every stage is genuinely batched, and
    both screening radii (the one-gemm ADD screen and the vmapped DEL
    certificate) are widened by the certified rounding bound of their
    respective compute precisions before any decision is taken.
    """
    loss = get_loss(loss_name)
    n, p = X.shape
    b = Y.shape[0]
    barange = jnp.arange(b)
    lam = jnp.asarray(lam, X.dtype)
    weights = W if has_weights else None
    screen = make_batch_screen_fast(X, col_norm, h,
                                    screen_dtype=screen_dtype)
    # working-precision batched contractions re-associate: the DEL rule's
    # correlations carry the working-dtype gamma widening (tiny — ~3e-6
    # relative at n=50/f32 — but what makes the re-association *certified*
    # rather than hoped-harmless)
    gamma_work = mixed_precision_gamma(n, X.dtype, X.dtype)

    aset0 = aset_lib.init_active_set_batch(p, k_max, init_idx, X.dtype,
                                           init_beta, live_mask=init_mask)
    if pad_mask is not None:
        aset0 = aset0._replace(in_active=aset0.in_active | pad_mask[None, :])
    carry0, _ = _gram_rebuild_fast(X, Y, weights, aset0)
    trace0 = jnp.full((b, max_outer), -1.0, X.dtype)
    itrace0 = jnp.full((b, max_outer), -1, jnp.int32)
    state0 = _BatchState(
        aset=aset0, z=jnp.zeros_like(Y),
        gap=jnp.full((b,), jnp.inf, X.dtype),
        delta=jnp.asarray(delta0, X.dtype),
        is_add=jnp.ones((b,), bool), stop=jnp.zeros((b,), bool),
        t=jnp.zeros((b,), jnp.int32), inner=carry0,
        trace_n_active=trace0, trace_gap=trace0, trace_dual=trace0,
        trace_screened=itrace0, trace_survivors=itrace0,
        trace_post_viol=itrace0)

    def cond(s: _BatchState):
        return jnp.any(~s.stop & (s.t < max_outer))

    def _certify_one(y_b, w_b, theta_b, gap_b, lam_b, eps_b, delta_b,
                     is_add_b, Xa_b, idx_b, mask_b, cn_b, c0_b):
        """Serial certificate arithmetic, vmapped (re-associated) — with
        the DEL radius widened by the working-precision dot bound."""
        ball = gap_ball(loss, theta_b, gap_b, lam_b,
                        floor=gap_precision_floor(theta_b, lam_b))
        if use_seq_ball:
            c0_active = jnp.where(mask_b, jnp.take(c0_b, idx_b), -jnp.inf)
            lam0t = jnp.maximum(jnp.max(c0_active), lam_b * (1 + 1e-12))
            g0_b = loss.grad(jnp.zeros_like(y_b), y_b)
            theta0t = -g0_b / lam0t
            b_seq = sequential_ball(loss, y_b, theta0t, lam0t, lam_b)
            ball = intersect_balls(b_seq, ball)
        stop_now_b = (~is_add_b) & (gap_b <= eps_b)
        corr_act = jnp.abs(Xa_b.T @ ball.center)
        norm_act = jnp.where(mask_b, jnp.take(cn_b, idx_b), 0.0)
        r_del = widened_radius(ball.radius, ball.center, gamma_work)
        del_row = mask_b & (corr_act + norm_act * r_del < 1.0)
        conj = loss.conj(-lam_b * theta_b, y_b)
        if w_b is not None:
            conj = w_b * conj
        dual_val = -jnp.sum(conj)
        if screen_rule.add_bound == "point":
            # strong-rule ADD at radius 0: the mixed-precision screen
            # widens whatever radius it is handed by its own certified
            # rounding bound, so the "point" screen under a reduced dtype
            # is really a gamma*||theta||-ball — still aggressive, still
            # covered by the post-check below
            r_eff_b = jnp.zeros_like(ball.radius)
        else:
            r_eff_b = delta_b * ball.radius
        # the raw safe radius rides along for the post-check screen, which
        # re-applies the dtype-appropriate widening internally
        return (ball.center, r_eff_b, stop_now_b, del_row,
                dual_val, ball.radius)

    if has_weights:
        certify = jax.vmap(_certify_one)
        dual_gap = jax.vmap(
            lambda Xa_b, y_b, beta_b, z_b, mask_b, lam_b, w_b:
            _dual_and_gap(loss, Xa_b, y_b, beta_b, z_b, mask_b, lam_b,
                          sample_w=w_b))
    else:
        certify = jax.vmap(
            lambda *a: _certify_one(a[0], None, *a[1:]))
        dual_gap = jax.vmap(
            lambda Xa_b, y_b, beta_b, z_b, mask_b, lam_b:
            _dual_and_gap(loss, Xa_b, y_b, beta_b, z_b, mask_b, lam_b))

    def body(s: _BatchState) -> _BatchState:
        live = ~s.stop & (s.t < max_outer)
        aset = s.aset
        n_ep = jnp.where(s.is_add, inner_epochs,
                         inner_epochs * polish_factor)
        n_ep = jnp.where(live, n_ep, 0).astype(jnp.int32)

        # --- lockstep inner burst (Gram form; LS-only by dispatch) -------
        Xa = aset_lib.gather_columns_batch(X, aset)      # (B, n, k)
        # polish bodies (post-ADD) mutate nothing but masks, so the
        # h-column Gram reconcile is skipped fleet-wide when no slot is
        # dirty; dead slots still drop their feature id (gidx=-1) so a
        # later re-add of the same feature forces a refresh — its Gram
        # row was zeroed by neighbours' refreshes while the slot was dead
        gidx2 = jnp.where(aset.mask, s.inner.gidx, -1)
        any_dirty = jnp.any(aset.mask & (gidx2 != aset.idx))
        carry2 = jax.lax.cond(
            any_dirty,
            lambda c: _gram_refresh_fast(X, Y, weights, c, aset, Xa, h),
            lambda c: c._replace(gidx=gidx2),
            s.inner)
        beta = _gram_sweep_fast(carry2.G, carry2.rho, aset.beta, aset.mask,
                                lam, n_ep, smoothness=loss.smoothness)
        z = jnp.einsum("bnk,bk->bn", Xa, beta)
        if has_weights:
            theta, gap = dual_gap(Xa, Y, beta, z, aset.mask, lam, weights)
        else:
            theta, gap = dual_gap(Xa, Y, beta, z, aset.mask, lam)
        gap = jnp.asarray(gap, X.dtype)

        # --- fleet Newton polish (hybrid rule, DESIGN.md §13) -------------
        # The lockstep engine already holds the batched working-set normal
        # equations, so the serial engine's Newton step batches as ONE
        # (B, k, k) masked solve. Acceptance stays per problem and is
        # certified by the same (vmapped) official dual/gap the §11
        # contract already trusts — a rejected proposal leaves that
        # problem's CM iterate untouched.
        if screen_rule.newton_polish:
            polishing = live & ~s.is_add

            def newton_fleet(args):
                beta_c, z_c, theta_cc, gap_c = args
                m = aset.mask & (beta_c != 0.0)
                mf = m.astype(X.dtype)
                k = beta_c.shape[1]
                Gm = (carry2.G * (mf[:, :, None] * mf[:, None, :]) +
                      jnp.eye(k, dtype=X.dtype) * (1.0 - mf)[:, :, None])
                rhs = (carry2.rho - lam[:, None] * jnp.sign(beta_c)) * mf
                b_n = jnp.where(
                    m, jnp.linalg.solve(Gm, rhs[..., None])[..., 0], 0.0)
                z_n = jnp.einsum("bnk,bk->bn", Xa, b_n)
                if has_weights:
                    th_n, gap_n = dual_gap(Xa, Y, b_n, z_n, m, lam,
                                           weights)
                else:
                    th_n, gap_n = dual_gap(Xa, Y, b_n, z_n, m, lam)
                gap_n = jnp.asarray(gap_n, X.dtype)
                better = polishing & (gap_n < gap_c)
                return (jnp.where(better[:, None], b_n, beta_c),
                        jnp.where(better[:, None], z_n, z_c),
                        jnp.where(better[:, None], th_n, theta_cc),
                        jnp.where(better, gap_n, gap_c))

            beta, z, theta, gap = jax.lax.cond(
                jnp.any(polishing), newton_fleet, lambda a: a,
                (beta, z, theta, gap))

        if has_weights:
            (theta_c, r_eff, stop_now, del_row, dual_val,
             r_del_raw) = certify(
                Y, weights, theta, gap, lam, eps, s.delta, s.is_add, Xa,
                aset.idx, aset.mask, col_norm, c0)
        else:
            (theta_c, r_eff, stop_now, del_row, dual_val,
             r_del_raw) = certify(
                Y, theta, gap, lam, eps, s.delta, s.is_add, Xa,
                aset.idx, aset.mask, col_norm, c0)

        aset = aset._replace(beta=beta)

        # --- DEL (per-problem widened gap-safe rule) ----------------------
        deleting = live & ~stop_now
        del_mask = del_row & deleting[:, None]
        aset = _delete_features_fast(aset, del_mask)

        # --- ADD phase (skipped fleet-wide once every problem is done) ----
        if screen_rule.add_bound == "point":
            do_add = live & ~stop_now
        else:
            do_add = live & s.is_add & ~stop_now

        def do_add_phase(args):
            aset, delta, is_add = args
            out: ScreenOut = screen(theta_c, r_eff, aset.in_active, do_add)
            add_done = out.max_ub < 1.0                  # (B,)
            n_sur_scr = _n_surv32_batch(out, b)
            n_scr_scr = (jnp.sum(~aset.in_active, axis=1).astype(jnp.int32)
                         - n_sur_scr)
            ranks = jnp.arange(h)
            v_count = jnp.maximum(out.cand_ge - 1 - ranks[None, :], 0)
            keep = ((v_count < h_tilde[:, None]) &
                    (ranks[None, :] < h_cap[:, None]) &
                    jnp.isfinite(out.cand_score))
            if screen_rule.add_bound == "point":
                keep = keep & (out.cand_score >= 1.0)
            keep = jnp.cumprod(keep.astype(jnp.int32), axis=1).astype(bool)
            stuck = gap <= 100.0 * eps
            keep = keep.at[:, 0].set(
                keep[:, 0] | (stuck & jnp.isfinite(out.cand_score[:, 0])))
            adding = do_add & ~add_done
            aset = _add_features_fast(aset, out.cand_idx,
                                      keep & adding[:, None])
            done = do_add & add_done
            if screen_rule.delta_ramp:
                grown = jnp.minimum(10.0 * delta, 1.0)
                new_delta = jnp.where(done & (delta < 1.0), grown, delta)
                new_is_add = jnp.where(done & (delta >= 1.0), False,
                                       is_add)
            else:
                new_delta = delta
                new_is_add = jnp.where(done, False, is_add)
            return (aset, new_delta, new_is_add,
                    jnp.where(do_add, n_scr_scr, -1),
                    jnp.where(do_add, n_sur_scr, -1))

        neg1 = jnp.full((b,), -1, jnp.int32)
        aset, delta, is_add, n_scr, n_sur = jax.lax.cond(
            jnp.any(do_add), do_add_phase,
            lambda a: a + (neg1, neg1),
            (aset, s.delta, s.is_add))

        # --- safe post-check (hybrid rule) --------------------------------
        # the mixed-precision screen re-widens the raw safe radius for its
        # own dtype, so a passing check certifies the exact screen passes
        if screen_rule.post_check:
            do_check = live & stop_now

            def check(a):
                chk: ScreenOut = screen(theta_c, r_del_raw, a.in_active,
                                        do_check)
                viol = do_check & (chk.max_ub >= 1.0)
                ub_c = (chk.cand_score +
                        jnp.take_along_axis(col_norm, chk.cand_idx, axis=1)
                        * r_del_raw[:, None])
                keep = (viol[:, None] & jnp.isfinite(chk.cand_score) &
                        (ub_c >= 1.0))
                keep = keep.at[:, 0].set(
                    viol & jnp.isfinite(chk.cand_score[:, 0]))
                return (_add_features_fast(a, chk.cand_idx, keep),
                        jnp.where(do_check, viol.astype(jnp.int32), -1))

            def no_check(a):
                return a, neg1

            aset, post_viol = jax.lax.cond(jnp.any(do_check), check,
                                           no_check, aset)
            stop_final = stop_now & (post_viol != 1)
        else:
            post_viol = neg1
            stop_final = stop_now

        n_act = aset.count.astype(X.dtype)
        new = _BatchState(
            aset=aset, z=z, gap=gap, delta=delta, is_add=is_add,
            stop=stop_final, t=s.t + 1, inner=carry2,
            trace_n_active=s.trace_n_active.at[barange, s.t].set(
                n_act, mode="drop"),
            trace_gap=s.trace_gap.at[barange, s.t].set(gap, mode="drop"),
            trace_dual=s.trace_dual.at[barange, s.t].set(
                dual_val, mode="drop"),
            trace_screened=s.trace_screened.at[barange, s.t].set(
                n_scr, mode="drop"),
            trace_survivors=s.trace_survivors.at[barange, s.t].set(
                n_sur, mode="drop"),
            trace_post_viol=s.trace_post_viol.at[barange, s.t].set(
                post_viol, mode="drop"))
        return _freeze_select(live, s, new)

    final = jax.lax.while_loop(cond, body, state0)
    beta_full = aset_lib.scatter_beta_batch(final.aset, p)
    return SaifResult(beta=beta_full, gap=final.gap, n_outer=final.t,
                      n_active=final.aset.count,
                      overflowed=final.aset.overflowed,
                      trace_n_active=final.trace_n_active,
                      trace_gap=final.trace_gap,
                      trace_dual=final.trace_dual,
                      active_idx=final.aset.idx,
                      active_mask=final.aset.mask,
                      inner=final.inner,
                      trace_screened=final.trace_screened,
                      trace_survivors=final.trace_survivors,
                      trace_post_viol=final.trace_post_viol)


def saif_batch_compile_count() -> int:
    """Distinct fleet-engine compilations alive in this process (the
    bitwise ``_saif_batch_jit`` cache plus the fast-parity
    ``_saif_batch_fast_jit`` cache)."""
    try:
        return (int(_saif_batch_jit._cache_size()) +
                int(_saif_batch_fast_jit._cache_size()))
    except Exception:       # pragma: no cover - jit internals moved
        return -1


class FleetPrep(NamedTuple):
    """One-time per-fleet preprocessing (one host sync for the h formula).
    ``c0_max`` doubles as the per-problem lambda_max: for the penalized-
    null model, lambda_max = max_i |x_i^T f'(null)| = max(c0) exactly."""
    X: jax.Array            # (n, p) shared design
    Y: jax.Array            # (B, n)
    W: Optional[jax.Array]  # (B, n) sample weights or None
    c0: jax.Array           # (B, p) per-problem |X^T f'(null)|
    col_norm: jax.Array     # (B, p) per-problem column norms
    c0_max: list            # B host floats (= per-problem lambda_max)
    c0_median: list
    # bucket-padded fleets (DESIGN.md §12): X/Y carry trailing zero
    # rows/columns up to a compile-bucket shape while every policy
    # quantity is computed on the real dims. 0 means "use X.shape".
    n_true: int = 0
    p_true: int = 0


@partial(jax.jit, static_argnames=("loss_name", "has_w"))
def _prepare_fleet_fast_jit(X, Y, W, *, loss_name: str, has_w: bool):
    """Device side of fast-parity fleet prep, fused under ONE dispatch:
    c0 as one gemm (the §11 re-association contract), col norms and the
    c0 statistics the host h formula syncs."""
    loss = get_loss(loss_name)
    G0 = loss.grad(jnp.zeros_like(Y), Y)
    if has_w:
        G0 = W * G0
    c0 = jnp.abs(G0 @ X)
    if has_w:
        col_norm = jnp.sqrt(W @ (X * X))
    else:
        col_norm = jnp.broadcast_to(jnp.linalg.norm(X, axis=0), c0.shape)
    # the median only buckets the pow2 h formula (heuristic-grade): its
    # f64 sort is the most expensive op in prep under x64, so fast parity
    # computes it on f32-cast scores. c0 itself, its max (lambda_max /
    # delta0 / seq-ball inputs) and col_norm stay working precision —
    # those feed certificates.
    med = jnp.median(c0.astype(jnp.float32), axis=1).astype(X.dtype)
    return c0, col_norm, jnp.max(c0, axis=1), med


def prepare_fleet(X, Y, config: SaifConfig, weights=None) -> FleetPrep:
    """Per-problem null gradients, c0, column norms + ONE host sync of the
    c0 statistics the (host-side) h formula needs."""
    loss = get_loss(config.loss)
    X = jnp.asarray(X)
    Y = jnp.asarray(Y)
    if Y.ndim == 1:
        Y = Y[None, :]
    W = None if weights is None else jnp.asarray(weights, X.dtype)
    if config.parity == "fast":
        # fast parity re-associates by contract (DESIGN.md §11): the whole
        # fleet's c0 scans are ONE gemm inside one jitted dispatch. c0
        # feeds the pow2-bucketed h formula, the cold-start top-h and the
        # seq-ball lam0t — all ulp-insensitive consumers (a re-associated
        # c0 only matters on an exact score tie or a bucket boundary).
        W_arg = W if W is not None else jnp.zeros((1, 1), X.dtype)
        c0, col_norm, c0_max, c0_med = _prepare_fleet_fast_jit(
            X, Y, W_arg, loss_name=config.loss, has_w=W is not None)
        c0_max, c0_med = jax.device_get((c0_max, c0_med))
        return FleetPrep(X=X, Y=Y, W=W, c0=c0, col_norm=col_norm,
                         c0_max=[float(v) for v in c0_max],
                         c0_median=[float(v) for v in c0_med])
    G0 = loss.grad(jnp.zeros_like(Y), Y)
    if W is not None:
        G0 = W * G0
    # per-problem c0 scans as B EAGER serial matvecs — the literal op
    # the serial driver's null_gradient dispatches, so lambda_max,
    # delta0, the cold-start top-h and the seq-ball lam0t are bitwise
    # per problem (a (B, n) x (n, p) matmul — or even a lax.map'd
    # matvec, which compiles under scan instead of dispatching the
    # eager dot executable — re-associates the reduction at the ulp
    # level; same rule as the §8 screen paths). One-time prep cost,
    # off the hot path.
    c0 = jnp.stack([jnp.abs(X.T @ G0[i]) for i in range(Y.shape[0])])
    if W is None:
        col_norm = jnp.broadcast_to(jnp.linalg.norm(X, axis=0),
                                    c0.shape)
    else:
        col_norm = jnp.sqrt(W @ (X * X))                   # (B, p)
    c0_max, c0_med = jax.device_get(
        (jnp.max(c0, axis=1), jnp.median(c0, axis=1)))
    return FleetPrep(X=X, Y=Y, W=W, c0=c0, col_norm=col_norm,
                     c0_max=[float(v) for v in c0_max],
                     c0_median=[float(v) for v in c0_med])


def pad_fleet_prep(prep: FleetPrep, n_bucket: int,
                   p_bucket: int) -> FleetPrep:
    """Zero-pad a real fleet preparation up to a compile-bucket shape —
    the fleet edition of :func:`repro.core.saif.pad_path_state`
    (DESIGN.md §12): the per-problem stats stay those of the real
    problems (c0 pads at -inf, col-norm pads at 1.0, zero pad rows with
    zero weights), and ``n_true``/``p_true`` feed every policy formula.
    """
    n, p = prep.X.shape
    if n_bucket < n or p_bucket < p:
        raise ValueError(
            f"bucket ({n_bucket}, {p_bucket}) must dominate the fleet "
            f"design shape ({n}, {p})")
    if (n_bucket, p_bucket) == (n, p):
        return prep
    dn, dp = n_bucket - n, p_bucket - p
    return prep._replace(
        X=jnp.pad(prep.X, ((0, dn), (0, dp))),
        Y=jnp.pad(prep.Y, ((0, 0), (0, dn))),
        W=None if prep.W is None else jnp.pad(prep.W, ((0, 0), (0, dn))),
        c0=jnp.pad(prep.c0, ((0, 0), (0, dp)), constant_values=-jnp.inf),
        col_norm=jnp.pad(prep.col_norm, ((0, 0), (0, dp)),
                         constant_values=1.0),
        n_true=n, p_true=p)


def fleet_batch_sizes(prep: FleetPrep, lams, config: SaifConfig):
    """Per-problem h values + the fleet-static maximum (pow2-bucketed by
    ``add_batch_size_static`` already)."""
    p = prep.p_true or prep.X.shape[1]
    hs = [add_batch_size_static(config.c, float(lam), mx, md, p)
          for lam, mx, md in zip(lams, prep.c0_max, prep.c0_median)]
    return hs, (max(hs) if hs else 1)


def initial_support_batch(c0: jax.Array, hs, k_max: int, p: int,
                          dtype=jnp.float32):
    """Batched cold start: per-problem top-h_b features by c0.

    Per-problem counts ride on the static fleet maximum via top_k's prefix
    property (top_k(x, m)[: j] == top_k(x, j) for j <= m, ties to the
    lowest id), so every problem's initial slots are bitwise the serial
    :func:`repro.core.saif.initial_support` layout.
    """
    b = c0.shape[0]
    n_cap = min(max(hs), k_max, p)
    top = jax.lax.top_k(c0, n_cap)[1].astype(jnp.int32)    # (B, n_cap)
    n_init = jnp.asarray([min(h_b, k_max, p) for h_b in hs], jnp.int32)
    ranks = jnp.arange(k_max)
    init_idx = jnp.zeros((b, k_max), jnp.int32).at[:, :n_cap].set(top)
    mask = ranks[None, :] < n_init[:, None]
    init_idx = jnp.where(mask, init_idx, 0)
    return init_idx, jnp.zeros((b, k_max), dtype), mask


@partial(jax.jit, static_argnames=("hs", "k_max", "p", "dtype",
                                   "sel_dtype"))
def _initial_support_batch_jit(c0, *, hs, k_max: int, p: int, dtype,
                               sel_dtype=None):
    """Jitted :func:`initial_support_batch` (fast-parity dispatch): the
    eager top_k + scatters are ~2.6 ms of host dispatch at the CI fleet
    shape — a third of the whole fast solve. ``hs`` rides as a static
    tuple; results are identical (top_k and the mask arithmetic are
    deterministic, jit or eager).

    ``sel_dtype`` (mixed-precision screens only) runs the cold-start
    top-h *selection* on down-cast scores: under x64 the f64 top_k sort
    is ~60x the f32 one on XLA:CPU, and which features seed the active
    set is heuristic-grade (any seed set is safe; the certificates that
    consume c0 itself — seq-ball lam0t, delta0 — keep the working-
    precision array)."""
    c0_sel = c0 if sel_dtype is None else c0.astype(sel_dtype)
    return initial_support_batch(c0_sel, list(hs), k_max, p, dtype)


def _delta0s(prep: FleetPrep, lams, config: SaifConfig):
    if config.delta0 is not None:
        return [float(config.delta0)] * len(lams)
    return [min(max(float(lam) / mx, 1e-3), 1.0)
            for lam, mx in zip(lams, prep.c0_max)]


def resolve_batch_inner(config: SaifConfig, n: int, k_max: int,
                        b: int) -> str:
    """Fleet inner-backend policy: the serial policy with the
    double-buffered fleet VMEM budget gating the pallas kernel."""
    from repro.kernels.cm.cm import cm_vmem_ok

    name, loss_name = config.inner_backend, config.loss
    from repro.core.inner_backend import GRAM_CROSSOVER
    if name == "auto":
        if loss_name == "least_squares" and GRAM_CROSSOVER * n >= k_max:
            return "gram"
        if jax.default_backend() == "tpu" and cm_vmem_ok(n, k_max, batch=b):
            return "pallas"
        return "jnp"
    if name not in ("jnp", "gram", "pallas"):
        raise ValueError(f"unknown inner backend {name!r}")
    if name == "gram" and loss_name != "least_squares":
        raise ValueError("inner_backend='gram' requires "
                         "loss='least_squares'")
    if name == "pallas" and not cm_vmem_ok(n, k_max, batch=b):
        raise ValueError(
            f"inner_backend='pallas': a fleet of {b} {n}x{k_max} active "
            f"blocks exceeds the double-buffered VMEM budget (DESIGN.md "
            f"§8); shrink k_max or use 'gram'/'jnp'")
    return name


def fleet_solve(X, Y, lam, config: SaifConfig = SaifConfig(),
                weights=None,
                screen_fn: Optional[BatchScreenFn] = None,
                prep: Optional[FleetPrep] = None) -> SaifResult:
    """Solve a fleet of B LASSO problems over a shared design in lockstep.

    Args:
      X:       (n, p) shared design.
      Y:       (B, n) per-problem responses (a (n,) vector is a fleet of 1).
      lam:     scalar or (B,) per-problem regularization.
      weights: optional (B, n) per-problem sample weights (binary row
               masks = the K-fold CV trick, DESIGN.md §8; disables the
               Thm-2 sequential ball exactly like the fused subsystem).
      screen_fn: custom batched screening backend (e.g. the sharded
               collective from ``repro.distributed.saif_sharded``).
      prep:    optional prebuilt :class:`FleetPrep` — the serving layer
               passes a bucket-padded preparation whose c0/col_norm were
               computed on the real design and zero/-inf-padded, with
               ``n_true``/``p_true`` recording the real dims (DESIGN.md
               §12). ``X``/``Y``/``weights`` are ignored when given.

    Returns a :class:`~repro.core.saif.SaifResult` whose every field has a
    leading problem axis. The whole fleet runs in ONE ``_saif_batch_jit``
    compilation (plus the rare elastic-capacity recompile, exactly like
    the serial driver); supports and coefficients are bitwise those of B
    serial :func:`~repro.core.saif.saif` calls.
    """
    if config.unpen_idx is not None:
        raise NotImplementedError(
            "saif_batch solves plain-LASSO fleets; the fused unpenalized "
            "slot is serial-only for now (DESIGN.md §8)")
    if prep is None:
        prep = prepare_fleet(X, Y, config, weights=weights)
    X, Y, W = prep.X, prep.Y, prep.W
    n, p = X.shape
    n_eff = prep.n_true or n
    p_eff = prep.p_true or p
    pad_mask = (jnp.arange(p) >= p_eff) if p_eff < p else None
    b = Y.shape[0]
    lam_arr = jnp.broadcast_to(
        jnp.asarray(lam, X.dtype).reshape(-1), (b,))
    lams = [float(v) for v in jax.device_get(lam_arr)]
    rule = resolve_screen_rule(config.screen_rule)
    use_seq = config.use_seq_ball and W is None and rule.use_seq_ball
    backend = resolve_batch_screen(config.screen_backend, b=b, p=p_eff)
    # parity="fast" dispatch (DESIGN.md §11): the lockstep engine is
    # least-squares only (its inner burst is the batched Gram sweep) and
    # a custom screen_fn owns its own scores — both fall back to the
    # bitwise engine, which is always a valid (slower) implementation of
    # the same contract.
    use_fast = (config.parity == "fast"
                and config.loss == "least_squares"
                and screen_fn is None)

    hs, h = fleet_batch_sizes(prep, lams, config)
    h_tilde = jnp.asarray(
        [max(int(math.ceil(config.zeta * h_b)), 1) for h_b in hs],
        jnp.int32)
    h_cap = jnp.asarray(hs, jnp.int32)
    k_max = config.k_max or default_capacity(h, p_eff)
    delta0 = jnp.asarray(_delta0s(prep, lams, config), X.dtype)
    W_arg = W if W is not None else jnp.zeros((1, 1), X.dtype)

    # cold start computed ONCE at the original capacity: like the serial
    # driver, elastic growth pads the buffers but keeps the original
    # (possibly capacity-truncated) initial support, so a re-entered fleet
    # reproduces the serial overflow-recovery trajectories bitwise
    if use_fast:
        sel_dt = (None if config.screen_dtype == "working"
                  else jnp.dtype(jnp.float32))
        init_idx, init_beta, init_mask = _initial_support_batch_jit(
            prep.c0, hs=tuple(hs), k_max=k_max, p=p_eff, dtype=X.dtype,
            sel_dtype=sel_dt)
    else:
        init_idx, init_beta, init_mask = initial_support_batch(
            prep.c0, hs, k_max, p_eff, X.dtype)
    while True:
        pad = k_max - init_idx.shape[1]
        if pad > 0:
            init_idx = jnp.pad(init_idx, ((0, 0), (0, pad)))
            init_beta = jnp.pad(init_beta, ((0, 0), (0, pad)))
            init_mask = jnp.pad(init_mask, ((0, 0), (0, pad)))
        # the fleet dispatch routes through the fault-injection seam
        # (repro.runtime.inject) — a single None-check when disarmed
        if use_fast:
            km = k_max
            res = _fault_seam("fleet", lambda: _saif_batch_fast_jit(
                X, Y, W_arg, prep.col_norm, prep.c0, lam_arr,
                jnp.full((b,), config.eps, X.dtype), delta0,
                init_idx, init_beta, init_mask, h_tilde, h_cap,
                pad_mask,
                loss_name=config.loss, h=h, k_max=km,
                inner_epochs=config.inner_epochs,
                polish_factor=config.polish_factor,
                max_outer=config.max_outer, use_seq_ball=use_seq,
                screen_dtype=config.screen_dtype,
                has_weights=W is not None, screen_rule=rule))
        else:
            inner = resolve_batch_inner(config, n_eff, k_max, b)
            carry = cold_inner_carry_batch(b, k_max, X.dtype, backend=inner)
            res = _fault_seam("fleet", lambda: _saif_batch_jit(
                X, Y, W_arg, prep.col_norm, prep.c0, lam_arr,
                jnp.full((b,), config.eps, X.dtype), delta0,
                init_idx, init_beta, init_mask,
                carry.G, carry.rho, carry.gidx, h_tilde, h_cap,
                pad_mask,
                loss_name=config.loss, h=h, k_max=k_max,
                inner_epochs=config.inner_epochs,
                polish_factor=config.polish_factor,
                max_outer=config.max_outer, use_seq_ball=use_seq,
                screen_backend=backend, inner_backend=inner,
                has_weights=W is not None, screen_fn=screen_fn,
                screen_rule=rule))
        # ONE host sync for the whole fleet's overflow flags; elastic
        # growth re-enters cold at doubled capacity (per-problem results
        # are capacity-invariant, so non-overflowing problems reproduce
        # their previous answers bitwise)
        if not bool(jnp.any(res.overflowed)) or k_max >= p_eff:
            return res
        k_max = min(2 * k_max, p_eff)


def saif_batch(X, Y, lam, config: SaifConfig = SaifConfig(),
               weights=None,
               screen_fn: Optional[BatchScreenFn] = None) -> SaifResult:
    """DEPRECATED legacy frontend — one-shot session over
    :func:`fleet_solve`.

    Use ``repro.open_session(Problem(X), config).solve(Fleet(Y, lams))``;
    a held-open session keeps the fleet compilation alive across request
    streams (DESIGN.md §9).
    """
    from repro.core._compat import warn_deprecated
    warn_deprecated("repro.core.saif_batch",
                    "session.solve(Fleet(Y, lams))")
    from repro.core.api import Fleet, Problem, open_session

    sess = open_session(Problem(X=X, loss=config.loss), config)
    return sess.solve(Fleet(Y=Y, lams=lam, weights=weights,
                            screen_fn=screen_fn))
