"""Sequential (DPP-style) screening baseline over a lambda path (paper Sec 5.3).

Given the exact-enough solution at lambda_0 > lambda, Theorem 2 yields a ball
for theta*(lambda); features with |x_i^T c| + ||x_i|| r < 1 are screened before
solving the reduced problem with CM. Applied along a descending lambda path
with warm starts — the classical use of sequential screening.
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cm import cm_epoch
from repro.core.duality import (dual_point, duality_gap, feasible_dual,
                                gap_ball, sequential_ball)
from repro.core.losses import get_loss


@dataclasses.dataclass(frozen=True)
class SeqConfig:
    eps: float = 1e-6
    inner_epochs: int = 10
    max_outer: int = 20000
    loss: str = "least_squares"


class PathResult(NamedTuple):
    lams: np.ndarray
    betas: List[jax.Array]      # one (p,) vector per lambda
    screened_frac: List[float]  # fraction screened before each solve
    coord_updates: int


def _solve_reduced(loss, Xr, y, lam, beta0, eps, inner_epochs, max_outer):
    """CM to duality gap <= eps on the reduced matrix; returns beta, updates."""
    k = Xr.shape[1]
    mask = jnp.ones((k,), bool)

    def cond(state):
        _, _, gap, t = state
        return (gap > eps) & (t < max_outer)

    def body(state):
        beta, z, _, t = state
        def cm_body(_, carry):
            b, z = carry
            return cm_epoch(loss, Xr, y, b, z, mask, lam)
        beta, z = jax.lax.fori_loop(0, inner_epochs, cm_body, (beta, z))
        hat = -loss.grad(z, y) / lam
        theta = feasible_dual(loss, Xr, y, hat, lam)
        gap = duality_gap(loss, Xr, y, beta, theta, lam)
        return beta, z, gap, t + 1

    state = (beta0, Xr @ beta0, jnp.asarray(jnp.inf, Xr.dtype),
             jnp.asarray(0))
    beta, z, gap, t = jax.lax.while_loop(cond, body, state)
    return beta, z, gap, t


def sequential_path(X, y, lams: Sequence[float],
                    config: SeqConfig = SeqConfig()) -> PathResult:
    """Solve LASSO along a descending lambda path with DPP-style screening."""
    loss = get_loss(config.loss)
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    n, p = X.shape
    col_norm = jnp.linalg.norm(X, axis=0)
    g0 = loss.grad(jnp.zeros_like(y), y)
    lam_max = float(jnp.max(jnp.abs(X.T @ g0)))

    lams = np.asarray(sorted([float(l) for l in lams], reverse=True))
    betas, fracs = [], []
    coord_updates = 0

    # state of the previous solve (starts at lambda_max, beta = 0)
    lam_prev = lam_max
    theta_prev = -g0 / lam_max
    beta_prev_full = jnp.zeros((p,), X.dtype)

    for lam_f in lams:
        lam = jnp.asarray(min(lam_f, lam_max * (1 - 1e-12)), X.dtype)
        ball = sequential_ball(loss, y, theta_prev,
                               jnp.asarray(lam_prev, X.dtype), lam)
        corr = jnp.abs(X.T @ ball.center)
        keep = ~(corr + col_norm * ball.radius < 1.0)
        keep_np = np.asarray(keep)
        fracs.append(1.0 - keep_np.mean())

        Xr = X[:, keep_np]
        beta0 = beta_prev_full[keep_np]
        beta_r, z, gap, t = _solve_reduced(
            loss, Xr, y, lam, beta0, jnp.asarray(config.eps, X.dtype),
            config.inner_epochs, config.max_outer)
        coord_updates += int(t) * config.inner_epochs * Xr.shape[1]

        beta_full = jnp.zeros((p,), X.dtype).at[np.where(keep_np)[0]].set(beta_r)
        betas.append(beta_full)

        hat = -loss.grad(z, y) / lam
        theta_prev = feasible_dual(loss, Xr, y, hat, lam)
        lam_prev = float(lam)
        beta_prev_full = beta_full

    return PathResult(lams=lams, betas=betas, screened_frac=fracs,
                      coord_updates=coord_updates)
