"""SAIF — Safe Active Incremental Feature selection (paper Algorithms 1 & 2).

The entire outer loop is a single jitted ``lax.while_loop``; the active set is
the fixed-capacity buffer from :mod:`repro.core.active_set`. The only O(p)
work per outer step is the screening scan (gated on the ADD phase), and that
scan is pluggable: a :class:`~repro.core.screen_backend.ScreenFn` produces
the ADD-stop bound, the top-h candidates and their violation counts in one
shot, so the ADD phase never materializes or sorts a second (p,)-shaped
array. Backends: the default jnp matvec, the fused Pallas TPU kernel pair
(``repro.kernels.screen``), and the multi-pod shard_map version
(``repro.distributed.saif_sharded``) — all computing the same function
(tested against each other; selection policy in DESIGN.md §3).

The inner solver is pluggable the same way (:mod:`repro.core.inner_backend`,
DESIGN.md §6): an :class:`~repro.core.inner_backend.InnerBackend` owns the
whole "CM burst + dual point + duality gap" of an outer step, and the
covariance-update (``gram``) engine threads its Gram buffers through the
while_loop carry — each coordinate step is then O(k_max), with no O(n) work
anywhere in the burst.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import active_set as aset_lib
from repro.core.active_set import ActiveSet
from repro.core.duality import (gap_ball, gap_precision_floor,
                                intersect_balls, sequential_ball)
from repro.core.inner_backend import (InnerCarry, _dual_and_gap,
                                      cold_inner_carry, make_inner,
                                      resolve_inner_backend)
from repro.core.losses import get_loss
from repro.core.screen_backend import (ScreenFn, ScreenOut, ScreenRule,
                                       make_screen_from_scan,
                                       make_screen_jnp, make_screen_pallas,
                                       resolve_backend, resolve_screen_rule)
from repro.core.screen_rule import SCREEN_RULES
from repro.runtime.inject import seam as _fault_seam


@dataclasses.dataclass(frozen=True)
class SaifConfig:
    """Hyper-parameters of Algorithm 1/2 (paper defaults where given)."""
    eps: float = 1e-6            # stopping duality gap
    inner_epochs: int = 5        # K soft-threshold sweeps per outer step
    polish_factor: int = 8       # K multiplier once ADD has stopped (§Perf:
    #   the accuracy-pursuit phase has no screening decisions to make, so
    #   longer CM bursts amortize the per-outer dual/gap/gather overhead)
    c: float = 1.0               # ADD batch size constant (h formula)
    zeta: float = 1.0            # violation tolerance multiplier (h~ = zeta h)
    k_max: Optional[int] = None  # active-set capacity (None => auto)
    max_outer: int = 2000        # while_loop guard / trace length
    delta0: Optional[float] = None  # initial radius factor (None => lam/lam_max)
    use_seq_ball: bool = True    # intersect Thm-2 ball with the gap ball
    loss: str = "least_squares"
    screen_backend: str = "auto"  # "auto" | "jnp" | "pallas" (DESIGN.md §3)
    inner_backend: str = "auto"   # "auto" | "jnp" | "gram" | "pallas" (§6)
    unpen_idx: Optional[int] = None  # feature id exempt from the l1 penalty
    #   (fused LASSO's always-resident ``b`` slot, Thm 7 / DESIGN.md §7);
    #   None = plain LASSO. The slot is pinned in the active set, never
    #   DELed, its coordinate step is unthresholded, and the dual point is
    #   projected onto its equality constraint.
    parity: str = "bitwise"      # "bitwise" | "fast" (DESIGN.md §11).
    #   "bitwise" (default): fleet solves replay the serial float path
    #   bit-for-bit (DESIGN.md §8 discipline) — unchanged from PR 6.
    #   "fast" (opt-in): fleet solves may re-associate batch reductions,
    #   run lockstep CM sweeps and the one-gemm-per-step screen; every
    #   screening decision is widened by a rigorous rounding-error bound
    #   and every solve still ends with a working-precision certificate.
    screen_dtype: str = "working"  # "working" | "float32" | "bfloat16":
    #   compute dtype of the fast-parity screening gemm (inputs cast down,
    #   f32 accumulation, radius widened by the certified error bound).
    #   Anything but "working" requires parity="fast".
    screen_rule: str = "saif"     # "saif" | "gap_safe" | "hybrid" — the
    #   certificate geometry (repro.core.screen_rule, DESIGN.md §13).
    #   "saif" keeps the Theorem-2 sequential+gap ball and the delta ramp
    #   bitwise-unchanged; "gap_safe" screens on the gap sphere alone;
    #   "hybrid" discards with the strong-rule point bound and gates every
    #   stop behind a safe full-radius post-check (fallback recruits any
    #   violator in-loop, so safety is preserved by construction).

    def __post_init__(self):
        if self.parity not in ("bitwise", "fast"):
            raise ValueError(
                f"parity must be 'bitwise' or 'fast', got {self.parity!r}")
        if self.screen_dtype not in ("working", "float32", "bfloat16"):
            raise ValueError(
                "screen_dtype must be 'working', 'float32' or 'bfloat16', "
                f"got {self.screen_dtype!r}")
        if self.screen_dtype != "working" and self.parity != "fast":
            raise ValueError(
                "screen_dtype != 'working' is a fast-parity feature: "
                "low-precision screening deviates from the bitwise serial "
                "float path; set parity='fast' to opt in")
        resolve_screen_rule(self.screen_rule)   # fail fast on unknown names


class SaifResult(NamedTuple):
    beta: jax.Array          # (p,) full solution
    gap: jax.Array           # final sub-problem duality gap
    n_outer: jax.Array       # outer iterations executed
    n_active: jax.Array      # final |A_t|
    overflowed: jax.Array    # capacity overflow flag
    trace_n_active: jax.Array  # (max_outer,) |A_t| per outer step (-1 pad)
    trace_gap: jax.Array       # (max_outer,)
    trace_dual: jax.Array      # (max_outer,)
    # final slot state + inner-solver carry: the path engine hands these to
    # the next lambda so slot assignment (and the Gram buffers that are
    # indexed by it) survive the warm start (DESIGN.md §6)
    active_idx: jax.Array    # (k_max,) final slot -> feature map
    active_mask: jax.Array   # (k_max,) final slot validity
    inner: InnerCarry        # final inner-backend carry (placeholder if none)
    # screening observability (ISSUE 9; fleet engines carry a leading B
    # axis). Per outer step: features the ADD screen ruled out / could not
    # rule out (-1 on steps whose ADD phase did not run), and the number
    # of safe post-check violations (-1 on steps with no check — always
    # -1 for rules without one). None from engines predating the counters.
    trace_screened: Optional[jax.Array] = None    # (max_outer,) int32
    trace_survivors: Optional[jax.Array] = None   # (max_outer,) int32
    trace_post_viol: Optional[jax.Array] = None   # (max_outer,) int32


class _State(NamedTuple):
    aset: ActiveSet
    z: jax.Array        # (n,) model vector Xa beta
    gap: jax.Array
    delta: jax.Array
    is_add: jax.Array   # bool
    stop: jax.Array     # bool
    t: jax.Array        # outer counter
    inner: InnerCarry   # inner-solver carry (Gram buffers for "gram")
    trace_n_active: jax.Array
    trace_gap: jax.Array
    trace_dual: jax.Array
    trace_screened: jax.Array   # int32 screening counters (ISSUE 9)
    trace_survivors: jax.Array
    trace_post_viol: jax.Array


def add_batch_size_static(c: float, lam: float, c0_max: float,
                          c0_median: float, p: int) -> int:
    """h = ceil(c log((md+mx)/lam) log p)  — paper Sec 2.2 (static value).

    Rounded up to the next power of two: h is a jit-static argument, so
    bucketing caps the number of recompiles across a lambda path at
    O(log p) instead of one per lambda (§Perf iteration 1). Takes the c0
    statistics as host floats so path drivers sync them exactly once.
    """
    h = math.ceil(max(c * math.log(max((c0_median + c0_max) / lam,
                                       1.0 + 1e-9))
                      * math.log(max(p, 2)), 1.0))
    h = 1 << (max(h, 1) - 1).bit_length()       # next pow2 bucket
    return max(min(h, p), 1)


def add_batch_size(c: float, lam: float, c0: jax.Array, p: int) -> int:
    """Device-array convenience wrapper around :func:`add_batch_size_static`."""
    return add_batch_size_static(c, lam, float(jnp.max(c0)),
                                 float(jnp.median(c0)), p)


def default_capacity(h: int, p: int) -> int:
    return int(min(p, max(8 * h, 64)))


def initial_support(c0, h: int, k_max: int, p: int,
                    unpen_idx: Optional[int] = None, b0=0.0,
                    dtype=jnp.float32):
    """Cold-start support (Algorithm 1 line 1): top-h' features by c0.

    Returns ``(init_idx (k_max,), init_beta (k_max,), n_init)``. With an
    unpenalized coordinate (fused LASSO) the slot is pinned at position 0,
    seeded at its null-fit value ``b0``, and masked out of the top-k so it
    can never occupy two slots. Shared by the single-lambda driver and the
    path engine's cold start so both produce bitwise-identical layouts.
    """
    if unpen_idx is None:
        n_init = min(h, k_max, p)
        top = jax.lax.top_k(c0, n_init)[1].astype(jnp.int32)
        init_idx = jnp.zeros((k_max,), jnp.int32).at[:n_init].set(top)
        return init_idx, jnp.zeros((k_max,), dtype), n_init
    n_init = min(h + 1, k_max, p)
    n_top = n_init - 1
    c0_top = c0.at[unpen_idx].set(-jnp.inf)     # ties at 0 must not pick it
    top = jax.lax.top_k(c0_top, max(n_top, 1))[1].astype(jnp.int32)
    init_idx = jnp.zeros((k_max,), jnp.int32).at[0].set(unpen_idx)
    init_idx = init_idx.at[1:n_init].set(top[:n_top])
    init_beta = jnp.zeros((k_max,), dtype).at[0].set(
        jnp.asarray(b0, dtype))
    return init_idx, init_beta, n_init


ScanFn = Callable[[jax.Array], jax.Array]
# legacy signature: theta (n,) -> |X^T theta| (p,)


def _n_surv32(out: ScreenOut) -> jax.Array:
    """Survivor count as int32; legacy/custom ScreenFns without the
    counter (n_surv=None) read as 0."""
    ns = out.n_surv
    if ns is None:
        return jnp.zeros((), jnp.int32)
    return ns.astype(jnp.int32)


@partial(jax.jit, static_argnames=("loss_name", "h", "k_max",
                                   "inner_epochs", "polish_factor",
                                   "max_outer", "use_seq_ball",
                                   "screen_backend", "inner_backend",
                                   "unpen_idx", "screen_fn", "scan_fn",
                                   "screen_rule"))
def _saif_jit(X, y, col_norm, c0, lam, eps, delta0, init_idx, init_beta,
              init_mask, init_G, init_rho, init_gidx, h_tilde, h_cap,
              pad_mask=None,
              *, loss_name: str, h: int, k_max: int,
              inner_epochs: int, polish_factor: int, max_outer: int,
              use_seq_ball: bool, screen_backend: str = "jnp",
              inner_backend: str = "jnp", unpen_idx: int = -1,
              screen_fn: Optional[ScreenFn] = None,
              scan_fn: Optional[ScanFn] = None,
              screen_rule: ScreenRule = SCREEN_RULES["saif"]) -> SaifResult:
    # h (static) sizes the candidate shapes; h_tilde (the violation
    # tolerance) and h_cap (the effective per-step batch size, <= h) are
    # traced — they only feed comparisons. Splitting them lets a lambda
    # path share ONE compilation at the grid-max h while every lambda
    # keeps its own tolerance and batch size, so the ADD decisions are
    # bitwise those of a per-lambda compile. The same split applies to the
    # inner carry: (init_G, init_rho, init_gidx) are traced warm-handoff
    # buffers at fixed (k_max,)-derived shapes (placeholders for stateless
    # inner backends).
    loss = get_loss(loss_name)
    n, p = X.shape
    lam = jnp.asarray(lam, X.dtype)
    if screen_fn is not None:
        screen = screen_fn
    elif scan_fn is not None:
        # legacy bare-scan hook (e.g. the shard_map scan): adapt in-trace so
        # the caller-stable function object stays the jit cache key
        screen = make_screen_from_scan(scan_fn, col_norm, h)
    elif screen_backend == "pallas":
        screen = make_screen_pallas(X, col_norm, h)
    else:
        screen = make_screen_jnp(X, col_norm, h)
    inner = make_inner(inner_backend, loss, X, y, col_norm, h, unpen_idx)

    g0 = loss.grad(jnp.zeros_like(y), y)   # f'(0)

    aset0 = aset_lib.init_active_set(p, k_max, init_idx, X.dtype, init_beta,
                                     live_mask=init_mask)
    if pad_mask is not None:
        # Bucket-pad columns (traced, so every problem in a compile bucket
        # shares this cache entry) are born "already active" without ever
        # holding a slot: the screens mask active columns to -inf, DEL
        # only touches live slots, and ADD draws from screen candidates —
        # so a pad can never be recruited, deleted, or scored, and the
        # real columns' trajectory is exactly the unpadded one.
        aset0 = aset0._replace(in_active=aset0.in_active | pad_mask)
    carry_in = InnerCarry(G=init_G, rho=init_rho, gidx=init_gidx)
    inner0 = inner.init(aset0, carry_in,
                        aset_lib.gather_columns(X, aset0))
    trace0 = jnp.full((max_outer,), -1.0, X.dtype)
    itrace0 = jnp.full((max_outer,), -1, jnp.int32)
    state0 = _State(aset=aset0, z=jnp.zeros_like(y),
                    gap=jnp.asarray(jnp.inf, X.dtype),
                    delta=jnp.asarray(delta0, X.dtype),
                    is_add=jnp.asarray(True), stop=jnp.asarray(False),
                    t=jnp.asarray(0), inner=inner0,
                    trace_n_active=trace0, trace_gap=trace0, trace_dual=trace0,
                    trace_screened=itrace0, trace_survivors=itrace0,
                    trace_post_viol=itrace0)

    def cond(s: _State):
        return (~s.stop) & (s.t < max_outer)

    def body(s: _State) -> _State:
        aset = s.aset
        Xa = aset_lib.gather_columns(X, aset)

        # --- K epochs of coordinate minimization on the sub-problem --------
        # (K * polish_factor once recruiting is done — §Perf iteration 2;
        #  sweeps only the aset.count live slots, in the incrementally
        #  maintained aset.order — §Perf iteration 3 + PR 2 hoist.)
        # The backend absorbs last step's ADD/DEL (bounded Gram column
        # refresh for "gram", no-op otherwise), runs the burst, and returns
        # the dual point + duality gap (Eq. 11) along with (beta, z).
        inner_carry = inner.refresh(s.inner, aset, Xa)
        newton = (screen_rule.newton_polish and inner_backend == "gram"
                  and loss_name == "least_squares" and unpen_idx < 0)
        n_ep = jnp.where(s.is_add, inner_epochs,
                         inner_epochs * polish_factor)
        out = inner.run(inner_carry, aset, Xa, lam, n_ep)
        beta, z, theta = out.beta, out.z, out.theta
        gap = jnp.asarray(out.gap, X.dtype)

        # --- working-set Newton polish (hybrid rule, DESIGN.md §13) --------
        # Once recruiting quiesces, the gram carry already holds the
        # working-set normal equations, so ONE masked solve of
        # G b = rho - lam*sign gives the exact sub-problem solution under
        # the current sign pattern — collapsing the O(1/rate) CM polish
        # tail into a handful of outer steps. The proposal is certified by
        # the OFFICIAL dual/gap tail and accepted only if it beats the CM
        # iterate's gap, so a wrong sign pattern, a singular working set
        # (|A| > n), or numerical junk silently falls back to the CM burst
        # — no certificate is ever derived from an unverified solve.
        if newton:
            def newton_step(args):
                beta_c, z_c, theta_c_, gap_c = args
                G, rho = inner_carry.G, inner_carry.rho
                # Solve on the CM iterate's *support*, not the whole
                # working set: soft-thresholding zeroes slots whose partial
                # correlation is < lam exactly, so recruited-but-inactive
                # extras sit at beta == 0 long before DEL evicts them —
                # forcing the equality KKT on those slots would push them
                # off zero and lose the accept test every step.
                m = aset.mask & (beta_c != 0.0)
                sgn = jnp.sign(beta_c)
                mf = m.astype(X.dtype)
                Gm = (G * (mf[:, None] * mf[None, :]) +
                      jnp.diag(1.0 - mf))
                rhs = (rho - lam * sgn) * mf
                b_n = jnp.where(m, jnp.linalg.solve(Gm, rhs), 0.0)
                z_n = Xa @ b_n
                th_n, gap_n = _dual_and_gap(loss, Xa, y, b_n, z_n, m, lam)
                gap_n = jnp.asarray(gap_n, X.dtype)
                better = gap_n < gap_c          # NaN/garbage reads False
                return (jnp.where(better, b_n, beta_c),
                        jnp.where(better, z_n, z_c),
                        jnp.where(better, th_n, theta_c_),
                        jnp.where(better, gap_n, gap_c))

            beta, z, theta, gap = jax.lax.cond(
                ~s.is_add, newton_step, lambda a: a, (beta, z, theta, gap))
        aset = aset._replace(beta=beta)

        # --- ball region from the backend's dual point (Thm 2 / Eq. 12) ----
        # The radius is floored at the gap's own arithmetic precision: a
        # machine-converged sub-problem reports gap 0 (or negative) and a
        # zero radius would let the strict DEL / ADD-stop comparisons evict
        # or ignore boundary features (|x^T theta*| = 1) on float noise —
        # the near-lambda_max gaussian-design support misses (ROADMAP item).
        ball = gap_ball(loss, theta, gap, lam,
                        floor=gap_precision_floor(theta, lam))
        if use_seq_ball:
            # lam_max(t) over the *active* features (paper Sec 2.2).
            c0_active = jnp.where(aset.mask, jnp.take(c0, aset.idx), -jnp.inf)
            lam0t = jnp.maximum(jnp.max(c0_active), lam * (1 + 1e-12))
            theta0t = -g0 / lam0t
            b_seq = sequential_ball(loss, y, theta0t, lam0t, lam)
            ball = intersect_balls(b_seq, ball)
        # delta shrinks the radius for the ADD-side rules only (its paper
        # role: avoid recruiting inaccurately-screened features early). DEL
        # keeps the full gap-safe radius: a delta-shrunk DEL can evict
        # genuinely-active features of the sub-problem, destroying CM
        # progress and thrashing (observed experimentally; documented
        # deviation in DESIGN.md §2).
        if screen_rule.add_bound == "point":
            # strong-rule geometry (DESIGN.md §13): the ADD screen runs at
            # radius 0 — pure KKT violation at the current dual iterate.
            # Aggressive, not safe; the post-check below gates every stop.
            r_eff = jnp.zeros_like(ball.radius)
        else:
            r_eff = s.delta * ball.radius
        r_del = ball.radius
        theta_c = ball.center

        # --- global stop check (gap target reached & recruiting finished) --
        stop_now = (~s.is_add) & (gap <= eps)

        # --- DEL (gap-safe rule on the sub-problem) ------------------------
        corr_act = jnp.abs(Xa.T @ theta_c)                     # (k_max,)
        norm_act = jnp.where(aset.mask, jnp.take(col_norm, aset.idx), 0.0)
        del_mask = aset.mask & (corr_act + norm_act * r_del < 1.0)
        if unpen_idx >= 0:
            # the unpenalized slot is always resident: its dual constraint
            # is an equality (Thm 7), so the <1 DEL rule never applies
            del_mask = del_mask & (aset.idx != unpen_idx)
        aset = jax.lax.cond(
            stop_now, lambda a: a,
            lambda a: aset_lib.delete_features(a, del_mask), aset)

        # --- ADD phase ------------------------------------------------------
        def do_add_phase(args):
            aset, delta, is_add = args
            # One backend call covers the whole full-width decision: the
            # ADD-stop bound, the top-h candidates and their violation
            # counts. No (p,)-shaped sort, no second full-width pass.
            out: ScreenOut = screen(theta_c, r_eff, aset.in_active)
            # stop criterion for ADD (Remark 1): max_{R_t} ub < 1
            add_done = out.max_ub < 1.0
            n_sur = _n_surv32(out)
            n_scr = (jnp.sum(~aset.in_active).astype(jnp.int32) - n_sur)

            def on_done(args):
                aset, delta, is_add = args
                if not screen_rule.delta_ramp:
                    # point-bound rules (DESIGN.md §13): no violator at the
                    # current iterate means recruiting is over — go straight
                    # to the polish phase; the safe post-check still gates
                    # the eventual stop.
                    return aset, delta, jnp.asarray(False)
                grown = jnp.minimum(10.0 * delta, 1.0)
                new_delta = jnp.where(delta < 1.0, grown, delta)
                new_is_add = jnp.where(delta < 1.0, is_add, False)
                return aset, new_delta, new_is_add

            def on_add(args):
                aset, delta, is_add = args
                # Algorithm 2: candidates = top-h by score; candidate l is
                # added iff its violation count |V_i| < h~, evaluated against
                # R_t minus the better-ranked candidates (cumulative-AND).
                ranks = jnp.arange(h)
                v_count = jnp.maximum(out.cand_ge - 1 - ranks, 0)
                keep = ((v_count < h_tilde) & (ranks < h_cap) &
                        jnp.isfinite(out.cand_score))
                if screen_rule.add_bound == "point":
                    # strong-rule recruiting: only actual KKT violators
                    # (ub = score >= 1) enter; scores sort descending so
                    # the cumulative-AND below keeps the violator prefix
                    keep = keep & (out.cand_score >= 1.0)
                keep = jnp.cumprod(keep.astype(jnp.int32)).astype(bool)
                # Progress guarantee (TPU adaptation, DESIGN.md §2): when the
                # sub-problem is already solved to near-target accuracy but no
                # candidate passes the violation test (radius floored by
                # arithmetic precision), force-recruit the top-scoring
                # feature. ADDing extra features is always safe (Thm 1a) —
                # it can only cost compute, never correctness.
                stuck = gap <= 100.0 * eps
                keep = keep.at[0].set(
                    keep[0] | (stuck & jnp.isfinite(out.cand_score[0])))
                return (aset_lib.add_features(aset, out.cand_idx, keep),
                        delta, is_add)

            aset, delta, is_add = jax.lax.cond(add_done, on_done, on_add,
                                               (aset, delta, is_add))
            return aset, delta, is_add, n_scr, n_sur

        if screen_rule.add_bound == "point":
            # the point screen costs one matvec, so it runs on EVERY
            # non-stopping step, polish phase included: a feature whose
            # score crosses 1 mid-convergence is recruited the burst it
            # crosses, not discovered by the final post-check after full
            # convergence (each such late discovery would otherwise pay a
            # whole re-convergence of the sub-problem — measured 3-4x the
            # total solve time on the CI benchmark shape). ``is_add``
            # still flips off at the first violator-free screen and stays
            # off (long polish bursts); late recruits don't re-enter the
            # short-burst phase.
            do_add = ~stop_now
        else:
            do_add = s.is_add & ~stop_now
        aset, delta, is_add, n_scr, n_sur = jax.lax.cond(
            do_add, do_add_phase,
            lambda args: args + (jnp.full((), -1, jnp.int32),
                                 jnp.full((), -1, jnp.int32)),
            (aset, s.delta, s.is_add))

        # --- safe post-check (hybrid rule, DESIGN.md §13) -------------------
        # A point-bound ADD phase discards aggressively, so termination is
        # gated behind ONE full screen at the certified safe radius: any
        # violator denies the stop and is recruited on the spot (the safe
        # fallback). The active set strictly grows on every failed check,
        # so at most p checks can fail — termination is preserved. All
        # ADDs are safe (Thm 1a); a solve can only stop with a passing
        # safe certificate, so hybrid keeps the SAIF guarantee.
        if screen_rule.post_check:
            def check(a):
                chk: ScreenOut = screen(theta_c, r_del, a.in_active)
                viol = chk.max_ub >= 1.0
                # recruit every candidate the safe ball cannot rule out;
                # force slot 0 so a failed check always makes progress
                # (max_ub can come from a non-candidate column, so the
                # top-score recruit is the progress guarantee, not ub_c)
                ub_c = (chk.cand_score +
                        jnp.take(col_norm, chk.cand_idx) * r_del)
                keep = viol & jnp.isfinite(chk.cand_score) & (ub_c >= 1.0)
                keep = keep.at[0].set(
                    viol & jnp.isfinite(chk.cand_score[0]))
                return (aset_lib.add_features(a, chk.cand_idx, keep),
                        viol.astype(jnp.int32))

            def no_check(a):
                return a, jnp.full((), -1, jnp.int32)

            aset, post_viol = jax.lax.cond(stop_now, check, no_check, aset)
            stop_final = stop_now & (post_viol != 1)
        else:
            post_viol = jnp.full((), -1, jnp.int32)
            stop_final = stop_now

        dual_val = loss.dual_objective(y, theta, lam)   # feasible point
        n_act = aset.count.astype(X.dtype)
        return _State(
            aset=aset, z=z, gap=gap, delta=delta, is_add=is_add,
            stop=stop_final, t=s.t + 1, inner=inner_carry,
            trace_n_active=s.trace_n_active.at[s.t].set(n_act),
            trace_gap=s.trace_gap.at[s.t].set(gap),
            trace_dual=s.trace_dual.at[s.t].set(dual_val),
            trace_screened=s.trace_screened.at[s.t].set(n_scr),
            trace_survivors=s.trace_survivors.at[s.t].set(n_sur),
            trace_post_viol=s.trace_post_viol.at[s.t].set(post_viol))

    final = jax.lax.while_loop(cond, body, state0)
    beta_full = aset_lib.scatter_beta(final.aset, p)
    return SaifResult(beta=beta_full, gap=final.gap, n_outer=final.t,
                      n_active=final.aset.count,
                      overflowed=final.aset.overflowed,
                      trace_n_active=final.trace_n_active,
                      trace_gap=final.trace_gap,
                      trace_dual=final.trace_dual,
                      active_idx=final.aset.idx,
                      active_mask=final.aset.mask,
                      inner=final.inner,
                      trace_screened=final.trace_screened,
                      trace_survivors=final.trace_survivors,
                      trace_post_viol=final.trace_post_viol)


def saif_jit_compile_count() -> int:
    """Number of distinct solver-core compilations alive in this process
    (the serial ``_saif_jit`` cache plus the fleet engine's
    ``_saif_batch_jit`` cache, once that module has been imported).

    The compile-first path engine, the batch engine and the benchmarks
    assert on deltas of this counter (acceptance: O(log p) compilations
    per lambda path; exactly 1 per fleet).
    """
    try:
        total = int(_saif_jit._cache_size())
    except Exception:       # pragma: no cover - older/newer jit internals
        return -1
    try:
        import sys
        batch_mod = sys.modules.get("repro.core.batch")
        if batch_mod is not None:
            total += int(batch_mod._saif_batch_jit._cache_size())
            total += int(batch_mod._saif_batch_fast_jit._cache_size())
    except Exception:       # pragma: no cover
        pass
    return total


class PathState(NamedTuple):
    """One-time O(np) problem preparation (c0 / col_norm / lambda_max and
    the host-side c0 statistics the h formula needs, synced exactly once).

    Shared by every driver layer: the single-lambda solver consumes one,
    the compile-first path engine (``core/path.py``) threads one through a
    whole grid, and a :class:`repro.core.api.Session` computes one at
    ``open_session`` and serves every subsequent request from it.
    """
    X: jax.Array          # (n, p)
    y: jax.Array          # (n,)
    c0: jax.Array         # (p,) |X^T f'(null model)|
    col_norm: jax.Array   # (p,)
    lam_max: float
    c0_max: float         # host copies of the c0 statistics the h formula
    c0_median: float      # needs — synced exactly once per preparation
    b0: float = 0.0       # unpenalized-slot null fit (fused problems; §7)
    # Bucket-padded preparations (DESIGN.md §12): X/y carry trailing zero
    # rows/columns up to a compile-bucket shape, while every *policy*
    # quantity (h, capacity, backend crossovers, initial support) must be
    # computed on the real problem. 0 means "unpadded: use X.shape".
    n_true: int = 0
    p_true: int = 0


def prepare_path(X, y, config: SaifConfig) -> PathState:
    """The one-time preparation pass (see :class:`PathState`).

    Penalized-null model: f'(0) for plain LASSO; with an unpenalized
    coordinate the null model sits at its partial optimum b0 (Thm 7) and
    c0[unpen] is 0, so lambda_max / h / the initial set stay exact.
    """
    from repro.core.duality import null_gradient

    loss = get_loss(config.loss)
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    _, c0, b0 = null_gradient(loss, X, y, config.unpen_idx)
    col_norm = jnp.linalg.norm(X, axis=0)
    c0_max, c0_median, b0 = jax.device_get(
        (jnp.max(c0), jnp.median(c0), b0))
    return PathState(X=X, y=y, c0=c0, col_norm=col_norm,
                     lam_max=float(c0_max), c0_max=float(c0_max),
                     c0_median=float(c0_median), b0=float(b0))


def pad_path_state(prep: PathState, n_bucket: int,
                   p_bucket: int) -> PathState:
    """Zero-pad a real preparation up to a compile-bucket shape
    (DESIGN.md §12).

    The stats stay those of the REAL problem: c0 pads sit at -inf (they
    can never win a top-k or a max), col-norm pads at 1.0 (never read —
    pads are masked out of every screen — but a finite value keeps any
    speculative lane arithmetic NaN-free), and ``n_true``/``p_true``
    record the real dims for every policy formula. Zero pad rows are
    mathematically inert for least squares (each contributes exactly 0
    to the primal, the gradient and the column norms); column padding is
    additionally *bitwise*-inert because no engine reduction ever runs
    over the feature axis (screens score per column with the
    padding-stable ``theta @ X`` orientation, selection is top-k/max).
    """
    n, p = prep.X.shape
    if n_bucket < n or p_bucket < p:
        raise ValueError(
            f"bucket ({n_bucket}, {p_bucket}) must dominate the problem "
            f"shape ({n}, {p})")
    if (n_bucket, p_bucket) == (n, p):
        return prep
    return prep._replace(
        X=jnp.pad(prep.X, ((0, n_bucket - n), (0, p_bucket - p))),
        y=jnp.pad(prep.y, (0, n_bucket - n)),
        c0=jnp.pad(prep.c0, (0, p_bucket - p), constant_values=-jnp.inf),
        col_norm=jnp.pad(prep.col_norm, (0, p_bucket - p),
                         constant_values=1.0),
        n_true=n, p_true=p)


def solve_scalar(prep: PathState, lam: float,
                 config: SaifConfig = SaifConfig(),
                 scan_fn: Optional[ScanFn] = None,
                 screen_fn: Optional[ScreenFn] = None,
                 warm_idx: Optional[jax.Array] = None,
                 warm_beta: Optional[jax.Array] = None) -> SaifResult:
    """Solve LASSO at ``lam`` from an existing preparation. Host driver.

    Handles the static pieces (h, capacity, initial active set, screening
    backend selection) and the capacity-overflow recompile loop; everything
    else runs inside one jitted while_loop. ``screen_fn`` plugs a full
    custom backend (e.g. the sharded one); ``scan_fn`` is the legacy
    bare-scan hook, adapted on the fly. :func:`saif` is the prepare+solve
    convenience; a session (``repro.core.api``) prepares once and calls
    this per request.
    """
    X, y, c0, col_norm = prep.X, prep.y, prep.c0, prep.col_norm
    n, p = X.shape
    # Bucket-padded preparations (DESIGN.md §12): the arrays carry the
    # bucket shape; every policy decision below runs on the real dims so
    # padding can never change h, capacity, or a backend crossover.
    n_true = prep.n_true or n
    p_true = prep.p_true or p
    pad_mask = (jnp.arange(p) >= p_true) if p_true < p else None
    unpen = config.unpen_idx
    lam_max = prep.lam_max
    b0 = prep.b0
    rule = resolve_screen_rule(config.screen_rule)
    # The Thm-2 sequential ball assumes the all-penalized null dual
    # theta0 = -f'(0)/lam_max — invalid once b is unpenalized (DESIGN.md
    # §7), so the gap ball alone drives screening there. The rule gates it
    # too: gap_safe/hybrid screen on the gap sphere alone (§13).
    use_seq = config.use_seq_ball and unpen is None and rule.use_seq_ball

    h = add_batch_size_static(config.c, lam, prep.c0_max, prep.c0_median,
                              p_true)
    h_tilde = max(int(math.ceil(config.zeta * h)), 1)
    k_max = config.k_max or default_capacity(h, p_true)
    delta0 = config.delta0 if config.delta0 is not None else \
        min(max(lam / lam_max, 1e-3), 1.0)
    backend = resolve_backend(config.screen_backend)

    # Initial active set: top-h' by |X^T f'(0)| (Algorithm 1 line 1),
    # or a warm start from a neighbouring lambda (Sec 5.3 path mode).
    # Always padded to (k_max,) so warm-started paths share one compilation.
    if warm_idx is not None:
        k_max = max(k_max, default_capacity(h, p_true))
        if unpen is None:
            # plain LASSO: stay on device, no host round-trip
            n_init = min(int(warm_idx.shape[0]), k_max, p_true)
            init_idx = jnp.zeros((k_max,), jnp.int32).at[:n_init].set(
                jnp.asarray(warm_idx)[:n_init].astype(jnp.int32))
            init_beta = jnp.zeros((k_max,), X.dtype)
            if warm_beta is not None:
                init_beta = init_beta.at[:n_init].set(
                    jnp.asarray(warm_beta)[:n_init].astype(X.dtype))
        else:
            warm_ids = [int(i) for i in jnp.asarray(warm_idx).tolist()]
            warm_vals = (list(jnp.asarray(warm_beta).tolist())
                         if warm_beta is not None
                         else [0.0] * len(warm_ids))
            if unpen not in warm_ids:
                # the unpenalized slot is always resident, even when the
                # previous lambda left b exactly 0 — PREPEND it so a
                # capacity-full warm support can never truncate it away
                warm_ids.insert(0, unpen)
                warm_vals.insert(0, float(b0))
            n_init = min(len(warm_ids), k_max, p_true)
            init_idx = jnp.zeros((k_max,), jnp.int32).at[:n_init].set(
                jnp.asarray(warm_ids[:n_init], jnp.int32))
            init_beta = jnp.zeros((k_max,), X.dtype).at[:n_init].set(
                jnp.asarray(warm_vals[:n_init], X.dtype))
    else:
        init_idx, init_beta, n_init = initial_support(
            c0, h, k_max, p_true, unpen, b0, X.dtype)

    while True:
        init_idx = init_idx[:k_max]
        init_beta = init_beta[:k_max]
        if init_idx.shape[0] < k_max:   # capacity grew after overflow
            pad = k_max - init_idx.shape[0]
            init_idx = jnp.pad(init_idx, (0, pad))
            init_beta = jnp.pad(init_beta, (0, pad))
        # capacity growth can move the auto crossover (DESIGN.md §6)
        inner = resolve_inner_backend(config.inner_backend, config.loss,
                                      n_true, k_max)
        carry = cold_inner_carry(k_max, X.dtype, backend=inner)
        # the engine dispatch routes through the fault-injection seam
        # (repro.runtime.inject) — a single None-check when disarmed
        res = _fault_seam("serial", lambda: _saif_jit(
            X, y, col_norm, c0, jnp.asarray(lam, X.dtype),
            jnp.asarray(config.eps, X.dtype),
            delta0, init_idx, init_beta,
            jnp.arange(k_max) < n_init,
            carry.G, carry.rho, carry.gidx,
            jnp.asarray(h_tilde, jnp.int32),
            jnp.asarray(h, jnp.int32),
            pad_mask,
            loss_name=config.loss, h=h,
            k_max=k_max, inner_epochs=config.inner_epochs,
            polish_factor=config.polish_factor,
            max_outer=config.max_outer,
            use_seq_ball=use_seq,
            screen_backend=backend, inner_backend=inner,
            unpen_idx=-1 if unpen is None else unpen,
            screen_fn=screen_fn, scan_fn=scan_fn,
            screen_rule=rule))
        if not bool(res.overflowed) or k_max >= p_true:
            return res
        k_max = min(2 * k_max, p_true)  # elastic capacity growth + recompile


def saif(X, y, lam: float, config: SaifConfig = SaifConfig(),
         scan_fn: Optional[ScanFn] = None,
         screen_fn: Optional[ScreenFn] = None,
         warm_idx: Optional[jax.Array] = None,
         warm_beta: Optional[jax.Array] = None) -> SaifResult:
    """Solve LASSO at ``lam`` with SAIF: one-shot prepare + solve.

    Thin over :func:`prepare_path` + :func:`solve_scalar`. Callers with
    more than one request on the same problem should hold a session
    instead (``repro.open_session``) so the preparation, the compile
    caches and the warm buffers persist across requests (DESIGN.md §9).
    """
    return solve_scalar(prepare_path(X, y, config), lam, config,
                        scan_fn=scan_fn, screen_fn=screen_fn,
                        warm_idx=warm_idx, warm_beta=warm_beta)
