"""Tree fused LASSO via the column transform of Theorem 6 — device-native.

Problem (17):  min_beta  sum_j f(x_j. beta, y_j) + lam ||D beta||_1,
where D has one row per edge of a tree G(F, E).

Theorem 6 construction, concretely: root the tree; new variables are
  beta_tilde_e = beta_child(e) - beta_parent(e)   (one per edge, penalized)
  b            = beta_root                        (unpenalized)
so beta_v = b + sum of beta_tilde along the root->v path, giving
  x_tilde_e = sum of x_v over the subtree below edge e      (transformed col)
  x_tilde_p = sum of all x_v                                (the b column)
and D T = [I 0]: the fused problem becomes a plain LASSO (18) in beta_tilde
with one unpenalized coordinate b.

Subsystem layout (DESIGN.md §7):

  * the tree's *level schedule* (nodes grouped by depth, padded to the
    widest level) is precomputed host-side once per tree — it is the only
    static piece; the subtree-sum column transform and the ``recover_beta``
    prefix sums then run on device as a ``lax.scan`` over levels
    (scatter-adds within a level), so the whole solve pipeline —
    transform, SAIF path, recovery — is jittable end to end;
  * the chain special case (1-D fused lasso, the paper's Fig-7 workload)
    collapses to column suffix sums and runs as a tiled Pallas kernel
    (``repro.kernels.fused``) whose exact right fold is bitwise-identical
    to the dense numpy reference kept below for parity tests;
  * the unpenalized coordinate ``b`` is NOT eliminated: it rides as an
    always-resident unpenalized *slot* in the SAIF active-set buffer
    (``SaifConfig.unpen_idx``), which works for every alpha-smooth loss —
    fused logistic regression included. Theorem 7's least-squares exact
    elimination (``eliminate_b_ls``) is retained as a parity oracle only.

``fused_path`` wires the transformed problem into the compile-first path
engine (``core/path.py``): one ``_saif_jit`` compilation per lambda grid,
slot-preserving warm starts with ``b`` pinned resident.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core._compat import warn_deprecated
from repro.core.saif import SaifConfig, SaifResult, saif
from repro.core.path import SaifPathResult
from repro.core.cm import solve_lasso_cm
from repro.core.losses import get_loss


class TreeTransform(NamedTuple):
    """Static description of the Theorem-6 transform for a given tree."""
    parent: np.ndarray        # (p,) parent[v] = parent node id, -1 at root
    edge_child: np.ndarray    # (p-1,) child node of edge e
    topo: np.ndarray          # (p,) nodes in topological (root-first) order
    root: int


class LevelSchedule(NamedTuple):
    """Host-side static level schedule of a tree (DESIGN.md §7).

    Nodes are grouped by depth (root = depth 0 excluded); every row is one
    level padded to the widest level's width with ``valid=False`` lanes.
    Within a level all children are distinct, and their parents live one
    level up — so a level's scatter-add reads only finished columns, and
    the device transform visits levels exactly once, deepest first.
    """
    child: np.ndarray    # (L, W) int32 node ids (-1 padding)
    parent: np.ndarray   # (L, W) int32 parent ids
    edge: np.ndarray     # (L, W) int32 edge index of child (-1 padding)
    valid: np.ndarray    # (L, W) bool
    is_chain: bool       # path graph 0-1-...-p-1 rooted at 0


def build_tree(parent: np.ndarray) -> TreeTransform:
    parent = np.asarray(parent, np.int64)
    (roots,) = np.where(parent < 0)
    if len(roots) != 1:
        raise ValueError("parent array must encode exactly one root")
    root = int(roots[0])
    p = len(parent)
    # topological order via BFS from root
    children: list[list[int]] = [[] for _ in range(p)]
    for v, pa in enumerate(parent):
        if pa >= 0:
            children[pa].append(v)
    topo, stack = [], [root]
    while stack:
        v = stack.pop()
        topo.append(v)
        stack.extend(children[v])
    if len(topo) != p:
        raise ValueError("parent array does not encode a connected tree")
    edge_child = np.asarray([v for v in range(p) if v != root], np.int64)
    return TreeTransform(parent=parent, edge_child=edge_child,
                         topo=np.asarray(topo, np.int64), root=root)


def build_schedule(tree: TreeTransform) -> LevelSchedule:
    """Group the tree's nodes by depth — the static input of the device
    transform. O(p) host work, once per tree."""
    p = len(tree.parent)
    depth = np.zeros(p, np.int64)
    for v in tree.topo:                       # parents precede children
        pa = tree.parent[v]
        if pa >= 0:
            depth[v] = depth[pa] + 1
    edge_of_child = np.full(p, -1, np.int64)
    edge_of_child[tree.edge_child] = np.arange(p - 1)
    n_levels = int(depth.max()) if p > 1 else 0
    levels = [[] for _ in range(n_levels)]
    for v in tree.topo:                       # deterministic: topo order
        if tree.parent[v] >= 0:
            levels[depth[v] - 1].append(v)
    width = max((len(l) for l in levels), default=1)
    child = np.full((n_levels, width), -1, np.int32)
    par = np.full((n_levels, width), -1, np.int32)
    edge = np.full((n_levels, width), -1, np.int32)
    valid = np.zeros((n_levels, width), bool)
    for d, nodes in enumerate(levels):
        m = len(nodes)
        child[d, :m] = nodes
        par[d, :m] = tree.parent[nodes]
        edge[d, :m] = edge_of_child[nodes]
        valid[d, :m] = True
    is_chain = bool(p >= 2 and
                    np.array_equal(tree.parent, np.arange(p) - 1))
    return LevelSchedule(child=child, parent=par, edge=edge, valid=valid,
                         is_chain=is_chain)


# --------------------------------------------------------------------------
# dense numpy reference transform (the parity oracle of the device paths)
# --------------------------------------------------------------------------

def transform_design(X: np.ndarray, tree: TreeTransform
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (X_bar (n, p-1) edge columns, xb (n,) the b column).

    x_tilde for edge e = subtree sum of X columns below e: accumulate child
    into parent in reverse topological order. Host-side numpy reference —
    the device paths (:func:`transform_design_scan` and the Pallas chain
    kernel) are tested against it bitwise on chains.
    """
    X = np.asarray(X)
    sub = X.copy()                      # sub[:, v] accumulates subtree sums
    for v in tree.topo[::-1]:
        pa = tree.parent[v]
        if pa >= 0:
            sub[:, pa] += sub[:, v]
    xb = sub[:, tree.root].copy()
    X_bar = sub[:, tree.edge_child]
    return X_bar, xb


def recover_beta(beta_tilde: np.ndarray, b: float,
                 tree: TreeTransform) -> np.ndarray:
    """beta = T [beta_tilde; b]: prefix-sum the edge deltas down the tree.
    Host-side numpy reference of :func:`recover_beta_device`."""
    p = len(tree.parent)
    edge_of_child = np.full(p, -1, np.int64)
    edge_of_child[tree.edge_child] = np.arange(p - 1)
    beta = np.zeros(p)
    for v in tree.topo:
        pa = tree.parent[v]
        if pa < 0:
            beta[v] = b
        else:
            beta[v] = beta[pa] + beta_tilde[edge_of_child[v]]
    return beta


# --------------------------------------------------------------------------
# device transform: lax.scan over the level schedule + Pallas chain kernel
# --------------------------------------------------------------------------

def transform_design_scan(X, tree: TreeTransform,
                          schedule: Optional[LevelSchedule] = None
                          ) -> Tuple[jax.Array, jax.Array]:
    """Jittable Theorem-6 transform: ``lax.scan`` over the level schedule.

    Levels run deepest-first; each step gathers the (finished) child
    columns of one level and scatter-adds them into their parents. Chains
    (one child per level) reproduce the numpy reference bitwise; general
    trees agree to re-association of the per-parent child sums.
    """
    if schedule is None:
        schedule = build_schedule(tree)
    X = jnp.asarray(X)
    n, p = X.shape
    if schedule.child.shape[0] == 0:            # single-node tree
        return X[:, :0], X[:, tree.root]
    ch = jnp.asarray(schedule.child)[::-1]      # deepest level first
    pa = jnp.asarray(schedule.parent)[::-1]
    va = jnp.asarray(schedule.valid)[::-1]

    def level_step(sub, lvl):
        c, q, v = lvl
        cols = jnp.take(sub, jnp.clip(c, 0, p - 1), axis=1)
        cols = cols * v.astype(sub.dtype)[None, :]
        sub = sub.at[:, jnp.where(v, q, p)].add(cols, mode="drop")
        return sub, None

    sub, _ = jax.lax.scan(level_step, X, (ch, pa, va))
    xb = sub[:, tree.root]
    X_bar = sub[:, jnp.asarray(tree.edge_child)]
    return X_bar, xb


def transform_design_device(X, tree: TreeTransform,
                            schedule: Optional[LevelSchedule] = None,
                            backend: str = "auto",
                            interpret: Optional[bool] = None
                            ) -> Tuple[jax.Array, jax.Array]:
    """Device transform dispatcher: ``pallas`` (chain suffix-sum kernel),
    ``scan`` (general trees), or ``auto`` — the kernel on TPU chains, the
    scan elsewhere (off-TPU the kernel runs interpreted: parity oracle,
    not a fast path — same policy as every backend in DESIGN.md §3/§6)."""
    if schedule is None:
        schedule = build_schedule(tree)
    if backend == "auto":
        backend = ("pallas" if schedule.is_chain
                   and jax.default_backend() == "tpu" else "scan")
    if backend == "pallas":
        if not schedule.is_chain:
            raise ValueError("the Pallas fused transform is the chain "
                             "(1-D fused lasso) special case; use "
                             "backend='scan' for general trees")
        from repro.kernels.fused.fused import chain_suffix_sums_pallas
        S = chain_suffix_sums_pallas(jnp.asarray(X), interpret=interpret)
        return S[:, 1:], S[:, 0]
    if backend != "scan":
        raise ValueError(f"unknown fused transform backend {backend!r}")
    return transform_design_scan(X, tree, schedule)


def recover_beta_device(beta_tilde: jax.Array, b, tree: TreeTransform,
                        schedule: Optional[LevelSchedule] = None
                        ) -> jax.Array:
    """Jittable beta = T [beta_tilde; b]: top-down ``lax.scan`` prefix sums
    over the level schedule. Bitwise-identical to the numpy reference (one
    add per node, same order)."""
    if schedule is None:
        schedule = build_schedule(tree)
    p = len(tree.parent)
    beta_tilde = jnp.asarray(beta_tilde)
    beta0 = jnp.zeros((p,), beta_tilde.dtype).at[tree.root].set(
        jnp.asarray(b, beta_tilde.dtype))
    if p == 1 or schedule.child.shape[0] == 0:
        return beta0
    ch = jnp.asarray(schedule.child)
    pa = jnp.asarray(schedule.parent)
    ed = jnp.asarray(schedule.edge)
    va = jnp.asarray(schedule.valid)

    def level_step(beta, lvl):
        c, q, e, v = lvl
        vals = (jnp.take(beta, jnp.clip(q, 0, p - 1)) +
                jnp.take(beta_tilde, jnp.clip(e, 0, p - 2)))
        beta = beta.at[jnp.where(v, c, p)].set(vals, mode="drop")
        return beta, None

    beta, _ = jax.lax.scan(level_step, beta0, (ch, pa, ed, va))
    return beta


# --------------------------------------------------------------------------
# the fused problem object + SAIF drivers
# --------------------------------------------------------------------------

class FusedDesign(NamedTuple):
    """One-time transform of a fused problem (tree + device design).

    ``Xt`` holds the p-1 transformed edge columns followed by the
    unpenalized b column at ``unpen_idx`` = p-1 — the layout every driver
    below shares with :class:`~repro.core.saif.SaifConfig.unpen_idx`.
    """
    tree: TreeTransform
    schedule: LevelSchedule
    Xt: jax.Array        # (n, p) transformed design, b column last
    unpen_idx: int


class FusedPathResult(NamedTuple):
    lams: np.ndarray
    betas: List[jax.Array]     # node-space solutions (recovered)
    path: SaifPathResult       # transformed-space engine result


def prepare_fused(X, parent, backend: str = "auto",
                  interpret: Optional[bool] = None) -> FusedDesign:
    """Build the tree, its level schedule and the transformed design —
    the one-time O(p-depth) prep every fused solve/path shares."""
    tree = build_tree(np.asarray(parent))
    schedule = build_schedule(tree)
    X_bar, xb = transform_design_device(X, tree, schedule, backend,
                                        interpret)
    Xt = jnp.concatenate([X_bar, xb[:, None]], axis=1)
    return FusedDesign(tree=tree, schedule=schedule, Xt=Xt,
                       unpen_idx=Xt.shape[1] - 1)


def recover_from_transformed(beta_t: jax.Array,
                             design: FusedDesign) -> jax.Array:
    """Node-space beta from a transformed-space solution (b column last)."""
    pt = beta_t.shape[0]
    return recover_beta_device(beta_t[:pt - 1], beta_t[pt - 1],
                               design.tree, design.schedule)


def saif_fused(X, y, parent, lam: float,
               config: SaifConfig = SaifConfig(),
               transform_backend: str = "auto"
               ) -> Tuple[jax.Array, SaifResult]:
    """DEPRECATED legacy frontend — one-shot session over the fused
    subsystem. Use ``repro.open_session(Problem(X, y,
    penalty=fused(parent)), config).solve(Scalar(lam))``; the session
    performs the Theorem-6 transform exactly once and serves every
    subsequent request from it (DESIGN.md §9)."""
    warn_deprecated("repro.core.saif_fused",
                    "session.solve(Scalar(lam)) with penalty=fused(parent)")
    from repro.core.api import Problem, Scalar, fused, open_session

    sess = open_session(
        Problem(X=X, y=y, loss=config.loss,
                penalty=fused(parent, transform_backend=transform_backend)),
        config)
    return sess.solve(Scalar(lam=float(lam)))


def fused_path(X, y, parent, lams,
               config: SaifConfig = SaifConfig(),
               transform_backend: str = "auto",
               segment_len: int = 16) -> FusedPathResult:
    """DEPRECATED legacy frontend — one-shot session over
    :func:`fused_path_from_design` (DESIGN.md §9)."""
    warn_deprecated("repro.core.fused_path",
                    "session.solve(Path(lams)) with penalty=fused(parent)")
    from repro.core.api import Path, Problem, fused, open_session

    sess = open_session(
        Problem(X=X, y=y, loss=config.loss,
                penalty=fused(parent, transform_backend=transform_backend)),
        config, segment_len=segment_len)
    return sess.solve(Path(lams=tuple(float(l) for l in lams)))


def fused_lambda_max(X, y, parent, loss: str = "least_squares") -> float:
    """Smallest lam with beta_tilde* = 0 (all coefficients fused): the max
    |x_tilde^T f'| at the unpenalized null model (b at its partial
    optimum, Thm 7)."""
    from repro.core.duality import null_gradient

    design = prepare_fused(X, parent, backend="scan")
    y = jnp.asarray(y, design.Xt.dtype)
    _, c0, _ = null_gradient(get_loss(loss), design.Xt, y,
                             design.unpen_idx)
    return float(jnp.max(c0))


# --------------------------------------------------------------------------
# baselines and validation helpers
# --------------------------------------------------------------------------

def fused_baseline_cm(X, y, parent, lam: float, tol: float = 1e-9,
                      loss: str = "least_squares",
                      max_epochs: int = 100_000) -> jax.Array:
    """Unscreened fused solve (the 'CVX' stand-in baseline for Fig 7):
    full-width CM on the transformed problem, b as an unpenalized
    coordinate — any alpha-smooth loss."""
    design = prepare_fused(X, parent, backend="scan")
    y = jnp.asarray(y, design.Xt.dtype)
    beta_t = solve_lasso_cm(get_loss(loss), design.Xt, y, lam, tol=tol,
                            max_epochs=max_epochs,
                            unpen_idx=design.unpen_idx)
    return recover_from_transformed(beta_t, design)


def eliminate_b_ls(X_bar: np.ndarray, xb: np.ndarray, y: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Least-squares exact elimination of the unpenalized coordinate b
    (Theorem 7's tau-projection). Superseded by the always-resident
    unpenalized slot — kept as the LS parity oracle for it."""
    q = xb / max(np.linalg.norm(xb), 1e-30)
    Xp = X_bar - np.outer(q, q @ X_bar)
    yp = y - q * (q @ y)
    return Xp, yp


def recover_b_ls(X_bar, xb, y, beta_tilde) -> float:
    r = y - X_bar @ beta_tilde
    return float((xb @ r) / max(xb @ xb, 1e-30))


def saif_fused_eliminated(X, y, parent, lam: float,
                          config: SaifConfig = SaifConfig()
                          ) -> Tuple[np.ndarray, SaifResult]:
    """Legacy least-squares route: eliminate b exactly, solve a plain
    LASSO. Parity oracle for the unpenalized-slot path (DESIGN.md §7)."""
    if config.loss != "least_squares":
        raise ValueError("exact b-elimination is least-squares only; "
                         "saif_fused handles general losses")
    tree = build_tree(np.asarray(parent))
    X_bar, xb = transform_design(np.asarray(X), tree)
    Xp, yp = eliminate_b_ls(X_bar, xb, np.asarray(y, X_bar.dtype))
    res = saif(jnp.asarray(Xp), jnp.asarray(yp), lam, config)
    beta_tilde = np.asarray(res.beta)
    b = recover_b_ls(X_bar, xb, np.asarray(y, X_bar.dtype), beta_tilde)
    return recover_beta(beta_tilde, b, tree), res


def fused_objective(X, y, parent, beta, lam,
                    loss: str = "least_squares") -> float:
    """Direct evaluation of (17) for validation — any smooth loss."""
    tree = build_tree(np.asarray(parent))
    lo = get_loss(loss)
    beta = jnp.asarray(beta)
    z = jnp.asarray(X) @ beta
    pen = jnp.sum(jnp.abs(beta[jnp.asarray(tree.edge_child)] -
                          beta[jnp.asarray(tree.parent[tree.edge_child])]))
    return float(jnp.sum(lo.value(z, jnp.asarray(y))) + lam * pen)
