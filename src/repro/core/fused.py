"""Tree fused LASSO via the column transform of Theorem 6.

Problem (17):  min_beta  sum_j f(x_j. beta, y_j) + lam ||D beta||_1,
where D has one row per edge of a tree G(F, E).

Theorem 6 construction, concretely: root the tree; new variables are
  beta_tilde_e = beta_child(e) - beta_parent(e)   (one per edge, penalized)
  b            = beta_root                        (unpenalized)
so beta_v = b + sum of beta_tilde along the root->v path, giving
  x_tilde_e = sum of x_v over the subtree below edge e      (transformed col)
  x_tilde_p = sum of all x_v                                (the b column)
and D T = [I 0]: the fused problem becomes a plain LASSO (18) in beta_tilde
with one unpenalized coordinate b.

For least squares the unpenalized b is eliminated *exactly* by projecting y
and every transformed column orthogonal to the b-column (standard partialled-
out regression), after which ANY LASSO solver — SAIF included — applies
unchanged and retains its safe guarantee. Theorem 7's tau-projection is what
`duality.feasible_dual` already performs on the reduced problem.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.saif import SaifConfig, saif
from repro.core.cm import solve_lasso_cm
from repro.core.losses import get_loss


class TreeTransform(NamedTuple):
    """Static description of the Theorem-6 transform for a given tree."""
    parent: np.ndarray        # (p,) parent[v] = parent node id, -1 at root
    edge_child: np.ndarray    # (p-1,) child node of edge e
    topo: np.ndarray          # (p,) nodes in topological (root-first) order
    root: int


def build_tree(parent: np.ndarray) -> TreeTransform:
    parent = np.asarray(parent, np.int64)
    (roots,) = np.where(parent < 0)
    if len(roots) != 1:
        raise ValueError("parent array must encode exactly one root")
    root = int(roots[0])
    p = len(parent)
    # topological order via BFS from root
    children: list[list[int]] = [[] for _ in range(p)]
    for v, pa in enumerate(parent):
        if pa >= 0:
            children[pa].append(v)
    topo, stack = [], [root]
    while stack:
        v = stack.pop()
        topo.append(v)
        stack.extend(children[v])
    if len(topo) != p:
        raise ValueError("parent array does not encode a connected tree")
    edge_child = np.asarray([v for v in range(p) if v != root], np.int64)
    return TreeTransform(parent=parent, edge_child=edge_child,
                         topo=np.asarray(topo, np.int64), root=root)


def transform_design(X: np.ndarray, tree: TreeTransform
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (X_bar (n, p-1) edge columns, xb (n,) the b column).

    x_tilde for edge e = subtree sum of X columns below e: accumulate child
    into parent in reverse topological order.
    """
    X = np.asarray(X)
    sub = X.copy()                      # sub[:, v] accumulates subtree sums
    for v in tree.topo[::-1]:
        pa = tree.parent[v]
        if pa >= 0:
            sub[:, pa] += sub[:, v]
    xb = sub[:, tree.root].copy()
    X_bar = sub[:, tree.edge_child]
    return X_bar, xb


def recover_beta(beta_tilde: np.ndarray, b: float,
                 tree: TreeTransform) -> np.ndarray:
    """beta = T [beta_tilde; b]: prefix-sum the edge deltas down the tree."""
    p = len(tree.parent)
    edge_of_child = np.full(p, -1, np.int64)
    edge_of_child[tree.edge_child] = np.arange(p - 1)
    beta = np.zeros(p)
    for v in tree.topo:
        pa = tree.parent[v]
        if pa < 0:
            beta[v] = b
        else:
            beta[v] = beta[pa] + beta_tilde[edge_of_child[v]]
    return beta


def eliminate_b_ls(X_bar: np.ndarray, xb: np.ndarray, y: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Least-squares exact elimination of the unpenalized coordinate b.

    min_b 0.5||X_bar bt + xb b - y||^2 is quadratic in b; substituting the
    minimizer projects everything orthogonal to xb.
    """
    q = xb / max(np.linalg.norm(xb), 1e-30)
    Xp = X_bar - np.outer(q, q @ X_bar)
    yp = y - q * (q @ y)
    return Xp, yp


def recover_b_ls(X_bar, xb, y, beta_tilde) -> float:
    r = y - X_bar @ beta_tilde
    return float((xb @ r) / max(xb @ xb, 1e-30))


def saif_fused(X, y, parent, lam: float,
               config: SaifConfig = SaifConfig()) -> Tuple[np.ndarray, object]:
    """Solve tree fused LASSO (least squares) with SAIF. Returns (beta, result)."""
    if config.loss != "least_squares":
        raise NotImplementedError(
            "fused LASSO is wired for least squares (see DESIGN.md §6); "
            "the transform itself is loss-agnostic")
    tree = build_tree(np.asarray(parent))
    X_bar, xb = transform_design(np.asarray(X), tree)
    Xp, yp = eliminate_b_ls(X_bar, xb, np.asarray(y, X_bar.dtype))
    res = saif(jnp.asarray(Xp), jnp.asarray(yp), lam, config)
    beta_tilde = np.asarray(res.beta)
    b = recover_b_ls(X_bar, xb, np.asarray(y, X_bar.dtype), beta_tilde)
    return recover_beta(beta_tilde, b, tree), res


def fused_baseline_cm(X, y, parent, lam: float, tol: float = 1e-9
                      ) -> np.ndarray:
    """Unscreened fused solve (the 'CVX' stand-in baseline for Fig 7)."""
    tree = build_tree(np.asarray(parent))
    X_bar, xb = transform_design(np.asarray(X), tree)
    Xp, yp = eliminate_b_ls(X_bar, xb, np.asarray(y, X_bar.dtype))
    beta_tilde = np.asarray(
        solve_lasso_cm(get_loss("least_squares"), jnp.asarray(Xp),
                       jnp.asarray(yp), lam, tol=tol))
    b = recover_b_ls(X_bar, xb, np.asarray(y, X_bar.dtype), beta_tilde)
    return recover_beta(beta_tilde, b, tree)


def fused_objective(X, y, parent, beta, lam) -> float:
    """Direct evaluation of (17) for validation."""
    tree = build_tree(np.asarray(parent))
    r = np.asarray(X) @ beta - np.asarray(y)
    pen = np.abs(beta[tree.edge_child] -
                 beta[tree.parent[tree.edge_child]]).sum()
    return float(0.5 * (r @ r) + lam * pen)
