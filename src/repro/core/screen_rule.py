"""ScreenRule — pluggable certificate geometry for the SAIF screens (ISSUE 9).

PRs 1-8 made the screening *kernels* fast (fused Pallas, one-gemm batched,
certified mixed precision) but every solve still used the one Theorem-2
sphere rule. This module splits the remaining axis: the **rule** decides
the certificate geometry — which ball is screened against, what bound form
the ADD phase uses, and whether the final stop must pass a safe post-check
— while the **backend** (:mod:`repro.core.screen_backend`) only computes
bounds fast. Three rules ship (DESIGN.md §13):

``saif``
    Today's default, bitwise-unchanged: the gap-safe ball intersected with
    the Theorem-2 sequential ball (Eq. 12), the delta radius ramp on the
    ADD stop, no post-check. Every decision is safe per step.

``gap_safe``
    The Fercoq-Gramfort-Salmon gap sphere alone: identical engine trace to
    ``saif`` minus the sequential-ball intersection (the gap radius is
    derived from the fused dual/gap tail every InnerBackend already
    maintains, so the rule costs nothing extra per step). Strictly safe;
    preferable on warm lambda-path steps where the entry gap is tiny and
    the Theorem-2 ball adds only arithmetic.

``hybrid``
    The Zeng-Yang-Breheny safe-strong composition adapted to SAIF's
    incremental loop: the ADD phase screens with the **point** bound
    (radius 0 — pure KKT violation at the current dual iterate, the
    aggressive strong-rule analogue), stops recruiting as soon as no
    feature violates, and skips the delta ramp entirely; the solver then
    polishes, and the final stop is gated by a vectorized **safe
    post-check** — one full screen at the certified gap-safe radius. Any
    violator denies the stop and is recruited on the spot (the in-loop
    ``lax.cond`` fallback to the safe certificate), so the SAIF safety
    guarantee is preserved by construction: no solve can terminate
    without a passing safe certificate. DELs stay on the safe ball at
    every step under every rule.

This module is deliberately import-light (no jax): ``ScreenRule`` and
:func:`resolve_screen_rule` are part of the PEP-562 lazy public surface
(``from repro import ScreenRule`` must not pull the engines in).
"""
from __future__ import annotations

import dataclasses
from typing import Union

VALID_BOUNDS = ("ball", "point")


@dataclasses.dataclass(frozen=True)
class ScreenRule:
    """Certificate geometry of a screening rule (DESIGN.md §13).

    The engine consumes exactly four facts:

    * ``use_seq_ball`` — intersect the Theorem-2 sequential ball into the
      per-step safe region (``saif`` only; composed with the driver-level
      gates that already disable the seq ball for weighted / unpenalized
      problems);
    * ``add_bound`` — the bound form of the ADD-phase screen: ``"ball"``
      evaluates ``ub_i = |x_i^T c| + ||x_i|| r`` at the (delta-shrunk)
      safe radius, ``"point"`` at radius 0 (``ub_i = |x_i^T c|``, the
      strong-rule analogue — ADD decisions are then *unsafe-aggressive*
      and must be covered by a post-check before the solve may stop);
    * ``post_check`` — the final stop additionally requires one full
      screen at the **unshrunk** safe radius to certify no feature was
      wrongly discarded; violators deny the stop and are recruited
      (the safe fallback);
    * ``delta_ramp`` — whether the ADD stop walks the paper's delta
      radius ramp (point-bound rules stop recruiting immediately);
    * ``newton_polish`` — once recruiting quiesces, propose the exact
      working-set solution from the gram carry (one masked solve of
      ``G b = rho - lam sign``) each polish step; the proposal is
      accepted only if the *official* duality gap certifies it beats the
      CM iterate, so a wrong sign pattern or singular working set just
      falls back to the CM burst — the certificate path is unchanged.
      Applied only where the quantities exist (least-squares loss with
      the ``gram`` inner backend); elsewhere the rule degrades to plain
      CM polish.

    Safety invariant: ``add_bound == "point"`` requires ``post_check``
    (enforced in ``__post_init__``) — an aggressive discard without a
    safe gate on termination would forfeit the SAIF guarantee.
    """
    name: str
    use_seq_ball: bool = True
    add_bound: str = "ball"
    post_check: bool = False
    delta_ramp: bool = True
    newton_polish: bool = False

    def __post_init__(self):
        if self.add_bound not in VALID_BOUNDS:
            raise ValueError(
                f"add_bound must be one of {VALID_BOUNDS}, "
                f"got {self.add_bound!r}")
        if self.add_bound == "point" and not self.post_check:
            raise ValueError(
                "add_bound='point' discards aggressively (strong-rule "
                "semantics); it requires post_check=True so termination "
                "is gated by a safe certificate")


SCREEN_RULES = {
    "saif": ScreenRule("saif", use_seq_ball=True, add_bound="ball",
                       post_check=False, delta_ramp=True),
    "gap_safe": ScreenRule("gap_safe", use_seq_ball=False, add_bound="ball",
                           post_check=False, delta_ramp=True),
    "hybrid": ScreenRule("hybrid", use_seq_ball=False, add_bound="point",
                         post_check=True, delta_ramp=False,
                         newton_polish=True),
}


def resolve_screen_rule(rule: Union[str, ScreenRule]) -> ScreenRule:
    """Rule-selection policy: a name resolves through the registry, a
    :class:`ScreenRule` instance passes through (custom geometries keep
    the same seam the built-ins use)."""
    if isinstance(rule, ScreenRule):
        return rule
    try:
        return SCREEN_RULES[rule]
    except (KeyError, TypeError):
        raise ValueError(
            f"unknown screen rule {rule!r}: expected one of "
            f"{sorted(SCREEN_RULES)} or a ScreenRule instance") from None
