"""Core SAIF library — the paper's contribution in JAX.

Public API:
  saif, SaifConfig, SaifResult           — Algorithm 1/2
  saif_path                              — warm-started lambda path (Sec 5.3)
  saif_batch                             — lockstep fleet solves (DESIGN §8)
  cv_path                                — K-fold CV lambda selection (§8)
  dynamic_screening                      — gap-safe dynamic baseline
  sequential_path                        — DPP-style sequential baseline
  homotopy_path                          — unsafe strong-rule baseline (Table 1)
  saif_fused / fused_baseline_cm         — tree fused LASSO (Sec 4)
  solve_lasso_cm                         — unscreened oracle solver
"""
from repro.core.batch import (prepare_fleet, saif_batch,
                              saif_batch_compile_count)
from repro.core.cv import CVPathResult, cv_path, kfold_weights
from repro.core.cm import gram_epochs, solve_lasso_cm, soft_threshold
from repro.core.dynamic import DynConfig, dynamic_screening
from repro.core.group import (GroupSaifConfig, group_lambda_max, group_saif,
                              solve_group_lasso_bcd)
from repro.core.fused import (FusedDesign, FusedPathResult, build_schedule,
                              build_tree, fused_baseline_cm,
                              fused_lambda_max, fused_objective, fused_path,
                              prepare_fused, recover_beta,
                              recover_beta_device, recover_from_transformed,
                              saif_fused, saif_fused_eliminated,
                              transform_design, transform_design_device,
                              transform_design_scan)
from repro.core.homotopy import HomotopyConfig, homotopy_path, support_metrics
from repro.core.losses import get_loss, least_squares, logistic
from repro.core.path import (PathState, SaifPathResult, lambda_grid,
                             prepare_path, saif_path, saif_path_naive)
from repro.core.inner_backend import (InnerBackend, InnerCarry, InnerOut,
                                      make_inner_gram, make_inner_jnp,
                                      make_inner_pallas,
                                      resolve_inner_backend)
from repro.core.saif import (SaifConfig, SaifResult, saif,
                             saif_jit_compile_count)
from repro.core.screen_backend import (ScreenFn, ScreenOut, make_screen_jnp,
                                       make_screen_pallas, resolve_backend)
from repro.core.sequential import SeqConfig, sequential_path

__all__ = [
    "saif", "SaifConfig", "SaifResult", "saif_path", "saif_path_naive",
    "SaifPathResult", "PathState", "prepare_path", "lambda_grid",
    "saif_batch", "saif_batch_compile_count", "prepare_fleet",
    "cv_path", "CVPathResult", "kfold_weights",
    "saif_jit_compile_count", "ScreenFn", "ScreenOut", "make_screen_jnp",
    "make_screen_pallas", "resolve_backend",
    "InnerBackend", "InnerCarry", "InnerOut", "make_inner_jnp",
    "make_inner_gram", "make_inner_pallas", "resolve_inner_backend",
    "gram_epochs",
    "dynamic_screening", "DynConfig", "sequential_path", "SeqConfig",
    "homotopy_path", "HomotopyConfig", "support_metrics",
    "group_saif", "GroupSaifConfig", "group_lambda_max",
    "solve_group_lasso_bcd",
    "saif_fused", "saif_fused_eliminated", "fused_baseline_cm",
    "fused_objective", "fused_path", "fused_lambda_max", "FusedDesign",
    "FusedPathResult", "prepare_fused", "build_tree", "build_schedule",
    "transform_design", "transform_design_scan", "transform_design_device",
    "recover_beta", "recover_beta_device", "recover_from_transformed",
    "solve_lasso_cm", "soft_threshold",
    "get_loss", "least_squares", "logistic",
]
