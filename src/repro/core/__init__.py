"""Core SAIF library — the paper's contribution in JAX.

Primary surface (DESIGN.md §9):
  Problem, open_session, Session          — declarative spec + serving
  Scalar, Path, Fleet, CV                 — the request types
  saif, SaifConfig, SaifResult            — one-shot Algorithm 1/2

Legacy frontends (deprecated shims over one-shot sessions; each warns
once per process — migration table in DESIGN.md §9):
  saif_path, saif_batch, cv_path          — path / fleet / K-fold CV
  saif_fused, fused_path, group_saif      — fused and group penalties

Attributes resolve lazily (PEP 562): importing :mod:`repro.core` pulls in
no jax-heavy engine until the name is actually touched, so
``from repro import Problem, open_session`` stays cheap. ``from
repro.core import <name>`` keeps working for every pre-session export.
"""
from __future__ import annotations

import importlib
import sys
import types

# name -> defining module (resolved on first attribute access)
_EXPORTS = {
    # unified serving API (DESIGN.md §9)
    "Problem": "repro.core.api", "Session": "repro.core.api",
    "open_session": "repro.core.api",
    "Scalar": "repro.core.api", "Path": "repro.core.api",
    "Fleet": "repro.core.api", "CV": "repro.core.api",
    "lasso": "repro.core.api",
    "LassoPenalty": "repro.core.api", "FusedPenalty": "repro.core.api",
    "GroupPenalty": "repro.core.api",
    "GroupPathResult": "repro.core.api",
    "CompileStats": "repro.core.api",
    "unified_compile_count": "repro.core.api",
    # NOTE: the fused(parent)/group(gsize) penalty factories are NOT
    # re-exported here — they would shadow the repro.core.fused /
    # repro.core.group submodules. Use repro.fused / repro.group (the
    # top-level surface) or repro.core.api.fused / .group.

    # fault-tolerant serving runtime (DESIGN.md §10)
    "open_serving": "repro.core.serving",
    "ServingSession": "repro.core.serving",
    "ServingConfig": "repro.core.serving",
    "ServingResult": "repro.core.serving",
    "ServingStats": "repro.core.serving",
    "Verdict": "repro.core.serving", "Rung": "repro.core.serving",
    "ServingError": "repro.core.serving",
    "RequestError": "repro.core.serving",
    "NumericalError": "repro.core.serving",
    "BackendFault": "repro.core.serving",
    "DeadlineExceeded": "repro.core.serving",
    "validate_problem": "repro.core.serving",
    "validate_request": "repro.core.serving",

    # streaming & model selection (DESIGN.md §14; import-light)
    "Update": "repro.core.online",
    "online_compile_count": "repro.core.online",
    "Select": "repro.core.select",
    "SelectionReport": "repro.core.select",
    "select_solve": "repro.core.select",
    "subsample_weights": "repro.core.select",
    "WarmCache": "repro.core.warm_cache",
    "WarmCacheConfig": "repro.core.warm_cache",
    "WarmCacheStats": "repro.core.warm_cache",
    "problem_digest": "repro.core.warm_cache",

    # serial solver
    "saif": "repro.core.saif", "solve_scalar": "repro.core.saif",
    "SaifConfig": "repro.core.saif", "SaifResult": "repro.core.saif",
    "saif_jit_compile_count": "repro.core.saif",
    "PathState": "repro.core.saif", "prepare_path": "repro.core.saif",

    # path engine
    "run_path": "repro.core.path", "saif_path": "repro.core.path",
    "saif_path_naive": "repro.core.path",
    "SaifPathResult": "repro.core.path", "lambda_grid": "repro.core.path",

    # fleet engine
    "fleet_solve": "repro.core.batch", "saif_batch": "repro.core.batch",
    "saif_batch_compile_count": "repro.core.batch",
    "prepare_fleet": "repro.core.batch",

    # cross-validation
    "cv_solve": "repro.core.cv", "cv_path": "repro.core.cv",
    "CVPathResult": "repro.core.cv", "kfold_weights": "repro.core.cv",
    "one_se_lambda": "repro.core.cv",

    # oracle / inner machinery
    "solve_lasso_cm": "repro.core.cm", "soft_threshold": "repro.core.cm",
    "gram_epochs": "repro.core.cm",
    "InnerBackend": "repro.core.inner_backend",
    "InnerCarry": "repro.core.inner_backend",
    "InnerOut": "repro.core.inner_backend",
    "make_inner_jnp": "repro.core.inner_backend",
    "make_inner_gram": "repro.core.inner_backend",
    "make_inner_pallas": "repro.core.inner_backend",
    "resolve_inner_backend": "repro.core.inner_backend",

    # screening backends
    "ScreenFn": "repro.core.screen_backend",
    "ScreenOut": "repro.core.screen_backend",
    "make_screen_jnp": "repro.core.screen_backend",
    "make_screen_pallas": "repro.core.screen_backend",
    "resolve_backend": "repro.core.screen_backend",

    # screening rules (certificate geometry, DESIGN.md §13; import-light)
    "ScreenRule": "repro.core.screen_rule",
    "SCREEN_RULES": "repro.core.screen_rule",
    "resolve_screen_rule": "repro.core.screen_rule",

    # baselines
    "dynamic_screening": "repro.core.dynamic",
    "DynConfig": "repro.core.dynamic",
    "sequential_path": "repro.core.sequential",
    "SeqConfig": "repro.core.sequential",
    "homotopy_path": "repro.core.homotopy",
    "HomotopyConfig": "repro.core.homotopy",
    "support_metrics": "repro.core.homotopy",

    # group subsystem
    "group_saif": "repro.core.group", "group_solve": "repro.core.group",
    "GroupSaifConfig": "repro.core.group",
    "GroupSaifResult": "repro.core.group",
    "group_lambda_max": "repro.core.group",
    "group_compile_count": "repro.core.group",
    "prepare_group": "repro.core.group",
    "solve_group_lasso_bcd": "repro.core.group",

    # fused subsystem
    "saif_fused": "repro.core.fused",
    "saif_fused_eliminated": "repro.core.fused",
    "fused_baseline_cm": "repro.core.fused",
    "fused_objective": "repro.core.fused",
    "fused_path": "repro.core.fused",
    "fused_lambda_max": "repro.core.fused",
    "FusedDesign": "repro.core.fused",
    "FusedPathResult": "repro.core.fused",
    "prepare_fused": "repro.core.fused",
    "build_tree": "repro.core.fused", "build_schedule": "repro.core.fused",
    "transform_design": "repro.core.fused",
    "transform_design_scan": "repro.core.fused",
    "transform_design_device": "repro.core.fused",
    "recover_beta": "repro.core.fused",
    "recover_beta_device": "repro.core.fused",
    "recover_from_transformed": "repro.core.fused",

    # losses
    "get_loss": "repro.core.losses",
    "least_squares": "repro.core.losses",
    "logistic": "repro.core.losses",
}

_SUBMODULES = {
    "active_set", "api", "batch", "cm", "cv", "duality", "dynamic",
    "fused", "group", "homotopy", "inner_backend", "losses", "online",
    "path", "saif", "screen_backend", "screen_rule", "select",
    "sequential", "serving", "warm_cache",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    if name in _EXPORTS:
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.core.{name}")
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")


def __dir__():
    return sorted(set(__all__) | _SUBMODULES | set(globals()))


class _LazyCoreModule(types.ModuleType):
    """Keeps ``from repro.core import saif`` resolving to the *function*.

    ``saif`` is both a submodule name and a public export; the import
    machinery sets the submodule as a package attribute at first load,
    which would then shadow the PEP 562 ``__getattr__`` above. Dropping
    exactly that setattr keeps every access on the lazy resolver (only
    docstrings ever reference ``repro.core.saif`` dotted; code uses
    ``from repro.core.saif import ...``, which goes through sys.modules
    and is unaffected).
    """

    def __setattr__(self, name, value):
        if name == "saif" and isinstance(value, types.ModuleType):
            return
        super().__setattr__(name, value)


sys.modules[__name__].__class__ = _LazyCoreModule
