"""Checkpointing: atomic, resumable, elastic.

Layout: <dir>/step_<N>/  with one .npy per flattened leaf + meta.json
(treedef paths, step, data cursor, config digest). Writes go to a temp dir
then os.replace() — a crash mid-flush never corrupts the latest checkpoint.
``save_async`` flushes on a daemon thread (training continues).

Elastic resume: arrays are restored host-side then ``jax.device_put`` onto
whatever sharding the *current* mesh prescribes — restoring a 512-chip
checkpoint onto 256 chips (or vice versa) is just a different device_put.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out, treedef


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(ckpt_dir: str, step: int, tree: Any,
         extra: Optional[Dict[str, Any]] = None) -> str:
    """Synchronous atomic checkpoint write. Returns the final path."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat, _ = _flatten_with_paths(tree)
    names = []
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"arr_{i:05d}.npy"), arr)
        names.append(key)
    meta = {"step": step, "names": names, "extra": extra or {}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _fsync_dir(ckpt_dir)
    _gc(ckpt_dir, keep=3)
    return final


def _fsync_dir(path: str):
    """Flush the directory entry so the atomic rename survives power loss
    (the rename itself is atomic; its durability needs the parent dir
    synced)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:         # pragma: no cover - exotic filesystems
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


_pending: list[threading.Thread] = []


def save_async(ckpt_dir: str, step: int, tree: Any,
               extra: Optional[Dict[str, Any]] = None) -> threading.Thread:
    """Device->host copy happens now; disk flush on a daemon thread."""
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    t = threading.Thread(target=save, args=(ckpt_dir, step, host_tree, extra),
                         daemon=True)
    t.start()
    _pending.append(t)
    return t


def wait_pending():
    for t in _pending:
        t.join()
    _pending.clear()


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def load_meta(ckpt_dir: str, step: int) -> Dict[str, Any]:
    """Read a checkpoint's meta.json (names, step, extra) without
    touching the arrays — callers that must *reconstruct* the ``like``
    tree before :func:`restore` (e.g. the serving runtime's warm-state
    restore, which records leaf shapes/dtypes in ``extra``) peek here."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        return json.load(f)


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Any = None) -> tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``like``; reshard onto ``shardings``
    (a matching pytree of NamedSharding, or None for default placement)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    flat_like, treedef = _flatten_with_paths(like)
    order = {k: i for i, k in enumerate(sorted(flat_like))}
    assert set(meta["names"]) == set(order), (
        "checkpoint structure mismatch: "
        f"{set(meta['names']) ^ set(order)}")
    arrays = {}
    for i, key in enumerate(sorted(flat_like)):
        arr = np.load(os.path.join(path, f"arr_{i:05d}.npy"))
        arrays[key] = arr

    leaves_sorted_keys = sorted(flat_like)
    flat_sh = None
    if shardings is not None:
        flat_sh, _ = _flatten_with_paths(shardings)

    restored = {}
    for key in leaves_sorted_keys:
        a = arrays[key]
        like_leaf = flat_like[key]
        a = a.astype(like_leaf.dtype) if hasattr(like_leaf, "dtype") else a
        if flat_sh is not None:
            restored[key] = jax.device_put(a, flat_sh[key])
        else:
            restored[key] = jax.device_put(a)

    # rebuild in original tree order
    flat_paths, treedef2 = jax.tree_util.tree_flatten_with_path(like)
    out_leaves = []
    for p, _ in flat_paths:
        key = "/".join(_path_str(x) for x in p)
        out_leaves.append(restored[key])
    return jax.tree_util.tree_unflatten(treedef2, out_leaves), meta["extra"]


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
