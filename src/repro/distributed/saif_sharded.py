"""Multi-pod SAIF: feature-parallel screening via shard_map (DESIGN.md §5).

The cost profile of SAIF (Theorem 5) is: CM epochs on a tiny active block
(O(p̄) work) + an O(p) screening scan. At cluster scale the scan is the ONLY
term that touches the full feature set, so it is the ONLY term we shard:

  * X is partitioned column-wise across ALL mesh devices (the 'feature'
    axis = every axis of the mesh, flattened — 512 shards on the production
    mesh). Each device owns X_local (n, p/devs) and its column norms.
  * screen: each device computes |X_local^T theta| (+ ball arithmetic) and
    reduces to (local top-h candidates, local max-ub). One tiny all_gather
    of h*(score, id) pairs + a pmax — 512 * h * 8 bytes on the wire instead
    of p * 4. The active block (n x k_max) and the CM sweeps are replicated:
    redundant FLOPs, zero collectives, which is the right trade at p >> p̄.
  * for tall problems the sample dim additionally shards over 'data' with a
    psum for the n-dim dots (samples_sharded=True).

``saif_distributed`` plugs the sharded scan into the identical Algorithm-1
loop from ``repro.core.saif`` — same math, same tests, different iron.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


class ShardedDesign(NamedTuple):
    X: jax.Array          # (n, p_pad) feature-sharded on all mesh axes
    col_norm: jax.Array   # (p_pad,)
    c0: jax.Array         # (p_pad,) |X^T f'(0)|
    p: int                # true feature count (p_pad >= p)
    mesh: Mesh


def _feature_axes(mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def shard_design(X, y_grad0, mesh) -> ShardedDesign:
    """Pad p to a multiple of the device count and place the shards."""
    n, p = X.shape
    devs = int(np.prod(list(mesh.shape.values())))
    p_pad = -(-p // devs) * devs
    Xp = jnp.pad(jnp.asarray(X), ((0, 0), (0, p_pad - p)))
    axes = _feature_axes(mesh)
    x_sh = NamedSharding(mesh, P(None, axes))
    v_sh = NamedSharding(mesh, P(axes))
    Xp = jax.device_put(Xp, x_sh)
    col_norm = jax.device_put(jnp.linalg.norm(Xp, axis=0), v_sh)
    c0 = jax.device_put(jnp.abs(Xp.T @ y_grad0), v_sh)
    return ShardedDesign(X=Xp, col_norm=col_norm, c0=c0, p=p, mesh=mesh)


def make_sharded_scan(design: ShardedDesign):
    """Returns scan_fn(theta) -> |X^T theta| (p_pad,), sharded end-to-end.

    Legacy bare-scan hook: pass as ``saif(..., scan_fn=...)`` and
    ``repro.core.screen_backend.make_screen_from_scan`` adapts it to the
    full backend interface in-trace (the production path uses the fused
    :func:`make_sharded_screen` instead). The output stays device-sharded;
    downstream top_k/max run as sharded reductions XLA lowers to the
    gather-of-partials pattern described above.
    """
    mesh = design.mesh
    axes = _feature_axes(mesh)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(None, axes), P(None)),
        out_specs=P(axes))
    def scan(X_local, theta):
        return jnp.abs(X_local.T @ theta)

    def scan_fn(theta):
        out = scan(design.X, theta)
        # padding columns are all-zero => score 0; mask them so they are
        # never recruited
        if design.p != design.X.shape[1]:
            idx = jnp.arange(design.X.shape[1])
            out = jnp.where(idx < design.p, out, -jnp.inf)
        return out
    return scan_fn


def make_sharded_screen(design: ShardedDesign, h: int):
    """Sharded :class:`~repro.core.screen_backend.ScreenFn` — the backend
    interface of ``repro.core.saif._saif_jit``, same math as the jnp and
    Pallas backends, sharded iron.

    One shard_map computes, per device: local masked scores, local ub, the
    local top-h candidates with global ids, and the pmax of ub. The gathered
    devs*h candidate pairs are merged with one small top_k; the violation
    counts stream over the still-sharded (p_pad,) ub vector (searchsorted
    against the h sorted bounds + bincount — no O(p) gather, no O(p log p)
    sort; XLA lowers the (h+1,)-sized reductions to a tiny psum).
    """
    from repro.core.screen_backend import (ScreenOut, survivor_count,
                                           violation_ge_counts)

    mesh = design.mesh
    axes = _feature_axes(mesh)
    devs = int(np.prod(list(mesh.shape.values())))
    p_pad = design.X.shape[1]
    p_local = p_pad // devs
    k = min(h, p_local)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(None, axes), P(axes), P(None), P(), P(axes)),
        out_specs=(P(axes), P(axes), P(axes), P()))
    def local(X_local, norm_local, theta, r, excl_local):
        ax_index = sum(jax.lax.axis_index(a) *
                       int(np.prod([mesh.shape[b]
                                    for b in axes[axes.index(a) + 1:]]))
                       for a in axes)
        offset = ax_index * p_local
        scores = jnp.abs(X_local.T @ theta)               # (p_local,)
        # exclusions: current actives + the padding columns beyond true p
        pad_col = offset + jnp.arange(p_local) >= design.p
        masked = jnp.where(excl_local | pad_col, -jnp.inf, scores)
        ub = masked + norm_local * r
        top_s, top_i = jax.lax.top_k(masked, k)
        if k < h:
            top_s = jnp.pad(top_s, (0, h - k), constant_values=-jnp.inf)
            top_i = jnp.pad(top_i, (0, h - k))
        gid = top_i + offset
        max_ub = jax.lax.pmax(jnp.max(ub), axes)
        return top_s, gid.astype(jnp.int32), ub, max_ub

    def screen(theta, r, in_active):
        r = jnp.asarray(r, design.X.dtype)
        ts, gid, ub, max_ub = local(design.X, design.col_norm, theta, r,
                                    jnp.asarray(in_active, bool))
        cand_score, pos = jax.lax.top_k(ts, h)   # merge devs*h candidates
        cand_idx = gid[pos]
        cand_lb = jnp.abs(cand_score - jnp.take(design.col_norm, cand_idx) * r)
        cand_ge = violation_ge_counts(ub, cand_lb)
        return ScreenOut(max_ub=max_ub, cand_score=cand_score,
                         cand_idx=cand_idx, cand_lb=cand_lb, cand_ge=cand_ge,
                         n_surv=survivor_count(ub))
    return screen


def make_sharded_screen_batch(design: ShardedDesign, h: int):
    """Batched sharded screen: the §5 collective serving a whole fleet.

    One shard_map round screens ALL B problems: each device computes its
    (B, p_local) masked-score block with a single local (B, n) x
    (n, p_local) matmul (the shared-X fast path on sharded iron), reduces
    per-problem local top-h and a per-problem pmax of ub, and the gathered
    devs*h candidate pairs merge per problem. Wire bytes per outer step:
    O(B * devs * h) for the candidates — B problems ride one collective
    instead of B of them (the batched ``saif_distributed`` economics,
    DESIGN.md §8). Per-problem column norms are supported (CV fleets), so
    the design carries the *shared* norms and the caller passes fleet
    norms explicitly when they differ.
    """
    from repro.core.screen_backend import (ScreenOut, survivor_count,
                                           violation_ge_counts)

    mesh = design.mesh
    axes = _feature_axes(mesh)
    devs = int(np.prod(list(mesh.shape.values())))
    p_pad = design.X.shape[1]
    p_local = p_pad // devs
    k = min(h, p_local)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(None, axes), P(axes), P(None, None), P(None),
                  P(None, axes)),
        out_specs=(P(None, axes), P(None, axes), P(None, axes), P(None)))
    def local(X_local, norm_local, Theta, r, excl_local):
        ax_index = sum(jax.lax.axis_index(a) *
                       int(np.prod([mesh.shape[b]
                                    for b in axes[axes.index(a) + 1:]]))
                       for a in axes)
        offset = ax_index * p_local
        scores = jnp.abs(Theta @ X_local)                 # (B, p_local)
        pad_col = offset + jnp.arange(p_local) >= design.p
        masked = jnp.where(excl_local | pad_col[None, :], -jnp.inf, scores)
        ub = masked + norm_local[None, :] * r[:, None]
        top_s, top_i = jax.lax.top_k(masked, k)           # (B, k)
        if k < h:
            top_s = jnp.pad(top_s, ((0, 0), (0, h - k)),
                            constant_values=-jnp.inf)
            top_i = jnp.pad(top_i, ((0, 0), (0, h - k)))
        gid = top_i + offset
        max_ub = jax.lax.pmax(jnp.max(ub, axis=1), axes)  # (B,)
        return top_s, gid.astype(jnp.int32), ub, max_ub

    def screen(Theta, r, in_active, do=None):
        # ``do`` (per-problem ADD gate) is unused: the collective runs for
        # the whole fleet whenever any problem screens — that is the point
        del do
        r = jnp.asarray(r, design.X.dtype)
        excl = jnp.asarray(in_active, bool)
        if excl.shape[1] != p_pad:                        # pad fleet masks
            excl = jnp.pad(excl, ((0, 0), (0, p_pad - excl.shape[1])),
                           constant_values=True)
        ts, gid, ub, max_ub = local(design.X, design.col_norm, Theta, r,
                                    excl)
        cand_score, pos = jax.lax.top_k(ts, h)            # (B, h) merge
        cand_idx = jnp.take_along_axis(gid, pos, axis=1)
        cand_lb = jnp.abs(cand_score -
                          jnp.take(design.col_norm, cand_idx) * r[:, None])
        cand_ge = jax.vmap(violation_ge_counts)(ub, cand_lb)
        return ScreenOut(max_ub=max_ub, cand_score=cand_score,
                         cand_idx=cand_idx, cand_lb=cand_lb,
                         cand_ge=cand_ge, n_surv=survivor_count(ub, axis=1))
    return screen


def fleet_solve_sharded(X, Y, lam, mesh, config=None,
                        inner_backend: str = None,
                        design: ShardedDesign = None,
                        screen_cache: dict = None):
    """Fleet SAIF with the feature-sharded screening collective: B lockstep
    solves whose O(p) scans ride one shard_map round per outer step.

    Same results as ``repro.core.batch.fleet_solve`` (which equals B serial
    solves); the active blocks, CM bursts and the per-problem Gram buffers
    replicate across the mesh exactly like the serial distributed driver —
    only the scan is sharded, now amortized over the fleet (DESIGN.md §8).
    Plain-LASSO fleets over one shared design (no sample weights: a CV
    fleet's per-fold column norms live on the replicated path for now).

    ``design``/``screen_cache`` mirror :func:`solve_scalar_sharded`: the
    session passes its cached placement and per-h batched-ScreenFn memo
    so a stream of sharded fleet requests shares one ``_saif_batch_jit``
    compilation per static key instead of recompiling on every fresh
    screen closure (the ScreenFn is a jit-static argument). The design's
    ``c0`` is ignored here — the fleet driver recomputes per-problem c0
    from ``Y`` — so one cached placement serves every response batch.
    """
    import dataclasses

    from repro.core.batch import fleet_batch_sizes, fleet_solve, prepare_fleet
    from repro.core.saif import SaifConfig

    config = config or SaifConfig()
    if inner_backend is not None:
        config = dataclasses.replace(config, inner_backend=inner_backend)
    if config.unpen_idx is not None:
        raise NotImplementedError("fused fleets are serial-only for now")
    X = jnp.asarray(X)
    Y = jnp.asarray(Y)
    if Y.ndim == 1:
        Y = Y[None, :]
    b = Y.shape[0]
    if design is None:
        design = fleet_design_for(X, Y, mesh, config)
    lam_arr = jnp.broadcast_to(jnp.asarray(lam, X.dtype).reshape(-1), (b,))
    # the screen's candidate width must equal the engine's static h, so
    # derive it through the EXACT code path the fleet driver uses on the
    # padded design (prepare_fleet's per-problem serial matvecs — a
    # differently-associated matmul here could land an ulp on a pow2
    # bucket boundary and break the kernel shapes)
    prep = prepare_fleet(design.X, Y, config)
    _, h = fleet_batch_sizes(prep, [float(l) for l in
                                    jax.device_get(lam_arr)], config)
    if screen_cache is not None and h in screen_cache:
        screen_fn = screen_cache[h]
    else:
        screen_fn = make_sharded_screen_batch(design, h)
        if screen_cache is not None:
            screen_cache[h] = screen_fn
    res = fleet_solve(design.X, Y, lam_arr, config, screen_fn=screen_fn)
    return res._replace(beta=res.beta[:, :design.p])


def saif_batch_distributed(X, Y, lam, mesh, config=None,
                           inner_backend: str = None):
    """DEPRECATED legacy frontend — one-shot session over
    :func:`fleet_solve_sharded`. Use ``repro.open_session(Problem(X),
    config, mesh=mesh).solve(Fleet(Y, lams, sharded=True))``
    (DESIGN.md §9)."""
    from repro.core._compat import warn_deprecated
    warn_deprecated("repro.distributed.saif_batch_distributed",
                    "session.solve(Fleet(Y, lams, sharded=True))")
    import dataclasses

    from repro.core.api import Fleet, Problem, open_session
    from repro.core.saif import SaifConfig

    config = config or SaifConfig()
    if inner_backend is not None:
        config = dataclasses.replace(config, inner_backend=inner_backend)
    sess = open_session(Problem(X=X, loss=config.loss), config, mesh=mesh)
    return sess.solve(Fleet(Y=Y, lams=lam, sharded=True))


class ScreenResult(NamedTuple):
    top_scores: jax.Array   # (h,)
    top_idx: jax.Array      # (h,) global feature ids
    max_ub: jax.Array       # scalar: max_i |x_i^T th| + ||x_i|| r


def make_fused_screen(design: ShardedDesign, h: int):
    """The production screening collective: local top-h + local max-ub,
    then one small all_gather — O(devs*h) wire bytes, not O(p)."""
    mesh = design.mesh
    axes = _feature_axes(mesh)
    devs = int(np.prod(list(mesh.shape.values())))
    p_local = design.X.shape[1] // devs

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(None, axes), P(axes), P(None), P()),
        out_specs=(P(axes), P(axes), P()))
    def screen(X_local, norm_local, theta, r):
        scores = jnp.abs(X_local.T @ theta)           # (p_local,)
        ub = scores + norm_local * r
        k = min(h, p_local)
        top_s, top_i = jax.lax.top_k(scores, k)
        if k < h:
            top_s = jnp.pad(top_s, (0, h - k), constant_values=-jnp.inf)
            top_i = jnp.pad(top_i, (0, h - k))
        # global ids: offset by this shard's position
        ax_index = sum(jax.lax.axis_index(a) *
                       int(np.prod([mesh.shape[b]
                                    for b in axes[axes.index(a) + 1:]]))
                       for a in axes)
        gid = top_i + ax_index * p_local
        max_ub = jax.lax.pmax(jnp.max(ub), axes)
        return top_s, gid.astype(jnp.int32), max_ub

    def fused(theta, r):
        s, i, mub = screen(design.X, design.col_norm, theta,
                           jnp.asarray(r, design.X.dtype))
        # merge the devs*h candidates (already gathered by out_specs P(axes))
        top_s, pos = jax.lax.top_k(s, h)
        return ScreenResult(top_scores=top_s, top_idx=i[pos], max_ub=mub)
    return fused


def fleet_design_for(X, Y, mesh, config) -> ShardedDesign:
    """Fleet placement: shard the design from a *representative* null
    gradient (the first response's). Only X and the column norms matter
    for fleet screening — per-problem c0 is recomputed from ``Y`` inside
    the fleet driver against the padded design — so one placement serves
    every response batch (the session caches it)."""
    from repro.core.losses import get_loss
    from repro.core.saif import SaifConfig

    config = config or SaifConfig()
    loss = get_loss(config.loss)
    Y = jnp.asarray(Y)
    y0 = Y if Y.ndim == 1 else Y[0]
    g0 = loss.grad(jnp.zeros_like(y0), y0)
    return shard_design(jnp.asarray(X), g0, mesh)


def design_for(X, y, mesh, config) -> ShardedDesign:
    """Build the feature-sharded design from the penalized-null gradient:
    f'(0) for plain LASSO; at the unpenalized slot's partial optimum for
    fused problems (Thm 7, DESIGN.md §7) — the same construction the
    serial driver uses internally, so every h derived from the sharded
    c0 matches the solver's static h exactly. The one-time placement a
    session performs at its first sharded request and then reuses."""
    from repro.core.duality import null_gradient
    from repro.core.losses import get_loss
    from repro.core.saif import SaifConfig

    config = config or SaifConfig()
    loss = get_loss(config.loss)
    y = jnp.asarray(y)
    X = jnp.asarray(X)
    g0, _, _ = null_gradient(loss, X, y, config.unpen_idx)
    return shard_design(X, g0, mesh)


def solve_scalar_sharded(X, y, lam: float, mesh, config=None,
                         inner_backend: str = None,
                         design: ShardedDesign = None,
                         screen_cache: dict = None,
                         prep=None):
    """SAIF with the sharded screening backend. Same result as core.saif.

    The inner solver is NOT sharded (the active block is replicated — see
    the module docstring), so every inner backend from
    ``repro.core.inner_backend`` composes with the sharded screen: the
    ``gram`` engine's (k_max, k_max) buffers replicate like the active
    block (tiny next to X), and its ADD-time column refresh gathers only
    the <= h touched columns of the feature-sharded X — an O(n h) fetch,
    not O(n p). ``inner_backend`` overrides ``config.inner_backend``
    (resolution happens in the core driver against the *padded* problem
    shape, so "auto" is deterministic across mesh sizes).

    ``design``/``screen_cache``/``prep`` are the session hooks: a
    prebuilt :class:`ShardedDesign` skips the one-time placement, a
    prebuilt :class:`~repro.core.saif.PathState` over the *padded*
    design skips the per-request O(np) preparation, and the per-h screen
    memo keeps the ScreenFn *object* stable across requests — the
    function is a jit-static argument of ``_saif_jit``, so a fresh
    closure per request would defeat the one-compilation-per-static-key
    contract.
    """
    import dataclasses

    from repro.core.saif import (SaifConfig, add_batch_size, prepare_path,
                                 solve_scalar)

    config = config or SaifConfig()
    if inner_backend is not None:
        config = dataclasses.replace(config, inner_backend=inner_backend)
    y = jnp.asarray(y)
    if design is None:
        design = design_for(X, y, mesh, config)
    # X itself is also consumed (gathers of active columns, duality gap);
    # padded to p_pad, so run SAIF on the padded problem — padding columns
    # are screened out by the backend; beta padding is sliced off.
    # h must match what saif() derives for the padded problem (same c0,
    # same p_pad), so the backend's candidate count lines up with the
    # solver's static h.
    c0 = design.c0
    if config.unpen_idx is not None:
        c0 = c0.at[config.unpen_idx].set(0.0)
    h = add_batch_size(config.c, lam, c0, design.X.shape[1])
    if screen_cache is not None and h in screen_cache:
        screen_fn = screen_cache[h]
    else:
        screen_fn = make_sharded_screen(design, h)
        if screen_cache is not None:
            screen_cache[h] = screen_fn
    if prep is None:
        prep = prepare_path(design.X, y, config)
    res = solve_scalar(prep, lam, config, screen_fn=screen_fn)
    return res._replace(beta=res.beta[:design.p])


def saif_distributed(X, y, lam: float, mesh, config=None,
                     inner_backend: str = None):
    """DEPRECATED legacy frontend — one-shot session over
    :func:`solve_scalar_sharded`. Use ``repro.open_session(Problem(X, y),
    config, mesh=mesh).solve(Scalar(lam, sharded=True))`` (DESIGN.md §9).
    """
    from repro.core._compat import warn_deprecated
    warn_deprecated("repro.distributed.saif_distributed",
                    "session.solve(Scalar(lam, sharded=True))")
    import dataclasses

    from repro.core.api import Problem, Scalar, open_session
    from repro.core.saif import SaifConfig

    config = config or SaifConfig()
    if inner_backend is not None:
        config = dataclasses.replace(config, inner_backend=inner_backend)
    sess = open_session(Problem(X=X, y=y, loss=config.loss), config,
                        mesh=mesh)
    return sess.solve(Scalar(lam=float(lam), sharded=True))


def saif_fused_distributed(X, y, parent, lam: float, mesh, config=None,
                           transform_backend: str = "auto"):
    """DEPRECATED legacy frontend — tree fused LASSO with feature-sharded
    screening (DESIGN.md §5/§7) as a one-shot session.

    The Theorem-6 transform runs once (device-native, chain Pallas kernel
    or level-schedule scan); the *transformed* design — edge columns plus
    the unpenalized b column — is then column-partitioned across the mesh
    exactly like a plain design, so the O(p) fused screening scan is the
    sharded collective while the active block, the b slot and the CM
    sweeps stay replicated. Returns (beta in node space, SaifResult).
    Use ``repro.open_session(Problem(X, y, penalty=fused(parent)), config,
    mesh=mesh).solve(Scalar(lam, sharded=True))`` (DESIGN.md §9).
    """
    from repro.core._compat import warn_deprecated
    warn_deprecated("repro.distributed.saif_fused_distributed",
                    "session.solve(Scalar(lam, sharded=True)) with "
                    "penalty=fused(parent)")
    from repro.core.api import Problem, Scalar, fused, open_session
    from repro.core.saif import SaifConfig

    config = config or SaifConfig()
    sess = open_session(
        Problem(X=X, y=y, loss=config.loss,
                penalty=fused(parent, transform_backend=transform_backend)),
        config, mesh=mesh)
    return sess.solve(Scalar(lam=float(lam), sharded=True))
