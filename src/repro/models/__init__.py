"""Model zoo: unified causal LM over the assigned architecture families."""
from repro.models.config import ModelConfig
from repro.models.lm import (backbone, decode_step, fill_cross_cache, init,
                             init_decode_state, train_loss)
