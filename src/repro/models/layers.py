"""Shared transformer layers: norms, RoPE, GQA attention, MLP variants.

All functions are pure (params passed explicitly) and batched over (B, S, D).
Sharding is applied by the caller via with_sharding_constraint; these layers
only provide the math. KV caches are explicit pytrees for the decode path.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def rms_norm(x, w, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w.astype(x.dtype)


def rope_freqs(hd: int, theta: float, positions):
    """positions: (...,) int32 -> cos/sin of shape (..., hd//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, hd); cos/sin: (B, S, hd//2) or (S, hd//2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


class KVCache(NamedTuple):
    k: jax.Array   # (B, S_max, Hkv, hd)
    v: jax.Array
    # ring-buffer semantics when window > 0: slot = pos % S_max


def gqa_attention(q, k, v, *, causal: bool, window: int = 0,
                  q_offset: int | jax.Array = 0):
    """Grouped-query attention.

    q: (B, Sq, H, hd); k, v: (B, Sk, Hkv, hd). H % Hkv == 0.
    ``q_offset``: absolute position of q[0] (for causal masking in decode).
    """
    B, Sq, H, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    g = H // Hkv
    qh = q.reshape(B, Sq, Hkv, g, hd)
    scale = hd ** -0.5
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qh, k,
                        preferred_element_type=jnp.float32) * scale
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(B, Sq, H, hd)


def attention_block(x, p, cfg, *, positions, causal=True, window=0,
                    kv_x: Optional[jax.Array] = None, use_rope=True):
    """Full attention sublayer (projections + GQA + out-proj).

    p: dict with wq (D, H*hd), wk/wv (D, Hkv*hd), wo (H*hd, D).
    kv_x: source of k/v (cross attention) — defaults to x.
    """
    B, S, D = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    src = x if kv_x is None else kv_x
    Skv = src.shape[1]
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (src @ p["wk"]).reshape(B, Skv, Hkv, hd)
    v = (src @ p["wv"]).reshape(B, Skv, Hkv, hd)
    if use_rope and kv_x is None:
        cos, sin = rope_freqs(hd, cfg.rope_theta, positions)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    out = gqa_attention(q, k, v, causal=causal and kv_x is None,
                        window=window)
    return out.reshape(B, S, H * hd) @ p["wo"]


def attention_decode(x, p, cfg, cache: KVCache, pos, *, window=0,
                     kv_cached: bool = False):
    """One-token decode with KV cache update. x: (B, 1, D); pos scalar int.

    Returns (out (B,1,D), new_cache). When ``window`` > 0 the cache is a ring
    buffer of size window (sub-quadratic memory); otherwise size S_max.
    """
    B, _, D = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, 1, H, hd)
    if kv_cached:
        # cross-attention: cache holds precomputed encoder/image k,v (no RoPE)
        out = gqa_attention(q, cache.k, cache.v, causal=False)
        return out.reshape(B, 1, H * hd) @ p["wo"], cache
    k = (x @ p["wk"]).reshape(B, 1, Hkv, hd)
    v = (x @ p["wv"]).reshape(B, 1, Hkv, hd)
    cos, sin = rope_freqs(hd, cfg.rope_theta, jnp.asarray([pos]))
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    S_max = cache.k.shape[1]
    slot = pos % S_max if window > 0 else pos
    k_all = jax.lax.dynamic_update_slice_in_dim(cache.k, k, slot, axis=1)
    v_all = jax.lax.dynamic_update_slice_in_dim(cache.v, v, slot, axis=1)
    new_cache = KVCache(k_all, v_all)

    g = H // Hkv
    qh = q.reshape(B, 1, Hkv, g, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qh, k_all,
                        preferred_element_type=jnp.float32) * hd ** -0.5
    kpos = jnp.arange(S_max)
    if window > 0:
        # ring buffer: valid slots are the last min(pos+1, window) writes
        age = (slot - kpos) % S_max
        valid = age < jnp.minimum(pos + 1, S_max)
    else:
        valid = kpos <= pos
    logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v_all).reshape(B, 1, H * hd)
    return out @ p["wo"], new_cache


def mlp_block(x, p, act: str):
    """Dense FFN. swiglu: w1,w3,w2; gelu/sq_relu: w1,w2."""
    if act == "swiglu":
        h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    elif act == "gelu":
        h = jax.nn.gelu(x @ p["w1"])
    elif act == "sq_relu":
        h = jnp.square(jax.nn.relu(x @ p["w1"]))
    else:
        raise ValueError(act)
    return h @ p["w2"]


def shard(x, spec: P):
    """with_sharding_constraint that is a no-op outside a mesh context
    (CPU unit tests) or when the named axes don't exist on the active mesh
    or don't divide the dims."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:       # noqa: BLE001 — strictly best-effort
        return x
