"""Architecture configuration schema for the model zoo (deliverable f).

One ``ModelConfig`` describes any of the assigned families:
  dense   — standard decoder-only transformer (GQA + RoPE)
  moe     — dense attention + top-k routed expert FFN
  ssm     — recurrent blocks only (xLSTM: mLSTM/sLSTM mix)
  hybrid  — parallel attention + SSM heads in each layer (Hymba)
  encdec  — encoder-decoder backbone (Whisper; stub audio frontend)
  vlm     — decoder with interleaved cross-attention layers (Llama-vision;
            stub vision tower)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    mlp_act: str = "swiglu"                 # swiglu | gelu | sq_relu
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # --- SSM / xLSTM / hybrid ---
    ssm_state: int = 0            # N (mamba state size / per-head kv rank)
    ssm_expand: int = 2           # mamba inner expansion
    ssm_chunk: int = 128          # chunkwise-parallel scan chunk length
    window: int = 0               # sliding-window size (0 = full attention)

    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    n_frames: int = 1500          # stub audio frontend output length

    # --- vlm ---
    cross_every: int = 0          # insert a cross-attn layer every k layers
    n_image_tokens: int = 0       # stub vision tower output length

    # --- numerics / training ---
    dtype: str = "bfloat16"       # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True
    tie_embeddings: bool = False
    chunk_unroll: bool = False    # fully unroll the SSM/mLSTM chunk scans
    # (expensive HLO; only for small shapes — see dryrun notes)
    scan_unroll: bool = False     # fully unroll layer scans. Used by
    # the roofline pass: XLA cost_analysis counts a while-loop body ONCE, so
    # scanned-layer FLOPs/bytes/collectives are undercounted by ~n_layers;
    # the dry-run lowers small unrolled variants and extrapolates
    # total(L) = fixed + L * body  (see launch/dryrun.py).

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k? (SSM-only or windowed-hybrid.)"""
        return self.family == "ssm" or (self.family == "hybrid"
                                        and self.window > 0)

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced-config variant for CPU smoke tests."""
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline
        MODEL_FLOPS = 6*N*D."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        hd, H, Hkv = self.hd, self.n_heads, self.n_kv_heads
        attn = D * H * hd + 2 * D * Hkv * hd + H * hd * D
        if self.family == "moe":
            ff = self.n_experts * (3 * D * F) + D * self.n_experts
        elif self.mlp_act == "swiglu":
            ff = 3 * D * F
        elif self.family == "ssm":
            ff = 0
        else:
            ff = 2 * D * F
        if self.family == "ssm":
            # mLSTM: q,k,v,o projections + i/f/o gates
            per_layer = 4 * D * D + 3 * D * H
        elif self.family == "hybrid":
            Di = self.ssm_expand * D
            ssm = D * 2 * Di + Di * (2 * self.ssm_state + Di // 16 + 1) \
                + Di * D
            per_layer = attn + ff + ssm
        else:
            per_layer = attn + ff
        n_cross = (self.n_layers // self.cross_every) if self.cross_every else 0
        cross = n_cross * (2 * D * H * hd + 2 * D * Hkv * hd)
        enc = self.n_enc_layers * (attn + ff)
        emb = V * D * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + cross + enc + emb

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.family != "moe":
            return self.param_count()
        D, F = self.d_model, self.d_ff
        full = self.param_count()
        ff_all = self.n_layers * self.n_experts * 3 * D * F
        ff_act = self.n_layers * self.top_k * 3 * D * F
        return full - ff_all + ff_act
