"""Top-k routed mixture-of-experts FFN (token-choice, sort-based dispatch).

Dispatch strategy (static shapes, EP-shardable):
  1. router logits -> top_k (expert_id, prob) per token
  2. flatten the T*k assignments; compute each assignment's rank within its
     expert via a sort-free cumulative count (one-hot cumsum)
  3. scatter token rows into an (E, C, D) buffer (assignments past capacity
     C are DROPPED — standard token-dropping MoE; C = T*k/E * capacity_factor)
  4. batched expert matmul (E, C, D) x (E, D, F) on the MXU
  5. weighted scatter-add back to (T, D)

Sharding: the (E, ...) dims live on the `model` axis (expert parallelism);
token dims on `data`. XLA inserts the all-to-all-equivalent collectives at
the gather/scatter boundaries.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import shard


def moe_ffn(x, p, cfg):
    """x: (B, S, D). p: router (D, E), w1/w3 (E, D, F), w2 (E, F, D)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    F = cfg.d_ff
    T = B * S
    xt = x.reshape(T, D)

    logits = (xt @ p["router"]).astype(jnp.float32)          # (T, E)
    topv, topi = jax.lax.top_k(logits, k)                    # (T, k)
    probs = jax.nn.softmax(topv, axis=-1).astype(x.dtype)    # renormalized

    # ---- assignment ranks within each expert (T*k,) -----------------------
    # sort-based ranking (§Perf): the one_hot+cumsum formulation
    # materializes a (T*k, E) intermediate and is cost-modelled
    # quadratically by XLA — it dominated the MoE train cells' compute term
    # (hundreds of seconds). Stable-sort by expert id instead: O(n log n)
    # comparisons, no big intermediate.
    flat_e = topi.reshape(-1)                                # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))       # (E,)
    rank_sorted = jnp.arange(T * k) - starts[sorted_e]
    rank = jnp.zeros((T * k,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))

    C = max(int(T * k / E * cfg.capacity_factor), 1)
    C = -(-C // 256) * 256 if C > 256 else C   # pad: data-shardable dim
    keep = rank < C
    slot = jnp.where(keep, flat_e * C + rank, E * C)         # E*C => dropped

    # ---- dispatch: (E*C, D) buffer ----------------------------------------
    tok_of_assign = jnp.repeat(jnp.arange(T), k)
    buf = jnp.zeros((E * C + 1, D), x.dtype)
    buf = buf.at[slot].add(xt[tok_of_assign])
    buf = buf[:-1].reshape(E, C, D)
    # Explicit EP constraint (§Perf): XLA's sharding propagation does not
    # survive the dispatch scatter — without this the expert einsums get
    # REPLICATED on every device (observed: per-device HLO flops == global
    # flops on the 256-chip mesh). Pin the expert dim to the model axis.
    buf = shard(buf, P("model", None, None))

    # ---- expert computation (batched over E) ------------------------------
    if cfg.mlp_act == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w1"])) \
            * jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, p["w1"]))
    h = shard(h, P("model", None, None))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w2"])
    out_buf = shard(out_buf, P("model", None, None)).reshape(E * C, D)

    # ---- combine: weighted scatter back to tokens --------------------------
    gathered = jnp.where(keep[:, None],
                         out_buf[jnp.clip(slot, 0, E * C - 1)], 0.0)
    w = probs.reshape(-1)[:, None].astype(x.dtype)
    out = jnp.zeros((T, D), x.dtype).at[tok_of_assign].add(gathered * w)

    # auxiliary load-balance loss (Switch-style)
    me = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=0)           # (E,)
    ce = jnp.mean(jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    return out.reshape(B, S, D), aux
