"""Unified causal LM over all assigned architecture families.

Parameters are stored as stacked per-layer pytrees (leading dim = n_layers)
and applied with ``lax.scan`` — HLO size stays O(1) in depth, which keeps the
40-cell dry-run compilable. Sharding constraints (DP/TP/EP) are injected by
``repro.launch.shardings``; this module is mesh-agnostic.

Entry points:
  init(rng, cfg)                  -> params
  train_loss(params, batch, cfg)  -> scalar loss   (used by train_step)
  prefill(params, tokens, cfg)    -> (logits_last, caches)
  decode_step(params, tok, pos, caches, cfg) -> (logits, caches)
  input_specs(cfg, shape)         -> ShapeDtypeStruct stand-ins (dry-run)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig
from repro.models.layers import (KVCache, attention_block, attention_decode,
                                 mlp_block, rms_norm)
from repro.models.moe import moe_ffn

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _dense_block_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    D, F = cfg.d_model, cfg.d_ff
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    s = {"ln1": (D,), "ln2": (D,),
         "wq": (D, H * hd), "wk": (D, Hkv * hd), "wv": (D, Hkv * hd),
         "wo": (H * hd, D)}
    if cfg.family == "moe":
        E, Fe = cfg.n_experts, cfg.d_ff
        s |= {"router": (D, E), "w1": (E, D, Fe), "w3": (E, D, Fe),
              "w2": (E, Fe, D)}
    elif F > 0:
        if cfg.mlp_act == "swiglu":
            s |= {"w1": (D, F), "w3": (D, F), "w2": (F, D)}
        else:
            s |= {"w1": (D, F), "w2": (F, D)}
    if cfg.family == "hybrid":
        Di = cfg.ssm_expand * D
        N = cfg.ssm_state
        dt_rank = max(D // 16, 1)
        s |= {"in_proj": (D, 2 * Di), "conv": (4, Di),
              "x_proj": (Di, dt_rank + 2 * N), "dt_proj": (dt_rank, Di),
              "A_log": (Di, N), "Dskip": (Di,), "out_proj": (Di, D)}
    return s


def _cross_block_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    D = cfg.d_model
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return {"ln": (D,), "wq": (D, H * hd), "wk": (D, Hkv * hd),
            "wv": (D, Hkv * hd), "wo": (H * hd, D)}


def _mlstm_block_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    D, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    return {"ln": (D,), "wq": (D, H * hd), "wk": (D, H * hd),
            "wv": (D, H * hd), "wi": (D, H), "wf": (D, H),
            "wo_gate": (D, H * hd), "out": (H * hd, D)}


def _slstm_block_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    D = cfg.d_model
    return {"ln": (D,), "wz": (D, D), "wi": (D, D), "wf": (D, D),
            "wo": (D, D), "out": (D, D)}


def n_slstm_layers(cfg: ModelConfig) -> int:
    return cfg.n_layers // 4 if cfg.family == "ssm" else 0


def param_shapes(cfg: ModelConfig) -> Dict[str, Any]:
    """Full parameter shape tree (used by init and by the dry-run specs)."""
    D, V = cfg.d_model, cfg.vocab
    L = cfg.n_layers
    tree: Dict[str, Any] = {
        "embed": (V, D),
        "final_ln": (D,),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = (D, V)
    if cfg.family == "ssm":
        Ls = n_slstm_layers(cfg)
        Lm = L - Ls
        tree["blocks_m"] = {k: (Lm, *v)
                            for k, v in _mlstm_block_shapes(cfg).items()}
        if Ls:
            tree["blocks_s"] = {k: (Ls, *v)
                                for k, v in _slstm_block_shapes(cfg).items()}
    else:
        tree["blocks"] = {k: (L, *v)
                          for k, v in _dense_block_shapes(cfg).items()}
    if cfg.family == "vlm" and cfg.cross_every:
        G = L // cfg.cross_every
        tree["cross_blocks"] = {k: (G, *v)
                                for k, v in _cross_block_shapes(cfg).items()}
        tree["img_proj"] = (D, D)   # stub vision tower output -> d_model
    if cfg.family == "encdec":
        Le = cfg.n_enc_layers
        enc_cfg = cfg
        tree["enc_blocks"] = {k: (Le, *v)
                              for k, v in _dense_block_shapes(enc_cfg).items()}
        tree["enc_ln"] = (D,)
        tree["cross_blocks"] = {k: (L, *v)
                                for k, v in _cross_block_shapes(cfg).items()}
    return tree


def init(rng: jax.Array, cfg: ModelConfig) -> Params:
    shapes = param_shapes(cfg)
    leaves, treedef = jax.tree.flatten(shapes,
                                       is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(rng, len(leaves))

    def one(key, shp):
        if len(shp) >= 2:
            fan_in = shp[-2]
            w = jax.random.normal(key, shp, cfg.pdtype) * fan_in ** -0.5
        else:
            w = jnp.ones(shp, cfg.pdtype)
        return w

    params = jax.tree.unflatten(treedef, [one(k, s)
                                          for k, s in zip(keys, leaves)])
    # norms start at 1, A_log at small positive, Dskip at 1
    def fix(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name.startswith("ln") or name in ("final_ln", "enc_ln"):
            return jnp.ones_like(x)
        if name == "A_log":
            return jnp.zeros_like(x)        # A = -1
        if name == "Dskip":
            return jnp.ones_like(x)
        return x
    return jax.tree_util.tree_map_with_path(fix, params)


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------

def _dense_layer(x, bp, cfg, positions, window):
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    attn = attention_block(h, bp, cfg, positions=positions, causal=True,
                           window=window)
    if cfg.family == "hybrid":
        attn = 0.5 * (attn + ssm_lib.mamba_block(h, bp, cfg))
    x = x + attn
    h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        ff, aux = moe_ffn(h2, bp, cfg)
    elif cfg.d_ff > 0:
        ff, aux = mlp_block(h2, bp, cfg.mlp_act), 0.0
    else:
        ff, aux = 0.0, 0.0
    return x + ff, aux



def _lscan(f, init, xs, cfg):
    """lax.scan honoring cfg.scan_unroll (roofline cost-correction mode)."""
    n = jax.tree.leaves(xs)[0].shape[0]
    return jax.lax.scan(f, init, xs, unroll=n if cfg.scan_unroll else 1)

def _cast_params(p, cfg):
    """Compute-dtype cast (bf16 compute / fp32 master weights)."""
    return jax.tree.map(lambda a: a.astype(cfg.adtype), p)


def _scan_layers(x, blocks, layer_fn, cfg):
    """lax.scan over stacked layer params, with optional remat."""
    body = layer_fn
    if cfg.remat:
        body = jax.checkpoint(body)

    def step(carry, bp):
        x, aux = carry
        x, a = body(x, _cast_params(bp, cfg))
        return (x, aux + a), None

    n = jax.tree.leaves(blocks)[0].shape[0]
    (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)), blocks,
                               unroll=n if cfg.scan_unroll else 1)
    return x, aux


def backbone(params: Params, tokens, cfg: ModelConfig, *,
             img_embed=None, frames=None):
    """Token ids (B, S) -> final hidden states (B, S, D) + aux loss."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.adtype)
    positions = jnp.arange(S)[None, :]
    window = cfg.window

    aux_total = 0.0
    if cfg.family == "ssm":
        def mbody(x, bp):
            h = rms_norm(x, bp["ln"], cfg.norm_eps)
            return x + ssm_lib.mlstm_block(h, bp, cfg), 0.0
        x, _ = _scan_layers(x, params["blocks_m"], mbody, cfg)
        if "blocks_s" in params:
            def sbody(x, bp):
                h = rms_norm(x, bp["ln"], cfg.norm_eps)
                return x + ssm_lib.slstm_block(h, bp, cfg), 0.0
            x, _ = _scan_layers(x, params["blocks_s"], sbody, cfg)
    elif cfg.family == "vlm" and cfg.cross_every and img_embed is not None:
        img = (img_embed.astype(cfg.adtype)
               @ params["img_proj"].astype(cfg.adtype))
        G = cfg.n_layers // cfg.cross_every
        grouped = jax.tree.map(
            lambda a: a.reshape(G, cfg.cross_every, *a.shape[1:]),
            params["blocks"])

        def group(carry, gp):
            x, aux = carry
            bp_group, cp = gp
            def dbody(x, bp):
                return _dense_layer(x, bp, cfg, positions, window)
            x, a = _scan_layers(x, bp_group, dbody, cfg)
            # cross-attention to image tokens
            cp = _cast_params(cp, cfg)
            h = rms_norm(x, cp["ln"], cfg.norm_eps)
            x = x + attention_block(h, cp, cfg, positions=positions,
                                    causal=False, kv_x=img, use_rope=False)
            return (x, aux + a), None

        G_un = G if cfg.scan_unroll else 1
        (x, aux_total), _ = jax.lax.scan(group, (x, 0.0),
                                         (grouped, params["cross_blocks"]),
                                         unroll=G_un)
    elif cfg.family == "encdec":
        # encoder over stub frame embeddings (bidirectional)
        enc = frames.astype(cfg.adtype)
        enc_pos = jnp.arange(enc.shape[1])[None, :]

        def ebody(e, bp):
            h = rms_norm(e, bp["ln1"], cfg.norm_eps)
            a = attention_block(h, bp, cfg, positions=enc_pos, causal=False)
            e = e + a
            h2 = rms_norm(e, bp["ln2"], cfg.norm_eps)
            return e + mlp_block(h2, bp, cfg.mlp_act), 0.0
        enc, _ = _scan_layers(enc, params["enc_blocks"], ebody, cfg)
        enc = rms_norm(enc, params["enc_ln"], cfg.norm_eps)

        def dbody(x, bp):
            blk, cp = bp
            x, a = _dense_layer(x, blk, cfg, positions, window)
            h = rms_norm(x, cp["ln"], cfg.norm_eps)
            x = x + attention_block(h, cp, cfg, positions=positions,
                                    causal=False, kv_x=enc, use_rope=False)
            return x, a
        x, aux_total = _scan_layers(
            x, (params["blocks"], params["cross_blocks"]), dbody, cfg)
    else:
        def dbody(x, bp):
            return _dense_layer(x, bp, cfg, positions, window)
        x, aux_total = _scan_layers(x, params["blocks"], dbody, cfg)

    return rms_norm(x, params["final_ln"], cfg.norm_eps), aux_total


def logits_fn(params, hidden, cfg):
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cfg.adtype)
    return hidden @ head


def train_loss(params: Params, batch: Dict[str, jax.Array],
               cfg: ModelConfig) -> jax.Array:
    """Next-token cross-entropy (+ MoE aux). batch: tokens, labels (B, S)."""
    hidden, aux = backbone(params, batch["tokens"], cfg,
                           img_embed=batch.get("img_embed"),
                           frames=batch.get("frames"))
    logits = logits_fn(params, hidden, cfg).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, batch["labels"][..., None],
                             axis=-1)[..., 0]
    loss = -jnp.mean(ll)
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode with caches
# ---------------------------------------------------------------------------

class DecodeState(NamedTuple):
    caches: Any        # per-family cache pytree (stacked over layers)
    pos: jax.Array     # current position (scalar int32)


def init_decode_state(params, cfg: ModelConfig, batch: int, s_max: int,
                      *, img_embed=None, frames=None) -> DecodeState:
    """Allocate empty caches sized for ``s_max`` context."""
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    L = cfg.n_layers
    cache_len = min(cfg.window, s_max) if cfg.window else s_max
    dt = cfg.adtype

    def kv(leading):
        return KVCache(jnp.zeros((leading, batch, cache_len, Hkv, hd), dt),
                       jnp.zeros((leading, batch, cache_len, Hkv, hd), dt))

    if cfg.family == "ssm":
        Lm = L - n_slstm_layers(cfg)
        caches = {"m": ssm_lib.MLSTMState(
            C=jnp.zeros((Lm, batch, cfg.n_heads, hd, hd), dt),
            n=jnp.zeros((Lm, batch, cfg.n_heads, hd), dt))}
        if n_slstm_layers(cfg):
            Ls = n_slstm_layers(cfg)
            caches["s"] = ssm_lib.SLSTMState(
                c=jnp.zeros((Ls, batch, cfg.d_model), jnp.float32),
                n=jnp.zeros((Ls, batch, cfg.d_model), jnp.float32))
    elif cfg.family == "hybrid":
        Di = cfg.ssm_expand * cfg.d_model
        caches = {"kv": kv(L),
                  "ssm": ssm_lib.MambaState(
                      h=jnp.zeros((L, batch, Di, cfg.ssm_state), dt),
                      conv=jnp.zeros((L, batch, Di, 3), dt))}
    elif cfg.family in ("vlm", "encdec"):
        n_cross = (cfg.n_layers // cfg.cross_every if cfg.family == "vlm"
                   else cfg.n_layers)
        src_len = (cfg.n_image_tokens if cfg.family == "vlm"
                   else cfg.n_frames)
        caches = {"kv": kv(L),
                  "cross": KVCache(
                      jnp.zeros((n_cross, batch, src_len, Hkv, hd), dt),
                      jnp.zeros((n_cross, batch, src_len, Hkv, hd), dt))}
    else:
        caches = {"kv": kv(L)}
    return DecodeState(caches=caches, pos=jnp.asarray(0, jnp.int32))


def decode_step(params: Params, tok, state: DecodeState, cfg: ModelConfig
                ) -> Tuple[jax.Array, DecodeState]:
    """One new token for every sequence. tok: (B,) int32."""
    B = tok.shape[0]
    x = params["embed"][tok][:, None].astype(cfg.adtype)   # (B, 1, D)
    pos = state.pos
    caches = state.caches

    if cfg.family == "ssm":
        def mstep(x, bp_cache):
            bp, c = bp_cache
            bp = _cast_params(bp, cfg)
            h = rms_norm(x, bp["ln"], cfg.norm_eps)
            y, c2 = ssm_lib.mlstm_decode(h, bp, cfg, c)
            return x + y, c2

        x, new_m = _lscan(mstep, x, (params["blocks_m"], caches["m"]), cfg)
        new_caches = {"m": new_m}
        if "blocks_s" in params:
            def sstep(x, bc):
                bp, c = bc
                bp = _cast_params(bp, cfg)
                h = rms_norm(x, bp["ln"], cfg.norm_eps)
                y, c2 = ssm_lib.slstm_decode(h, bp, cfg, c)
                return x + y, c2
            x, new_s = _lscan(sstep, x,
                              (params["blocks_s"], caches["s"]), cfg)
            new_caches["s"] = new_s
    elif cfg.family == "hybrid":
        def hstep(x, bc):
            bp, kvc, sc = bc
            bp = _cast_params(bp, cfg)
            h = rms_norm(x, bp["ln1"], cfg.norm_eps)
            a, kv2 = attention_decode(h, bp, cfg, kvc, pos,
                                      window=cfg.window)
            m, sc2 = ssm_lib.mamba_decode(h, bp, cfg, sc)
            x = x + 0.5 * (a + m)
            h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
            x = x + mlp_block(h2, bp, cfg.mlp_act)
            return x, (kv2, sc2)
        x, (new_kv, new_ssm) = _lscan(
            hstep, x, (params["blocks"], caches["kv"], caches["ssm"]), cfg)
        new_caches = {"kv": new_kv, "ssm": new_ssm}
    elif cfg.family == "vlm":
        G = cfg.n_layers // cfg.cross_every
        grouped = jax.tree.map(
            lambda a: a.reshape(G, cfg.cross_every, *a.shape[1:]),
            params["blocks"])
        kv_grouped = jax.tree.map(
            lambda a: a.reshape(G, cfg.cross_every, *a.shape[1:]),
            caches["kv"])

        def gstep(x, bc):
            bp_group, cp, kvg, crossc = bc

            def dstep(x, bc2):
                bp, kvc = bc2
                bp = _cast_params(bp, cfg)
                h = rms_norm(x, bp["ln1"], cfg.norm_eps)
                a, kv2 = attention_decode(h, bp, cfg, kvc, pos)
                x = x + a
                h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
                return x + mlp_block(h2, bp, cfg.mlp_act), kv2
            x, kv2 = _lscan(dstep, x, (bp_group, kvg), cfg)
            cp = _cast_params(cp, cfg)
            h = rms_norm(x, cp["ln"], cfg.norm_eps)
            a, _ = attention_decode(h, cp, cfg, crossc, pos, kv_cached=True)
            return x + a, kv2
        x, new_kv_g = _lscan(
            gstep, x, (grouped, params["cross_blocks"], kv_grouped,
                       caches["cross"]), cfg)
        new_kv = jax.tree.map(
            lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), new_kv_g)
        new_caches = {"kv": new_kv, "cross": caches["cross"]}
    elif cfg.family == "encdec":
        def estep(x, bc):
            bp, cp, kvc, crossc = bc
            bp = _cast_params(bp, cfg)
            cp = _cast_params(cp, cfg)
            h = rms_norm(x, bp["ln1"], cfg.norm_eps)
            a, kv2 = attention_decode(h, bp, cfg, kvc, pos)
            x = x + a
            h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
            x = x + mlp_block(h2, bp, cfg.mlp_act)
            hc = rms_norm(x, cp["ln"], cfg.norm_eps)
            a2, _ = attention_decode(hc, cp, cfg, crossc, pos,
                                     kv_cached=True)
            return x + a2, kv2
        x, new_kv = _lscan(
            estep, x, (params["blocks"], params["cross_blocks"],
                       caches["kv"], caches["cross"]), cfg)
        new_caches = {"kv": new_kv, "cross": caches["cross"]}
    else:
        def dstep(x, bc):
            bp, kvc = bc
            bp = _cast_params(bp, cfg)
            h = rms_norm(x, bp["ln1"], cfg.norm_eps)
            a, kv2 = attention_decode(h, bp, cfg, kvc, pos,
                                      window=cfg.window)
            x = x + a
            h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                ff, _ = moe_ffn(h2, bp, cfg)
            else:
                ff = mlp_block(h2, bp, cfg.mlp_act)
            return x + ff, kv2
        x, new_kv = _lscan(dstep, x, (params["blocks"], caches["kv"]), cfg)
        new_caches = {"kv": new_kv}

    hidden = rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = logits_fn(params, hidden, cfg)[:, 0]
    return logits, DecodeState(caches=new_caches, pos=pos + 1)


def fill_cross_cache(params, cfg, state: DecodeState, *, img_embed=None,
                     frames=None) -> DecodeState:
    """Populate cross-attention caches from the stub frontend embeddings."""
    if cfg.family == "vlm":
        img = img_embed.astype(cfg.adtype) \
            @ params["img_proj"].astype(cfg.adtype)
        Hkv, hd = cfg.n_kv_heads, cfg.hd

        def proj(cp):
            cp = _cast_params(cp, cfg)
            B, Si, _ = img.shape
            k = (img @ cp["wk"]).reshape(B, Si, Hkv, hd)
            v = (img @ cp["wv"]).reshape(B, Si, Hkv, hd)
            return KVCache(k, v)
        cross = jax.vmap(proj)(params["cross_blocks"])
        return state._replace(caches={**state.caches, "cross": cross})
    if cfg.family == "encdec":
        # run the encoder once, then project k/v per decoder layer
        enc = frames.astype(cfg.adtype)
        enc_pos = jnp.arange(enc.shape[1])[None, :]

        def ebody(e, bp):
            h = rms_norm(e, bp["ln1"], cfg.norm_eps)
            a = attention_block(h, bp, cfg, positions=enc_pos, causal=False)
            e = e + a
            h2 = rms_norm(e, bp["ln2"], cfg.norm_eps)
            return e + mlp_block(h2, bp, cfg.mlp_act), 0.0
        enc, _ = _scan_layers(enc, params["enc_blocks"], ebody, cfg)
        enc = rms_norm(enc, params["enc_ln"], cfg.norm_eps)
        Hkv, hd = cfg.n_kv_heads, cfg.hd

        def proj(cp):
            cp = _cast_params(cp, cfg)
            B, Sf, _ = enc.shape
            k = (enc @ cp["wk"]).reshape(B, Sf, Hkv, hd)
            v = (enc @ cp["wv"]).reshape(B, Sf, Hkv, hd)
            return KVCache(k, v)
        cross = jax.vmap(proj)(params["cross_blocks"])
        return state._replace(caches={**state.caches, "cross": cross})
    return state
