"""Recurrent sequence blocks: selective SSM (Mamba-style), mLSTM, sLSTM.

All recurrences are implemented in **chunkwise-parallel** form where the
state is matrix-valued (Mamba, mLSTM): a lax.scan over chunks carries the
recurrent state; within a chunk the recurrence is evaluated in parallel
(associative_scan / decay-weighted attention). This bounds live memory to
O(B * state * S/chunk) boundary states instead of O(B * state * S), which is
what makes the 500k-token shapes feasible (see DESIGN.md §4).

Decode paths carry the state explicitly — O(1) per token.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


# ===========================================================================
# Mamba-style selective SSM
# ===========================================================================

class MambaState(NamedTuple):
    h: jax.Array      # (B, Di, N) SSM state
    conv: jax.Array   # (B, Di, K-1) causal-conv tail


def _ssm_chunk_scan(u, dt, Bm, Cm, A, chunk: int, unroll=False):
    """Chunked selective-SSM scan.

    u: (B, S, Di); dt: (B, S, Di); Bm/Cm: (B, S, N); A: (Di, N) (negative).
    Returns y: (B, S, Di).
    """
    B, S, Di = u.shape
    N = A.shape[1]
    nc = S // chunk
    uc = u.reshape(B, nc, chunk, Di)
    dtc = dt.reshape(B, nc, chunk, Di)
    Bc = Bm.reshape(B, nc, chunk, N)
    Cc = Cm.reshape(B, nc, chunk, N)

    def chunk_body(h, inp):
        uq, dtq, bq, cq = inp                     # (B, Q, ...)
        # discretize: a_t = exp(dt_t * A)  (B, Q, Di, N); b_t = dt*u*B
        da = jnp.exp(dtq[..., None] * A[None, None])          # (B,Q,Di,N)
        db = (dtq * uq)[..., None] * bq[:, :, None, :]        # (B,Q,Di,N)

        # parallel prefix over the chunk: h_t = a_t h_{t-1} + b_t
        def combine(x, y):
            ax, bx = x
            ay, by = y
            return ax * ay, ay * bx + by

        a_pref, b_pref = jax.lax.associative_scan(combine, (da, db), axis=1)
        hs = a_pref * h[:, None] + b_pref                     # (B,Q,Di,N)
        y = jnp.einsum("bqdn,bqn->bqd", hs, cq)
        h_new = hs[:, -1]
        return h_new, y

    h0 = jnp.zeros((B, Di, N), u.dtype)
    body = jax.checkpoint(chunk_body)
    _, ys = jax.lax.scan(body, h0,
                         (uc.transpose(1, 0, 2, 3), dtc.transpose(1, 0, 2, 3),
                          Bc.transpose(1, 0, 2, 3), Cc.transpose(1, 0, 2, 3)),
                         unroll=nc if unroll else 1)
    return ys.transpose(1, 0, 2, 3).reshape(B, S, Di)


def mamba_block(x, p, cfg):
    """Selective-SSM sublayer. x: (B, S, D) -> (B, S, D).

    p: in_proj (D, 2Di), conv (K, Di), x_proj (Di, dt_rank + 2N),
       dt_proj (dt_rank, Di), A_log (Di, N), Dskip (Di,), out_proj (Di, D).
    """
    B, S, D = x.shape
    Di = cfg.ssm_expand * cfg.d_model
    N = cfg.ssm_state
    K = p["conv"].shape[0]
    dt_rank = p["dt_proj"].shape[0]

    ur = x @ p["in_proj"]                                     # (B, S, 2Di)
    u, res = jnp.split(ur, 2, axis=-1)
    # causal depthwise conv (kernel K)
    upad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    u = sum(upad[:, i:i + S] * p["conv"][i][None, None]
            for i in range(K))
    u = jax.nn.silu(u)

    proj = u @ p["x_proj"]                                    # (B,S,rank+2N)
    dt_low, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["dt_proj"])               # (B, S, Di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32)).astype(x.dtype)

    chunk = min(cfg.ssm_chunk, S)
    if S % chunk:
        pad = chunk - S % chunk
        y = _ssm_chunk_scan(jnp.pad(u, ((0, 0), (0, pad), (0, 0))),
                            jnp.pad(dt, ((0, 0), (0, pad), (0, 0))),
                            jnp.pad(Bm, ((0, 0), (0, pad), (0, 0))),
                            jnp.pad(Cm, ((0, 0), (0, pad), (0, 0))),
                            A, chunk, cfg.chunk_unroll)[:, :S]
    else:
        y = _ssm_chunk_scan(u, dt, Bm, Cm, A, chunk, cfg.chunk_unroll)
    y = y + u * p["Dskip"][None, None]
    return (y * jax.nn.silu(res)) @ p["out_proj"]


def mamba_init_state(cfg, batch, dtype) -> MambaState:
    Di = cfg.ssm_expand * cfg.d_model
    return MambaState(h=jnp.zeros((batch, Di, cfg.ssm_state), dtype),
                      conv=jnp.zeros((batch, Di, 3), dtype))


def mamba_decode(x, p, cfg, state: MambaState) -> Tuple[jax.Array, MambaState]:
    """One-token step. x: (B, 1, D)."""
    B = x.shape[0]
    N = cfg.ssm_state
    dt_rank = p["dt_proj"].shape[0]
    K = p["conv"].shape[0]

    ur = x[:, 0] @ p["in_proj"]
    u, res = jnp.split(ur, 2, axis=-1)                        # (B, Di)
    conv_buf = jnp.concatenate([state.conv, u[..., None]], axis=-1)  # (B,Di,K)
    u = jnp.einsum("bdk,kd->bd", conv_buf, p["conv"])
    u = jax.nn.silu(u)
    new_conv = conv_buf[..., 1:]

    proj = u @ p["x_proj"]
    dt_low, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["dt_proj"])               # (B, Di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32)).astype(x.dtype)
    da = jnp.exp(dt[..., None] * A[None])                     # (B, Di, N)
    h = da * state.h + (dt * u)[..., None] * Bm[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cm) + u * p["Dskip"][None]
    out = (y * jax.nn.silu(res)) @ p["out_proj"]
    return out[:, None], MambaState(h=h, conv=new_conv)


# ===========================================================================
# mLSTM (xLSTM matrix-memory block) — chunked linear attention with decay
# ===========================================================================

class MLSTMState(NamedTuple):
    C: jax.Array   # (B, H, dk, dv) matrix memory
    n: jax.Array   # (B, H, dk)     normalizer


def mlstm_block(x, p, cfg):
    """x: (B, S, D). p: wq/wk/wv (D, H*hd), wi/wf (D, H), wo_gate (D, H*hd),
    out (H*hd, D). Chunked parallel evaluation."""
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd) * hd ** -0.5
    k = (x @ p["wk"]).reshape(B, S, H, hd)
    v = (x @ p["wv"]).reshape(B, S, H, hd)
    # gates: log-sigmoid forget, exponential-capped input
    lf = jax.nn.log_sigmoid((x @ p["wf"]).astype(jnp.float32))   # (B,S,H)
    li = (x @ p["wi"]).astype(jnp.float32)
    li = jnp.minimum(li, 10.0)                                    # stability
    og = jax.nn.sigmoid(x @ p["wo_gate"]).reshape(B, S, H, hd)

    Q = min(cfg.ssm_chunk, S)
    if S % Q:
        raise ValueError(f"seq {S} not divisible by chunk {Q}")
    nc = S // Q

    def reshape_c(t):
        return t.reshape(B, nc, Q, *t.shape[2:]).transpose(1, 0, 2,
                                                           *range(3, t.ndim + 1))

    qc, kc, vc = map(reshape_c, (q, k, v))        # (nc, B, Q, H, hd)
    lfc = lf.reshape(B, nc, Q, H).transpose(1, 0, 2, 3)
    lic = li.reshape(B, nc, Q, H).transpose(1, 0, 2, 3)
    ogc = og.reshape(B, nc, Q, H, hd).transpose(1, 0, 2, 3, 4)

    def chunk_body(carry, inp):
        C, n = carry                               # (B,H,dk,dv), (B,H,dk)
        qq, kk, vv, lff, lii, oo = inp
        Lc = jnp.cumsum(lff, axis=1)               # (B, Q, H) inclusive
        # inter-chunk: y_t += (q_t * exp(Lc_t)) C_prev
        dec_t = jnp.exp(Lc).astype(x.dtype)        # decay from chunk start
        y_inter = jnp.einsum("bqhk,bhkv->bqhv", qq * dec_t[..., None], C)
        n_inter = jnp.einsum("bqhk,bhk->bqh", qq * dec_t[..., None], n)
        # intra-chunk: s_{t,tau} = q_t.k_tau exp(Lc_t - Lc_tau + li_tau)
        w = Lc[:, :, None, :] - Lc[:, None, :, :] + lii[:, None, :, :]
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        w = jnp.where(mask[None, :, :, None], w, -jnp.inf)
        wexp = jnp.exp(jnp.minimum(w, 30.0)).astype(x.dtype)  # (B,Qt,Qs,H)
        s = jnp.einsum("bqhk,bshk->bqsh", qq, kk) * wexp
        y = y_inter + jnp.einsum("bqsh,bshv->bqhv", s, vv)
        nrm = n_inter + jnp.sum(s, axis=2)         # q_t . n_t (intra part)
        # normalizer: max(|q.n|, 1) per xLSTM
        denom = jnp.maximum(jnp.abs(nrm), 1.0)[..., None]
        y = oo * (y / denom.astype(x.dtype))
        # state update
        dec_chunk = jnp.exp(Lc[:, -1]).astype(x.dtype)        # (B, H)
        rdec = jnp.exp(Lc[:, -1][:, None] - Lc + lii).astype(x.dtype)  # (B,Q,H)
        C_new = dec_chunk[..., None, None] * C + jnp.einsum(
            "bqhk,bqhv->bhkv", kk * rdec[..., None], vv)
        n_new = dec_chunk[..., None] * n + jnp.einsum(
            "bqh,bqhk->bhk", rdec, kk)
        return (C_new, n_new), y

    C0 = jnp.zeros((B, H, hd, hd), x.dtype)
    n0 = jnp.zeros((B, H, hd), x.dtype)
    body = jax.checkpoint(chunk_body)
    (_, _), ys = jax.lax.scan(body, (C0, n0), (qc, kc, vc, lfc, lic, ogc),
                              unroll=nc if cfg.chunk_unroll else 1)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H * hd)
    return y @ p["out"]


def mlstm_init_state(cfg, batch, dtype) -> MLSTMState:
    H, hd = cfg.n_heads, cfg.hd
    return MLSTMState(C=jnp.zeros((batch, H, hd, hd), dtype),
                      n=jnp.zeros((batch, H, hd), dtype))


def mlstm_decode(x, p, cfg, state: MLSTMState):
    B = x.shape[0]
    H, hd = cfg.n_heads, cfg.hd
    q = (x[:, 0] @ p["wq"]).reshape(B, H, hd) * hd ** -0.5
    k = (x[:, 0] @ p["wk"]).reshape(B, H, hd)
    v = (x[:, 0] @ p["wv"]).reshape(B, H, hd)
    f = jnp.exp(jax.nn.log_sigmoid((x[:, 0] @ p["wf"]).astype(jnp.float32))
                ).astype(x.dtype)                             # (B, H)
    i = jnp.exp(jnp.minimum((x[:, 0] @ p["wi"]).astype(jnp.float32), 10.0)
                ).astype(x.dtype)
    og = jax.nn.sigmoid(x[:, 0] @ p["wo_gate"]).reshape(B, H, hd)
    C = f[..., None, None] * state.C + i[..., None, None] * \
        jnp.einsum("bhk,bhv->bhkv", k, v)
    n = f[..., None] * state.n + i[..., None] * k
    num = jnp.einsum("bhk,bhkv->bhv", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)), 1.0)
    y = og * (num / den[..., None].astype(x.dtype))
    return (y.reshape(B, 1, H * hd) @ p["out"]), MLSTMState(C=C, n=n)


# ===========================================================================
# sLSTM (scalar-memory xLSTM block) — sequential elementwise scan
# ===========================================================================

class SLSTMState(NamedTuple):
    c: jax.Array   # (B, D)
    n: jax.Array   # (B, D)


def slstm_block(x, p, cfg):
    """x: (B, S, D). p: wz/wi/wf/wo (D, D), out (D, D)."""
    B, S, D = x.shape
    z = jnp.tanh(x @ p["wz"])
    i = jnp.exp(jnp.minimum((x @ p["wi"]).astype(jnp.float32), 10.0))
    lf = jax.nn.log_sigmoid((x @ p["wf"]).astype(jnp.float32))
    o = jax.nn.sigmoid(x @ p["wo"])

    # linear recurrence c_t = f_t c_{t-1} + i_t z_t — associative scan
    f = jnp.exp(lf)

    def combine(a, b):
        af, ax = a
        bf, bx = b
        return af * bf, bf * ax + bx

    _, c = jax.lax.associative_scan(
        combine, (f, i * z.astype(jnp.float32)), axis=1)
    _, n = jax.lax.associative_scan(combine, (f, i), axis=1)
    h = o * (c / jnp.maximum(jnp.abs(n), 1.0)).astype(x.dtype)
    return h @ p["out"]


def slstm_init_state(cfg, batch, dtype) -> SLSTMState:
    D = cfg.d_model
    return SLSTMState(c=jnp.zeros((batch, D), jnp.float32),
                      n=jnp.zeros((batch, D), jnp.float32))


def slstm_decode(x, p, cfg, state: SLSTMState):
    z = jnp.tanh(x[:, 0] @ p["wz"])
    i = jnp.exp(jnp.minimum((x[:, 0] @ p["wi"]).astype(jnp.float32), 10.0))
    f = jnp.exp(jax.nn.log_sigmoid((x[:, 0] @ p["wf"]).astype(jnp.float32)))
    o = jax.nn.sigmoid(x[:, 0] @ p["wo"])
    c = f * state.c + i * z.astype(jnp.float32)
    n = f * state.n + i
    h = o * (c / jnp.maximum(jnp.abs(n), 1.0)).astype(x.dtype)
    return (h @ p["out"])[:, None], SLSTMState(c=c, n=n)
