"""Int8 error-feedback gradient compression for DP all-reduce.

Classic EF-SGD scheme: quantize (g + e) to int8 with a per-tensor scale,
all-reduce the int8 payload (8x less ICI traffic on the DP axis), keep the
quantization residual e locally. The error-feedback invariant — the running
sum of applied compressed gradients equals the running sum of true gradients
minus the current residual — makes the scheme convergent; it is asserted
exactly in tests.

Integration: ``train.py --compress-grads`` wraps the loss grad in
``shard_map`` over the dp axes, replacing the implicit all-reduce with
``psum(quantize(g))``.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any   # params-shaped pytree of float32


def init(params) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / 127.0, 1e-30)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, ef: EFState):
    """Per-leaf: c = Q(g + e); new_e = (g + e) - deq(c). Returns
    (quantized tree [(q, scale) per leaf], new EFState)."""
    def one(g, e):
        t = g.astype(jnp.float32) + e
        q, s = quantize(t)
        deq = dequantize(q, s)
        return (q, s), t - deq

    pairs = jax.tree.map(one, grads, ef.residual)
    leaves, treedef = jax.tree.flatten(pairs, is_leaf=lambda t:
                                       isinstance(t, tuple) and len(t) == 2)
    qs = [l[0] for l in leaves]
    es = [l[1] for l in leaves]
    return jax.tree.unflatten(treedef, qs), EFState(
        residual=jax.tree.unflatten(treedef, es))


def decompress_tree(qtree):
    return jax.tree.map(lambda qs: dequantize(*qs), qtree,
                        is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2)


def dp_allreduce_compressed(grads, ef: EFState, axis_names):
    """Inside shard_map: mean-all-reduce int8-compressed grads over dp axes.

    int8 payloads are summed in int32 (no overflow for <= 2^23 replicas),
    then dequantized with the max scale — a standard conservative scheme.
    """
    def one(g, e):
        t = g.astype(jnp.float32) + e
        q, s = quantize(t)
        new_e = t - dequantize(q, s)
        acc = jax.lax.psum(q.astype(jnp.int32), axis_names)
        s_max = jax.lax.pmax(s, axis_names)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_names)
        g_hat = acc.astype(jnp.float32) * s_max / n
        return g_hat, new_e

    pairs = jax.tree.map(one, grads, ef.residual)
    leaves, treedef = jax.tree.flatten(
        pairs, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2)
    return (jax.tree.unflatten(treedef, [l[0] for l in leaves]),
            EFState(residual=jax.tree.unflatten(treedef,
                                                [l[1] for l in leaves])))
