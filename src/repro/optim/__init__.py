from repro.optim import adamw, compress
