"""AdamW + global-norm clipping + cosine schedule (no external deps).

State is a params-shaped pytree pair (m, v) + scalar step. Sharding of m/v
is decided by ``launch.shardings`` (ZeRO-1 style: optimizer moments sharded
over the data axis where the parameter itself is only TP-sharded, so the
optimizer memory scales down with DP size — the collectives XLA inserts are
exactly reduce-scatter + all-gather).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def schedule(cfg: AdamWConfig, step) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(grads, state: AdamWState, params, cfg: AdamWConfig
           ) -> Tuple[Any, AdamWState]:
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-12))
    grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2.astype(m.dtype), v2.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
